//! Serving demo: the L3 coordinator batches concurrent inference
//! requests over the AOT-compiled SmallCNN artifact (PJRT, no Python),
//! while the accelerator simulator reports what the same workload costs
//! on the RRAM chip under naive vs pattern mapping.
//!
//! Run: `make artifacts && cargo run --release --example serve -- --requests 64`

use std::path::Path;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use rram_pattern_accel::config::{HardwareConfig, SimConfig};
use rram_pattern_accel::coordinator::{Coordinator, PjrtBackend};
use rram_pattern_accel::mapping::{
    naive::NaiveMapping, pattern::PatternMapping, MappingScheme,
};
use rram_pattern_accel::runtime::Engine;
use rram_pattern_accel::sim::{self, smallcnn};
use rram_pattern_accel::util::cli::Args;

fn main() {
    let args = Args::new("serving demo over the SmallCNN artifact")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("requests", "64", "demo request count")
        .opt("max-wait-ms", "2", "batcher max wait")
        .parse(std::env::args().skip(1))
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        });
    let dir = args.get("artifacts").to_string();
    let n = args.get_usize("requests").unwrap();
    let wait = Duration::from_millis(args.get_usize("max-wait-ms").unwrap() as u64);

    let td = smallcnn::TestData::load(Path::new(&dir))
        .expect("test data (run `make artifacts` first)");
    let model = smallcnn::SmallCnn::load(Path::new(&dir)).expect("model bundle");

    // --- serving path: PJRT functional model behind the batcher ---
    let hlo = format!("{dir}/smallcnn_b8.hlo.txt");
    let coord = Coordinator::start(
        move || {
            let engine = Engine::load(Path::new(&hlo)).expect("load artifact");
            println!("[serve] engine up on platform {}", engine.platform());
            PjrtBackend {
                engine,
                batch: 8,
                input_shape: vec![3, 32, 32],
                output_len: 10,
            }
        },
        wait,
    );

    let img_len = 3 * 32 * 32;
    let avail = td.test_x.shape[0];
    let t0 = Instant::now();
    // Submit from 4 client threads to exercise the router.
    let replies: Vec<(usize, smallcnn::TestData)> = Vec::new();
    drop(replies);
    let mut correct = 0usize;
    std::thread::scope(|scope| {
        let coord = &coord;
        let td = &td;
        let mut handles = Vec::new();
        for t in 0..4usize {
            handles.push(scope.spawn(move || {
                let mut ok = 0usize;
                for i in (t..n).step_by(4) {
                    let idx = i % avail;
                    let img =
                        &td.test_x.data[idx * img_len..(idx + 1) * img_len];
                    let rx = coord.submit(img.to_vec());
                    let reply = rx.recv().expect("reply");
                    if smallcnn::argmax(reply.logits()) as i32 == td.test_y[idx] {
                        ok += 1;
                    }
                }
                ok
            }));
        }
        for h in handles {
            correct += h.join().unwrap();
        }
    });
    let elapsed = t0.elapsed();
    let lat = coord.metrics.latency_summary();
    println!(
        "[serve] {n} requests, {:.1} req/s, accuracy {:.1}%, {} batches \
         ({} padded slots), latency mean {:.2} ms p50 {:.2} p99 {:.2}",
        n as f64 / elapsed.as_secs_f64(),
        100.0 * correct as f64 / n as f64,
        coord.metrics.batches.load(Ordering::Relaxed),
        coord.metrics.padded_slots.load(Ordering::Relaxed),
        lat.mean() / 1000.0,
        lat.median() / 1000.0,
        lat.percentile(99.0) / 1000.0,
    );
    coord.shutdown();

    // --- accelerator cost of the same workload (per the simulator) ---
    let hw = HardwareConfig::smallcnn_functional();
    let geom = rram_pattern_accel::xbar::CellGeometry::from_hw(&hw);
    let sim_cfg = SimConfig { sample_positions: None, ..Default::default() };
    let naive = NaiveMapping.map_network(&model.weights, &geom, 4);
    let ours = PatternMapping.map_network(&model.weights, &geom, 4);
    let base = sim::simulate_network(&naive, &model.spec, &hw, &sim_cfg, 4);
    let mine = sim::simulate_network(&ours, &model.spec, &hw, &sim_cfg, 4);
    let cmp = sim::Comparison { baseline: base, ours: mine };
    println!(
        "[accel] per-image on-chip cost: naive {:.1} nJ / {:.0} cycles; \
         pattern {:.1} nJ / {:.0} cycles -> {:.2}x energy, {:.2}x speedup, \
         {:.2}x crossbar area",
        cmp.baseline.total_energy().total_pj() / 1000.0,
        cmp.baseline.total_cycles(),
        cmp.ours.total_energy().total_pj() / 1000.0,
        cmp.ours.total_cycles(),
        cmp.energy_efficiency(),
        cmp.speedup(),
        cmp.area_efficiency(),
    );
}
