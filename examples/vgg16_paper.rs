//! Full paper reproduction driver: Table II, Fig. 7, Fig. 8, §V-C
//! speedup and §V-D index overhead for all three datasets, written to
//! `results/*.json` and printed in the paper's units.
//!
//! Run: `cargo run --release --example vgg16_paper`

use rram_pattern_accel::config::{HardwareConfig, SimConfig};
use rram_pattern_accel::mapping::{
    index, kmeans::KmeansMapping, naive::NaiveMapping, ou_sparse::OuSparseMapping,
    pattern::PatternMapping, MappingScheme,
};
use rram_pattern_accel::pruning::synthetic::ALL_PROFILES;
use rram_pattern_accel::report;
use rram_pattern_accel::sim;
use rram_pattern_accel::util::json::{obj, Json};
use rram_pattern_accel::util::threadpool;
use rram_pattern_accel::xbar::CellGeometry;

const PAPER_AREA: [f64; 3] = [4.67, 5.20, 4.16];
const PAPER_ENERGY: [f64; 3] = [2.13, 2.15, 1.98];
const PAPER_SPEEDUP: [f64; 3] = [1.35, 1.15, 1.17];
const PAPER_INDEX_KB: [f64; 3] = [729.5, 1013.5, 990.6];

fn main() {
    let seed = 42u64;
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let threads = threadpool::default_threads();
    let sim_cfg = SimConfig::default();

    println!("{}", report::table1(&hw));
    let mut out_rows = Vec::new();

    for (pi, profile) in ALL_PROFILES.iter().enumerate() {
        println!("==== {} ====", profile.name);
        let nw = profile.generate(seed);
        let spec = nw.spec.clone();

        // --- Table II ---
        let stats = nw.stats();
        println!("{}", report::table2_row(profile, &stats));

        // --- mappings ---
        let naive = NaiveMapping.map_network(&nw, &geom, threads);
        let ours = PatternMapping.map_network(&nw, &geom, threads);
        let km = KmeansMapping::default().map_network(&nw, &geom, threads);
        let sre = OuSparseMapping.map_network(&nw, &geom, threads);
        ours.validate().expect("mapping invariants");

        // --- Fig. 7 ---
        let f7 = report::Fig7Row {
            dataset: profile.name.to_string(),
            naive_crossbars: naive.total_crossbars(),
            pattern_crossbars: ours.total_crossbars(),
            kmeans_crossbars: km.total_crossbars(),
            ou_sparse_crossbars: sre.total_crossbars(),
            theoretical_best: 1.0 / (1.0 - profile.sparsity),
            paper_efficiency: PAPER_AREA[pi],
        };
        println!("{}", f7.line());

        // --- Fig. 8 + §V-C ---
        let base = sim::simulate_network(&naive, &spec, &hw, &sim_cfg, threads);
        let mine = sim::simulate_network(&ours, &spec, &hw, &sim_cfg, threads);
        let f8 = report::Fig8Row {
            dataset: profile.name.to_string(),
            baseline: base.total_energy(),
            ours: mine.total_energy(),
            paper_efficiency: PAPER_ENERGY[pi],
        };
        println!("{}", f8.lines());
        let cmp = sim::Comparison { baseline: base, ours: mine };
        println!(
            "{}",
            report::speedup_line(profile.name, &cmp, PAPER_SPEEDUP[pi])
        );

        // --- §V-D index overhead ---
        let idx_bits: usize = ours
            .layers
            .iter()
            .map(|l| index::overhead(l).total_bits())
            .sum();
        let idx_kb = idx_bits as f64 / 8.0 / 1000.0;
        let model_mb_dense = spec.total_weights() as f64 * 2.0 / 1e6; // 16-bit
        let stored: usize = ours
            .layers
            .iter()
            .flat_map(|l| l.blocks.iter())
            .map(|b| b.kernels() * b.rows())
            .sum();
        let model_mb_pruned = stored as f64 * 2.0 / 1e6;
        println!(
            "index overhead: {:.1} KB (paper {:.1} KB); model {:.1} MB -> {:.1} MB; \
             index/model = {:.1}%",
            idx_kb,
            PAPER_INDEX_KB[pi],
            model_mb_dense,
            model_mb_pruned,
            100.0 * idx_kb / 1000.0 / model_mb_pruned,
        );
        println!();

        out_rows.push(obj(vec![
            ("dataset", profile.name.into()),
            ("table2_sparsity", stats.sparsity.into()),
            (
                "table2_patterns",
                rram_pattern_accel::util::json::arr_usize(&stats.patterns_per_layer),
            ),
            ("table2_zero_ratio", stats.all_zero_kernel_ratio.into()),
            ("fig7", f7.to_json()),
            ("fig8", f8.to_json()),
            ("speedup", cmp.speedup().into()),
            ("paper_speedup", PAPER_SPEEDUP[pi].into()),
            ("index_kb", idx_kb.into()),
            ("paper_index_kb", PAPER_INDEX_KB[pi].into()),
        ]));
    }

    let j = Json::Arr(out_rows);
    report::write_json("vgg16_paper.json", &j).expect("write results");
    println!("wrote results/vgg16_paper.json");
}
