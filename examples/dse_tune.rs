//! Worked DSE → auto-tuned-serving example (no artifacts or PJRT
//! runtime needed — the sweep runs entirely on the analytic
//! simulator).
//!
//! 1. Sweep the config grid (OU dims × crossbar dims × pattern count ×
//!    pruning rate × mapping scheme) in parallel, cached under
//!    `results/dse_cache/` — rerun the example and watch the second
//!    pass complete from cache hits.
//! 2. Extract the (area, energy, cycles) Pareto frontier and the
//!    per-axis sensitivity summary.
//! 3. Select the frontier point for a weighted objective and print the
//!    `serve --auto-tune` invocation that boots a worker pool from it.
//!
//! Run: `cargo run --release --example dse_tune -- --grid small`

use rram_pattern_accel::dse::{
    self, Objective, ResultCache, SweepRunner, SweepSpec,
};
use rram_pattern_accel::util::cli::Args;
use rram_pattern_accel::util::threadpool;

fn main() {
    let args = Args::new("design-space exploration worked example")
        .opt("grid", "small", "sweep grid: small|medium")
        .opt("seed", "42", "workload seed")
        .opt("threads", "0", "sweep threads (0 = auto)")
        .opt("weights", "1,1,1", "selection weights: area,energy,cycles")
        .flag("no-cache", "evaluate every point fresh")
        .parse(std::env::args().skip(1))
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        });
    let seed = args.get_u64("seed").unwrap_or(42);
    let spec = SweepSpec::by_name(args.get("grid"), seed).unwrap_or_else(|| {
        eprintln!("unknown grid {}", args.get("grid"));
        std::process::exit(2)
    });
    let obj = Objective::parse(args.get("weights")).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2)
    });
    let threads = match args.get_usize("threads") {
        Ok(0) | Err(_) => threadpool::default_threads(),
        Ok(n) => n,
    };
    let cache = if args.get_flag("no-cache") {
        None
    } else {
        Some(ResultCache::default_dir())
    };

    let outcome = SweepRunner { spec, threads, cache }.run();
    println!("{}", outcome.summary_line());
    print!("{}", outcome.frontier.table(&outcome.results));
    println!();
    for axis in dse::sensitivity(&outcome.results) {
        print!("{}", axis.lines());
    }
    println!();

    match outcome.select(&obj) {
        Some(t) => {
            println!(
                "selected under weights {}: {}\n  cycles {:.0}, energy \
                 {:.4e} pJ, {} crossbars ({:.0} cells, {:.1}% utilized)",
                args.get("weights"),
                t.point.label(),
                t.metrics.cycles,
                t.metrics.energy_pj,
                t.metrics.crossbars,
                t.metrics.area_cells,
                t.metrics.utilization * 100.0,
            );
            println!(
                "\nserve this configuration (needs the PJRT artifact, \
                 `make artifacts` + `--features xla-runtime`):\n  \
                 rram-accel serve --auto-tune --tune-grid {} \
                 --tune-weights {} --workers 4 --balance cost",
                args.get("grid"),
                args.get("weights"),
            );
        }
        None => {
            eprintln!("empty frontier — every grid point was skipped");
            std::process::exit(1)
        }
    }
}
