//! End-to-end validation (DESIGN.md §6): the full three-layer stack on a
//! real trained-and-pattern-pruned network.
//!
//! `make artifacts` trained SmallCNN on the synthetic 10-class dataset,
//! ran the paper's iterative prune→project→retrain pipeline (L2/L1,
//! JAX + Pallas), and exported weights + golden logits + HLO. This
//! example closes the loop in Rust:
//!
//!   1. PJRT executes the AOT artifact; logits must match the python
//!      golden file (runtime equivalence).
//!   2. The mapper lays the pruned weights onto crossbars; the index
//!      buffer must reconstruct the placement (paper §IV-C).
//!   3. The functional OU simulator classifies real test images through
//!      the *mapped* crossbars; accuracy must match the python
//!      crossbar-mode accuracy (mapping preserves the computation).
//!   4. The cycle/energy simulator reports the paper's metrics for this
//!      network under naive vs pattern mapping.
//!
//! Run: `make artifacts && cargo run --release --example e2e_train_map`

use std::path::Path;

use rram_pattern_accel::config::{HardwareConfig, SimConfig};
use rram_pattern_accel::mapping::{
    index, naive::NaiveMapping, pattern::PatternMapping, MappingScheme,
};
use rram_pattern_accel::report;
use rram_pattern_accel::runtime::Engine;
use rram_pattern_accel::sim::{self, smallcnn};
use rram_pattern_accel::util::cli::Args;
use rram_pattern_accel::util::json::obj;

fn main() {
    let args = Args::new("end-to-end train->prune->map->simulate validation")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("images", "128", "test images for the accuracy check")
        .parse(std::env::args().skip(1))
        .unwrap_or_else(|e| {
            eprintln!("{e}");
            std::process::exit(2)
        });
    let dir = Path::new(args.get("artifacts"));
    let n_images = args.get_usize("images").unwrap();

    let model = smallcnn::SmallCnn::load(dir).expect("run `make artifacts` first");
    let td = smallcnn::TestData::load(dir).expect("test data");
    let hw = HardwareConfig::smallcnn_functional();

    println!("== training pipeline (from smallcnn_meta.json) ==");
    let acc = model.meta.get("accuracy");
    println!(
        "  dense {:.2}% -> projected {:.2}% -> retrained {:.2}% \
         (crossbar-quantized {:.2}%)",
        100.0 * acc.get("dense").as_f64().unwrap_or(0.0),
        100.0 * acc.get("projected").as_f64().unwrap_or(0.0),
        100.0 * acc.get("retrained_float").as_f64().unwrap_or(0.0),
        100.0 * acc.get("crossbar").as_f64().unwrap_or(0.0),
    );
    let stats = model.weights.stats();
    println!(
        "  sparsity {:.2}%, patterns/layer {:?}, all-zero kernels {:.1}%",
        100.0 * stats.sparsity,
        stats.patterns_per_layer,
        100.0 * stats.all_zero_kernel_ratio
    );

    // ---- 1. PJRT vs golden ----
    let engine = Engine::load(&dir.join("smallcnn_b1.hlo.txt")).expect("load HLO");
    let n_golden = td.golden_x.shape[0];
    let mut max_err = 0.0f32;
    for i in 0..n_golden {
        let img = smallcnn::image(&td.golden_x, i);
        let out = engine
            .run_f32(&[(&[1usize, 3, 32, 32], &img.data)])
            .expect("execute");
        for (o, g) in out
            .iter()
            .zip(td.golden_logits.data[i * 10..(i + 1) * 10].iter())
        {
            max_err = max_err.max((o - g).abs());
        }
    }
    println!("\n== 1. runtime equivalence ==");
    println!("  PJRT vs python golden logits over {n_golden} images: max |err| = {max_err:.2e}");
    assert!(max_err < 1e-3, "golden mismatch");

    // ---- 2. mapping + index round-trip ----
    let mapped = model.map(&PatternMapping, &hw);
    mapped.validate().expect("mapping invariants");
    let geom = rram_pattern_accel::xbar::CellGeometry::from_hw(&hw);
    let mut idx_bytes = 0usize;
    for ml in &mapped.layers {
        let buf = index::encode(ml);
        let decoded = index::decode(&buf).expect("decode");
        let replay = index::reconstruct_placements(&decoded, &geom);
        assert_eq!(replay, ml.placements, "placement reconstruction");
        idx_bytes += buf.bytes.len();
    }
    println!("\n== 2. mapping ==");
    println!(
        "  {} crossbars ({} naive), {} pattern blocks, index buffers {} bytes, \
         placement reconstruction from indexes: OK",
        mapped.total_crossbars(),
        NaiveMapping.map_network(&model.weights, &geom, 4).total_crossbars(),
        mapped.layers.iter().map(|l| l.blocks.len()).sum::<usize>(),
        idx_bytes
    );

    // ---- 3. mapped functional accuracy ----
    let n = n_images.min(td.test_x.shape[0]);
    let mut correct = 0usize;
    for i in 0..n {
        let img = smallcnn::image(&td.test_x, i);
        let logits = model.forward(&mapped, &img, &hw, true);
        if smallcnn::argmax(&logits) as i32 == td.test_y[i] {
            correct += 1;
        }
    }
    let sim_acc = correct as f64 / n as f64;
    let py_acc = model.meta.get("accuracy").get("crossbar").as_f64().unwrap_or(0.0);
    println!("\n== 3. mapped-crossbar functional accuracy ==");
    println!(
        "  rust OU simulator: {:.2}% on {} images (python crossbar mode: {:.2}%)",
        100.0 * sim_acc,
        n,
        100.0 * py_acc
    );
    assert!(
        (sim_acc - py_acc).abs() < 0.12,
        "mapped accuracy diverged from python crossbar accuracy"
    );

    // ---- 4. accelerator metrics for this network ----
    let sim_cfg = SimConfig { sample_positions: None, ..Default::default() };
    let naive = NaiveMapping.map_network(&model.weights, &geom, 4);
    let base = sim::simulate_network(&naive, &model.spec, &hw, &sim_cfg, 4);
    let mine = sim::simulate_network(&mapped, &model.spec, &hw, &sim_cfg, 4);
    let cmp = sim::Comparison { baseline: base, ours: mine };
    println!("\n== 4. accelerator metrics (SmallCNN) ==");
    println!(
        "  area {:.2}x | energy {:.2}x | speedup {:.2}x",
        cmp.area_efficiency(),
        cmp.energy_efficiency(),
        cmp.speedup()
    );

    let j = obj(vec![
        ("golden_max_err", (max_err as f64).into()),
        ("mapped_accuracy", sim_acc.into()),
        ("python_crossbar_accuracy", py_acc.into()),
        ("area_efficiency", cmp.area_efficiency().into()),
        ("energy_efficiency", cmp.energy_efficiency().into()),
        ("speedup", cmp.speedup().into()),
        ("sparsity", stats.sparsity.into()),
    ]);
    report::write_json("e2e_train_map.json", &j).expect("write results");
    println!("\nwrote results/e2e_train_map.json — all e2e checks passed");
}
