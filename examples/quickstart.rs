//! Quickstart: the paper's own Fig. 4 case study, end to end.
//!
//! One input channel, sixteen 3×3 kernels, four patterns (one all-zero).
//! We run the kernel-reordering mapping, print the resulting pattern
//! blocks, placements and OU schedule as ASCII, verify the index-buffer
//! round-trip (§IV-C), and compare crossbar area against the naive
//! Fig. 1 baseline.
//!
//! Run: `cargo run --release --example quickstart`

use rram_pattern_accel::config::HardwareConfig;
use rram_pattern_accel::mapping::{
    index, naive::NaiveMapping, ou::enumerate_ous, pattern::PatternMapping,
    MappingScheme,
};
use rram_pattern_accel::nn::{ConvLayer, Tensor};
use rram_pattern_accel::pruning::Pattern;
use rram_pattern_accel::report;
use rram_pattern_accel::xbar::CellGeometry;

fn main() {
    let hw = HardwareConfig::default();
    println!("{}", report::table1(&hw));

    // Fig. 4's layer: cin=1, cout=16, four patterns incl. all-zero.
    // (1 cell per weight here so the ASCII matches the figure's units.)
    let geom = CellGeometry { cells_per_weight: 1, ..CellGeometry::from_hw(&hw) };
    let layer = ConvLayer { name: "fig4".into(), cin: 1, cout: 16, fmap: 8 };

    let patterns: [(u16, &[usize]); 3] = [
        (0b000010001, &[0, 3, 5, 8, 11, 14]), // pattern A: positions {0,4}
        (0b001000100, &[1, 6, 9, 12]),        // pattern B: positions {2,6}
        (0b100010000, &[2, 7]),               // pattern C: positions {4,8}
    ]; // kernels 4,10,13,15 stay all-zero
    let mut w = Tensor::zeros(&[16, 1, 3, 3]);
    for (pid, kernels) in &patterns {
        for &k in *kernels {
            for pos in Pattern(*pid).positions() {
                w.set4(k, 0, pos / 3, pos % 3, 0.1 * (k as f32 + 1.0) + pos as f32);
            }
        }
    }

    println!("== kernels and their patterns ==");
    for k in 0..16 {
        let p = Pattern::from_kernel(&w.data[k * 9..k * 9 + 9]);
        println!(
            "  kernel {:>2}: pattern {:09b} (size {})",
            k, p.0, p.size()
        );
    }

    let mapped = PatternMapping.map_layer(0, &layer, &w, &geom);
    mapped.validate().expect("mapping invariants");
    println!("\n== pattern blocks (kernel-reordered, compressed) ==");
    for (b, p) in mapped.blocks.iter().zip(mapped.placements.iter()) {
        println!(
            "  cin {} pattern {:09b} size {} kernels {:?} -> xbar {} row {} col {}",
            b.cin, b.pattern.0, b.pattern.size(), b.out_channels, p.xbar, p.row, p.col
        );
    }

    // ASCII view of the occupied crossbar corner.
    println!("\n== crossbar corner (letters = blocks, . = free) ==");
    let view_rows = 6;
    let view_cols = 16;
    let mut grid = vec![b'.'; view_rows * view_cols];
    for (bi, p) in mapped.placements.iter().enumerate() {
        for r in p.row..(p.row + p.rows).min(view_rows) {
            for c in p.col..(p.col + p.cols).min(view_cols) {
                grid[r * view_cols + c] = b'A' + (bi as u8 % 26);
            }
        }
    }
    for r in 0..view_rows {
        let line: String =
            grid[r * view_cols..(r + 1) * view_cols].iter().map(|&b| b as char).collect();
        println!("  {line}");
    }

    // OU schedule (Fig. 5c red boxes).
    let ous = enumerate_ous(&mapped);
    println!("\n== OU schedule ({} activations per position) ==", ous.len());
    for t in &ous {
        println!(
            "  block {} xbar {}: rows {}..{} cols {}..{}",
            t.block, t.xbar, t.row_off, t.row_off + t.rows, t.col_off,
            t.col_off + t.cols
        );
    }

    // Index buffer round-trip (paper §IV-C).
    let buf = index::encode(&mapped);
    let decoded = index::decode(&buf).expect("decode");
    let replayed = index::reconstruct_placements(&decoded, &geom);
    assert_eq!(replayed, mapped.placements);
    println!(
        "\nindex buffer: {} bytes; placement reconstruction from indexes: OK",
        buf.bytes.len()
    );

    // Area vs the naive Fig. 1 mapping.
    let naive = NaiveMapping.map_layer(0, &layer, &w, &geom);
    println!("\n== area ==");
    println!(
        "  naive (Fig. 1):   {} weight cells ({} rows x {} filters)",
        naive.used_cells, 9, 16
    );
    println!(
        "  pattern (Fig. 4): {} weight cells in {} blocks ({} all-zero kernels deleted)",
        mapped.used_cells, mapped.blocks.len(), mapped.zero_kernels
    );
    println!(
        "  compression: {:.1}x fewer cells — the paper's \"9x16 -> 2x9\" case study",
        naive.used_cells as f64 / mapped.used_cells as f64
    );
}
