//! Bench F7: regenerate Fig. 7 (crossbar area efficiency) for all three
//! datasets, with the k-means [15] and OU-sparse [12] comparison series
//! (ablation A3), plus mapping timing.
//!
//! Run: `cargo bench --bench fig7_area`

use rram_pattern_accel::config::HardwareConfig;
use rram_pattern_accel::mapping::{
    kmeans::KmeansMapping, naive::NaiveMapping, ou_sparse::OuSparseMapping,
    pattern::PatternMapping, MappingScheme,
};
use rram_pattern_accel::pruning::synthetic::ALL_PROFILES;
use rram_pattern_accel::report;
use rram_pattern_accel::util::json::Json;
use rram_pattern_accel::util::threadpool;
use rram_pattern_accel::xbar::CellGeometry;

const PAPER_AREA: [f64; 3] = [4.67, 5.20, 4.16];

fn main() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let threads = threadpool::default_threads();

    println!("FIG. 7 — RRAM CROSSBAR AREA EFFICIENCY (y = crossbar count)\n");
    let mut rows = Vec::new();
    for (pi, profile) in ALL_PROFILES.iter().enumerate() {
        let nw = profile.generate(42);
        let t0 = std::time::Instant::now();
        let naive = NaiveMapping.map_network(&nw, &geom, threads);
        let ours = PatternMapping.map_network(&nw, &geom, threads);
        let km = KmeansMapping::default().map_network(&nw, &geom, threads);
        let sre = OuSparseMapping.map_network(&nw, &geom, threads);
        let map_time = t0.elapsed();
        ours.validate().expect("invariants");

        let row = report::Fig7Row {
            dataset: profile.name.to_string(),
            naive_crossbars: naive.total_crossbars(),
            pattern_crossbars: ours.total_crossbars(),
            kmeans_crossbars: km.total_crossbars(),
            ou_sparse_crossbars: sre.total_crossbars(),
            theoretical_best: 1.0 / (1.0 - profile.sparsity),
            paper_efficiency: PAPER_AREA[pi],
        };
        println!("{}  [mapped 4 schemes in {map_time:?}]", row.line());

        // reproduction bands: factor and ordering must match the paper
        assert!(
            row.efficiency() > 3.0 && row.efficiency() < 8.0,
            "{}: area efficiency {:.2} out of band",
            profile.name,
            row.efficiency()
        );
        assert!(row.kmeans_crossbars > row.pattern_crossbars);
        assert!(row.ou_sparse_crossbars >= row.pattern_crossbars);
        rows.push(row.to_json());
    }
    report::write_json("fig7.json", &Json::Arr(rows)).expect("write");
    println!("\nwrote results/fig7.json");
}
