//! Bench F7: regenerate Fig. 7 (crossbar area efficiency) for all three
//! datasets, with the k-means [15] and OU-sparse [12] comparison series
//! (ablation A3), plus pipeline timing.
//!
//! Since ISSUE-5 the rows come from the shared paper-artifact layer
//! (`report::artifacts::compute_dataset_rows`) instead of a local copy
//! of the scheme-sweep loop — the same code path the `rram-accel
//! artifacts` pipeline and the tier-2 conformance suite exercise.
//!
//! Run: `cargo bench --bench fig7_area`

use rram_pattern_accel::report;
use rram_pattern_accel::report::artifacts::{
    compute_dataset_rows, ArtifactConfig, TraceMode,
};
use rram_pattern_accel::pruning::synthetic::ALL_PROFILES;
use rram_pattern_accel::util::json::Json;
use rram_pattern_accel::util::threadpool;

fn main() {
    let cfg = ArtifactConfig {
        seed: 42,
        mode: TraceMode::Sampled(64),
        threads: threadpool::default_threads(),
    };

    println!("FIG. 7 — RRAM CROSSBAR AREA EFFICIENCY (y = crossbar count)\n");
    let mut rows = Vec::new();
    for profile in ALL_PROFILES {
        let t0 = std::time::Instant::now();
        let ds = compute_dataset_rows(profile, &cfg);
        let elapsed = t0.elapsed();
        let row = &ds.fig7;
        println!("{}  [computed in {elapsed:?}]", row.line());

        // reproduction bands: factor and ordering must match the paper
        assert!(
            row.efficiency() > 3.0 && row.efficiency() < 8.0,
            "{}: area efficiency {:.2} out of band",
            profile.name,
            row.efficiency()
        );
        assert!(row.kmeans_crossbars > row.pattern_crossbars);
        assert!(row.ou_sparse_crossbars >= row.pattern_crossbars);
        rows.push(row.to_json());
    }
    report::write_json("fig7.json", &Json::Arr(rows)).expect("write");
    println!("\nwrote results/fig7.json");
}
