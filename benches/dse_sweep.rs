//! Bench (ISSUE-4): design-space sweep throughput — the parallel
//! point fan-out vs the same grid single-threaded, on the 48-point
//! `small` grid (acceptance target: >= 2x on a >= 32-point grid).
//!
//! Extended (ISSUE-8) with the raw-speed-at-DSE-scale measurements:
//!
//!   * cache store cold vs warm — first sweep populates, second serves
//!     every point from disk — on both the binary pack backend and the
//!     legacy per-file JSON backend, with the on-disk footprint of each
//!     (including the compact-vs-pretty delta of the legacy entries);
//!   * frontier extraction head-to-head — the sort-based
//!     `ParetoFrontier::from_results` vs the O(n²) pairwise oracle on a
//!     synthetic 10^4-point result set, members asserted bit-identical.
//!
//! Parity first: the frontier must be byte-identical across thread
//! counts before the speeds mean anything. The throughput section keeps
//! caching disabled so both sides do full evaluations.
//!
//! Run: `cargo bench --bench dse_sweep`

use std::path::{Path, PathBuf};
use std::time::Duration;

use rram_pattern_accel::dse::{
    ParetoFrontier, PointMetrics, PointResult, ResultCache, SweepPoint,
    SweepRunner, SweepSpec,
};
use rram_pattern_accel::report;
use rram_pattern_accel::util::bench::{bb, bench, time_once, BenchConfig};
use rram_pattern_accel::util::json::Json;
use rram_pattern_accel::util::rng::Rng;
use rram_pattern_accel::util::threadpool;

fn main() {
    let threads = threadpool::default_threads().max(2);
    let spec = SweepSpec::small(42);
    let n_points = spec.expand().len();
    assert!(n_points >= 32, "speedup target is defined on a >= 32-point grid");

    println!("§DSE — PARALLEL SWEEP THROUGHPUT ({n_points}-point small grid)\n");

    // Parity: identical frontier bytes across thread counts.
    let single =
        SweepRunner { spec: spec.clone(), threads: 1, cache: None }.run();
    let multi =
        SweepRunner { spec: spec.clone(), threads, cache: None }.run();
    assert_eq!(
        single.frontier_json().to_string_pretty(),
        multi.frontier_json().to_string_pretty(),
        "frontier must be thread-invariant"
    );
    assert!(!single.frontier.is_empty(), "non-empty frontier");
    println!(
        "frontier parity 1 vs {threads} threads: OK ({} members, {} points \
         evaluated, {} skipped)\n",
        single.frontier.len(),
        single.evaluated(),
        single.skipped(),
    );

    let cfg = BenchConfig::default();
    let r1 = bench("dse sweep small grid (1 thread)", &cfg, || {
        bb(SweepRunner { spec: spec.clone(), threads: 1, cache: None }
            .run()
            .frontier
            .len());
    });
    let rn = bench(
        &format!("dse sweep small grid ({threads} threads)"),
        &cfg,
        || {
            bb(SweepRunner { spec: spec.clone(), threads, cache: None }
                .run()
                .frontier
                .len());
        },
    );
    println!("{}", report::sweep_speedup_line(r1.mean_ns, rn.mean_ns));
    println!(
        "  points/s: {:.0} single vs {:.0} parallel",
        n_points as f64 / (r1.mean_ns / 1e9),
        n_points as f64 / (rn.mean_ns / 1e9),
    );
    // Enforce the acceptance target where the host can physically meet
    // it; a 2-core box still prints the head-to-head above.
    let ratio = r1.mean_ns / rn.mean_ns.max(1e-9);
    if threads >= 4 {
        assert!(
            ratio >= 2.0,
            "parallel sweep {ratio:.2}x on {threads} threads misses the \
             >= 2x acceptance target"
        );
    }

    bench_store_cold_vs_warm(&spec, threads);
    bench_frontier_extraction();
}

/// §2: cache store cold vs warm, binary pack vs legacy per-file JSON,
/// plus the on-disk footprint of each layout.
fn bench_store_cold_vs_warm(spec: &SweepSpec, threads: usize) {
    let n_points = spec.expand().len();
    println!("\n§DSE — CACHE STORE COLD VS WARM ({n_points}-point small grid)\n");

    let bin_dir = temp_dir("bench-bin");
    let legacy_dir = temp_dir("bench-legacy");

    // Cold: every point evaluated fresh and persisted.
    let (bin_cold, _) = {
        let c = ResultCache::new(bin_dir.clone());
        time_once("cold sweep → binary pack store", || {
            SweepRunner { spec: spec.clone(), threads, cache: Some(c.clone()) }
                .run()
                .cache_misses()
        })
    };
    assert_eq!(bin_cold, n_points - skipped(spec, threads), "all misses");
    let (legacy_cold, _) = {
        let c = ResultCache::legacy_json(legacy_dir.clone());
        time_once("cold sweep → legacy per-file JSON", || {
            SweepRunner { spec: spec.clone(), threads, cache: Some(c.clone()) }
                .run()
                .cache_misses()
        })
    };
    assert_eq!(bin_cold, legacy_cold, "backends cache the same point set");

    // On-disk footprint, measured after the cold run (warm iterations
    // below keep appending frontier-snapshot records to the pack):
    // pack+idx bytes vs per-file JSON bytes, and the pretty-print
    // overhead the legacy writer used to pay per entry.
    let pack_bytes = file_size(&bin_dir.join("dse.pack"))
        + file_size(&bin_dir.join("dse.idx"));
    let (compact_bytes, pretty_bytes, n_entries) = legacy_footprint(&legacy_dir);
    println!(
        "  on disk: binary pack {pack_bytes} B; legacy compact \
         {compact_bytes} B over {n_entries} files \
         (pretty form of the same entries: {pretty_bytes} B, compact saves \
         {:.1}%)",
        100.0 * (pretty_bytes as f64 - compact_bytes as f64)
            / (pretty_bytes as f64).max(1.0),
    );

    // Warm: every point served from disk.
    let cfg = BenchConfig::default();
    let warm_bin = {
        let c = ResultCache::new(bin_dir.clone());
        bench("warm sweep ← binary pack store", &cfg, || {
            let o = SweepRunner {
                spec: spec.clone(),
                threads,
                cache: Some(c.clone()),
            }
            .run();
            assert_eq!(o.cache_misses(), 0, "warm run must be all hits");
            bb(o.cache_hits());
        })
    };
    let warm_legacy = {
        let c = ResultCache::legacy_json(legacy_dir.clone());
        bench("warm sweep ← legacy per-file JSON", &cfg, || {
            let o = SweepRunner {
                spec: spec.clone(),
                threads,
                cache: Some(c.clone()),
            }
            .run();
            assert_eq!(o.cache_misses(), 0, "warm run must be all hits");
            bb(o.cache_hits());
        })
    };
    println!(
        "  warm binary vs warm legacy: {:.2}x",
        warm_legacy.mean_ns / warm_bin.mean_ns.max(1e-9)
    );

    let _ = std::fs::remove_dir_all(&bin_dir);
    let _ = std::fs::remove_dir_all(&legacy_dir);
}

/// §3: sort-based frontier extraction vs the O(n²) pairwise oracle at
/// DSE scale (10^4 synthetic points), members asserted bit-identical.
fn bench_frontier_extraction() {
    const N: usize = 10_000;
    println!("\n§DSE — FRONTIER EXTRACTION HEAD-TO-HEAD ({N} synthetic points)\n");
    let results = synth_results(N);

    let fast = ParetoFrontier::from_results(&results);
    let oracle = ParetoFrontier::from_results_oracle(&results);
    assert_eq!(
        fast.members, oracle.members,
        "sort-based extraction must be bit-identical to the oracle"
    );
    println!(
        "member parity fast vs oracle: OK ({} of {N} non-dominated)",
        fast.members.len()
    );

    let fast_cfg = BenchConfig::default();
    let r_fast = bench("frontier extraction (sort-based)", &fast_cfg, || {
        bb(ParetoFrontier::from_results(&results).members.len());
    });
    // The oracle does ~10^8 dominance checks per iteration: keep its
    // sample count small, the gap is orders of magnitude.
    let oracle_cfg = BenchConfig {
        warmup: Duration::from_millis(0),
        measure: Duration::from_millis(0),
        min_iters: 3,
        max_iters: 3,
    };
    let r_oracle = bench("frontier extraction (O(n²) oracle)", &oracle_cfg, || {
        bb(ParetoFrontier::from_results_oracle(&results).members.len());
    });
    let speedup = r_oracle.mean_ns / r_fast.mean_ns.max(1e-9);
    println!("  sort-based vs oracle at {N} points: {speedup:.1}x");
    assert!(
        r_fast.mean_ns < r_oracle.mean_ns,
        "sort-based extraction must beat the O(n²) oracle at {N} points \
         ({:.0} ns vs {:.0} ns)",
        r_fast.mean_ns,
        r_oracle.mean_ns,
    );
}

/// Synthetic sweep results: deterministic pseudo-random objectives with
/// deliberate ties (coarse quantization) and a sprinkle of skips, so
/// the extraction exercises its grouping paths and not just the sort.
fn synth_results(n: usize) -> Vec<PointResult> {
    let mut rng = Rng::seed_from(0x5EED_D5E_u64);
    let point = SweepPoint {
        scheme: "pattern".into(),
        ou_rows: 9,
        ou_cols: 8,
        xbar_rows: 512,
        xbar_cols: 512,
        n_patterns: 8,
        pruning: 0.86,
        zero_detection: true,
        block_switch_cycles: 2.0,
        cores: 1,
        noc_bandwidth: 32.0,
        noc_hop_latency: 4.0,
    };
    (0..n)
        .map(|i| {
            let outcome = if rng.chance(0.02) {
                Err("synthetic skip".into())
            } else {
                let cycles = rng.below(2_000) as f64 * 16.0;
                let energy = rng.below(2_000) as f64 * 0.5;
                let area = rng.below(64) as f64 * 4096.0;
                Ok(PointMetrics {
                    cycles,
                    energy_pj: energy,
                    area_cells: area,
                    crossbars: 1 + (area as usize >> 18),
                    ou_ops: cycles,
                    utilization: 0.5,
                })
            };
            PointResult { index: i, point: point.clone(), outcome, cache_hit: false }
        })
        .collect()
}

fn skipped(spec: &SweepSpec, threads: usize) -> usize {
    SweepRunner { spec: spec.clone(), threads, cache: None }.run().skipped()
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("rram-dse-bench-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn file_size(p: &Path) -> u64 {
    std::fs::metadata(p).map(|m| m.len()).unwrap_or(0)
}

/// Total bytes of the legacy cache's JSON entries as written (compact),
/// and what the same entries would occupy pretty-printed (the
/// historical layout).
fn legacy_footprint(dir: &Path) -> (u64, u64, usize) {
    let mut compact = 0u64;
    let mut pretty = 0u64;
    let mut n = 0usize;
    let Ok(entries) = std::fs::read_dir(dir) else {
        return (0, 0, 0);
    };
    let mut paths: Vec<PathBuf> = entries
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|e| e == "json"))
        .collect();
    paths.sort();
    for p in paths {
        let Ok(text) = std::fs::read_to_string(&p) else { continue };
        compact += text.len() as u64;
        if let Ok(j) = Json::parse(&text) {
            pretty += j.to_string_pretty().len() as u64;
        }
        n += 1;
    }
    (compact, pretty, n)
}
