//! Bench (ISSUE-4): design-space sweep throughput — the parallel
//! point fan-out vs the same grid single-threaded, on the 48-point
//! `small` grid (acceptance target: >= 2x on a >= 32-point grid).
//!
//! Parity first: the frontier must be byte-identical across thread
//! counts before the speeds mean anything. Caching is disabled so both
//! sides do full evaluations.
//!
//! Run: `cargo bench --bench dse_sweep`

use rram_pattern_accel::dse::{SweepRunner, SweepSpec};
use rram_pattern_accel::report;
use rram_pattern_accel::util::bench::{bb, bench, BenchConfig};
use rram_pattern_accel::util::threadpool;

fn main() {
    let threads = threadpool::default_threads().max(2);
    let spec = SweepSpec::small(42);
    let n_points = spec.expand().len();
    assert!(n_points >= 32, "speedup target is defined on a >= 32-point grid");

    println!("§DSE — PARALLEL SWEEP THROUGHPUT ({n_points}-point small grid)\n");

    // Parity: identical frontier bytes across thread counts.
    let single =
        SweepRunner { spec: spec.clone(), threads: 1, cache: None }.run();
    let multi =
        SweepRunner { spec: spec.clone(), threads, cache: None }.run();
    assert_eq!(
        single.frontier_json().to_string_pretty(),
        multi.frontier_json().to_string_pretty(),
        "frontier must be thread-invariant"
    );
    assert!(!single.frontier.is_empty(), "non-empty frontier");
    println!(
        "frontier parity 1 vs {threads} threads: OK ({} members, {} points \
         evaluated, {} skipped)\n",
        single.frontier.len(),
        single.evaluated(),
        single.skipped(),
    );

    let cfg = BenchConfig::default();
    let r1 = bench("dse sweep small grid (1 thread)", &cfg, || {
        bb(SweepRunner { spec: spec.clone(), threads: 1, cache: None }
            .run()
            .frontier
            .len());
    });
    let rn = bench(
        &format!("dse sweep small grid ({threads} threads)"),
        &cfg,
        || {
            bb(SweepRunner { spec: spec.clone(), threads, cache: None }
                .run()
                .frontier
                .len());
        },
    );
    println!("{}", report::sweep_speedup_line(r1.mean_ns, rn.mean_ns));
    println!(
        "  points/s: {:.0} single vs {:.0} parallel",
        n_points as f64 / (r1.mean_ns / 1e9),
        n_points as f64 / (rn.mean_ns / 1e9),
    );
    // Enforce the acceptance target where the host can physically meet
    // it; a 2-core box still prints the head-to-head above.
    let ratio = r1.mean_ns / rn.mean_ns.max(1e-9);
    if threads >= 4 {
        assert!(
            ratio >= 2.0,
            "parallel sweep {ratio:.2}x on {threads} threads misses the \
             >= 2x acceptance target"
        );
    }
}
