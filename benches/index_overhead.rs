//! Bench X1: regenerate §V-D index overhead analysis for all three
//! datasets (KB of out-channel indexes + pattern shapes, vs model size).
//!
//! Run: `cargo bench --bench index_overhead`

use rram_pattern_accel::config::HardwareConfig;
use rram_pattern_accel::mapping::{index, pattern::PatternMapping, MappingScheme};
use rram_pattern_accel::pruning::synthetic::ALL_PROFILES;
use rram_pattern_accel::report;
use rram_pattern_accel::util::json::{obj, Json};
use rram_pattern_accel::util::threadpool;
use rram_pattern_accel::xbar::CellGeometry;

const PAPER_INDEX_KB: [f64; 3] = [729.5, 1013.5, 990.6];
const PAPER_ZERO_RATIO: [f64; 3] = [0.409, 0.274, 0.285];

fn main() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let threads = threadpool::default_threads();

    println!("§V-D — INDEX OVERHEAD ANALYSIS\n");
    let mut rows = Vec::new();
    for (pi, profile) in ALL_PROFILES.iter().enumerate() {
        let nw = profile.generate(42);
        let mapped = PatternMapping.map_network(&nw, &geom, threads);
        let kernel_bits: usize = mapped
            .layers
            .iter()
            .map(|l| index::overhead(l).kernel_index_bits)
            .sum();
        let shape_bits: usize = mapped
            .layers
            .iter()
            .map(|l| index::overhead(l).shape_bits)
            .sum();
        let kb = (kernel_bits + shape_bits) as f64 / 8.0 / 1000.0;
        let stored_weights: usize = mapped
            .layers
            .iter()
            .flat_map(|l| l.blocks.iter())
            .map(|b| b.kernels() * b.rows())
            .sum();
        let dense_mb = nw.spec.total_weights() as f64 * 2.0 / 1e6;
        let pruned_mb = stored_weights as f64 * 2.0 / 1e6;
        let zr = nw.stats().all_zero_kernel_ratio;
        println!(
            "{:<10} index {:>7.1} KB (paper {:>7.1} KB)  kernel-idx {:>7.1} KB \
             shapes {:>5.1} KB  model {:>5.1}->{:4.1} MB  index/model {:>4.1}%  \
             zero-kernels {:.1}% (paper {:.1}%)",
            profile.name,
            kb,
            PAPER_INDEX_KB[pi],
            kernel_bits as f64 / 8e3,
            shape_bits as f64 / 8e3,
            dense_mb,
            pruned_mb,
            100.0 * kb / 1000.0 / pruned_mb,
            100.0 * zr,
            100.0 * PAPER_ZERO_RATIO[pi],
        );
        // shape check: the dataset ordering of overhead follows the
        // paper (cifar10 smallest — highest all-zero ratio).
        rows.push(obj(vec![
            ("dataset", profile.name.into()),
            ("index_kb", kb.into()),
            ("paper_index_kb", PAPER_INDEX_KB[pi].into()),
            ("kernel_index_kb", (kernel_bits as f64 / 8e3).into()),
            ("shape_kb", (shape_bits as f64 / 8e3).into()),
            ("model_pruned_mb", pruned_mb.into()),
        ]));
    }
    let kbs: Vec<f64> = rows
        .iter()
        .map(|r| r.get("index_kb").as_f64().unwrap())
        .collect();
    assert!(
        kbs[0] < kbs[1] && kbs[0] < kbs[2],
        "cifar10 must have the smallest index overhead (highest zero ratio): {kbs:?}"
    );
    report::write_json("index_overhead.json", &Json::Arr(rows)).expect("write");
    println!("\nwrote results/index_overhead.json");
}
