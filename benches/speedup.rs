//! Bench S1: regenerate §V-C performance speedup for all three datasets,
//! plus ablation A2 (activation-sparsity sweep) showing how the Input
//! Preprocessing Unit's all-zero detection drives the gain.
//!
//! Run: `cargo bench --bench speedup`

use rram_pattern_accel::config::{HardwareConfig, SimConfig};
use rram_pattern_accel::mapping::{naive::NaiveMapping, pattern::PatternMapping, MappingScheme};
use rram_pattern_accel::pruning::synthetic::ALL_PROFILES;
use rram_pattern_accel::report;
use rram_pattern_accel::sim;
use rram_pattern_accel::util::json::{obj, Json};
use rram_pattern_accel::util::threadpool;
use rram_pattern_accel::xbar::CellGeometry;

const PAPER_SPEEDUP: [f64; 3] = [1.35, 1.15, 1.17];

fn main() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let threads = threadpool::default_threads();
    let sim_cfg = SimConfig::default();

    println!("§V-C — PERFORMANCE SPEEDUP (cycles, naive / pattern)\n");
    let mut rows = Vec::new();
    let mut speedups = Vec::new();
    for (pi, profile) in ALL_PROFILES.iter().enumerate() {
        let nw = profile.generate(42);
        let spec = nw.spec.clone();
        let naive = NaiveMapping.map_network(&nw, &geom, threads);
        let ours = PatternMapping.map_network(&nw, &geom, threads);
        let base = sim::simulate_network(&naive, &spec, &hw, &sim_cfg, threads);
        let mine = sim::simulate_network(&ours, &spec, &hw, &sim_cfg, threads);
        let cmp = sim::Comparison { baseline: base, ours: mine };
        println!("{}", report::speedup_line(profile.name, &cmp, PAPER_SPEEDUP[pi]));
        assert!(cmp.speedup() > 1.0, "{}: must win", profile.name);
        speedups.push(cmp.speedup());
        rows.push(obj(vec![
            ("dataset", profile.name.into()),
            ("naive_cycles", cmp.baseline.total_cycles().into()),
            ("pattern_cycles", cmp.ours.total_cycles().into()),
            ("speedup", cmp.speedup().into()),
            ("paper_speedup", PAPER_SPEEDUP[pi].into()),
        ]));
    }
    // shape check: cifar10 (highest all-zero ratio) wins the most,
    // as in the paper (1.35 > 1.17 > 1.15).
    assert!(
        speedups[0] > speedups[1] && speedups[0] > speedups[2],
        "cifar10 should have the largest speedup: {speedups:?}"
    );
    report::write_json("speedup.json", &Json::Arr(rows)).expect("write");
    println!("\nwrote results/speedup.json");

    // --- Ablation A2: activation zero-blob ratio sweep (cifar10) ---
    println!("\nABLATION A2 — activation sparsity sweep (cifar10)\n");
    let nw = ALL_PROFILES[0].generate(42);
    let spec = nw.spec.clone();
    let naive = NaiveMapping.map_network(&nw, &geom, threads);
    let ours = PatternMapping.map_network(&nw, &geom, threads);

    // Engine parity spot check (ISSUE-1): the trace-aggregated engine
    // must reproduce the per-position reference on a full paper sweep.
    let agg = sim::simulate_network(&ours, &spec, &hw, &sim_cfg, threads);
    let refr = sim::simulate_network_with(
        sim::SimEngine::Reference,
        &ours,
        &spec,
        &hw,
        &sim_cfg,
        threads,
    );
    assert_eq!(agg.total_cycles(), refr.total_cycles(), "engine parity");
    println!("engine parity (aggregated vs reference, cifar10): OK\n");

    let mut ablation = Vec::new();
    for blob in [0.0, 0.15, 0.3, 0.45, 0.6, 0.75, 0.9] {
        let cfg = SimConfig {
            zero_blob_ratio: blob,
            dead_channel_ratio: 0.0,
            ..Default::default()
        };
        let base = sim::simulate_network(&naive, &spec, &hw, &cfg, threads);
        let mine = sim::simulate_network(&ours, &spec, &hw, &cfg, threads);
        let cmp = sim::Comparison { baseline: base, ours: mine };
        println!(
            "  zero-blob {:.2}: speedup {:.2}x  energy {:.2}x",
            blob, cmp.speedup(), cmp.energy_efficiency()
        );
        ablation.push(obj(vec![
            ("zero_blob_ratio", blob.into()),
            ("speedup", cmp.speedup().into()),
            ("energy_efficiency", cmp.energy_efficiency().into()),
        ]));
    }
    report::write_json("ablation_activation.json", &Json::Arr(ablation)).expect("write");
    println!("\nwrote results/ablation_activation.json");
}
