//! Bench F8: regenerate Fig. 8 (normalized energy, ADC/DAC/RRAM
//! breakdown) for all three datasets, plus ablation A1 (OU-size sweep).
//!
//! Run: `cargo bench --bench fig8_energy`

use rram_pattern_accel::config::{HardwareConfig, SimConfig};
use rram_pattern_accel::mapping::{naive::NaiveMapping, pattern::PatternMapping, MappingScheme};
use rram_pattern_accel::pruning::synthetic::ALL_PROFILES;
use rram_pattern_accel::report;
use rram_pattern_accel::sim;
use rram_pattern_accel::util::json::Json;
use rram_pattern_accel::util::threadpool;
use rram_pattern_accel::xbar::CellGeometry;

const PAPER_ENERGY: [f64; 3] = [2.13, 2.15, 1.98];

fn main() {
    let threads = threadpool::default_threads();
    let sim_cfg = SimConfig::default();

    println!("FIG. 8 — NORMALIZED ENERGY (baseline = 1.0)\n");
    let mut rows = Vec::new();
    for (pi, profile) in ALL_PROFILES.iter().enumerate() {
        let hw = HardwareConfig::default();
        let geom = CellGeometry::from_hw(&hw);
        let nw = profile.generate(42);
        let spec = nw.spec.clone();
        let naive = NaiveMapping.map_network(&nw, &geom, threads);
        let ours = PatternMapping.map_network(&nw, &geom, threads);
        let base = sim::simulate_network(&naive, &spec, &hw, &sim_cfg, threads);
        let mine = sim::simulate_network(&ours, &spec, &hw, &sim_cfg, threads);
        let row = report::Fig8Row {
            dataset: profile.name.to_string(),
            baseline: base.total_energy(),
            ours: mine.total_energy(),
            paper_efficiency: PAPER_ENERGY[pi],
        };
        println!("{}", row.lines());
        // paper's key observation: ADC dominates both stacks
        let be = base.total_energy();
        let oe = mine.total_energy();
        assert!(be.adc_pj > be.dac_pj + be.rram_pj, "ADC must dominate baseline");
        assert!(oe.adc_pj > oe.dac_pj + oe.rram_pj, "ADC must dominate ours");
        // band: ~2x energy efficiency
        assert!(
            row.efficiency() > 1.4 && row.efficiency() < 3.5,
            "{}: energy efficiency {:.2} out of band",
            profile.name,
            row.efficiency()
        );
        rows.push(row.to_json());
    }
    report::write_json("fig8.json", &Json::Arr(rows)).expect("write");
    println!("\nwrote results/fig8.json");

    // --- Ablation A1: OU-size sweep (cifar10) ---
    println!("\nABLATION A1 — OU size sweep (cifar10, energy efficiency)\n");
    let nw = ALL_PROFILES[0].generate(42);
    let spec = nw.spec.clone();
    let mut ablation = Vec::new();
    for (our, ouc) in [(4usize, 4usize), (8, 8), (9, 8), (16, 16)] {
        let hw = HardwareConfig { ou_rows: our, ou_cols: ouc, ..Default::default() };
        let geom = CellGeometry::from_hw(&hw);
        let naive = NaiveMapping.map_network(&nw, &geom, threads);
        let ours = PatternMapping.map_network(&nw, &geom, threads);
        let base = sim::simulate_network(&naive, &spec, &hw, &sim_cfg, threads);
        let mine = sim::simulate_network(&ours, &spec, &hw, &sim_cfg, threads);
        let cmp = sim::Comparison { baseline: base, ours: mine };
        println!(
            "  OU {:>2}x{:<2}: energy {:.2}x  speedup {:.2}x",
            our, ouc, cmp.energy_efficiency(), cmp.speedup(),
        );
        ablation.push(rram_pattern_accel::util::json::obj(vec![
            ("ou_rows", our.into()),
            ("ou_cols", ouc.into()),
            ("energy_efficiency", cmp.energy_efficiency().into()),
            ("speedup", cmp.speedup().into()),
        ]));
    }
    report::write_json("ablation_ou_size.json", &Json::Arr(ablation)).expect("write");
    println!("\nwrote results/ablation_ou_size.json");
}
