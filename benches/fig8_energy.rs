//! Bench F8: regenerate Fig. 8 (normalized energy, ADC/DAC/RRAM
//! breakdown) for all three datasets, plus ablation A1 (OU-size sweep).
//!
//! Since ISSUE-5 the per-dataset rows come from the shared
//! paper-artifact layer (`report::artifacts::compute_dataset_rows`);
//! the ablation sweep below stays a local loop because it varies the
//! hardware geometry, which the paper artifacts pin to Table I.
//!
//! Run: `cargo bench --bench fig8_energy`

use rram_pattern_accel::config::{HardwareConfig, SimConfig};
use rram_pattern_accel::mapping::{naive::NaiveMapping, pattern::PatternMapping, MappingScheme};
use rram_pattern_accel::pruning::synthetic::ALL_PROFILES;
use rram_pattern_accel::report;
use rram_pattern_accel::report::artifacts::{
    compute_dataset_rows, ArtifactConfig, TraceMode,
};
use rram_pattern_accel::sim;
use rram_pattern_accel::util::json::Json;
use rram_pattern_accel::util::threadpool;
use rram_pattern_accel::xbar::CellGeometry;

fn main() {
    let threads = threadpool::default_threads();
    let cfg = ArtifactConfig {
        seed: 42,
        mode: TraceMode::Sampled(64),
        threads,
    };

    println!("FIG. 8 — NORMALIZED ENERGY (baseline = 1.0)\n");
    let mut rows = Vec::new();
    for profile in ALL_PROFILES {
        let ds = compute_dataset_rows(profile, &cfg);
        let row = &ds.fig8;
        println!("{}", row.lines());
        // paper's key observation: ADC dominates both stacks
        let be = &row.baseline;
        let oe = &row.ours;
        assert!(be.adc_pj > be.dac_pj + be.rram_pj, "ADC must dominate baseline");
        assert!(oe.adc_pj > oe.dac_pj + oe.rram_pj, "ADC must dominate ours");
        // band: ~2x energy efficiency
        assert!(
            row.efficiency() > 1.4 && row.efficiency() < 3.5,
            "{}: energy efficiency {:.2} out of band",
            profile.name,
            row.efficiency()
        );
        rows.push(row.to_json());
    }
    report::write_json("fig8.json", &Json::Arr(rows)).expect("write");
    println!("\nwrote results/fig8.json");

    // --- Ablation A1: OU-size sweep (cifar10) ---
    println!("\nABLATION A1 — OU size sweep (cifar10, energy efficiency)\n");
    let sim_cfg = SimConfig::default();
    let nw = ALL_PROFILES[0].generate(42);
    let spec = nw.spec.clone();
    let mut ablation = Vec::new();
    for (our, ouc) in [(4usize, 4usize), (8, 8), (9, 8), (16, 16)] {
        let hw = HardwareConfig { ou_rows: our, ou_cols: ouc, ..Default::default() };
        let geom = CellGeometry::from_hw(&hw);
        let naive = NaiveMapping.map_network(&nw, &geom, threads);
        let ours = PatternMapping.map_network(&nw, &geom, threads);
        let base = sim::simulate_network(&naive, &spec, &hw, &sim_cfg, threads);
        let mine = sim::simulate_network(&ours, &spec, &hw, &sim_cfg, threads);
        let cmp = sim::Comparison { baseline: base, ours: mine };
        println!(
            "  OU {:>2}x{:<2}: energy {:.2}x  speedup {:.2}x",
            our, ouc, cmp.energy_efficiency(), cmp.speedup(),
        );
        ablation.push(rram_pattern_accel::util::json::obj(vec![
            ("ou_rows", our.into()),
            ("ou_cols", ouc.into()),
            ("energy_efficiency", cmp.energy_efficiency().into()),
            ("speedup", cmp.speedup().into()),
        ]));
    }
    report::write_json("ablation_ou_size.json", &Json::Arr(ablation)).expect("write");
    println!("\nwrote results/ablation_ou_size.json");
}
