//! Bench (ISSUE-7): HTTP front-door throughput — closed-loop keep-alive
//! load over loopback against a multi-worker mock-backend pool,
//! reporting sustained RPS and p50/p99 tail latency.
//!
//! The mock backend sleeps a fixed per-batch latency so the numbers
//! exercise the full edge (socket accept, bounded reader, lazy scanner,
//! coordinator batching/dispatch, JSON response) rather than a no-op
//! handler. Every response must be a 200: a single non-200 under plain
//! well-formed load is a correctness failure, not a perf number.
//!
//! ISSUE-9 runs the same load twice — tracing off (the default
//! `CoordinatorConfig { trace: None }`, which keeps every span call
//! inert) and tracing on (a live registry behind `/debug/trace`) — and
//! reports both, so a tracing-layer regression on the hot path shows up
//! as a gap between the two lines instead of silently taxing serving.
//!
//! Run: `cargo bench --bench http_load` (HTTP_LOAD_SECS overrides the
//! 2 s default run length; the CI smoke job runs 1 s).

use std::time::Duration;

use rram_pattern_accel::coordinator::{Coordinator, CoordinatorConfig};
use rram_pattern_accel::obs;
use rram_pattern_accel::report;
use rram_pattern_accel::serve_http::client::{run_load, LoadConfig, LoadReport};
use rram_pattern_accel::serve_http::{HttpConfig, HttpServer, MockInferBackend};
use rram_pattern_accel::util::clock;
use rram_pattern_accel::util::json::{obj, Json};
use rram_pattern_accel::util::threadpool;

const INPUT_LEN: usize = 64;
const CLIENTS: usize = 8;

/// One closed-loop run against a fresh server; `traced` wires a live
/// span registry into the pool (the serve-http production default),
/// `!traced` pins the zero-overhead path where every span site is
/// inert.
fn run_phase(traced: bool, secs: u64, workers: usize) -> LoadReport {
    let trace = traced.then(|| {
        obs::Registry::new(clock::monotonic(), obs::DEFAULT_RING_CAPACITY)
    });
    let coord = Coordinator::start_pool(
        move |_worker| MockInferBackend {
            input_len: INPUT_LEN,
            output_len: 10,
            batch: 8,
            delay: Duration::from_micros(200),
            fail: false,
        },
        CoordinatorConfig {
            max_wait: Duration::from_millis(1),
            workers,
            trace,
            ..Default::default()
        },
        None,
    );
    let server = HttpServer::start(
        coord,
        HttpConfig {
            addr: "127.0.0.1:0".to_string(),
            input_len: INPUT_LEN,
            ..Default::default()
        },
    )
    .expect("bind loopback");

    let image: Vec<String> =
        (0..INPUT_LEN).map(|i| format!("{}", i as f32 * 0.25)).collect();
    let body = format!("{{\"image\":[{}]}}", image.join(",")).into_bytes();
    let cfg = LoadConfig {
        addr: server.addr(),
        clients: CLIENTS,
        duration: Duration::from_secs(secs),
        body,
    };
    let label = if traced { "tracing on " } else { "tracing off" };
    let rep = run_load(&cfg);
    println!("  [{label}] {}", rep.line());

    let stats = server.http_stats();
    println!(
        "  [{label}] server side: {} connections, {} requests, {} bad, {} panics",
        stats.connections, stats.requests, stats.bad_requests, stats.handler_panics
    );
    assert_eq!(rep.non_200, 0, "well-formed load must be all 200s");
    assert_eq!(stats.handler_panics, 0, "no handler may panic under load");
    assert!(rep.requests > 0, "load loop produced no requests");
    server.shutdown();
    rep
}

fn phase_json(rep: &LoadReport) -> Json {
    obj(vec![
        ("requests", (rep.requests as f64).into()),
        ("rps", rep.rps().into()),
        ("latency_p50_us", rep.latencies_us.percentile(50.0).into()),
        ("latency_p99_us", rep.latencies_us.percentile(99.0).into()),
        ("latency_max_us", rep.latencies_us.max().into()),
        ("non_200", (rep.non_200 as f64).into()),
    ])
}

fn main() {
    let secs: u64 = std::env::var("HTTP_LOAD_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(2);
    let workers = threadpool::default_threads().min(4);

    println!("ISSUE-7 — HTTP FRONT DOOR LOAD\n");
    println!(
        "{CLIENTS} keep-alive clients -> {workers} worker(s), \
         batch 8, 200 us backend latency, {secs}s per phase"
    );
    let off = run_phase(false, secs, workers);
    let on = run_phase(true, secs, workers);

    let out = obj(vec![
        ("bench", "http_load".into()),
        ("clients", CLIENTS.into()),
        ("workers", workers.into()),
        ("duration_s", (secs as f64).into()),
        ("tracing_off", phase_json(&off)),
        ("tracing_on", phase_json(&on)),
    ]);
    report::write_json("bench_http_load.json", &out).expect("write");
    println!("\nwrote results/bench_http_load.json");
}
