//! Bench T2: regenerate Table II (pattern pruning results + the §V-C
//! speedup column) from the Table-II-calibrated synthetic networks,
//! plus report generator timing.
//!
//! Since ISSUE-5 the rows come from the shared paper-artifact layer
//! (`report::artifacts::compute_dataset_rows`) — the same code path
//! the `rram-accel artifacts` pipeline and the tier-2 conformance
//! suite exercise.
//!
//! Run: `cargo bench --bench table2_pruning`

use rram_pattern_accel::pruning::synthetic::ALL_PROFILES;
use rram_pattern_accel::report;
use rram_pattern_accel::report::artifacts::{
    compute_dataset_rows, ArtifactConfig, TraceMode,
};
use rram_pattern_accel::util::bench::{bench, BenchConfig};
use rram_pattern_accel::util::json::Json;
use rram_pattern_accel::util::threadpool;

fn main() {
    let cfg = ArtifactConfig {
        seed: 42,
        mode: TraceMode::Sampled(64),
        threads: threadpool::default_threads(),
    };

    println!("TABLE II — PATTERN PRUNING RESULTS (measured vs paper)\n");
    let mut rows = Vec::new();
    for profile in ALL_PROFILES {
        let ds = compute_dataset_rows(profile, &cfg);
        let row = &ds.table2;
        println!("{}", row.line());
        assert_eq!(
            row.patterns_per_layer,
            profile.patterns_per_layer.to_vec(),
            "{}: per-layer pattern counts must match Table II exactly",
            profile.name
        );
        assert!(
            row.speedup() > 1.0,
            "{}: pattern scheme must beat the naive baseline ({}x)",
            profile.name,
            row.speedup()
        );
        rows.push(row.to_json());
    }
    report::write_json("table2.json", &Json::Arr(rows)).expect("write");
    println!("\nwrote results/table2.json\n");

    // perf: generator throughput (it sits on the bench critical path)
    let cfg = BenchConfig::default();
    bench("generate vgg16-cifar10 (synthetic)", &cfg, || {
        let nw = ALL_PROFILES[0].generate(7);
        std::hint::black_box(nw.layers.len());
    });
}
