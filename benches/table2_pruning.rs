//! Bench T2: regenerate Table II (pattern pruning results) from the
//! Table-II-calibrated synthetic networks + report generator timing.
//!
//! Run: `cargo bench --bench table2_pruning`

use rram_pattern_accel::pruning::synthetic::ALL_PROFILES;
use rram_pattern_accel::report;
use rram_pattern_accel::util::bench::{bench, BenchConfig};
use rram_pattern_accel::util::json::{obj, Json};

fn main() {
    println!("TABLE II — PATTERN PRUNING RESULTS (measured vs paper)\n");
    let mut rows = Vec::new();
    for profile in ALL_PROFILES {
        let nw = profile.generate(42);
        let stats = nw.stats();
        println!("{}", report::table2_row(profile, &stats));
        assert_eq!(
            stats.patterns_per_layer,
            profile.patterns_per_layer.to_vec(),
            "{}: per-layer pattern counts must match Table II exactly",
            profile.name
        );
        rows.push(obj(vec![
            ("dataset", profile.name.into()),
            ("sparsity", stats.sparsity.into()),
            ("paper_sparsity", profile.sparsity.into()),
            (
                "patterns_per_layer",
                rram_pattern_accel::util::json::arr_usize(&stats.patterns_per_layer),
            ),
            ("all_zero_ratio", stats.all_zero_kernel_ratio.into()),
            ("paper_all_zero_ratio", profile.all_zero_ratio.into()),
        ]));
    }
    report::write_json("table2.json", &Json::Arr(rows)).expect("write");
    println!("\nwrote results/table2.json\n");

    // perf: generator throughput (it sits on the bench critical path)
    let cfg = BenchConfig::default();
    bench("generate vgg16-cifar10 (synthetic)", &cfg, || {
        let nw = ALL_PROFILES[0].generate(7);
        std::hint::black_box(nw.layers.len());
    });
}
