//! Bench P2 (§Perf): cycle/energy simulator inner-loop throughput.
//!
//! Measures simulated OU-operations per second over the VGG16/cifar10
//! network — the DESIGN.md §8 target is ≥ 10 M OU-ops/s.
//!
//! Run: `cargo bench --bench sim_hotpath`

use rram_pattern_accel::config::{HardwareConfig, SimConfig};
use rram_pattern_accel::mapping::{naive::NaiveMapping, pattern::PatternMapping, MappingScheme};
use rram_pattern_accel::pruning::synthetic::CIFAR10;
use rram_pattern_accel::sim;
use rram_pattern_accel::util::bench::{bb, bench, BenchConfig};
use rram_pattern_accel::util::threadpool;
use rram_pattern_accel::xbar::CellGeometry;

fn main() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let threads = threadpool::default_threads();
    let cfg = BenchConfig::default();

    println!("§Perf P2 — SIMULATOR HOT PATH\n");
    let nw = CIFAR10.generate(42);
    let spec = nw.spec.clone();
    let naive = NaiveMapping.map_network(&nw, &geom, threads);
    let ours = PatternMapping.map_network(&nw, &geom, threads);
    let sim_cfg = SimConfig::default();

    // how many OU ops does one full simulation visit?
    let probe = sim::simulate_network(&ours, &spec, &hw, &sim_cfg, threads);
    let ou_ops_visited: f64 = probe
        .layers
        .iter()
        .map(|l| {
            let samples = sim_cfg.sample_positions.unwrap_or(1) as f64;
            let positions = spec.layers[l.layer_idx].positions() as f64;
            (l.ou_ops + l.skipped_ou_ops) * samples / positions
        })
        .sum();

    for (name, mapped) in [("pattern", &ours), ("naive", &naive)] {
        let r1 = bench(&format!("simulate {name} (1 thread)"), &cfg, || {
            bb(sim::simulate_network(mapped, &spec, &hw, &sim_cfg, 1).total_cycles());
        });
        let rn = bench(
            &format!("simulate {name} ({threads} threads)"),
            &cfg,
            || {
                bb(sim::simulate_network(mapped, &spec, &hw, &sim_cfg, threads)
                    .total_cycles());
            },
        );
        if name == "pattern" {
            let mops = ou_ops_visited / (rn.mean_ns / 1e9) / 1e6;
            println!(
                "  -> {:.1} M simulated OU-ops/s (target >= 10 M/s: {}), \
                 thread scaling {:.2}x\n",
                mops,
                if mops >= 10.0 { "MET" } else { "MISSED" },
                r1.mean_ns / rn.mean_ns,
            );
        }
    }
}
