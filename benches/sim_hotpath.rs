//! Bench P2 (§Perf): cycle/energy simulator throughput — the
//! trace-aggregated engine vs the per-position reference oracle on the
//! VGG16/cifar10 layer sweep, plus the batched multi-image engine vs
//! the looped per-image path (ISSUE-2).
//!
//! Targets: ≥ 10 M simulated OU-ops/s (DESIGN.md §8), ≥ 5× the
//! reference engine's throughput (ISSUE-1), and the batch engine at
//! least matching N looped per-image runs with bit-exact totals.
//!
//! Run: `cargo bench --bench sim_hotpath`

use rram_pattern_accel::config::{HardwareConfig, SimConfig};
use rram_pattern_accel::mapping::{naive::NaiveMapping, pattern::PatternMapping, MappingScheme};
use rram_pattern_accel::pruning::synthetic::CIFAR10;
use rram_pattern_accel::report;
use rram_pattern_accel::sim::{self, SimEngine};
use rram_pattern_accel::util::bench::{bb, bench, BenchConfig};
use rram_pattern_accel::util::threadpool;
use rram_pattern_accel::xbar::CellGeometry;

fn main() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let threads = threadpool::default_threads();
    let cfg = BenchConfig::default();

    println!("§Perf P2 — SIMULATOR HOT PATH\n");
    let nw = CIFAR10.generate(42);
    let spec = nw.spec.clone();
    let naive = NaiveMapping.map_network(&nw, &geom, threads);
    let ours = PatternMapping.map_network(&nw, &geom, threads);
    let sim_cfg = SimConfig::default();

    // Parity first: the engines must agree on the whole sweep before
    // their speeds mean anything.
    let probe = sim::simulate_network(&ours, &spec, &hw, &sim_cfg, threads);
    let refr = sim::simulate_network_with(
        SimEngine::Reference,
        &ours,
        &spec,
        &hw,
        &sim_cfg,
        threads,
    );
    assert_eq!(probe.total_cycles(), refr.total_cycles(), "cycle parity");
    assert_eq!(probe.total_ou_ops(), refr.total_ou_ops(), "ou-op parity");
    let e_rel = (probe.total_energy().total_pj() - refr.total_energy().total_pj())
        .abs()
        / refr.total_energy().total_pj().max(1e-12);
    assert!(e_rel < 1e-9, "energy parity {e_rel}");
    println!("engine parity on VGG16/cifar10: OK (energy rel err {e_rel:.1e})\n");

    // how many OU ops does one full simulation visit?
    let ou_ops_visited: f64 = probe
        .layers
        .iter()
        .map(|l| {
            let samples = sim_cfg.sample_positions.unwrap_or(1) as f64;
            let positions = spec.layers[l.layer_idx].positions() as f64;
            (l.ou_ops + l.skipped_ou_ops) * samples / positions
        })
        .sum();

    // Engine head-to-head (single thread: pure engine throughput).
    let r_ref = bench("simulate pattern (reference, 1 thread)", &cfg, || {
        bb(sim::simulate_network_with(
            SimEngine::Reference,
            &ours,
            &spec,
            &hw,
            &sim_cfg,
            1,
        )
        .total_cycles());
    });
    let r_agg = bench("simulate pattern (aggregated, 1 thread)", &cfg, || {
        bb(sim::simulate_network(&ours, &spec, &hw, &sim_cfg, 1).total_cycles());
    });
    println!("{}\n", report::engine_speedup_line(r_ref.mean_ns, r_agg.mean_ns));

    // Batched multi-image engine: parity first, then the head-to-head
    // against the looped per-image oracle.
    let n_images = 4usize;
    let batch = sim::simulate_network_batch(&ours, &spec, &hw, &sim_cfg, n_images, threads);
    let looped_total =
        sim::simulate_network_looped(&ours, &spec, &hw, &sim_cfg, n_images, threads);
    assert_eq!(batch.total_cycles(), looped_total, "batch/looped parity");
    println!("{}", report::batch_line(&batch));

    let r_loop = bench(
        &format!("simulate {n_images}-image batch (looped, 1 thread)"),
        &cfg,
        || {
            bb(sim::simulate_network_looped(
                &ours, &spec, &hw, &sim_cfg, n_images, 1,
            ));
        },
    );
    let r_batch = bench(
        &format!("simulate {n_images}-image batch (batched, 1 thread)"),
        &cfg,
        || {
            bb(sim::simulate_network_batch(&ours, &spec, &hw, &sim_cfg, n_images, 1)
                .total_cycles());
        },
    );
    println!("{}\n", report::batch_speedup_line(r_loop.mean_ns, r_batch.mean_ns));

    for (name, mapped) in [("pattern", &ours), ("naive", &naive)] {
        let r1 = bench(&format!("simulate {name} (1 thread)"), &cfg, || {
            bb(sim::simulate_network(mapped, &spec, &hw, &sim_cfg, 1).total_cycles());
        });
        let rn = bench(
            &format!("simulate {name} ({threads} threads)"),
            &cfg,
            || {
                bb(sim::simulate_network(mapped, &spec, &hw, &sim_cfg, threads)
                    .total_cycles());
            },
        );
        if name == "pattern" {
            let mops = ou_ops_visited / (rn.mean_ns / 1e9) / 1e6;
            println!(
                "  -> {:.1} M simulated OU-ops/s (target >= 10 M/s: {}), \
                 thread scaling {:.2}x\n",
                mops,
                if mops >= 10.0 { "MET" } else { "MISSED" },
                r1.mean_ns / rn.mean_ns,
            );
        }
    }
}
