//! Bench P1 (§Perf): mapping-engine hot path throughput.
//!
//! Measures kernel-reordering mapping end to end (group → compress →
//! place) per layer and for the full VGG16/ImageNet network, in
//! kernels/second — the L3 target in DESIGN.md §8 is mapping the full
//! ImageNet VGG16 in under a second.
//!
//! Run: `cargo bench --bench mapping_hotpath`

use rram_pattern_accel::config::HardwareConfig;
use rram_pattern_accel::mapping::{pattern::PatternMapping, MappingScheme};
use rram_pattern_accel::nn::ConvLayer;
use rram_pattern_accel::pruning::synthetic::{generate_layer, IMAGENET};
use rram_pattern_accel::util::bench::{bb, bench, throughput, BenchConfig};
use rram_pattern_accel::util::rng::Rng;
use rram_pattern_accel::util::threadpool;
use rram_pattern_accel::xbar::CellGeometry;

fn main() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let cfg = BenchConfig::default();

    println!("§Perf P1 — MAPPING HOT PATH\n");

    // single hot layer: VGG conv5_x-scale (512x512 kernels)
    let mut rng = Rng::seed_from(1);
    let w = generate_layer(512, 512, 8, 0.86, 0.41, &mut rng);
    let layer = ConvLayer { name: "conv8".into(), cout: 512, cin: 512, fmap: 4 };
    let r = bench("map 512x512 layer (262k kernels)", &cfg, || {
        let ml = PatternMapping.map_layer(0, &layer, &w, &geom);
        bb(ml.n_crossbars);
    });
    println!(
        "  -> {:.1} M kernels/s\n",
        throughput(&r, (512 * 512) as u64) / 1e6
    );

    // full ImageNet VGG16 network, serial vs parallel
    let nw = IMAGENET.generate(42);
    let total_kernels = nw.spec.total_kernels() as u64;
    let r1 = bench("map vgg16-imagenet (1 thread)", &cfg, || {
        bb(PatternMapping.map_network(&nw, &geom, 1).total_crossbars());
    });
    let nthreads = threadpool::default_threads();
    let rn = bench(
        &format!("map vgg16-imagenet ({nthreads} threads)"),
        &cfg,
        || {
            bb(PatternMapping.map_network(&nw, &geom, nthreads).total_crossbars());
        },
    );
    println!(
        "\n  -> serial {:.1} M kernels/s, parallel {:.1} M kernels/s \
         ({:.2}x scaling); target: full network < 1 s ({})",
        throughput(&r1, total_kernels) / 1e6,
        throughput(&rn, total_kernels) / 1e6,
        r1.mean_ns / rn.mean_ns,
        if rn.mean_ns < 1e9 { "MET" } else { "MISSED" },
    );
}
