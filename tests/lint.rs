//! Fixture-driven tests for the `rram-accel lint` static-analysis pass.
//!
//! Every rule has at least one `bad/` fixture (exact rule IDs and line
//! numbers asserted) and one `good/` counterpart (zero findings). The
//! suite also checks pragma suppression accounting, `--json` output
//! validity and byte-stability, diagnostic ordering, and that the
//! self-scan of this crate is clean under `--deny-warnings` semantics.

use std::path::{Path, PathBuf};

use rram_pattern_accel::analysis::{self, LintReport, Severity};
use rram_pattern_accel::util::json::Json;

fn fixture(rel: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures")
        .join(rel)
}

fn lint_one(rel: &str) -> LintReport {
    analysis::lint_roots(&[fixture(rel)])
        .unwrap_or_else(|e| panic!("lint_roots({rel}): {e}"))
}

/// Assert a bad fixture produces exactly `expected` as its
/// (rule, line) multiset, in report order.
fn assert_findings(rel: &str, expected: &[(&str, usize)]) {
    let report = lint_one(rel);
    let got: Vec<(&str, usize)> = report
        .diagnostics
        .iter()
        .map(|d| (d.rule, d.line))
        .collect();
    assert_eq!(
        got, expected,
        "unexpected findings for {rel}:\n{}",
        report.lines()
    );
}

fn assert_clean(rel: &str) {
    let report = lint_one(rel);
    assert!(
        report.diagnostics.is_empty(),
        "expected {rel} to be clean, got:\n{}",
        report.lines()
    );
}

// ---------------------------------------------------------------- rules

#[test]
fn unordered_iteration_bad_and_good() {
    assert_findings(
        "bad/unordered_iteration.rs",
        &[("no-unordered-iteration", 3), ("no-unordered-iteration", 5)],
    );
    assert_clean("good/unordered_iteration.rs");
}

#[test]
fn wall_clock_bad_and_good() {
    assert_findings(
        "bad/wall_clock.rs",
        &[
            ("no-wall-clock-in-pure-paths", 5),
            ("no-wall-clock-in-pure-paths", 9),
            ("no-wall-clock-in-pure-paths", 10),
        ],
    );
    // Same construct, but scoped (via lint:path) to the serving edge
    // where wall-clock reads are legitimate.
    assert_clean("good/wall_clock.rs");
}

#[test]
fn ambient_rng_bad_and_good() {
    // Line 14 fires twice: once for the `rand::` path and once for
    // `thread_rng` itself.
    assert_findings(
        "bad/ambient_rng.rs",
        &[
            ("no-ambient-rng", 2),
            ("no-ambient-rng", 6),
            ("no-ambient-rng", 14),
            ("no-ambient-rng", 14),
        ],
    );
    assert_clean("good/ambient_rng.rs");
}

#[test]
fn float_accumulation_bad_and_good() {
    assert_findings(
        "bad/float_accumulation.rs",
        &[("no-float-accumulation-across-threads", 8)],
    );
    // `+=` after the join is the sanctioned pattern.
    assert_clean("good/float_accumulation.rs");
}

#[test]
fn mutex_discipline_bad_and_good() {
    // Line 10 fires three times: `.unwrap()`, `.expect(`, and the
    // nested single-statement acquisition.
    assert_findings(
        "bad/mutex_discipline.rs",
        &[
            ("mutex-discipline", 6),
            ("mutex-discipline", 10),
            ("mutex-discipline", 10),
            ("mutex-discipline", 10),
        ],
    );
    assert_clean("good/mutex_discipline.rs");
}

#[test]
fn severities_match_rule_table() {
    let report = lint_one("bad/mutex_discipline.rs");
    assert!(report
        .diagnostics
        .iter()
        .all(|d| d.severity == Severity::Warning));
    let report = lint_one("bad/ambient_rng.rs");
    assert!(report
        .diagnostics
        .iter()
        .all(|d| d.severity == Severity::Error));
}

// -------------------------------------------------------------- pragmas

#[test]
fn pragma_allow_suppresses_and_is_counted() {
    let report = lint_one("good/pragma_allow.rs");
    assert!(
        report.diagnostics.is_empty(),
        "pragmas failed to suppress:\n{}",
        report.lines()
    );
    assert_eq!(report.suppressed, 2, "both forms should be counted");
}

#[test]
fn pragma_for_wrong_rule_does_not_suppress() {
    let report = lint_one("bad/pragma_mismatch.rs");
    let got: Vec<(&str, usize)> = report
        .diagnostics
        .iter()
        .map(|d| (d.rule, d.line))
        .collect();
    assert_eq!(got, vec![("no-wall-clock-in-pure-paths", 6)]);
    assert_eq!(report.suppressed, 0);
}

// ------------------------------------------------------- whole corpus

#[test]
fn corpus_scan_is_sorted_and_totals_add_up() {
    let report = analysis::lint_roots(&[fixture("")]).expect("scan corpus");
    // 12 fixture files, 15 findings total across the bad/ half.
    assert_eq!(report.files_scanned, 12);
    assert_eq!(report.diagnostics.len(), 15);
    assert_eq!(report.errors(), 10);
    assert_eq!(report.warnings(), 5);
    assert_eq!(report.suppressed, 2);
    // Diagnostics must come out ordered by (path, line, col, rule).
    let keys: Vec<(String, usize, usize, &str)> = report
        .diagnostics
        .iter()
        .map(|d| (d.path.clone(), d.line, d.col, d.rule))
        .collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted, "diagnostics are not in canonical order");
    // Every finding in the corpus scan points at a bad/ fixture.
    assert!(report
        .diagnostics
        .iter()
        .all(|d| d.path.contains("bad") && d.path.ends_with(".rs")));
}

// ----------------------------------------------------------- json shape

#[test]
fn json_report_is_valid_and_byte_stable() {
    let a = analysis::lint_roots(&[fixture("")]).expect("scan corpus");
    let b = analysis::lint_roots(&[fixture("")]).expect("scan corpus");
    let ja = a.to_json().to_string_pretty();
    let jb = b.to_json().to_string_pretty();
    assert_eq!(ja, jb, "lint --json must be byte-stable across runs");

    let parsed = Json::parse(&ja).expect("report must be valid JSON");
    assert_eq!(parsed.get("version").as_usize(), Some(1));
    assert_eq!(parsed.get("files_scanned").as_usize(), Some(12));
    assert_eq!(parsed.get("errors").as_usize(), Some(10));
    assert_eq!(parsed.get("warnings").as_usize(), Some(5));
    assert_eq!(parsed.get("suppressed").as_usize(), Some(2));
    assert_eq!(parsed.get("rules").as_arr().expect("rules array").len(), 5);
    let diags = parsed.get("diagnostics").as_arr().expect("diagnostics array");
    assert_eq!(diags.len(), 15);
    for d in diags {
        assert!(!d.get("path").as_str().expect("path").is_empty());
        assert!(d.get("line").as_usize().expect("line") >= 1);
        assert!(d.get("col").as_usize().expect("col") >= 1);
        assert!(!d.get("rule").as_str().expect("rule").is_empty());
        assert!(!d.get("message").as_str().expect("message").is_empty());
        let sev = d.get("severity").as_str().expect("severity");
        assert!(sev == "error" || sev == "warning", "severity {sev:?}");
    }
}

// ------------------------------------------------------------ self-scan

#[test]
fn self_scan_of_crate_is_clean() {
    let base = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = analysis::lint_tree(base).expect("self-scan");
    assert!(
        report.files_scanned > 30,
        "self-scan saw only {} files — tree walk is broken",
        report.files_scanned
    );
    assert_eq!(
        report.errors(),
        0,
        "self-scan must be error-free:\n{}",
        report.lines()
    );
    assert_eq!(
        report.warnings(),
        0,
        "self-scan must pass --deny-warnings:\n{}",
        report.lines()
    );
    // The fixture corpus is excluded from the default tree walk, so
    // none of the scanned paths may point into it.
    assert!(report.files_scanned > 0);
}
