// lint:path(rust/src/report/fixture.rs)
// GOOD: BTreeMap iterates in key order — deterministic artifacts.
use std::collections::BTreeMap;

pub fn emit_rows(rows: &BTreeMap<String, f64>) -> String {
    let mut out = String::new();
    for (k, v) in rows {
        out.push_str(&format!("{k}={v}\n"));
    }
    out
}
