// GOOD: the lockcheck wrapper recovers poison, and guards are taken
// one statement at a time.
use rram_pattern_accel::util::lockcheck::Mutex;

pub fn sample(m: &Mutex<Vec<f64>>, v: f64) {
    m.lock().push(v);
}

pub fn combined_len(a: &Mutex<Vec<f64>>, b: &Mutex<Vec<f64>>) -> usize {
    let n = a.lock().len();
    let m = b.lock().len();
    n + m
}
