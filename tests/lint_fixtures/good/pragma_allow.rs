// lint:path(rust/src/sim/fixture.rs)
// Suppression pragmas: above-line and same-line forms. Suppressed
// findings are counted in the report's `suppressed` field.

pub fn probe_us() -> u128 {
    // lint:allow(no-wall-clock-in-pure-paths)
    let t0 = std::time::Instant::now();
    let t1 = std::time::Instant::now(); // lint:allow(no-wall-clock-in-pure-paths)
    t1.duration_since(t0).as_micros()
}
