// GOOD: all randomness flows from an explicit recorded seed.
use rram_pattern_accel::util::rng::Rng;

pub fn roll(seed: u64) -> u64 {
    let mut rng = Rng::seed_from(seed);
    rng.next_u64()
}
