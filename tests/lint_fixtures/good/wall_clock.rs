// lint:path(rust/src/coordinator/fixture.rs)
// GOOD: the serving edge measures real queueing latency — outside the
// pure scope, so wall-clock reads are allowed without a pragma.

pub fn queue_latency_us(t0: std::time::Instant) -> u128 {
    let now = std::time::Instant::now();
    now.duration_since(t0).as_micros()
}
