// GOOD: per-item results fold in index order after the join — the
// canonical pattern (parallel_map preserves item order).
use rram_pattern_accel::util::threadpool::parallel_map;

pub fn total_energy(parts: &[f64], threads: usize) -> f64 {
    let per_item = parallel_map(parts, threads, |p| p * 2.0);
    let mut total = 0.0_f64;
    for v in per_item {
        total += v;
    }
    total
}
