// lint:path(rust/src/report/fixture.rs)
// BAD: HashMap feeds a serialized artifact — iteration order varies.
use std::collections::HashMap;

pub fn emit_rows(rows: &HashMap<String, f64>) -> String {
    let mut out = String::new();
    for (k, v) in rows {
        out.push_str(&format!("{k}={v}\n"));
    }
    out
}
