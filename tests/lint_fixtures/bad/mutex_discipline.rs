// BAD: raw poison-propagating locks and a nested single-statement
// acquisition outside the util wrappers.
use std::sync::Mutex;

pub fn sample(m: &Mutex<Vec<f64>>, v: f64) {
    m.lock().unwrap().push(v);
}

pub fn combined_len(a: &Mutex<Vec<f64>>, b: &Mutex<Vec<f64>>) -> usize {
    a.lock().unwrap().len() + b.lock().expect("poisoned").len()
}
