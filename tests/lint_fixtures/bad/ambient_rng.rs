// BAD: ambient entropy — unreproducible from a recorded seed.
use std::collections::hash_map::DefaultHasher;

pub fn unstable_hash(v: &[u64]) -> u64 {
    use std::hash::Hasher;
    let mut h = DefaultHasher::new();
    for x in v {
        h.write_u64(*x);
    }
    h.finish()
}

pub fn roll() -> u64 {
    rand::thread_rng().gen()
}
