// lint:path(rust/src/sim/fixture.rs)
// A pragma naming a *different* rule must not suppress the finding.

pub fn probe_us() -> u128 {
    // lint:allow(no-ambient-rng)
    let t0 = std::time::Instant::now();
    t0.elapsed().as_micros()
}
