// BAD: shared float accumulation inside a parallel closure commits in
// scheduling order — totals drift with thread count.
use rram_pattern_accel::util::threadpool::parallel_for;

pub fn total_energy(parts: &[f64], threads: usize) -> f64 {
    let mut total = 0.0_f64;
    parallel_for(parts.len(), threads, |i| {
        total += parts[i];
    });
    total
}
