// lint:path(rust/src/sim/fixture.rs)
// BAD: wall-clock reads inside the pure simulation scope.

pub fn stamp_us() -> u128 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_micros()
}

pub fn wall() -> std::time::SystemTime {
    std::time::SystemTime::now()
}
