//! Deterministic-seed regression tests (ISSUE-2): the synthetic trace →
//! aggregate → closed-form costing pipeline and the SmallCNN exact-mode
//! simulation must be byte-stable for a pinned seed, catching accidental
//! nondeterminism (e.g. in the histogram pass or the parallel layer
//! map).
//!
//! Each test renders its `LayerSimResult`s as pretty JSON and compares
//! them against a snapshot under `tests/snapshots/`. A missing snapshot
//! is written ("blessed") on first run so a fresh checkout
//! self-bootstraps — commit the generated file to pin the bytes.
//! Independently of the snapshot, every test re-runs its pipeline and
//! asserts in-process byte equality (and thread-count invariance where
//! a thread pool is involved), so nondeterminism is caught even before
//! a snapshot exists.

use std::path::PathBuf;

use rram_pattern_accel::config::{HardwareConfig, SimConfig};
use rram_pattern_accel::mapping::{pattern::PatternMapping, MappingScheme};
use rram_pattern_accel::nn::{ConvLayer, NetworkSpec, Tensor};
use rram_pattern_accel::pruning::synthetic::{generate_layer, CIFAR10};
use rram_pattern_accel::pruning::NetworkWeights;
use rram_pattern_accel::sim::smallcnn::SmallCnn;
use rram_pattern_accel::sim::workload::LayerTrace;
use rram_pattern_accel::sim::{self, simulate_layer};
use rram_pattern_accel::util::json::Json;
use rram_pattern_accel::util::rng::Rng;
use rram_pattern_accel::xbar::CellGeometry;

fn snapshot_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/snapshots")
        .join(name)
}

/// Compare `rendered` against the named snapshot, blessing the snapshot
/// when it does not exist yet.
fn assert_snapshot(name: &str, rendered: &str) {
    let path = snapshot_path(name);
    if !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, rendered).unwrap();
        eprintln!(
            "blessed new snapshot {} — commit it to pin the bytes",
            path.display()
        );
        return;
    }
    let want = std::fs::read_to_string(&path).unwrap();
    assert_eq!(
        rendered, want,
        "snapshot {name} drifted; delete the file to re-bless if the \
         change is intentional"
    );
}

/// Table-II-calibrated synthetic layer, pattern-mapped, costed against
/// a pinned-seed synthetic trace.
fn synthetic_layer_json() -> String {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let mut rng = Rng::seed_from(42);
    let w = generate_layer(
        64,
        16,
        6,
        CIFAR10.sparsity,
        CIFAR10.all_zero_ratio,
        &mut rng,
    );
    let l = ConvLayer { name: "snap".into(), cout: 64, cin: 16, fmap: 8 };
    let ml = PatternMapping.map_layer(0, &l, &w, &geom);
    let sim_cfg = SimConfig::default();
    let mut trng = Rng::seed_from(sim_cfg.seed);
    let trace = LayerTrace::synthetic(l.cin, 48, &sim_cfg, &mut trng);
    let r = simulate_layer(
        &ml,
        l.positions(),
        &trace,
        &hw,
        true,
        sim_cfg.block_switch_cycles,
    );
    r.to_json().to_string_pretty()
}

#[test]
fn synthetic_layer_sim_is_byte_stable() {
    let a = synthetic_layer_json();
    let b = synthetic_layer_json();
    assert_eq!(a, b, "pipeline not deterministic across in-process runs");
    assert_snapshot("synthetic_layer_sim_seed42.json", &a);
}

/// Synthetic two-conv SmallCNN bundle driven through the exact-mode
/// (real-activation-trace) simulation.
fn smallcnn_exact_json() -> String {
    let spec = NetworkSpec {
        name: "snapnet".into(),
        layers: vec![
            ConvLayer { name: "c0".into(), cin: 3, cout: 8, fmap: 8 },
            ConvLayer { name: "c1".into(), cin: 8, cout: 12, fmap: 8 },
        ],
    };
    let model = SmallCnn::synthetic(spec, 7);
    let hw = HardwareConfig::smallcnn_functional();
    let mapped = model.map(&PatternMapping, &hw);
    let mut rng = Rng::seed_from(0xDECAF);
    let mut x = Tensor::zeros(&[1, 3, 8, 8]);
    for v in x.data.iter_mut() {
        *v = if rng.chance(0.4) { 0.0 } else { rng.f32() };
    }
    let results = model.simulate_exact(&mapped, &x, &hw, &SimConfig::default());
    Json::Arr(results.iter().map(|r| r.to_json()).collect()).to_string_pretty()
}

#[test]
fn smallcnn_exact_sim_is_byte_stable() {
    let a = smallcnn_exact_json();
    let b = smallcnn_exact_json();
    assert_eq!(a, b, "exact-mode pipeline not deterministic");
    assert_snapshot("smallcnn_exact_sim_seed7.json", &a);
}

/// Batched simulation bytes must not depend on the worker thread count
/// — the parallel layer map may not change accumulation order.
#[test]
fn batch_sim_bytes_are_thread_invariant() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let spec = NetworkSpec {
        name: "tnet".into(),
        layers: vec![
            ConvLayer { name: "c0".into(), cin: 3, cout: 16, fmap: 8 },
            ConvLayer { name: "c1".into(), cin: 16, cout: 24, fmap: 8 },
            ConvLayer { name: "c2".into(), cin: 24, cout: 24, fmap: 4 },
        ],
    };
    let mut rng = Rng::seed_from(123);
    let layers = spec
        .layers
        .iter()
        .map(|l| generate_layer(l.cout, l.cin, 5, 0.85, 0.35, &mut rng))
        .collect();
    let nw = NetworkWeights::new(spec.clone(), layers);
    let mapped = PatternMapping.map_network(&nw, &geom, 2);
    let sim_cfg = SimConfig::default();
    let a = sim::simulate_network_batch(&mapped, &spec, &hw, &sim_cfg, 3, 1)
        .to_json()
        .to_string_pretty();
    let b = sim::simulate_network_batch(&mapped, &spec, &hw, &sim_cfg, 3, 4)
        .to_json()
        .to_string_pretty();
    assert_eq!(a, b, "batch JSON differs across thread counts");
}
