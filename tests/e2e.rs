//! End-to-end tests over the real AOT artifacts (require
//! `make artifacts` to have run; they are skipped with a notice when
//! artifacts/ is absent so `cargo test` works on a fresh checkout).
//! The PJRT-backed tests additionally require the `xla-runtime`
//! feature — the default build's stub engine cannot load artifacts.

use std::path::{Path, PathBuf};

use rram_pattern_accel::config::HardwareConfig;
#[cfg(feature = "xla-runtime")]
use rram_pattern_accel::coordinator::{Coordinator, PjrtBackend};
use rram_pattern_accel::mapping::{pattern::PatternMapping, MappingScheme};
use rram_pattern_accel::pruning::Pattern;
#[cfg(feature = "xla-runtime")]
use rram_pattern_accel::runtime::Engine;
use rram_pattern_accel::sim::smallcnn::{argmax, image, SmallCnn, TestData};
use rram_pattern_accel::xbar::CellGeometry;

fn artifacts() -> Option<PathBuf> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("smallcnn_meta.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn model_bundle_loads_and_maps() {
    let Some(dir) = artifacts() else { return };
    let model = SmallCnn::load(&dir).expect("load bundle");
    assert_eq!(model.spec.layers.len(), 5);
    assert_eq!(model.n_classes, 10);
    let hw = HardwareConfig::smallcnn_functional();
    let mapped = model.map(&PatternMapping, &hw);
    mapped.validate().expect("mapping invariants");
    // pruned network must actually be pattern-sparse
    let stats = model.weights.stats();
    assert!(stats.sparsity > 0.5, "sparsity {}", stats.sparsity);
    for (li, n) in stats.patterns_per_layer.iter().enumerate() {
        assert!(*n <= 10, "layer {li} has {n} patterns");
    }
}

#[test]
fn python_candidates_match_rust_extraction() {
    // The candidate patterns python selected must cover every kernel
    // pattern rust extracts from the exported weights.
    let Some(dir) = artifacts() else { return };
    let model = SmallCnn::load(&dir).expect("load bundle");
    for (li, w) in model.weights.layers.iter().enumerate() {
        let name = format!("conv{li}");
        let cands: Vec<Pattern> = model
            .meta
            .get("candidates")
            .get(&name)
            .as_arr()
            .expect("candidates")
            .iter()
            .map(|p| Pattern(p.as_usize().unwrap() as u16))
            .collect();
        let counts = rram_pattern_accel::pruning::layer_pattern_counts(w);
        for pat in counts.keys() {
            let covered = pat.is_zero()
                || cands.iter().any(|c| c.superset_of(*pat));
            assert!(covered, "layer {li}: pattern {:#b} not covered", pat.0);
        }
    }
}

#[cfg(feature = "xla-runtime")]
#[test]
fn pjrt_matches_python_golden() {
    let Some(dir) = artifacts() else { return };
    let td = TestData::load(&dir).expect("test data");
    let engine = Engine::load(&dir.join("smallcnn_b1.hlo.txt")).expect("engine");
    let n = td.golden_x.shape[0];
    for i in 0..n {
        let img = image(&td.golden_x, i);
        let out = engine
            .run_f32(&[(&[1usize, 3, 32, 32], &img.data)])
            .expect("run");
        for (o, g) in out
            .iter()
            .zip(td.golden_logits.data[i * 10..(i + 1) * 10].iter())
        {
            assert!((o - g).abs() < 1e-3, "image {i}: {o} vs {g}");
        }
    }
}

#[test]
fn mapped_simulator_accuracy_matches_python() {
    let Some(dir) = artifacts() else { return };
    let model = SmallCnn::load(&dir).expect("bundle");
    let td = TestData::load(&dir).expect("test data");
    let hw = HardwareConfig::smallcnn_functional();
    let mapped = model.map(&PatternMapping, &hw);
    let n = 48.min(td.test_x.shape[0]);
    let mut correct = 0usize;
    for i in 0..n {
        let img = image(&td.test_x, i);
        let logits = model.forward(&mapped, &img, &hw, true);
        if argmax(&logits) as i32 == td.test_y[i] {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;
    let py = model.meta.get("accuracy").get("crossbar").as_f64().unwrap();
    assert!(
        (acc - py).abs() < 0.15,
        "rust mapped accuracy {acc} vs python crossbar {py}"
    );
}

#[cfg(feature = "xla-runtime")]
#[test]
fn coordinator_serves_real_engine() {
    let Some(dir) = artifacts() else { return };
    let td = TestData::load(&dir).expect("test data");
    let hlo = dir.join("smallcnn_b8.hlo.txt");
    let coord = Coordinator::start(
        move || {
            let engine = Engine::load(&hlo).expect("engine");
            PjrtBackend {
                engine,
                batch: 8,
                input_shape: vec![3, 32, 32],
                output_len: 10,
            }
        },
        std::time::Duration::from_millis(5),
    );
    let img_len = 3 * 32 * 32;
    let n = 16usize;
    let rxs: Vec<_> = (0..n)
        .map(|i| coord.submit(td.test_x.data[i * img_len..(i + 1) * img_len].to_vec()))
        .collect();
    let mut correct = 0;
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx.recv_timeout(std::time::Duration::from_secs(60)).expect("reply");
        assert_eq!(reply.logits().len(), 10);
        if argmax(reply.logits()) as i32 == td.test_y[i] {
            correct += 1;
        }
    }
    // the pruned model is highly accurate on its test set
    assert!(correct >= n * 6 / 10, "served accuracy too low: {correct}/{n}");
    coord.shutdown();
}

#[test]
fn exact_simulation_over_real_image() {
    // Trace-aggregated engine in exact mode: the real activations of
    // one test image drive per-layer cycle/energy accounting.
    let Some(dir) = artifacts() else { return };
    let model = SmallCnn::load(&dir).expect("bundle");
    let td = TestData::load(&dir).expect("test data");
    let hw = HardwareConfig::smallcnn_functional();
    let mapped = model.map(&PatternMapping, &hw);
    let img = image(&td.test_x, 0);
    let sim_cfg = rram_pattern_accel::config::SimConfig::default();
    let results = model.simulate_exact(&mapped, &img, &hw, &sim_cfg);
    assert_eq!(results.len(), mapped.layers.len());
    for r in &results {
        assert!(r.ou_ops > 0.0, "layer {} executes nothing", r.layer_idx);
        assert!(r.energy.total_pj() > 0.0);
        assert!(r.cycles >= r.ou_ops);
    }
}

#[test]
fn scales_metadata_sane() {
    let Some(dir) = artifacts() else { return };
    let model = SmallCnn::load(&dir).expect("bundle");
    for s in &model.scales {
        assert!(s.sx > 0.0 && s.sx < 10.0);
        assert!(s.sw > 0.0 && s.sw < 1.0);
    }
    // geometry check: mapping respects the functional hw config
    let hw = HardwareConfig::smallcnn_functional();
    let geom = CellGeometry::from_hw(&hw);
    assert_eq!(geom.cells_per_weight, 4);
}
