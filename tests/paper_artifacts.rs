//! Tier-2 paper-artifact conformance suite (ISSUE-5).
//!
//! These tests regenerate the paper figure artifacts through the
//! `report::artifacts` pipeline on the smallest Table-II profile
//! (cifar10) in both trace modes and pin the pipeline's contract:
//!
//!   1. exact artifacts are byte-identical across thread counts and
//!      across cached-vs-fresh runs,
//!   2. every |sampled − exact| relative delta sits inside the declared
//!      tolerance bands (structural metrics: exactly equal),
//!   3. the paper's ordering/band invariants hold in exact mode
//!      (pattern ≥ k-means ≥ naive on area efficiency; the published
//!      4.16x–5.20x area band bracketed by the reproduction band).
//!
//! They are `#[ignore]`d so the tier-1 `cargo test -q` wall time is
//! untouched; the CI `paper-artifacts` job (and local runs) enable
//! them with:
//!
//! ```text
//! PAPER_TIER2=1 cargo test --release --test paper_artifacts -- --ignored
//! ```

use rram_pattern_accel::pruning::synthetic::{DatasetProfile, CIFAR10};
use rram_pattern_accel::report::artifacts::{
    delta_report, ArtifactCache, ArtifactConfig, DeltaTolerances,
    PaperArtifacts, TraceMode, PAPER_AREA_BAND,
};

const TIER2_ENV: &str = "PAPER_TIER2";

/// The suite runs only when explicitly requested: `--ignored` alone is
/// not enough, the env gate must agree (so a blanket
/// `cargo test -- --ignored` elsewhere cannot pull in the slow tier).
/// Any non-empty value except `0` enables it; a skip always says so on
/// stderr — a green gate must never mean "silently did nothing".
fn tier2_enabled() -> bool {
    match std::env::var(TIER2_ENV) {
        Ok(v) if !v.is_empty() && v != "0" => true,
        other => {
            eprintln!(
                "skipping: tier-2 conformance needs {TIER2_ENV}=1 \
                 (currently {other:?}; run via the CI paper-artifacts job \
                 or set it locally)"
            );
            false
        }
    }
}

/// Smallest profile: the tier-2 CI budget is one VGG16-CIFAR dataset.
fn profiles() -> Vec<&'static DatasetProfile> {
    vec![&CIFAR10]
}

fn cfg(mode: TraceMode, threads: usize) -> ArtifactConfig {
    ArtifactConfig { seed: 42, mode, threads }
}

fn emitted_bytes(p: &PaperArtifacts) -> Vec<String> {
    vec![
        p.fig7_json().to_string_pretty(),
        p.fig8_json().to_string_pretty(),
        p.table2_json().to_string_pretty(),
    ]
}

fn temp_cache(tag: &str) -> ArtifactCache {
    let dir = std::env::temp_dir()
        .join(format!("rram-paper-tier2-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    ArtifactCache::new(dir)
}

/// Conformance 1a: exact (and sampled) artifact bytes are invariant
/// under the worker thread count — and so is the delta report built
/// from them.
#[test]
#[ignore = "tier 2: set PAPER_TIER2=1 and run with --ignored"]
fn artifacts_are_byte_identical_across_thread_counts() {
    if !tier2_enabled() {
        return;
    }
    let profs = profiles();
    let tol = DeltaTolerances::default();
    let mut reports = Vec::new();
    for threads in [1usize, 2] {
        let sampled = PaperArtifacts::generate(
            &profs,
            &cfg(TraceMode::Sampled(64), threads),
            None,
        );
        let exact =
            PaperArtifacts::generate(&profs, &cfg(TraceMode::Exact, threads), None);
        reports.push((
            emitted_bytes(&sampled),
            emitted_bytes(&exact),
            delta_report(&sampled, &exact, &tol)
                .expect("delta report")
                .to_json()
                .to_string_pretty(),
        ));
    }
    let (s1, e1, d1) = &reports[0];
    let (s2, e2, d2) = &reports[1];
    assert_eq!(s1, s2, "sampled artifact bytes differ across thread counts");
    assert_eq!(e1, e2, "exact artifact bytes differ across thread counts");
    assert_eq!(d1, d2, "delta report bytes differ across thread counts");
}

/// Conformance 1b: a cached rerun serves every dataset from the cache
/// and reproduces the fresh run's bytes exactly.
#[test]
#[ignore = "tier 2: set PAPER_TIER2=1 and run with --ignored"]
fn cached_rerun_is_bit_exact_with_fresh_run() {
    if !tier2_enabled() {
        return;
    }
    let profs = profiles();
    let cache = temp_cache("cache");
    for mode in [TraceMode::Sampled(64), TraceMode::Exact] {
        let fresh =
            PaperArtifacts::generate(&profs, &cfg(mode, 2), Some(&cache));
        assert_eq!(fresh.cache_hits, 0, "{} cold cache", mode.name());
        let cached =
            PaperArtifacts::generate(&profs, &cfg(mode, 1), Some(&cache));
        assert_eq!(
            cached.cache_hits,
            profs.len(),
            "{} rerun must be all cache hits",
            mode.name()
        );
        assert_eq!(
            emitted_bytes(&fresh),
            emitted_bytes(&cached),
            "{} cached bytes drifted from fresh",
            mode.name()
        );
    }
    let _ = std::fs::remove_dir_all(cache.dir());
}

/// Conformance 2: every recorded |sampled − exact| relative delta is
/// inside its tolerance band — structural metrics exactly equal,
/// trace-dependent metrics within the configured bands.
#[test]
#[ignore = "tier 2: set PAPER_TIER2=1 and run with --ignored"]
fn sampled_vs_exact_deltas_within_tolerance() {
    if !tier2_enabled() {
        return;
    }
    let profs = profiles();
    let sampled =
        PaperArtifacts::generate(&profs, &cfg(TraceMode::Sampled(64), 2), None);
    let exact =
        PaperArtifacts::generate(&profs, &cfg(TraceMode::Exact, 2), None);
    let rep = delta_report(&sampled, &exact, &DeltaTolerances::default())
        .expect("delta report");
    assert!(!rep.entries.is_empty());
    assert!(rep.all_within(), "deltas out of band:\n{}", rep.lines());
    // structural metrics must not move between modes at all
    for e in &rep.entries {
        if e.tolerance == 0.0 {
            assert_eq!(
                e.rel_delta, 0.0,
                "structural metric {}/{} moved between modes",
                e.figure, e.metric
            );
        }
    }
}

/// Conformance 3: the paper's ordering and band invariants hold in
/// exact mode — no sampling artifacts behind the headline claims.
#[test]
#[ignore = "tier 2: set PAPER_TIER2=1 and run with --ignored"]
fn exact_mode_ordering_and_band_invariants() {
    if !tier2_enabled() {
        return;
    }
    let profs = profiles();
    let exact =
        PaperArtifacts::generate(&profs, &cfg(TraceMode::Exact, 2), None);
    for d in &exact.datasets {
        let naive = d.metric("fig7", "naive_crossbars").unwrap();
        let pattern = d.metric("fig7", "pattern_crossbars").unwrap();
        let kmeans = d.metric("fig7", "kmeans_crossbars").unwrap();
        // area-efficiency ordering: pattern ≥ k-means ≥ naive (i.e.
        // pattern needs the fewest crossbars, naive the most), and the
        // pattern scheme's saving is strict
        assert!(
            pattern <= kmeans && kmeans <= naive && pattern < naive,
            "{}: area ordering broken (naive {naive}, kmeans {kmeans}, \
             pattern {pattern})",
            d.dataset
        );
        let eff = d.metric("fig7", "area_efficiency").unwrap();
        // the reproduction band (3x..8x) brackets the paper's published
        // 4.16x–5.20x spread; the row must carry the paper reference
        assert!(
            eff > 3.0 && eff < 8.0,
            "{}: exact-mode area efficiency {eff:.2} out of band",
            d.dataset
        );
        let paper = d.metric("fig7", "paper_efficiency").unwrap();
        assert!(
            (PAPER_AREA_BAND.0..=PAPER_AREA_BAND.1).contains(&paper),
            "{}: paper reference {paper} outside the published 4.16–5.20 band",
            d.dataset
        );
        // energy and speedup stay in their reproduction bands too
        let energy = d.metric("fig8", "energy_efficiency").unwrap();
        assert!(
            energy > 1.4 && energy < 3.5,
            "{}: exact-mode energy efficiency {energy:.2} out of band",
            d.dataset
        );
        let speedup = d.metric("table2", "speedup").unwrap();
        assert!(
            speedup > 1.0,
            "{}: exact-mode speedup {speedup:.2} must beat the baseline",
            d.dataset
        );
    }
}
