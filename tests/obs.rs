//! Integration tests for the tracing & profiling layer (`src/obs/`):
//!
//!   * ring buffers stay bounded and overwrite oldest-first;
//!   * a traced pool emits the causally-linked span chain
//!     root → `pool.admit` → `pool.queue` → `pool.exec`;
//!   * trace IDs survive the failure paths: per-attempt `pool.retry`
//!     instants, and a cross-worker requeue keeps the rescued request's
//!     original trace ID end to end;
//!   * Chrome trace-event JSON is byte-stable given pinned timestamps
//!     (the `TestClock`);
//!   * pool latency telemetry is O(1) in memory under a million-request
//!     loop, with deterministic quantiles (satellite of ISSUE-9: the
//!     unbounded `latencies_us` vector is gone).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use rram_pattern_accel::coordinator::{
    BalancePolicy, Coordinator, CoordinatorConfig, InferBackend, Metrics,
};
use rram_pattern_accel::obs::{
    self, chrome_trace_json, Registry, SpanRecord, TraceCtx,
    DEFAULT_RESERVOIR_CAP,
};
use rram_pattern_accel::util::clock::TestClock;

fn test_registry(cap: usize) -> (Arc<TestClock>, Arc<Registry>) {
    let clock = Arc::new(TestClock::new());
    let reg = Registry::new(clock.clone(), cap);
    (clock, reg)
}

/// Deterministic single-slot backend: sums the two input elements.
struct SumBackend;

impl InferBackend for SumBackend {
    fn input_len(&self) -> usize {
        2
    }
    fn output_len(&self) -> usize {
        1
    }
    fn batch_size(&self) -> usize {
        1
    }
    fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
        Ok(vec![batch[0] + batch[1]])
    }
}

fn find<'a>(spans: &'a [SpanRecord], name: &str) -> Option<&'a SpanRecord> {
    spans.iter().find(|s| s.name == name)
}

#[test]
fn ring_buffers_stay_bounded_under_load() {
    let (_clock, reg) = test_registry(8);
    let buf = reg.buffer("load");
    for i in 0..100u64 {
        reg.record(&buf, 1, 0, "e", i, 1, &[("i", i)]);
    }
    assert_eq!(buf.len(), 8, "ring bounded at capacity");
    assert_eq!(buf.capacity(), 8);
    let snap = buf.snapshot();
    let starts: Vec<u64> = snap.iter().map(|s| s.start_us).collect();
    assert_eq!(starts, (92..100).collect::<Vec<u64>>(), "oldest overwritten");
}

/// The acceptance criterion of ISSUE-9: one traced request produces at
/// least four nested, causally-linked spans (boundary root →
/// `pool.admit` → `pool.queue` → `pool.exec`), and the reply echoes the
/// trace ID for correlation.
#[test]
fn traced_pool_emits_nested_span_chain() {
    let (_clock, reg) = test_registry(64);
    let c = Coordinator::start_pool(
        |_worker| SumBackend,
        CoordinatorConfig {
            max_wait: Duration::from_millis(1),
            trace: Some(reg.clone()),
            ..Default::default()
        },
        None,
    );
    // Emulate the serving boundary the way serve_http does: mint the
    // trace, open a root span, propagate the context into the pool.
    let edge = reg.buffer("edge");
    let trace_id = reg.new_trace();
    assert_ne!(trace_id, 0);
    let root = reg.begin(trace_id, 0, "edge.infer");
    let ctx = TraceCtx { trace_id, parent: root.span_id };
    let reply = c
        .submit_traced(vec![2.0, 3.0], None, ctx)
        .recv_timeout(Duration::from_secs(10))
        .expect("terminal reply");
    assert_eq!(reply.result.expect("success")[0], 5.0);
    assert_eq!(reply.trace_id, trace_id, "reply echoes the trace ID");
    reg.end(&edge, root, &[("status", 200)]);
    c.shutdown();

    let spans: Vec<SpanRecord> = reg
        .snapshot()
        .into_iter()
        .filter(|s| s.trace_id == trace_id)
        .collect();
    assert!(spans.len() >= 4, "expected >= 4 spans, got {spans:?}");
    let root_rec = find(&spans, "edge.infer").expect("root span");
    let admit = find(&spans, "pool.admit").expect("admission span");
    let queue = find(&spans, "pool.queue").expect("queue span");
    let exec = find(&spans, "pool.exec").expect("exec span");
    assert_eq!(root_rec.parent_id, 0, "root has no parent");
    assert_eq!(admit.parent_id, root_rec.span_id);
    assert_eq!(queue.parent_id, admit.span_id);
    assert_eq!(exec.parent_id, queue.span_id);
    assert!(
        exec.args().iter().any(|&(k, v)| k == "fill" && v >= 1),
        "exec span carries the batch fill: {:?}",
        exec.args()
    );
    // the admission span landed in the dispatcher's ring, the
    // queue/exec spans in the worker's
    let names: Vec<String> =
        reg.buffers().iter().map(|b| b.name().to_string()).collect();
    assert!(names.contains(&"dispatch".to_string()), "{names:?}");
    assert!(names.contains(&"worker-0".to_string()), "{names:?}");
}

/// A cross-worker requeue keeps the rescued request's original trace
/// ID: the whole journey — dead worker, `pool.requeue` instant, rescue
/// on the sibling — is one trace.
#[test]
fn requeued_request_keeps_its_trace_id() {
    struct DirectedBackend {
        dead: bool,
    }
    impl InferBackend for DirectedBackend {
        fn input_len(&self) -> usize {
            2
        }
        fn output_len(&self) -> usize {
            1
        }
        fn batch_size(&self) -> usize {
            1
        }
        fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
            if self.dead {
                return Err("dead backend".to_string());
            }
            Ok(vec![batch[0] + batch[1]])
        }
    }
    let (_clock, reg) = test_registry(64);
    let c = Coordinator::start_pool(
        |worker| DirectedBackend { dead: worker == 0 },
        CoordinatorConfig {
            max_wait: Duration::from_millis(1),
            max_retries: 0,
            workers: 2,
            balance: BalancePolicy::RoundRobin,
            quarantine_after: 0, // keep routing to the dead worker
            max_requeues: 1,
            trace: Some(reg.clone()),
            ..Default::default()
        },
        None,
    );
    let trace_id = reg.new_trace();
    let ctx = TraceCtx { trace_id, parent: 0 };
    let reply = c
        .submit_traced(vec![4.0, 1.0], None, ctx)
        .recv_timeout(Duration::from_secs(10))
        .expect("terminal reply");
    assert_eq!(reply.result.expect("requeue rescues the request")[0], 5.0);
    assert_eq!(
        reply.trace_id, trace_id,
        "requeued request keeps its original trace ID"
    );
    c.shutdown();

    let spans: Vec<SpanRecord> = reg
        .snapshot()
        .into_iter()
        .filter(|s| s.trace_id == trace_id)
        .collect();
    let requeue = find(&spans, "pool.requeue").expect("requeue instant");
    assert_eq!(requeue.dur_us, 0, "instant event");
    assert!(
        requeue
            .args()
            .iter()
            .any(|&(k, v)| k == "from_worker" && v == 0),
        "{:?}",
        requeue.args()
    );
    // both admissions (initial + requeue) and the final exec are on
    // the same trace
    let admits = spans.iter().filter(|s| s.name == "pool.admit").count();
    assert_eq!(admits, 2, "{spans:?}");
    assert!(find(&spans, "pool.exec").is_some(), "{spans:?}");
}

/// Per-attempt `pool.retry` instants share the request's trace, and the
/// final `pool.exec` span reports the attempt count.
#[test]
fn retry_instants_share_the_trace() {
    struct FailOnce {
        calls: AtomicUsize,
    }
    impl InferBackend for FailOnce {
        fn input_len(&self) -> usize {
            2
        }
        fn output_len(&self) -> usize {
            1
        }
        fn batch_size(&self) -> usize {
            1
        }
        fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
            if self.calls.fetch_add(1, Ordering::SeqCst) == 0 {
                return Err("transient".to_string());
            }
            Ok(vec![batch[0] + batch[1]])
        }
    }
    let (_clock, reg) = test_registry(64);
    let c = Coordinator::start_pool(
        |_worker| FailOnce { calls: AtomicUsize::new(0) },
        CoordinatorConfig {
            max_wait: Duration::from_millis(1),
            max_retries: 1,
            trace: Some(reg.clone()),
            ..Default::default()
        },
        None,
    );
    let trace_id = reg.new_trace();
    let reply = c
        .submit_traced(vec![1.0, 1.0], None, TraceCtx { trace_id, parent: 0 })
        .recv_timeout(Duration::from_secs(10))
        .expect("terminal reply");
    assert_eq!(reply.result.expect("retry rescues the batch")[0], 2.0);
    c.shutdown();

    let spans: Vec<SpanRecord> = reg
        .snapshot()
        .into_iter()
        .filter(|s| s.trace_id == trace_id)
        .collect();
    let retry = find(&spans, "pool.retry").expect("retry instant");
    assert!(
        retry.args().iter().any(|&(k, v)| k == "attempt" && v == 1),
        "{:?}",
        retry.args()
    );
    let exec = find(&spans, "pool.exec").expect("exec span");
    assert!(
        exec.args().iter().any(|&(k, v)| k == "attempts" && v == 2),
        "{:?}",
        exec.args()
    );
}

/// Chrome trace-event export is byte-stable: two identically-driven
/// registries with pinned clocks produce identical compact JSON.
#[test]
fn chrome_trace_json_is_byte_stable() {
    let build = || {
        let (clock, reg) = test_registry(16);
        let buf = reg.buffer("main");
        clock.set(100);
        let t = reg.new_trace();
        let outer = reg.begin(t, 0, "outer");
        clock.advance(40);
        let inner = reg.begin(t, outer.span_id, "inner");
        clock.advance(10);
        let inner_id = reg.end(&buf, inner, &[("n", 2)]);
        assert_ne!(inner_id, 0);
        clock.advance(5);
        reg.end(&buf, outer, &[]);
        chrome_trace_json(&reg.snapshot()).to_string_compact()
    };
    let a = build();
    let b = build();
    assert_eq!(a, b, "byte-stable given pinned timestamps");
    assert!(a.starts_with("{\"traceEvents\":["), "{a}");

    // required Chrome trace-event keys, via the parsed form
    let j = rram_pattern_accel::util::json::Json::parse(&a).expect("valid JSON");
    let events = j.get("traceEvents");
    let ev = events.idx(0);
    assert_eq!(ev.get("ph").as_str(), Some("X"));
    assert_eq!(ev.get("pid").as_u64(), Some(1));
    assert!(ev.get("tid").as_u64().is_some());
    assert!(ev.get("ts").as_u64().is_some());
    assert!(ev.get("name").as_str().is_some());
    assert!(ev.get("args").get("trace_id").as_u64().is_some());
    // snapshot order is (start_us, span_id): outer (ts 100) first,
    // then inner (ts 140, dur 10)
    let inner = events.idx(1);
    assert_eq!(inner.get("ts").as_u64(), Some(140));
    assert_eq!(inner.get("dur").as_u64(), Some(10));
}

/// Satellite 1 of ISSUE-9: pool latency telemetry must be O(1) in
/// memory however many requests pass through — the histogram holds
/// every sample in fixed buckets, the reservoir caps the exact-quantile
/// set — and quantiles must be deterministic run to run.
#[test]
fn latency_telemetry_is_bounded_and_deterministic() {
    let run = || {
        let m = Metrics::default();
        for i in 0..1_000_000u64 {
            m.record_latency_us((i % 1_000) as f64);
        }
        m
    };
    let a = run();
    // the exact-value reservoir is capped; the histogram counted all
    assert_eq!(a.latency_summary().len(), DEFAULT_RESERVOIR_CAP);
    let sa = a.snapshot();
    assert_eq!(sa.latency_count, 1_000_000);
    assert!(sa.latency_p99_us > 0.0);
    let last = *sa.latency_buckets.last().expect("buckets");
    assert!(last.0.is_infinite());
    assert_eq!(last.1, 1_000_000, "cumulative buckets cover every sample");

    // bit-deterministic across identical runs, including after a merge
    let b = run();
    let sb = b.snapshot();
    assert_eq!(sa.latency_p50_us, sb.latency_p50_us);
    assert_eq!(sa.latency_p99_us, sb.latency_p99_us);
    assert_eq!(sa.latency_mean_us, sb.latency_mean_us);
    assert_eq!(sa.latency_buckets, sb.latency_buckets);
    let merged = Metrics::merge([&a, &b]);
    let sm = merged.snapshot();
    assert_eq!(sm.latency_count, 2_000_000);
    assert_eq!(sm.latency_p99_us, sa.latency_p99_us);
}

/// The process-wide cache counters only ever accumulate, and a store
/// probe moves exactly one of hit/miss.
#[test]
fn cache_counters_accumulate_monotonically() {
    let before = obs::counters::snapshot();
    let dir = std::env::temp_dir()
        .join(format!("rram-obs-it-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = rram_pattern_accel::store::PackStore::open(
        &dir.to_string_lossy(),
        "obs-test",
    )
    .expect("open pack");
    assert!(store.get(42).is_none(), "cold store misses");
    store.put(42, "answer", &[1, 2, 3]).expect("put");
    assert!(store.get(42).is_some(), "hit after put");
    let after = obs::counters::snapshot();
    assert!(after.store_misses >= before.store_misses + 1);
    assert!(after.store_hits >= before.store_hits + 1);
    let _ = std::fs::remove_dir_all(&dir);
}
