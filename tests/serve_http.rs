//! HTTP front door (ISSUE-7): property/fuzz suites over the bounded
//! request reader and the lazy infer-body scanner, plus live-server
//! end-to-end tests over loopback — status-code mapping (504/429/502/
//! 4xx families), exact mock logits, keep-alive, connection caps, and
//! an arbitrary-byte fuzz asserting the server always answers with a
//! well-formed status line and never panics a handler. ISSUE-9 adds
//! end-to-end trace coverage: a traced pool behind the server must
//! expose the request's nested span chain via `GET /debug/trace`.

use std::io::Cursor;
use std::time::Duration;

use rram_pattern_accel::coordinator::{
    Coordinator, CoordinatorConfig, CostModel, ERR_DEADLINE_PREFIX,
    ERR_OVERLOAD_PREFIX,
};
use rram_pattern_accel::obs;
use rram_pattern_accel::serve_http::client::HttpClient;
use rram_pattern_accel::serve_http::request::{
    read_request, ReadError, MAX_HEADERS,
};
use rram_pattern_accel::serve_http::scan::scan_infer;
use rram_pattern_accel::serve_http::{HttpConfig, HttpServer, MockInferBackend};
use rram_pattern_accel::util::clock;
use rram_pattern_accel::util::json::Json;
use rram_pattern_accel::util::prop;
use rram_pattern_accel::util::rng::Rng;

const INPUT_LEN: usize = 8;
const OUTPUT_LEN: usize = 4;

/// Start a loopback server over a mock-backend pool. Every knob the
/// tests vary is a parameter; everything else is the production
/// default.
fn start_mock(
    backend: MockInferBackend,
    ccfg: CoordinatorConfig,
    cost: Option<CostModel>,
    mut http: HttpConfig,
) -> HttpServer {
    let MockInferBackend { input_len, output_len, batch, delay, fail } = backend;
    http.addr = "127.0.0.1:0".to_string();
    http.input_len = input_len;
    let coord = Coordinator::start_pool(
        move |_worker| MockInferBackend { input_len, output_len, batch, delay, fail },
        ccfg,
        cost,
    );
    HttpServer::start(coord, http).expect("bind loopback")
}

fn mock(delay: Duration, fail: bool, batch: usize) -> MockInferBackend {
    MockInferBackend {
        input_len: INPUT_LEN,
        output_len: OUTPUT_LEN,
        batch,
        delay,
        fail,
    }
}

fn infer_body(
    image: &[f32],
    deadline_us: Option<u64>,
    batch_hint: Option<u64>,
) -> Vec<u8> {
    let mut s = String::from("{\"image\":[");
    for (i, v) in image.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!("{v}"));
    }
    s.push(']');
    if let Some(d) = deadline_us {
        s.push_str(&format!(",\"deadline_us\":{d}"));
    }
    if let Some(b) = batch_hint {
        s.push_str(&format!(",\"batch_hint\":{b}"));
    }
    s.push('}');
    s.into_bytes()
}

// ---- request reader: property/fuzz suites (no server) ----

/// Arbitrary bytes through the reader: any outcome is fine, panicking
/// or hanging is not. (Hangs are impossible off a Cursor — EOF ends
/// every read loop.)
#[test]
fn prop_reader_survives_arbitrary_bytes() {
    prop::check("reader_arbitrary_bytes", prop::cases(256), |rng| {
        let len = rng.below(2048);
        let bytes: Vec<u8> =
            (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect();
        let mut carry = Vec::new();
        let _ = read_request(&mut Cursor::new(&bytes), &mut carry, 4096);
    });
}

/// Every strict prefix of a valid request is reported as a truncation
/// (or idle close for the empty prefix) — never as success, never as a
/// parse error that would mislabel a network problem as a bad request.
#[test]
fn prop_reader_classifies_truncation() {
    prop::check("reader_truncation", prop::cases(128), |rng| {
        let body_len = rng.below(64);
        let body: Vec<u8> =
            (0..body_len).map(|_| b'a' + (rng.below(26) as u8)).collect();
        let mut req = format!(
            "POST /v1/infer HTTP/1.1\r\nHost: x\r\nContent-Length: {body_len}\r\n\r\n"
        )
        .into_bytes();
        req.extend_from_slice(&body);
        let cut = rng.below(req.len()); // strict prefix: 0..len-1 bytes
        let mut carry = Vec::new();
        let got = read_request(&mut Cursor::new(&req[..cut]), &mut carry, 4096);
        match got {
            Err(ReadError::ClosedIdle) => assert_eq!(cut, 0, "idle close needs empty input"),
            Err(ReadError::Truncated) => assert!(cut > 0),
            other => panic!("prefix of {cut} bytes -> {other:?}"),
        }
    });
}

/// Header counts across the cap: <= MAX_HEADERS parses, more is 431
/// material. Duplicate Content-Length is rejected at any count.
#[test]
fn prop_reader_header_count_boundary() {
    prop::check("reader_header_count", prop::cases(64), |rng| {
        let n = rng.range(1, MAX_HEADERS * 2);
        let mut req = String::from("GET /healthz HTTP/1.1\r\n");
        for i in 0..n {
            req.push_str(&format!("X-Pad-{i}: {i}\r\n"));
        }
        req.push_str("\r\n");
        let mut carry = Vec::new();
        let got =
            read_request(&mut Cursor::new(req.as_bytes()), &mut carry, 4096);
        if n <= MAX_HEADERS {
            let (head, body) = got.expect("within cap");
            assert_eq!(head.method, "GET");
            assert!(body.is_empty());
        } else {
            assert_eq!(got.unwrap_err(), ReadError::HeadTooLarge, "{n} headers");
        }
    });
}

/// Declared Content-Length vs delivered bytes: short deliveries are
/// truncations, exact deliveries round-trip the body, and over-cap
/// declarations are rejected before any body byte is read.
#[test]
fn prop_reader_content_length_contract() {
    prop::check("reader_content_length", prop::cases(128), |rng| {
        let declared = rng.below(256);
        let delivered = rng.below(256);
        let max_body = 128;
        let mut req =
            format!("POST / HTTP/1.1\r\nContent-Length: {declared}\r\n\r\n")
                .into_bytes();
        req.extend(std::iter::repeat_n(b'z', delivered));
        let mut carry = Vec::new();
        let got = read_request(&mut Cursor::new(&req), &mut carry, max_body);
        if declared > max_body {
            assert_eq!(got.unwrap_err(), ReadError::BodyTooLarge);
        } else if delivered < declared {
            assert_eq!(got.unwrap_err(), ReadError::Truncated);
        } else {
            let (head, body) = got.expect("full delivery");
            assert_eq!(head.content_length, declared);
            assert_eq!(body.len(), declared);
            // Overrun past the declared body is pipelined, not lost.
            assert_eq!(carry.len(), delivered - declared);
        }
    });
}

// ---- lazy scanner: property/fuzz suites (no server) ----

/// Arbitrary bytes through the scanner: must return, never panic.
#[test]
fn prop_scanner_survives_arbitrary_bytes() {
    prop::check("scanner_arbitrary_bytes", prop::cases(256), |rng| {
        let len = rng.below(512);
        let bytes: Vec<u8> = if rng.chance(0.5) {
            // Raw bytes (mostly invalid UTF-8 / not JSON).
            (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect()
        } else {
            // Mutated valid body: flip one byte so the scanner walks
            // deep into real structure before hitting the fault.
            let img: Vec<f32> = (0..8).map(|i| i as f32).collect();
            let mut b = infer_body(&img, Some(7), None);
            let at = rng.below(b.len());
            b[at] = (rng.next_u64() & 0xff) as u8;
            b
        };
        let _ = scan_infer(&bytes);
    });
}

/// On well-formed bodies the lazy scanner agrees field-for-field with
/// the tree parser it bypasses, ignoring unrelated keys.
#[test]
fn prop_scanner_matches_tree_parser() {
    prop::check("scanner_matches_tree", prop::cases(128), |rng| {
        let n = rng.below(32);
        let img: Vec<f32> = (0..n).map(|_| prop::gen_f32(rng, 100.0)).collect();
        let deadline = rng.chance(0.5).then(|| rng.next_u64() >> 12);
        let hint = rng.chance(0.5).then(|| rng.range(1, 4096) as u64);
        let mut body = String::from("{");
        if rng.chance(0.5) {
            body.push_str("\"extra\":{\"nested\":[1,2,{\"deep\":null}]},");
        }
        body.push_str("\"image\":[");
        for (i, v) in img.iter().enumerate() {
            if i > 0 {
                body.push(',');
            }
            body.push_str(&format!("{v}"));
        }
        body.push(']');
        if let Some(d) = deadline {
            body.push_str(&format!(",\"deadline_us\":{d}"));
        }
        if let Some(h) = hint {
            body.push_str(&format!(",\"batch_hint\":{h}"));
        }
        body.push('}');

        let fields = scan_infer(body.as_bytes())
            .unwrap_or_else(|e| panic!("{e} in {body}"));
        assert_eq!(fields.image, img, "{body}");
        assert_eq!(fields.deadline_us, deadline, "{body}");
        assert_eq!(fields.batch_hint, hint, "{body}");

        // Cross-check against the full tree parser.
        let tree = Json::parse(&body).expect("generated body is valid JSON");
        let tree_img: Vec<f32> = match tree.get("image") {
            Json::Arr(a) => a
                .iter()
                .map(|v| v.as_f64().expect("image numbers") as f32)
                .collect(),
            other => panic!("tree image: {other:?}"),
        };
        assert_eq!(fields.image, tree_img);
    });
}

// ---- live server: end-to-end over loopback ----

#[test]
fn healthz_and_metrics_roundtrip() {
    let server = start_mock(
        mock(Duration::ZERO, false, 4),
        CoordinatorConfig { workers: 2, ..Default::default() },
        None,
        HttpConfig::default(),
    );
    let mut c = HttpClient::connect(server.addr()).unwrap();

    let h = c.get("/healthz").unwrap();
    assert_eq!(h.status, 200, "{}", h.body_text());
    let hj = Json::parse(&h.body_text()).unwrap();
    assert_eq!(hj.get("status").as_str(), Some("ok"));
    assert_eq!(hj.get("workers").as_usize(), Some(2));

    // One infer so the counters are non-trivial.
    let r = c.post("/v1/infer", &infer_body(&[0.0; INPUT_LEN], None, None)).unwrap();
    assert_eq!(r.status, 200, "{}", r.body_text());

    let m = c.get("/metrics").unwrap();
    assert_eq!(m.status, 200);
    let text = m.body_text();
    for series in [
        "rram_requests_total 1",
        "rram_latency_us_count 1",
        "rram_worker_requests_total{worker=\"0\"}",
        "rram_worker_requests_total{worker=\"1\"}",
        "rram_http_requests_total",
        "rram_http_handler_panics_total 0",
        // Bounded-telemetry series: the latency/batch-fill histograms
        // and the previously internal-only counters (quarantine,
        // store/DSE cache) must all reach the scrape endpoint.
        "rram_quarantine_events_total 0",
        "rram_latency_us_hist_bucket{le=\"+Inf\"} 1",
        "rram_latency_us_hist_count 1",
        "rram_batch_fill_bucket{le=\"1\"} 1",
        "rram_batch_fill_count 1",
        "rram_store_hits_total",
        "rram_store_misses_total",
        "rram_dse_cache_hits_total",
        "rram_dse_cache_misses_total",
    ] {
        assert!(text.contains(series), "missing {series:?} in:\n{text}");
    }

    let mj = c.get("/metrics?format=json").unwrap();
    assert_eq!(mj.status, 200);
    let j = Json::parse(&mj.body_text()).unwrap();
    assert_eq!(
        j.get("pool").get("requests").as_u64(),
        Some(1),
        "{}",
        mj.body_text()
    );
    assert!(j.get("workers").as_arr().is_some());
    assert_eq!(j.get("http").get("handler_panics").as_u64(), Some(0));
    assert_eq!(j.get("pool").get("quarantine_events").as_f64(), Some(0.0));
    let hist = j.get("pool").get("latency_hist");
    assert!(hist.get("sum").as_f64().is_some(), "{}", mj.body_text());
    assert!(hist.get("buckets").as_arr().is_some(), "{}", mj.body_text());
    assert!(j.get("cache").get("store_hits").as_f64().is_some());
    server.shutdown();
}

/// ISSUE-9 acceptance: one served `POST /v1/infer` produces a trace of
/// at least four causally-linked spans — `http.infer` → {`http.parse`,
/// `pool.admit`} → `pool.queue` → `pool.exec` — retrievable as Chrome
/// trace-event JSON from `GET /debug/trace`.
#[test]
fn debug_trace_serves_nested_span_chain() {
    let server = start_mock(
        mock(Duration::ZERO, false, 4),
        CoordinatorConfig {
            trace: Some(obs::Registry::new(
                clock::monotonic(),
                obs::DEFAULT_RING_CAPACITY,
            )),
            ..Default::default()
        },
        None,
        HttpConfig::default(),
    );
    let mut c = HttpClient::connect(server.addr()).unwrap();
    let r = c
        .post("/v1/infer", &infer_body(&[1.0; INPUT_LEN], None, None))
        .unwrap();
    assert_eq!(r.status, 200, "{}", r.body_text());

    let t = c.get("/debug/trace").unwrap();
    assert_eq!(t.status, 200);
    let j = Json::parse(&t.body_text()).unwrap();
    let events = j.get("traceEvents").as_arr().expect("traceEvents array");

    // Every exported event is a complete ("X") Chrome event on pid 1
    // with the timeline fields Perfetto needs.
    for e in events {
        assert_eq!(e.get("ph").as_str(), Some("X"), "{}", t.body_text());
        assert_eq!(e.get("pid").as_u64(), Some(1));
        assert!(e.get("ts").as_u64().is_some());
        assert!(e.get("tid").as_u64().is_some());
        assert!(e.get("name").as_str().is_some());
    }

    // Walk the one request's trace by its minted ID.
    let root = events
        .iter()
        .find(|e| e.get("name").as_str() == Some("http.infer"))
        .expect("http.infer span");
    let trace_id = root.get("args").get("trace_id").as_u64().expect("trace id");
    assert!(trace_id >= 1);
    let in_trace: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("args").get("trace_id").as_u64() == Some(trace_id))
        .collect();
    assert!(
        in_trace.len() >= 4,
        "want >= 4 spans in trace {trace_id}, got {}:\n{}",
        in_trace.len(),
        t.body_text()
    );
    let field = |name: &str, key: &str| -> u64 {
        in_trace
            .iter()
            .find(|e| e.get("name").as_str() == Some(name))
            .unwrap_or_else(|| panic!("span {name} missing:\n{}", t.body_text()))
            .get("args")
            .get(key)
            .as_u64()
            .unwrap_or_else(|| panic!("span {name} lacks {key}"))
    };
    let root_id = field("http.infer", "span_id");
    assert_eq!(field("http.parse", "parent_id"), root_id);
    assert_eq!(field("pool.admit", "parent_id"), root_id);
    assert_eq!(field("pool.queue", "parent_id"), field("pool.admit", "span_id"));
    assert_eq!(field("pool.exec", "parent_id"), field("pool.queue", "span_id"));

    // ?last=N truncates to the most recent spans; junk values keep the
    // default instead of erroring a diagnostics endpoint.
    let t1 = c.get("/debug/trace?last=1").unwrap();
    let j1 = Json::parse(&t1.body_text()).unwrap();
    assert_eq!(j1.get("traceEvents").as_arr().map(|a| a.len()), Some(1));
    let tbad = c.get("/debug/trace?last=banana").unwrap();
    assert_eq!(tbad.status, 200);
    // Non-GET on the path is 405, like the other fixed routes.
    assert_eq!(c.request("DELETE", "/debug/trace", b"").unwrap().status, 405);
    assert_eq!(server.http_stats().handler_panics, 0);
    server.shutdown();
}

#[test]
fn infer_returns_exact_mock_logits_over_keepalive() {
    let server = start_mock(
        mock(Duration::ZERO, false, 4),
        CoordinatorConfig::default(),
        None,
        HttpConfig::default(),
    );
    let mut c = HttpClient::connect(server.addr()).unwrap();
    // Three sequential requests over the same connection: keep-alive
    // framing must stay in sync.
    for round in 0..3u32 {
        let fill = 0.5 + round as f32;
        let image = [fill; INPUT_LEN];
        let sum = fill * INPUT_LEN as f32;
        let r = c
            .post("/v1/infer", &infer_body(&image, None, Some(4)))
            .unwrap();
        assert_eq!(r.status, 200, "round {round}: {}", r.body_text());
        let j = Json::parse(&r.body_text()).unwrap();
        let logits: Vec<f32> = match j.get("logits") {
            Json::Arr(a) => {
                a.iter().map(|v| v.as_f64().unwrap() as f32).collect()
            }
            other => panic!("logits: {other:?}"),
        };
        let want: Vec<f32> =
            (0..OUTPUT_LEN).map(|k| sum + k as f32).collect();
        assert_eq!(logits, want, "round {round}");
        assert!(j.get("queue_us").as_u64().is_some());
        assert_eq!(j.get("batch_fill").as_usize(), Some(1));
        assert_eq!(j.get("batch_hint").as_u64(), Some(4));
    }
    assert_eq!(server.http_stats().handler_panics, 0);
    server.shutdown();
}

#[test]
fn expired_deadline_maps_to_504() {
    // Batch of 4 with one request: the batcher waits max_wait (50 ms)
    // for fill, so a 1 ms deadline is guaranteed expired at dispatch.
    let server = start_mock(
        mock(Duration::ZERO, false, 4),
        CoordinatorConfig {
            max_wait: Duration::from_millis(50),
            ..Default::default()
        },
        None,
        HttpConfig::default(),
    );
    let mut c = HttpClient::connect(server.addr()).unwrap();
    let r = c
        .post("/v1/infer", &infer_body(&[1.0; INPUT_LEN], Some(1_000), None))
        .unwrap();
    assert_eq!(r.status, 504, "{}", r.body_text());
    assert!(r.body_text().contains(ERR_DEADLINE_PREFIX), "{}", r.body_text());
    server.shutdown();
}

#[test]
fn overload_admission_maps_to_429() {
    // Cost model prices every request at 1000 cycles against a 1-cycle
    // admission limit: the first request is admitted (nothing
    // outstanding) and parks in the slow backend; the second arrives
    // with 1000 cycles outstanding and is rejected up front.
    let server = start_mock(
        mock(Duration::from_millis(400), false, 1),
        CoordinatorConfig {
            max_wait: Duration::from_millis(1),
            max_outstanding_cost: 1.0,
            ..Default::default()
        },
        Some(CostModel {
            dense_cycles: 1000.0,
            dense_energy_pj: 1000.0,
            skip_slope: 0.0,
            energy_skip_slope: 0.0,
        }),
        HttpConfig::default(),
    );
    let addr = server.addr();
    let body = infer_body(&[1.0; INPUT_LEN], None, None);
    let first = {
        let body = body.clone();
        std::thread::spawn(move || {
            let mut c = HttpClient::connect(addr).unwrap();
            c.post("/v1/infer", &body).unwrap()
        })
    };
    // Let the first request reach the backend before the second lands.
    std::thread::sleep(Duration::from_millis(150));
    let mut c = HttpClient::connect(addr).unwrap();
    let second = c.post("/v1/infer", &body).unwrap();
    assert_eq!(second.status, 429, "{}", second.body_text());
    assert!(
        second.body_text().contains(ERR_OVERLOAD_PREFIX),
        "{}",
        second.body_text()
    );
    let first = first.join().unwrap();
    assert_eq!(first.status, 200, "{}", first.body_text());
    server.shutdown();
}

#[test]
fn backend_failure_maps_to_502() {
    let server = start_mock(
        mock(Duration::ZERO, true, 2),
        CoordinatorConfig::default(),
        None,
        HttpConfig::default(),
    );
    let mut c = HttpClient::connect(server.addr()).unwrap();
    let r = c.post("/v1/infer", &infer_body(&[1.0; INPUT_LEN], None, None)).unwrap();
    assert_eq!(r.status, 502, "{}", r.body_text());
    assert!(r.body_text().contains("mock backend"), "{}", r.body_text());
    server.shutdown();
}

#[test]
fn bad_request_family_over_one_keepalive_connection() {
    let server = start_mock(
        mock(Duration::ZERO, false, 4),
        CoordinatorConfig::default(),
        None,
        HttpConfig::default(),
    );
    let mut c = HttpClient::connect(server.addr()).unwrap();

    let depth_bomb =
        format!("{{\"junk\":{}", "[".repeat(100_000)).into_bytes();
    let cases: Vec<(Vec<u8>, &str)> = vec![
        (infer_body(&[1.0; 3], None, None), "elements"), // wrong image len
        (b"{\"deadline_us\":5}".to_vec(), "image"),      // missing image
        (b"{\"image\":[1,".to_vec(), ""),                // cut-off JSON
        (b"not json at all".to_vec(), ""),               // not JSON
        (depth_bomb, "nesting too deep"),                // flat-skip depth cap
        (b"{\"image\":[1e999]}".to_vec(), "finite"),     // inf element
        (infer_body(&[1.0; INPUT_LEN], None, Some(0)), "batch_hint"),
        (infer_body(&[1.0; INPUT_LEN], None, Some(5000)), "batch_hint"),
        (b"{\"image\":[1],\"image\":[2]}".to_vec(), "duplicate"),
    ];
    for (body, want) in &cases {
        let r = c.post("/v1/infer", body).unwrap();
        assert_eq!(r.status, 400, "{} -> {}", String::from_utf8_lossy(body), r.body_text());
        assert!(r.body_text().contains(want), "{} -> {}", want, r.body_text());
    }

    // Routing misses on the same connection.
    assert_eq!(c.get("/v1/nope").unwrap().status, 404);
    assert_eq!(c.request("DELETE", "/healthz", b"").unwrap().status, 405);
    assert_eq!(c.request("PUT", "/v1/infer", b"").unwrap().status, 405);

    // The connection survived every rejection; a valid request still
    // works and nothing panicked server-side.
    let ok = c.post("/v1/infer", &infer_body(&[0.0; INPUT_LEN], None, None)).unwrap();
    assert_eq!(ok.status, 200, "{}", ok.body_text());
    let stats = server.http_stats();
    assert_eq!(stats.handler_panics, 0);
    assert_eq!(stats.bad_requests, cases.len() as u64 + 3);
    server.shutdown();
}

#[test]
fn wire_level_rejections_413_431_400_408() {
    let server = start_mock(
        mock(Duration::ZERO, false, 4),
        CoordinatorConfig::default(),
        None,
        HttpConfig {
            max_body_bytes: 1024,
            read_timeout: Duration::from_millis(200),
            ..HttpConfig::default()
        },
    );
    let addr = server.addr();

    // Declared body over the cap -> 413 before any body byte is read
    // (head-only on the wire, so nothing is left unread at close).
    let mut c = HttpClient::connect(addr).unwrap();
    let r = c
        .raw(b"POST /v1/infer HTTP/1.1\r\nContent-Length: 1048576\r\n\r\n")
        .unwrap();
    assert_eq!(r.status, 413, "{}", r.body_text());

    // Oversized head -> 431. (Connection closed after each wire-level
    // rejection, so every case dials fresh.)
    let mut c = HttpClient::connect(addr).unwrap();
    let big = format!("GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "a".repeat(9000));
    let r = c.raw(big.as_bytes()).unwrap();
    assert_eq!(r.status, 431, "{}", r.body_text());

    // Duplicate Content-Length -> 400 at head parse, body never read.
    let mut c = HttpClient::connect(addr).unwrap();
    let r = c
        .raw(b"POST /v1/infer HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\n")
        .unwrap();
    assert_eq!(r.status, 400, "{}", r.body_text());

    // Invalid UTF-8 in the head -> 400.
    let mut c = HttpClient::connect(addr).unwrap();
    let r = c.raw(b"GET /\xff\xfe HTTP/1.1\r\n\r\n").unwrap();
    assert_eq!(r.status, 400, "{}", r.body_text());

    // Half a request then silence -> read timeout -> 408.
    let mut c = HttpClient::connect(addr).unwrap();
    let r = c.raw(b"POST /v1/infer HTTP/1.1\r\nConte").unwrap();
    assert_eq!(r.status, 408, "{}", r.body_text());

    assert_eq!(server.http_stats().handler_panics, 0);
    server.shutdown();
}

#[test]
fn connection_cap_answers_503_inline() {
    let server = start_mock(
        mock(Duration::ZERO, false, 4),
        CoordinatorConfig::default(),
        None,
        HttpConfig { max_connections: 1, ..HttpConfig::default() },
    );
    // First connection occupies the only slot...
    let mut holder = HttpClient::connect(server.addr()).unwrap();
    assert_eq!(holder.get("/healthz").unwrap().status, 200);
    // ...so the second is turned away at accept, without parsing.
    let mut turned_away = HttpClient::connect(server.addr()).unwrap();
    let r = turned_away.get("/healthz").unwrap();
    assert_eq!(r.status, 503, "{}", r.body_text());
    // The held connection still works.
    assert_eq!(holder.get("/healthz").unwrap().status, 200);
    server.shutdown();
}

/// Arbitrary bytes at the socket: the server must answer every opened
/// conversation with a well-formed HTTP/1.1 status line (the client
/// helper errors on anything else) and never panic a handler.
#[test]
fn fuzz_server_always_answers_well_formed() {
    let server = start_mock(
        mock(Duration::ZERO, false, 4),
        CoordinatorConfig::default(),
        None,
        HttpConfig {
            read_timeout: Duration::from_millis(100),
            ..HttpConfig::default()
        },
    );
    let addr = server.addr();
    prop::check("http_fuzz_wire", prop::cases(24), |rng: &mut Rng| {
        let len = rng.range(1, 512);
        let mut bytes: Vec<u8> =
            (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect();
        if rng.chance(0.5) {
            // Half the cases terminate the head so the parser (not the
            // read timeout) produces the answer.
            bytes.extend_from_slice(b"\r\n\r\n");
        }
        let mut c = HttpClient::connect(addr).unwrap();
        let resp = c.raw(&bytes).unwrap_or_else(|e| {
            panic!("no well-formed response to {} bytes: {e}", bytes.len())
        });
        assert!(
            (200..600).contains(&resp.status),
            "implausible status {} for {} fuzz bytes",
            resp.status,
            bytes.len()
        );
    });
    assert_eq!(server.http_stats().handler_panics, 0);
    server.shutdown();
}
