//! Integration tests for the binary pack store (`src/store/`):
//!
//!   * the on-disk format is pinned byte-for-byte against golden files
//!     (`tests/golden/store_v1.{pack,idx}`) — both the reader (the
//!     goldens open clean, no rebuild, no truncation) and the writer
//!     (replaying the same puts reproduces the goldens exactly);
//!   * randomized put/overwrite histories round-trip through reopen;
//!   * a truncated pack tail self-heals at every possible cut point;
//!   * an index that disagrees with the pack is rebuilt from the pack.
//!
//! Any intentional byte-level format change must bump
//! `store::FORMAT_VERSION` and regenerate the goldens.

use std::collections::BTreeMap;
use std::fs;
use std::path::PathBuf;

use rram_pattern_accel::store::{PackStore, FORMAT_VERSION};
use rram_pattern_accel::util::{fnv1a, prop};

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("rram-store-it-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn golden(name: &str) -> Vec<u8> {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/golden")
        .join(name);
    fs::read(&path).unwrap_or_else(|e| panic!("read golden {path:?}: {e}"))
}

/// The goldens hold two records, put in this order: (`"alpha"`,
/// payload `01 02 03`) then (`"beta"`, empty payload), keyed by
/// FNV-1a of the id.
fn golden_puts() -> [(u64, &'static str, &'static [u8]); 2] {
    [
        (fnv1a("alpha"), "alpha", &[1u8, 2, 3]),
        (fnv1a("beta"), "beta", &[]),
    ]
}

#[test]
fn golden_pack_reads_clean_and_writer_reproduces_it() {
    assert_eq!(FORMAT_VERSION, 1, "goldens are for format v1 — regenerate");

    // Reader: the golden files open without any recovery.
    let dir = temp_dir("golden-read");
    fs::create_dir_all(&dir).expect("mkdir");
    fs::write(dir.join("g.pack"), golden("store_v1.pack")).expect("seed pack");
    fs::write(dir.join("g.idx"), golden("store_v1.idx")).expect("seed idx");
    let store = PackStore::open(&dir.to_string_lossy(), "g").expect("open");
    let stats = store.open_stats();
    assert_eq!(stats.live_records, 2);
    assert!(!stats.index_rebuilt, "golden idx must validate against pack");
    assert_eq!(stats.truncated_bytes, 0, "golden pack has no corrupt tail");
    for (key, id, payload) in golden_puts() {
        let rec = store.get(key).expect("golden record hit");
        assert_eq!(rec.key, key);
        assert_eq!(rec.id, id);
        assert_eq!(rec.payload, payload);
    }
    // Opening and reading must not rewrite clean files.
    assert_eq!(fs::read(dir.join("g.pack")).unwrap(), golden("store_v1.pack"));
    assert_eq!(fs::read(dir.join("g.idx")).unwrap(), golden("store_v1.idx"));
    let _ = fs::remove_dir_all(&dir);

    // Writer: replaying the same puts into a fresh store reproduces
    // the goldens byte for byte.
    let dir = temp_dir("golden-write");
    let store =
        PackStore::open(&dir.to_string_lossy(), "g").expect("open fresh");
    for (key, id, payload) in golden_puts() {
        store.put(key, id, payload).expect("put");
    }
    assert_eq!(
        fs::read(dir.join("g.pack")).unwrap(),
        golden("store_v1.pack"),
        "pack writer bytes drifted from the pinned format"
    );
    assert_eq!(
        fs::read(dir.join("g.idx")).unwrap(),
        golden("store_v1.idx"),
        "index writer bytes drifted from the pinned format"
    );
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn random_put_histories_roundtrip_through_reopen() {
    prop::check("store round trip", prop::cases(24), |rng| {
        let dir = temp_dir(&format!("prop-{:016x}", rng.next_u64()));
        let store = PackStore::open(&dir.to_string_lossy(), "t").expect("open");
        // Keys from a small pool force overwrites; ids carry the key so
        // identity verification on get is meaningful.
        let mut model: BTreeMap<u64, Vec<u8>> = BTreeMap::new();
        let n_ops = 1 + rng.below(40) as usize;
        for _ in 0..n_ops {
            let key = rng.below(12);
            let id = format!("entry-{key}");
            let payload: Vec<u8> = (0..rng.below(64)).map(|_| rng.below(256) as u8).collect();
            store.put(key, &id, &payload).expect("put");
            model.insert(key, payload);
        }
        let verify = |store: &PackStore| {
            assert_eq!(store.len(), model.len());
            assert_eq!(store.keys(), model.keys().copied().collect::<Vec<_>>());
            for (key, payload) in &model {
                let rec = store.get(*key).expect("live key hits");
                assert_eq!(rec.id, format!("entry-{key}"));
                assert_eq!(&rec.payload, payload, "last write wins");
            }
            assert!(store.get(999).is_none(), "absent key misses");
        };
        verify(&store);
        drop(store);
        let store = PackStore::open(&dir.to_string_lossy(), "t").expect("reopen");
        assert_eq!(store.open_stats().truncated_bytes, 0);
        assert!(!store.open_stats().index_rebuilt, "clean close reopens clean");
        verify(&store);
        let _ = fs::remove_dir_all(&dir);
    });
}

#[test]
fn truncated_tail_heals_at_every_cut_point() {
    // Build a reference pack with three records of distinct sizes.
    let seed_dir = temp_dir("cut-seed");
    let puts: [(u64, &str, &[u8]); 3] = [
        (10, "first", b"0123456789"),
        (11, "second", b""),
        (12, "third", b"zz"),
    ];
    let store = PackStore::open(&seed_dir.to_string_lossy(), "t").expect("open");
    let mut ends = Vec::new(); // pack length after each put
    for (key, id, payload) in puts {
        store.put(key, id, payload).expect("put");
        ends.push(fs::metadata(seed_dir.join("t.pack")).unwrap().len());
    }
    drop(store);
    let full = fs::read(seed_dir.join("t.pack")).expect("read pack");
    let idx_bytes = fs::read(seed_dir.join("t.idx")).expect("read idx");
    assert_eq!(*ends.last().unwrap() as usize, full.len());

    // Cut the pack at every byte position past the header: the store
    // must come back with exactly the records whose bytes survived
    // whole, and stay writable.
    for cut in 8..full.len() {
        let dir = temp_dir("cut-case");
        fs::create_dir_all(&dir).expect("mkdir");
        fs::write(dir.join("t.pack"), &full[..cut]).expect("truncate");
        fs::write(dir.join("t.idx"), &idx_bytes).expect("stale idx");
        let store =
            PackStore::open(&dir.to_string_lossy(), "t").expect("reopen");
        let expect_live =
            ends.iter().filter(|&&e| e as usize <= cut).count();
        assert_eq!(
            store.len(),
            expect_live,
            "cut at byte {cut}: wrong survivor count"
        );
        for (i, (key, id, payload)) in puts.iter().enumerate() {
            match store.get(*key) {
                Some(rec) if i < expect_live => {
                    assert_eq!(rec.id, *id);
                    assert_eq!(rec.payload, *payload);
                }
                None if i >= expect_live => {}
                other => panic!(
                    "cut at byte {cut}, record {i}: unexpected {other:?}"
                ),
            }
        }
        store.put(99, "fresh", b"post-heal").expect("put after heal");
        drop(store);
        let store =
            PackStore::open(&dir.to_string_lossy(), "t").expect("second open");
        assert_eq!(store.open_stats().truncated_bytes, 0, "heal persisted");
        assert_eq!(store.len(), expect_live + 1);
        assert_eq!(store.get(99).expect("hit").payload, b"post-heal");
        let _ = fs::remove_dir_all(&dir);
    }
    let _ = fs::remove_dir_all(&seed_dir);
}

#[test]
fn index_disagreeing_with_pack_is_rebuilt() {
    let dir = temp_dir("swap");
    let store = PackStore::open(&dir.to_string_lossy(), "t").expect("open");
    // Two records with identical id/payload lengths, so swapped index
    // offsets still frame valid records — only the key check catches it.
    store.put(1, "aaaa", b"AAAA").expect("put");
    store.put(2, "bbbb", b"BBBB").expect("put");
    drop(store);
    let idx_path = dir.join("t.idx");
    let mut idx = fs::read(&idx_path).expect("read idx");
    // Swap the two 8-byte offsets (entries at 8.. and 32..; offset is
    // the second u64 of each 24-byte entry).
    let (a, b) = (16, 40);
    for i in 0..8 {
        idx.swap(a + i, b + i);
    }
    fs::write(&idx_path, &idx).expect("forge idx");
    let store = PackStore::open(&dir.to_string_lossy(), "t").expect("reopen");
    assert!(
        store.open_stats().index_rebuilt,
        "offset swap must be detected and rebuilt from the pack"
    );
    assert_eq!(store.get(1).expect("hit").id, "aaaa");
    assert_eq!(store.get(2).expect("hit").id, "bbbb");
    let _ = fs::remove_dir_all(&dir);
}
