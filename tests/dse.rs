//! DSE acceptance tests (ISSUE-4): frontier determinism across thread
//! counts and runs, cache-hit bit-exactness, skip handling, and the
//! sweep → serving auto-tune bridge.

use rram_pattern_accel::config::HardwareConfig;
use rram_pattern_accel::dse::{
    pareto, Objective, ResultCache, SweepRunner, SweepSpec, Workload,
};
use rram_pattern_accel::nn::ConvLayer;

/// A 8-point grid small enough for test runs, large enough to carry a
/// real area/energy/cycles trade-off (two schemes, two OU shapes, two
/// crossbar sizes).
fn tiny_spec(seed: u64) -> SweepSpec {
    SweepSpec {
        grid: "tiny-test".into(),
        schemes: vec!["naive".into(), "pattern".into()],
        ou: vec![(4, 4), (9, 8)],
        xbar: vec![(256, 256), (512, 512)],
        patterns: vec![4],
        pruning: vec![0.8],
        workload: Workload {
            name: "tiny".into(),
            layers: vec![
                ConvLayer { name: "c0".into(), cin: 4, cout: 16, fmap: 6 },
                ConvLayer { name: "c1".into(), cin: 16, cout: 16, fmap: 4 },
            ],
            n_images: 2,
            samples: 12,
            zero_ratio: 0.25,
            seed,
        },
    }
}

fn run(spec: SweepSpec, threads: usize, cache: Option<ResultCache>) -> String {
    SweepRunner { spec, threads, cache }
        .run()
        .frontier_json()
        .to_string_pretty()
}

fn temp_cache(tag: &str) -> ResultCache {
    let dir = std::env::temp_dir()
        .join(format!("rram-dse-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    ResultCache::new(dir)
}

/// Acceptance: the frontier JSON is byte-identical for any thread
/// count and across repeated runs.
#[test]
fn frontier_json_is_thread_invariant_and_repeatable() {
    let a = run(tiny_spec(42), 1, None);
    let b = run(tiny_spec(42), 4, None);
    let c = run(tiny_spec(42), 3, None);
    assert_eq!(a, b, "1 vs 4 threads must emit identical bytes");
    assert_eq!(a, c, "1 vs 3 threads must emit identical bytes");
    let again = run(tiny_spec(42), 4, None);
    assert_eq!(a, again, "repeat runs must emit identical bytes");
    // a different workload seed is a genuinely different sweep
    let other = run(tiny_spec(43), 4, None);
    assert_ne!(a, other, "seed must reach the workload");
}

/// Acceptance: a second invocation completes from cache hits and its
/// results — frontier bytes *and* every per-point metric — are
/// bit-exact with the fresh run.
#[test]
fn cached_sweep_is_bit_exact_with_fresh_sweep() {
    let cache = temp_cache("bitexact");
    let fresh = SweepRunner {
        spec: tiny_spec(42),
        threads: 2,
        cache: Some(cache.clone()),
    }
    .run();
    assert_eq!(fresh.cache_hits(), 0, "cold cache");
    assert!(fresh.cache_misses() > 0);
    assert_eq!(fresh.cache_misses(), fresh.evaluated());

    let cached = SweepRunner {
        spec: tiny_spec(42),
        threads: 4,
        cache: Some(cache.clone()),
    }
    .run();
    assert_eq!(cached.cache_misses(), 0, "second run must be all hits");
    assert_eq!(cached.cache_hits(), fresh.evaluated());

    assert_eq!(
        fresh.frontier_json().to_string_pretty(),
        cached.frontier_json().to_string_pretty(),
        "cache hits must reproduce the fresh frontier bitwise"
    );
    for (f, c) in fresh.results.iter().zip(cached.results.iter()) {
        assert_eq!(f.point, c.point);
        match (&f.outcome, &c.outcome) {
            (Ok(fm), Ok(cm)) => assert_eq!(fm, cm, "point {}", f.index),
            (Err(fe), Err(ce)) => assert_eq!(fe, ce),
            _ => panic!("outcome kind changed for point {}", f.index),
        }
    }
    let _ = std::fs::remove_dir_all(cache.dir());
}

/// Every valid swept point is either on the frontier or dominated by a
/// frontier member, and no frontier member is dominated by anything —
/// on a real sweep, not synthetic metrics.
#[test]
fn frontier_is_sound_and_complete_on_real_sweep() {
    let outcome = SweepRunner { spec: tiny_spec(42), threads: 2, cache: None }.run();
    assert!(!outcome.frontier.is_empty(), "non-empty deterministic frontier");
    let members: Vec<usize> = outcome.frontier.members.clone();
    assert!(
        members.windows(2).all(|w| w[0] < w[1]),
        "members must be in ascending grid order"
    );
    for (i, r) in outcome.results.iter().enumerate() {
        let Some(m) = r.metrics() else { continue };
        let dominated = outcome
            .results
            .iter()
            .filter_map(|o| o.metrics())
            .any(|o| pareto::dominates(o, m));
        if members.contains(&i) {
            assert!(!dominated, "frontier member {i} is dominated");
        } else {
            assert!(dominated, "non-member {i} must be dominated");
        }
    }
    // the tiny grid carries a real trade-off: pattern mapping reaches
    // the frontier (naive never dominates it on cycles/energy)
    assert!(
        members.iter().any(|&i| outcome.results[i].point.scheme == "pattern"),
        "pattern scheme must appear on the frontier"
    );
}

/// Invalid grid points (geometry the config system rejects) are
/// reported as skips with a reason, never silently dropped, and never
/// reach the frontier.
#[test]
fn invalid_points_are_skipped_with_reason() {
    let mut spec = tiny_spec(42);
    spec.ou.push((1024, 8)); // taller than both crossbars
    spec.schemes.push("not-a-scheme".into());
    let outcome = SweepRunner { spec, threads: 2, cache: None }.run();
    assert!(outcome.skipped() > 0);
    assert_eq!(
        outcome.results.len(),
        outcome.evaluated() + outcome.skipped(),
        "every expanded point is accounted for"
    );
    let mut saw_geometry = false;
    let mut saw_scheme = false;
    for r in &outcome.results {
        if let Err(e) = &r.outcome {
            assert!(!e.is_empty());
            saw_geometry |= r.point.ou_rows == 1024;
            saw_scheme |= e.contains("unknown mapping scheme");
        }
    }
    assert!(saw_geometry && saw_scheme);
    for &i in &outcome.frontier.members {
        assert!(outcome.results[i].outcome.is_ok());
    }
}

/// The auto-tune bridge: a weighted objective selects a frontier point
/// whose geometry grafts onto the serving base config and validates.
#[test]
fn selected_config_boots_the_serving_base() {
    let outcome = SweepRunner { spec: tiny_spec(42), threads: 2, cache: None }.run();
    for weights in ["1,1,1", "1,0,0", "0,1,0", "0,0,1", "2,0.5,1"] {
        let obj = Objective::parse(weights).unwrap();
        let t = outcome.select(&obj).expect("non-empty frontier selects");
        // the selection is a frontier member
        assert!(outcome
            .frontier
            .members
            .iter()
            .any(|&i| outcome.results[i].point == t.point));
        // its geometry must boot both the Table I base and the SmallCNN
        // functional base serve --auto-tune uses
        t.point.hardware().expect("Table I base");
        let hw = t
            .point
            .apply_dims(&HardwareConfig::smallcnn_functional())
            .expect("serving base");
        assert_eq!(hw.ou_rows, t.point.ou_rows);
        assert_eq!(hw.weight_bits, 8, "serving base precision preserved");
        use rram_pattern_accel::mapping::MappingScheme as _;
        let scheme = rram_pattern_accel::mapping::scheme_by_name(&t.point.scheme)
            .expect("tuned scheme registered");
        assert_eq!(scheme.name(), t.point.scheme);
    }
    // extreme weights pick the extreme frontier points
    let min_area = outcome
        .select(&Objective::parse("1,0,0").unwrap())
        .unwrap()
        .metrics
        .area_cells;
    let min_cycles = outcome
        .select(&Objective::parse("0,0,1").unwrap())
        .unwrap()
        .metrics
        .cycles;
    for &i in &outcome.frontier.members {
        let m = outcome.results[i].metrics().unwrap();
        assert!(m.area_cells >= min_area);
        assert!(m.cycles >= min_cycles);
    }
}
