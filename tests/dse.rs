//! DSE acceptance tests (ISSUE-4): frontier determinism across thread
//! counts and runs, cache-hit bit-exactness, skip handling, and the
//! sweep → serving auto-tune bridge.

use rram_pattern_accel::config::{HardwareConfig, SimConfig};
use rram_pattern_accel::coordinator::CostModel;
use rram_pattern_accel::dse::{
    pareto, Objective, ResultCache, SweepRunner, SweepSpec, Workload,
};
use rram_pattern_accel::mapping::scheme_by_name;
use rram_pattern_accel::nn::{ConvLayer, NetworkSpec, Tensor};
use rram_pattern_accel::sim::smallcnn::SmallCnn;
use rram_pattern_accel::util::rng::Rng;

/// A 8-point grid small enough for test runs, large enough to carry a
/// real area/energy/cycles trade-off (two schemes, two OU shapes, two
/// crossbar sizes).
fn tiny_spec(seed: u64) -> SweepSpec {
    SweepSpec {
        grid: "tiny-test".into(),
        schemes: vec!["naive".into(), "pattern".into()],
        ou: vec![(4, 4), (9, 8)],
        xbar: vec![(256, 256), (512, 512)],
        patterns: vec![4],
        pruning: vec![0.8],
        zero_detection: vec![true],
        block_switch: vec![2.0],
        cores: vec![1],
        interconnect: vec![(32.0, 4.0)],
        workload: Workload {
            name: "tiny".into(),
            layers: vec![
                ConvLayer { name: "c0".into(), cin: 4, cout: 16, fmap: 6 },
                ConvLayer { name: "c1".into(), cin: 16, cout: 16, fmap: 4 },
            ],
            n_images: 2,
            samples: 12,
            exact: false,
            zero_ratio: 0.25,
            seed,
        },
    }
}

fn run(spec: SweepSpec, threads: usize, cache: Option<ResultCache>) -> String {
    SweepRunner { spec, threads, cache }
        .run()
        .frontier_json()
        .to_string_pretty()
}

fn temp_cache(tag: &str) -> ResultCache {
    let dir = std::env::temp_dir()
        .join(format!("rram-dse-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    ResultCache::new(dir)
}

/// Acceptance: the frontier JSON is byte-identical for any thread
/// count and across repeated runs.
#[test]
fn frontier_json_is_thread_invariant_and_repeatable() {
    let a = run(tiny_spec(42), 1, None);
    let b = run(tiny_spec(42), 4, None);
    let c = run(tiny_spec(42), 3, None);
    assert_eq!(a, b, "1 vs 4 threads must emit identical bytes");
    assert_eq!(a, c, "1 vs 3 threads must emit identical bytes");
    let again = run(tiny_spec(42), 4, None);
    assert_eq!(a, again, "repeat runs must emit identical bytes");
    // a different workload seed is a genuinely different sweep
    let other = run(tiny_spec(43), 4, None);
    assert_ne!(a, other, "seed must reach the workload");
}

/// Acceptance: a second invocation completes from cache hits and its
/// results — frontier bytes *and* every per-point metric — are
/// bit-exact with the fresh run.
#[test]
fn cached_sweep_is_bit_exact_with_fresh_sweep() {
    let cache = temp_cache("bitexact");
    let fresh = SweepRunner {
        spec: tiny_spec(42),
        threads: 2,
        cache: Some(cache.clone()),
    }
    .run();
    assert_eq!(fresh.cache_hits(), 0, "cold cache");
    assert!(fresh.cache_misses() > 0);
    assert_eq!(fresh.cache_misses(), fresh.evaluated());

    let cached = SweepRunner {
        spec: tiny_spec(42),
        threads: 4,
        cache: Some(cache.clone()),
    }
    .run();
    assert_eq!(cached.cache_misses(), 0, "second run must be all hits");
    assert_eq!(cached.cache_hits(), fresh.evaluated());

    assert_eq!(
        fresh.frontier_json().to_string_pretty(),
        cached.frontier_json().to_string_pretty(),
        "cache hits must reproduce the fresh frontier bitwise"
    );
    for (f, c) in fresh.results.iter().zip(cached.results.iter()) {
        assert_eq!(f.point, c.point);
        match (&f.outcome, &c.outcome) {
            (Ok(fm), Ok(cm)) => assert_eq!(fm, cm, "point {}", f.index),
            (Err(fe), Err(ce)) => assert_eq!(fe, ce),
            _ => panic!("outcome kind changed for point {}", f.index),
        }
    }
    let _ = std::fs::remove_dir_all(cache.dir());
}

/// Every valid swept point is either on the frontier or dominated by a
/// frontier member, and no frontier member is dominated by anything —
/// on a real sweep, not synthetic metrics.
#[test]
fn frontier_is_sound_and_complete_on_real_sweep() {
    let outcome = SweepRunner { spec: tiny_spec(42), threads: 2, cache: None }.run();
    assert!(!outcome.frontier.is_empty(), "non-empty deterministic frontier");
    let members: Vec<usize> = outcome.frontier.members.clone();
    assert!(
        members.windows(2).all(|w| w[0] < w[1]),
        "members must be in ascending grid order"
    );
    for (i, r) in outcome.results.iter().enumerate() {
        let Some(m) = r.metrics() else { continue };
        let dominated = outcome
            .results
            .iter()
            .filter_map(|o| o.metrics())
            .any(|o| pareto::dominates(o, m));
        if members.contains(&i) {
            assert!(!dominated, "frontier member {i} is dominated");
        } else {
            assert!(dominated, "non-member {i} must be dominated");
        }
    }
    // the tiny grid carries a real trade-off: pattern mapping reaches
    // the frontier (naive never dominates it on cycles/energy)
    assert!(
        members.iter().any(|&i| outcome.results[i].point.scheme == "pattern"),
        "pattern scheme must appear on the frontier"
    );
}

/// Invalid grid points (geometry the config system rejects) are
/// reported as skips with a reason, never silently dropped, and never
/// reach the frontier.
#[test]
fn invalid_points_are_skipped_with_reason() {
    let mut spec = tiny_spec(42);
    spec.ou.push((1024, 8)); // taller than both crossbars
    spec.schemes.push("not-a-scheme".into());
    let outcome = SweepRunner { spec, threads: 2, cache: None }.run();
    assert!(outcome.skipped() > 0);
    assert_eq!(
        outcome.results.len(),
        outcome.evaluated() + outcome.skipped(),
        "every expanded point is accounted for"
    );
    let mut saw_geometry = false;
    let mut saw_scheme = false;
    for r in &outcome.results {
        if let Err(e) = &r.outcome {
            assert!(!e.is_empty());
            saw_geometry |= r.point.ou_rows == 1024;
            saw_scheme |= e.contains("unknown mapping scheme");
        }
    }
    assert!(saw_geometry && saw_scheme);
    for &i in &outcome.frontier.members {
        assert!(outcome.results[i].outcome.is_ok());
    }
}

/// Trace-mode cache separation (ISSUE-5 regression): a sampled-mode
/// sweep's cache entries must never be served for exact-mode points —
/// the second mode starts cold, and each mode re-hits only its own
/// entries afterwards, reproducing its frontier bit-exactly.
#[test]
fn sampled_and_exact_sweeps_use_disjoint_cache_entries() {
    let cache = temp_cache("mode-split");
    let sampled = SweepRunner {
        spec: tiny_spec(42),
        threads: 2,
        cache: Some(cache.clone()),
    }
    .run();
    assert_eq!(sampled.cache_hits(), 0, "cold cache");
    assert!(sampled.cache_misses() > 0);

    let mut espec = tiny_spec(42);
    espec.workload.exact = true;
    let exact = SweepRunner {
        spec: espec.clone(),
        threads: 2,
        cache: Some(cache.clone()),
    }
    .run();
    assert_eq!(
        exact.cache_hits(),
        0,
        "a sampled-mode cache entry was served for an exact-mode point"
    );
    assert_eq!(exact.cache_misses(), exact.evaluated());

    // each mode re-hits exactly its own entries, bit-exactly
    let sampled2 = SweepRunner {
        spec: tiny_spec(42),
        threads: 1,
        cache: Some(cache.clone()),
    }
    .run();
    assert_eq!(sampled2.cache_misses(), 0);
    assert_eq!(sampled2.cache_hits(), sampled.evaluated());
    assert_eq!(
        sampled.frontier_json().to_string_pretty(),
        sampled2.frontier_json().to_string_pretty()
    );
    let exact2 = SweepRunner {
        spec: espec,
        threads: 1,
        cache: Some(cache.clone()),
    }
    .run();
    assert_eq!(exact2.cache_misses(), 0);
    assert_eq!(exact2.cache_hits(), exact.evaluated());
    assert_eq!(
        exact.frontier_json().to_string_pretty(),
        exact2.frontier_json().to_string_pretty()
    );
    let _ = std::fs::remove_dir_all(cache.dir());
}

/// Backend byte-identity (ISSUE-8): a sweep over a legacy per-file JSON
/// cache and a sweep over the binary pack store emit identical frontier
/// bytes — and a binary-backend run over a legacy-seeded directory
/// completes on cache hits (via the migration fallback), after which
/// the JSON files are no longer needed.
#[test]
fn legacy_and_binary_backends_emit_identical_frontier_bytes() {
    let dir = std::env::temp_dir()
        .join(format!("rram-dse-test-backends-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let baseline = run(tiny_spec(42), 2, None);

    // Seed the directory through the legacy writer.
    let legacy = ResultCache::legacy_json(dir.clone());
    assert!(!legacy.is_binary());
    let seeded = SweepRunner {
        spec: tiny_spec(42),
        threads: 2,
        cache: Some(legacy.clone()),
    }
    .run();
    assert_eq!(seeded.cache_hits(), 0, "cold legacy cache");
    assert_eq!(
        seeded.frontier_json().to_string_pretty(),
        baseline,
        "legacy backend must emit the uncached frontier bytes"
    );

    // Binary backend over the same directory: every point served from
    // the legacy JSON entries (and migrated into the pack).
    let binary = ResultCache::new(dir.clone());
    assert!(binary.is_binary());
    let migrated = SweepRunner {
        spec: tiny_spec(42),
        threads: 2,
        cache: Some(binary.clone()),
    }
    .run();
    assert_eq!(
        migrated.cache_misses(),
        0,
        "legacy entries must be served through the fallback"
    );
    assert_eq!(migrated.frontier_json().to_string_pretty(), baseline);

    // The migration made the JSON files redundant: remove them and the
    // next binary run still completes on hits, same bytes.
    for f in std::fs::read_dir(&dir).unwrap() {
        let f = f.unwrap().path();
        if f.extension().is_some_and(|e| e == "json") {
            std::fs::remove_file(f).unwrap();
        }
    }
    let packed = SweepRunner {
        spec: tiny_spec(42),
        threads: 4,
        cache: Some(ResultCache::new(dir.clone())),
    }
    .run();
    assert_eq!(packed.cache_misses(), 0, "pack now holds every entry");
    assert_eq!(packed.frontier_json().to_string_pretty(), baseline);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Warm-start byte-identity (ISSUE-8): `run_with(true)` seeds the
/// frontier from the cache's snapshot; its artifact bytes must equal
/// the cold extraction's — on the identical grid, on a grown grid
/// (incremental update path), and on a shrunk grid (soundness
/// fallback to full extraction).
#[test]
fn warm_started_frontier_is_bit_identical_to_cold() {
    let cache = temp_cache("warm-start");

    // Cold run populates the cache and stores the frontier snapshot.
    let cold = SweepRunner {
        spec: tiny_spec(42),
        threads: 2,
        cache: Some(cache.clone()),
    }
    .run();
    let cold_bytes = cold.frontier_json().to_string_pretty();

    // Identical grid, warm-started: all hits, identical bytes.
    let warm = SweepRunner {
        spec: tiny_spec(42),
        threads: 4,
        cache: Some(cache.clone()),
    }
    .run_with(true);
    assert_eq!(warm.cache_misses(), 0);
    assert_eq!(warm.frontier_json().to_string_pretty(), cold_bytes);

    // Grown grid (an extra OU shape): the snapshot's covered set is a
    // subset of the new grid, so the incremental update path runs; the
    // artifact must match a from-scratch sweep of the grown grid.
    let mut grown = tiny_spec(42);
    grown.ou.push((16, 8));
    let grown_fresh = run(grown.clone(), 2, None);
    let grown_warm = SweepRunner {
        spec: grown.clone(),
        threads: 2,
        cache: Some(cache.clone()),
    }
    .run_with(true);
    assert!(grown_warm.cache_hits() > 0, "old points hit");
    assert!(grown_warm.cache_misses() > 0, "new points evaluate");
    assert_eq!(grown_warm.frontier_json().to_string_pretty(), grown_fresh);

    // Shrunk grid: covered keys left the grid, the warm shortcut is
    // unsound and must silently fall back to full extraction.
    let mut shrunk = tiny_spec(42);
    shrunk.ou.truncate(1);
    let shrunk_fresh = run(shrunk.clone(), 2, None);
    let shrunk_warm = SweepRunner {
        spec: shrunk,
        threads: 2,
        cache: Some(cache.clone()),
    }
    .run_with(true);
    assert_eq!(shrunk_warm.cache_misses(), 0, "subset grid is all hits");
    assert_eq!(shrunk_warm.frontier_json().to_string_pretty(), shrunk_fresh);
    let _ = std::fs::remove_dir_all(cache.dir());
}

/// Serving-bridge acceptance (ISSUE-5): `serve --auto-tune --tune-exact`
/// boils down to (1) selecting a frontier point from an exact-mode
/// sweep of the 48-point `small` grid and (2) building the pool's
/// `HardwareConfig` and calibrated `CostModel` from it. Both halves are
/// pinned against hand computations here.
#[test]
fn exact_auto_tune_matches_hand_computed_selection() {
    let mut spec = SweepSpec::small(42);
    spec.workload.exact = true;
    assert_eq!(spec.expand().len(), 48, "the 48-point small grid");
    let outcome = SweepRunner { spec, threads: 2, cache: None }.run();
    let obj = Objective { w_area: 1.0, w_energy: 0.5, w_cycles: 2.0 };
    let t = outcome.select(&obj).expect("exact-mode frontier selects");

    // Hand-computed selection: per-metric frontier minima, then the
    // weighted normalized score, first minimum winning — exactly the
    // documented `select_config` contract, recomputed from scratch.
    let members = &outcome.frontier.members;
    assert!(!members.is_empty());
    let (mut min_a, mut min_e, mut min_c) =
        (f64::INFINITY, f64::INFINITY, f64::INFINITY);
    for &i in members {
        let m = outcome.results[i].metrics().unwrap();
        min_a = min_a.min(m.area_cells);
        min_e = min_e.min(m.energy_pj);
        min_c = min_c.min(m.cycles);
    }
    let mut best = None;
    for &i in members {
        let m = outcome.results[i].metrics().unwrap();
        let s = obj.w_area * m.area_cells / min_a
            + obj.w_energy * m.energy_pj / min_e
            + obj.w_cycles * m.cycles / min_c;
        match best {
            Some((_, bs)) if bs <= s => {}
            _ => best = Some((i, s)),
        }
    }
    let (want_i, _) = best.unwrap();
    assert_eq!(t.point, outcome.results[want_i].point, "selected point");
    assert_eq!(
        &t.metrics,
        outcome.results[want_i].metrics().unwrap(),
        "selected metrics"
    );

    // The tuned HardwareConfig serve builds: the point's geometry on
    // the SmallCNN functional base, precision untouched.
    let serve_hw = t
        .point
        .apply_dims(&HardwareConfig::smallcnn_functional())
        .expect("tuned geometry boots the serving base");
    assert_eq!(serve_hw.ou_rows, t.point.ou_rows);
    assert_eq!(serve_hw.ou_cols, t.point.ou_cols);
    assert_eq!(serve_hw.xbar_rows, t.point.xbar_rows);
    assert_eq!(serve_hw.weight_bits, 8, "serving precision preserved");

    // The tuned CostModel serve builds: the winner's scheme maps a
    // SmallCNN bundle, exact traces over calibration images fit the
    // per-layer regressions, and `CostModel::from_calibration` must
    // reproduce the hand-derived dense cost, skip slope and estimates.
    let scheme = scheme_by_name(&t.point.scheme).expect("tuned scheme registered");
    let net = NetworkSpec {
        name: "bridge".into(),
        layers: vec![
            ConvLayer { name: "c0".into(), cin: 2, cout: 6, fmap: 6 },
            ConvLayer { name: "c1".into(), cin: 6, cout: 8, fmap: 3 },
        ],
    };
    let model = SmallCnn::synthetic(net, 11);
    let mapped = model.map(scheme.as_ref(), &serve_hw);
    mapped.validate().expect("tuned geometry maps the serving bundle");
    let n = 5;
    let img_len = 2 * 6 * 6;
    let mut rng = Rng::seed_from(17);
    let mut calib = Tensor::zeros(&[n, 2, 6, 6]);
    for i in 0..n {
        let pz = i as f64 / n as f64;
        for v in calib.data[i * img_len..(i + 1) * img_len].iter_mut() {
            *v = if rng.chance(pz) { 0.0 } else { rng.f32() + 0.01 };
        }
    }
    let cal = model.calibrate(&mapped, &calib, &serve_hw, &SimConfig::default(), 2);
    let cm = CostModel::from_calibration(&cal);

    // hand-derived dense cost and slope from the per-layer fits
    let want_dense = cal.total_cycles_at(0.0).max(0.0);
    assert!(
        (cm.dense_cycles - want_dense).abs() <= 1e-9 * want_dense.max(1.0),
        "dense cycles {} vs fit {}",
        cm.dense_cycles,
        want_dense
    );
    let cyc_slope: f64 = cal.layers.iter().map(|l| l.cycles_slope).sum();
    let want_slope = if cm.dense_cycles > 1e-12 {
        (-cyc_slope / cm.dense_cycles).max(0.0)
    } else {
        0.0
    };
    assert!(
        (cm.skip_slope - want_slope).abs() <= 1e-9 * want_slope.max(1.0),
        "skip slope {} vs hand {}",
        cm.skip_slope,
        want_slope
    );
    // estimates follow the fitted line: dense image pays the full dense
    // cost, a half-zero image pays the discounted cost
    let dense_img = vec![1.0f32; 8];
    assert_eq!(cm.estimate(&dense_img).est_cycles, cm.dense_cycles);
    let half: Vec<f32> =
        (0..8).map(|i| if i % 2 == 0 { 0.0 } else { 1.0 }).collect();
    let est = cm.estimate(&half);
    assert!((est.input_zero_fraction - 0.5).abs() < 1e-12);
    let keep = (1.0 - cm.skip_slope * 0.5).clamp(0.0, 1.0);
    assert_eq!(est.est_cycles, cm.dense_cycles * keep);
}

/// ISSUE-10 acceptance: widening the small grid with the multi-core
/// scale-out axes (`--cores 1,2,4`, fast interconnect) puts at least
/// one multi-core point on the Pareto frontier — pipelining cuts
/// cycles at unchanged area/energy — while the frontier stays
/// byte-identical across thread counts and single-core points keep
/// their historical metrics bit-for-bit.
#[test]
fn multicore_axes_reach_the_frontier_and_stay_deterministic() {
    let spec = tiny_spec(42).with_core_axes(&[1, 2, 4], &[(1e6, 0.0)]);
    let a = SweepRunner { spec: spec.clone(), threads: 2, cache: None }.run();
    let b = SweepRunner { spec, threads: 4, cache: None }.run();
    assert_eq!(
        a.frontier_json().to_string_pretty(),
        b.frontier_json().to_string_pretty(),
        "multi-core frontier must be thread-invariant"
    );
    assert!(
        a.frontier.members.iter().any(|&i| a.results[i].point.cores > 1),
        "no multi-core point reached the frontier"
    );
    // Multi-core evaluation changes the cycle metric only: every
    // multi-core point's single-core sibling (same point, cores = 1)
    // reports bit-identical area/energy/ou_ops, and the near-free
    // interconnect means pipelining never slows the batch.
    for r in &a.results {
        if r.point.cores == 1 {
            continue;
        }
        let Some(m) = r.metrics() else { continue };
        let sibling = a
            .results
            .iter()
            .find(|o| {
                o.point.cores == 1
                    && o.point.scheme == r.point.scheme
                    && o.point.ou_rows == r.point.ou_rows
                    && o.point.ou_cols == r.point.ou_cols
                    && o.point.xbar_rows == r.point.xbar_rows
                    && o.point.xbar_cols == r.point.xbar_cols
                    && o.point.n_patterns == r.point.n_patterns
                    && o.point.pruning == r.point.pruning
                    && o.point.zero_detection == r.point.zero_detection
                    && o.point.block_switch_cycles == r.point.block_switch_cycles
            })
            .expect("single-core sibling in grid");
        let sm = sibling.metrics().unwrap();
        assert_eq!(m.area_cells, sm.area_cells, "area is placement-invariant");
        assert_eq!(m.energy_pj, sm.energy_pj, "energy is placement-invariant");
        assert_eq!(m.ou_ops, sm.ou_ops, "work is placement-invariant");
        assert!(
            m.cycles <= sm.cycles + 1.0,
            "pipelining slowed {}: {} vs {}",
            r.point.label(),
            m.cycles,
            sm.cycles
        );
    }
}

/// The auto-tune bridge: a weighted objective selects a frontier point
/// whose geometry grafts onto the serving base config and validates.
#[test]
fn selected_config_boots_the_serving_base() {
    let outcome = SweepRunner { spec: tiny_spec(42), threads: 2, cache: None }.run();
    for weights in ["1,1,1", "1,0,0", "0,1,0", "0,0,1", "2,0.5,1"] {
        let obj = Objective::parse(weights).unwrap();
        let t = outcome.select(&obj).expect("non-empty frontier selects");
        // the selection is a frontier member
        assert!(outcome
            .frontier
            .members
            .iter()
            .any(|&i| outcome.results[i].point == t.point));
        // its geometry must boot both the Table I base and the SmallCNN
        // functional base serve --auto-tune uses
        t.point.hardware().expect("Table I base");
        let hw = t
            .point
            .apply_dims(&HardwareConfig::smallcnn_functional())
            .expect("serving base");
        assert_eq!(hw.ou_rows, t.point.ou_rows);
        assert_eq!(hw.weight_bits, 8, "serving base precision preserved");
        use rram_pattern_accel::mapping::MappingScheme as _;
        let scheme = rram_pattern_accel::mapping::scheme_by_name(&t.point.scheme)
            .expect("tuned scheme registered");
        assert_eq!(scheme.name(), t.point.scheme);
    }
    // extreme weights pick the extreme frontier points
    let min_area = outcome
        .select(&Objective::parse("1,0,0").unwrap())
        .unwrap()
        .metrics
        .area_cells;
    let min_cycles = outcome
        .select(&Objective::parse("0,0,1").unwrap())
        .unwrap()
        .metrics
        .cycles;
    for &i in &outcome.frontier.members {
        let m = outcome.results[i].metrics().unwrap();
        assert!(m.area_cells >= min_area);
        assert!(m.cycles >= min_cycles);
    }
}
