//! Cross-module property tests on the mapping/simulation invariants the
//! paper's correctness rests on (DESIGN.md §6).

use rram_pattern_accel::config::{HardwareConfig, SimConfig};
use rram_pattern_accel::mapping::{
    index, naive::NaiveMapping, ou_sparse::OuSparseMapping,
    pattern::PatternMapping, reconstruct_dense, MappingScheme,
};
use rram_pattern_accel::nn::{conv2d_ref, ConvLayer, NetworkSpec, Tensor};
use rram_pattern_accel::pruning::synthetic::generate_layer;
use rram_pattern_accel::pruning::{NetworkWeights, Pattern};
use rram_pattern_accel::sim::functional::{conv_forward, LayerScales};
use rram_pattern_accel::sim::workload::{LayerTrace, TraceAggregate};
use rram_pattern_accel::sim::{
    image_seed, simulate_layer, simulate_layer_reference, simulate_network,
    simulate_network_batch, ShardPlan,
};
use rram_pattern_accel::util::prop;
use rram_pattern_accel::util::rng::Rng;
use rram_pattern_accel::xbar::energy::EnergyLedger;
use rram_pattern_accel::xbar::CellGeometry;

fn geom() -> CellGeometry {
    CellGeometry::from_hw(&HardwareConfig::default())
}

fn rand_layer(rng: &mut Rng) -> (ConvLayer, Tensor) {
    let cout = rng.range(1, 40);
    let cin = rng.range(1, 6);
    let n_pat = rng.range(1, 9).min(cout * cin);
    let sparsity = 0.4 + rng.f64() * 0.55;
    let zr = rng.f64() * 0.5;
    let w = generate_layer(cout, cin, n_pat, sparsity, zr, rng);
    (ConvLayer { name: "p".into(), cout, cin, fmap: 5 }, w)
}

/// Mapping is information-preserving for every scheme (zeros of the
/// naive scheme included).
#[test]
fn prop_all_schemes_reconstruct() {
    prop::check("all schemes reconstruct", prop::cases(40), |rng| {
        let (l, w) = rand_layer(rng);
        for s in [
            &PatternMapping as &dyn MappingScheme,
            &NaiveMapping,
            &OuSparseMapping,
        ] {
            let ml = s.map_layer(0, &l, &w, &geom());
            ml.validate().unwrap();
            assert_eq!(reconstruct_dense(&ml).data, w.data, "{}", s.name());
        }
    });
}

/// The paper's §IV-C decode: placements are recoverable from the index
/// stream for arbitrary layers.
#[test]
fn prop_index_stream_recovers_placement() {
    prop::check("index stream recovers placement", prop::cases(40), |rng| {
        let (l, w) = rand_layer(rng);
        let ml = PatternMapping.map_layer(0, &l, &w, &geom());
        let decoded = index::decode(&index::encode(&ml)).unwrap();
        assert_eq!(
            index::reconstruct_placements(&decoded, &geom()),
            ml.placements
        );
    });
}

/// Functional spine: mapped float compute == dense conv for random
/// sparse inputs (the Output Indexing Unit undoes the reorder exactly).
#[test]
fn prop_mapped_compute_equals_conv() {
    prop::check("mapped compute equals conv", prop::cases(24), |rng| {
        let hw = HardwareConfig::smallcnn_functional();
        let (l, w) = rand_layer(rng);
        let mut x = Tensor::zeros(&[1, l.cin, 5, 5]);
        for v in x.data.iter_mut() {
            *v = if rng.chance(0.5) { 0.0 } else { rng.f32() * 2.0 - 1.0 };
        }
        let ml = PatternMapping.map_layer(0, &l, &w, &geom());
        let got = conv_forward(&ml, &x, 0, LayerScales { sx: 1.0, sw: 1.0 }, &hw, false);
        let want = conv2d_ref(&x, &w);
        let scale = want.max_abs().max(1.0);
        for (g, v) in got.data.iter().zip(want.data.iter()) {
            assert!((g - v).abs() < 1e-4 * scale, "{g} vs {v}");
        }
    });
}

/// Energy/cycle accounting conservation: skipped + executed OU ops is
/// exactly the static schedule size, and energy is monotone in work.
#[test]
fn prop_sim_conservation() {
    prop::check("sim conservation", prop::cases(24), |rng| {
        let hw = HardwareConfig::default();
        let (l, w) = rand_layer(rng);
        let ml = PatternMapping.map_layer(0, &l, &w, &geom());
        let sim_cfg = SimConfig {
            zero_blob_ratio: rng.f64() * 0.8,
            dead_channel_ratio: rng.f64() * 0.3,
            ..Default::default()
        };
        let n_pos = rng.range(1, 20);
        let trace = LayerTrace::synthetic(l.cin, n_pos, &sim_cfg, rng);
        let on = simulate_layer(&ml, l.positions(), &trace, &hw, true, 0.0);
        let off = simulate_layer(&ml, l.positions(), &trace, &hw, false, 0.0);
        let static_total = (ml.ou_ops_per_position() * l.positions()) as f64;
        assert!((off.ou_ops - static_total).abs() < 1e-6);
        assert!((on.ou_ops + on.skipped_ou_ops - static_total).abs() < 1e-6);
        assert!(on.energy.total_pj() <= off.energy.total_pj() + 1e-9);
        assert!(on.cycles <= off.cycles + 1e-9);
    });
}

/// Tentpole invariant (ISSUE-1): the trace-aggregated engine is
/// bit-identical to the per-position reference on ou_ops / skipped /
/// cycles and within 1e-9 relative on every energy component, across
/// random layers, schemes, traces and sim configs.
#[test]
fn prop_aggregated_engine_matches_reference() {
    prop::check("aggregated engine matches reference", prop::cases(48), |rng| {
        let hw = HardwareConfig::default();
        let (l, w) = rand_layer(rng);
        let ml = if rng.chance(0.5) {
            PatternMapping.map_layer(0, &l, &w, &geom())
        } else {
            NaiveMapping.map_layer(0, &l, &w, &geom())
        };
        let sim_cfg = SimConfig {
            zero_blob_ratio: rng.f64() * 0.9,
            dead_channel_ratio: rng.f64() * 0.5,
            ..Default::default()
        };
        let n_pos = rng.range(1, 48);
        let trace = LayerTrace::synthetic(l.cin, n_pos, &sim_cfg, rng);
        let skip = rng.chance(0.75);
        let switch_cycles = rng.f64() * 8.0;
        let a = simulate_layer(&ml, l.positions(), &trace, &hw, skip, switch_cycles);
        let r = simulate_layer_reference(
            &ml,
            l.positions(),
            &trace,
            &hw,
            skip,
            switch_cycles,
        );
        assert_eq!(a.ou_ops, r.ou_ops, "ou_ops");
        assert_eq!(a.skipped_ou_ops, r.skipped_ou_ops, "skipped");
        assert_eq!(a.cycles, r.cycles, "cycles");
        for (ae, re) in [
            (a.energy.adc_pj, r.energy.adc_pj),
            (a.energy.dac_pj, r.energy.dac_pj),
            (a.energy.rram_pj, r.energy.rram_pj),
            (a.energy.total_pj(), r.energy.total_pj()),
        ] {
            let rel = (ae - re).abs() / re.abs().max(1e-12);
            assert!(rel < 1e-9, "energy component {ae} vs {re}");
        }
    });
}

/// ISSUE-2 merge invariant: merging per-image `TraceAggregate`s (built
/// from one shared key set) is bit-identical to aggregating the
/// concatenation of the underlying traces — every skippable count, the
/// fully-skippable count and the position total.
#[test]
fn prop_merge_matches_concatenated_aggregate() {
    prop::check("merge matches concat", prop::cases(48), |rng| {
        let cin = rng.range(1, 6);
        let n_keys = rng.range(1, 10);
        // keys may repeat, hit any channel, and include the zero pattern
        let keys: Vec<(usize, Pattern)> = (0..n_keys)
            .map(|_| (rng.below(cin), Pattern(rng.below(512) as u16)))
            .collect();
        let cfg = SimConfig {
            zero_blob_ratio: rng.f64() * 0.8,
            dead_channel_ratio: rng.f64() * 0.4,
            ..Default::default()
        };
        let n_traces = rng.range(1, 5);
        let mut merged: Option<TraceAggregate> = None;
        let mut all_masks: Vec<u16> = Vec::new();
        let mut total_pos = 0usize;
        for _ in 0..n_traces {
            let n_pos = rng.range(1, 20);
            let t = LayerTrace::synthetic(cin, n_pos, &cfg, rng);
            all_masks.extend_from_slice(&t.masks);
            total_pos += n_pos;
            let agg = t.aggregate(&keys);
            match &mut merged {
                Some(m) => m.merge(&agg),
                None => merged = Some(agg),
            }
        }
        let merged = merged.unwrap();
        let concat = LayerTrace { n_positions: total_pos, cin, masks: all_masks }
            .aggregate(&keys);
        assert_eq!(merged.n_positions, concat.n_positions);
        assert_eq!(
            merged.fully_skippable_positions(),
            concat.fully_skippable_positions()
        );
        for &(ch, p) in &keys {
            assert_eq!(
                merged.skippable_positions(ch, p),
                concat.skippable_positions(ch, p),
                "key ({ch}, {p:?})"
            );
        }
    });
}

/// ISSUE-2 tentpole invariant: `simulate_network_batch` over N images
/// is bit-exact with N independent `simulate_network` runs seeded with
/// `image_seed` — field by field per image per layer, and on the batch
/// totals folded in image order.
#[test]
fn prop_batch_sim_equals_sum_of_singles() {
    prop::check("batch equals singles", prop::cases(16), |rng| {
        let hw = HardwareConfig::default();
        let n_layers = rng.range(1, 3);
        let mut spec_layers = Vec::new();
        let mut weights = Vec::new();
        let mut cin = rng.range(1, 5);
        for li in 0..n_layers {
            let cout = rng.range(1, 24);
            let n_pat = rng.range(1, 7).min(cout * cin);
            let w = generate_layer(
                cout,
                cin,
                n_pat,
                0.5 + rng.f64() * 0.45,
                rng.f64() * 0.4,
                rng,
            );
            spec_layers.push(ConvLayer {
                name: format!("l{li}"),
                cout,
                cin,
                fmap: 5,
            });
            weights.push(w);
            cin = cout;
        }
        let spec = NetworkSpec { name: "prop".into(), layers: spec_layers };
        let nw = NetworkWeights::new(spec.clone(), weights);
        let mapped = if rng.chance(0.5) {
            PatternMapping.map_network(&nw, &geom(), 1)
        } else {
            NaiveMapping.map_network(&nw, &geom(), 1)
        };
        let sim_cfg = SimConfig {
            zero_blob_ratio: rng.f64() * 0.8,
            dead_channel_ratio: rng.f64() * 0.4,
            sample_positions: Some(rng.range(1, 24)),
            seed: rng.next_u64(),
            ..Default::default()
        };
        let n_images = rng.range(1, 5);
        let batch =
            simulate_network_batch(&mapped, &spec, &hw, &sim_cfg, n_images, 2);
        assert_eq!(batch.n_images(), n_images);

        let mut sum_cycles = 0.0;
        let mut sum_ou_ops = 0.0;
        let mut sum_energy = EnergyLedger::default();
        for i in 0..n_images {
            let cfg_i = SimConfig {
                seed: image_seed(sim_cfg.seed, i as u64),
                ..sim_cfg.clone()
            };
            let single = simulate_network(&mapped, &spec, &hw, &cfg_i, 1);
            let bi = &batch.per_image[i];
            assert_eq!(bi.layers.len(), single.layers.len());
            for (a, b) in bi.layers.iter().zip(single.layers.iter()) {
                assert_eq!(a.layer_idx, b.layer_idx);
                assert_eq!(a.ou_ops, b.ou_ops, "image {i}");
                assert_eq!(a.skipped_ou_ops, b.skipped_ou_ops, "image {i}");
                assert_eq!(a.cycles, b.cycles, "image {i}");
                assert_eq!(a.energy, b.energy, "image {i}");
                assert_eq!(a.n_crossbars, b.n_crossbars);
            }
            sum_cycles += single.total_cycles();
            sum_ou_ops += single.total_ou_ops();
            sum_energy.add(&single.total_energy());
        }
        assert_eq!(batch.total_cycles(), sum_cycles, "total cycles");
        assert_eq!(batch.total_ou_ops(), sum_ou_ops, "total ou ops");
        assert_eq!(batch.total_energy(), sum_energy, "total energy");
    });
}

/// ISSUE-5 convergence: sampled-trace simulation converges toward the
/// exact (every-position) closed-form result as the sample count grows.
/// The mean |relative error| over a bundle of independent trace seeds
/// shrinks monotonically (within slack for the folded-normal noise of
/// a finite bundle) from 16 to 64 to 256 sampled positions, for both
/// per-layer cycles and energy, on seeded synthetic layers.
///
/// Statistical design (margins Monte-Carlo-verified to hold with large
/// headroom at the nightly PROP_CASES=1024 count): the exact trace
/// covers 2500 positions so its own deviation from the distribution
/// mean — a floor no sample count can get under — is far below the
/// decrease threshold; 32 error samples per count tame the
/// folded-normal noise of the bundle averages; and the layer generator
/// is kept in a many-block, moderate-skip-probability regime (blob
/// ratio 0.3–0.6, ≥ 4 mapped blocks) where per-position costs
/// concentrate.
#[test]
fn prop_sampled_error_converges_monotonically_to_exact() {
    prop::check("sampled converges to exact", prop::cases(6), |rng| {
        let hw = HardwareConfig::default();
        let cout = rng.range(12, 33);
        let cin = rng.range(2, 6);
        let n_pat = rng.range(3, 8).min(cout * cin);
        let w = generate_layer(
            cout,
            cin,
            n_pat,
            0.6 + rng.f64() * 0.3,
            rng.f64() * 0.4,
            rng,
        );
        // 50×50 feature map: 256 samples still genuinely subsample the
        // 2500-position exact trace, and the exact reference's own
        // sampling floor is ~1/sqrt(2500) — negligible vs the bands.
        let l = ConvLayer { name: "cv".into(), cout, cin, fmap: 50 };
        let ml = PatternMapping.map_layer(0, &l, &w, &geom());
        if ml.blocks.len() < 4 {
            return; // degenerate few-block draw: skip, not meaningful
        }
        // Per-position randomness only: channel death is a per-trace
        // draw (shared by every position), which would put an
        // irreducible, k-independent floor under the sampling error.
        let cfg = SimConfig {
            dead_channel_ratio: 0.0,
            zero_blob_ratio: 0.3 + rng.f64() * 0.3,
            ..Default::default()
        };
        let base = rng.next_u64();
        let mut erng = Rng::seed_from(base);
        let exact_trace =
            LayerTrace::synthetic(cin, l.positions(), &cfg, &mut erng);
        let exact = simulate_layer(
            &ml,
            l.positions(),
            &exact_trace,
            &hw,
            true,
            cfg.block_switch_cycles,
        );
        assert!(exact.cycles > 0.0 && exact.energy.total_pj() > 0.0);

        const SEEDS: u64 = 32;
        let counts = [16usize, 64, 256];
        let mut avg_cycles = [0.0f64; 3];
        let mut avg_energy = [0.0f64; 3];
        for (ki, &k) in counts.iter().enumerate() {
            for s in 0..SEEDS {
                let mut trng = Rng::seed_from(
                    base ^ (ki as u64 * 131 + s + 1)
                        .wrapping_mul(0x9E37_79B9_7F4A_7C15),
                );
                let t = LayerTrace::synthetic(cin, k, &cfg, &mut trng);
                let r = simulate_layer(
                    &ml,
                    l.positions(),
                    &t,
                    &hw,
                    true,
                    cfg.block_switch_cycles,
                );
                avg_cycles[ki] += (r.cycles - exact.cycles).abs() / exact.cycles;
                avg_energy[ki] += (r.energy.total_pj() - exact.energy.total_pj())
                    .abs()
                    / exact.energy.total_pj();
            }
            avg_cycles[ki] /= SEEDS as f64;
            avg_energy[ki] /= SEEDS as f64;
        }
        for (name, a) in [("cycles", avg_cycles), ("energy", avg_energy)] {
            assert!(
                a[1] <= a[0] * 1.5 + 1e-12,
                "{name}: err(64)={} not below err(16)={}",
                a[1],
                a[0]
            );
            assert!(
                a[2] <= a[1] * 1.5 + 1e-12,
                "{name}: err(256)={} not below err(64)={}",
                a[2],
                a[1]
            );
            if a[0] > 1e-6 {
                assert!(
                    a[2] <= a[0] * 0.9,
                    "{name}: err(256)={} did not converge vs err(16)={}",
                    a[2],
                    a[0]
                );
            }
        }
    });
}

/// ISSUE-3 sharding invariant: cost-balanced sharding never yields a
/// worse max-shard load than round-robin on the same per-image cost
/// set, for any batch size and shard count — and both plans conserve
/// the work (every item assigned exactly once, loads summing to the
/// total cost).
#[test]
fn prop_cost_balanced_shard_never_worse_than_round_robin() {
    prop::check("cost shard <= rr shard", prop::cases(64), |rng| {
        let n = rng.range(1, 40);
        let shards = rng.range(1, 9);
        // heavy-tailed costs: squaring spreads the load like real
        // per-image cycle variation does
        let costs: Vec<f64> = (0..n)
            .map(|_| {
                let u = rng.f64();
                1.0 + u * u * 1e6
            })
            .collect();
        let cost = ShardPlan::cost_balanced(&costs, shards);
        let rr = ShardPlan::round_robin(&costs, shards);
        assert!(
            cost.max_load() <= rr.max_load() + 1e-9,
            "cost {} > rr {} (n={n}, shards={shards})",
            cost.max_load(),
            rr.max_load()
        );
        // both plans conserve the batch
        let total: f64 = costs.iter().sum();
        for plan in [&cost, &rr] {
            assert_eq!(plan.assignment.len(), n);
            for &s in &plan.assignment {
                assert!(s < plan.n_shards);
            }
            let load_sum: f64 = plan.loads.iter().sum();
            assert!(
                (load_sum - total).abs() < total.max(1.0) * 1e-12,
                "loads {load_sum} vs total {total}"
            );
            // re-evaluating a plan on its own costs reproduces loads
            let re = plan.loads_with(&costs);
            for (a, b) in re.iter().zip(plan.loads.iter()) {
                assert_eq!(a, b);
            }
            assert!(plan.max_load() >= plan.mean_load() - 1e-9);
        }
    });
}

/// ISSUE-10 satellite: NaN-poisoned per-image costs no longer poison
/// the shard planner — `cost_balanced` sanitizes every cost (NaN → 0,
/// negatives → 0) before ranking and accumulation, so plans stay
/// finite, deterministic, conservative, and never worse than
/// round-robin on the same sanitized costs.
#[test]
fn prop_cost_balanced_survives_nan_costs() {
    prop::check("cost shard with NaN costs", prop::cases(64), |rng| {
        let n = rng.range(1, 32);
        let shards = rng.range(1, 7);
        let costs: Vec<f64> = (0..n)
            .map(|_| match rng.below(5) {
                0 => f64::NAN,
                1 => -rng.f64() * 100.0,
                2 => -0.0,
                _ => rng.f64() * 1e5,
            })
            .collect();
        let plan = ShardPlan::cost_balanced(&costs, shards);
        assert_eq!(plan.assignment.len(), n);
        for &s in &plan.assignment {
            assert!(s < plan.n_shards);
        }
        for &l in &plan.loads {
            assert!(l.is_finite() && l >= 0.0, "load {l}");
        }
        assert!(plan.max_load().is_finite());
        // deterministic: replanning the same costs is bit-identical
        let again = ShardPlan::cost_balanced(&costs, shards);
        assert_eq!(plan.assignment, again.assignment);
        assert_eq!(plan.loads, again.loads);
        // the greedy-vs-round-robin pin holds on the sanitized costs
        let rr = ShardPlan::round_robin(&costs, shards);
        assert!(
            plan.max_load() <= rr.max_load() + 1e-9,
            "cost {} > rr {}",
            plan.max_load(),
            rr.max_load()
        );
        // loads_with sanitizes identically: re-evaluation reproduces
        assert_eq!(plan.loads_with(&costs), plan.loads);
    });
}

/// ISSUE-10 tentpole: placement planner invariants over random
/// instances (NaN/negative compute costs included) — finite and
/// deterministic plans, every layer on a real core, never worse than
/// the optimal contiguous split, and total transfer cycles bounded by
/// cutting every edge at the chain's full diameter.
#[test]
fn prop_placement_pinned_and_conserves_transfers() {
    use rram_pattern_accel::sim::placement::{self, PlacementProblem};
    prop::check("placement pin + conservation", prop::cases(48), |rng| {
        let layers = rng.range(1, 7);
        let cores = rng.range(1, 5);
        let layer_cycles: Vec<f64> = (0..layers)
            .map(|_| match rng.below(8) {
                0 => f64::NAN,
                1 => -rng.f64() * 10.0,
                _ => rng.f64() * 1e4,
            })
            .collect();
        let transfer_bytes: Vec<f64> = (0..layers.saturating_sub(1))
            .map(|_| if rng.chance(0.1) { f64::NAN } else { rng.f64() * 1e3 })
            .collect();
        let p = PlacementProblem {
            layer_cycles,
            transfer_bytes,
            n_cores: cores,
            noc_bandwidth: 0.5 + rng.f64() * 64.0,
            noc_hop_latency: rng.f64() * 8.0,
        };
        let best = placement::plan(&p);
        let base = placement::contiguous(&p);
        assert!(best.max_stage_time().is_finite());
        for t in best.stage_times() {
            assert!(t.is_finite() && t >= 0.0, "stage {t}");
        }
        assert!(
            best.max_stage_time() <= base.max_stage_time() + 1e-9,
            "planner {} worse than contiguous {}",
            best.max_stage_time(),
            base.max_stage_time()
        );
        assert_eq!(best.assignment.len(), p.layer_cycles.len());
        for &c in &best.assignment {
            assert!(c < cores);
        }
        // conservation: per-edge volumes are placement-independent, so
        // no placement can spend more transfer cycles than cutting
        // every edge across the whole chain
        let all_cut: f64 = p
            .transfer_bytes
            .iter()
            .map(|&b| {
                b.max(0.0) / p.noc_bandwidth
                    + p.noc_hop_latency * (cores - 1) as f64
            })
            .sum();
        assert!(
            best.total_transfer_cycles() <= all_cut + 1e-9,
            "transfer {} > all-cut bound {all_cut}",
            best.total_transfer_cycles()
        );
        if cores == 1 {
            assert_eq!(best.total_transfer_cycles(), 0.0);
        }
        // deterministic: replanning is bit-identical
        let again = placement::plan(&p);
        assert_eq!(best.assignment, again.assignment);
        assert_eq!(best.compute, again.compute);
        assert_eq!(best.transfer, again.transfer);
    });
}

/// ISSUE-10 acceptance: on tiny instances the planner is checked
/// against an exhaustive enumeration of ALL layer-to-core assignments
/// — never worse than any contiguous assignment (stronger than the DP
/// pin) and never claiming to beat the global optimum.
#[test]
fn prop_placement_matches_exhaustive_oracle() {
    use rram_pattern_accel::sim::placement::{self, PlacementProblem};
    // Independent re-statement of the communication model (compute in
    // layer order, cut edges charged to the receiver with one hop per
    // chain step) — the oracle must not share the implementation.
    fn max_stage(p: &PlacementProblem, a: &[usize]) -> f64 {
        let mut stage = vec![0.0f64; p.n_cores];
        for (li, &c) in a.iter().enumerate() {
            stage[c] += p.layer_cycles[li].max(0.0);
        }
        for (e, &b) in p.transfer_bytes.iter().enumerate() {
            let (x, y) = (a[e], a[e + 1]);
            if x != y {
                stage[y] += b.max(0.0) / p.noc_bandwidth
                    + p.noc_hop_latency * x.abs_diff(y) as f64;
            }
        }
        stage.iter().copied().fold(0.0, f64::max)
    }
    prop::check("placement vs exhaustive oracle", prop::cases(24), |rng| {
        let layers = rng.range(1, 6);
        let cores = rng.range(1, 4);
        let p = PlacementProblem {
            layer_cycles: (0..layers).map(|_| rng.f64() * 100.0).collect(),
            transfer_bytes: (0..layers.saturating_sub(1))
                .map(|_| rng.f64() * 50.0)
                .collect(),
            n_cores: cores,
            noc_bandwidth: 0.5 + rng.f64() * 16.0,
            noc_hop_latency: rng.f64() * 4.0,
        };
        let best = placement::plan(&p);
        let mut all = vec![Vec::new()];
        for _ in 0..layers {
            let mut next = Vec::new();
            for a in &all {
                for c in 0..cores {
                    let mut b = a.clone();
                    b.push(c);
                    next.push(b);
                }
            }
            all = next;
        }
        let mut opt = f64::INFINITY;
        for a in &all {
            let m = max_stage(&p, a);
            opt = opt.min(m);
            let contiguous = a[0] == 0
                && a.windows(2).all(|w| w[1] == w[0] || w[1] == w[0] + 1);
            if contiguous {
                assert!(
                    best.max_stage_time() <= m + 1e-9,
                    "worse than contiguous {a:?}: {} vs {m}",
                    best.max_stage_time()
                );
            }
        }
        assert!(
            best.max_stage_time() + 1e-9 >= opt,
            "planner {} below the exhaustive optimum {opt}",
            best.max_stage_time()
        );
    });
}

/// ISSUE-10 acceptance: single-core placement is bit-exact with the
/// non-pipelined layer-order batch total (and within float noise of
/// the image-order total), and the placement JSON artifact is
/// byte-identical across the thread counts of the batch simulation
/// feeding it.
#[test]
fn prop_placement_single_core_exact_and_thread_invariant() {
    use rram_pattern_accel::report;
    use rram_pattern_accel::sim::placement::{self, PlacementProblem};
    prop::check("placement 1-core + threads", prop::cases(8), |rng| {
        let hw = HardwareConfig::default();
        let n_layers = rng.range(1, 4);
        let mut spec_layers = Vec::new();
        let mut weights = Vec::new();
        let mut cin = rng.range(1, 5);
        for li in 0..n_layers {
            let cout = rng.range(1, 16);
            let n_pat = rng.range(1, 7).min(cout * cin);
            let w = generate_layer(
                cout,
                cin,
                n_pat,
                0.5 + rng.f64() * 0.45,
                rng.f64() * 0.4,
                rng,
            );
            spec_layers.push(ConvLayer {
                name: format!("l{li}"),
                cout,
                cin,
                fmap: 5,
            });
            weights.push(w);
            cin = cout;
        }
        let spec = NetworkSpec { name: "prop".into(), layers: spec_layers };
        let nw = NetworkWeights::new(spec.clone(), weights);
        let mapped = PatternMapping.map_network(&nw, &geom(), 1);
        let sim_cfg = SimConfig {
            sample_positions: Some(rng.range(1, 16)),
            seed: rng.next_u64(),
            ..Default::default()
        };
        let n_images = rng.range(1, 4);
        let b1 = simulate_network_batch(&mapped, &spec, &hw, &sim_cfg, n_images, 1);
        let b3 = simulate_network_batch(&mapped, &spec, &hw, &sim_cfg, n_images, 3);

        // one core: the plan IS the non-pipelined schedule, bit for bit
        let p1 = PlacementProblem::from_batch(&b1, &spec, &hw, &sim_cfg, true);
        let plan1 = placement::plan(&p1);
        let layer_sum: f64 = b1.layer_cycles().iter().sum();
        assert_eq!(plan1.max_stage_time(), layer_sum);
        assert_eq!(plan1.pipeline_makespan(n_images), layer_sum);
        assert_eq!(plan1.total_transfer_cycles(), 0.0);
        // layer-order vs image-order accumulation: same sum, float noise
        let rel = (layer_sum - b1.total_cycles()).abs()
            / b1.total_cycles().max(1.0);
        assert!(rel < 1e-9, "layer-order diverged: rel {rel}");

        // multi-core: the artifact bytes do not depend on how many
        // threads simulated the batch
        let hw4 = HardwareConfig::default().with_cores(4, 64.0, 2.0).unwrap();
        let pa = PlacementProblem::from_batch(&b1, &spec, &hw4, &sim_cfg, true);
        let pb = PlacementProblem::from_batch(&b3, &spec, &hw4, &sim_cfg, true);
        let ja = report::placement_json(
            &placement::plan(&pa),
            n_images,
            b1.total_cycles(),
        )
        .to_string_pretty();
        let jb = report::placement_json(
            &placement::plan(&pb),
            n_images,
            b3.total_cycles(),
        )
        .to_string_pretty();
        assert_eq!(ja, jb, "placement artifact must be thread-invariant");
    });
}

/// Area monotonicity: higher weight sparsity never costs more pattern
/// crossbar area (same pattern count, same shape).
#[test]
fn prop_area_monotone_in_sparsity() {
    prop::check("area monotone in sparsity", prop::cases(12), |rng| {
        let cout = 64;
        let cin = 16;
        let seed_rng_a = &mut rng.fork(1);
        let seed_rng_b = &mut rng.fork(2);
        let w_dense = generate_layer(cout, cin, 6, 0.6, 0.2, seed_rng_a);
        let w_sparse = generate_layer(cout, cin, 6, 0.9, 0.45, seed_rng_b);
        let l = ConvLayer { name: "p".into(), cout, cin, fmap: 8 };
        let a = PatternMapping.map_layer(0, &l, &w_dense, &geom()).used_cells;
        let b = PatternMapping.map_layer(0, &l, &w_sparse, &geom()).used_cells;
        assert!(b <= a, "sparser layer used more cells: {b} > {a}");
    });
}

/// DSE Pareto invariants (ISSUE-4): no frontier member is dominated by
/// any swept point, every excluded valid point is dominated by some
/// frontier member, and the frontier's objective set is invariant under
/// evaluation order — random metric tuples drawn from small discrete
/// ranges so ties and exact duplicates are common.
#[test]
fn prop_pareto_frontier_sound_complete_order_invariant() {
    use rram_pattern_accel::dse::pareto::{dominates, ParetoFrontier};
    use rram_pattern_accel::dse::{PointMetrics, PointResult, SweepPoint};

    fn mk(i: usize, area: f64, energy: f64, cycles: f64) -> PointResult {
        PointResult {
            index: i,
            point: SweepPoint {
                scheme: "pattern".into(),
                ou_rows: 9,
                ou_cols: 8,
                xbar_rows: 512,
                xbar_cols: 512,
                n_patterns: 8,
                pruning: 0.86,
                zero_detection: true,
                block_switch_cycles: 2.0,
                cores: 1,
                noc_bandwidth: 32.0,
                noc_hop_latency: 4.0,
            },
            outcome: Ok(PointMetrics {
                cycles,
                energy_pj: energy,
                area_cells: area,
                crossbars: 1,
                ou_ops: cycles,
                utilization: 0.5,
            }),
            cache_hit: false,
        }
    }

    prop::check("pareto frontier invariants", prop::cases(64), |rng| {
        let n = rng.range(1, 40);
        let results: Vec<PointResult> = (0..n)
            .map(|i| {
                mk(
                    i,
                    (1 + rng.below(4)) as f64,
                    (1 + rng.below(4)) as f64,
                    (1 + rng.below(4)) as f64,
                )
            })
            .collect();
        let f = ParetoFrontier::from_results(&results);
        assert!(!f.is_empty(), "a non-empty sweep has a frontier");
        for (i, r) in results.iter().enumerate() {
            let m = r.metrics().unwrap();
            let dominated = results
                .iter()
                .any(|o| dominates(o.metrics().unwrap(), m));
            if f.members.contains(&i) {
                // soundness: members are dominated by nothing at all
                assert!(!dominated, "frontier member {i} dominated");
            } else {
                // completeness: exclusion only ever means dominated —
                // and a *frontier member* dominates it (dominance over
                // these finite tuples is transitive and acyclic)
                assert!(dominated, "non-member {i} not dominated");
                let by_member = f.members.iter().any(|&j| {
                    dominates(results[j].metrics().unwrap(), m)
                });
                assert!(by_member, "non-member {i} not dominated by the frontier");
            }
        }
        // order invariance: a random permutation of the results yields
        // the same multiset of frontier objective tuples
        let mut perm: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut perm);
        let permuted: Vec<PointResult> =
            perm.iter().map(|&j| results[j].clone()).collect();
        let f2 = ParetoFrontier::from_results(&permuted);
        let tuples = |f: &ParetoFrontier, rs: &[PointResult]| {
            let mut v: Vec<(u64, u64, u64)> = f
                .members
                .iter()
                .map(|&i| {
                    let m = rs[i].metrics().unwrap();
                    (m.area_cells as u64, m.energy_pj as u64, m.cycles as u64)
                })
                .collect();
            v.sort_unstable();
            v
        };
        assert_eq!(
            tuples(&f, &results),
            tuples(&f2, &permuted),
            "frontier must not depend on evaluation order"
        );
    });
}

/// ISSUE-8 extraction pin: the sort-based `from_results` is
/// **bit-identical** (same member indices, same order) to the retired
/// O(n²) pairwise pass kept as `from_results_oracle`, across random
/// grids dense with ties, duplicates, signed zeros and skipped points —
/// and the incremental `update` over a split result set reproduces the
/// full extraction exactly.
#[test]
fn prop_fast_frontier_matches_oracle_and_update_matches_full() {
    use rram_pattern_accel::dse::pareto::ParetoFrontier;
    use rram_pattern_accel::dse::{PointMetrics, PointResult, SweepPoint};

    fn mk(i: usize, outcome: Result<(f64, f64, f64), ()>) -> PointResult {
        PointResult {
            index: i,
            point: SweepPoint {
                scheme: "pattern".into(),
                ou_rows: 9,
                ou_cols: 8,
                xbar_rows: 512,
                xbar_cols: 512,
                n_patterns: 8,
                pruning: 0.86,
                zero_detection: true,
                block_switch_cycles: 2.0,
                cores: 1,
                noc_bandwidth: 32.0,
                noc_hop_latency: 4.0,
            },
            outcome: match outcome {
                Ok((area, energy, cycles)) => Ok(PointMetrics {
                    cycles,
                    energy_pj: energy,
                    area_cells: area,
                    crossbars: 1,
                    ou_ops: 1.0,
                    utilization: 0.5,
                }),
                Err(()) => Err("skip".into()),
            },
            cache_hit: false,
        }
    }

    fn coord(rng: &mut Rng) -> f64 {
        // Small discrete range → heavy ties/duplicates; occasional -0.0
        // exercises the total_cmp normalization.
        if rng.chance(0.05) { -0.0 } else { rng.below(6) as f64 }
    }

    prop::check("pareto fast == oracle (integration)", prop::cases(64), |rng| {
        let n = 1 + rng.below(120);
        let results: Vec<PointResult> = (0..n)
            .map(|i| {
                let outcome = if rng.chance(0.1) {
                    Err(())
                } else {
                    Ok((coord(rng), coord(rng), coord(rng)))
                };
                mk(i, outcome)
            })
            .collect();
        let fast = ParetoFrontier::from_results(&results);
        let oracle = ParetoFrontier::from_results_oracle(&results);
        assert_eq!(fast.members, oracle.members, "extraction drifted");

        // Warm-start path: frontier of a prefix, updated with the rest,
        // equals the full extraction bit for bit.
        let split = rng.below(n + 1);
        let mut warm = ParetoFrontier::from_results(&results[..split]);
        let rest: Vec<usize> = (split..n).collect();
        warm.update(&results, &rest);
        assert_eq!(warm.members, fast.members, "update drifted");
    });
}

/// Weighted selection always lands on the frontier and responds to the
/// weights: an all-area objective picks (one of) the minimum-area
/// frontier point(s), likewise for energy and cycles.
#[test]
fn prop_objective_selection_stays_on_frontier() {
    use rram_pattern_accel::dse::pareto::ParetoFrontier;
    use rram_pattern_accel::dse::{select_config, Objective, PointMetrics, PointResult, SweepPoint};

    prop::check("objective selection on frontier", prop::cases(32), |rng| {
        let n = rng.range(2, 24);
        let results: Vec<PointResult> = (0..n)
            .map(|i| PointResult {
                index: i,
                point: SweepPoint {
                    scheme: "pattern".into(),
                    ou_rows: 9,
                    ou_cols: 8,
                    xbar_rows: 512,
                    xbar_cols: 512,
                    n_patterns: 8,
                    pruning: 0.86,
                    zero_detection: true,
                    block_switch_cycles: 2.0,
                    cores: 1,
                    noc_bandwidth: 32.0,
                    noc_hop_latency: 4.0,
                },
                outcome: Ok(PointMetrics {
                    cycles: (1 + rng.below(8)) as f64,
                    energy_pj: (1 + rng.below(8)) as f64,
                    area_cells: (1 + rng.below(8)) as f64,
                    crossbars: 1,
                    ou_ops: 1.0,
                    utilization: 0.5,
                }),
                cache_hit: false,
            })
            .collect();
        let f = ParetoFrontier::from_results(&results);
        let axes: [(Objective, fn(&PointMetrics) -> f64); 3] = [
            (Objective { w_area: 1.0, w_energy: 0.0, w_cycles: 0.0 }, |m| m.area_cells),
            (Objective { w_area: 0.0, w_energy: 1.0, w_cycles: 0.0 }, |m| m.energy_pj),
            (Objective { w_area: 0.0, w_energy: 0.0, w_cycles: 1.0 }, |m| m.cycles),
        ];
        for (obj, metric) in axes {
            let t = select_config(&results, &f, &obj).expect("non-empty");
            let best = f
                .members
                .iter()
                .map(|&i| metric(results[i].metrics().unwrap()))
                .fold(f64::INFINITY, f64::min);
            assert_eq!(metric(&t.metrics), best, "single-axis objective");
            assert!(f.members.iter().any(|&i| {
                results[i].metrics().unwrap() == &t.metrics
            }));
        }
    });
}
