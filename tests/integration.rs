//! Cross-module integration tests: synthetic networks → all four
//! mapping schemes → cycle/energy simulation → paper-band checks.

use rram_pattern_accel::config::{HardwareConfig, SimConfig};
use rram_pattern_accel::mapping::{
    index, kmeans::KmeansMapping, naive::NaiveMapping, ou::enumerate_ous,
    ou_sparse::OuSparseMapping, pattern::PatternMapping, reconstruct_dense,
    MappingScheme,
};
use rram_pattern_accel::nn::NetworkSpec;
use rram_pattern_accel::pruning::synthetic::{CIFAR10, CIFAR100, IMAGENET};
use rram_pattern_accel::pruning::NetworkWeights;
use rram_pattern_accel::sim;
use rram_pattern_accel::util::threadpool;
use rram_pattern_accel::xbar::CellGeometry;

fn smallnet() -> NetworkWeights {
    // scaled-down VGG-ish net for fast integration runs
    let spec = NetworkSpec {
        name: "testnet".into(),
        layers: vec![
            rram_pattern_accel::nn::ConvLayer { name: "c0".into(), cin: 3, cout: 32, fmap: 16 },
            rram_pattern_accel::nn::ConvLayer { name: "c1".into(), cin: 32, cout: 64, fmap: 16 },
            rram_pattern_accel::nn::ConvLayer { name: "c2".into(), cin: 64, cout: 64, fmap: 8 },
        ],
    };
    let mut rng = rram_pattern_accel::util::rng::Rng::seed_from(99);
    let layers = spec
        .layers
        .iter()
        .map(|l| {
            rram_pattern_accel::pruning::synthetic::generate_layer(
                l.cout, l.cin, 6, 0.85, 0.38, &mut rng,
            )
        })
        .collect();
    NetworkWeights::new(spec, layers)
}

#[test]
fn all_schemes_map_and_validate() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let nw = smallnet();
    let schemes: Vec<Box<dyn MappingScheme>> = vec![
        Box::new(NaiveMapping),
        Box::new(PatternMapping),
        Box::new(KmeansMapping::default()),
        Box::new(OuSparseMapping),
    ];
    for s in &schemes {
        let mapped = s.map_network(&nw, &geom, 2);
        mapped.validate().unwrap_or_else(|e| panic!("{}: {e}", s.name()));
        // every scheme reconstructs the same dense weights
        for (li, ml) in mapped.layers.iter().enumerate() {
            let dense = reconstruct_dense(ml);
            assert_eq!(dense.data, nw.layers[li].data, "{} layer {li}", s.name());
        }
    }
}

#[test]
fn area_ordering_pattern_best() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let nw = smallnet();
    let naive = NaiveMapping.map_network(&nw, &geom, 2).total_crossbars();
    let pat = PatternMapping.map_network(&nw, &geom, 2).total_crossbars();
    let km = KmeansMapping::default().map_network(&nw, &geom, 2).total_crossbars();
    let sre = OuSparseMapping.map_network(&nw, &geom, 2).total_crossbars();
    assert!(pat <= sre && sre <= naive, "pattern {pat} sre {sre} naive {naive}");
    assert!(km <= naive);
    assert!(pat < naive, "pattern must save crossbars");
}

#[test]
fn ou_schedules_valid_for_all_schemes() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let nw = smallnet();
    for s in [&PatternMapping as &dyn MappingScheme, &NaiveMapping, &OuSparseMapping] {
        let mapped = s.map_network(&nw, &geom, 2);
        for ml in &mapped.layers {
            let tasks = enumerate_ous(ml);
            rram_pattern_accel::mapping::ou::validate_schedule(ml, &tasks, &geom)
                .unwrap_or_else(|e| panic!("{}: {e}", s.name()));
        }
    }
}

#[test]
fn index_roundtrip_whole_network() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let nw = smallnet();
    let mapped = PatternMapping.map_network(&nw, &geom, 2);
    for ml in &mapped.layers {
        let decoded = index::decode(&index::encode(ml)).expect("decode");
        assert_eq!(index::reconstruct_placements(&decoded, &geom), ml.placements);
    }
}

#[test]
fn simulation_comparison_bands() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let nw = smallnet();
    let spec = nw.spec.clone();
    let sim_cfg = SimConfig::default();
    let t = threadpool::default_threads();
    let naive = NaiveMapping.map_network(&nw, &geom, t);
    let ours = PatternMapping.map_network(&nw, &geom, t);
    let base = sim::simulate_network(&naive, &spec, &hw, &sim_cfg, t);
    let mine = sim::simulate_network(&ours, &spec, &hw, &sim_cfg, t);
    let cmp = sim::Comparison { baseline: base, ours: mine };
    assert!(cmp.speedup() > 1.0);
    assert!(cmp.energy_efficiency() > 1.2);
    assert!(cmp.area_efficiency() >= 1.0);
    // skipping only ever removes work
    for l in &cmp.ours.layers {
        assert!(l.ou_ops >= 0.0 && l.skipped_ou_ops >= 0.0);
    }
}

#[test]
fn simulation_deterministic_across_runs() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let nw = smallnet();
    let spec = nw.spec.clone();
    let sim_cfg = SimConfig::default();
    let ours = PatternMapping.map_network(&nw, &geom, 2);
    let a = sim::simulate_network(&ours, &spec, &hw, &sim_cfg, 1);
    let b = sim::simulate_network(&ours, &spec, &hw, &sim_cfg, 4);
    assert_eq!(a.total_cycles(), b.total_cycles());
    assert_eq!(a.total_energy(), b.total_energy());
}

#[test]
fn table2_profiles_generate_exact_pattern_counts() {
    for profile in [&CIFAR10, &CIFAR100, &IMAGENET] {
        let nw = profile.generate(42);
        let stats = nw.stats();
        assert_eq!(
            stats.patterns_per_layer,
            profile.patterns_per_layer.to_vec(),
            "{}",
            profile.name
        );
        assert!(
            (stats.sparsity - profile.sparsity).abs() < 0.02,
            "{}: sparsity {} vs {}",
            profile.name,
            stats.sparsity,
            profile.sparsity
        );
        assert!(
            (stats.all_zero_kernel_ratio - profile.all_zero_ratio).abs() < 0.02,
            "{}: zero ratio",
            profile.name
        );
    }
}

/// Fig. 7 headline band on the real (full-size) CIFAR-10 profile:
/// 3–8x area efficiency, pattern < kmeans < ... ordering.
#[test]
fn fig7_band_cifar10_full_scale() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let t = threadpool::default_threads();
    let nw = CIFAR10.generate(42);
    let naive = NaiveMapping.map_network(&nw, &geom, t).total_crossbars();
    let pat = PatternMapping.map_network(&nw, &geom, t).total_crossbars();
    let eff = naive as f64 / pat as f64;
    assert!(eff > 3.0 && eff < 8.0, "area efficiency {eff} out of band");
}
