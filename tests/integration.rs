//! Cross-module integration tests: synthetic networks → all four
//! mapping schemes → cycle/energy simulation → paper-band checks.

use rram_pattern_accel::config::{HardwareConfig, SimConfig};
use rram_pattern_accel::mapping::{
    index, kmeans::KmeansMapping, naive::NaiveMapping, ou::enumerate_ous,
    ou_sparse::OuSparseMapping, pattern::PatternMapping, reconstruct_dense,
    MappingScheme,
};
use rram_pattern_accel::nn::NetworkSpec;
use rram_pattern_accel::pruning::synthetic::{CIFAR10, CIFAR100, IMAGENET};
use rram_pattern_accel::pruning::NetworkWeights;
use rram_pattern_accel::sim;
use rram_pattern_accel::util::threadpool;
use rram_pattern_accel::xbar::CellGeometry;

fn smallnet() -> NetworkWeights {
    // scaled-down VGG-ish net for fast integration runs
    let spec = NetworkSpec {
        name: "testnet".into(),
        layers: vec![
            rram_pattern_accel::nn::ConvLayer { name: "c0".into(), cin: 3, cout: 32, fmap: 16 },
            rram_pattern_accel::nn::ConvLayer { name: "c1".into(), cin: 32, cout: 64, fmap: 16 },
            rram_pattern_accel::nn::ConvLayer { name: "c2".into(), cin: 64, cout: 64, fmap: 8 },
        ],
    };
    let mut rng = rram_pattern_accel::util::rng::Rng::seed_from(99);
    let layers = spec
        .layers
        .iter()
        .map(|l| {
            rram_pattern_accel::pruning::synthetic::generate_layer(
                l.cout, l.cin, 6, 0.85, 0.38, &mut rng,
            )
        })
        .collect();
    NetworkWeights::new(spec, layers)
}

#[test]
fn all_schemes_map_and_validate() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let nw = smallnet();
    let schemes: Vec<Box<dyn MappingScheme>> = vec![
        Box::new(NaiveMapping),
        Box::new(PatternMapping),
        Box::new(KmeansMapping::default()),
        Box::new(OuSparseMapping),
    ];
    for s in &schemes {
        let mapped = s.map_network(&nw, &geom, 2);
        mapped.validate().unwrap_or_else(|e| panic!("{}: {e}", s.name()));
        // every scheme reconstructs the same dense weights
        for (li, ml) in mapped.layers.iter().enumerate() {
            let dense = reconstruct_dense(ml);
            assert_eq!(dense.data, nw.layers[li].data, "{} layer {li}", s.name());
        }
    }
}

#[test]
fn area_ordering_pattern_best() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let nw = smallnet();
    let naive = NaiveMapping.map_network(&nw, &geom, 2).total_crossbars();
    let pat = PatternMapping.map_network(&nw, &geom, 2).total_crossbars();
    let km = KmeansMapping::default().map_network(&nw, &geom, 2).total_crossbars();
    let sre = OuSparseMapping.map_network(&nw, &geom, 2).total_crossbars();
    assert!(pat <= sre && sre <= naive, "pattern {pat} sre {sre} naive {naive}");
    assert!(km <= naive);
    assert!(pat < naive, "pattern must save crossbars");
}

#[test]
fn ou_schedules_valid_for_all_schemes() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let nw = smallnet();
    for s in [&PatternMapping as &dyn MappingScheme, &NaiveMapping, &OuSparseMapping] {
        let mapped = s.map_network(&nw, &geom, 2);
        for ml in &mapped.layers {
            let tasks = enumerate_ous(ml);
            rram_pattern_accel::mapping::ou::validate_schedule(ml, &tasks, &geom)
                .unwrap_or_else(|e| panic!("{}: {e}", s.name()));
        }
    }
}

#[test]
fn index_roundtrip_whole_network() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let nw = smallnet();
    let mapped = PatternMapping.map_network(&nw, &geom, 2);
    for ml in &mapped.layers {
        let decoded = index::decode(&index::encode(ml)).expect("decode");
        assert_eq!(index::reconstruct_placements(&decoded, &geom), ml.placements);
    }
}

#[test]
fn simulation_comparison_bands() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let nw = smallnet();
    let spec = nw.spec.clone();
    let sim_cfg = SimConfig::default();
    let t = threadpool::default_threads();
    let naive = NaiveMapping.map_network(&nw, &geom, t);
    let ours = PatternMapping.map_network(&nw, &geom, t);
    let base = sim::simulate_network(&naive, &spec, &hw, &sim_cfg, t);
    let mine = sim::simulate_network(&ours, &spec, &hw, &sim_cfg, t);
    let cmp = sim::Comparison { baseline: base, ours: mine };
    assert!(cmp.speedup() > 1.0);
    assert!(cmp.energy_efficiency() > 1.2);
    assert!(cmp.area_efficiency() >= 1.0);
    // skipping only ever removes work
    for l in &cmp.ours.layers {
        assert!(l.ou_ops >= 0.0 && l.skipped_ou_ops >= 0.0);
    }
}

#[test]
fn simulation_deterministic_across_runs() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let nw = smallnet();
    let spec = nw.spec.clone();
    let sim_cfg = SimConfig::default();
    let ours = PatternMapping.map_network(&nw, &geom, 2);
    let a = sim::simulate_network(&ours, &spec, &hw, &sim_cfg, 1);
    let b = sim::simulate_network(&ours, &spec, &hw, &sim_cfg, 4);
    assert_eq!(a.total_cycles(), b.total_cycles());
    assert_eq!(a.total_energy(), b.total_energy());
}

#[test]
fn table2_profiles_generate_exact_pattern_counts() {
    for profile in [&CIFAR10, &CIFAR100, &IMAGENET] {
        let nw = profile.generate(42);
        let stats = nw.stats();
        assert_eq!(
            stats.patterns_per_layer,
            profile.patterns_per_layer.to_vec(),
            "{}",
            profile.name
        );
        assert!(
            (stats.sparsity - profile.sparsity).abs() < 0.02,
            "{}: sparsity {} vs {}",
            profile.name,
            stats.sparsity,
            profile.sparsity
        );
        assert!(
            (stats.all_zero_kernel_ratio - profile.all_zero_ratio).abs() < 0.02,
            "{}: zero ratio",
            profile.name
        );
    }
}

/// Fig. 7 headline band on the real (full-size) CIFAR-10 profile:
/// 3–8x area efficiency, pattern < kmeans < ... ordering.
#[test]
fn fig7_band_cifar10_full_scale() {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let t = threadpool::default_threads();
    let nw = CIFAR10.generate(42);
    let naive = NaiveMapping.map_network(&nw, &geom, t).total_crossbars();
    let pat = PatternMapping.map_network(&nw, &geom, t).total_crossbars();
    let eff = naive as f64 / pat as f64;
    assert!(eff > 3.0 && eff < 8.0, "area efficiency {eff} out of band");
}

/// ISSUE-3 acceptance: with a 4-shard plan over a seeded synthetic
/// batch, cost-aware dispatch yields a strictly lower max-shard
/// predicted-cycle load than round-robin on the same batch set.
#[test]
fn cost_balanced_sharding_beats_round_robin_on_seeded_batch() {
    use rram_pattern_accel::sim::ShardPolicy;
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let nw = smallnet();
    let spec = nw.spec.clone();
    let mapped = PatternMapping.map_network(&nw, &geom, 2);
    // high-variance traces spread the per-image costs, which is exactly
    // the regime where cost-blind round-robin stacks heavy images
    let sim_cfg = SimConfig {
        seed: 42,
        zero_blob_ratio: 0.35,
        dead_channel_ratio: 0.1,
        ..Default::default()
    };
    // 10 images over 4 shards: the uneven split leaves round-robin
    // with a heavy 3-image shard the cost-balanced plan avoids
    let batch = sim::simulate_network_batch(&mapped, &spec, &hw, &sim_cfg, 10, 2);
    let cost = batch.shard_plan(4, ShardPolicy::CostBalanced);
    let rr = batch.shard_plan(4, ShardPolicy::RoundRobin);
    assert!(
        cost.max_load() < rr.max_load(),
        "cost-balanced max shard load {} must beat round-robin {}",
        cost.max_load(),
        rr.max_load()
    );
    // the plan's balance carries over to the achieved cycles (small
    // slack: the plan was built on first-order predicted costs, and the
    // achieved cycles add block-switch overhead on top)
    let achieved_cost = cost.loads_with(&batch.image_cycles());
    let achieved_rr = rr.loads_with(&batch.image_cycles());
    let max = |v: &[f64]| v.iter().copied().fold(0.0, f64::max);
    assert!(
        max(&achieved_cost) <= max(&achieved_rr) * 1.01,
        "achieved max shard cycles: cost {} vs rr {}",
        max(&achieved_cost),
        max(&achieved_rr)
    );
}

/// Coordinator failure-injection suite (ISSUE-2): flaky backends
/// exercise retry/requeue, queued requests past their deadline get a
/// timely error reply, near-deadline requests fire partial batches
/// early, and the failed-request alarm trips under concurrent
/// submitters.
mod coordinator_failure_injection {
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    use rram_pattern_accel::coordinator::{
        Coordinator, CoordinatorConfig, CostModel, InferBackend,
    };

    /// Sums each request's two inputs; fails the first `fail_first`
    /// run_batch calls with an injected error.
    struct FlakyBackend {
        batch: usize,
        fail_first: u64,
        calls: Arc<AtomicU64>,
    }

    impl InferBackend for FlakyBackend {
        fn input_len(&self) -> usize {
            2
        }
        fn output_len(&self) -> usize {
            1
        }
        fn batch_size(&self) -> usize {
            self.batch
        }
        fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
            let n = self.calls.fetch_add(1, Ordering::Relaxed);
            if n < self.fail_first {
                return Err(format!("injected failure #{n}"));
            }
            Ok((0..self.batch)
                .map(|i| batch[i * 2] + batch[i * 2 + 1])
                .collect())
        }
    }

    /// Single-slot backend that holds the worker for `delay` per batch.
    struct SlowBackend {
        delay: Duration,
    }

    impl InferBackend for SlowBackend {
        fn input_len(&self) -> usize {
            2
        }
        fn output_len(&self) -> usize {
            1
        }
        fn batch_size(&self) -> usize {
            1
        }
        fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
            std::thread::sleep(self.delay);
            Ok(vec![batch[0] + batch[1]])
        }
    }

    const LONG: Duration = Duration::from_secs(10);

    #[test]
    fn flaky_backend_retries_transparently() {
        let calls = Arc::new(AtomicU64::new(0));
        let calls2 = calls.clone();
        let c = Coordinator::start_with(
            move || FlakyBackend { batch: 2, fail_first: 1, calls: calls2 },
            CoordinatorConfig {
                max_wait: Duration::from_millis(200),
                max_retries: 1,
                ..Default::default()
            },
            None,
        );
        let rx1 = c.submit(vec![1.0, 2.0]);
        let rx2 = c.submit(vec![3.0, 4.0]);
        let r1 = rx1.recv_timeout(LONG).expect("reply 1");
        let r2 = rx2.recv_timeout(LONG).expect("reply 2");
        // the first run failed, the retry succeeded: requesters never
        // see the injected error
        assert_eq!(r1.logits(), &[3.0][..]);
        assert_eq!(r2.logits(), &[7.0][..]);
        assert_eq!(c.metrics.retried_batches.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.failed_requests.load(Ordering::Relaxed), 0);
        assert_eq!(c.metrics.requests.load(Ordering::Relaxed), 2);
        assert_eq!(calls.load(Ordering::Relaxed), 2);
        c.shutdown();
    }

    #[test]
    fn persistent_failure_exhausts_retries_then_reports() {
        let calls = Arc::new(AtomicU64::new(0));
        let calls2 = calls.clone();
        let c = Coordinator::start_with(
            move || FlakyBackend {
                batch: 2,
                fail_first: u64::MAX,
                calls: calls2,
            },
            CoordinatorConfig {
                max_wait: Duration::from_millis(5),
                max_retries: 1,
                alarm_threshold: 2,
                ..Default::default()
            },
            None,
        );
        let reply = c.submit(vec![1.0, 2.0]).recv_timeout(LONG).expect("reply");
        let err = reply.result.expect_err("exhausted retries must deliver");
        assert!(err.contains("injected failure"), "{err}");
        assert_eq!(calls.load(Ordering::Relaxed), 2, "original + one retry");
        assert_eq!(c.metrics.retried_batches.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.failed_requests.load(Ordering::Relaxed), 1);
        assert!(!c.metrics.failed_alarm(), "below threshold");
        let reply2 = c.submit(vec![0.5, 0.5]).recv_timeout(LONG).expect("reply");
        assert!(reply2.result.is_err());
        assert_eq!(c.metrics.failed_requests.load(Ordering::Relaxed), 2);
        assert!(c.metrics.failed_alarm(), "threshold 2 reached");
        c.shutdown();
    }

    #[test]
    fn queued_past_deadline_gets_timely_error() {
        let c = Coordinator::start_with(
            || SlowBackend { delay: Duration::from_millis(300) },
            CoordinatorConfig {
                max_wait: Duration::from_millis(1),
                ..Default::default()
            },
            None,
        );
        // A occupies the single-slot backend for ~300 ms…
        let rx_a = c.submit(vec![1.0, 2.0]);
        std::thread::sleep(Duration::from_millis(50));
        // …so B's 30 ms deadline passes while it waits in the queue.
        let t0 = Instant::now();
        let rx_b = c.submit_with_deadline(vec![3.0, 4.0], Duration::from_millis(30));
        let rep_b = rx_b.recv_timeout(LONG).expect("B must get a reply");
        let waited = t0.elapsed();
        let err = rep_b.result.expect_err("B must see the deadline error");
        assert!(err.contains("deadline"), "{err}");
        assert!(waited < Duration::from_secs(5), "error not timely: {waited:?}");
        assert_eq!(c.metrics.deadline_expired.load(Ordering::Relaxed), 1);
        assert_eq!(c.metrics.failed_requests.load(Ordering::Relaxed), 1);
        // A itself completes normally
        let rep_a = rx_a.recv_timeout(LONG).expect("A completes");
        assert_eq!(rep_a.logits(), &[3.0][..]);
        c.shutdown();
    }

    #[test]
    fn near_deadline_fires_partial_batch_early() {
        let calls = Arc::new(AtomicU64::new(0));
        let c = Coordinator::start_with(
            move || FlakyBackend { batch: 8, fail_first: 0, calls },
            CoordinatorConfig {
                // without the deadline the batcher would wait 30 s
                max_wait: Duration::from_secs(30),
                ..Default::default()
            },
            None,
        );
        // 1.5 s deadline: generous enough that worker scheduling delay on
        // a loaded CI machine cannot expire it, still far below the 30 s
        // batch window it must cut short.
        let rx = c.submit_with_deadline(vec![1.0, 2.0], Duration::from_millis(1500));
        let rep = rx.recv_timeout(LONG).expect("batch must fire by the deadline");
        assert!(rep.result.is_ok(), "{:?}", rep.result);
        assert_eq!(rep.batch_fill, 1, "fired padded, not full");
        assert_eq!(c.metrics.deadline_expired.load(Ordering::Relaxed), 0);
        c.shutdown();
    }

    #[test]
    fn alarm_trips_under_concurrent_failing_submitters() {
        let calls = Arc::new(AtomicU64::new(0));
        let c = Arc::new(Coordinator::start_with(
            move || FlakyBackend {
                batch: 4,
                fail_first: u64::MAX,
                calls,
            },
            CoordinatorConfig {
                max_wait: Duration::from_millis(2),
                max_retries: 1,
                alarm_threshold: 5,
                ..Default::default()
            },
            None,
        ));
        let mut handles = Vec::new();
        for t in 0..8 {
            let c2 = c.clone();
            handles.push(std::thread::spawn(move || {
                let rx = c2.submit(vec![t as f32, 1.0]);
                let rep = rx.recv_timeout(LONG).expect("reply delivered");
                assert!(rep.result.is_err(), "backend always fails");
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.metrics.failed_requests.load(Ordering::Relaxed), 8);
        assert!(c.metrics.failed_alarm(), "threshold 5 < 8 failures");
        assert!(c.metrics.retried_batches.load(Ordering::Relaxed) >= 1);
    }

    /// One worker's backend permanently fails while its siblings are
    /// healthy, with cross-worker requeue disabled (`max_requeues: 0`):
    /// the failure stays inside that worker's domain — the strict
    /// per-worker isolation contract of PR 3. The first request routed
    /// to it exhausts its (zero) retries and gets the error; quarantine
    /// then routes every later request around the dead worker, and the
    /// pool keeps serving.
    #[test]
    fn dead_worker_only_fails_its_own_requests() {
        use rram_pattern_accel::coordinator::BalancePolicy;

        /// Sums each request's two inputs; worker 0's instance is
        /// configured dead.
        struct DirectedBackend {
            dead: bool,
        }
        impl InferBackend for DirectedBackend {
            fn input_len(&self) -> usize {
                2
            }
            fn output_len(&self) -> usize {
                1
            }
            fn batch_size(&self) -> usize {
                1
            }
            fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
                if self.dead {
                    return Err("worker backend is dead".to_string());
                }
                Ok(vec![batch[0] + batch[1]])
            }
        }

        let c = Coordinator::start_pool(
            |worker| DirectedBackend { dead: worker == 0 },
            CoordinatorConfig {
                max_wait: Duration::from_millis(1),
                max_retries: 0,
                max_requeues: 0,
                workers: 3,
                balance: BalancePolicy::RoundRobin,
                quarantine_after: 1,
                ..Default::default()
            },
            None,
        );
        // sequential submit+recv: routing is deterministic, and each
        // reply lands before the next request is routed, so the
        // quarantine decision is visible to the dispatcher in time
        let mut failed = 0usize;
        let mut ok = 0usize;
        for i in 0..9 {
            let rx = c.submit(vec![i as f32, 1.0]);
            let rep = rx.recv_timeout(LONG).expect("terminal reply");
            match rep.result {
                Ok(logits) => {
                    assert_eq!(logits[0], i as f32 + 1.0);
                    ok += 1;
                }
                Err(e) => {
                    assert!(e.contains("dead"), "{e}");
                    failed += 1;
                }
            }
        }
        // round-robin sends request 0 to worker 0; its failure
        // quarantines the worker, and everything else succeeds
        assert_eq!(failed, 1, "only the dead worker's request fails");
        assert_eq!(ok, 8);
        let shards = c.worker_metrics();
        assert_eq!(shards.len(), 3);
        assert_eq!(shards[0].requests.load(Ordering::Relaxed), 1);
        assert_eq!(shards[0].failed_requests.load(Ordering::Relaxed), 1);
        for s in &shards[1..] {
            assert_eq!(s.failed_requests.load(Ordering::Relaxed), 0);
            assert_eq!(s.requests.load(Ordering::Relaxed), 4);
        }
        let stats = c.worker_stats();
        assert!(stats[0].quarantined, "dead worker must be quarantined");
        assert!(!stats[1].quarantined && !stats[2].quarantined);
        let merged = c.merged_metrics();
        assert_eq!(merged.requests.load(Ordering::Relaxed), 9);
        assert_eq!(merged.failed_requests.load(Ordering::Relaxed), 1);
        // successes only in the latency summary, each exactly once
        assert_eq!(merged.latency_summary().len(), 8);
        c.shutdown();
    }

    /// Same failure under concurrent submitters: every request gets a
    /// terminal reply and the healthy majority of the pool keeps
    /// serving (no pool-wide stall or failure).
    #[test]
    fn pool_survives_dead_worker_under_concurrent_load() {
        use rram_pattern_accel::coordinator::BalancePolicy;

        struct DirectedBackend {
            dead: bool,
        }
        impl InferBackend for DirectedBackend {
            fn input_len(&self) -> usize {
                2
            }
            fn output_len(&self) -> usize {
                1
            }
            fn batch_size(&self) -> usize {
                2
            }
            fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
                if self.dead {
                    return Err("worker backend is dead".to_string());
                }
                Ok((0..2).map(|i| batch[i * 2] + batch[i * 2 + 1]).collect())
            }
        }

        let c = Arc::new(Coordinator::start_pool(
            |worker| DirectedBackend { dead: worker == 0 },
            CoordinatorConfig {
                max_wait: Duration::from_millis(2),
                max_retries: 0,
                max_requeues: 0,
                workers: 3,
                balance: BalancePolicy::RoundRobin,
                quarantine_after: 1,
                ..Default::default()
            },
            None,
        ));
        let n = 16usize;
        let mut handles = Vec::new();
        for t in 0..n {
            let c2 = c.clone();
            handles.push(std::thread::spawn(move || {
                let rx = c2.submit(vec![t as f32, 1.0]);
                let rep = rx.recv_timeout(LONG).expect("terminal reply");
                match rep.result {
                    Ok(logits) => {
                        assert_eq!(logits[0], t as f32 + 1.0);
                        true
                    }
                    Err(e) => {
                        assert!(e.contains("dead"), "{e}");
                        false
                    }
                }
            }));
        }
        let mut ok = 0usize;
        for h in handles {
            if h.join().unwrap() {
                ok += 1;
            }
        }
        let merged = c.merged_metrics();
        assert_eq!(
            merged.requests.load(Ordering::Relaxed),
            n as u64,
            "every request gets a terminal reply"
        );
        let dead_failures =
            c.worker_metrics()[0].failed_requests.load(Ordering::Relaxed);
        assert_eq!(
            merged.failed_requests.load(Ordering::Relaxed),
            dead_failures,
            "failures only ever come from the dead worker"
        );
        assert_eq!(
            ok,
            n - dead_failures as usize,
            "successes and dead-worker failures must partition the requests"
        );
        assert!(ok > 0, "the pool must keep serving");
    }

    /// Same dead worker, but with the default cross-worker requeue
    /// enabled (ISSUE-4 satellite): the failed batch's requests are
    /// re-dispatched to healthy siblings before any error is delivered,
    /// so every request succeeds even under concurrent submitters.
    #[test]
    fn dead_worker_requests_are_rescued_by_requeue() {
        use rram_pattern_accel::coordinator::BalancePolicy;

        struct DirectedBackend {
            dead: bool,
        }
        impl InferBackend for DirectedBackend {
            fn input_len(&self) -> usize {
                2
            }
            fn output_len(&self) -> usize {
                1
            }
            fn batch_size(&self) -> usize {
                2
            }
            fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
                if self.dead {
                    return Err("worker backend is dead".to_string());
                }
                Ok((0..2).map(|i| batch[i * 2] + batch[i * 2 + 1]).collect())
            }
        }

        let c = Arc::new(Coordinator::start_pool(
            |worker| DirectedBackend { dead: worker == 0 },
            CoordinatorConfig {
                max_wait: Duration::from_millis(2),
                max_retries: 0,
                max_requeues: 1, // the default, spelled out
                workers: 3,
                balance: BalancePolicy::RoundRobin,
                quarantine_after: 1,
                ..Default::default()
            },
            None,
        ));
        let n = 16usize;
        let mut handles = Vec::new();
        for t in 0..n {
            let c2 = c.clone();
            handles.push(std::thread::spawn(move || {
                let rx = c2.submit(vec![t as f32, 1.0]);
                let rep = rx.recv_timeout(LONG).expect("terminal reply");
                let logits =
                    rep.result.expect("requeue must rescue dead-worker requests");
                assert_eq!(logits[0], t as f32 + 1.0);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let merged = c.merged_metrics();
        assert_eq!(merged.requests.load(Ordering::Relaxed), n as u64);
        assert_eq!(
            merged.failed_requests.load(Ordering::Relaxed),
            0,
            "no request may fail while healthy siblings exist"
        );
        // whatever landed on the dead worker first was requeued exactly
        // once and replied exactly once (the no-double-count invariant)
        let requeued = merged.requeued_requests.load(Ordering::Relaxed);
        assert_eq!(
            c.worker_metrics()[0].requeued_requests.load(Ordering::Relaxed),
            requeued,
            "only the dead worker requeues"
        );
        assert_eq!(merged.latency_summary().len(), n);
        // the dead worker records no terminal replies of its own
        assert_eq!(c.worker_metrics()[0].requests.load(Ordering::Relaxed), 0);
    }

    /// Quarantine expiry (ISSUE-4 satellite): a worker that recovers
    /// while quarantined rejoins routing after the configured wall time
    /// without needing a probe request to drain through its queue.
    #[test]
    fn quarantine_expiry_readmits_recovered_worker() {
        use rram_pattern_accel::coordinator::BalancePolicy;

        /// Worker 0 fails its first batch only; everything after (and
        /// every sibling) succeeds.
        struct RecoveringBackend {
            worker: usize,
            w0_calls: Arc<AtomicU64>,
        }
        impl InferBackend for RecoveringBackend {
            fn input_len(&self) -> usize {
                2
            }
            fn output_len(&self) -> usize {
                1
            }
            fn batch_size(&self) -> usize {
                1
            }
            fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
                if self.worker == 0
                    && self.w0_calls.fetch_add(1, Ordering::Relaxed) == 0
                {
                    return Err("transient fault".to_string());
                }
                Ok(vec![batch[0] + batch[1]])
            }
        }

        let w0_calls = Arc::new(AtomicU64::new(0));
        let calls2 = w0_calls.clone();
        let c = Coordinator::start_pool(
            move |worker| RecoveringBackend { worker, w0_calls: calls2.clone() },
            CoordinatorConfig {
                max_wait: Duration::from_millis(1),
                max_retries: 0,
                max_requeues: 0, // isolate the expiry behavior
                workers: 2,
                balance: BalancePolicy::RoundRobin,
                quarantine_after: 1,
                // Generous enough that scheduling delay on a loaded CI
                // machine cannot parole worker 0 before the
                // while-quarantined assertions below have run.
                quarantine_expiry: Some(Duration::from_millis(1500)),
                ..Default::default()
            },
            None,
        );
        // request 0 lands on worker 0 and hits the transient fault
        let rep = c.submit(vec![1.0, 2.0]).recv_timeout(LONG).expect("reply");
        assert!(rep.result.is_err(), "transient fault delivered");
        assert!(c.worker_stats()[0].quarantined, "worker 0 quarantined");
        // while quarantined, traffic routes around worker 0
        for _ in 0..2 {
            let rep = c.submit(vec![1.0, 2.0]).recv_timeout(LONG).expect("reply");
            assert!(rep.result.is_ok());
        }
        assert_eq!(
            c.worker_metrics()[0].requests.load(Ordering::Relaxed),
            1,
            "no new traffic while quarantined"
        );
        // after the expiry the worker rejoins on probation — no probe
        // request was needed (its queue stayed empty the whole time)
        std::thread::sleep(Duration::from_millis(1800));
        assert!(
            !c.worker_stats()[0].quarantined,
            "expiry must lift the quarantine"
        );
        for i in 0..4 {
            let rep = c
                .submit(vec![i as f32, 1.0])
                .recv_timeout(LONG)
                .expect("reply");
            assert!(rep.result.is_ok(), "recovered worker must serve");
        }
        let w0 = c.worker_metrics()[0].requests.load(Ordering::Relaxed);
        assert!(w0 >= 2, "worker 0 must take traffic again, got {w0}");
        assert_eq!(c.merged_metrics().failed_requests.load(Ordering::Relaxed), 1);
        c.shutdown();
    }

    #[test]
    fn cost_estimates_attached_and_track_input_sparsity() {
        let calls = Arc::new(AtomicU64::new(0));
        let model = CostModel {
            dense_cycles: 1000.0,
            dense_energy_pj: 500.0,
            skip_slope: 1.0,
            energy_skip_slope: 1.0,
        };
        let c = Coordinator::start_with(
            move || FlakyBackend { batch: 2, fail_first: 0, calls },
            CoordinatorConfig {
                max_wait: Duration::from_millis(200),
                ..Default::default()
            },
            Some(model),
        );
        let rx_dense = c.submit(vec![1.0, 2.0]);
        let rx_sparse = c.submit(vec![0.0, 2.0]);
        let dense = rx_dense.recv_timeout(LONG).expect("dense reply");
        let sparse = rx_sparse.recv_timeout(LONG).expect("sparse reply");
        let cd = dense.cost.expect("estimate attached");
        let cs = sparse.cost.expect("estimate attached");
        assert_eq!(cd.input_zero_fraction, 0.0);
        assert!((cs.input_zero_fraction - 0.5).abs() < 1e-12);
        assert!((cd.est_cycles - 1000.0).abs() < 1e-9);
        assert!(cs.est_cycles < cd.est_cycles, "sparser input is cheaper");
        assert!(cs.est_energy_pj < cd.est_energy_pj);
        c.shutdown();
    }
}
