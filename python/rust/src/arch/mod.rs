// placeholder
