"""L2: JAX model definitions built on the L1 crossbar kernels.

Two forward modes:

- ``mode="float"``: pure-jnp float conv (training path, gradients flow).
- ``mode="crossbar"``: every conv runs through the Pallas OU crossbar
  kernel (``kernels.ou_mvm``) — the functional model of the accelerator.
  This is the graph that ``aot.py`` lowers to HLO for the Rust runtime.

Networks:

- ``SmallCNN`` — 5 conv layers + GAP + FC, ~36k conv weights; used for
  the real end-to-end train→prune→map pipeline (paper's VGG16 stands in
  at the statistics level, see DESIGN.md §3).
- ``vgg16_conv_shapes`` — the paper's modified VGG16 (13 conv layers,
  one FC); used for shape/inventory checks and by the Rust synthetic
  generator (it reads these shapes from the metadata JSON).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import quant, ref
from .kernels.ou_mvm import ou_mvm
from .kernels.quant import QuantConfig

# The model runs inputs at 8 effective bits: a 4-bit DAC driven
# bit-serially over two cycles (ISAAC-style). The energy model (rust
# `xbar::energy`) accounts x_bits/dac_bits DAC conversions per input.
MODEL_QUANT = QuantConfig(x_bits=8)

# (cout, cin) for each 3x3 conv layer of SmallCNN; 'M' = 2x2 maxpool.
SMALLCNN_ARCH: List = [(16, 3), (16, 16), "M", (32, 16), (32, 32), "M",
                       (64, 32), "M"]
SMALLCNN_CLASSES = 10
SMALLCNN_INPUT = (3, 32, 32)

# The paper's modified VGG16: 13 conv layers (Simonyan config D) and a
# single FC layer. (cout, cin) per conv layer, CIFAR-sized input.
VGG16_CONV: List[Tuple[int, int]] = [
    (64, 3), (64, 64),
    (128, 64), (128, 128),
    (256, 128), (256, 256), (256, 256),
    (512, 256), (512, 512), (512, 512),
    (512, 512), (512, 512), (512, 512),
]
# Feature-map spatial size entering each VGG16 conv layer.
VGG16_FMAP_CIFAR = [32, 32, 16, 16, 8, 8, 8, 4, 4, 4, 2, 2, 2]
VGG16_FMAP_IMAGENET = [224, 224, 112, 112, 56, 56, 56, 28, 28, 28, 14, 14, 14]


def conv_layer_names(arch=SMALLCNN_ARCH) -> List[str]:
    names = []
    i = 0
    for item in arch:
        if item == "M":
            continue
        names.append(f"conv{i}")
        i += 1
    return names


def init_params(rng: np.random.Generator, arch=SMALLCNN_ARCH,
                n_classes=SMALLCNN_CLASSES) -> Dict[str, np.ndarray]:
    """He-normal init. Params dict: conv{i}/w [Cout,Cin,3,3], conv{i}/b,
    fc/w [Cfeat, n_classes], fc/b."""
    params: Dict[str, np.ndarray] = {}
    i = 0
    last_c = None
    for item in arch:
        if item == "M":
            continue
        cout, cin = item
        fan_in = cin * 9
        params[f"conv{i}/w"] = (rng.standard_normal((cout, cin, 3, 3))
                                * np.sqrt(2.0 / fan_in)).astype(np.float32)
        params[f"conv{i}/b"] = np.zeros((cout,), np.float32)
        last_c = cout
        i += 1
    params["fc/w"] = (rng.standard_normal((last_c, n_classes))
                      * np.sqrt(1.0 / last_c)).astype(np.float32)
    params["fc/b"] = np.zeros((n_classes,), np.float32)
    return params


def _maxpool2(x):
    b, c, h, w = x.shape
    x = x.reshape(b, c, h // 2, 2, w // 2, 2)
    return jnp.max(x, axis=(3, 5))


def _conv_float(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=((1, 1), (1, 1)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def _conv_crossbar(x, w, scales, cfg: QuantConfig):
    """Conv through the OU crossbar Pallas kernel via im2col."""
    sx, sw = scales
    cout = w.shape[0]
    cols, (b, oh, ow) = ref.im2col(x, 3, 3, 1, 1)
    wmat = w.reshape(cout, -1).T
    out = ou_mvm(cols, wmat, sx, sw, cfg)
    return out.reshape(b, oh, ow, cout).transpose(0, 3, 1, 2)


def forward(params, x, mode: str = "float", scales=None,
            cfg: QuantConfig = MODEL_QUANT, arch=SMALLCNN_ARCH):
    """SmallCNN forward. ``scales``: {layer_name: (sx, sw)} for crossbar
    mode (static calibration, see ``calibrate_scales``)."""
    i = 0
    for item in arch:
        if item == "M":
            x = _maxpool2(x)
            continue
        w = params[f"conv{i}/w"]
        b = params[f"conv{i}/b"]
        if mode == "float":
            x = _conv_float(x, w)
        elif mode == "crossbar":
            x = _conv_crossbar(x, w, scales[f"conv{i}"], cfg)
        else:
            raise ValueError(mode)
        x = jax.nn.relu(x + b[None, :, None, None])
        i += 1
    x = jnp.mean(x, axis=(2, 3))                    # global average pool
    return x @ params["fc/w"] + params["fc/b"]


def calibrate_scales(params, x_batch, arch=SMALLCNN_ARCH,
                     cfg: QuantConfig = MODEL_QUANT):
    """Run a float forward on calibration data, record per-layer input
    max and weight max -> static (sx, sw) per conv layer."""
    scales = {}
    x = jnp.asarray(x_batch)
    i = 0
    for item in arch:
        if item == "M":
            x = _maxpool2(x)
            continue
        w = params[f"conv{i}/w"]
        b = params[f"conv{i}/b"]
        # im2col rows see the padded input, same max as x.
        sx = float(jnp.max(jnp.abs(x))) / cfg.x_max
        sw = float(jnp.max(jnp.abs(w))) / ((1 << (cfg.w_bits - 1)) - 1)
        scales[f"conv{i}"] = (max(sx, 1e-8), max(sw, 1e-8))
        x = jax.nn.relu(_conv_float(x, w) + b[None, :, None, None])
        i += 1
    return scales


def loss_fn(params, x, y, arch=SMALLCNN_ARCH):
    logits = forward(params, x, mode="float", arch=arch)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    return nll


def accuracy(params, x, y, mode="float", scales=None, arch=SMALLCNN_ARCH,
             cfg: QuantConfig = MODEL_QUANT):
    logits = forward(params, x, mode=mode, scales=scales, cfg=cfg, arch=arch)
    return float(jnp.mean(jnp.argmax(logits, axis=1) == y))
