"""SmallCNN training + pattern-pruning retraining (build-time only).

SGD with momentum, hand-rolled (no optax in this image). The pipeline
`train -> irregular prune + pattern project -> masked retrain` mirrors
the paper's §III-A loop at SmallCNN scale and produces the real pruned
weights that the Rust mapper consumes.
"""

from __future__ import annotations

import functools
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from . import dataset, model, pruning


def _adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


@functools.partial(jax.jit, static_argnames=("lr",))
def _adam_step(params, opt, x, y, lr=1e-3):
    """Hand-rolled Adam (no optax in this image)."""
    b1, b2, eps = 0.9, 0.999, 1e-8
    loss, grads = jax.value_and_grad(model.loss_fn)(params, x, y)
    t = opt["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    scale = lr * jnp.sqrt(1.0 - b2**t) / (1.0 - b1**t)
    new_params = jax.tree.map(
        lambda p, m_, v_: p - scale * m_ / (jnp.sqrt(v_) + eps), params, m, v)
    return new_params, {"m": m, "v": v, "t": t}, loss


@functools.partial(jax.jit, static_argnames=("lr",))
def _adam_step_masked(params, opt, masks, x, y, lr=1e-3):
    """Retraining step with the assigned pattern masks frozen."""
    new_params, new_opt, loss = _adam_step(params, opt, x, y, lr=lr)
    new_params = dict(new_params)
    for name, m in masks.items():
        new_params[f"{name}/w"] = new_params[f"{name}/w"] * m
    return new_params, new_opt, loss


def _batches(x, y, batch, rng):
    idx = rng.permutation(len(x))
    for i in range(0, len(x) - batch + 1, batch):
        sel = idx[i : i + batch]
        yield jnp.asarray(x[sel]), jnp.asarray(y[sel])


def train_pipeline(
    n_train: int = 4096,
    n_test: int = 1024,
    epochs: int = 6,
    retrain_epochs: int = 4,
    batch: int = 64,
    sparsity: float = 0.80,
    prune_rounds: int = 3,
    patterns_per_layer: List[int] = (4, 4, 6, 6, 6),
    seed: int = 0,
    log=print,
) -> Dict:
    """Full paper pipeline on SmallCNN: train, then iterate
    prune -> project -> masked retrain over `prune_rounds` increasing
    sparsity targets ("the procedures above are repeated until the
    accuracy meets our expectation", §III-A). Returns a result dict with
    params, masks, candidate patterns, stats and accuracies."""
    t0 = time.time()
    xtr, ytr = dataset.make_dataset(n_train, seed=seed)
    xte, yte = dataset.make_dataset(n_test, seed=seed + 1)
    rng = np.random.default_rng(seed + 2)

    params = model.init_params(np.random.default_rng(seed + 3))
    layer_names = model.conv_layer_names()
    opt = _adam_init(params)

    for ep in range(epochs):
        for xb, yb in _batches(xtr, ytr, batch, rng):
            params, opt, loss = _adam_step(params, opt, xb, yb)
        acc = model.accuracy(params, jnp.asarray(xte), yte)
        log(f"[train] epoch {ep} loss={float(loss):.4f} test_acc={acc:.4f}")
    dense_acc = model.accuracy(params, jnp.asarray(xte), yte)

    # ---- iterative prune + project + masked retrain (paper §III-A) ----
    targets = [
        sparsity * (r + 1) / prune_rounds for r in range(prune_rounds)
    ]
    proj_acc = dense_acc
    masks, cands = {}, {}
    for rnd, target in enumerate(targets):
        params = {k: np.asarray(v) for k, v in params.items()}
        pruned, masks, cands = pruning.prune_network(
            params, layer_names, target, list(patterns_per_layer))
        proj_acc = model.accuracy(pruned, jnp.asarray(xte), yte)
        log(f"[prune r{rnd}] target={target:.2f} projected acc={proj_acc:.4f}")

        params = {k: jnp.asarray(v) for k, v in pruned.items()}
        jmasks = {k: jnp.asarray(v) for k, v in masks.items()}
        opt = _adam_init(params)
        for ep in range(retrain_epochs):
            for xb, yb in _batches(xtr, ytr, batch, rng):
                params, opt, loss = _adam_step_masked(
                    params, opt, jmasks, xb, yb)
            acc = model.accuracy(params, jnp.asarray(xte), yte)
            log(f"[retrain r{rnd}] epoch {ep} loss={float(loss):.4f} "
                f"test_acc={acc:.4f}")
    final_acc = model.accuracy(params, jnp.asarray(xte), yte)

    params = {k: np.asarray(v) for k, v in params.items()}
    stats = pruning.network_stats(params, layer_names)
    log(f"[stats] sparsity={stats['sparsity']:.4f} "
        f"patterns={stats['patterns_per_layer']} "
        f"all_zero_ratio={stats['all_zero_kernel_ratio']:.4f}")
    log(f"[done] dense={dense_acc:.4f} projected={proj_acc:.4f} "
        f"retrained={final_acc:.4f} ({time.time()-t0:.1f}s)")

    return {
        "params": params,
        "masks": masks,
        "candidates": cands,
        "stats": stats,
        "dense_acc": dense_acc,
        "projected_acc": proj_acc,
        "final_acc": final_acc,
        "test_x": xte,
        "test_y": yte,
    }
