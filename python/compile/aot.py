"""AOT build: train + prune SmallCNN, export weights/golden data, lower
inference graphs to HLO text for the Rust runtime.

Python runs ONLY here (``make artifacts``); the Rust binary is
self-contained afterwards.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, trainer, weights_io
from .kernels.ou_mvm import ou_mvm


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default printer elides big constants as
    # "{...}", which the rust-side HLO text parser would silently read
    # as zeros — the baked SmallCNN weights must survive the round-trip.
    return comp.as_hlo_text(print_large_constants=True)


def export_smallcnn_hlo(params, scales, batch: int, out_path: str) -> None:
    """Lower the crossbar-mode SmallCNN forward (weights baked as
    constants) for a fixed batch size."""
    jparams = {k: jnp.asarray(v) for k, v in params.items()}

    def infer(x):
        return (model.forward(jparams, x, mode="crossbar", scales=scales),)

    spec = jax.ShapeDtypeStruct((batch,) + model.SMALLCNN_INPUT, jnp.float32)
    lowered = jax.jit(infer).lower(spec)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    print(f"[aot] wrote {out_path} ({len(text)} chars)")


def export_ou_mvm_hlo(b: int, r: int, c: int, out_path: str) -> None:
    """Lower the standalone OU-MVM kernel (x, w, sx, sw all parameters)."""

    def mvm(x, w, sx, sw):
        return (ou_mvm(x, w, sx, sw, cfg=model.MODEL_QUANT),)

    lowered = jax.jit(mvm).lower(
        jax.ShapeDtypeStruct((b, r), jnp.float32),
        jax.ShapeDtypeStruct((r, c), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    print(f"[aot] wrote {out_path} ({len(text)} chars)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--retrain-epochs", type=int, default=4)
    ap.add_argument("--n-train", type=int, default=4096)
    ap.add_argument("--sparsity", type=float, default=0.80)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    res = trainer.train_pipeline(
        n_train=args.n_train,
        epochs=args.epochs,
        retrain_epochs=args.retrain_epochs,
        sparsity=args.sparsity,
    )
    params = res["params"]
    layer_names = model.conv_layer_names()

    # Static per-layer calibration scales from a training-distribution batch.
    from . import dataset
    xcal, _ = dataset.make_dataset(256, seed=7)
    scales = model.calibrate_scales(params, xcal)

    xte, yte = res["test_x"], res["test_y"]
    float_acc = model.accuracy(params, jnp.asarray(xte[:512]), yte[:512],
                               mode="float")
    xbar_acc = model.accuracy(params, jnp.asarray(xte[:512]), yte[:512],
                              mode="crossbar", scales=scales)
    print(f"[aot] retrained float acc={float_acc:.4f} "
          f"crossbar acc={xbar_acc:.4f}")

    # ---- weights + test data + golden logits (RPAT1 container) ----
    weights_io.save_tensors(
        os.path.join(args.out_dir, "smallcnn_weights.bin"),
        {k: np.asarray(v) for k, v in params.items()},
    )
    n_golden = 16
    golden = np.asarray(model.forward(
        {k: jnp.asarray(v) for k, v in params.items()},
        jnp.asarray(xte[:n_golden]), mode="crossbar", scales=scales))
    weights_io.save_tensors(
        os.path.join(args.out_dir, "test_data.bin"),
        {
            "test_x": xte[:256],
            "test_y": yte[:256].astype(np.int32),
            "golden_x": xte[:n_golden],
            "golden_logits": golden.astype(np.float32),
        },
    )

    # ---- metadata JSON (read by rust util::json) ----
    meta = {
        "arch": [list(a) if a != "M" else "M" for a in model.SMALLCNN_ARCH],
        "n_classes": model.SMALLCNN_CLASSES,
        "input_shape": list(model.SMALLCNN_INPUT),
        "layer_names": layer_names,
        "scales": {k: [float(v[0]), float(v[1])] for k, v in scales.items()},
        "candidates": {k: [int(p) for p in v]
                       for k, v in res["candidates"].items()},
        "stats": {
            "sparsity": res["stats"]["sparsity"],
            "patterns_per_layer": res["stats"]["patterns_per_layer"],
            "total_patterns": res["stats"]["total_patterns"],
            "all_zero_kernel_ratio": res["stats"]["all_zero_kernel_ratio"],
        },
        "accuracy": {
            "dense": float(res["dense_acc"]),
            "projected": float(res["projected_acc"]),
            "retrained_float": float(float_acc),
            "crossbar": float(xbar_acc),
        },
        "quant": {
            "x_bits": model.MODEL_QUANT.x_bits,
            "w_bits": model.MODEL_QUANT.w_bits,
            "cell_bits": model.MODEL_QUANT.cell_bits,
            "adc_bits": model.MODEL_QUANT.adc_bits,
            "ou_rows": model.MODEL_QUANT.ou_rows,
            "ou_cols": model.MODEL_QUANT.ou_cols,
        },
        "vgg16_conv": [list(s) for s in model.VGG16_CONV],
        "vgg16_fmap_cifar": model.VGG16_FMAP_CIFAR,
        "vgg16_fmap_imagenet": model.VGG16_FMAP_IMAGENET,
    }
    with open(os.path.join(args.out_dir, "smallcnn_meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print(f"[aot] wrote smallcnn_meta.json")

    # ---- HLO artifacts ----
    export_smallcnn_hlo(params, scales, 1,
                        os.path.join(args.out_dir, "smallcnn_b1.hlo.txt"))
    export_smallcnn_hlo(params, scales, 8,
                        os.path.join(args.out_dir, "smallcnn_b8.hlo.txt"))
    export_ou_mvm_hlo(64, 288, 64,
                      os.path.join(args.out_dir, "ou_mvm_b64_r288_c64.hlo.txt"))
    print("[aot] done")


if __name__ == "__main__":
    main()
