"""Synthetic 10-class image dataset (CIFAR stand-in, DESIGN.md §3).

Deterministic, procedurally generated 32x32x3 images. Class k is a
Gabor-like oriented grating (angle k*18 deg, class-specific spatial
frequency) with a class-specific colour tint, plus per-sample phase
jitter and pixel noise — separable enough to train a SmallCNN to high
accuracy in a few hundred steps, hard enough that accuracy is not 100%
at high noise.
"""

from __future__ import annotations

import numpy as np

N_CLASSES = 10


def make_dataset(n: int, seed: int = 0, noise: float = 0.35, size: int = 32):
    """Returns (x [n,3,size,size] float32 in ~[-1,1], y [n] int32)."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, N_CLASSES, size=n).astype(np.int32)
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32) / size

    xs = np.empty((n, 3, size, size), np.float32)
    tints = np.stack([
        0.5 + 0.5 * np.cos(2 * np.pi * (np.arange(N_CLASSES) / N_CLASSES + o))
        for o in (0.0, 1 / 3, 2 / 3)
    ], axis=1).astype(np.float32)  # [C, 3]

    for i in range(n):
        k = int(y[i])
        theta = np.pi * k / N_CLASSES
        freq = 3.0 + 2.0 * (k % 3)
        phase = rng.uniform(0, 2 * np.pi)
        grating = np.sin(2 * np.pi * freq *
                         (xx * np.cos(theta) + yy * np.sin(theta)) + phase)
        img = grating[None, :, :] * tints[k][:, None, None]
        img = img + noise * rng.standard_normal((3, size, size))
        xs[i] = img.astype(np.float32)
    return xs, y
