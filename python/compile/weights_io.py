"""Binary tensor container shared with rust `nn::tensor_io` (format RPAT1).

Layout (all little-endian):

    magic   b"RPAT1\\0"          (6 bytes)
    version u16                  (currently 1)
    count   u32                  number of tensors
    per tensor:
      name_len u16, name utf-8 bytes
      dtype    u8   (0 = f32, 1 = i32, 2 = u8)
      ndim     u8
      dims     u32 * ndim
      nbytes   u64
      data     raw bytes
"""

from __future__ import annotations

import struct
from typing import Dict

import numpy as np

MAGIC = b"RPAT1\x00"
VERSION = 1
_DTYPES = {0: np.float32, 1: np.int32, 2: np.uint8}
_DTYPE_IDS = {np.dtype(np.float32): 0, np.dtype(np.int32): 1,
              np.dtype(np.uint8): 2}


def save_tensors(path: str, tensors: Dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<HI", VERSION, len(tensors)))
        for name, arr in tensors.items():
            # note: np.ascontiguousarray would promote 0-d to 1-d;
            # np.asarray + tobytes (always C-order) preserves shape.
            arr = np.asarray(arr)
            if arr.dtype not in _DTYPE_IDS:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPE_IDS[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            data = arr.tobytes()
            f.write(struct.pack("<Q", len(data)))
            f.write(data)


def load_tensors(path: str) -> Dict[str, np.ndarray]:
    with open(path, "rb") as f:
        blob = f.read()
    if blob[:6] != MAGIC:
        raise ValueError("bad magic")
    (version, count) = struct.unpack_from("<HI", blob, 6)
    if version != VERSION:
        raise ValueError(f"unsupported version {version}")
    off = 12
    out: Dict[str, np.ndarray] = {}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<H", blob, off); off += 2
        name = blob[off : off + nlen].decode("utf-8"); off += nlen
        dtype_id, ndim = struct.unpack_from("<BB", blob, off); off += 2
        dims = struct.unpack_from(f"<{ndim}I", blob, off); off += 4 * ndim
        (nbytes,) = struct.unpack_from("<Q", blob, off); off += 8
        n_elem = int(np.prod(dims)) if ndim else 1
        arr = np.frombuffer(blob, dtype=_DTYPES[dtype_id], count=n_elem,
                            offset=off)
        arr = np.array(arr).reshape(dims)
        out[name] = arr
        off += nbytes
    return out
