"""Pattern pruning pipeline (paper §III-A).

Implements the ADMM-flavoured pattern compression of Wang et al. [11] as
used by the paper:

1. start from an irregularly (magnitude-) pruned network;
2. compute the PDF of kernel patterns per layer;
3. pick the top-N patterns per layer as candidates (N is the per-layer
   knob — Table II uses 2..12);
4. project every kernel onto its nearest candidate pattern
   (element-wise multiply with the pattern mask);
5. retrain with masks frozen to regain accuracy;
6. repeat until accuracy converges.

A *pattern* is a 9-bit mask over the 3x3 kernel positions, bit ``i`` =
position ``(i // 3, i % 3)`` — identical encoding to rust
``pruning::Pattern``.
"""

from __future__ import annotations

from collections import Counter
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def kernel_pattern(k: np.ndarray) -> int:
    """9-bit pattern id of a 3x3 kernel (bit i = position i nonzero)."""
    flat = k.reshape(9)
    pid = 0
    for i in range(9):
        if flat[i] != 0.0:
            pid |= 1 << i
    return pid


def pattern_mask(pid: int) -> np.ndarray:
    """Pattern id -> float 3x3 mask."""
    m = np.zeros(9, np.float32)
    for i in range(9):
        if pid >> i & 1:
            m[i] = 1.0
    return m.reshape(3, 3)


def pattern_size(pid: int) -> int:
    return bin(pid).count("1")


def layer_patterns(w: np.ndarray) -> Counter:
    """PDF (counts) of patterns over all [Cout, Cin] kernels of a layer."""
    cout, cin = w.shape[:2]
    c: Counter = Counter()
    for o in range(cout):
        for i in range(cin):
            c[kernel_pattern(w[o, i])] += 1
    return c


def magnitude_prune(w: np.ndarray, sparsity: float) -> np.ndarray:
    """Irregular magnitude pruning of a conv weight tensor [Cout,Cin,3,3]."""
    flat = np.abs(w).reshape(-1)
    k = int(np.ceil(sparsity * flat.size))
    if k <= 0:
        return w.copy()
    thresh = np.partition(flat, k - 1)[k - 1]
    out = w.copy()
    out[np.abs(out) <= thresh] = 0.0
    return out


def select_candidates(counts: Counter, n: int,
                      keep_all_zero: bool = True) -> List[int]:
    """Top-n patterns by probability (paper: PDF-based selection).

    The all-zero pattern (id 0), when present, is always kept: pruned
    kernels must stay prunable (they are *deleted* from the crossbar).
    """
    ranked = [p for p, _ in counts.most_common()]
    cands = ranked[:n]
    if keep_all_zero and 0 in counts and 0 not in cands:
        cands = cands[: n - 1] + [0]
    return cands


def _hamming(a: int, b: int) -> int:
    return bin(a ^ b).count("1")


def project_kernel(k: np.ndarray, candidates: List[int],
                   distance: str = "magnitude") -> Tuple[np.ndarray, int]:
    """Project one 3x3 kernel onto its best candidate pattern.

    ``magnitude``: keep the candidate retaining the largest L2 energy
    (ties -> smaller pattern). ``hamming``: nearest mask by hamming
    distance, as mentioned in the paper.
    """
    own = kernel_pattern(k)
    best, best_key = None, None
    for pid in candidates:
        if distance == "magnitude":
            m = pattern_mask(pid)
            kept = float(np.sum((k * m) ** 2))
            key = (-kept, pattern_size(pid))
        elif distance == "hamming":
            key = (_hamming(own, pid), pattern_size(pid))
        else:
            raise ValueError(distance)
        if best_key is None or key < best_key:
            best, best_key = pid, key
    return k * pattern_mask(best), best


def project_layer(w: np.ndarray, candidates: List[int],
                  distance: str = "magnitude"):
    """Project all kernels of a layer. Returns (projected_w, assigned)
    where ``assigned[cout, cin]`` is the candidate pattern id chosen for
    each kernel (the pattern the mapper will group by)."""
    out = np.empty_like(w)
    cout, cin = w.shape[:2]
    assigned = np.zeros((cout, cin), np.int32)
    for o in range(cout):
        for i in range(cin):
            out[o, i], assigned[o, i] = project_kernel(
                w[o, i], candidates, distance)
    return out, assigned


def prune_network(params: Dict[str, np.ndarray], layer_names: List[str],
                  sparsity: float, patterns_per_layer: List[int],
                  distance: str = "magnitude"):
    """Irregular prune + pattern projection over all conv layers.

    Returns (new_params, masks, per_layer_candidates).
    ``masks[name]`` is the float mask to freeze during retraining — the
    *assigned candidate pattern* per kernel (paper semantics: retraining
    may regrow any weight inside the kernel's pattern).
    """
    new = dict(params)
    masks: Dict[str, np.ndarray] = {}
    cands: Dict[str, List[int]] = {}
    for li, name in enumerate(layer_names):
        w = params[f"{name}/w"]
        wp = magnitude_prune(w, sparsity)
        counts = layer_patterns(wp)
        cand = select_candidates(counts, patterns_per_layer[li])
        wproj, assigned = project_layer(wp, cand, distance)
        new[f"{name}/w"] = wproj
        cout, cin = w.shape[:2]
        mask = np.zeros_like(w)
        for o in range(cout):
            for i in range(cin):
                mask[o, i] = pattern_mask(int(assigned[o, i]))
        masks[name] = mask.astype(np.float32)
        cands[name] = cand
    return new, masks, cands


def apply_masks(params, masks):
    """Re-impose pattern masks (after an unconstrained gradient step)."""
    out = dict(params)
    for name, m in masks.items():
        out[f"{name}/w"] = out[f"{name}/w"] * m
    return out


def network_stats(params: Dict[str, np.ndarray], layer_names: List[str]):
    """Table-II-style statistics: overall conv sparsity, per-layer pattern
    counts, total patterns, all-zero kernel ratio."""
    total, zeros = 0, 0
    per_layer_patterns: List[int] = []
    all_kernels, zero_kernels = 0, 0
    for name in layer_names:
        w = np.asarray(params[f"{name}/w"])
        total += w.size
        zeros += int(np.sum(w == 0.0))
        counts = layer_patterns(w)
        per_layer_patterns.append(len(counts))
        for pid, c in counts.items():
            all_kernels += c
            if pid == 0:
                zero_kernels += c
    return {
        "sparsity": zeros / max(total, 1),
        "patterns_per_layer": per_layer_patterns,
        "total_patterns": int(sum(per_layer_patterns)),
        "all_zero_kernel_ratio": zero_kernels / max(all_kernels, 1),
    }
