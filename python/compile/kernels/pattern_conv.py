"""L1 Pallas kernel: pattern-block sparse convolution.

Executes the paper's *mapped* compute: after kernel reordering, each
(input-channel, pattern) group is a dense ``pattern_size × n_kernels``
block on the crossbar.  The kernel walks pattern blocks on the grid;
each step gathers the im2col rows selected by the pattern (the Input
Preprocessing Unit), multiplies by the compressed block weights, and
scatters into output channels via a one-hot matmul (the Output Indexing
Unit).  Scatter-as-matmul keeps the whole step on the MXU.

Blocks are padded to a uniform ``(p_max, k_max)`` so shapes stay static;
padding rows/cols carry zero weights and are exact no-ops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def pack_blocks(blocks, p_max=None, k_max=None):
    """Pack a list of pattern-block dicts into padded dense arrays.

    Each block dict has ``rows`` [P], ``out_idx`` [K], ``w`` [P, K]
    (see ``ref.pattern_conv_ref``).  Returns
    ``(rows, out_idx, w)`` with shapes ``[NB, p_max]``, ``[NB, k_max]``,
    ``[NB, p_max, k_max]``.  Padded entries index row/channel 0 but have
    zero weight.
    """
    nb = len(blocks)
    p_max = p_max or max(len(b["rows"]) for b in blocks)
    k_max = k_max or max(len(b["out_idx"]) for b in blocks)
    rows = np.zeros((nb, p_max), np.int32)
    oidx = np.zeros((nb, k_max), np.int32)
    w = np.zeros((nb, p_max, k_max), np.float32)
    for i, b in enumerate(blocks):
        p, k = len(b["rows"]), len(b["out_idx"])
        assert p <= p_max and k <= k_max
        rows[i, :p] = b["rows"]
        oidx[i, :k] = b["out_idx"]
        w[i, :p, :k] = b["w"]
    return jnp.asarray(rows), jnp.asarray(oidx), jnp.asarray(w)


def _pattern_conv_kernel(cols_ref, rows_ref, oidx_ref, w_ref, o_ref, *,
                         cout: int):
    """One pattern block: gather rows -> dense matmul -> one-hot scatter."""
    blk = pl.program_id(0)

    @pl.when(blk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    cols = cols_ref[...]                    # [N, R]
    rows = rows_ref[0]                      # [p_max]
    oidx = oidx_ref[0]                      # [k_max]
    w = w_ref[0]                            # [p_max, k_max]

    # Input Preprocessing Unit: select the activations the pattern needs.
    gathered = jnp.take(cols, rows, axis=1)         # [N, p_max]
    contrib = gathered @ w                          # [N, k_max]
    # Output Indexing Unit: scatter to out channels (one-hot matmul).
    onehot = (oidx[:, None] == jnp.arange(cout)[None, :]).astype(jnp.float32)
    # Padded kernels have zero weight columns, so contrib[:, pad] == 0 and
    # double-scatter to channel 0 is harmless.
    o_ref[...] += contrib @ onehot                  # [N, cout]


@functools.partial(jax.jit, static_argnames=("cout",))
def pattern_conv_cols(cols, rows, oidx, w, cout: int):
    """Pattern-block sparse matmul over an im2col matrix.

    Args:
      cols: ``[N, R]`` im2col patch matrix.
      rows/oidx/w: packed blocks from :func:`pack_blocks`.
      cout: number of output channels.
    Returns ``[N, cout]``.
    """
    nb = rows.shape[0]
    n, r = cols.shape
    p_max, k_max = w.shape[1], w.shape[2]
    kernel = functools.partial(_pattern_conv_kernel, cout=cout)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((n, r), lambda b: (0, 0)),
            pl.BlockSpec((1, p_max), lambda b: (b, 0)),
            pl.BlockSpec((1, k_max), lambda b: (b, 0)),
            pl.BlockSpec((1, p_max, k_max), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((n, cout), lambda b: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, cout), jnp.float32),
        interpret=True,
    )(cols, rows, oidx, w)


def pattern_conv(x, blocks, cout: int, pad=1, stride=1):
    """NCHW pattern-block sparse convolution (wrapper over the kernel)."""
    from . import ref  # local import to avoid cycle

    cols, (b, oh, ow) = ref.im2col(x, 3, 3, pad, stride)
    rows, oidx, w = pack_blocks(blocks)
    out = pattern_conv_cols(cols.astype(jnp.float32), rows, oidx, w, cout)
    return out.reshape(b, oh, ow, cout).transpose(0, 3, 1, 2)
