"""L1 Pallas kernel: OU-granular quantized crossbar matrix multiply.

This is the compute hot-spot of the whole stack: every convolution in the
L2 model lowers to im2col + this kernel.  It simulates the analog RRAM
crossbar executing one Operation Unit (``ou_rows`` wordlines ×
``ou_cols`` bitlines) per step, with DAC input quantization, 4-bit cell
bit-slicing of offset-encoded weights, per-OU-slice ADC quantization,
shift-add recombination, and digital offset correction — exactly the
semantics of ``ref.ou_mvm_ref``.

TPU mapping (DESIGN.md §Hardware-Adaptation): one grid step owns a
``block_b × block_c`` output tile resident in VMEM; the fori_loop over
row groups is the HBM→VMEM OU schedule the paper implements with its
crossbar controller; the per-slice ``xr @ nib`` matmuls are the MXU work.
``interpret=True`` is mandatory on CPU (Mosaic custom-calls cannot run
on the CPU PJRT plugin).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import quant
from .quant import QuantConfig


def _ou_mvm_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, *, cfg: QuantConfig,
                   n_groups: int):
    """One (block_b, block_c) output tile; reduces over all row groups."""
    x = x_ref[...]                       # [TB, R]
    w = w_ref[...]                       # [R, TC]
    sx = sx_ref[0, 0]
    sw = sw_ref[0, 0]

    tb = x.shape[0]
    tc = w.shape[1]

    # DAC input quantization (signed, symmetric).
    xq = jnp.clip(jnp.round(x / sx), -cfg.x_max, cfg.x_max)
    # Weight quantization; cells store differential (G+/G-) nibble pairs,
    # i.e. slice s carries sign(wq) * nibble_s(|wq|).
    w_max = (1 << (cfg.w_bits - 1)) - 1
    wq = jnp.clip(jnp.round(w / sw), -w_max, w_max).astype(jnp.int32)
    wsign = jnp.sign(wq)
    wmag = jnp.abs(wq)

    lsb = cfg.adc_lsb()

    def group_body(g, acc):
        # One OU row-group: ou_rows wordlines activated at once.
        xr = jax.lax.dynamic_slice(xq, (0, g * cfg.ou_rows), (tb, cfg.ou_rows))
        sr = jax.lax.dynamic_slice(wsign, (g * cfg.ou_rows, 0),
                                   (cfg.ou_rows, tc))
        mr = jax.lax.dynamic_slice(wmag, (g * cfg.ou_rows, 0),
                                   (cfg.ou_rows, tc))
        gacc = jnp.zeros((tb, tc), jnp.float32)
        for s in range(cfg.n_slices):    # static: one 4-bit cell slice each
            nib = (sr * ((mr >> (s * cfg.cell_bits)) & cfg.cell_max)) \
                .astype(jnp.float32)
            partial = xr @ nib           # analog bitline sums (MXU work)
            code = jnp.clip(jnp.round(partial / lsb), -cfg.adc_levels,
                            cfg.adc_levels)
            gacc = gacc + float(1 << (cfg.cell_bits * s)) * (code * lsb)
        return acc + gacc

    acc = jax.lax.fori_loop(0, n_groups, group_body,
                            jnp.zeros((tb, tc), jnp.float32))
    o_ref[...] = acc * (sx * sw)


def _pad_to(a, multiple, axis):
    pad = (-a.shape[axis]) % multiple
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("cfg", "block_b", "block_c"))
def ou_mvm(x, w, sx, sw, cfg: QuantConfig = quant.DEFAULT,
           block_b: int = 64, block_c: int = 64):
    """OU-granular crossbar matmul: ``[B,R] @ [R,C] -> [B,C]``.

    ``sx``/``sw`` are scalar (or 0-d array) calibration scales; they are
    traced (not baked), so one compiled artifact serves any calibration.
    """
    B, R = x.shape
    Rw, C = w.shape
    assert R == Rw, (x.shape, w.shape)

    xp = _pad_to(x.astype(jnp.float32), cfg.ou_rows, axis=1)
    wp = _pad_to(w.astype(jnp.float32), cfg.ou_rows, axis=0)
    # Zero-padded rows are exact no-ops: xq=0 there, so both the analog
    # term and the offset correction vanish.
    xp = _pad_to(xp, block_b, axis=0)
    wp = _pad_to(wp, block_c, axis=1)
    Bp, Rp = xp.shape
    Cp = wp.shape[1]
    n_groups = Rp // cfg.ou_rows

    sx_arr = jnp.asarray(sx, jnp.float32).reshape(1, 1)
    sw_arr = jnp.asarray(sw, jnp.float32).reshape(1, 1)

    kernel = functools.partial(_ou_mvm_kernel, cfg=cfg, n_groups=n_groups)
    out = pl.pallas_call(
        kernel,
        grid=(Bp // block_b, Cp // block_c),
        in_specs=[
            pl.BlockSpec((block_b, Rp), lambda i, j: (i, 0)),
            pl.BlockSpec((Rp, block_c), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Bp, Cp), jnp.float32),
        interpret=True,
    )(xp, wp, sx_arr, sw_arr)
    return out[:B, :C]
