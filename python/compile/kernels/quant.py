"""Shared quantization model for the RRAM crossbar functional simulation.

This module defines the *numerical contract* between every layer of the
stack: the Pallas kernel (`ou_mvm.py`), the pure-jnp oracle (`ref.py`),
and the Rust fixed-point simulator all implement the same arithmetic.

Model (paper Table I + §II-A):

- Inputs pass through a DAC with ``x_bits`` (default 4) resolution:
  symmetric signed quantization to ``[-(2^(b-1)-1), 2^(b-1)-1]``.
- Weights are quantized to ``w_bits`` (default 8) symmetric signed
  integers and stored *differentially* (PRIME-style G+/G- cell pairs,
  subtracted in analog on the bitline) across ``w_bits / cell_bits``
  cell-pair slices of ``cell_bits`` (default 4) each — the paper's
  "4 bits per cell" bit-slicing.  Differential pairs mean a zero weight
  contributes an exact analog zero (no offset current through the ADC).
- An Operation Unit activates ``ou_rows`` wordlines at once; the analog
  partial sum of one OU row-group and one cell slice is digitized by an
  ``adc_bits`` ADC.  The ADC step (LSB) is fixed at design time from the
  worst-case OU partial sum, so quantization is static and AOT-friendly.
- Slice partial sums are recombined by shift-add and rescaled by the
  weight and input scales.

With ``adc_bits`` large the model is exact (equals the float matmul up to
input/weight quantization); with the paper's 8-bit ADC it reproduces the
partial-sum truncation error real OU-based accelerators see.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Static quantization parameters (mirror of rust `config::QuantConfig`)."""

    x_bits: int = 4          # DAC resolution (paper Table I: 4 bits)
    w_bits: int = 8          # weight precision stored across cells
    cell_bits: int = 4       # bits per RRAM cell (paper Table I: 4)
    adc_bits: int = 8        # ADC resolution (paper Table I: 8 bits)
    ou_rows: int = 9         # wordlines activated per cycle (paper: 9)
    ou_cols: int = 8         # bitlines activated per cycle (paper: 8)

    @property
    def n_slices(self) -> int:
        assert self.w_bits % self.cell_bits == 0
        return self.w_bits // self.cell_bits

    @property
    def x_max(self) -> int:
        return (1 << (self.x_bits - 1)) - 1  # 7 for 4-bit DAC

    @property
    def cell_max(self) -> int:
        return (1 << self.cell_bits) - 1  # 15 for 4-bit cells

    @property
    def cells_per_weight(self) -> int:
        return 2 * self.n_slices  # differential pair per slice

    @property
    def adc_levels(self) -> int:
        return (1 << (self.adc_bits - 1)) - 1  # symmetric levels

    def adc_lsb(self) -> float:
        """Static ADC step sized for the worst-case OU/slice partial sum.

        One OU slice partial sum is ``sum_{r<ou_rows} cell(u) * xq`` with
        ``cell in [0, cell_max]`` and ``xq in [-x_max, x_max]``, so the
        magnitude is bounded by ``ou_rows * cell_max * x_max``.
        """
        max_abs = float(self.ou_rows * self.cell_max * self.x_max)
        lsb = max_abs / float(self.adc_levels)
        return max(lsb, 1.0)


DEFAULT = QuantConfig()


def x_scale(x, cfg: QuantConfig = DEFAULT):
    """Per-tensor symmetric input scale (calibration helper)."""
    m = jnp.max(jnp.abs(x))
    return jnp.where(m > 0, m / cfg.x_max, 1.0)


def w_scale(w, cfg: QuantConfig = DEFAULT):
    """Per-tensor symmetric weight scale (calibration helper)."""
    m = jnp.max(jnp.abs(w))
    w_max = (1 << (cfg.w_bits - 1)) - 1
    return jnp.where(m > 0, m / w_max, 1.0)


def quantize_x(x, sx, cfg: QuantConfig = DEFAULT):
    """DAC input quantization: float -> signed integers in [-x_max, x_max]."""
    q = jnp.round(x / sx)
    return jnp.clip(q, -cfg.x_max, cfg.x_max)


def quantize_w(w, sw, cfg: QuantConfig = DEFAULT):
    """Weight quantization: float -> signed integers, symmetric w_bits."""
    w_max = (1 << (cfg.w_bits - 1)) - 1
    q = jnp.round(w / sw)
    return jnp.clip(q, -w_max, w_max)


def signed_cell_slices(wq, cfg: QuantConfig = DEFAULT):
    """Split signed quantized weights into differential cell slices.

    Each weight is stored as G+/G- cell pairs per slice; the bitline
    subtracts them in analog, so slice ``s`` contributes
    ``sign(wq) * nibble_s(|wq|)`` in ``[-cell_max, cell_max]``.
    Returns an array with a new leading axis of length ``n_slices``,
    LSB slice first.
    """
    wq = wq.astype(jnp.int32)
    sign = jnp.sign(wq)
    mag = jnp.abs(wq)
    slices = []
    for s in range(cfg.n_slices):
        nib = (mag >> (s * cfg.cell_bits)) & cfg.cell_max
        slices.append(sign * nib)
    return jnp.stack(slices, axis=0)


def adc_quantize(v, cfg: QuantConfig = DEFAULT):
    """Static symmetric ADC transfer function on a partial sum."""
    lsb = cfg.adc_lsb()
    code = jnp.clip(jnp.round(v / lsb), -cfg.adc_levels, cfg.adc_levels)
    return code * lsb
