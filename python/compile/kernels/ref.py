"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness anchors: slow, obviously-correct
implementations of the OU-granular crossbar MVM and the pattern-block
sparse convolution.  pytest (``python/tests/``) asserts the Pallas
kernels match these bit-for-bit (same float ops, same quantization).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import quant
from .quant import QuantConfig


def _pad_rows(a, multiple, axis=0):
    r = a.shape[axis]
    pad = (-r) % multiple
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def ou_mvm_ref(x, w, sx, sw, cfg: QuantConfig = quant.DEFAULT):
    """Reference OU-granular quantized crossbar matmul.

    Args:
      x: ``[B, R]`` float inputs (im2col rows).
      w: ``[R, C]`` float weights.
      sx, sw: scalar input/weight scales (static calibration values).
      cfg: quantization config.

    Returns ``[B, C]`` float outputs of the simulated analog compute.

    Semantics: inputs are DAC-quantized; weights are quantized and
    bit-sliced into differential (G+/G-) cell pairs; rows are processed
    ``ou_rows`` at a time; each (row-group, slice) partial sum passes
    through the ADC; slices recombine by shift-add; finally the result
    is rescaled to float.
    """
    B, R = x.shape
    Rw, C = w.shape
    assert R == Rw, (x.shape, w.shape)

    xq = quant.quantize_x(x, sx, cfg)              # [B, R] signed
    wq = quant.quantize_w(w, sw, cfg)              # [R, C] signed
    slices = quant.signed_cell_slices(wq, cfg)     # [S, R, C] signed nibbles

    xq = _pad_rows(xq, cfg.ou_rows, axis=1)
    slices = _pad_rows(slices, cfg.ou_rows, axis=1)
    Rp = xq.shape[1]
    G = Rp // cfg.ou_rows

    xg = xq.reshape(B, G, cfg.ou_rows)             # [B, G, r]
    sg = slices.reshape(cfg.n_slices, G, cfg.ou_rows, C)

    # Analog partial sums per (slice, group): [S, B, G, C]
    partial = jnp.einsum("bgr,sgrc->sbgc", xg.astype(jnp.float32),
                         sg.astype(jnp.float32))
    partial = quant.adc_quantize(partial, cfg)

    # Shift-add slice recombination: [B, G, C]
    shift = (1 << (cfg.cell_bits * np.arange(cfg.n_slices))).astype(np.float32)
    acc = jnp.einsum("s,sbgc->bgc", shift, partial)

    out = jnp.sum(acc, axis=1)                     # [B, C]
    return out * (sx * sw)


def mvm_float_ref(x, w):
    """Quantization-free oracle: plain matmul (ADC->inf bits limit)."""
    return x @ w


def im2col(x, kh=3, kw=3, pad=1, stride=1):
    """NCHW -> [B*OH*OW, Cin*kh*kw] patch matrix (row order: cin, kh, kw).

    The column order (cin-major, then kernel position) matches the
    paper's Fig. 1 weight unrolling and the rust `nn::im2col`.
    """
    b, cin, h, wd = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (wd + 2 * pad - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = xp[:, :, i : i + stride * oh : stride, j : j + stride * ow : stride]
            cols.append(patch.reshape(b, cin, oh * ow))
    # [kh*kw, B, Cin, OH*OW] -> [B, OH*OW, Cin, kh*kw]
    stacked = jnp.stack(cols, axis=0).transpose(1, 3, 2, 0)
    return stacked.reshape(b * oh * ow, cin * kh * kw), (b, oh, ow)


def conv2d_ref(x, w, pad=1, stride=1):
    """Dense conv oracle via im2col + float matmul.

    Args:
      x: ``[B, Cin, H, W]``; w: ``[Cout, Cin, KH, KW]``.
    Returns ``[B, Cout, OH, OW]``.
    """
    cout, cin, kh, kw = w.shape
    cols, (b, oh, ow) = im2col(x, kh, kw, pad, stride)
    wmat = w.reshape(cout, cin * kh * kw).T          # [Cin*KH*KW, Cout]
    out = cols @ wmat                                # [B*OH*OW, Cout]
    return out.reshape(b, oh, ow, cout).transpose(0, 3, 1, 2)


def conv2d_ou_ref(x, w, sx, sw, cfg: QuantConfig = quant.DEFAULT, pad=1, stride=1):
    """Conv through the simulated OU crossbar (reference path)."""
    cout, cin, kh, kw = w.shape
    cols, (b, oh, ow) = im2col(x, kh, kw, pad, stride)
    wmat = w.reshape(cout, cin * kh * kw).T
    out = ou_mvm_ref(cols, wmat, sx, sw, cfg)
    return out.reshape(b, oh, ow, cout).transpose(0, 3, 1, 2)


def pattern_conv_ref(x, blocks, cout, pad=1, stride=1):
    """Reference pattern-block sparse convolution.

    ``blocks`` is a list of dicts (one per pattern block, i.e. one
    (input-channel, pattern) group after kernel reordering):
      ``rows``: [P] int — rows of the im2col matrix (cin*9 + position).
      ``out_idx``: [K] int — output channel of each kernel in the block.
      ``w``: [P, K] float — compressed nonzero weights.

    Computes ``out[:, out_idx] += cols[:, rows] @ w`` per block — exactly
    what the mapped crossbar computes pattern-block by pattern-block,
    with the Output Indexing Unit doing the scatter.
    """
    cols, (b, oh, ow) = im2col(x, 3, 3, pad, stride)
    out = jnp.zeros((cols.shape[0], cout), dtype=cols.dtype)
    for blk in blocks:
        rows = jnp.asarray(blk["rows"], dtype=jnp.int32)
        oidx = jnp.asarray(blk["out_idx"], dtype=jnp.int32)
        wm = jnp.asarray(blk["w"])
        contrib = cols[:, rows] @ wm                  # [N, K]
        out = out.at[:, oidx].add(contrib)
    return out.reshape(b, oh, ow, cout).transpose(0, 3, 1, 2)
