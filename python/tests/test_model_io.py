"""L2 model shape/behaviour tests + weights_io round-trip."""

import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import dataset, model, weights_io


@pytest.fixture(scope="module")
def params():
    return model.init_params(np.random.default_rng(0))


class TestModel:
    def test_forward_shapes(self, params):
        x = jnp.zeros((4, 3, 32, 32), jnp.float32)
        out = model.forward(params, x, mode="float")
        assert out.shape == (4, 10)

    def test_crossbar_mode_close_to_float(self, params):
        rng = np.random.default_rng(1)
        x = rng.standard_normal((2, 3, 32, 32)).astype(np.float32) * 0.5
        scales = model.calibrate_scales(params, x)
        f = model.forward(params, jnp.asarray(x), mode="float")
        q = model.forward(params, jnp.asarray(x), mode="crossbar",
                          scales=scales)
        # 8-bit inputs / 8-bit weights / 8-bit ADC: same ballpark logits
        err = float(jnp.max(jnp.abs(f - q)) / (jnp.max(jnp.abs(f)) + 1e-9))
        assert err < 0.5

    def test_calibrate_scales_positive(self, params):
        x = np.random.default_rng(2).standard_normal((4, 3, 32, 32)) \
            .astype(np.float32)
        scales = model.calibrate_scales(params, x)
        assert set(scales) == set(model.conv_layer_names())
        for sx, sw in scales.values():
            assert sx > 0 and sw > 0

    def test_loss_decreases_one_step(self, params):
        import jax
        x, y = dataset.make_dataset(64, seed=3)
        g = jax.grad(model.loss_fn)(
            {k: jnp.asarray(v) for k, v in params.items()},
            jnp.asarray(x), jnp.asarray(y))
        l0 = model.loss_fn({k: jnp.asarray(v) for k, v in params.items()},
                           jnp.asarray(x), jnp.asarray(y))
        stepped = {k: jnp.asarray(v) - 0.05 * g[k] for k, v in params.items()}
        l1 = model.loss_fn(stepped, jnp.asarray(x), jnp.asarray(y))
        assert float(l1) < float(l0)

    def test_vgg16_inventory(self):
        assert len(model.VGG16_CONV) == 13
        assert len(model.VGG16_FMAP_CIFAR) == 13
        assert len(model.VGG16_FMAP_IMAGENET) == 13
        assert model.VGG16_CONV[0] == (64, 3)
        assert model.VGG16_CONV[-1] == (512, 512)


class TestDataset:
    def test_deterministic(self):
        x1, y1 = dataset.make_dataset(16, seed=5)
        x2, y2 = dataset.make_dataset(16, seed=5)
        assert np.array_equal(x1, x2) and np.array_equal(y1, y2)

    def test_shapes_and_range(self):
        x, y = dataset.make_dataset(8, seed=6)
        assert x.shape == (8, 3, 32, 32)
        assert y.shape == (8,)
        assert y.min() >= 0 and y.max() < dataset.N_CLASSES
        assert np.abs(x).max() < 5.0


class TestWeightsIO:
    def test_roundtrip(self):
        rng = np.random.default_rng(7)
        tensors = {
            "a/w": rng.standard_normal((3, 4, 3, 3)).astype(np.float32),
            "b": np.arange(10, dtype=np.int32),
            "c_bytes": rng.integers(0, 255, size=(5,)).astype(np.uint8),
            "scalar": np.float32(3.5).reshape(()),
        }
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "t.bin")
            weights_io.save_tensors(p, tensors)
            back = weights_io.load_tensors(p)
        assert set(back) == set(tensors)
        for k in tensors:
            assert back[k].dtype == tensors[k].dtype
            assert back[k].shape == tensors[k].shape
            assert np.array_equal(back[k], tensors[k])

    def test_bad_magic_rejected(self):
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "bad.bin")
            with open(p, "wb") as f:
                f.write(b"NOTRPAT000")
            with pytest.raises(ValueError):
                weights_io.load_tensors(p)

    def test_empty_container(self):
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "e.bin")
            weights_io.save_tensors(p, {})
            assert weights_io.load_tensors(p) == {}
