"""L1 kernel correctness: Pallas vs pure-jnp oracle (the CORE signal).

hypothesis sweeps shapes and quant configs; assert_allclose against
ref.py per the session contract.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import quant, ref
from compile.kernels.ou_mvm import ou_mvm
from compile.kernels.pattern_conv import pattern_conv, pack_blocks, \
    pattern_conv_cols


def _scales(x, w, cfg):
    sx = float(np.abs(x).max()) / cfg.x_max or 1.0
    sw = float(np.abs(w).max()) / ((1 << (cfg.w_bits - 1)) - 1) or 1.0
    return max(sx, 1e-8), max(sw, 1e-8)


def _rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


class TestOuMvmVsRef:
    @pytest.mark.parametrize("b,r,c", [
        (1, 9, 1), (2, 27, 16), (10, 27, 16), (7, 30, 5),
        (64, 288, 64), (3, 8, 3), (5, 100, 33),
    ])
    def test_matches_ref_default_cfg(self, b, r, c):
        rng = np.random.default_rng(b * 1000 + r + c)
        x, w = _rand(rng, b, r), _rand(rng, r, c)
        sx, sw = _scales(x, w, quant.DEFAULT)
        got = ou_mvm(jnp.asarray(x), jnp.asarray(w), sx, sw)
        want = ref.ou_mvm_ref(jnp.asarray(x), jnp.asarray(w), sx, sw)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                        atol=1e-5)

    @pytest.mark.parametrize("cfg", [
        quant.QuantConfig(x_bits=8),
        quant.QuantConfig(adc_bits=6),
        quant.QuantConfig(adc_bits=16),
        quant.QuantConfig(ou_rows=4, ou_cols=4),
        quant.QuantConfig(ou_rows=16, ou_cols=16),
        quant.QuantConfig(w_bits=4, cell_bits=4),
        quant.QuantConfig(w_bits=16, cell_bits=4, adc_bits=12),
    ])
    def test_matches_ref_across_configs(self, cfg):
        rng = np.random.default_rng(42)
        x, w = _rand(rng, 6, 45), _rand(rng, 45, 12)
        sx, sw = _scales(x, w, cfg)
        got = ou_mvm(jnp.asarray(x), jnp.asarray(w), sx, sw, cfg)
        want = ref.ou_mvm_ref(jnp.asarray(x), jnp.asarray(w), sx, sw, cfg)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                        atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        b=st.integers(1, 20),
        r=st.integers(1, 64),
        c=st.integers(1, 24),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_hypothesis(self, b, r, c, seed):
        rng = np.random.default_rng(seed)
        x, w = _rand(rng, b, r), _rand(rng, r, c)
        sx, sw = _scales(x, w, quant.DEFAULT)
        got = ou_mvm(jnp.asarray(x), jnp.asarray(w), sx, sw,
                     block_b=16, block_c=16)
        want = ref.ou_mvm_ref(jnp.asarray(x), jnp.asarray(w), sx, sw)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                        atol=1e-5)

    def test_block_size_invariance(self):
        rng = np.random.default_rng(3)
        x, w = _rand(rng, 30, 54), _rand(rng, 54, 20)
        sx, sw = _scales(x, w, quant.DEFAULT)
        outs = [
            np.asarray(ou_mvm(jnp.asarray(x), jnp.asarray(w), sx, sw,
                              block_b=bb, block_c=bc))
            for bb, bc in [(8, 8), (16, 32), (64, 64)]
        ]
        for o in outs[1:]:
            assert_allclose(o, outs[0], rtol=1e-6, atol=1e-6)

    def test_high_adc_bits_approaches_float(self):
        """With a very fine ADC the only error left is input/weight quant."""
        rng = np.random.default_rng(5)
        x, w = _rand(rng, 16, 27), _rand(rng, 27, 8)
        cfg = quant.QuantConfig(x_bits=16, w_bits=16, cell_bits=4,
                                adc_bits=28)
        sx, sw = _scales(x, w, cfg)
        got = np.asarray(ou_mvm(jnp.asarray(x), jnp.asarray(w), sx, sw, cfg))
        want = x @ w
        assert_allclose(got, want, rtol=5e-3, atol=5e-3)

    def test_zero_inputs_give_zero(self):
        x = np.zeros((4, 18), np.float32)
        w = np.ones((18, 6), np.float32)
        got = np.asarray(ou_mvm(jnp.asarray(x), jnp.asarray(w), 1.0, 1.0))
        assert_allclose(got, np.zeros((4, 6)), atol=0)

    def test_zero_weights_give_zero(self):
        rng = np.random.default_rng(6)
        x = _rand(rng, 4, 18)
        w = np.zeros((18, 6), np.float32)
        got = np.asarray(ou_mvm(jnp.asarray(x), jnp.asarray(w), 1.0, 1.0))
        assert_allclose(got, np.zeros((4, 6)), atol=0)


class TestRefSelfConsistency:
    def test_adc_inf_equals_quantized_matmul(self):
        """ref with huge ADC == exact integer matmul of quantized values."""
        rng = np.random.default_rng(7)
        x, w = _rand(rng, 5, 36), _rand(rng, 36, 9)
        cfg = quant.QuantConfig(adc_bits=30)
        sx, sw = _scales(x, w, cfg)
        got = np.asarray(ref.ou_mvm_ref(jnp.asarray(x), jnp.asarray(w),
                                        sx, sw, cfg))
        xq = np.clip(np.round(x / sx), -cfg.x_max, cfg.x_max)
        wq = np.clip(np.round(w / sw), -127, 127)
        want = (xq @ wq) * sx * sw
        assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_conv2d_ref_matches_lax_conv(self):
        import jax
        rng = np.random.default_rng(8)
        x = _rand(rng, 2, 3, 8, 8)
        w = _rand(rng, 5, 3, 3, 3)
        want = jax.lax.conv_general_dilated(
            jnp.asarray(x), jnp.asarray(w), (1, 1), ((1, 1), (1, 1)),
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
        got = ref.conv2d_ref(jnp.asarray(x), jnp.asarray(w))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                        atol=1e-4)


def _random_blocks(rng, cin, cout, n_patterns=3):
    """Random pattern-block structure covering every out channel once per
    input channel (valid mapping of a dense kernel-reordered layer)."""
    blocks = []
    for ci in range(cin):
        perm = rng.permutation(cout)
        splits = np.array_split(perm, n_patterns)
        for ks in splits:
            if len(ks) == 0:
                continue
            psize = int(rng.integers(1, 10))
            pos = sorted(rng.choice(9, size=psize, replace=False).tolist())
            blocks.append({
                "rows": [ci * 9 + p for p in pos],
                "out_idx": ks.tolist(),
                "w": rng.standard_normal((psize, len(ks))).astype(np.float32),
            })
    return blocks


def _blocks_to_dense(blocks, cout, cin):
    wd = np.zeros((cout, cin, 3, 3), np.float32)
    for b in blocks:
        for j, oc in enumerate(b["out_idx"]):
            for i, r in enumerate(b["rows"]):
                ci, pos = r // 9, r % 9
                wd[oc, ci, pos // 3, pos % 3] = b["w"][i][j]
    return wd


class TestPatternConv:
    @pytest.mark.parametrize("cin,cout,hw", [(1, 4, 6), (2, 5, 8), (3, 8, 5)])
    def test_matches_ref(self, cin, cout, hw):
        rng = np.random.default_rng(cin * 100 + cout)
        x = _rand(rng, 2, cin, hw, hw)
        blocks = _random_blocks(rng, cin, cout)
        got = pattern_conv(jnp.asarray(x), blocks, cout)
        want = ref.pattern_conv_ref(jnp.asarray(x), blocks, cout)
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                        atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), cin=st.integers(1, 4),
           cout=st.integers(1, 10))
    def test_equals_dense_conv_hypothesis(self, seed, cin, cout):
        """Pattern-block compute == dense conv with the equivalent dense
        weights — the paper's functional-correctness claim for the
        reordered mapping."""
        rng = np.random.default_rng(seed)
        x = _rand(rng, 1, cin, 6, 6)
        blocks = _random_blocks(rng, cin, cout)
        wd = _blocks_to_dense(blocks, cout, cin)
        got = pattern_conv(jnp.asarray(x), blocks, cout)
        want = ref.conv2d_ref(jnp.asarray(x), jnp.asarray(wd))
        assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                        atol=1e-4)

    def test_pack_blocks_padding(self):
        rng = np.random.default_rng(11)
        blocks = [
            {"rows": [0, 1], "out_idx": [0], "w": np.ones((2, 1), np.float32)},
            {"rows": [3], "out_idx": [1, 2, 3],
             "w": np.ones((1, 3), np.float32)},
        ]
        rows, oidx, w = pack_blocks(blocks)
        assert rows.shape == (2, 2)
        assert oidx.shape == (2, 3)
        assert w.shape == (2, 2, 3)
        # padded weights must be exactly zero
        assert float(w[0, :, 1:].sum()) == 0.0
        assert float(w[1, 1:, :].sum()) == 0.0
