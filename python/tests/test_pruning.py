"""Pattern-pruning pipeline invariants (paper §III-A)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import pruning


class TestPatternBasics:
    def test_pattern_roundtrip(self):
        for pid in [0, 1, 0b101010101, 511, 0b100000000]:
            mask = pruning.pattern_mask(pid)
            k = mask * 3.14
            assert pruning.kernel_pattern(k) == pid

    def test_pattern_size(self):
        assert pruning.pattern_size(0) == 0
        assert pruning.pattern_size(511) == 9
        assert pruning.pattern_size(0b101) == 2

    @settings(max_examples=50, deadline=None)
    @given(pid=st.integers(0, 511))
    def test_mask_matches_bits(self, pid):
        m = pruning.pattern_mask(pid).reshape(9)
        for i in range(9):
            assert (m[i] == 1.0) == bool(pid >> i & 1)


class TestMagnitudePrune:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000),
           sparsity=st.floats(0.0, 0.95))
    def test_sparsity_reached(self, seed, sparsity):
        rng = np.random.default_rng(seed)
        w = rng.standard_normal((8, 4, 3, 3)).astype(np.float32)
        wp = pruning.magnitude_prune(w, sparsity)
        achieved = np.mean(wp == 0.0)
        assert achieved >= sparsity - 1e-9
        # no more than necessary beyond ties
        assert achieved <= sparsity + 0.05

    def test_keeps_largest(self):
        w = np.arange(1, 10, dtype=np.float32).reshape(1, 1, 3, 3)
        wp = pruning.magnitude_prune(w, 5 / 9)
        assert set(np.nonzero(wp.reshape(9))[0]) == {5, 6, 7, 8}

    def test_zero_sparsity_identity(self):
        w = np.random.default_rng(0).standard_normal((2, 2, 3, 3))
        assert np.array_equal(pruning.magnitude_prune(w, 0.0), w)


class TestCandidateSelection:
    def test_top_n_by_count(self):
        from collections import Counter
        counts = Counter({7: 100, 3: 50, 1: 10, 0: 5})
        assert pruning.select_candidates(counts, 2) == [7, 0]
        assert pruning.select_candidates(counts, 3) == [7, 3, 0]
        assert pruning.select_candidates(counts, 4) == [7, 3, 1, 0]

    def test_all_zero_always_kept_when_present(self):
        from collections import Counter
        counts = Counter({7: 100, 3: 50, 0: 1})
        cands = pruning.select_candidates(counts, 2)
        assert 0 in cands


class TestProjection:
    def test_projection_selects_subset(self):
        rng = np.random.default_rng(1)
        k = rng.standard_normal((3, 3)).astype(np.float32)
        out, pid = pruning.project_kernel(k, [0b111, 0b111000000])
        assert pruning.kernel_pattern(out) in (0b111, 0b111000000, 0)
        # projected kernel is k masked
        mask = pruning.pattern_mask(pid)
        assert np.array_equal(out, k * mask)

    def test_magnitude_projection_picks_max_energy(self):
        k = np.zeros((3, 3), np.float32)
        k[0, 0] = 10.0
        k[2, 2] = 1.0
        out, pid = pruning.project_kernel(k, [1, 1 << 8])  # pos 0 vs pos 8
        assert pid == 1
        assert out[0, 0] == 10.0 and out[2, 2] == 0.0

    def test_hamming_projection(self):
        k = np.ones((3, 3), np.float32)  # pattern 511
        _, pid = pruning.project_kernel(k, [0b111111110, 0b1], "hamming")
        assert pid == 0b111111110

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_layer_patterns_after_projection_within_candidates(self, seed):
        rng = np.random.default_rng(seed)
        w = rng.standard_normal((6, 3, 3, 3)).astype(np.float32)
        wp = pruning.magnitude_prune(w, 0.7)
        counts = pruning.layer_patterns(wp)
        cands = pruning.select_candidates(counts, 4)
        wproj, assigned = pruning.project_layer(wp, cands)
        # every assigned pattern is a candidate, and every projected
        # kernel's observed pattern is a SUBSET of its assigned pattern
        # (zeros inside the pattern stay zero until retraining regrows).
        cout, cin = wp.shape[:2]
        for o in range(cout):
            for i in range(cin):
                pid = int(assigned[o, i])
                assert pid in cands
                obs = pruning.kernel_pattern(wproj[o, i])
                assert obs & ~pid == 0


class TestPruneNetwork:
    def test_full_pipeline_stats(self):
        rng = np.random.default_rng(2)
        params = {
            "conv0/w": rng.standard_normal((8, 3, 3, 3)).astype(np.float32),
            "conv1/w": rng.standard_normal((16, 8, 3, 3)).astype(np.float32),
        }
        new, masks, cands = pruning.prune_network(
            params, ["conv0", "conv1"], 0.75, [4, 4])
        stats = pruning.network_stats(new, ["conv0", "conv1"])
        assert stats["sparsity"] >= 0.5
        # <=4 distinct patterns + possible all-zero per layer
        for n in stats["patterns_per_layer"]:
            assert n <= 5
        for name in ["conv0", "conv1"]:
            w = new[f"{name}/w"]
            # nonzeros always live inside the assigned pattern mask
            assert np.all((w != 0) <= (masks[name] != 0))

    def test_masks_freeze_zeros(self):
        rng = np.random.default_rng(3)
        params = {"conv0/w": rng.standard_normal((4, 2, 3, 3)).astype(np.float32)}
        new, masks, _ = pruning.prune_network(params, ["conv0"], 0.6, [2])
        grown = {k: v + 1.0 for k, v in new.items()}
        masked = pruning.apply_masks(grown, masks)
        w = masked["conv0/w"]
        assert np.all(w[masks["conv0"] == 0] == 0.0)
