//! Layer-to-core placement / pipelining planner — the multi-core CIM
//! scale-out model (ROADMAP; Pelke et al., arXiv:2309.03805).
//!
//! Where [`super::ShardPlan`] balances *images* over identical shards,
//! this module assigns *layers* to the chip's CIM cores
//! ([`crate::config::HardwareConfig::cores`]) and pays for the
//! inter-core activation traffic the assignment induces.
//!
//! # Communication model
//!
//! Cores sit on a linear NoC chain; a transfer from core `a` to core
//! `b` crosses `|a − b|` hops. Layer adjacency is the network's
//! straight-line dataflow: edge `e` carries layer `e`'s output feature
//! map into layer `e + 1`.
//!
//! - **Transfer volume** ([`edge_transfer_bytes`]): edge `e` moves
//!   layer `e + 1`'s input feature map, `cin · fmap² ·
//!   (input_bits / 8)` bytes dense. When the receiving core has an
//!   Input Preprocessing Unit (zero detection), zero activations need
//!   not be sent — the volume is discounted by the trace-measured
//!   zero-entry fraction, derived from the *same* per-layer seeded
//!   trace stream the simulator uses (`sim.seed ^ ((layer + 1) ·
//!   0x9E37)`), so volumes are deterministic and consistent with the
//!   cycle model.
//! - **Transfer cost**: a `v`-byte transfer from core `a` to core `b ≠
//!   a` costs `v / noc_bandwidth + noc_hop_latency · |a − b|` cycles,
//!   charged to the *receiving* core's stage (the consumer stalls on
//!   its inputs). Same-core edges are free.
//! - **Stage time**: core `c`'s stage time is the sum of its layers'
//!   compute cycles (accumulated in layer order — at one core this is
//!   bit-exact with [`super::NetworkSimResult::total_cycles`]) plus its
//!   incoming transfer cycles (accumulated in edge order). The
//!   pipeline bottleneck is the max stage time, which the planner
//!   minimizes.
//! - **Makespan** ([`PlacementPlan::pipeline_makespan`]): streaming `n`
//!   images through the pipe, `(Σ_c t_c + (n − 1) · max_c t_c) / n`
//!   with `t_c` the whole-batch stage totals — the first image pays
//!   the full pipeline latency, every further image is absorbed by the
//!   bottleneck stage. At one core this collapses exactly to the
//!   non-pipelined batch total.
//!
//! Transfer *energy* is not modeled (cycles only); area is unaffected
//! by placement (the same crossbars exist wherever a layer lands).
//!
//! # Planner
//!
//! [`plan`] runs two strategies and keeps the better max stage time:
//!
//! - [`contiguous`] — optimal *contiguous* split (dynamic program over
//!   cut points, adjacent segments on adjacent cores, so every cut
//!   edge pays exactly one hop). This is the baseline.
//! - [`greedy_lpt`] — longest-processing-time order over layers, each
//!   placed on the core minimizing the resulting max stage time
//!   (including the transfer edges both of whose endpoints are already
//!   placed), ties to the lighter stage then the lower core index.
//!
//! Keeping the better of the two pins the planner *structurally* never
//! worse than the contiguous-split baseline — the same fallback shape
//! as [`super::ShardPlan::cost_balanced`]'s round-robin pin — and
//! `tests/prop_invariants.rs` re-checks it against an exhaustive
//! enumeration of all assignments on small cases.

use crate::config::{HardwareConfig, SimConfig};
use crate::nn::NetworkSpec;
use crate::util::json::{arr_f64, arr_usize, obj, Json};
use crate::util::rng::Rng;

use super::plan_cost;
use super::workload::LayerTrace;

/// Sentinel for a layer the greedy pass has not placed yet.
const UNPLACED: usize = usize::MAX;

/// A placement instance: per-layer compute costs, per-edge transfer
/// volumes, and the chip's multi-core block.
#[derive(Debug, Clone)]
pub struct PlacementProblem {
    /// Per-layer compute cycles, in layer order (batch totals when
    /// planning for a batch).
    pub layer_cycles: Vec<f64>,
    /// `transfer_bytes[e]` = activation bytes layer `e` sends to layer
    /// `e + 1` (length `layer_cycles.len() - 1`, or 0 when empty).
    pub transfer_bytes: Vec<f64>,
    /// Cores available (≥ 1; clamped like shard counts).
    pub n_cores: usize,
    /// NoC bandwidth, bytes per cycle (> 0).
    pub noc_bandwidth: f64,
    /// NoC per-hop latency, cycles (≥ 0).
    pub noc_hop_latency: f64,
}

impl PlacementProblem {
    /// Build the instance for a simulated batch on `hw`'s multi-core
    /// block: layer costs are the batch's per-layer cycle totals and
    /// edge volumes are per-image trace-derived bytes scaled by the
    /// image count.
    pub fn from_batch(
        batch: &super::BatchSimResult,
        spec: &NetworkSpec,
        hw: &HardwareConfig,
        sim: &SimConfig,
        ipu_compress: bool,
    ) -> PlacementProblem {
        let n = batch.n_images() as f64;
        let transfer_bytes = edge_transfer_bytes(spec, hw, sim, ipu_compress)
            .iter()
            .map(|v| v * n)
            .collect();
        PlacementProblem {
            layer_cycles: batch.layer_cycles(),
            transfer_bytes,
            n_cores: hw.cores,
            noc_bandwidth: hw.noc_bandwidth,
            noc_hop_latency: hw.noc_hop_latency,
        }
    }

    fn cores(&self) -> usize {
        self.n_cores.max(1)
    }

    /// Per-core (compute, transfer) cycle totals under `assignment`
    /// (`UNPLACED` layers and their edges contribute nothing). Compute
    /// accumulates in layer order, transfers in edge order — the
    /// canonical orders every evaluation of a plan uses, so replanning
    /// and re-evaluating are bit-identical.
    fn stage_components(&self, assignment: &[usize]) -> (Vec<f64>, Vec<f64>) {
        let cores = self.cores();
        let mut compute = vec![0.0; cores];
        let mut transfer = vec![0.0; cores];
        for (li, &c) in assignment.iter().enumerate() {
            if c == UNPLACED {
                continue;
            }
            compute[c] += plan_cost(self.layer_cycles[li]);
        }
        for (e, &bytes) in self.transfer_bytes.iter().enumerate() {
            if e + 1 >= assignment.len() {
                break;
            }
            let (a, b) = (assignment[e], assignment[e + 1]);
            if a == UNPLACED || b == UNPLACED || a == b {
                continue;
            }
            transfer[b] += plan_cost(bytes) / self.noc_bandwidth
                + self.noc_hop_latency * a.abs_diff(b) as f64;
        }
        (compute, transfer)
    }
}

/// A layer-to-core assignment with its per-core cycle breakdown — the
/// placement generalization of [`super::ShardPlan`].
#[derive(Debug, Clone)]
pub struct PlacementPlan {
    pub n_cores: usize,
    /// `assignment[layer]` = core index.
    pub assignment: Vec<usize>,
    /// Per-core compute cycles (layer-order accumulation).
    pub compute: Vec<f64>,
    /// Per-core incoming-transfer cycles (edge-order accumulation).
    pub transfer: Vec<f64>,
    /// Which strategy produced the winning assignment.
    pub method: &'static str,
}

impl PlacementPlan {
    /// Per-core stage time: compute + incoming transfers.
    pub fn stage_times(&self) -> Vec<f64> {
        self.compute
            .iter()
            .zip(&self.transfer)
            .map(|(c, t)| c + t)
            .collect()
    }

    /// The pipeline bottleneck — what the planner minimizes.
    pub fn max_stage_time(&self) -> f64 {
        self.stage_times().iter().copied().fold(0.0, f64::max)
    }

    /// Total cycles spent moving activations between cores.
    pub fn total_transfer_cycles(&self) -> f64 {
        self.transfer.iter().sum()
    }

    /// Per-core utilization: stage time over the bottleneck stage time
    /// (1.0 on the bottleneck core, 0.0 everywhere for an empty plan).
    pub fn utilization(&self) -> Vec<f64> {
        let max = self.max_stage_time();
        self.stage_times()
            .iter()
            .map(|t| if max > 0.0 { t / max } else { 0.0 })
            .collect()
    }

    /// Pipelined batch makespan for `n_images` streamed through the
    /// pipe: `(Σ_c t_c + (n − 1) · max_c t_c) / n` with `t_c` the
    /// whole-batch stage totals. At one core this collapses exactly to
    /// the non-pipelined batch total.
    pub fn pipeline_makespan(&self, n_images: usize) -> f64 {
        let n = n_images.max(1) as f64;
        let stages = self.stage_times();
        let sum: f64 = stages.iter().sum();
        let max = stages.iter().copied().fold(0.0, f64::max);
        if sum == max {
            // One active stage: nothing pipelines, the batch takes
            // exactly the stage total. Returning `max` directly keeps
            // the single-core collapse bit-exact instead of rounding
            // through the general formula.
            return max;
        }
        (sum + (n - 1.0) * max) / n
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("method", self.method.into()),
            ("n_cores", self.n_cores.into()),
            ("assignment", arr_usize(&self.assignment)),
            ("compute_cycles", arr_f64(&self.compute)),
            ("transfer_cycles", arr_f64(&self.transfer)),
            ("stage_cycles", arr_f64(&self.stage_times())),
            ("max_stage_cycles", self.max_stage_time().into()),
            ("total_transfer_cycles", self.total_transfer_cycles().into()),
            ("utilization", arr_f64(&self.utilization())),
        ])
    }
}

fn finish(
    p: &PlacementProblem,
    method: &'static str,
    assignment: Vec<usize>,
) -> PlacementPlan {
    let (compute, transfer) = p.stage_components(&assignment);
    PlacementPlan { n_cores: p.cores(), assignment, compute, transfer, method }
}

/// Optimal *contiguous* split of the layer chain into at most
/// `n_cores` segments, adjacent segments on adjacent cores (every cut
/// edge pays one hop), minimizing max stage time — the baseline the
/// planner is pinned against. Dynamic program over cut points,
/// O(layers² × cores).
pub fn contiguous(p: &PlacementProblem) -> PlacementPlan {
    let l = p.layer_cycles.len();
    if l == 0 {
        return finish(p, "contiguous", Vec::new());
    }
    let k_max = p.cores().min(l);
    let inf = f64::INFINITY;
    // best[j][k] = minimal max-stage over the first j layers split
    // into exactly k segments; cut[j][k] = where segment k starts.
    let mut best = vec![vec![inf; k_max + 1]; l + 1];
    let mut cut = vec![vec![0usize; k_max + 1]; l + 1];
    best[0][0] = 0.0;
    for j in 1..=l {
        for k in 1..=k_max.min(j) {
            for i in (k - 1)..j {
                if best[i][k - 1] == inf {
                    continue;
                }
                let mut seg: f64 =
                    p.layer_cycles[i..j].iter().map(|&c| plan_cost(c)).sum();
                if i > 0 {
                    // the cut edge (i-1 → i) enters this segment: one
                    // hop on the chain plus serialization.
                    seg += plan_cost(p.transfer_bytes[i - 1])
                        / p.noc_bandwidth
                        + p.noc_hop_latency;
                }
                let v = best[i][k - 1].max(seg);
                if v < best[j][k] {
                    best[j][k] = v;
                    cut[j][k] = i;
                }
            }
        }
    }
    // Fewer segments can win when transfers dominate; ties prefer
    // fewer cores (first minimum).
    let mut k_best = 1;
    for k in 2..=k_max {
        if best[l][k] < best[l][k_best] {
            k_best = k;
        }
    }
    let mut assignment = vec![0usize; l];
    let (mut j, mut k) = (l, k_best);
    while k > 0 {
        let i = cut[j][k];
        for a in assignment.iter_mut().take(j).skip(i) {
            *a = k - 1;
        }
        j = i;
        k -= 1;
    }
    finish(p, "contiguous", assignment)
}

/// Greedy LPT-plus-transfer heuristic: layers in descending compute
/// order, each placed on the core that minimizes the resulting max
/// stage time over the layers placed so far (transfer edges count as
/// soon as both endpoints are placed); ties break to the lighter
/// destination stage, then the lower core index.
pub fn greedy_lpt(p: &PlacementProblem) -> PlacementPlan {
    let l = p.layer_cycles.len();
    let cores = p.cores();
    let mut order: Vec<usize> = (0..l).collect();
    order.sort_by(|&a, &b| {
        plan_cost(p.layer_cycles[b])
            .total_cmp(&plan_cost(p.layer_cycles[a]))
            .then(a.cmp(&b))
    });
    let mut assignment = vec![UNPLACED; l];
    for &li in &order {
        let mut best_core = 0usize;
        let mut best_key = (f64::INFINITY, f64::INFINITY);
        for c in 0..cores {
            assignment[li] = c;
            let (compute, transfer) = p.stage_components(&assignment);
            let stage_max = compute
                .iter()
                .zip(&transfer)
                .map(|(a, b)| a + b)
                .fold(0.0, f64::max);
            let key = (stage_max, compute[c] + transfer[c]);
            if key.0 < best_key.0
                || (key.0 == best_key.0 && key.1 < best_key.1)
            {
                best_key = key;
                best_core = c;
            }
        }
        assignment[li] = best_core;
    }
    finish(p, "greedy-lpt", assignment)
}

/// Plan a placement: run [`greedy_lpt`] and the [`contiguous`]
/// baseline, keep whichever has the strictly smaller max stage time
/// (ties go to the baseline) — so the result is *never* worse than the
/// contiguous split, by construction.
pub fn plan(p: &PlacementProblem) -> PlacementPlan {
    let greedy = greedy_lpt(p);
    let base = contiguous(p);
    if greedy.max_stage_time() < base.max_stage_time() {
        greedy
    } else {
        base
    }
}

/// Per-edge activation-transfer volumes for a network, in bytes: edge
/// `e` carries layer `e + 1`'s input feature map (`cin · fmap² ·
/// input_bits / 8` dense). With `ipu_compress`, the volume is
/// discounted by the zero-entry fraction of layer `e + 1`'s input
/// trace — generated from the *same* per-layer seeded stream the
/// simulator uses, so the volumes are deterministic and scheme-
/// consistent.
pub fn edge_transfer_bytes(
    spec: &NetworkSpec,
    hw: &HardwareConfig,
    sim: &SimConfig,
    ipu_compress: bool,
) -> Vec<f64> {
    let bytes_per_act = hw.input_bits as f64 / 8.0;
    (1..spec.layers.len())
        .map(|li| {
            let layer = &spec.layers[li];
            let dense =
                (layer.cin * layer.positions()) as f64 * bytes_per_act;
            if !ipu_compress {
                return dense;
            }
            let n = sim
                .sample_positions
                .map(|s| s.min(layer.positions()))
                .unwrap_or(layer.positions());
            // Same per-layer stream derivation as simulate_network.
            let mut rng =
                Rng::seed_from(sim.seed ^ ((li as u64 + 1) * 0x9E37));
            let trace = LayerTrace::synthetic(layer.cin, n, sim, &mut rng);
            dense * (1.0 - trace.zero_entry_fraction())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn problem(
        cycles: &[f64],
        bytes: &[f64],
        cores: usize,
        bw: f64,
        hop: f64,
    ) -> PlacementProblem {
        PlacementProblem {
            layer_cycles: cycles.to_vec(),
            transfer_bytes: bytes.to_vec(),
            n_cores: cores,
            noc_bandwidth: bw,
            noc_hop_latency: hop,
        }
    }

    /// Every assignment of `l` layers to `cores` cores — the oracle.
    fn all_assignments(l: usize, cores: usize) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new()];
        for _ in 0..l {
            let mut next = Vec::new();
            for a in &out {
                for c in 0..cores {
                    let mut b = a.clone();
                    b.push(c);
                    next.push(b);
                }
            }
            out = next;
        }
        out
    }

    fn max_stage(p: &PlacementProblem, assignment: &[usize]) -> f64 {
        let (c, t) = p.stage_components(assignment);
        c.iter().zip(&t).map(|(a, b)| a + b).fold(0.0, f64::max)
    }

    #[test]
    fn single_core_stage_is_plain_layer_sum() {
        let p = problem(&[10.0, 7.5, 3.25], &[100.0, 50.0], 1, 32.0, 4.0);
        let plan = plan(&p);
        assert_eq!(plan.assignment, vec![0, 0, 0]);
        // bit-exact with the non-pipelined total (same accumulation
        // order as NetworkSimResult::total_cycles)
        let expect: f64 = [10.0, 7.5, 3.25].iter().sum();
        assert_eq!(plan.max_stage_time(), expect);
        assert_eq!(plan.total_transfer_cycles(), 0.0);
        assert_eq!(plan.pipeline_makespan(8), expect);
    }

    #[test]
    fn greedy_beats_contiguous_on_interleaved_loads() {
        // [10, 10, 1, 1]: best contiguous split is 12 (10 | 10,1,1);
        // LPT reaches 11 by pairing a heavy layer with a light one.
        // Transfers are nearly free so the extra cut edges don't pay.
        let p = problem(
            &[10.0, 10.0, 1.0, 1.0],
            &[1.0, 1.0, 1.0],
            2,
            1000.0,
            0.0,
        );
        let base = contiguous(&p);
        let g = greedy_lpt(&p);
        assert!(
            g.max_stage_time() < base.max_stage_time(),
            "greedy {} vs contiguous {}",
            g.max_stage_time(),
            base.max_stage_time()
        );
        let best = plan(&p);
        assert_eq!(best.method, "greedy-lpt");
        assert!(best.max_stage_time() <= 11.01);
    }

    #[test]
    fn contiguous_collapses_when_transfers_dominate() {
        // Hop latency dwarfs any balance gain: the DP keeps everything
        // on one core and the planner agrees.
        let p = problem(&[5.0, 5.0], &[10.0], 2, 1.0, 1e6);
        let best = plan(&p);
        assert_eq!(best.assignment, vec![0, 0]);
        assert_eq!(best.max_stage_time(), 10.0);
    }

    #[test]
    fn planner_matches_exhaustive_oracle_on_small_cases() {
        let cases = [
            problem(&[9.0, 1.0, 8.0, 2.0], &[6.0, 6.0, 6.0], 2, 2.0, 1.0),
            problem(&[4.0, 4.0, 4.0], &[8.0, 8.0], 3, 4.0, 0.5),
            problem(&[7.0, 1.0, 1.0, 7.0], &[2.0, 2.0, 2.0], 2, 1.0, 3.0),
        ];
        for p in &cases {
            let best = plan(&p.clone());
            // never worse than ANY contiguous assignment (stronger
            // than the DP pin), and sane vs the global optimum
            let mut opt = f64::INFINITY;
            for a in all_assignments(p.layer_cycles.len(), p.cores()) {
                let m = max_stage(p, &a);
                opt = opt.min(m);
                let is_contig =
                    a.windows(2).all(|w| w[1] == w[0] || w[1] == w[0] + 1)
                        && a[0] == 0;
                if is_contig {
                    assert!(
                        best.max_stage_time() <= m + 1e-9,
                        "worse than contiguous {a:?}"
                    );
                }
            }
            assert!(best.max_stage_time() + 1e-9 >= opt, "beat the optimum?");
        }
    }

    #[test]
    fn nan_and_negative_inputs_stay_finite() {
        let p = problem(
            &[f64::NAN, 5.0, -3.0],
            &[f64::NAN, -10.0],
            2,
            8.0,
            1.0,
        );
        let best = plan(&p);
        assert!(best.max_stage_time().is_finite());
        for t in best.stage_times() {
            assert!(t.is_finite() && t >= 0.0);
        }
    }

    #[test]
    fn utilization_and_json_shape() {
        let p = problem(&[6.0, 2.0], &[16.0], 2, 16.0, 1.0);
        let best = plan(&p);
        let u = best.utilization();
        assert_eq!(u.len(), 2);
        assert!(u.iter().any(|&x| (x - 1.0).abs() < 1e-12));
        assert!(u.iter().all(|&x| (0.0..=1.0).contains(&x)));
        let j = best.to_json();
        assert_eq!(j.get("n_cores").as_usize(), Some(2));
        assert!(j.get("max_stage_cycles").as_f64().unwrap() > 0.0);
        assert_eq!(
            j.get("assignment").as_arr().map(|a| a.len()),
            Some(2)
        );
    }

    #[test]
    fn transfer_volume_conservation_across_placements() {
        // The per-edge byte volumes are placement-independent; only
        // *which* edges are cut changes. Sum of cut-edge serialization
        // cycles is bounded by the all-cut total.
        let p = problem(&[3.0, 3.0, 3.0, 3.0], &[8.0, 8.0, 8.0], 4, 2.0, 0.0);
        let all_cut: f64 =
            p.transfer_bytes.iter().map(|b| b / p.noc_bandwidth).sum();
        for a in all_assignments(4, 2) {
            let (_, t) = p.stage_components(&a);
            let total: f64 = t.iter().sum();
            assert!(total <= all_cut + 1e-9);
        }
    }

    #[test]
    fn edge_volumes_follow_geometry_and_compression() {
        let hw = HardwareConfig::default();
        let sim = SimConfig::default();
        let spec = NetworkSpec::vgg16_cifar("t");
        let dense = edge_transfer_bytes(&spec, &hw, &sim, false);
        assert_eq!(dense.len(), spec.layers.len() - 1);
        for (e, v) in dense.iter().enumerate() {
            let l = &spec.layers[e + 1];
            let expect = (l.cin * l.positions()) as f64
                * (hw.input_bits as f64 / 8.0);
            assert_eq!(*v, expect);
        }
        let packed = edge_transfer_bytes(&spec, &hw, &sim, true);
        for (d, c) in dense.iter().zip(&packed) {
            assert!(*c <= *d, "compression never grows volume");
            assert!(*c > 0.0);
        }
        // deterministic: same inputs, same bytes
        assert_eq!(packed, edge_transfer_bytes(&spec, &hw, &sim, true));
    }
}
