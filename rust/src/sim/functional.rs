//! Functional (numerical) simulation of the mapped accelerator.
//!
//! Executes a mapped layer OU-by-OU with the full quantization chain —
//! DAC input quantization, differential cell slicing, per-OU-slice ADC,
//! shift-add — mirroring `python/compile/kernels/quant.py`, with the
//! Input Preprocessing Unit selecting inputs and the Output Indexing
//! Unit scattering results. This is equivalence-spine link #3
//! (DESIGN.md §6): the *reordered, compressed, placed* weights compute
//! the same convolution as the dense float oracle (up to quantization).

use crate::arch::{InputPreprocessor, OutputIndexer};
use crate::config::HardwareConfig;
use crate::mapping::MappedLayer;
use crate::nn::{im2col, Tensor};
use crate::xbar;

/// Per-layer static calibration scales (mirror of python `scales`).
#[derive(Debug, Clone, Copy)]
pub struct LayerScales {
    pub sx: f32,
    pub sw: f32,
}

/// Execute one mapped conv layer on one image (NCHW input), returning
/// the pre-activation output `[cout, H, W]` flattened row-major.
///
/// `quantized = false` bypasses the converters (pure float MVM over the
/// mapped blocks) — used to isolate mapping errors from quantization.
pub fn conv_forward(
    layer: &MappedLayer,
    x: &Tensor,
    img: usize,
    scales: LayerScales,
    hw: &HardwareConfig,
    quantized: bool,
) -> Tensor {
    let (h, w) = (x.shape[2], x.shape[3]);
    let rows = im2col(x, img);
    conv_forward_rows(layer, &rows, h, w, scales, hw, quantized)
}

/// As [`conv_forward`] but over pre-extracted im2col rows, so callers
/// that also need the rows (e.g. the exact-mode trace in
/// `SmallCnn::simulate_exact`) extract them once.
pub fn conv_forward_rows(
    layer: &MappedLayer,
    rows: &[Vec<f32>],
    h: usize,
    w: usize,
    scales: LayerScales,
    hw: &HardwareConfig,
    quantized: bool,
) -> Tensor {
    debug_assert_eq!(rows.len(), h * w);
    let mut out = Tensor::zeros(&[layer.cout, h, w]);

    for (pos, row) in rows.iter().enumerate() {
        let ipp = InputPreprocessor::new(row);
        let mut oi = OutputIndexer::new(layer.cout);
        for block in &layer.blocks {
            if ipp.all_zero(block) {
                continue; // §IV-A skip — exact no-op either way
            }
            let inputs = ipp.select(block);
            let vals = if quantized {
                block_mvm_quantized(block, &inputs, scales, hw, layer)
            } else {
                block_mvm_float(block, &inputs)
            };
            oi.scatter(block, &vals);
        }
        let colv = oi.finish();
        for (oc, v) in colv.into_iter().enumerate() {
            out.data[oc * h * w + pos] = v;
        }
    }
    out
}

/// Float MVM over one block: `out[k] = sum_r inputs[r] * w[r][k]`.
fn block_mvm_float(block: &crate::mapping::PatternBlock, inputs: &[f32]) -> Vec<f32> {
    let k = block.kernels();
    let mut out = vec![0.0f32; k];
    for (r, &xv) in inputs.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let row = &block.weights[r * k..(r + 1) * k];
        for (o, wv) in out.iter_mut().zip(row.iter()) {
            *o += xv * wv;
        }
    }
    out
}

/// Quantized OU-granular MVM over one block, mirroring the Pallas
/// kernel's arithmetic (`ou_mvm.py`): per OU row-group and cell slice,
/// integer partial sums pass through the static ADC transfer function
/// before shift-add recombination.
fn block_mvm_quantized(
    block: &crate::mapping::PatternBlock,
    inputs: &[f32],
    scales: LayerScales,
    hw: &HardwareConfig,
    layer: &MappedLayer,
) -> Vec<f32> {
    let geom = &layer.geom;
    let k = block.kernels();
    let n_slices = hw.weight_bits.div_ceil(hw.cell_bits);

    // DAC quantization of the block's inputs.
    let xq: Vec<i32> = inputs
        .iter()
        .map(|&v| xbar::quantize_input(v, scales.sx, hw.input_bits))
        .collect();
    // Weight quantization (cells store differential nibble pairs).
    let wq: Vec<i32> = block
        .weights
        .iter()
        .map(|&v| xbar::quantize_weight(v, scales.sw, hw.weight_bits))
        .collect();

    let mut out = vec![0.0f32; k];
    let h = block.rows();
    let mut row_off = 0;
    while row_off < h {
        let rows = (h - row_off).min(geom.ou_rows);
        // One OU row-group: per slice, integer partials then ADC.
        for (kk, o) in out.iter_mut().enumerate() {
            let mut acc = 0.0f64;
            for s in 0..n_slices {
                let mut partial = 0i64;
                for r in row_off..row_off + rows {
                    let nib = xbar::signed_cell_slice(wq[r * k + kk], s, hw.cell_bits);
                    partial += (xq[r] as i64) * (nib as i64);
                }
                let adc = xbar::adc_quantize(partial as f64, hw, hw.input_bits);
                acc += ((1usize << (hw.cell_bits * s)) as f64) * adc;
            }
            *o += (acc * scales.sx as f64 * scales.sw as f64) as f32;
        }
        row_off += rows;
    }
    out
}

/// ReLU + bias, then 2×2 max-pool if requested — the digital tail of a
/// conv stage in the SmallCNN network.
pub fn relu_bias_pool(x: &Tensor, bias: &[f32], pool: bool) -> Tensor {
    let (c, h, w) = (x.shape[0], x.shape[1], x.shape[2]);
    let mut act = Tensor::zeros(&[c, h, w]);
    for ch in 0..c {
        for i in 0..h * w {
            let v = x.data[ch * h * w + i] + bias[ch];
            act.data[ch * h * w + i] = v.max(0.0);
        }
    }
    if !pool {
        return act;
    }
    let (ph, pw) = (h / 2, w / 2);
    let mut out = Tensor::zeros(&[c, ph, pw]);
    for ch in 0..c {
        for y in 0..ph {
            for xw in 0..pw {
                let mut m = f32::NEG_INFINITY;
                for dy in 0..2 {
                    for dx in 0..2 {
                        m = m.max(act.data[ch * h * w + (2 * y + dy) * w + 2 * xw + dx]);
                    }
                }
                out.data[ch * ph * pw + y * pw + xw] = m;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::mapping::naive::NaiveMapping;
    use crate::mapping::pattern::PatternMapping;
    use crate::mapping::MappingScheme;
    use crate::nn::{conv2d_ref, ConvLayer};
    use crate::pruning::synthetic::generate_layer;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use crate::xbar::CellGeometry;

    fn rand_input(rng: &mut Rng, c: usize, hw: usize) -> Tensor {
        let mut x = Tensor::zeros(&[1, c, hw, hw]);
        for v in x.data.iter_mut() {
            // post-ReLU-like: ~40% zeros, positive values
            *v = if rng.chance(0.4) { 0.0 } else { rng.f32() };
        }
        x
    }

    fn scales_for(x: &Tensor, w: &Tensor, hw: &HardwareConfig) -> LayerScales {
        let x_max = (1usize << (hw.input_bits - 1)) as f32 - 1.0;
        let w_max = (1usize << (hw.weight_bits - 1)) as f32 - 1.0;
        LayerScales {
            sx: (x.max_abs() / x_max).max(1e-8),
            sw: (w.max_abs() / w_max).max(1e-8),
        }
    }

    /// Float-mode mapped compute == dense conv oracle, exactly.
    #[test]
    fn mapped_float_equals_dense_conv() {
        let hw = HardwareConfig::smallcnn_functional();
        let geom = CellGeometry::from_hw(&hw);
        let mut rng = Rng::seed_from(1);
        let w = generate_layer(12, 4, 5, 0.8, 0.3, &mut rng);
        let l = ConvLayer { name: "t".into(), cout: 12, cin: 4, fmap: 6 };
        let x = rand_input(&mut rng, 4, 6);
        let want = conv2d_ref(&x, &w);
        for scheme in [&PatternMapping as &dyn MappingScheme, &NaiveMapping] {
            let ml = scheme.map_layer(0, &l, &w, &geom);
            let got = conv_forward(
                &ml,
                &x,
                0,
                LayerScales { sx: 1.0, sw: 1.0 },
                &hw,
                false,
            );
            for (g, wv) in got.data.iter().zip(want.data.iter()) {
                assert!((g - wv).abs() < 1e-4, "{} vs {} ({})", g, wv, scheme.name());
            }
        }
    }

    /// Quantized mapped compute tracks the float oracle within the
    /// 8-bit-ADC error budget.
    #[test]
    fn mapped_quantized_close_to_dense_conv() {
        let hw = HardwareConfig::smallcnn_functional();
        let geom = CellGeometry::from_hw(&hw);
        let mut rng = Rng::seed_from(2);
        let w = generate_layer(16, 8, 6, 0.82, 0.35, &mut rng);
        let l = ConvLayer { name: "t".into(), cout: 16, cin: 8, fmap: 8 };
        let x = rand_input(&mut rng, 8, 8);
        let sc = scales_for(&x, &w, &hw);
        let ml = PatternMapping.map_layer(0, &l, &w, &geom);
        let got = conv_forward(&ml, &x, 0, sc, &hw, true);
        let want = conv2d_ref(&x, &w);
        let max_ref = want.max_abs();
        let mut max_err = 0.0f32;
        for (g, wv) in got.data.iter().zip(want.data.iter()) {
            max_err = max_err.max((g - wv).abs());
        }
        assert!(
            max_err / max_ref < 0.25,
            "relative error too high: {}",
            max_err / max_ref
        );
    }

    /// A very fine ADC leaves only input/weight quantization error.
    #[test]
    fn high_resolution_adc_near_exact() {
        let hw = HardwareConfig {
            adc_bits: 28,
            weight_bits: 16,
            input_bits: 16,
            differential: true,
            ..HardwareConfig::smallcnn_functional()
        };
        let geom = CellGeometry::from_hw(&hw);
        let mut rng = Rng::seed_from(3);
        let w = generate_layer(8, 4, 4, 0.75, 0.3, &mut rng);
        let l = ConvLayer { name: "t".into(), cout: 8, cin: 4, fmap: 5 };
        let x = rand_input(&mut rng, 4, 5);
        let sc = scales_for(&x, &w, &hw);
        let ml = PatternMapping.map_layer(0, &l, &w, &geom);
        let got = conv_forward(&ml, &x, 0, sc, &hw, true);
        let want = conv2d_ref(&x, &w);
        for (g, wv) in got.data.iter().zip(want.data.iter()) {
            assert!((g - wv).abs() < 2e-2 * want.max_abs().max(1.0));
        }
    }

    /// Naive and pattern mappings agree bit-for-bit in float mode
    /// (same math, different layout).
    #[test]
    fn prop_schemes_agree_float_mode() {
        prop::check("schemes agree float", 16, |rng: &mut Rng| {
            let hw = HardwareConfig::smallcnn_functional();
            let geom = CellGeometry::from_hw(&hw);
            let cout = rng.range(1, 20);
            let cin = rng.range(1, 5);
            let n_pat = rng.range(1, 7).min(cout * cin);
            let w = generate_layer(cout, cin, n_pat, 0.7, 0.25, rng);
            let l = ConvLayer { name: "t".into(), cout, cin, fmap: 5 };
            let x = rand_input(rng, cin, 5);
            let sc = LayerScales { sx: 1.0, sw: 1.0 };
            let a = conv_forward(
                &PatternMapping.map_layer(0, &l, &w, &geom),
                &x, 0, sc, &hw, false,
            );
            let b = conv_forward(
                &NaiveMapping.map_layer(0, &l, &w, &geom),
                &x, 0, sc, &hw, false,
            );
            for (x1, x2) in a.data.iter().zip(b.data.iter()) {
                assert!((x1 - x2).abs() < 1e-4);
            }
        });
    }

    /// The exact-mode trace (im2col rows → `LayerTrace::from_rows`)
    /// skips exactly the blocks the Input Preprocessing Unit declares
    /// all-zero — the analytic engine and the functional simulator
    /// agree on what executes.
    #[test]
    fn exact_trace_matches_ipu_zero_detection() {
        let mut rng = Rng::seed_from(5);
        let w = generate_layer(10, 4, 5, 0.8, 0.3, &mut rng);
        let l = ConvLayer { name: "t".into(), cout: 10, cin: 4, fmap: 6 };
        let x = rand_input(&mut rng, 4, 6);
        let hw = HardwareConfig::smallcnn_functional();
        let geom = CellGeometry::from_hw(&hw);
        let ml = PatternMapping.map_layer(0, &l, &w, &geom);
        let rows = im2col(&x, 0);
        let trace = crate::sim::workload::LayerTrace::from_rows(&rows, l.cin);
        assert_eq!(trace.n_positions, rows.len());
        for (pos, row) in rows.iter().enumerate() {
            let ipp = InputPreprocessor::new(row);
            for b in &ml.blocks {
                assert_eq!(
                    trace.block_skippable(pos, b.cin, b.pattern),
                    ipp.all_zero(b),
                    "pos {pos}"
                );
            }
        }
    }

    #[test]
    fn relu_bias_pool_behaviour() {
        let x = Tensor::from_vec(&[1, 2, 2], vec![-1.0, 2.0, 3.0, -4.0]);
        let act = relu_bias_pool(&x, &[1.0], false);
        assert_eq!(act.data, vec![0.0, 3.0, 4.0, 0.0]);
        let pooled = relu_bias_pool(&x, &[1.0], true);
        assert_eq!(pooled.shape, vec![1, 1, 1]);
        assert_eq!(pooled.data, vec![4.0]);
    }
}
