//! Cycle & energy simulator (paper §V).
//!
//! Model (documented in DESIGN.md §5 and EXPERIMENTS.md):
//!
//! - **Cycles** — the chip is ADC-throughput-limited: every executed OU
//!   activation costs one cycle, plus `block_switch_cycles` control
//!   overhead whenever the scheduler crosses a pattern-block boundary —
//!   i.e. only when the pattern block actually *changes* between two
//!   consecutively executed blocks of a position's schedule (index
//!   decode + Input-Preprocessing reconfiguration; pattern scheme only —
//!   naive's dense walk needs no index decode). The first executed
//!   block of a position is not a crossing, so a position executing
//!   `B` blocks is charged `B - 1` switches.
//! - **Energy** — per executed OU, component-wise partial-activation
//!   energy from [`crate::xbar::energy::ou_op_energy`].
//! - **Skipping** — the pattern scheme never *stores* all-zero-pattern
//!   kernels (they cost nothing by construction), and with
//!   `zero_detection` skips blocks whose selected inputs are all zero.
//!   The naive baseline executes everything (paper Fig. 1 baseline has
//!   no Input Preprocessing Unit).
//!
//! Layers are simulated at `sample_positions` sampled output positions
//! and scaled to the full feature map (exact mode: `None`).
//!
//! # Sampled vs exact trace mode
//!
//! `SimConfig::sample_positions` selects the trace fidelity
//! ([`crate::config::SimConfig::sampled`] /
//! [`crate::config::SimConfig::exact`]):
//!
//! - **Sampled** (`Some(n)`): each layer's synthetic trace covers
//!   `min(n, positions)` output positions and `finish_result` scales
//!   the integer OU/switch counts by `positions / trace_positions` —
//!   cheap, but skip fractions carry a ~`1/sqrt(n)` sampling error
//!   (`tests/prop_invariants.rs` pins the monotone convergence of that
//!   error at n ∈ {16, 64, 256}).
//! - **Exact** (`None`): the trace covers every output position, the
//!   scale is exactly 1.0, and no sampling error exists. Affordable
//!   since the trace-aggregated engine: one O(positions × cin)
//!   histogram pass per layer, no per-position block walk.
//!
//! Both modes share the same trace seed and activation model, so an
//! exact run is the sampled run's limit, not a different experiment.
//! The paper-artifact pipeline ([`crate::report::artifacts`],
//! `rram-accel artifacts`) regenerates Fig. 7 / Fig. 8 / Table 2 in
//! both modes and emits `results/paper/delta_report.json`: per
//! dataset, per scheme, entries `{figure, metric, scheme, sampled,
//! exact, rel_delta, tolerance, within}` where `rel_delta =
//! |sampled − exact| / |exact|`. Structural metrics (crossbar counts,
//! area efficiency, sparsity) get a zero band — they must not move
//! between modes; trace-dependent metrics (cycles, energy, speedup)
//! get 10% bands. `tests/paper_artifacts.rs` (tier 2, `PAPER_TIER2=1`)
//! gates the report plus byte-level determinism of the artifacts.
//!
//! Two engines compute this model. [`simulate_layer_reference`] is the
//! per-position oracle: it walks every (position × block) pair, which
//! is readable but O(positions × blocks). [`simulate_layer`] is the
//! production trace-aggregated engine: one O(positions × cin) histogram
//! pass over the trace ([`workload::TraceAggregate`]) and then each
//! block's executed/skipped OU counts, cycles and energy in closed form
//! from its precomputed `BlockCost` — no per-position loop over blocks
//! at all. `tests/prop_invariants.rs` pins the two engines to identical
//! counts and 1e-9-relative energy.
//!
//! On top of the aggregated engine, [`simulate_network_batch`] costs a
//! whole multi-image batch in one closed-form pass per layer (per-block
//! cost tables computed once, [`workload::BatchAggregate`] per-image
//! histograms), reporting per-image and per-batch cycles/energy that
//! are bit-exact with independent per-image runs.

pub mod functional;
pub mod placement;
pub mod smallcnn;
pub mod workload;

use crate::config::{HardwareConfig, SimConfig};
use crate::mapping::{MappedLayer, MappedNetwork};
use crate::nn::NetworkSpec;
use crate::pruning::Pattern;
use crate::util::json::{arr_f64, arr_usize, obj, Json};
use crate::util::rng::Rng;
use crate::util::stats::linear_fit;
use crate::util::threadpool;
use crate::xbar::energy::{ou_op_energy_batch, EnergyLedger};
use crate::xbar::CellGeometry;
use workload::{BatchAggregate, LayerTrace, TraceAggregate};

/// Per-layer simulation result.
#[derive(Debug, Clone, Default)]
pub struct LayerSimResult {
    pub layer_idx: usize,
    /// Executed OU operations over the whole feature map.
    pub ou_ops: f64,
    /// OU operations skipped by all-zero input detection.
    pub skipped_ou_ops: f64,
    /// Total cycles (OU ops + block-switch overhead).
    pub cycles: f64,
    pub energy: EnergyLedger,
    pub n_crossbars: usize,
}

impl LayerSimResult {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("layer_idx", self.layer_idx.into()),
            ("ou_ops", self.ou_ops.into()),
            ("skipped_ou_ops", self.skipped_ou_ops.into()),
            ("cycles", self.cycles.into()),
            ("adc_pj", self.energy.adc_pj.into()),
            ("dac_pj", self.energy.dac_pj.into()),
            ("rram_pj", self.energy.rram_pj.into()),
            ("n_crossbars", self.n_crossbars.into()),
        ])
    }
}

/// Whole-network simulation result.
#[derive(Debug, Clone, Default)]
pub struct NetworkSimResult {
    pub scheme: String,
    pub network: String,
    pub layers: Vec<LayerSimResult>,
}

impl NetworkSimResult {
    pub fn total_cycles(&self) -> f64 {
        self.layers.iter().map(|l| l.cycles).sum()
    }

    pub fn total_ou_ops(&self) -> f64 {
        self.layers.iter().map(|l| l.ou_ops).sum()
    }

    pub fn total_energy(&self) -> EnergyLedger {
        let mut e = EnergyLedger::default();
        for l in &self.layers {
            e.add(&l.energy);
        }
        e
    }

    pub fn total_crossbars(&self) -> usize {
        self.layers.iter().map(|l| l.n_crossbars).sum()
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("scheme", self.scheme.as_str().into()),
            ("network", self.network.as_str().into()),
            ("total_cycles", self.total_cycles().into()),
            ("total_ou_ops", self.total_ou_ops().into()),
            ("total_energy_pj", self.total_energy().total_pj().into()),
            (
                "layers",
                Json::Arr(self.layers.iter().map(|l| l.to_json()).collect()),
            ),
        ])
    }
}

/// Whole-batch simulation result: one [`NetworkSimResult`] per image.
/// Batch totals fold the per-image results in image order, so they are
/// bit-exact with summing N independent per-image simulations the same
/// way (the ISSUE-2 batch invariant, pinned by
/// `tests/prop_invariants.rs`).
#[derive(Debug, Clone, Default)]
pub struct BatchSimResult {
    pub scheme: String,
    pub network: String,
    pub per_image: Vec<NetworkSimResult>,
}

impl BatchSimResult {
    pub fn n_images(&self) -> usize {
        self.per_image.len()
    }

    pub fn total_cycles(&self) -> f64 {
        self.per_image.iter().map(|r| r.total_cycles()).sum()
    }

    pub fn total_ou_ops(&self) -> f64 {
        self.per_image.iter().map(|r| r.total_ou_ops()).sum()
    }

    pub fn total_energy(&self) -> EnergyLedger {
        let mut e = EnergyLedger::default();
        for r in &self.per_image {
            e.add(&r.total_energy());
        }
        e
    }

    pub fn mean_cycles_per_image(&self) -> f64 {
        self.total_cycles() / self.n_images().max(1) as f64
    }

    /// Slowest image of the batch — the batch's critical path when
    /// images run on separate shards.
    pub fn max_image_cycles(&self) -> f64 {
        self.per_image
            .iter()
            .map(|r| r.total_cycles())
            .fold(0.0, f64::max)
    }

    /// Per-image simulated cycles, in image order — the per-item costs
    /// a sharded dispatcher balances (`max_image_cycles` is their max).
    pub fn image_cycles(&self) -> Vec<f64> {
        self.per_image.iter().map(|r| r.total_cycles()).collect()
    }

    /// Per-layer cycles summed across the batch's images (image order
    /// within each layer), in layer order — the compute costs the
    /// layer-to-core placement planner ([`placement`]) balances.
    pub fn layer_cycles(&self) -> Vec<f64> {
        let n_layers =
            self.per_image.first().map(|r| r.layers.len()).unwrap_or(0);
        let mut out = vec![0.0; n_layers];
        for r in &self.per_image {
            for (li, l) in r.layers.iter().enumerate() {
                out[li] += l.cycles;
            }
        }
        out
    }

    /// First-order predicted per-image cost: executed OU ops only, no
    /// block-switch overhead — what a cheap cost model sees before the
    /// full cycle accounting is known. Shard plans are built on these
    /// and then evaluated against the achieved [`Self::image_cycles`].
    pub fn image_predicted_costs(&self) -> Vec<f64> {
        self.per_image.iter().map(|r| r.total_ou_ops()).collect()
    }

    /// Plan how to spread this batch's images over `n_shards` parallel
    /// compute shards, using the first-order predicted costs.
    pub fn shard_plan(&self, n_shards: usize, policy: ShardPolicy) -> ShardPlan {
        ShardPlan::plan(&self.image_predicted_costs(), n_shards, policy)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("scheme", self.scheme.as_str().into()),
            ("network", self.network.as_str().into()),
            ("n_images", self.n_images().into()),
            ("total_cycles", self.total_cycles().into()),
            ("total_ou_ops", self.total_ou_ops().into()),
            ("total_energy_pj", self.total_energy().total_pj().into()),
            ("mean_cycles_per_image", self.mean_cycles_per_image().into()),
            ("max_image_cycles", self.max_image_cycles().into()),
            (
                "per_image",
                Json::Arr(self.per_image.iter().map(|r| r.to_json()).collect()),
            ),
        ])
    }
}

/// How [`ShardPlan`] assigns per-image work to shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// Greedy longest-processing-time: items in descending cost order,
    /// each to the currently least-loaded shard. Never yields a worse
    /// max-shard load than round-robin on the same costs (the
    /// constructor falls back to the round-robin assignment in the
    /// rare case it would).
    CostBalanced,
    /// Item `i` to shard `i % n_shards`, cost-blind.
    RoundRobin,
}

impl ShardPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            ShardPolicy::CostBalanced => "cost",
            ShardPolicy::RoundRobin => "rr",
        }
    }
}

/// Static assignment of per-item costs (e.g. a batch's predicted
/// per-image cycles) to `n_shards` parallel shards. A shard's load is
/// the sum of its items' costs — its serial makespan — so the plan's
/// [`ShardPlan::max_load`] is the batch's critical path under the plan
/// (the sharded generalization of
/// [`BatchSimResult::max_image_cycles`]).
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub policy: ShardPolicy,
    pub n_shards: usize,
    /// `assignment[item]` = shard index.
    pub assignment: Vec<usize>,
    /// Planned per-shard load (sum of assigned costs).
    pub loads: Vec<f64>,
}

/// Clamp one item cost for planning: negatives clamp to 0 (documented
/// behavior) and NaN — one bad calibration fit away — collapses to 0
/// too. Without this the LPT comparator is non-total (order-dependent
/// plans, and `sort_by` may panic outright on its totality check). The
/// `+ 0.0` collapses -0.0 so `total_cmp` ordering is stable.
pub(crate) fn plan_cost(c: f64) -> f64 {
    if c.is_nan() {
        0.0
    } else {
        c.max(0.0) + 0.0
    }
}

impl ShardPlan {
    /// Build a plan under `policy` (negative and NaN costs are clamped
    /// to 0).
    pub fn plan(costs: &[f64], n_shards: usize, policy: ShardPolicy) -> ShardPlan {
        match policy {
            ShardPolicy::CostBalanced => Self::cost_balanced(costs, n_shards),
            ShardPolicy::RoundRobin => Self::round_robin(costs, n_shards),
        }
    }

    /// Cost-blind round-robin assignment.
    pub fn round_robin(costs: &[f64], n_shards: usize) -> ShardPlan {
        let n_shards = n_shards.max(1);
        let assignment: Vec<usize> =
            (0..costs.len()).map(|i| i % n_shards).collect();
        Self::from_assignment(ShardPolicy::RoundRobin, n_shards, assignment, costs)
    }

    /// Greedy LPT assignment, guaranteed never worse than round-robin
    /// on max-shard load: the round-robin plan is computed alongside
    /// and kept if it strictly beats the greedy one.
    pub fn cost_balanced(costs: &[f64], n_shards: usize) -> ShardPlan {
        let n_shards = n_shards.max(1);
        let clamped: Vec<f64> = costs.iter().map(|&c| plan_cost(c)).collect();
        let mut order: Vec<usize> = (0..clamped.len()).collect();
        order.sort_by(|&a, &b| {
            clamped[b].total_cmp(&clamped[a]).then(a.cmp(&b))
        });
        let mut greedy_loads = vec![0.0; n_shards];
        let mut assignment = vec![0usize; clamped.len()];
        for &i in &order {
            // argmin load, first minimum on ties (deterministic)
            let mut best = 0usize;
            for (s, load) in greedy_loads.iter().enumerate().skip(1) {
                if *load < greedy_loads[best] {
                    best = s;
                }
            }
            assignment[i] = best;
            greedy_loads[best] += clamped[i];
        }
        let lpt = Self::from_assignment(
            ShardPolicy::CostBalanced,
            n_shards,
            assignment,
            costs,
        );
        let rr = Self::round_robin(costs, n_shards);
        if rr.max_load() < lpt.max_load() {
            ShardPlan { policy: ShardPolicy::CostBalanced, ..rr }
        } else {
            lpt
        }
    }

    /// Build a plan from a fixed assignment, with loads accumulated in
    /// canonical item order — the same order [`ShardPlan::loads_with`]
    /// uses, so re-evaluating a plan on its own costs is bit-identical
    /// to its planned loads.
    fn from_assignment(
        policy: ShardPolicy,
        n_shards: usize,
        assignment: Vec<usize>,
        costs: &[f64],
    ) -> ShardPlan {
        let mut plan =
            ShardPlan { policy, n_shards, assignment, loads: Vec::new() };
        plan.loads = plan.loads_with(costs);
        plan
    }

    /// Heaviest planned shard load — the plan's critical path.
    pub fn max_load(&self) -> f64 {
        self.loads.iter().copied().fold(0.0, f64::max)
    }

    /// Mean shard load (total work / shards): the lower bound any plan
    /// can reach.
    pub fn mean_load(&self) -> f64 {
        self.loads.iter().sum::<f64>() / self.n_shards.max(1) as f64
    }

    /// `max_load / mean_load` — 1.0 is a perfectly balanced plan.
    pub fn imbalance(&self) -> f64 {
        self.max_load() / self.mean_load().max(1e-12)
    }

    /// Re-evaluate this plan's per-shard loads under different per-item
    /// costs (e.g. achieved cycles vs the predicted costs it was
    /// planned on).
    pub fn loads_with(&self, costs: &[f64]) -> Vec<f64> {
        assert_eq!(
            costs.len(),
            self.assignment.len(),
            "loads_with needs one cost per planned item"
        );
        let mut loads = vec![0.0; self.n_shards];
        for (i, &s) in self.assignment.iter().enumerate() {
            loads[s] += plan_cost(costs[i]);
        }
        loads
    }

    /// Items assigned per shard.
    pub fn shard_sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.n_shards];
        for &s in &self.assignment {
            sizes[s] += 1;
        }
        sizes
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("policy", self.policy.name().into()),
            ("n_shards", self.n_shards.into()),
            ("assignment", arr_usize(&self.assignment)),
            ("loads", arr_f64(&self.loads)),
            ("max_load", self.max_load().into()),
            ("mean_load", self.mean_load().into()),
            ("imbalance", self.imbalance().into()),
        ])
    }
}

/// One layer's zero-fraction→cost regression over exact-mode traces:
/// `cycles(zf) ≈ cycles_at_dense + cycles_slope · zf` (and likewise for
/// energy), fitted by least squares across calibration images.
#[derive(Debug, Clone)]
pub struct LayerCalibration {
    pub layer_idx: usize,
    /// Predicted cycles at input zero fraction 0 (regression intercept).
    pub cycles_at_dense: f64,
    /// d(cycles) / d(input zero fraction) — ≤ 0 when zero-skipping
    /// helps.
    pub cycles_slope: f64,
    pub energy_at_dense_pj: f64,
    pub energy_slope_pj: f64,
    pub n_samples: usize,
}

/// Whole-network cost calibration from real exact-mode activation
/// traces: one [`LayerCalibration`] per mapped layer, fitted against
/// the calibration images' *input* zero fractions (the only signal the
/// serving cost model sees at submit time). Built by
/// `SmallCnn::calibrate`; consumed by
/// `coordinator::CostModel::from_calibration`.
#[derive(Debug, Clone, Default)]
pub struct CostCalibration {
    pub layers: Vec<LayerCalibration>,
}

impl CostCalibration {
    /// Fit per-layer regressions from per-image exact simulations.
    /// `zero_fractions[i]` is image `i`'s input zero fraction;
    /// `per_image_layers[i][l]` its simulated result for layer `l`.
    pub fn from_samples(
        zero_fractions: &[f64],
        per_image_layers: &[Vec<LayerSimResult>],
    ) -> CostCalibration {
        assert_eq!(
            zero_fractions.len(),
            per_image_layers.len(),
            "one zero fraction per calibration image"
        );
        let n_layers = per_image_layers.first().map(|l| l.len()).unwrap_or(0);
        let mut layers = Vec::with_capacity(n_layers);
        for li in 0..n_layers {
            let cycles: Vec<f64> = per_image_layers
                .iter()
                .map(|img| img[li].cycles)
                .collect();
            let energy: Vec<f64> = per_image_layers
                .iter()
                .map(|img| img[li].energy.total_pj())
                .collect();
            let (cb, cm) = linear_fit(zero_fractions, &cycles);
            let (eb, em) = linear_fit(zero_fractions, &energy);
            layers.push(LayerCalibration {
                layer_idx: per_image_layers[0][li].layer_idx,
                cycles_at_dense: cb,
                cycles_slope: cm,
                energy_at_dense_pj: eb,
                energy_slope_pj: em,
                n_samples: zero_fractions.len(),
            });
        }
        CostCalibration { layers }
    }

    /// Predicted whole-network cycles at input zero fraction `zf`
    /// (sum of the per-layer fits).
    pub fn total_cycles_at(&self, zf: f64) -> f64 {
        self.layers
            .iter()
            .map(|l| l.cycles_at_dense + l.cycles_slope * zf)
            .sum()
    }

    /// Predicted whole-network energy (pJ) at input zero fraction `zf`.
    pub fn total_energy_at(&self, zf: f64) -> f64 {
        self.layers
            .iter()
            .map(|l| l.energy_at_dense_pj + l.energy_slope_pj * zf)
            .sum()
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.layers
                .iter()
                .map(|l| {
                    obj(vec![
                        ("layer_idx", l.layer_idx.into()),
                        ("cycles_at_dense", l.cycles_at_dense.into()),
                        ("cycles_slope", l.cycles_slope.into()),
                        ("energy_at_dense_pj", l.energy_at_dense_pj.into()),
                        ("energy_slope_pj", l.energy_slope_pj.into()),
                        ("n_samples", l.n_samples.into()),
                    ])
                })
                .collect(),
        )
    }
}

/// Precomputed per-block OU cost (hot-path optimization: the OU schedule
/// of a block does not depend on the position, only skipping does).
#[derive(Debug, Clone, Copy)]
struct BlockCost {
    ou_ops: usize,
    energy: EnergyLedger,
    cin: usize,
    pattern: Pattern,
}

fn block_costs(layer: &MappedLayer, hw: &HardwareConfig) -> Vec<BlockCost> {
    let geom = &layer.geom;
    layer
        .blocks
        .iter()
        .map(|b| {
            let (ou_ops, energy) =
                tile_cost(geom, hw, b.rows(), geom.weight_cols(b.kernels()));
            BlockCost { ou_ops, energy, cin: b.cin, pattern: b.pattern }
        })
        .collect()
}

/// OU count and energy of one dense `h × w_cells` block in closed form:
/// the OU tiling has at most four distinct tile shapes (interior, right
/// edge, bottom edge, corner), each costed once through
/// [`ou_op_energy_batch`] instead of per-tile ledger adds.
fn tile_cost(
    geom: &CellGeometry,
    hw: &HardwareConfig,
    h: usize,
    w_cells: usize,
) -> (usize, EnergyLedger) {
    let full_r = h / geom.ou_rows;
    let rem_r = h % geom.ou_rows;
    let full_c = w_cells / geom.ou_cols;
    let rem_c = w_cells % geom.ou_cols;
    let shapes = [
        (geom.ou_rows, geom.ou_cols, full_r * full_c),
        (geom.ou_rows, rem_c, full_r),
        (rem_r, geom.ou_cols, full_c),
        (rem_r, rem_c, 1),
    ];
    let mut ou_ops = 0usize;
    let mut energy = EnergyLedger::default();
    for (rows, cols, n) in shapes {
        if rows == 0 || cols == 0 || n == 0 {
            continue;
        }
        ou_ops += n;
        energy.add(&ou_op_energy_batch(hw, rows, cols, n as f64));
    }
    (ou_ops, energy)
}

/// Simulate one mapped layer against an activation trace with the
/// trace-aggregated engine.
///
/// `skip_zero_inputs` enables the Input Preprocessing Unit's all-zero
/// detection; `block_switch_cycles` models the §IV-C index-decode walk.
pub fn simulate_layer(
    layer: &MappedLayer,
    spec_positions: usize,
    trace: &LayerTrace,
    hw: &HardwareConfig,
    skip_zero_inputs: bool,
    block_switch_cycles: f64,
) -> LayerSimResult {
    let agg = layer_aggregate(layer, trace);
    simulate_layer_aggregated(
        layer,
        spec_positions,
        &agg,
        hw,
        skip_zero_inputs,
        block_switch_cycles,
    )
}

/// Build the [`TraceAggregate`] for exactly this layer's block keys.
/// Reusable across [`simulate_layer_aggregated`] calls on the same
/// trace (e.g. sweeping `block_switch_cycles` or toggling skipping).
pub fn layer_aggregate(layer: &MappedLayer, trace: &LayerTrace) -> TraceAggregate {
    let keys: Vec<(usize, Pattern)> =
        layer.blocks.iter().map(|b| (b.cin, b.pattern)).collect();
    trace.aggregate(&keys)
}

/// Closed-form simulation of one layer from a prebuilt aggregate: each
/// block contributes `executed × BlockCost` with no per-position work.
pub fn simulate_layer_aggregated(
    layer: &MappedLayer,
    spec_positions: usize,
    agg: &TraceAggregate,
    hw: &HardwareConfig,
    skip_zero_inputs: bool,
    block_switch_cycles: f64,
) -> LayerSimResult {
    let costs = block_costs(layer, hw);
    simulate_layer_with_costs(
        layer,
        spec_positions,
        &costs,
        agg,
        skip_zero_inputs,
        block_switch_cycles,
    )
}

/// Cost one layer for every image of a batch in a single closed-form
/// pass: the per-block OU cost tables are computed once and shared, so
/// each image's marginal work is O(blocks) histogram lookups. Results
/// are per-image in push order, and each is bit-exact with an
/// independent [`simulate_layer_aggregated`] call on that image's
/// aggregate (shared cost tables, identical accumulation order).
pub fn simulate_layer_batch(
    layer: &MappedLayer,
    spec_positions: usize,
    batch: &BatchAggregate,
    hw: &HardwareConfig,
    skip_zero_inputs: bool,
    block_switch_cycles: f64,
) -> Vec<LayerSimResult> {
    let costs = block_costs(layer, hw);
    batch
        .images()
        .iter()
        .map(|agg| {
            simulate_layer_with_costs(
                layer,
                spec_positions,
                &costs,
                agg,
                skip_zero_inputs,
                block_switch_cycles,
            )
        })
        .collect()
}

/// Shared closed-form core of [`simulate_layer_aggregated`] and
/// [`simulate_layer_batch`] — both must execute the exact same float
/// sequence for the batch-equals-singles invariant to hold bitwise.
fn simulate_layer_with_costs(
    layer: &MappedLayer,
    spec_positions: usize,
    costs: &[BlockCost],
    agg: &TraceAggregate,
    skip_zero_inputs: bool,
    block_switch_cycles: f64,
) -> LayerSimResult {
    let n_pos = agg.n_positions as u64;
    let mut ou_ops = 0u64;
    let mut skipped = 0u64;
    let mut executed_blocks = 0u64;
    let mut energy = EnergyLedger::default();
    for c in costs {
        let sk = if skip_zero_inputs {
            agg.skippable_positions(c.cin, c.pattern)
        } else {
            0
        };
        let exec = n_pos - sk;
        ou_ops += c.ou_ops as u64 * exec;
        skipped += c.ou_ops as u64 * sk;
        executed_blocks += exec;
        energy.add_scaled(&c.energy, exec as f64);
    }
    // Block switches: within a position's schedule every executed block
    // after the first is a boundary crossing, so the total is the
    // executed-block count minus the number of positions that execute
    // anything at all.
    let empty_positions = if costs.is_empty() {
        n_pos
    } else if skip_zero_inputs {
        agg.fully_skippable_positions()
    } else {
        0
    };
    let switches = executed_blocks - (n_pos - empty_positions);
    finish_result(
        layer,
        spec_positions,
        agg.n_positions,
        ou_ops,
        skipped,
        switches,
        energy,
        block_switch_cycles,
    )
}

/// Per-position oracle engine: the original O(positions × blocks) walk,
/// kept as the semantic reference the aggregated engine is pinned
/// against (and as the baseline in `benches/sim_hotpath.rs`).
pub fn simulate_layer_reference(
    layer: &MappedLayer,
    spec_positions: usize,
    trace: &LayerTrace,
    hw: &HardwareConfig,
    skip_zero_inputs: bool,
    block_switch_cycles: f64,
) -> LayerSimResult {
    let costs = block_costs(layer, hw);
    let mut ou_ops = 0u64;
    let mut skipped = 0u64;
    let mut switches = 0u64;
    let mut energy = EnergyLedger::default();

    for pos in 0..trace.n_positions {
        let mut executed_here = 0u64;
        for c in &costs {
            if skip_zero_inputs && trace.block_skippable(pos, c.cin, c.pattern) {
                skipped += c.ou_ops as u64;
                continue;
            }
            ou_ops += c.ou_ops as u64;
            executed_here += 1;
            energy.add(&c.energy);
        }
        // a switch only where the block actually changes: B executed
        // blocks cross B - 1 boundaries.
        switches += executed_here.saturating_sub(1);
    }
    finish_result(
        layer,
        spec_positions,
        trace.n_positions,
        ou_ops,
        skipped,
        switches,
        energy,
        block_switch_cycles,
    )
}

/// Scale sampled counts to the full feature map — shared by both
/// engines so equal integer counts give bit-identical results.
#[allow(clippy::too_many_arguments)]
fn finish_result(
    layer: &MappedLayer,
    spec_positions: usize,
    trace_positions: usize,
    ou_ops: u64,
    skipped: u64,
    switches: u64,
    energy: EnergyLedger,
    block_switch_cycles: f64,
) -> LayerSimResult {
    let scale = spec_positions as f64 / trace_positions.max(1) as f64;
    let ou_ops = ou_ops as f64 * scale;
    let skipped = skipped as f64 * scale;
    let cycles = ou_ops + switches as f64 * scale * block_switch_cycles;
    LayerSimResult {
        layer_idx: layer.layer_idx,
        ou_ops,
        skipped_ou_ops: skipped,
        cycles,
        energy: energy.scale(scale),
        n_crossbars: layer.n_crossbars,
    }
}

/// Does `scheme` have an Input Preprocessing Unit? Only IPU schemes
/// (everything but the naive Fig. 1 baseline) react to the
/// zero-detection and block-switch knobs — the single source of truth
/// shared by `ipu_policy` and the DSE grid expansion, which collapses
/// those axes for non-IPU schemes instead of evaluating duplicates.
pub fn scheme_has_ipu(scheme: &str) -> bool {
    scheme != "naive"
}

/// Shared scheme policy: only schemes with an Input Preprocessing Unit
/// get zero-input skipping and block-switch charges. Returns
/// `(skip_zero_inputs, block_switch_cycles)`.
fn ipu_policy(scheme: &str, sim: &SimConfig) -> (bool, f64) {
    let has_ipu = scheme_has_ipu(scheme);
    (
        sim.zero_detection && has_ipu,
        if has_ipu { sim.block_switch_cycles } else { 0.0 },
    )
}

/// Which `simulate_layer` implementation a network simulation uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimEngine {
    /// Trace-aggregated closed-form engine (production default).
    Aggregated,
    /// Per-position oracle loop (parity tests and perf baseline).
    Reference,
}

/// Simulate a whole mapped network with synthetic traces (layers in
/// parallel). `zero_detection` only applies to schemes with an Input
/// Preprocessing Unit (pattern / ou_sparse); the naive Fig. 1 baseline
/// runs with it off regardless.
pub fn simulate_network(
    mapped: &MappedNetwork,
    spec: &NetworkSpec,
    hw: &HardwareConfig,
    sim: &SimConfig,
    threads: usize,
) -> NetworkSimResult {
    simulate_network_with(SimEngine::Aggregated, mapped, spec, hw, sim, threads)
}

/// As [`simulate_network`] but with an explicit engine choice; both
/// engines see identical per-layer traces (seeded only from
/// `(sim.seed, layer index)`) so their results are directly comparable.
pub fn simulate_network_with(
    engine: SimEngine,
    mapped: &MappedNetwork,
    spec: &NetworkSpec,
    hw: &HardwareConfig,
    sim: &SimConfig,
    threads: usize,
) -> NetworkSimResult {
    let (skip, switch_cycles) = ipu_policy(&mapped.scheme, sim);

    let items: Vec<(usize, &MappedLayer)> =
        mapped.layers.iter().enumerate().collect();
    let layers = threadpool::parallel_map(&items, threads, |(li, ml)| {
        let layer = &spec.layers[*li];
        let positions = layer.positions();
        let n_samples = sim
            .sample_positions
            .map(|s| s.min(positions))
            .unwrap_or(positions);
        // Per-layer deterministic stream; the SAME trace must be used
        // for every scheme (and every engine), so seed only from
        // (sim.seed, layer index).
        let mut rng = Rng::seed_from(sim.seed ^ ((*li as u64 + 1) * 0x9E37));
        let trace = LayerTrace::synthetic(layer.cin, n_samples, sim, &mut rng);
        match engine {
            SimEngine::Aggregated => {
                simulate_layer(ml, positions, &trace, hw, skip, switch_cycles)
            }
            SimEngine::Reference => simulate_layer_reference(
                ml,
                positions,
                &trace,
                hw,
                skip,
                switch_cycles,
            ),
        }
    });

    NetworkSimResult {
        scheme: mapped.scheme.clone(),
        network: mapped.network.clone(),
        layers,
    }
}

/// Trace seed of image `image` within a batch whose base seed is
/// `base`. Image 0 keeps the base seed, so a 1-image batch reproduces
/// the plain single-image [`simulate_network`] run bit for bit; later
/// images get independent streams.
pub fn image_seed(base: u64, image: u64) -> u64 {
    if image == 0 {
        base
    } else {
        base ^ image.wrapping_mul(0xA076_1D64_78BD_642F)
    }
}

/// Simulate a batch of `n_images` images through a mapped network, one
/// closed-form pass per layer: per-block cost tables are computed once
/// per layer and shared by every image (layers in parallel, as in
/// [`simulate_network`]). Image `i`'s synthetic traces are seeded from
/// [`image_seed`]`(sim.seed, i)`, so its results are bit-exact with an
/// independent [`simulate_network`] run using that seed — and the batch
/// totals are bit-exact with summing those runs in image order
/// (`tests/prop_invariants.rs` pins both).
pub fn simulate_network_batch(
    mapped: &MappedNetwork,
    spec: &NetworkSpec,
    hw: &HardwareConfig,
    sim: &SimConfig,
    n_images: usize,
    threads: usize,
) -> BatchSimResult {
    let (skip, switch_cycles) = ipu_policy(&mapped.scheme, sim);

    let items: Vec<(usize, &MappedLayer)> =
        mapped.layers.iter().enumerate().collect();
    let per_layer: Vec<Vec<LayerSimResult>> =
        threadpool::parallel_map(&items, threads, |(li, ml)| {
            let layer = &spec.layers[*li];
            let positions = layer.positions();
            let n_samples = sim
                .sample_positions
                .map(|s| s.min(positions))
                .unwrap_or(positions);
            let mut batch = BatchAggregate::new();
            for img in 0..n_images {
                // Same per-layer stream derivation as simulate_network,
                // with the base seed replaced by the image seed.
                let mut rng = Rng::seed_from(
                    image_seed(sim.seed, img as u64)
                        ^ ((*li as u64 + 1) * 0x9E37),
                );
                let trace =
                    LayerTrace::synthetic(layer.cin, n_samples, sim, &mut rng);
                batch.push(layer_aggregate(ml, &trace));
            }
            simulate_layer_batch(ml, positions, &batch, hw, skip, switch_cycles)
        });

    collect_batch(mapped, n_images, per_layer)
}

/// Looped oracle for the batch engine: N independent
/// [`simulate_network`] runs, one per [`image_seed`], with total cycles
/// summed in image order. This is the single definition of the baseline
/// the batch invariant is cross-checked against (`batch-sim` CLI,
/// `benches/sim_hotpath.rs`); [`simulate_network_batch`] must equal it
/// bit for bit.
pub fn simulate_network_looped(
    mapped: &MappedNetwork,
    spec: &NetworkSpec,
    hw: &HardwareConfig,
    sim: &SimConfig,
    n_images: usize,
    threads: usize,
) -> f64 {
    let mut total = 0.0;
    for i in 0..n_images {
        let cfg_i =
            SimConfig { seed: image_seed(sim.seed, i as u64), ..sim.clone() };
        total += simulate_network(mapped, spec, hw, &cfg_i, threads)
            .total_cycles();
    }
    total
}

/// Transpose per-layer × per-image results into per-image network
/// results (shared by the synthetic and the SmallCNN exact batch paths).
fn collect_batch(
    mapped: &MappedNetwork,
    n_images: usize,
    per_layer: Vec<Vec<LayerSimResult>>,
) -> BatchSimResult {
    let per_image = (0..n_images)
        .map(|img| NetworkSimResult {
            scheme: mapped.scheme.clone(),
            network: mapped.network.clone(),
            layers: per_layer.iter().map(|l| l[img].clone()).collect(),
        })
        .collect();
    BatchSimResult {
        scheme: mapped.scheme.clone(),
        network: mapped.network.clone(),
        per_image,
    }
}

/// Head-to-head comparison of two schemes (paper Fig. 8 / §V-C).
#[derive(Debug, Clone)]
pub struct Comparison {
    pub baseline: NetworkSimResult,
    pub ours: NetworkSimResult,
}

impl Comparison {
    pub fn speedup(&self) -> f64 {
        self.baseline.total_cycles() / self.ours.total_cycles().max(1.0)
    }

    pub fn energy_efficiency(&self) -> f64 {
        self.baseline.total_energy().total_pj()
            / self.ours.total_energy().total_pj().max(1e-12)
    }

    pub fn area_efficiency(&self) -> f64 {
        self.baseline.total_crossbars() as f64
            / self.ours.total_crossbars().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::naive::NaiveMapping;
    use crate::mapping::pattern::PatternMapping;
    use crate::mapping::{MappingScheme, PatternBlock, Placement};
    use crate::nn::ConvLayer;
    use crate::pruning::synthetic::generate_layer;
    use crate::xbar::CellGeometry;

    fn setup() -> (ConvLayer, crate::nn::Tensor, CellGeometry, HardwareConfig) {
        let hw = HardwareConfig::default();
        let geom = CellGeometry::from_hw(&hw);
        let mut rng = Rng::seed_from(11);
        // Large enough that the naive mapping spans several crossbars —
        // area gains only materialize above one-crossbar scale.
        let w = generate_layer(256, 64, 6, 0.85, 0.4, &mut rng);
        let l = ConvLayer { name: "t".into(), cout: 256, cin: 64, fmap: 16 };
        (l, w, geom, hw)
    }

    #[test]
    fn dense_trace_matches_static_count() {
        let (l, w, geom, hw) = setup();
        let ml = PatternMapping.map_layer(0, &l, &w, &geom);
        let trace = LayerTrace::dense(l.cin, 4);
        let r = simulate_layer(&ml, l.positions(), &trace, &hw, true, 0.0);
        let want = ml.ou_ops_per_position() * l.positions();
        assert!((r.ou_ops - want as f64).abs() < 1e-6);
        assert_eq!(r.skipped_ou_ops, 0.0);
    }

    #[test]
    fn zero_detection_reduces_work() {
        let (l, w, geom, hw) = setup();
        let ml = PatternMapping.map_layer(0, &l, &w, &geom);
        let sim = SimConfig {
            zero_blob_ratio: 0.5,
            dead_channel_ratio: 0.0,
            ..Default::default()
        };
        let mut rng = Rng::seed_from(3);
        let trace = LayerTrace::synthetic(l.cin, 64, &sim, &mut rng);
        let off = simulate_layer(&ml, l.positions(), &trace, &hw, false, 0.0);
        let on = simulate_layer(&ml, l.positions(), &trace, &hw, true, 0.0);
        assert!(on.ou_ops < off.ou_ops * 0.8, "{} vs {}", on.ou_ops, off.ou_ops);
        assert!(on.skipped_ou_ops > 0.0);
        assert!(
            (on.ou_ops + on.skipped_ou_ops - off.ou_ops).abs() < 1e-6,
            "conservation"
        );
        assert!(on.energy.total_pj() < off.energy.total_pj());
    }

    #[test]
    fn block_switch_penalty_adds_cycles() {
        let (l, w, geom, hw) = setup();
        let ml = PatternMapping.map_layer(0, &l, &w, &geom);
        let trace = LayerTrace::dense(l.cin, 4);
        let r0 = simulate_layer(&ml, l.positions(), &trace, &hw, false, 0.0);
        let r5 = simulate_layer(&ml, l.positions(), &trace, &hw, false, 5.0);
        // Documented semantics: a switch is charged only when the
        // pattern block actually changes between consecutive executed
        // blocks, so a position executing B blocks crosses B - 1
        // boundaries — not B.
        let blocks_per_pos = ml.blocks.len() as f64;
        assert!(blocks_per_pos > 1.0, "need a multi-block layer");
        let want = r0.cycles + 5.0 * (blocks_per_pos - 1.0) * l.positions() as f64;
        assert!((r5.cycles - want).abs() / want < 1e-9);
        // the per-position oracle agrees exactly
        let rr = simulate_layer_reference(&ml, l.positions(), &trace, &hw, false, 5.0);
        assert_eq!(r5.cycles, rr.cycles);
    }

    #[test]
    fn single_block_layer_never_switches() {
        // One block means the scheduler never changes blocks, so switch
        // cycles must not be charged at all.
        let hw = HardwareConfig::default();
        let geom = CellGeometry::from_hw(&hw);
        let b = PatternBlock {
            cin: 0,
            pattern: Pattern(0b111),
            out_channels: vec![0, 1],
            weights: vec![1.0; 6],
        };
        let ml = MappedLayer {
            layer_idx: 0,
            cout: 2,
            cin: 1,
            geom,
            blocks: vec![b],
            placements: vec![Placement { xbar: 0, row: 0, col: 0, rows: 3, cols: 8 }],
            n_crossbars: 1,
            used_cells: 24,
            zero_kernels: 0,
        };
        let trace = LayerTrace::dense(1, 8);
        let r = simulate_layer(&ml, 8, &trace, &hw, false, 5.0);
        assert_eq!(r.cycles, r.ou_ops);
        let rr = simulate_layer_reference(&ml, 8, &trace, &hw, false, 5.0);
        assert_eq!(r.cycles, rr.cycles);
    }

    #[test]
    fn aggregated_engine_matches_reference() {
        let (l, w, geom, hw) = setup();
        let ml = PatternMapping.map_layer(0, &l, &w, &geom);
        let sim = SimConfig {
            zero_blob_ratio: 0.35,
            dead_channel_ratio: 0.1,
            ..Default::default()
        };
        let mut rng = Rng::seed_from(9);
        let trace = LayerTrace::synthetic(l.cin, 48, &sim, &mut rng);
        let a = simulate_layer(&ml, l.positions(), &trace, &hw, true, 2.0);
        let r = simulate_layer_reference(&ml, l.positions(), &trace, &hw, true, 2.0);
        assert_eq!(a.ou_ops, r.ou_ops);
        assert_eq!(a.skipped_ou_ops, r.skipped_ou_ops);
        assert_eq!(a.cycles, r.cycles);
        let rel = (a.energy.total_pj() - r.energy.total_pj()).abs()
            / r.energy.total_pj().max(1e-12);
        assert!(rel < 1e-9, "energy rel err {rel}");
    }

    #[test]
    fn prebuilt_aggregate_matches_inline_path() {
        let (l, w, geom, hw) = setup();
        let ml = PatternMapping.map_layer(0, &l, &w, &geom);
        let sim = SimConfig::default();
        let mut rng = Rng::seed_from(21);
        let trace = LayerTrace::synthetic(l.cin, 32, &sim, &mut rng);
        let agg = layer_aggregate(&ml, &trace);
        let a = simulate_layer_aggregated(&ml, l.positions(), &agg, &hw, true, 2.0);
        let b = simulate_layer(&ml, l.positions(), &trace, &hw, true, 2.0);
        assert_eq!(a.ou_ops, b.ou_ops);
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.energy, b.energy);
    }

    #[test]
    fn network_engines_agree() {
        let (l, w, geom, hw) = setup();
        let spec = NetworkSpec { name: "t".into(), layers: vec![l.clone()] };
        let nw = crate::pruning::NetworkWeights::new(spec.clone(), vec![w]);
        let mapped = PatternMapping.map_network(&nw, &geom, 1);
        let sim = SimConfig::default();
        let a = simulate_network_with(
            SimEngine::Aggregated,
            &mapped,
            &spec,
            &hw,
            &sim,
            1,
        );
        let r = simulate_network_with(
            SimEngine::Reference,
            &mapped,
            &spec,
            &hw,
            &sim,
            2,
        );
        assert_eq!(a.total_cycles(), r.total_cycles());
        assert_eq!(a.total_ou_ops(), r.total_ou_ops());
    }

    #[test]
    fn one_image_batch_reproduces_single_simulation() {
        let (l, w, geom, hw) = setup();
        let spec = NetworkSpec { name: "t".into(), layers: vec![l.clone()] };
        let nw = crate::pruning::NetworkWeights::new(spec.clone(), vec![w]);
        let mapped = PatternMapping.map_network(&nw, &geom, 1);
        let sim = SimConfig::default();
        let single = simulate_network(&mapped, &spec, &hw, &sim, 1);
        let batch = simulate_network_batch(&mapped, &spec, &hw, &sim, 1, 1);
        assert_eq!(batch.n_images(), 1);
        assert_eq!(batch.total_cycles(), single.total_cycles());
        assert_eq!(batch.total_ou_ops(), single.total_ou_ops());
        assert_eq!(batch.total_energy(), single.total_energy());
    }

    #[test]
    fn batch_totals_fold_per_image_results() {
        let (l, w, geom, hw) = setup();
        let spec = NetworkSpec { name: "t".into(), layers: vec![l.clone()] };
        let nw = crate::pruning::NetworkWeights::new(spec.clone(), vec![w]);
        let mapped = PatternMapping.map_network(&nw, &geom, 1);
        let sim = SimConfig::default();
        let batch = simulate_network_batch(&mapped, &spec, &hw, &sim, 3, 2);
        assert_eq!(batch.n_images(), 3);
        let sum: f64 = batch.per_image.iter().map(|r| r.total_cycles()).sum();
        assert_eq!(batch.total_cycles(), sum);
        assert!(batch.max_image_cycles() <= batch.total_cycles());
        assert!(
            batch.max_image_cycles() >= batch.mean_cycles_per_image(),
            "max {} < mean {}",
            batch.max_image_cycles(),
            batch.mean_cycles_per_image()
        );
        // distinct image seeds: not every image is identical in general,
        // but all of them must be positive work
        for r in &batch.per_image {
            assert!(r.total_cycles() > 0.0);
        }
        let j = batch.to_json();
        assert_eq!(j.get("n_images").as_usize(), Some(3));
        assert_eq!(j.get("per_image").as_arr().map(|a| a.len()), Some(3));
    }

    #[test]
    fn shard_plans_cover_items_and_balance() {
        let costs = [9.0, 1.0, 8.0, 2.0, 7.0, 3.0];
        let rr = ShardPlan::round_robin(&costs, 2);
        assert_eq!(rr.assignment, vec![0, 1, 0, 1, 0, 1]);
        assert_eq!(rr.loads, vec![24.0, 6.0]);
        assert_eq!(rr.max_load(), 24.0);
        let cb = ShardPlan::cost_balanced(&costs, 2);
        // LPT: 9→A, 8→B, 7→B? loads 9/8 → 7 to B(8)? no: least loaded
        // is B(8) after 9/8 → B=15, then 3→A(9)=12, 2→A? A=12,B=15 →
        // A=14, 1→A=15. Max 15 — the optimal split of 30 total.
        assert_eq!(cb.max_load(), 15.0);
        assert!(cb.max_load() <= rr.max_load());
        assert_eq!(cb.assignment.len(), costs.len());
        let total: f64 = cb.loads.iter().sum();
        assert!((total - 30.0).abs() < 1e-12);
        assert!((cb.mean_load() - 15.0).abs() < 1e-12);
        assert!((cb.imbalance() - 1.0).abs() < 1e-12);
        // re-evaluating the plan under the same costs reproduces loads
        assert_eq!(cb.loads_with(&costs), cb.loads);
        assert_eq!(cb.shard_sizes().iter().sum::<usize>(), costs.len());
        let j = cb.to_json();
        assert_eq!(j.get("n_shards").as_usize(), Some(2));
        assert_eq!(j.get("policy").as_str(), Some("cost"));
    }

    #[test]
    fn shard_plan_single_shard_and_empty() {
        let p = ShardPlan::cost_balanced(&[5.0, 5.0], 1);
        assert_eq!(p.loads, vec![10.0]);
        let e = ShardPlan::cost_balanced(&[], 4);
        assert_eq!(e.max_load(), 0.0);
        assert_eq!(e.assignment.len(), 0);
        // zero shards clamps to one
        let z = ShardPlan::round_robin(&[1.0], 0);
        assert_eq!(z.n_shards, 1);
    }

    #[test]
    fn batch_shard_plan_balances_predicted_costs() {
        let (l, w, geom, hw) = setup();
        let spec = NetworkSpec { name: "t".into(), layers: vec![l.clone()] };
        let nw = crate::pruning::NetworkWeights::new(spec.clone(), vec![w]);
        let mapped = PatternMapping.map_network(&nw, &geom, 1);
        let sim = SimConfig::default();
        let batch = simulate_network_batch(&mapped, &spec, &hw, &sim, 6, 2);
        let plan = batch.shard_plan(3, ShardPolicy::CostBalanced);
        let rr = batch.shard_plan(3, ShardPolicy::RoundRobin);
        assert_eq!(plan.assignment.len(), 6);
        assert!(plan.max_load() <= rr.max_load() + 1e-9);
        // achieved loads evaluate the same assignment on exact cycles
        let achieved = plan.loads_with(&batch.image_cycles());
        assert_eq!(achieved.len(), 3);
        let total: f64 = achieved.iter().sum();
        assert!((total - batch.total_cycles()).abs() < 1e-6);
    }

    #[test]
    fn cost_calibration_fits_per_layer_lines() {
        // three images on an exact linear cost surface: the fit must
        // recover each layer's intercept/slope and the summed model
        let zfs = [0.0, 0.25, 0.5];
        let mk = |cycles: f64, energy: f64| LayerSimResult {
            layer_idx: 0,
            ou_ops: cycles,
            skipped_ou_ops: 0.0,
            cycles,
            energy: EnergyLedger { adc_pj: energy, dac_pj: 0.0, rram_pj: 0.0 },
            n_crossbars: 1,
        };
        let per_image: Vec<Vec<LayerSimResult>> = zfs
            .iter()
            .map(|zf| {
                vec![
                    // layer 0: 1000 - 400·zf cycles, 100 - 40·zf pJ
                    mk(1000.0 - 400.0 * zf, 100.0 - 40.0 * zf),
                    // layer 1: 500 - 100·zf cycles, 50 - 10·zf pJ
                    LayerSimResult { layer_idx: 1, ..mk(500.0 - 100.0 * zf, 50.0 - 10.0 * zf) },
                ]
            })
            .collect();
        let cal = CostCalibration::from_samples(&zfs, &per_image);
        assert_eq!(cal.layers.len(), 2);
        assert!((cal.layers[0].cycles_at_dense - 1000.0).abs() < 1e-6);
        assert!((cal.layers[0].cycles_slope + 400.0).abs() < 1e-6);
        assert!((cal.layers[1].cycles_at_dense - 500.0).abs() < 1e-6);
        assert!((cal.layers[1].cycles_slope + 100.0).abs() < 1e-6);
        assert!((cal.total_cycles_at(0.0) - 1500.0).abs() < 1e-6);
        assert!((cal.total_cycles_at(0.5) - 1250.0).abs() < 1e-6);
        assert!((cal.total_energy_at(0.0) - 150.0).abs() < 1e-6);
        let j = cal.to_json();
        assert_eq!(j.as_arr().map(|a| a.len()), Some(2));
    }

    #[test]
    fn cost_calibration_degenerate_single_image() {
        // one image: constant predictor, no slope
        let per_image = vec![vec![LayerSimResult {
            layer_idx: 0,
            ou_ops: 100.0,
            skipped_ou_ops: 0.0,
            cycles: 100.0,
            energy: EnergyLedger::default(),
            n_crossbars: 1,
        }]];
        let cal = CostCalibration::from_samples(&[0.3], &per_image);
        assert_eq!(cal.layers[0].cycles_slope, 0.0);
        assert!((cal.layers[0].cycles_at_dense - 100.0).abs() < 1e-12);
    }

    #[test]
    fn image_seed_keeps_image_zero_on_base() {
        assert_eq!(image_seed(0x5EED, 0), 0x5EED);
        assert_ne!(image_seed(0x5EED, 1), 0x5EED);
        assert_ne!(image_seed(0x5EED, 1), image_seed(0x5EED, 2));
    }

    #[test]
    fn pattern_beats_naive_on_pruned_weights() {
        let (l, w, geom, hw) = setup();
        let spec = NetworkSpec { name: "t".into(), layers: vec![l.clone()] };
        let nw = crate::pruning::NetworkWeights::new(spec.clone(), vec![w]);
        let sim = SimConfig::default();
        let naive =
            simulate_network(&NaiveMapping.map_network(&nw, &geom, 1), &spec, &hw, &sim, 1);
        let ours = simulate_network(
            &PatternMapping.map_network(&nw, &geom, 1),
            &spec,
            &hw,
            &sim,
            1,
        );
        let cmp = Comparison { baseline: naive, ours };
        assert!(cmp.speedup() > 1.0, "speedup {}", cmp.speedup());
        assert!(cmp.energy_efficiency() > 1.5, "energy {}", cmp.energy_efficiency());
        assert!(cmp.area_efficiency() >= 1.0, "area {}", cmp.area_efficiency());
    }

    #[test]
    fn sampled_and_exact_agree_on_dense_trace() {
        // with a dense trace the sampling scale is exact
        let (l, w, geom, hw) = setup();
        let ml = PatternMapping.map_layer(0, &l, &w, &geom);
        let exact = simulate_layer(
            &ml,
            l.positions(),
            &LayerTrace::dense(l.cin, l.positions()),
            &hw,
            true,
            1.0,
        );
        let sampled = simulate_layer(
            &ml,
            l.positions(),
            &LayerTrace::dense(l.cin, 16),
            &hw,
            true,
            1.0,
        );
        assert!((exact.ou_ops - sampled.ou_ops).abs() < 1e-6);
        assert!((exact.cycles - sampled.cycles).abs() < 1e-6);
    }
}
