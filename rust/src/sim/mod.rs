//! Cycle & energy simulator (paper §V).
//!
//! Model (documented in DESIGN.md §5 and EXPERIMENTS.md):
//!
//! - **Cycles** — the chip is ADC-throughput-limited: every executed OU
//!   activation costs one cycle, plus `block_switch_cycles` control
//!   overhead whenever the scheduler crosses a pattern-block boundary
//!   (index decode + Input-Preprocessing reconfiguration; pattern scheme
//!   only — naive's dense walk needs no index decode).
//! - **Energy** — per executed OU, component-wise partial-activation
//!   energy from [`crate::xbar::energy::ou_op_energy`].
//! - **Skipping** — the pattern scheme never *stores* all-zero-pattern
//!   kernels (they cost nothing by construction), and with
//!   `zero_detection` skips blocks whose selected inputs are all zero.
//!   The naive baseline executes everything (paper Fig. 1 baseline has
//!   no Input Preprocessing Unit).
//!
//! Layers are simulated at `sample_positions` sampled output positions
//! and scaled to the full feature map (exact mode: `None`).

pub mod functional;
pub mod smallcnn;
pub mod workload;

use crate::config::{HardwareConfig, SimConfig};
use crate::mapping::{MappedLayer, MappedNetwork};
use crate::nn::NetworkSpec;
use crate::util::rng::Rng;
use crate::util::threadpool;
use crate::xbar::energy::{ou_op_energy, EnergyLedger};
use workload::LayerTrace;

/// Per-layer simulation result.
#[derive(Debug, Clone, Default)]
pub struct LayerSimResult {
    pub layer_idx: usize,
    /// Executed OU operations over the whole feature map.
    pub ou_ops: f64,
    /// OU operations skipped by all-zero input detection.
    pub skipped_ou_ops: f64,
    /// Total cycles (OU ops + block-switch overhead).
    pub cycles: f64,
    pub energy: EnergyLedger,
    pub n_crossbars: usize,
}

/// Whole-network simulation result.
#[derive(Debug, Clone, Default)]
pub struct NetworkSimResult {
    pub scheme: String,
    pub network: String,
    pub layers: Vec<LayerSimResult>,
}

impl NetworkSimResult {
    pub fn total_cycles(&self) -> f64 {
        self.layers.iter().map(|l| l.cycles).sum()
    }

    pub fn total_ou_ops(&self) -> f64 {
        self.layers.iter().map(|l| l.ou_ops).sum()
    }

    pub fn total_energy(&self) -> EnergyLedger {
        let mut e = EnergyLedger::default();
        for l in &self.layers {
            e.add(&l.energy);
        }
        e
    }

    pub fn total_crossbars(&self) -> usize {
        self.layers.iter().map(|l| l.n_crossbars).sum()
    }
}

/// Precomputed per-block OU cost (hot-path optimization: the OU schedule
/// of a block does not depend on the position, only skipping does).
#[derive(Debug, Clone, Copy)]
struct BlockCost {
    ou_ops: usize,
    energy: EnergyLedger,
    cin: usize,
    pattern: crate::pruning::Pattern,
}

fn block_costs(layer: &MappedLayer, hw: &HardwareConfig) -> Vec<BlockCost> {
    let geom = &layer.geom;
    layer
        .blocks
        .iter()
        .map(|b| {
            let h = b.rows();
            let w_cells = geom.weight_cols(b.kernels());
            let mut ou_ops = 0usize;
            let mut energy = EnergyLedger::default();
            let mut row_off = 0;
            while row_off < h {
                let rows = (h - row_off).min(geom.ou_rows);
                let mut col_off = 0;
                while col_off < w_cells {
                    let cols = (w_cells - col_off).min(geom.ou_cols);
                    ou_ops += 1;
                    energy.add(&ou_op_energy(hw, rows, cols));
                    col_off += cols;
                }
                row_off += rows;
            }
            BlockCost { ou_ops, energy, cin: b.cin, pattern: b.pattern }
        })
        .collect()
}

/// Simulate one mapped layer against an activation trace.
///
/// `skip_zero_inputs` enables the Input Preprocessing Unit's all-zero
/// detection; `block_switch_cycles` models the §IV-C index-decode walk.
pub fn simulate_layer(
    layer: &MappedLayer,
    spec_positions: usize,
    trace: &LayerTrace,
    hw: &HardwareConfig,
    skip_zero_inputs: bool,
    block_switch_cycles: f64,
) -> LayerSimResult {
    let costs = block_costs(layer, hw);
    let mut ou_ops = 0u64;
    let mut skipped = 0u64;
    let mut switches = 0u64;
    let mut energy = EnergyLedger::default();

    for pos in 0..trace.n_positions {
        for c in &costs {
            if skip_zero_inputs && trace.block_skippable(pos, c.cin, c.pattern) {
                skipped += c.ou_ops as u64;
                continue;
            }
            ou_ops += c.ou_ops as u64;
            switches += 1;
            energy.add(&c.energy);
        }
    }

    // Scale from sampled positions to the full feature map.
    let scale = spec_positions as f64 / trace.n_positions.max(1) as f64;
    let ou_ops = ou_ops as f64 * scale;
    let skipped = skipped as f64 * scale;
    let cycles = ou_ops + switches as f64 * scale * block_switch_cycles;
    LayerSimResult {
        layer_idx: layer.layer_idx,
        ou_ops,
        skipped_ou_ops: skipped,
        cycles,
        energy: energy.scale(scale),
        n_crossbars: layer.n_crossbars,
    }
}

/// Simulate a whole mapped network with synthetic traces (layers in
/// parallel). `zero_detection` only applies to schemes with an Input
/// Preprocessing Unit (pattern / ou_sparse); the naive Fig. 1 baseline
/// runs with it off regardless.
pub fn simulate_network(
    mapped: &MappedNetwork,
    spec: &NetworkSpec,
    hw: &HardwareConfig,
    sim: &SimConfig,
    threads: usize,
) -> NetworkSimResult {
    let has_ipu = mapped.scheme != "naive";
    let skip = sim.zero_detection && has_ipu;
    let switch_cycles = if has_ipu { sim.block_switch_cycles } else { 0.0 };

    let items: Vec<(usize, &MappedLayer)> =
        mapped.layers.iter().enumerate().collect();
    let layers = threadpool::parallel_map(&items, threads, |(li, ml)| {
        let layer = &spec.layers[*li];
        let positions = layer.positions();
        let n_samples = sim
            .sample_positions
            .map(|s| s.min(positions))
            .unwrap_or(positions);
        // Per-layer deterministic stream; the SAME trace must be used
        // for every scheme, so seed only from (sim.seed, layer index).
        let mut rng = Rng::seed_from(sim.seed ^ ((*li as u64 + 1) * 0x9E37));
        let trace = LayerTrace::synthetic(layer.cin, n_samples, sim, &mut rng);
        simulate_layer(ml, positions, &trace, hw, skip, switch_cycles)
    });

    NetworkSimResult {
        scheme: mapped.scheme.clone(),
        network: mapped.network.clone(),
        layers,
    }
}

/// Head-to-head comparison of two schemes (paper Fig. 8 / §V-C).
#[derive(Debug, Clone)]
pub struct Comparison {
    pub baseline: NetworkSimResult,
    pub ours: NetworkSimResult,
}

impl Comparison {
    pub fn speedup(&self) -> f64 {
        self.baseline.total_cycles() / self.ours.total_cycles().max(1.0)
    }

    pub fn energy_efficiency(&self) -> f64 {
        self.baseline.total_energy().total_pj()
            / self.ours.total_energy().total_pj().max(1e-12)
    }

    pub fn area_efficiency(&self) -> f64 {
        self.baseline.total_crossbars() as f64
            / self.ours.total_crossbars().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::naive::NaiveMapping;
    use crate::mapping::pattern::PatternMapping;
    use crate::mapping::MappingScheme;
    use crate::nn::ConvLayer;
    use crate::pruning::synthetic::generate_layer;
    use crate::xbar::CellGeometry;

    fn setup() -> (ConvLayer, crate::nn::Tensor, CellGeometry, HardwareConfig) {
        let hw = HardwareConfig::default();
        let geom = CellGeometry::from_hw(&hw);
        let mut rng = Rng::seed_from(11);
        // Large enough that the naive mapping spans several crossbars —
        // area gains only materialize above one-crossbar scale.
        let w = generate_layer(256, 64, 6, 0.85, 0.4, &mut rng);
        let l = ConvLayer { name: "t".into(), cout: 256, cin: 64, fmap: 16 };
        (l, w, geom, hw)
    }

    #[test]
    fn dense_trace_matches_static_count() {
        let (l, w, geom, hw) = setup();
        let ml = PatternMapping.map_layer(0, &l, &w, &geom);
        let trace = LayerTrace::dense(l.cin, 4);
        let r = simulate_layer(&ml, l.positions(), &trace, &hw, true, 0.0);
        let want = ml.ou_ops_per_position() * l.positions();
        assert!((r.ou_ops - want as f64).abs() < 1e-6);
        assert_eq!(r.skipped_ou_ops, 0.0);
    }

    #[test]
    fn zero_detection_reduces_work() {
        let (l, w, geom, hw) = setup();
        let ml = PatternMapping.map_layer(0, &l, &w, &geom);
        let sim = SimConfig {
            zero_blob_ratio: 0.5,
            dead_channel_ratio: 0.0,
            ..Default::default()
        };
        let mut rng = Rng::seed_from(3);
        let trace = LayerTrace::synthetic(l.cin, 64, &sim, &mut rng);
        let off = simulate_layer(&ml, l.positions(), &trace, &hw, false, 0.0);
        let on = simulate_layer(&ml, l.positions(), &trace, &hw, true, 0.0);
        assert!(on.ou_ops < off.ou_ops * 0.8, "{} vs {}", on.ou_ops, off.ou_ops);
        assert!(on.skipped_ou_ops > 0.0);
        assert!(
            (on.ou_ops + on.skipped_ou_ops - off.ou_ops).abs() < 1e-6,
            "conservation"
        );
        assert!(on.energy.total_pj() < off.energy.total_pj());
    }

    #[test]
    fn block_switch_penalty_adds_cycles() {
        let (l, w, geom, hw) = setup();
        let ml = PatternMapping.map_layer(0, &l, &w, &geom);
        let trace = LayerTrace::dense(l.cin, 4);
        let r0 = simulate_layer(&ml, l.positions(), &trace, &hw, false, 0.0);
        let r5 = simulate_layer(&ml, l.positions(), &trace, &hw, false, 5.0);
        let blocks_per_pos = ml.blocks.len() as f64;
        let want = r0.cycles + 5.0 * blocks_per_pos * l.positions() as f64;
        assert!((r5.cycles - want).abs() / want < 1e-9);
    }

    #[test]
    fn pattern_beats_naive_on_pruned_weights() {
        let (l, w, geom, hw) = setup();
        let spec = NetworkSpec { name: "t".into(), layers: vec![l.clone()] };
        let nw = crate::pruning::NetworkWeights::new(spec.clone(), vec![w]);
        let sim = SimConfig::default();
        let naive =
            simulate_network(&NaiveMapping.map_network(&nw, &geom, 1), &spec, &hw, &sim, 1);
        let ours = simulate_network(
            &PatternMapping.map_network(&nw, &geom, 1),
            &spec,
            &hw,
            &sim,
            1,
        );
        let cmp = Comparison { baseline: naive, ours };
        assert!(cmp.speedup() > 1.0, "speedup {}", cmp.speedup());
        assert!(cmp.energy_efficiency() > 1.5, "energy {}", cmp.energy_efficiency());
        assert!(cmp.area_efficiency() >= 1.0, "area {}", cmp.area_efficiency());
    }

    #[test]
    fn sampled_and_exact_agree_on_dense_trace() {
        // with a dense trace the sampling scale is exact
        let (l, w, geom, hw) = setup();
        let ml = PatternMapping.map_layer(0, &l, &w, &geom);
        let exact = simulate_layer(
            &ml,
            l.positions(),
            &LayerTrace::dense(l.cin, l.positions()),
            &hw,
            true,
            1.0,
        );
        let sampled = simulate_layer(
            &ml,
            l.positions(),
            &LayerTrace::dense(l.cin, 16),
            &hw,
            true,
            1.0,
        );
        assert!((exact.ou_ops - sampled.ou_ops).abs() < 1e-6);
        assert!((exact.cycles - sampled.cycles).abs() < 1e-6);
    }
}
