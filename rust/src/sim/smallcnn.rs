//! Full SmallCNN forward on the simulated accelerator (e2e path).
//!
//! Loads the pattern-pruned weights trained by `make artifacts`, maps
//! every conv layer with the paper's scheme, and runs images through the
//! functional OU simulator (conv → bias+ReLU → pool, then GAP → FC in
//! the digital domain), producing logits comparable to the PJRT
//! execution of the AOT artifact and to the python golden logits.

use std::collections::BTreeMap;
use std::path::Path;

use super::functional::{
    conv_forward, conv_forward_rows, relu_bias_pool, LayerScales,
};
use super::workload::LayerTrace;
use super::{layer_aggregate, simulate_layer_aggregated, LayerSimResult};
use crate::config::{HardwareConfig, SimConfig};
use crate::mapping::{MappedNetwork, MappingScheme};
use crate::nn::tensor_io::{load_tensors, AnyTensor};
use crate::nn::{im2col, NetworkSpec, Tensor};
use crate::pruning::NetworkWeights;
use crate::util::json::Json;
use crate::xbar::CellGeometry;

/// SmallCNN model bundle: weights + metadata + mapped layers.
pub struct SmallCnn {
    pub spec: NetworkSpec,
    pub weights: NetworkWeights,
    pub biases: Vec<Vec<f32>>,
    pub fc_w: Tensor,
    pub fc_b: Vec<f32>,
    pub scales: Vec<LayerScales>,
    /// Which conv stages are followed by a 2×2 max-pool.
    pub pool_after: Vec<bool>,
    pub n_classes: usize,
    pub meta: Json,
}

impl SmallCnn {
    /// Load from `artifacts/` (weights bin + meta json).
    pub fn load(artifacts_dir: &Path) -> Result<SmallCnn, String> {
        let meta_text =
            std::fs::read_to_string(artifacts_dir.join("smallcnn_meta.json"))
                .map_err(|e| format!("read meta: {e}"))?;
        let meta = Json::parse(&meta_text).map_err(|e| e.to_string())?;
        let tensors = load_tensors(&artifacts_dir.join("smallcnn_weights.bin"))
            .map_err(|e| e.to_string())?;
        Self::from_parts(meta, &tensors)
    }

    pub fn from_parts(
        meta: Json,
        tensors: &BTreeMap<String, AnyTensor>,
    ) -> Result<SmallCnn, String> {
        let spec = NetworkSpec::from_meta(&meta)?;
        let arch = meta.get("arch").as_arr().ok_or("meta missing arch")?;
        // pool flags: 'M' entries pool the *previous* conv stage
        let mut pool_after = Vec::new();
        for item in arch {
            if item.as_str() == Some("M") {
                if let Some(last) = pool_after.last_mut() {
                    *last = true;
                }
            } else {
                pool_after.push(false);
            }
        }

        let mut layers = Vec::new();
        let mut biases = Vec::new();
        let mut scales = Vec::new();
        for (i, _l) in spec.layers.iter().enumerate() {
            let name = format!("conv{i}");
            let w = tensors
                .get(&format!("{name}/w"))
                .and_then(|t| t.as_f32())
                .ok_or(format!("missing {name}/w"))?;
            let b = tensors
                .get(&format!("{name}/b"))
                .and_then(|t| t.as_f32())
                .ok_or(format!("missing {name}/b"))?;
            layers.push(w.clone());
            biases.push(b.data.clone());
            let sc = meta.get("scales").get(&name);
            scales.push(LayerScales {
                sx: sc.idx(0).as_f64().ok_or("missing scale sx")? as f32,
                sw: sc.idx(1).as_f64().ok_or("missing scale sw")? as f32,
            });
        }
        let fc_w = tensors
            .get("fc/w")
            .and_then(|t| t.as_f32())
            .ok_or("missing fc/w")?
            .clone();
        let fc_b = tensors
            .get("fc/b")
            .and_then(|t| t.as_f32())
            .ok_or("missing fc/b")?
            .data
            .clone();
        let n_classes = meta.get("n_classes").as_usize().unwrap_or(10);
        let weights = NetworkWeights::new(spec.clone(), layers);
        Ok(SmallCnn {
            spec,
            weights,
            biases,
            fc_w,
            fc_b,
            scales,
            pool_after,
            n_classes,
            meta,
        })
    }

    /// Map all conv layers with a given scheme.
    pub fn map(&self, scheme: &dyn MappingScheme, hw: &HardwareConfig) -> MappedNetwork {
        let geom = CellGeometry::from_hw(hw);
        scheme.map_network(&self.weights, &geom, 1)
    }

    /// Run one image (NCHW `[1, 3, 32, 32]`) through the mapped
    /// accelerator; returns logits.
    pub fn forward(
        &self,
        mapped: &MappedNetwork,
        x: &Tensor,
        hw: &HardwareConfig,
        quantized: bool,
    ) -> Vec<f32> {
        let mut cur = Tensor {
            shape: vec![1, x.shape[1], x.shape[2], x.shape[3]],
            data: x.data.clone(),
        };
        for (li, ml) in mapped.layers.iter().enumerate() {
            let conv = conv_forward(ml, &cur, 0, self.scales[li], hw, quantized);
            let staged = relu_bias_pool(&conv, &self.biases[li], self.pool_after[li]);
            cur = Tensor {
                shape: vec![1, staged.shape[0], staged.shape[1], staged.shape[2]],
                data: staged.data,
            };
        }
        // global average pool + FC (digital domain)
        let (c, h, w) = (cur.shape[1], cur.shape[2], cur.shape[3]);
        let mut feat = vec![0.0f32; c];
        for ch in 0..c {
            let s: f32 = cur.data[ch * h * w..(ch + 1) * h * w].iter().sum();
            feat[ch] = s / (h * w) as f32;
        }
        let nc = self.n_classes;
        let mut logits = self.fc_b.clone();
        for ch in 0..c {
            for k in 0..nc {
                logits[k] += feat[ch] * self.fc_w.data[ch * nc + k];
            }
        }
        logits
    }

    /// Exact-mode cycle/energy simulation of one image through every
    /// mapped conv layer: activations come from the functional float
    /// forward, each layer's real trace is aggregated once
    /// ([`layer_aggregate`]) and costed in closed form — the same
    /// trace-aggregated engine as the analytic VGG16 sweeps, with no
    /// per-position accounting loop. Like [`crate::sim::simulate_network`],
    /// zero-input skipping and block-switch cycles apply only to schemes
    /// with an Input Preprocessing Unit (not the naive baseline), and
    /// each layer's im2col rows are extracted once and shared between
    /// the trace and the compute.
    pub fn simulate_exact(
        &self,
        mapped: &MappedNetwork,
        x: &Tensor,
        hw: &HardwareConfig,
        sim_cfg: &SimConfig,
    ) -> Vec<LayerSimResult> {
        assert_eq!(x.shape[0], 1, "simulate_exact takes a single image");
        let has_ipu = mapped.scheme != "naive";
        let skip = sim_cfg.zero_detection && has_ipu;
        let switch_cycles = if has_ipu { sim_cfg.block_switch_cycles } else { 0.0 };
        let mut cur = Tensor {
            shape: vec![1, x.shape[1], x.shape[2], x.shape[3]],
            data: x.data.clone(),
        };
        let mut results = Vec::with_capacity(mapped.layers.len());
        for (li, ml) in mapped.layers.iter().enumerate() {
            let (h, w) = (cur.shape[2], cur.shape[3]);
            let rows = im2col(&cur, 0);
            let trace = LayerTrace::from_rows(&rows, cur.shape[1]);
            let agg = layer_aggregate(ml, &trace);
            results.push(simulate_layer_aggregated(
                ml,
                trace.n_positions,
                &agg,
                hw,
                skip,
                switch_cycles,
            ));
            let conv =
                conv_forward_rows(ml, &rows, h, w, self.scales[li], hw, false);
            let staged =
                relu_bias_pool(&conv, &self.biases[li], self.pool_after[li]);
            cur = Tensor {
                shape: vec![1, staged.shape[0], staged.shape[1], staged.shape[2]],
                data: staged.data,
            };
        }
        results
    }
}

/// Test data bundle exported by `aot.py`.
pub struct TestData {
    pub test_x: Tensor,
    pub test_y: Vec<i32>,
    pub golden_x: Tensor,
    pub golden_logits: Tensor,
}

impl TestData {
    pub fn load(artifacts_dir: &Path) -> Result<TestData, String> {
        let t = load_tensors(&artifacts_dir.join("test_data.bin"))
            .map_err(|e| e.to_string())?;
        let get_f32 = |k: &str| -> Result<Tensor, String> {
            t.get(k)
                .and_then(|a| a.as_f32())
                .cloned()
                .ok_or(format!("missing {k}"))
        };
        Ok(TestData {
            test_x: get_f32("test_x")?,
            test_y: t
                .get("test_y")
                .and_then(|a| a.as_i32())
                .ok_or("missing test_y")?
                .to_vec(),
            golden_x: get_f32("golden_x")?,
            golden_logits: get_f32("golden_logits")?,
        })
    }
}

/// Extract image `i` of an `[N, C, H, W]` batch as `[1, C, H, W]`.
pub fn image(batch: &Tensor, i: usize) -> Tensor {
    let (c, h, w) = (batch.shape[1], batch.shape[2], batch.shape[3]);
    let n = c * h * w;
    Tensor::from_vec(&[1, c, h, w], batch.data[i * n..(i + 1) * n].to_vec())
}

pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in v.iter().enumerate() {
        if *x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[3.0]), 0);
        assert_eq!(argmax(&[1.0, 1.0]), 0);
    }

    #[test]
    fn image_slicing() {
        let b = Tensor::from_vec(&[2, 1, 2, 2],
                                 vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let i1 = image(&b, 1);
        assert_eq!(i1.shape, vec![1, 1, 2, 2]);
        assert_eq!(i1.data, vec![5., 6., 7., 8.]);
    }
    // full-bundle tests live in tests/e2e.rs (require artifacts/)
}
