//! Full SmallCNN forward on the simulated accelerator (e2e path).
//!
//! Loads the pattern-pruned weights trained by `make artifacts`, maps
//! every conv layer with the paper's scheme, and runs images through the
//! functional OU simulator (conv → bias+ReLU → pool, then GAP → FC in
//! the digital domain), producing logits comparable to the PJRT
//! execution of the AOT artifact and to the python golden logits.

use std::collections::BTreeMap;
use std::path::Path;

use super::functional::{
    conv_forward, conv_forward_rows, relu_bias_pool, LayerScales,
};
use super::workload::{BatchAggregate, LayerTrace, TraceAggregate};
use super::{
    layer_aggregate, simulate_layer_aggregated, simulate_layer_batch,
    BatchSimResult, CostCalibration, LayerSimResult,
};
use crate::config::{HardwareConfig, SimConfig};
use crate::mapping::{MappedNetwork, MappingScheme};
use crate::nn::tensor_io::{load_tensors, AnyTensor};
use crate::nn::{im2col, NetworkSpec, Tensor};
use crate::pruning::synthetic::generate_layer;
use crate::pruning::NetworkWeights;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::threadpool;
use crate::xbar::CellGeometry;

/// SmallCNN model bundle: weights + metadata + mapped layers.
pub struct SmallCnn {
    pub spec: NetworkSpec,
    pub weights: NetworkWeights,
    pub biases: Vec<Vec<f32>>,
    pub fc_w: Tensor,
    pub fc_b: Vec<f32>,
    pub scales: Vec<LayerScales>,
    /// Which conv stages are followed by a 2×2 max-pool.
    pub pool_after: Vec<bool>,
    pub n_classes: usize,
    pub meta: Json,
}

impl SmallCnn {
    /// Load from `artifacts/` (weights bin + meta json).
    pub fn load(artifacts_dir: &Path) -> Result<SmallCnn, String> {
        let meta_text =
            std::fs::read_to_string(artifacts_dir.join("smallcnn_meta.json"))
                .map_err(|e| format!("read meta: {e}"))?;
        let meta = Json::parse(&meta_text).map_err(|e| e.to_string())?;
        let tensors = load_tensors(&artifacts_dir.join("smallcnn_weights.bin"))
            .map_err(|e| e.to_string())?;
        Self::from_parts(meta, &tensors)
    }

    pub fn from_parts(
        meta: Json,
        tensors: &BTreeMap<String, AnyTensor>,
    ) -> Result<SmallCnn, String> {
        let spec = NetworkSpec::from_meta(&meta)?;
        let arch = meta.get("arch").as_arr().ok_or("meta missing arch")?;
        // pool flags: 'M' entries pool the *previous* conv stage
        let mut pool_after = Vec::new();
        for item in arch {
            if item.as_str() == Some("M") {
                if let Some(last) = pool_after.last_mut() {
                    *last = true;
                }
            } else {
                pool_after.push(false);
            }
        }

        let mut layers = Vec::new();
        let mut biases = Vec::new();
        let mut scales = Vec::new();
        for (i, _l) in spec.layers.iter().enumerate() {
            let name = format!("conv{i}");
            let w = tensors
                .get(&format!("{name}/w"))
                .and_then(|t| t.as_f32())
                .ok_or(format!("missing {name}/w"))?;
            let b = tensors
                .get(&format!("{name}/b"))
                .and_then(|t| t.as_f32())
                .ok_or(format!("missing {name}/b"))?;
            layers.push(w.clone());
            biases.push(b.data.clone());
            let sc = meta.get("scales").get(&name);
            scales.push(LayerScales {
                sx: sc.idx(0).as_f64().ok_or("missing scale sx")? as f32,
                sw: sc.idx(1).as_f64().ok_or("missing scale sw")? as f32,
            });
        }
        let fc_w = tensors
            .get("fc/w")
            .and_then(|t| t.as_f32())
            .ok_or("missing fc/w")?
            .clone();
        let fc_b = tensors
            .get("fc/b")
            .and_then(|t| t.as_f32())
            .ok_or("missing fc/b")?
            .data
            .clone();
        let n_classes = meta.get("n_classes").as_usize().unwrap_or(10);
        let weights = NetworkWeights::new(spec.clone(), layers);
        Ok(SmallCnn {
            spec,
            weights,
            biases,
            fc_w,
            fc_b,
            scales,
            pool_after,
            n_classes,
            meta,
        })
    }

    /// Map all conv layers with a given scheme.
    pub fn map(&self, scheme: &dyn MappingScheme, hw: &HardwareConfig) -> MappedNetwork {
        let geom = CellGeometry::from_hw(hw);
        scheme.map_network(&self.weights, &geom, 1)
    }

    /// Run one image (NCHW `[1, 3, 32, 32]`) through the mapped
    /// accelerator; returns logits.
    pub fn forward(
        &self,
        mapped: &MappedNetwork,
        x: &Tensor,
        hw: &HardwareConfig,
        quantized: bool,
    ) -> Vec<f32> {
        let mut cur = Tensor {
            shape: vec![1, x.shape[1], x.shape[2], x.shape[3]],
            data: x.data.clone(),
        };
        for (li, ml) in mapped.layers.iter().enumerate() {
            let conv = conv_forward(ml, &cur, 0, self.scales[li], hw, quantized);
            let staged = relu_bias_pool(&conv, &self.biases[li], self.pool_after[li]);
            cur = Tensor {
                shape: vec![1, staged.shape[0], staged.shape[1], staged.shape[2]],
                data: staged.data,
            };
        }
        // global average pool + FC (digital domain)
        let (c, h, w) = (cur.shape[1], cur.shape[2], cur.shape[3]);
        let mut feat = vec![0.0f32; c];
        for ch in 0..c {
            let s: f32 = cur.data[ch * h * w..(ch + 1) * h * w].iter().sum();
            feat[ch] = s / (h * w) as f32;
        }
        let nc = self.n_classes;
        let mut logits = self.fc_b.clone();
        for ch in 0..c {
            for k in 0..nc {
                logits[k] += feat[ch] * self.fc_w.data[ch * nc + k];
            }
        }
        logits
    }

    /// Per-layer exact activation traces for one image: the functional
    /// float forward drives each layer, and its im2col rows — extracted
    /// once and shared between the trace and the compute — feed
    /// [`LayerTrace::from_rows`]. This is the per-image feeder for both
    /// [`SmallCnn::simulate_exact`] and the batched
    /// [`SmallCnn::simulate_exact_batch`].
    pub fn exact_traces(
        &self,
        mapped: &MappedNetwork,
        x: &Tensor,
        hw: &HardwareConfig,
    ) -> Vec<LayerTrace> {
        assert_eq!(x.shape[0], 1, "exact_traces takes a single image");
        let mut cur = Tensor {
            shape: vec![1, x.shape[1], x.shape[2], x.shape[3]],
            data: x.data.clone(),
        };
        let mut traces = Vec::with_capacity(mapped.layers.len());
        for (li, ml) in mapped.layers.iter().enumerate() {
            let (h, w) = (cur.shape[2], cur.shape[3]);
            let rows = im2col(&cur, 0);
            traces.push(LayerTrace::from_rows(&rows, cur.shape[1]));
            let conv =
                conv_forward_rows(ml, &rows, h, w, self.scales[li], hw, false);
            let staged =
                relu_bias_pool(&conv, &self.biases[li], self.pool_after[li]);
            cur = Tensor {
                shape: vec![1, staged.shape[0], staged.shape[1], staged.shape[2]],
                data: staged.data,
            };
        }
        traces
    }

    /// Exact-mode cycle/energy simulation of one image through every
    /// mapped conv layer: real per-layer traces ([`SmallCnn::exact_traces`])
    /// aggregated once ([`layer_aggregate`]) and costed in closed form —
    /// the same trace-aggregated engine as the analytic VGG16 sweeps,
    /// with no per-position accounting loop. Like
    /// [`crate::sim::simulate_network`], zero-input skipping and
    /// block-switch cycles apply only to schemes with an Input
    /// Preprocessing Unit (not the naive baseline).
    pub fn simulate_exact(
        &self,
        mapped: &MappedNetwork,
        x: &Tensor,
        hw: &HardwareConfig,
        sim_cfg: &SimConfig,
    ) -> Vec<LayerSimResult> {
        assert_eq!(x.shape[0], 1, "simulate_exact takes a single image");
        let (skip, switch_cycles) = super::ipu_policy(&mapped.scheme, sim_cfg);
        let traces = self.exact_traces(mapped, x, hw);
        mapped
            .layers
            .iter()
            .zip(traces.iter())
            .map(|(ml, trace)| {
                let agg = layer_aggregate(ml, trace);
                simulate_layer_aggregated(
                    ml,
                    trace.n_positions,
                    &agg,
                    hw,
                    skip,
                    switch_cycles,
                )
            })
            .collect()
    }

    /// Batched exact simulation of `[N, C, H, W]` images: per-image
    /// traces from the functional forward (images in parallel over
    /// `threads` workers — each image's forward is independent, and
    /// results are collected in image order so bit-exactness holds) are
    /// accumulated per layer into a [`BatchAggregate`] and costed in one
    /// closed-form pass per layer ([`simulate_layer_batch`], shared
    /// per-block cost tables). The per-image results are bit-exact with
    /// N independent [`SmallCnn::simulate_exact`] calls.
    pub fn simulate_exact_batch(
        &self,
        mapped: &MappedNetwork,
        batch_x: &Tensor,
        hw: &HardwareConfig,
        sim_cfg: &SimConfig,
        threads: usize,
    ) -> BatchSimResult {
        let (skip, switch_cycles) = super::ipu_policy(&mapped.scheme, sim_cfg);
        let n = batch_x.shape[0];
        let n_layers = mapped.layers.len();
        let idxs: Vec<usize> = (0..n).collect();
        let per_image_aggs: Vec<Vec<(usize, TraceAggregate)>> =
            threadpool::parallel_map(&idxs, threads, |i| {
                let img = image(batch_x, *i);
                self.exact_traces(mapped, &img, hw)
                    .into_iter()
                    .enumerate()
                    .map(|(li, t)| {
                        (t.n_positions, layer_aggregate(&mapped.layers[li], &t))
                    })
                    .collect()
            });
        let mut batches: Vec<BatchAggregate> =
            (0..n_layers).map(|_| BatchAggregate::new()).collect();
        let mut positions = vec![0usize; n_layers];
        for img_aggs in per_image_aggs {
            for (li, (pos, agg)) in img_aggs.into_iter().enumerate() {
                positions[li] = pos;
                batches[li].push(agg);
            }
        }
        let per_layer: Vec<Vec<LayerSimResult>> = mapped
            .layers
            .iter()
            .enumerate()
            .map(|(li, ml)| {
                simulate_layer_batch(
                    ml,
                    positions[li],
                    &batches[li],
                    hw,
                    skip,
                    switch_cycles,
                )
            })
            .collect();
        super::collect_batch(mapped, n, per_layer)
    }

    /// Calibrate the serving cost model from **real** activation
    /// traces: run the exact-mode batch simulation over the
    /// `[N, C, H, W]` calibration images and regress every layer's
    /// cycles/energy against each image's *input* zero fraction — the
    /// only signal the coordinator's submit-path cost model sees. The
    /// result feeds `coordinator::CostModel::from_calibration`,
    /// replacing the synthetic first-order slope of
    /// `CostModel::from_sim`.
    pub fn calibrate(
        &self,
        mapped: &MappedNetwork,
        batch_x: &Tensor,
        hw: &HardwareConfig,
        sim_cfg: &SimConfig,
        threads: usize,
    ) -> CostCalibration {
        let n = batch_x.shape[0];
        let img_len: usize = batch_x.shape[1..].iter().product();
        let zfs: Vec<f64> = (0..n)
            .map(|i| {
                let img = &batch_x.data[i * img_len..(i + 1) * img_len];
                let zeros = img.iter().filter(|v| **v == 0.0).count();
                zeros as f64 / img_len.max(1) as f64
            })
            .collect();
        let batch = self.simulate_exact_batch(mapped, batch_x, hw, sim_cfg, threads);
        let per_image_layers: Vec<Vec<LayerSimResult>> =
            batch.per_image.into_iter().map(|r| r.layers).collect();
        CostCalibration::from_samples(&zfs, &per_image_layers)
    }

    /// Fully synthetic SmallCNN-shaped bundle (no `make artifacts`
    /// needed): Table-II-style pattern-pruned weights, zero biases, unit
    /// scales, pools exactly where the spec's feature maps halve. Used
    /// by the `batch-sim` CLI demo and the determinism regression tests.
    pub fn synthetic(spec: NetworkSpec, seed: u64) -> SmallCnn {
        let mut rng = Rng::seed_from(seed);
        let mut layers = Vec::with_capacity(spec.layers.len());
        let mut biases = Vec::with_capacity(spec.layers.len());
        let mut scales = Vec::with_capacity(spec.layers.len());
        for l in &spec.layers {
            let n_pat = (l.cout * l.cin).min(6).max(1);
            layers.push(generate_layer(l.cout, l.cin, n_pat, 0.85, 0.35, &mut rng));
            biases.push(vec![0.0f32; l.cout]);
            scales.push(LayerScales { sx: 1.0, sw: 1.0 });
        }
        let n = spec.layers.len();
        let pool_after: Vec<bool> = (0..n)
            .map(|i| {
                i + 1 < n && spec.layers[i + 1].fmap * 2 == spec.layers[i].fmap
            })
            .collect();
        let n_classes = 10;
        let c_last = spec.layers[n - 1].cout;
        let fc_w = Tensor::from_vec(
            &[c_last, n_classes],
            (0..c_last * n_classes)
                .map(|_| (rng.f32() - 0.5) * 0.1)
                .collect(),
        );
        let fc_b = vec![0.0f32; n_classes];
        let weights = NetworkWeights::new(spec.clone(), layers);
        SmallCnn {
            spec,
            weights,
            biases,
            fc_w,
            fc_b,
            scales,
            pool_after,
            n_classes,
            meta: Json::Null,
        }
    }
}

/// Test data bundle exported by `aot.py`.
pub struct TestData {
    pub test_x: Tensor,
    pub test_y: Vec<i32>,
    pub golden_x: Tensor,
    pub golden_logits: Tensor,
}

impl TestData {
    pub fn load(artifacts_dir: &Path) -> Result<TestData, String> {
        let t = load_tensors(&artifacts_dir.join("test_data.bin"))
            .map_err(|e| e.to_string())?;
        let get_f32 = |k: &str| -> Result<Tensor, String> {
            t.get(k)
                .and_then(|a| a.as_f32())
                .cloned()
                .ok_or(format!("missing {k}"))
        };
        Ok(TestData {
            test_x: get_f32("test_x")?,
            test_y: t
                .get("test_y")
                .and_then(|a| a.as_i32())
                .ok_or("missing test_y")?
                .to_vec(),
            golden_x: get_f32("golden_x")?,
            golden_logits: get_f32("golden_logits")?,
        })
    }
}

/// Extract image `i` of an `[N, C, H, W]` batch as `[1, C, H, W]`.
pub fn image(batch: &Tensor, i: usize) -> Tensor {
    let (c, h, w) = (batch.shape[1], batch.shape[2], batch.shape[3]);
    let n = c * h * w;
    Tensor::from_vec(&[1, c, h, w], batch.data[i * n..(i + 1) * n].to_vec())
}

pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in v.iter().enumerate() {
        if *x > v[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[3.0]), 0);
        assert_eq!(argmax(&[1.0, 1.0]), 0);
    }

    #[test]
    fn image_slicing() {
        let b = Tensor::from_vec(&[2, 1, 2, 2],
                                 vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let i1 = image(&b, 1);
        assert_eq!(i1.shape, vec![1, 1, 2, 2]);
        assert_eq!(i1.data, vec![5., 6., 7., 8.]);
    }

    use crate::mapping::pattern::PatternMapping;
    use crate::nn::ConvLayer;

    fn tiny_model() -> SmallCnn {
        let spec = NetworkSpec {
            name: "tiny".into(),
            layers: vec![
                ConvLayer { name: "c0".into(), cin: 2, cout: 6, fmap: 6 },
                ConvLayer { name: "c1".into(), cin: 6, cout: 8, fmap: 3 },
            ],
        };
        SmallCnn::synthetic(spec, 11)
    }

    fn random_batch(n: usize, c: usize, hw: usize, seed: u64) -> Tensor {
        let mut rng = Rng::seed_from(seed);
        let mut x = Tensor::zeros(&[n, c, hw, hw]);
        for v in x.data.iter_mut() {
            *v = if rng.chance(0.4) { 0.0 } else { rng.f32() };
        }
        x
    }

    #[test]
    fn synthetic_bundle_maps_and_pools_where_fmaps_halve() {
        let m = tiny_model();
        // 6 → 3 feature map: pool after layer 0, never after the last
        assert_eq!(m.pool_after, vec![true, false]);
        assert_eq!(m.biases.len(), 2);
        assert_eq!(m.fc_b.len(), 10);
        let hw = HardwareConfig::smallcnn_functional();
        let mapped = m.map(&PatternMapping, &hw);
        mapped.validate().expect("synthetic bundle must map validly");
        // the forward must run end to end and produce one logit per class
        let x = random_batch(1, 2, 6, 3);
        let logits = m.forward(&mapped, &x, &hw, false);
        assert_eq!(logits.len(), 10);
    }

    #[test]
    fn exact_batch_matches_independent_exact_runs() {
        let m = tiny_model();
        let hw = HardwareConfig::smallcnn_functional();
        let mapped = m.map(&PatternMapping, &hw);
        let sim_cfg = SimConfig::default();
        let batch_x = random_batch(3, 2, 6, 5);
        let batch = m.simulate_exact_batch(&mapped, &batch_x, &hw, &sim_cfg, 2);
        assert_eq!(batch.n_images(), 3);
        for i in 0..3 {
            let single =
                m.simulate_exact(&mapped, &image(&batch_x, i), &hw, &sim_cfg);
            let bi = &batch.per_image[i].layers;
            assert_eq!(bi.len(), single.len());
            for (a, b) in bi.iter().zip(single.iter()) {
                assert_eq!(a.ou_ops, b.ou_ops, "image {i}");
                assert_eq!(a.skipped_ou_ops, b.skipped_ou_ops, "image {i}");
                assert_eq!(a.cycles, b.cycles, "image {i}");
                assert_eq!(a.energy, b.energy, "image {i}");
            }
        }
    }

    #[test]
    fn calibration_tracks_real_trace_costs() {
        let m = tiny_model();
        let hw = HardwareConfig::smallcnn_functional();
        let mapped = m.map(&PatternMapping, &hw);
        let sim_cfg = SimConfig::default();
        // calibration images spanning a range of input zero fractions
        let n = 6;
        let mut rng = Rng::seed_from(17);
        let mut batch_x = Tensor::zeros(&[n, 2, 6, 6]);
        let img_len = 2 * 6 * 6;
        for i in 0..n {
            let p_zero = i as f64 / n as f64; // 0, 1/6, …, 5/6
            for v in batch_x.data[i * img_len..(i + 1) * img_len].iter_mut() {
                *v = if rng.chance(p_zero) { 0.0 } else { rng.f32() + 0.01 };
            }
        }
        let cal = m.calibrate(&mapped, &batch_x, &hw, &sim_cfg, 2);
        assert_eq!(cal.layers.len(), 2);
        for l in &cal.layers {
            assert_eq!(l.n_samples, n);
            assert!(l.cycles_at_dense > 0.0, "layer {}", l.layer_idx);
        }
        // zero-skipping means sparser inputs cost no more: the summed
        // fit must not slope upward in any meaningful way
        let dense = cal.total_cycles_at(0.0);
        let sparse = cal.total_cycles_at(0.8);
        assert!(
            sparse <= dense * 1.05,
            "calibrated cost rises with sparsity: {sparse} vs {dense}"
        );
        // the per-layer fits predict the actually-simulated costs of
        // the calibration set to first order: check the mean image
        let exact = m.simulate_exact_batch(&mapped, &batch_x, &hw, &sim_cfg, 1);
        let total_sim: f64 = exact.total_cycles();
        let total_fit: f64 = (0..n)
            .map(|i| {
                let img = &batch_x.data[i * img_len..(i + 1) * img_len];
                let zf = img.iter().filter(|v| **v == 0.0).count() as f64
                    / img_len as f64;
                cal.total_cycles_at(zf)
            })
            .sum();
        let rel = (total_fit - total_sim).abs() / total_sim.max(1.0);
        assert!(rel < 0.25, "fit off by {:.1}% of simulated", rel * 100.0);
    }

    #[test]
    fn exact_traces_feed_the_single_image_path() {
        let m = tiny_model();
        let hw = HardwareConfig::smallcnn_functional();
        let mapped = m.map(&PatternMapping, &hw);
        let x = random_batch(1, 2, 6, 9);
        let traces = m.exact_traces(&mapped, &x, &hw);
        assert_eq!(traces.len(), 2);
        // layer 0 sees the raw 6x6 input, layer 1 the pooled 3x3 map
        assert_eq!(traces[0].n_positions, 36);
        assert_eq!(traces[0].cin, 2);
        assert_eq!(traces[1].n_positions, 9);
        assert_eq!(traces[1].cin, 6);
    }
    // full-bundle tests live in tests/e2e.rs (require artifacts/)
}
