//! Synthetic activation traces (DESIGN.md §3 substitution).
//!
//! The all-zero-detection gains of the Input Preprocessing Unit depend
//! on *correlated* post-ReLU sparsity: dead channels and contiguous zero
//! blobs, not iid zeros. A trace samples, per (layer, sampled position,
//! input channel), a 9-bit mask of which receptive-field positions are
//! zero; a block is skippable when the mask covers all of its pattern's
//! positions.

use crate::config::SimConfig;
use crate::pruning::Pattern;
use crate::util::rng::Rng;

/// Activation zero-structure for one layer at a set of sampled output
/// positions.
#[derive(Debug, Clone)]
pub struct LayerTrace {
    pub n_positions: usize,
    pub cin: usize,
    /// `masks[pos * cin + ch]` = 9-bit zero mask of channel `ch`'s patch
    /// at sampled position `pos` (bit i set = input at kernel position i
    /// is zero).
    pub masks: Vec<u16>,
}

impl LayerTrace {
    /// Generate a synthetic trace for `cin` channels at `n_positions`
    /// sampled output positions.
    pub fn synthetic(
        cin: usize,
        n_positions: usize,
        cfg: &SimConfig,
        rng: &mut Rng,
    ) -> LayerTrace {
        let mut masks = Vec::with_capacity(n_positions * cin);
        // Channel death is a per-channel property, shared by positions.
        let dead: Vec<bool> = (0..cin)
            .map(|_| rng.chance(cfg.dead_channel_ratio))
            .collect();
        // Baseline iid zero probability inside live channels (post-ReLU
        // activations are ~half nonpositive before the blob structure).
        const P_IID: f64 = 0.3;
        for _pos in 0..n_positions {
            for ch in 0..cin {
                let mask = if dead[ch] {
                    0x1FF // whole patch zero
                } else if rng.chance(cfg.zero_blob_ratio) {
                    // patch interior to a zero blob
                    0x1FF
                } else {
                    let mut m = 0u16;
                    for i in 0..9 {
                        if rng.chance(P_IID) {
                            m |= 1 << i;
                        }
                    }
                    m
                };
                masks.push(mask);
            }
        }
        LayerTrace { n_positions, cin, masks }
    }

    /// A trace from real feature-map data: `patches[pos][cin*9]` im2col
    /// rows (used by the SmallCNN exact simulation).
    pub fn from_rows(rows: &[Vec<f32>], cin: usize) -> LayerTrace {
        let mut masks = Vec::with_capacity(rows.len() * cin);
        for row in rows {
            debug_assert_eq!(row.len(), cin * 9);
            for ch in 0..cin {
                let mut m = 0u16;
                for i in 0..9 {
                    if row[ch * 9 + i] == 0.0 {
                        m |= 1 << i;
                    }
                }
                masks.push(m);
            }
        }
        LayerTrace { n_positions: rows.len(), cin, masks }
    }

    /// A dense (no zeros) trace.
    pub fn dense(cin: usize, n_positions: usize) -> LayerTrace {
        LayerTrace { n_positions, cin, masks: vec![0; n_positions * cin] }
    }

    #[inline]
    pub fn mask(&self, pos: usize, ch: usize) -> u16 {
        self.masks[pos * self.cin + ch]
    }

    /// Is a block with `pattern` on channel `ch` skippable at `pos`?
    /// (All of the pattern's inputs are zero — paper §IV-A.)
    #[inline]
    pub fn block_skippable(&self, pos: usize, ch: usize, pattern: Pattern) -> bool {
        let zeros = self.mask(pos, ch);
        pattern.0 & !zeros == 0 && !pattern.is_zero()
    }

    /// Fraction of (position, channel) patches entirely zero.
    pub fn full_zero_fraction(&self) -> f64 {
        let z = self.masks.iter().filter(|m| **m == 0x1FF).count();
        z as f64 / self.masks.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_zero_fraction_tracks_config() {
        let cfg = SimConfig {
            dead_channel_ratio: 0.0,
            zero_blob_ratio: 0.4,
            ..Default::default()
        };
        let mut rng = Rng::seed_from(1);
        let t = LayerTrace::synthetic(64, 128, &cfg, &mut rng);
        let f = t.full_zero_fraction();
        assert!((f - 0.4).abs() < 0.05, "blob fraction {f}");
    }

    #[test]
    fn dead_channels_always_zero() {
        let cfg = SimConfig {
            dead_channel_ratio: 1.0,
            zero_blob_ratio: 0.0,
            ..Default::default()
        };
        let mut rng = Rng::seed_from(2);
        let t = LayerTrace::synthetic(8, 16, &cfg, &mut rng);
        assert_eq!(t.full_zero_fraction(), 1.0);
    }

    #[test]
    fn skippable_requires_cover() {
        let t = LayerTrace {
            n_positions: 1,
            cin: 1,
            masks: vec![0b000000111],
        };
        assert!(t.block_skippable(0, 0, Pattern(0b101))); // ⊆ zeros
        assert!(!t.block_skippable(0, 0, Pattern(0b1001))); // pos 3 nonzero
        assert!(!t.block_skippable(0, 0, Pattern::ALL_ZERO)); // degenerate
    }

    #[test]
    fn from_rows_marks_exact_zeros() {
        let rows = vec![vec![
            0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, // ch0
            1.0, 1.0, 1.0, 1.0, 0.0, 1.0, 1.0, 1.0, 1.0, // ch1
        ]];
        let t = LayerTrace::from_rows(&rows, 2);
        assert_eq!(t.mask(0, 0), 0b111111101);
        assert_eq!(t.mask(0, 1), 0b000010000);
    }

    #[test]
    fn dense_trace_never_skips() {
        let t = LayerTrace::dense(4, 8);
        for pos in 0..8 {
            for ch in 0..4 {
                assert!(!t.block_skippable(pos, ch, Pattern(0b1)));
            }
        }
    }
}
