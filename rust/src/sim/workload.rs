//! Synthetic activation traces (DESIGN.md §3 substitution).
//!
//! The all-zero-detection gains of the Input Preprocessing Unit depend
//! on *correlated* post-ReLU sparsity: dead channels and contiguous zero
//! blobs, not iid zeros. A trace samples, per (layer, sampled position,
//! input channel), a 9-bit mask of which receptive-field positions are
//! zero; a block is skippable when the mask covers all of its pattern's
//! positions.
//!
//! [`TraceAggregate`] collapses a trace into the per-(channel, pattern)
//! skippable-position histogram the trace-aggregated simulator engine
//! consumes (`sim::simulate_layer_aggregated`), and [`TraceBuilder`] is
//! the incremental feeder for exact-mode traces built position by
//! position from real activations.
//!
//! # Merge / batch invariants
//!
//! The batched multi-image simulator rests on two invariants pinned by
//! `tests/prop_invariants.rs`:
//!
//! 1. **Merge = concat.** An aggregate is a vector of integer position
//!    counts, so [`TraceAggregate::merge`] over per-image aggregates
//!    (built from the *same* block-key set) is bit-identical to
//!    aggregating the concatenation of the underlying traces. Merging
//!    never loses information the closed-form costing needs.
//! 2. **Batch = Σ singles.** [`BatchAggregate`] keeps the per-image
//!    aggregates (alongside their running merge), and the batch engine
//!    costs each image through the same closed-form path — with the
//!    per-block cost tables computed once per layer — so batched
//!    results are bit-exact with summing independent per-image
//!    simulations, in image order.

use crate::config::SimConfig;
use crate::pruning::Pattern;
use crate::util::rng::Rng;

/// Activation zero-structure for one layer at a set of sampled output
/// positions.
#[derive(Debug, Clone)]
pub struct LayerTrace {
    pub n_positions: usize,
    pub cin: usize,
    /// `masks[pos * cin + ch]` = 9-bit zero mask of channel `ch`'s patch
    /// at sampled position `pos` (bit i set = input at kernel position i
    /// is zero).
    pub masks: Vec<u16>,
}

impl LayerTrace {
    /// Generate a synthetic trace for `cin` channels at `n_positions`
    /// sampled output positions.
    pub fn synthetic(
        cin: usize,
        n_positions: usize,
        cfg: &SimConfig,
        rng: &mut Rng,
    ) -> LayerTrace {
        let mut masks = Vec::with_capacity(n_positions * cin);
        // Channel death is a per-channel property, shared by positions.
        let dead: Vec<bool> = (0..cin)
            .map(|_| rng.chance(cfg.dead_channel_ratio))
            .collect();
        // Baseline iid zero probability inside live channels (post-ReLU
        // activations are ~half nonpositive before the blob structure).
        const P_IID: f64 = 0.3;
        for _pos in 0..n_positions {
            for ch in 0..cin {
                let mask = if dead[ch] {
                    0x1FF // whole patch zero
                } else if rng.chance(cfg.zero_blob_ratio) {
                    // patch interior to a zero blob
                    0x1FF
                } else {
                    let mut m = 0u16;
                    for i in 0..9 {
                        if rng.chance(P_IID) {
                            m |= 1 << i;
                        }
                    }
                    m
                };
                masks.push(mask);
            }
        }
        LayerTrace { n_positions, cin, masks }
    }

    /// A trace from real feature-map data: `patches[pos][cin*9]` im2col
    /// rows (used by the SmallCNN exact simulation).
    pub fn from_rows(rows: &[Vec<f32>], cin: usize) -> LayerTrace {
        let mut b = TraceBuilder::with_capacity(cin, rows.len());
        for row in rows {
            b.push_row(row);
        }
        b.finish()
    }

    /// A dense (no zeros) trace.
    pub fn dense(cin: usize, n_positions: usize) -> LayerTrace {
        LayerTrace { n_positions, cin, masks: vec![0; n_positions * cin] }
    }

    #[inline]
    pub fn mask(&self, pos: usize, ch: usize) -> u16 {
        self.masks[pos * self.cin + ch]
    }

    /// Is a block with `pattern` on channel `ch` skippable at `pos`?
    /// (All of the pattern's inputs are zero — paper §IV-A.)
    #[inline]
    pub fn block_skippable(&self, pos: usize, ch: usize, pattern: Pattern) -> bool {
        let zeros = self.mask(pos, ch);
        pattern.0 & !zeros == 0 && !pattern.is_zero()
    }

    /// Fraction of (position, channel) patches entirely zero.
    pub fn full_zero_fraction(&self) -> f64 {
        let z = self.masks.iter().filter(|m| **m == 0x1FF).count();
        z as f64 / self.masks.len().max(1) as f64
    }

    /// Fraction of individual patch *entries* that are zero — mean
    /// popcount of the 9-bit masks over 9. This is the activation-level
    /// sparsity the inter-core transfer model discounts by when the
    /// receiving core's IPU can reconstruct zeros locally
    /// (`sim::placement::edge_transfer_bytes`).
    pub fn zero_entry_fraction(&self) -> f64 {
        let bits: u64 =
            self.masks.iter().map(|m| m.count_ones() as u64).sum();
        bits as f64 / (9 * self.masks.len().max(1)) as f64
    }

    /// Collapse this trace into the skippable-position histogram for a
    /// layer's block keys, in O(positions × cin) bitmask work: one
    /// mask→subset lookup table turns every (position, channel) visit
    /// into a single probe plus a (usually empty) set-bit walk, instead
    /// of a per-block subset test at every position.
    pub fn aggregate(&self, keys: &[(usize, Pattern)]) -> TraceAggregate {
        // Distinct nonzero patterns, plus the per-channel union of that
        // channel's key patterns (`0` for channels without keys: they
        // constrain nothing).
        let mut patterns: Vec<Pattern> = Vec::new();
        let mut has_zero_key = false;
        let mut need = vec![0u16; self.cin];
        for &(ch, p) in keys {
            if p.is_zero() {
                // A zero-pattern block is never skippable (§IV-A
                // degenerate case), so it executes at every position.
                has_zero_key = true;
                continue;
            }
            if !patterns.contains(&p) {
                patterns.push(p);
            }
            need[ch] |= p.0;
        }

        let np = patterns.len();
        let mut skippable = vec![0u64; self.cin * np];
        // ≤ 64 patterns per lookup-table pass; real layers have ≤ ~10.
        for chunk_start in (0..np).step_by(64) {
            let chunk = &patterns[chunk_start..np.min(chunk_start + 64)];
            let mut table = [0u64; 512];
            for (j, p) in chunk.iter().enumerate() {
                for (m, bits) in table.iter_mut().enumerate() {
                    if p.0 & !(m as u16) == 0 {
                        *bits |= 1u64 << j;
                    }
                }
            }
            for pos in 0..self.n_positions {
                let row = &self.masks[pos * self.cin..(pos + 1) * self.cin];
                for (ch, &m) in row.iter().enumerate() {
                    let mut bits = table[(m & 0x1FF) as usize];
                    while bits != 0 {
                        let j = bits.trailing_zeros() as usize;
                        bits &= bits - 1;
                        skippable[ch * np + chunk_start + j] += 1;
                    }
                }
            }
        }

        // Fully skippable positions: every channel's needed union is
        // covered at once (`p1 ⊆ m ∧ p2 ⊆ m ⟺ (p1|p2) ⊆ m`). With a
        // zero-pattern key something always executes, so none qualify.
        let mut fully = 0u64;
        if !has_zero_key {
            for pos in 0..self.n_positions {
                let row = &self.masks[pos * self.cin..(pos + 1) * self.cin];
                let covered = row
                    .iter()
                    .zip(need.iter())
                    .all(|(&m, &nd)| nd & !m == 0);
                if covered {
                    fully += 1;
                }
            }
        }

        TraceAggregate {
            n_positions: self.n_positions,
            patterns,
            skippable,
            fully_skippable: fully,
        }
    }
}

/// Per-layer aggregate of a trace: for every (channel, pattern) block
/// key, at how many positions the key is skippable, plus how many
/// positions are *fully* skippable (every key covered at once — the
/// only positions that execute nothing). This is the entire input the
/// trace-aggregated engine needs: executed/skipped OU counts, cycles
/// and energy all follow in closed form.
#[derive(Debug, Clone)]
pub struct TraceAggregate {
    pub n_positions: usize,
    /// Distinct nonzero key patterns, in first-seen order.
    patterns: Vec<Pattern>,
    /// `skippable[ch * patterns.len() + pi]` — positions where
    /// `patterns[pi]` is covered by channel `ch`'s zero mask.
    skippable: Vec<u64>,
    fully_skippable: u64,
}

impl TraceAggregate {
    /// Fold another image's aggregate — built from the **same** block
    /// key set — into this one. All fields are plain integer counts, so
    /// merging per-image aggregates is bit-identical to aggregating the
    /// concatenation of their traces (module-doc invariant #1).
    pub fn merge(&mut self, other: &TraceAggregate) {
        assert_eq!(
            self.patterns, other.patterns,
            "merge requires aggregates built from the same key set"
        );
        assert_eq!(
            self.skippable.len(),
            other.skippable.len(),
            "merge requires aggregates over the same channel count"
        );
        self.n_positions += other.n_positions;
        for (a, b) in self.skippable.iter_mut().zip(other.skippable.iter()) {
            *a += *b;
        }
        self.fully_skippable += other.fully_skippable;
    }

    /// Positions where a block keyed `(ch, pattern)` is skippable.
    /// Zero patterns are never skippable.
    pub fn skippable_positions(&self, ch: usize, pattern: Pattern) -> u64 {
        if pattern.is_zero() {
            return 0;
        }
        let pi = self
            .patterns
            .iter()
            .position(|p| *p == pattern)
            .expect("pattern not in the aggregate's key set");
        self.skippable[ch * self.patterns.len() + pi]
    }

    /// Positions where every key is skippable simultaneously.
    pub fn fully_skippable_positions(&self) -> u64 {
        self.fully_skippable
    }
}

/// One layer's aggregates across the images of a batch: every per-image
/// [`TraceAggregate`] in image order (the batch engine reports
/// per-image results), with the whole-batch merge available on demand
/// for batch-level statistics and cross-checks.
#[derive(Debug, Clone, Default)]
pub struct BatchAggregate {
    per_image: Vec<TraceAggregate>,
}

impl BatchAggregate {
    pub fn new() -> BatchAggregate {
        BatchAggregate::default()
    }

    /// Append one image's aggregate. Panics when it was not built from
    /// the same block-key set as the previous images.
    pub fn push(&mut self, agg: TraceAggregate) {
        if let Some(first) = self.per_image.first() {
            assert_eq!(
                first.patterns, agg.patterns,
                "push requires aggregates built from the same key set"
            );
            assert_eq!(
                first.skippable.len(),
                agg.skippable.len(),
                "push requires aggregates over the same channel count"
            );
        }
        self.per_image.push(agg);
    }

    pub fn n_images(&self) -> usize {
        self.per_image.len()
    }

    pub fn is_empty(&self) -> bool {
        self.per_image.is_empty()
    }

    /// Per-image aggregates, in push (image) order.
    pub fn images(&self) -> &[TraceAggregate] {
        &self.per_image
    }

    /// Merge of every pushed aggregate (`None` for an empty batch),
    /// computed on demand — the hot batched path only reads
    /// [`BatchAggregate::images`], so pushes stay O(1).
    pub fn merged(&self) -> Option<TraceAggregate> {
        let mut it = self.per_image.iter();
        let mut m = it.next()?.clone();
        for a in it {
            m.merge(a);
        }
        Some(m)
    }

    /// Total trace positions across the whole batch.
    pub fn total_positions(&self) -> usize {
        self.per_image.iter().map(|a| a.n_positions).sum()
    }
}

/// Incremental trace construction: push one output position at a time
/// (an im2col row or precomputed masks). Exact-mode traces over real
/// activations are built through this as the rows are produced, so the
/// feeder never needs a second copy of the feature map.
#[derive(Debug, Clone)]
pub struct TraceBuilder {
    cin: usize,
    masks: Vec<u16>,
}

impl TraceBuilder {
    pub fn new(cin: usize) -> TraceBuilder {
        TraceBuilder { cin, masks: Vec::new() }
    }

    pub fn with_capacity(cin: usize, n_positions: usize) -> TraceBuilder {
        TraceBuilder { cin, masks: Vec::with_capacity(cin * n_positions) }
    }

    /// Append one position from a `cin * 9` im2col row (mask bit i set
    /// ⟺ the input at kernel position i is exactly zero).
    pub fn push_row(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.cin * 9);
        for ch in 0..self.cin {
            let mut m = 0u16;
            for (i, v) in row[ch * 9..ch * 9 + 9].iter().enumerate() {
                if *v == 0.0 {
                    m |= 1 << i;
                }
            }
            self.masks.push(m);
        }
    }

    /// Append one position from precomputed per-channel zero masks.
    pub fn push_masks(&mut self, masks: &[u16]) {
        debug_assert_eq!(masks.len(), self.cin);
        self.masks.extend_from_slice(masks);
    }

    pub fn n_positions(&self) -> usize {
        if self.cin == 0 {
            0
        } else {
            self.masks.len() / self.cin
        }
    }

    pub fn finish(self) -> LayerTrace {
        let n_positions =
            if self.cin == 0 { 0 } else { self.masks.len() / self.cin };
        LayerTrace { n_positions, cin: self.cin, masks: self.masks }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_zero_fraction_tracks_config() {
        let cfg = SimConfig {
            dead_channel_ratio: 0.0,
            zero_blob_ratio: 0.4,
            ..Default::default()
        };
        let mut rng = Rng::seed_from(1);
        let t = LayerTrace::synthetic(64, 128, &cfg, &mut rng);
        let f = t.full_zero_fraction();
        assert!((f - 0.4).abs() < 0.05, "blob fraction {f}");
    }

    #[test]
    fn dead_channels_always_zero() {
        let cfg = SimConfig {
            dead_channel_ratio: 1.0,
            zero_blob_ratio: 0.0,
            ..Default::default()
        };
        let mut rng = Rng::seed_from(2);
        let t = LayerTrace::synthetic(8, 16, &cfg, &mut rng);
        assert_eq!(t.full_zero_fraction(), 1.0);
    }

    #[test]
    fn skippable_requires_cover() {
        let t = LayerTrace {
            n_positions: 1,
            cin: 1,
            masks: vec![0b000000111],
        };
        assert!(t.block_skippable(0, 0, Pattern(0b101))); // ⊆ zeros
        assert!(!t.block_skippable(0, 0, Pattern(0b1001))); // pos 3 nonzero
        assert!(!t.block_skippable(0, 0, Pattern::ALL_ZERO)); // degenerate
    }

    #[test]
    fn from_rows_marks_exact_zeros() {
        let rows = vec![vec![
            0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, // ch0
            1.0, 1.0, 1.0, 1.0, 0.0, 1.0, 1.0, 1.0, 1.0, // ch1
        ]];
        let t = LayerTrace::from_rows(&rows, 2);
        assert_eq!(t.mask(0, 0), 0b111111101);
        assert_eq!(t.mask(0, 1), 0b000010000);
    }

    #[test]
    fn dense_trace_never_skips() {
        let t = LayerTrace::dense(4, 8);
        for pos in 0..8 {
            for ch in 0..4 {
                assert!(!t.block_skippable(pos, ch, Pattern(0b1)));
            }
        }
    }

    #[test]
    fn aggregate_matches_bruteforce_counts() {
        let cfg = SimConfig {
            dead_channel_ratio: 0.2,
            zero_blob_ratio: 0.3,
            ..Default::default()
        };
        let mut rng = Rng::seed_from(9);
        let t = LayerTrace::synthetic(6, 40, &cfg, &mut rng);
        let keys = vec![
            (0usize, Pattern(0b1)),
            (0, Pattern(0b110)),
            (3, Pattern(0b1)),
            (5, Pattern(0x1FF)),
        ];
        let agg = t.aggregate(&keys);
        assert_eq!(agg.n_positions, 40);
        for &(ch, p) in &keys {
            let brute = (0..t.n_positions)
                .filter(|&pos| t.block_skippable(pos, ch, p))
                .count() as u64;
            assert_eq!(agg.skippable_positions(ch, p), brute, "key ({ch}, {p:?})");
        }
        let brute_full = (0..t.n_positions)
            .filter(|&pos| {
                keys.iter().all(|&(ch, p)| t.block_skippable(pos, ch, p))
            })
            .count() as u64;
        assert_eq!(agg.fully_skippable_positions(), brute_full);
    }

    #[test]
    fn aggregate_zero_pattern_key_never_skips() {
        let t = LayerTrace {
            n_positions: 3,
            cin: 1,
            masks: vec![0x1FF, 0x1FF, 0x1FF],
        };
        let agg = t.aggregate(&[(0, Pattern::ALL_ZERO), (0, Pattern(0b1))]);
        assert_eq!(agg.skippable_positions(0, Pattern::ALL_ZERO), 0);
        assert_eq!(agg.skippable_positions(0, Pattern(0b1)), 3);
        // the zero-pattern block executes everywhere, so no position is
        // fully skippable
        assert_eq!(agg.fully_skippable_positions(), 0);
    }

    #[test]
    fn aggregate_handles_many_pattern_chunks() {
        // > 64 distinct patterns exercises the chunked lookup tables
        let keys: Vec<(usize, Pattern)> =
            (1u16..=100).map(|p| (0usize, Pattern(p))).collect();
        let cfg = SimConfig {
            dead_channel_ratio: 0.0,
            zero_blob_ratio: 0.25,
            ..Default::default()
        };
        let mut rng = Rng::seed_from(4);
        let t = LayerTrace::synthetic(2, 32, &cfg, &mut rng);
        let agg = t.aggregate(&keys);
        for &(ch, p) in keys.iter().step_by(7) {
            let brute = (0..t.n_positions)
                .filter(|&pos| t.block_skippable(pos, ch, p))
                .count() as u64;
            assert_eq!(agg.skippable_positions(ch, p), brute, "{p:?}");
        }
    }

    #[test]
    fn merge_matches_concatenated_trace() {
        let cfg = SimConfig {
            dead_channel_ratio: 0.15,
            zero_blob_ratio: 0.35,
            ..Default::default()
        };
        let keys = vec![
            (0usize, Pattern(0b1)),
            (1, Pattern(0b110)),
            (2, Pattern(0x1FF)),
            (2, Pattern::ALL_ZERO),
        ];
        let mut rng = Rng::seed_from(17);
        let a = LayerTrace::synthetic(3, 24, &cfg, &mut rng);
        let b = LayerTrace::synthetic(3, 9, &cfg, &mut rng);
        let mut merged = a.aggregate(&keys);
        merged.merge(&b.aggregate(&keys));

        let mut masks = a.masks.clone();
        masks.extend_from_slice(&b.masks);
        let concat = LayerTrace { n_positions: 33, cin: 3, masks }.aggregate(&keys);
        assert_eq!(merged.n_positions, concat.n_positions);
        assert_eq!(
            merged.fully_skippable_positions(),
            concat.fully_skippable_positions()
        );
        for &(ch, p) in &keys {
            assert_eq!(
                merged.skippable_positions(ch, p),
                concat.skippable_positions(ch, p),
                "key ({ch}, {p:?})"
            );
        }
    }

    #[test]
    #[should_panic(expected = "same key set")]
    fn merge_rejects_mismatched_keys() {
        let t = LayerTrace::dense(2, 4);
        let mut a = t.aggregate(&[(0, Pattern(0b1))]);
        let b = t.aggregate(&[(0, Pattern(0b11))]);
        a.merge(&b);
    }

    #[test]
    fn batch_aggregate_accumulates_in_image_order() {
        let cfg = SimConfig::default();
        let keys = vec![(0usize, Pattern(0b101)), (1, Pattern(0b1))];
        let mut rng = Rng::seed_from(33);
        let mut batch = BatchAggregate::new();
        assert!(batch.is_empty());
        assert!(batch.merged().is_none());
        let mut want_positions = 0usize;
        let mut want_skippable = 0u64;
        for i in 0..3 {
            let t = LayerTrace::synthetic(2, 8 + i, &cfg, &mut rng);
            want_positions += t.n_positions;
            let agg = t.aggregate(&keys);
            want_skippable += agg.skippable_positions(0, Pattern(0b101));
            batch.push(agg);
        }
        assert_eq!(batch.n_images(), 3);
        assert_eq!(batch.images().len(), 3);
        assert_eq!(batch.total_positions(), want_positions);
        let merged = batch.merged().unwrap();
        assert_eq!(merged.n_positions, want_positions);
        assert_eq!(
            merged.skippable_positions(0, Pattern(0b101)),
            want_skippable
        );
    }

    #[test]
    fn builder_matches_from_rows() {
        let rows = vec![
            vec![
                0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, // ch0
                1.0, 1.0, 1.0, 1.0, 0.0, 1.0, 1.0, 1.0, 1.0, // ch1
            ],
            vec![0.0; 18],
        ];
        let direct = LayerTrace::from_rows(&rows, 2);
        let mut b = TraceBuilder::new(2);
        b.push_row(&rows[0]);
        assert_eq!(b.n_positions(), 1);
        b.push_masks(&[direct.mask(1, 0), direct.mask(1, 1)]);
        let t = b.finish();
        assert_eq!(t.n_positions, 2);
        assert_eq!(t.masks, direct.masks);
    }
}
