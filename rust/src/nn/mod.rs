//! Network & tensor substrate: a small dense tensor type, the RPAT1
//! binary container shared with `python/compile/weights_io.py`, conv
//! layer/network descriptions (SmallCNN + the paper's modified VGG16),
//! and float reference convolution used as the functional oracle.

pub mod tensor_io;

use crate::util::json::Json;

/// Dense row-major f32 tensor (up to 4-D is what this crate needs).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Flat index for a 4-D tensor.
    #[inline]
    pub fn idx4(&self, a: usize, b: usize, c: usize, d: usize) -> usize {
        debug_assert_eq!(self.shape.len(), 4);
        ((a * self.shape[1] + b) * self.shape[2] + c) * self.shape[3] + d
    }

    #[inline]
    pub fn at4(&self, a: usize, b: usize, c: usize, d: usize) -> f32 {
        self.data[self.idx4(a, b, c, d)]
    }

    #[inline]
    pub fn set4(&mut self, a: usize, b: usize, c: usize, d: usize, v: f32) {
        let i = self.idx4(a, b, c, d);
        self.data[i] = v;
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }

    pub fn count_zeros(&self) -> usize {
        self.data.iter().filter(|v| **v == 0.0).count()
    }
}

/// One 3×3 convolution layer description.
#[derive(Debug, Clone, PartialEq)]
pub struct ConvLayer {
    pub name: String,
    pub cin: usize,
    pub cout: usize,
    /// Spatial size of the input feature map (H == W assumed).
    pub fmap: usize,
}

impl ConvLayer {
    pub fn kernels(&self) -> usize {
        self.cin * self.cout
    }

    pub fn weights(&self) -> usize {
        self.kernels() * 9
    }

    /// Output positions per image (3×3, pad 1, stride 1 -> same size).
    pub fn positions(&self) -> usize {
        self.fmap * self.fmap
    }
}

/// A CNN as the mapper sees it: an ordered list of 3×3 conv layers.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkSpec {
    pub name: String,
    pub layers: Vec<ConvLayer>,
}

impl NetworkSpec {
    /// The paper's modified VGG16 (13 conv layers, Simonyan config D),
    /// CIFAR-sized feature maps.
    pub fn vgg16_cifar(name: &str) -> NetworkSpec {
        Self::vgg16(name, &VGG16_FMAP_CIFAR)
    }

    /// Modified VGG16 with ImageNet-sized feature maps.
    pub fn vgg16_imagenet(name: &str) -> NetworkSpec {
        Self::vgg16(name, &VGG16_FMAP_IMAGENET)
    }

    fn vgg16(name: &str, fmaps: &[usize; 13]) -> NetworkSpec {
        let chans: [(usize, usize); 13] = [
            (64, 3),
            (64, 64),
            (128, 64),
            (128, 128),
            (256, 128),
            (256, 256),
            (256, 256),
            (512, 256),
            (512, 512),
            (512, 512),
            (512, 512),
            (512, 512),
            (512, 512),
        ];
        NetworkSpec {
            name: name.to_string(),
            layers: chans
                .iter()
                .zip(fmaps.iter())
                .enumerate()
                .map(|(i, (&(cout, cin), &fmap))| ConvLayer {
                    name: format!("conv{i}"),
                    cin,
                    cout,
                    fmap,
                })
                .collect(),
        }
    }

    /// SmallCNN conv stack (mirror of `python/compile/model.py`).
    pub fn smallcnn() -> NetworkSpec {
        let spec: [(usize, usize, usize); 5] = [
            (16, 3, 32),
            (16, 16, 32),
            (32, 16, 16),
            (32, 32, 16),
            (64, 32, 8),
        ];
        NetworkSpec {
            name: "smallcnn".into(),
            layers: spec
                .iter()
                .enumerate()
                .map(|(i, &(cout, cin, fmap))| ConvLayer {
                    name: format!("conv{i}"),
                    cin,
                    cout,
                    fmap,
                })
                .collect(),
        }
    }

    /// Parse the layer inventory from `smallcnn_meta.json`'s arch field.
    pub fn from_meta(meta: &Json) -> Result<NetworkSpec, String> {
        let arch = meta
            .get("arch")
            .as_arr()
            .ok_or("meta missing arch")?;
        let input = meta.get("input_shape");
        let mut fmap = input.idx(1).as_usize().ok_or("bad input_shape")?;
        let mut layers = Vec::new();
        let mut i = 0;
        for item in arch {
            if item.as_str() == Some("M") {
                fmap /= 2;
                continue;
            }
            let cout = item.idx(0).as_usize().ok_or("bad arch entry")?;
            let cin = item.idx(1).as_usize().ok_or("bad arch entry")?;
            layers.push(ConvLayer { name: format!("conv{i}"), cin, cout, fmap });
            i += 1;
        }
        Ok(NetworkSpec { name: "smallcnn".into(), layers })
    }

    pub fn total_kernels(&self) -> usize {
        self.layers.iter().map(|l| l.kernels()).sum()
    }

    pub fn total_weights(&self) -> usize {
        self.layers.iter().map(|l| l.weights()).sum()
    }
}

/// Feature-map sizes entering each VGG16 conv layer (CIFAR, 32×32 input).
pub const VGG16_FMAP_CIFAR: [usize; 13] =
    [32, 32, 16, 16, 8, 8, 8, 4, 4, 4, 2, 2, 2];
/// Feature-map sizes entering each VGG16 conv layer (ImageNet, 224×224).
pub const VGG16_FMAP_IMAGENET: [usize; 13] =
    [224, 224, 112, 112, 56, 56, 56, 28, 28, 28, 14, 14, 14];

/// Reference dense 3×3 conv, pad 1, stride 1 (NCHW x, OIHW w).
///
/// The functional oracle for the mapped-crossbar simulator.
pub fn conv2d_ref(x: &Tensor, w: &Tensor) -> Tensor {
    assert_eq!(x.ndim(), 4);
    assert_eq!(w.ndim(), 4);
    let (b, cin, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (cout, cin2, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    assert_eq!(cin, cin2);
    assert_eq!((kh, kw), (3, 3));
    let mut out = Tensor::zeros(&[b, cout, h, wd]);
    for bi in 0..b {
        for oc in 0..cout {
            for oy in 0..h {
                for ox in 0..wd {
                    let mut acc = 0.0f32;
                    for ic in 0..cin {
                        for ky in 0..3usize {
                            for kx in 0..3usize {
                                let iy = oy as isize + ky as isize - 1;
                                let ix = ox as isize + kx as isize - 1;
                                if iy < 0 || ix < 0 || iy >= h as isize || ix >= wd as isize {
                                    continue;
                                }
                                acc += x.at4(bi, ic, iy as usize, ix as usize)
                                    * w.at4(oc, ic, ky, kx);
                            }
                        }
                    }
                    out.set4(bi, oc, oy, ox, acc);
                }
            }
        }
    }
    out
}

/// im2col patch extraction for one image: returns `[positions][cin*9]`
/// rows in the same (cin-major, then kernel-position) order as
/// `python/compile/kernels/ref.im2col` and the paper's Fig. 1 unrolling.
pub fn im2col(x: &Tensor, img: usize) -> Vec<Vec<f32>> {
    let (cin, h, w) = (x.shape[1], x.shape[2], x.shape[3]);
    let mut rows = Vec::with_capacity(h * w);
    for oy in 0..h {
        for ox in 0..w {
            let mut row = vec![0.0f32; cin * 9];
            for ic in 0..cin {
                for ky in 0..3usize {
                    for kx in 0..3usize {
                        let iy = oy as isize + ky as isize - 1;
                        let ix = ox as isize + kx as isize - 1;
                        if iy < 0 || ix < 0 || iy >= h as isize || ix >= w as isize {
                            continue;
                        }
                        row[ic * 9 + ky * 3 + kx] =
                            x.at4(img, ic, iy as usize, ix as usize);
                    }
                }
            }
            rows.push(row);
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_indexing() {
        let mut t = Tensor::zeros(&[2, 3, 4, 5]);
        t.set4(1, 2, 3, 4, 7.5);
        assert_eq!(t.at4(1, 2, 3, 4), 7.5);
        assert_eq!(t.numel(), 120);
        assert_eq!(t.count_zeros(), 119);
        assert_eq!(t.max_abs(), 7.5);
    }

    #[test]
    fn vgg16_inventory() {
        let n = NetworkSpec::vgg16_cifar("vgg16-cifar10");
        assert_eq!(n.layers.len(), 13);
        assert_eq!(n.layers[0].cin, 3);
        assert_eq!(n.layers[0].cout, 64);
        assert_eq!(n.layers[12].cout, 512);
        // total conv weights of VGG16 ≈ 14.7M
        assert_eq!(n.total_weights(), 14_710_464);
        assert_eq!(n.total_kernels(), 1_634_496);
    }

    #[test]
    fn smallcnn_inventory() {
        let n = NetworkSpec::smallcnn();
        assert_eq!(n.layers.len(), 5);
        assert_eq!(n.layers[0].cin, 3);
        assert_eq!(n.layers[4].cout, 64);
        assert_eq!(n.layers[2].fmap, 16);
    }

    #[test]
    fn conv_identity_kernel() {
        // center-tap identity kernel returns the input
        let mut x = Tensor::zeros(&[1, 1, 4, 4]);
        for i in 0..16 {
            x.data[i] = i as f32;
        }
        let mut w = Tensor::zeros(&[1, 1, 3, 3]);
        w.set4(0, 0, 1, 1, 1.0);
        let y = conv2d_ref(&x, &w);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn conv_sum_kernel_interior() {
        // all-ones 3x3 kernel on all-ones input: interior = 9, corner = 4
        let x = Tensor::from_vec(&[1, 1, 4, 4], vec![1.0; 16]);
        let w = Tensor::from_vec(&[1, 1, 3, 3], vec![1.0; 9]);
        let y = conv2d_ref(&x, &w);
        assert_eq!(y.at4(0, 0, 1, 1), 9.0);
        assert_eq!(y.at4(0, 0, 0, 0), 4.0);
        assert_eq!(y.at4(0, 0, 0, 1), 6.0);
    }

    #[test]
    fn im2col_matches_conv() {
        // conv via im2col rows == conv2d_ref
        let mut rngv = 0.3f32;
        let mut x = Tensor::zeros(&[1, 2, 5, 5]);
        for v in x.data.iter_mut() {
            rngv = (rngv * 1.7 + 0.31) % 1.0;
            *v = rngv - 0.5;
        }
        let mut w = Tensor::zeros(&[3, 2, 3, 3]);
        for v in w.data.iter_mut() {
            rngv = (rngv * 1.9 + 0.17) % 1.0;
            *v = rngv - 0.5;
        }
        let want = conv2d_ref(&x, &w);
        let rows = im2col(&x, 0);
        for (pos, row) in rows.iter().enumerate() {
            for oc in 0..3 {
                let mut acc = 0.0f32;
                for ic in 0..2 {
                    for k in 0..9 {
                        acc += row[ic * 9 + k] * w.at4(oc, ic, k / 3, k % 3);
                    }
                }
                let (oy, ox) = (pos / 5, pos % 5);
                let diff = (acc - want.at4(0, oc, oy, ox)).abs();
                assert!(diff < 1e-5, "pos {pos} oc {oc} diff {diff}");
            }
        }
    }

    #[test]
    fn from_meta_parses_arch() {
        let meta = Json::parse(
            r#"{"arch": [[16,3],[16,16],"M",[32,16]],
                "input_shape": [3,32,32]}"#,
        )
        .unwrap();
        let n = NetworkSpec::from_meta(&meta).unwrap();
        assert_eq!(n.layers.len(), 3);
        assert_eq!(n.layers[2].fmap, 16);
        assert_eq!(n.layers[2].cin, 16);
    }
}
