//! RPAT1 binary tensor container — byte-compatible with
//! `python/compile/weights_io.py` (see that file for the layout).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use super::Tensor;

const MAGIC: &[u8; 6] = b"RPAT1\x00";
const VERSION: u16 = 1;

/// A loaded tensor of any supported dtype.
#[derive(Debug, Clone, PartialEq)]
pub enum AnyTensor {
    F32(Tensor),
    I32 { shape: Vec<usize>, data: Vec<i32> },
    U8 { shape: Vec<usize>, data: Vec<u8> },
}

impl AnyTensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            AnyTensor::F32(t) => &t.shape,
            AnyTensor::I32 { shape, .. } => shape,
            AnyTensor::U8 { shape, .. } => shape,
        }
    }

    pub fn as_f32(&self) -> Option<&Tensor> {
        match self {
            AnyTensor::F32(t) => Some(t),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            AnyTensor::I32 { data, .. } => Some(data),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub enum IoError {
    Io(std::io::Error),
    Format(String),
}

impl std::fmt::Display for IoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IoError::Io(e) => write!(f, "io error: {e}"),
            IoError::Format(m) => write!(f, "format error: {m}"),
        }
    }
}

impl std::error::Error for IoError {}

impl From<std::io::Error> for IoError {
    fn from(e: std::io::Error) -> Self {
        IoError::Io(e)
    }
}

fn fmt_err<T>(msg: &str) -> Result<T, IoError> {
    Err(IoError::Format(msg.to_string()))
}

/// Load every tensor in an RPAT1 file.
pub fn load_tensors(path: &Path) -> Result<BTreeMap<String, AnyTensor>, IoError> {
    let mut blob = Vec::new();
    std::fs::File::open(path)?.read_to_end(&mut blob)?;
    parse_tensors(&blob)
}

/// Parse an RPAT1 blob.
pub fn parse_tensors(blob: &[u8]) -> Result<BTreeMap<String, AnyTensor>, IoError> {
    let mut c = Cursor { b: blob, i: 0 };
    if c.take(6)? != MAGIC {
        return fmt_err("bad magic");
    }
    let version = c.u16()?;
    if version != VERSION {
        return fmt_err(&format!("unsupported version {version}"));
    }
    let count = c.u32()? as usize;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let name_len = c.u16()? as usize;
        let name = String::from_utf8(c.take(name_len)?.to_vec())
            .map_err(|_| IoError::Format("bad utf8 name".into()))?;
        let dtype = c.u8()?;
        let ndim = c.u8()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(c.u32()? as usize);
        }
        let nbytes = c.u64()? as usize;
        let data = c.take(nbytes)?;
        let n_elem: usize = shape.iter().product();
        let t = match dtype {
            0 => {
                if nbytes != n_elem * 4 {
                    return fmt_err("f32 size mismatch");
                }
                let v = data
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                AnyTensor::F32(Tensor { shape, data: v })
            }
            1 => {
                if nbytes != n_elem * 4 {
                    return fmt_err("i32 size mismatch");
                }
                let v = data
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                AnyTensor::I32 { shape, data: v }
            }
            2 => {
                if nbytes != n_elem {
                    return fmt_err("u8 size mismatch");
                }
                AnyTensor::U8 { shape, data: data.to_vec() }
            }
            d => return fmt_err(&format!("unknown dtype {d}")),
        };
        out.insert(name, t);
    }
    Ok(out)
}

/// Save tensors to an RPAT1 file (f32 only — all this crate emits).
pub fn save_tensors(
    path: &Path,
    tensors: &BTreeMap<String, Tensor>,
) -> Result<(), IoError> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&[0u8, t.shape.len() as u8])?;
        for d in &t.shape {
            f.write_all(&(*d as u32).to_le_bytes())?;
        }
        f.write_all(&((t.data.len() * 4) as u64).to_le_bytes())?;
        for v in &t.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], IoError> {
        if self.i + n > self.b.len() {
            return fmt_err("truncated file");
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, IoError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, IoError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32, IoError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, IoError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let mut m = BTreeMap::new();
        m.insert(
            "w".to_string(),
            Tensor::from_vec(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 5.0, -6.25]),
        );
        m.insert("b".to_string(), Tensor::from_vec(&[3], vec![0.1, 0.2, 0.3]));
        let dir = std::env::temp_dir().join("rpat_test_roundtrip");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.bin");
        save_tensors(&p, &m).unwrap();
        let back = load_tensors(&p).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back["w"].as_f32().unwrap(), &m["w"]);
        assert_eq!(back["b"].as_f32().unwrap(), &m["b"]);
    }

    #[test]
    fn parse_python_style_blob() {
        // Hand-built blob: one i32 tensor "y" of shape [2] = [7, -1]
        let mut blob = Vec::new();
        blob.extend_from_slice(MAGIC);
        blob.extend_from_slice(&1u16.to_le_bytes());
        blob.extend_from_slice(&1u32.to_le_bytes());
        blob.extend_from_slice(&1u16.to_le_bytes());
        blob.push(b'y');
        blob.push(1); // dtype i32
        blob.push(1); // ndim
        blob.extend_from_slice(&2u32.to_le_bytes());
        blob.extend_from_slice(&8u64.to_le_bytes());
        blob.extend_from_slice(&7i32.to_le_bytes());
        blob.extend_from_slice(&(-1i32).to_le_bytes());
        let m = parse_tensors(&blob).unwrap();
        assert_eq!(m["y"].as_i32().unwrap(), &[7, -1]);
        assert_eq!(m["y"].shape(), &[2]);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(parse_tensors(b"NOPE").is_err());
        let mut blob = Vec::new();
        blob.extend_from_slice(MAGIC);
        blob.extend_from_slice(&1u16.to_le_bytes());
        blob.extend_from_slice(&5u32.to_le_bytes()); // claims 5 tensors
        assert!(parse_tensors(&blob).is_err());
    }

    #[test]
    fn scalar_tensor() {
        let mut m = BTreeMap::new();
        m.insert("s".to_string(), Tensor::from_vec(&[], vec![2.5]));
        let dir = std::env::temp_dir().join("rpat_test_scalar");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("s.bin");
        save_tensors(&p, &m).unwrap();
        let back = load_tensors(&p).unwrap();
        assert_eq!(back["s"].as_f32().unwrap().data, vec![2.5]);
        assert!(back["s"].shape().is_empty());
    }
}
