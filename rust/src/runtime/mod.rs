//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them
//! on the request path (Python never runs here).
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. HLO *text*
//! is the interchange format (see `python/compile/aot.py`).
//!
//! The `xla` crate (and the `anyhow` error type its API uses) ships
//! only in the full offline image, so the real engine is compiled
//! behind the `xla-runtime` feature (see Cargo.toml for the path
//! dependencies to wire). The default build substitutes an
//! API-compatible stub whose `load` reports the runtime as unavailable;
//! everything that needs a live engine (the serve subcommand, the
//! artifact e2e tests) is already gated on the artifacts being present.

#[cfg(feature = "xla-runtime")]
mod pjrt {
    use std::path::Path;

    use anyhow::{Context, Result};

    /// A compiled executable plus its client.
    pub struct Engine {
        client: xla::PjRtClient,
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl Engine {
        /// Is a real PJRT runtime compiled into this build?
        pub fn available() -> bool {
            true
        }

        /// Load and compile an HLO-text artifact on the CPU PJRT client.
        pub fn load(path: &Path) -> Result<Engine> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).context("compile HLO")?;
            Ok(Engine {
                client,
                exe,
                name: path
                    .file_name()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_default(),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Execute with f32 tensor inputs; returns the flattened f32
        /// outputs of the (1-tuple) result.
        ///
        /// `inputs` are `(shape, data)` pairs.
        pub fn run_f32(&self, inputs: &[(&[usize], &[f32])]) -> Result<Vec<f32>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (shape, data) in inputs {
                let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims)
                    .context("reshape input literal")?;
                literals.push(lit);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .context("execute")?[0][0]
                .to_literal_sync()
                .context("fetch result")?;
            // aot.py lowers with return_tuple=True => 1-tuple output.
            let out = result.to_tuple1().context("unwrap 1-tuple")?;
            out.to_vec::<f32>().context("read f32 output")
        }
    }
}

#[cfg(not(feature = "xla-runtime"))]
mod pjrt {
    use std::path::Path;

    /// Stub engine for builds without the vendored `xla` crate: `load`
    /// always fails with an explanatory error, so artifact-gated code
    /// paths degrade to a clear message instead of a link error.
    pub struct Engine {
        pub name: String,
    }

    impl Engine {
        /// Is a real PJRT runtime compiled into this build? `false` for
        /// the stub — callers on the serving path can fail fast with a
        /// clear message instead of panicking inside the worker thread.
        pub fn available() -> bool {
            false
        }

        pub fn load(path: &Path) -> Result<Engine, String> {
            Err(format!(
                "PJRT runtime unavailable for {}: rebuild with \
                 `--features xla-runtime` in the full image (see Cargo.toml)",
                path.display()
            ))
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn run_f32(
            &self,
            _inputs: &[(&[usize], &[f32])],
        ) -> Result<Vec<f32>, String> {
            Err("PJRT runtime unavailable (xla-runtime feature off)".to_string())
        }
    }
}

pub use pjrt::Engine;

/// Cloneable per-worker engine factory for the sharded serving pool:
/// every pool worker loads its *own* engine instance from the same
/// artifact path, inside its own thread — the PJRT client is not
/// `Send`, so engines can never be shared (or even moved) across worker
/// threads. Cloning the factory is cheap (one `PathBuf`); loading is
/// where the compile cost lives, paid once per worker at pool start.
#[derive(Debug, Clone)]
pub struct EngineFactory {
    path: std::path::PathBuf,
}

impl EngineFactory {
    pub fn new<P: Into<std::path::PathBuf>>(path: P) -> EngineFactory {
        EngineFactory { path: path.into() }
    }

    pub fn path(&self) -> &std::path::Path {
        &self.path
    }

    /// Load + compile a fresh engine for one worker. Errors are
    /// stringified so the signature is identical with and without the
    /// `xla-runtime` feature.
    pub fn load(&self) -> Result<Engine, String> {
        Engine::load(&self.path).map_err(|e| e.to_string())
    }
}

#[cfg(test)]
mod tests {
    // Engine tests that need artifacts/ live in tests/e2e.rs; here we
    // only check error paths that need no artifact.
    use std::path::Path;

    use super::*;

    #[test]
    fn missing_file_errors() {
        let r = Engine::load(Path::new("/nonexistent/model.hlo.txt"));
        assert!(r.is_err());
    }

    #[test]
    fn availability_matches_feature() {
        assert_eq!(Engine::available(), cfg!(feature = "xla-runtime"));
    }

    #[test]
    fn factory_is_cloneable_and_reports_missing_artifacts() {
        let f = EngineFactory::new("/nonexistent/model.hlo.txt");
        let g = f.clone();
        assert_eq!(f.path(), g.path());
        // each clone loads independently; both see the same failure
        assert!(f.load().is_err());
        assert!(g.load().is_err());
    }
}
