//! Diagnostics and the deterministic lint report.
//!
//! Every finding carries a `path:line:col` span, the rule id, a
//! severity, and a one-line message. Reports sort findings by
//! `(path, line, col, rule)` so text output, `--json` output, and the
//! `results/lint_report.json` artifact are byte-identical run to run —
//! the `lint-static` CI job compares two consecutive runs with `cmp`.

use crate::util::json::{obj, Json};
use std::collections::BTreeSet;

/// Finding severity. `error` findings always fail the lint exit code;
/// `warning` findings fail only under `--deny-warnings` (which is how
/// CI runs it, so the distinction only matters for local iteration).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One lint finding, anchored to the original source span (the
/// scrubber preserves line/column structure exactly).
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Display path — relative to the repo root for tree scans so the
    /// report is stable across checkouts and machines.
    pub path: String,
    /// 1-based line in the original file.
    pub line: usize,
    /// 1-based byte column in the original file.
    pub col: usize,
    pub rule: &'static str,
    pub severity: Severity,
    pub message: String,
}

impl Diagnostic {
    fn sort_key(&self) -> (&str, usize, usize, &'static str) {
        (&self.path, self.line, self.col, self.rule)
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("path", Json::from(self.path.as_str())),
            ("line", Json::from(self.line)),
            ("col", Json::from(self.col)),
            ("rule", Json::from(self.rule)),
            ("severity", Json::from(self.severity.name())),
            ("message", Json::from(self.message.as_str())),
        ])
    }

    fn render(&self) -> String {
        format!(
            "{}:{}:{}: {}[{}] {}",
            self.path,
            self.line,
            self.col,
            self.severity.name(),
            self.rule,
            self.message
        )
    }
}

/// Aggregated result of one lint run.
#[derive(Debug, Default)]
pub struct LintReport {
    /// Findings, sorted by `(path, line, col, rule)`.
    pub diagnostics: Vec<Diagnostic>,
    pub files_scanned: usize,
    /// Findings silenced by `lint:allow` pragmas.
    pub suppressed: usize,
}

impl LintReport {
    /// Add one file's findings and re-establish the global sort order.
    pub fn absorb(&mut self, diags: Vec<Diagnostic>, suppressed: usize) {
        self.diagnostics.extend(diags);
        self.suppressed += suppressed;
        self.files_scanned += 1;
        self.diagnostics.sort_by(|a, b| a.sort_key().cmp(&b.sort_key()));
    }

    pub fn errors(&self) -> usize {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Error)
            .count()
    }

    pub fn warnings(&self) -> usize {
        self.diagnostics
            .iter()
            .filter(|d| d.severity == Severity::Warning)
            .count()
    }

    /// Human-readable findings, one per line (empty string when clean).
    pub fn lines(&self) -> String {
        let mut s = String::new();
        for d in &self.diagnostics {
            s.push_str(&d.render());
            s.push('\n');
        }
        s
    }

    pub fn summary_line(&self) -> String {
        format!(
            "lint: {} files scanned, {} errors, {} warnings ({} suppressed)",
            self.files_scanned,
            self.errors(),
            self.warnings(),
            self.suppressed
        )
    }

    /// Deterministic JSON form (object keys sorted by the `Json`
    /// BTreeMap representation, findings in report order).
    pub fn to_json(&self) -> Json {
        let rules: BTreeSet<&'static str> =
            super::RULES.iter().map(|r| r.id).collect();
        obj(vec![
            ("version", Json::from(1usize)),
            ("files_scanned", Json::from(self.files_scanned)),
            ("errors", Json::from(self.errors())),
            ("warnings", Json::from(self.warnings())),
            ("suppressed", Json::from(self.suppressed)),
            (
                "rules",
                Json::Arr(rules.into_iter().map(Json::from).collect()),
            ),
            (
                "diagnostics",
                Json::Arr(self.diagnostics.iter().map(|d| d.to_json()).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diag(path: &str, line: usize, col: usize, rule: &'static str) -> Diagnostic {
        Diagnostic {
            path: path.to_string(),
            line,
            col,
            rule,
            severity: Severity::Error,
            message: "m".to_string(),
        }
    }

    #[test]
    fn report_sorts_by_path_line_col_rule() {
        let mut r = LintReport::default();
        r.absorb(vec![diag("b.rs", 2, 1, "r1"), diag("b.rs", 1, 5, "r2")], 0);
        r.absorb(vec![diag("a.rs", 9, 1, "r1")], 1);
        let order: Vec<(String, usize)> = r
            .diagnostics
            .iter()
            .map(|d| (d.path.clone(), d.line))
            .collect();
        assert_eq!(
            order,
            vec![
                ("a.rs".to_string(), 9),
                ("b.rs".to_string(), 1),
                ("b.rs".to_string(), 2)
            ]
        );
        assert_eq!(r.files_scanned, 2);
        assert_eq!(r.suppressed, 1);
    }

    #[test]
    fn json_shape_and_counts() {
        let mut r = LintReport::default();
        let mut w = diag("a.rs", 1, 1, "r1");
        w.severity = Severity::Warning;
        r.absorb(vec![w, diag("a.rs", 2, 1, "r2")], 3);
        let j = r.to_json();
        let s = j.to_string_pretty();
        let parsed = Json::parse(&s).expect("report JSON must parse");
        assert_eq!(parsed.get("errors").as_usize(), Some(1));
        assert_eq!(parsed.get("warnings").as_usize(), Some(1));
        assert_eq!(parsed.get("suppressed").as_usize(), Some(3));
    }
}
