//! Comment/string-aware source scrubber for the lint engine.
//!
//! Rules must never fire on text inside comments, string literals, char
//! literals, or raw strings — a doc comment *describing* `HashMap` is
//! not a determinism hazard. [`scrub`] rewrites a Rust source file so
//! that every byte inside those regions becomes a space (newlines are
//! preserved), which keeps all remaining code at its original line and
//! column. Rule matching then runs on the scrubbed text with plain
//! substring/identifier searches and reports spans that line up with
//! the original file.
//!
//! Comment *text* is not discarded: it is collected per line so that
//! suppression pragmas (`// lint:allow(rule)`) and fixture path
//! overrides (`// lint:path(virtual/path.rs)`) can be parsed without a
//! second pass.
//!
//! The scrubber understands the lexical shapes that trip naive
//! scanners: nested block comments (Rust block comments nest), raw
//! strings with arbitrary `#` fences (`r#"…"#`, `br##"…"##`), byte
//! strings, escaped quotes inside strings and char literals, and the
//! char-literal/lifetime ambiguity (`'a'` vs `'a`).

use std::collections::{BTreeMap, BTreeSet};

/// A source file with comments and literal contents blanked out, plus
/// the pragmas that were found inside the comments.
#[derive(Debug)]
pub struct ScrubbedSource {
    /// Scrubbed text: byte-for-byte the same length and line structure
    /// as the input, with comment/literal interiors replaced by spaces.
    pub code: String,
    /// Byte offset of the start of each line of `code` (line `i` is
    /// 1-based line `i + 1`).
    line_starts: Vec<usize>,
    /// `lint:allow` pragmas: line number → rule ids allowed there.
    pragmas: BTreeMap<usize, BTreeSet<String>>,
    /// `lint:path(...)` override, used by fixtures to opt into
    /// directory-scoped rules from outside the real tree.
    pub virtual_path: Option<String>,
}

impl ScrubbedSource {
    /// Map a byte offset in `code` to a 1-based `(line, column)`.
    pub fn line_col(&self, offset: usize) -> (usize, usize) {
        let idx = self.line_starts.partition_point(|&s| s <= offset) - 1;
        (idx + 1, offset - self.line_starts[idx] + 1)
    }

    /// Is `rule` suppressed at `line`? A pragma applies to its own line
    /// and to the line directly below it, so both styles work:
    ///
    /// ```text
    /// // lint:allow(no-wall-clock-in-pure-paths)
    /// let t0 = Instant::now();                  // suppressed (line above)
    /// let t1 = Instant::now(); // lint:allow(no-wall-clock-in-pure-paths)
    /// ```
    pub fn allows(&self, line: usize, rule: &str) -> bool {
        let hit = |l: usize| {
            self.pragmas
                .get(&l)
                .is_some_and(|rules| rules.contains(rule) || rules.contains("all"))
        };
        hit(line) || (line > 1 && hit(line - 1))
    }

    /// Number of `lint:allow` pragma lines found (for report stats).
    pub fn pragma_lines(&self) -> usize {
        self.pragmas.len()
    }
}

/// Lexer state: which kind of region the cursor is inside.
enum State {
    Code,
    LineComment,
    /// Block comment with nesting depth.
    BlockComment(u32),
    Str,
    /// Raw string terminated by `"` + this many `#`s.
    RawStr(usize),
    CharLit,
}

/// Scrub one source file. Never fails: unterminated literals or
/// comments simply blank through to end of file, which is the safe
/// direction for a linter (no false positives from inside them).
pub fn scrub(src: &str) -> ScrubbedSource {
    let b = src.as_bytes();
    let mut out: Vec<u8> = Vec::with_capacity(b.len());
    // comment text per line, for pragma parsing only (ASCII suffices)
    let mut comments: BTreeMap<usize, String> = BTreeMap::new();
    let mut state = State::Code;
    let mut line = 1usize;
    let mut i = 0usize;

    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            out.push(b'\n');
            line += 1;
            i += 1;
            if matches!(state, State::LineComment) {
                state = State::Code;
            }
            continue;
        }
        match state {
            State::Code => {
                if c == b'/' && b.get(i + 1) == Some(&b'/') {
                    state = State::LineComment;
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if c == b'/' && b.get(i + 1) == Some(&b'*') {
                    state = State::BlockComment(1);
                    out.extend_from_slice(b"  ");
                    i += 2;
                } else if c == b'"' {
                    state = State::Str;
                    out.push(b'"');
                    i += 1;
                } else if c == b'r' && at_ident_start(b, i) {
                    match raw_string_open(b, i) {
                        Some((len, hashes)) => {
                            blank(&mut out, len);
                            i += len;
                            state = State::RawStr(hashes);
                        }
                        None => {
                            out.push(c);
                            i += 1;
                        }
                    }
                } else if c == b'b' && at_ident_start(b, i) && b.get(i + 1) == Some(&b'r') {
                    match raw_string_open(b, i + 1) {
                        Some((len, hashes)) => {
                            blank(&mut out, len + 1);
                            i += len + 1;
                            state = State::RawStr(hashes);
                        }
                        None => {
                            out.push(c);
                            i += 1;
                        }
                    }
                } else if c == b'\'' {
                    if char_literal_ahead(b, i) {
                        state = State::CharLit;
                    }
                    // lifetimes keep their quote; the ident after is code
                    out.push(b'\'');
                    i += 1;
                } else {
                    out.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                record_comment_byte(&mut comments, line, c);
                out.push(b' ');
                i += 1;
            }
            State::BlockComment(depth) => {
                if c == b'*' && b.get(i + 1) == Some(&b'/') {
                    out.extend_from_slice(b"  ");
                    i += 2;
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment(depth - 1)
                    };
                } else if c == b'/' && b.get(i + 1) == Some(&b'*') {
                    out.extend_from_slice(b"  ");
                    i += 2;
                    state = State::BlockComment(depth + 1);
                } else {
                    record_comment_byte(&mut comments, line, c);
                    out.push(b' ');
                    i += 1;
                }
            }
            State::Str => {
                if c == b'\\' {
                    i = blank_escape(b, i, &mut out, &mut line);
                } else if c == b'"' {
                    out.push(b'"');
                    i += 1;
                    state = State::Code;
                } else {
                    out.push(b' ');
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if c == b'"' && closes_raw_string(b, i, hashes) {
                    blank(&mut out, 1 + hashes);
                    i += 1 + hashes;
                    state = State::Code;
                } else {
                    out.push(b' ');
                    i += 1;
                }
            }
            State::CharLit => {
                if c == b'\\' {
                    i = blank_escape(b, i, &mut out, &mut line);
                } else if c == b'\'' {
                    out.push(b'\'');
                    i += 1;
                    state = State::Code;
                } else {
                    out.push(b' ');
                    i += 1;
                }
            }
        }
    }

    // Blanked regions are pure ASCII spaces; code regions are copied
    // verbatim from valid UTF-8, so this cannot actually be lossy.
    let code = String::from_utf8_lossy(&out).into_owned();
    let mut line_starts = vec![0usize];
    for (off, byte) in code.bytes().enumerate() {
        if byte == b'\n' {
            line_starts.push(off + 1);
        }
    }
    let (pragmas, virtual_path) = parse_pragmas(&comments);
    ScrubbedSource { code, line_starts, pragmas, virtual_path }
}

/// Push `n` spaces (blanked delimiter or literal bytes).
fn blank(out: &mut Vec<u8>, n: usize) {
    out.resize(out.len() + n, b' ');
}

/// Blank a `\x`-style escape pair inside a string/char literal. The
/// escaped byte must be consumed here so `\"` and `\'` cannot be
/// mistaken for the closing delimiter; escaped newlines (string
/// continuation) keep the line structure intact.
fn blank_escape(b: &[u8], i: usize, out: &mut Vec<u8>, line: &mut usize) -> usize {
    out.push(b' ');
    let mut j = i + 1;
    if j < b.len() {
        if b[j] == b'\n' {
            out.push(b'\n');
            *line += 1;
        } else {
            out.push(b' ');
        }
        j += 1;
    }
    j
}

/// Would an identifier starting at `i` be a fresh token (not the tail
/// of a longer identifier like `attr` before `r"..."`)?
fn at_ident_start(b: &[u8], i: usize) -> bool {
    i == 0 || !is_word_byte(b[i - 1])
}

fn is_word_byte(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Does `r` at `i` open a raw string? Returns the opener length in
/// bytes (`r` + hashes + `"`) and the hash count.
fn raw_string_open(b: &[u8], i: usize) -> Option<(usize, usize)> {
    debug_assert_eq!(b[i], b'r');
    let mut j = i + 1;
    while j < b.len() && b[j] == b'#' {
        j += 1;
    }
    if j < b.len() && b[j] == b'"' {
        Some((j + 1 - i, j - (i + 1)))
    } else {
        None
    }
}

/// Does the `"` at `i` close a raw string with `hashes` fence hashes?
fn closes_raw_string(b: &[u8], i: usize, hashes: usize) -> bool {
    debug_assert_eq!(b[i], b'"');
    i + hashes < b.len() && b[i + 1..=i + hashes].iter().all(|&c| c == b'#')
}

/// Disambiguate a `'` in code position: char literal (`'x'`, `'\n'`,
/// `'\u{1F600}'`) vs lifetime (`'static`, `<'a>`). A quote is a char
/// literal iff it is followed by an escape, or by exactly one char and
/// a closing quote.
fn char_literal_ahead(b: &[u8], i: usize) -> bool {
    match b.get(i + 1) {
        None | Some(&b'\'') => false,
        Some(&b'\\') => true,
        Some(&first) => {
            let len = utf8_len(first);
            b.get(i + 1 + len) == Some(&b'\'')
        }
    }
}

/// Length in bytes of the UTF-8 sequence starting with `lead`.
fn utf8_len(lead: u8) -> usize {
    match lead {
        _ if lead < 0x80 => 1,
        _ if lead < 0xE0 => 2,
        _ if lead < 0xF0 => 3,
        _ => 4,
    }
}

fn record_comment_byte(comments: &mut BTreeMap<usize, String>, line: usize, c: u8) {
    let text = comments.entry(line).or_default();
    text.push(if c.is_ascii() { c as char } else { ' ' });
}

/// Extract `lint:allow(...)` / `lint:path(...)` directives from the
/// collected per-line comment text.
fn parse_pragmas(
    comments: &BTreeMap<usize, String>,
) -> (BTreeMap<usize, BTreeSet<String>>, Option<String>) {
    let mut pragmas: BTreeMap<usize, BTreeSet<String>> = BTreeMap::new();
    let mut virtual_path = None;
    for (&line, text) in comments {
        let mut rest = text.as_str();
        while let Some(pos) = rest.find("lint:allow(") {
            let body = &rest[pos + "lint:allow(".len()..];
            let Some(end) = body.find(')') else { break };
            let entry = pragmas.entry(line).or_default();
            for rule in body[..end].split(',') {
                let rule = rule.trim();
                if !rule.is_empty() {
                    entry.insert(rule.to_string());
                }
            }
            rest = &body[end..];
        }
        if virtual_path.is_none() {
            if let Some(pos) = text.find("lint:path(") {
                let body = &text[pos + "lint:path(".len()..];
                if let Some(end) = body.find(')') {
                    virtual_path = Some(body[..end].trim().to_string());
                }
            }
        }
    }
    (pragmas, virtual_path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_comments_blanked_code_kept() {
        let s = scrub("let x = 1; // HashMap here\nlet y = 2;\n");
        assert!(!s.code.contains("HashMap"));
        assert!(s.code.contains("let x = 1;"));
        assert!(s.code.contains("let y = 2;"));
    }

    #[test]
    fn nested_block_comments() {
        let s = scrub("a /* outer /* inner */ still comment */ b\n");
        assert!(s.code.contains('a'));
        assert!(s.code.contains('b'));
        assert!(!s.code.contains("outer"));
        assert!(!s.code.contains("still"));
    }

    #[test]
    fn string_contents_blanked_delimiters_kept() {
        let s = scrub(r#"let m = "HashMap::new() \" quoted"; iter()"#);
        assert!(!s.code.contains("HashMap"));
        assert!(s.code.contains("iter()"));
        // the escaped quote must not have closed the string early
        assert_eq!(s.code.matches('"').count(), 2);
    }

    #[test]
    fn raw_strings_with_fences() {
        let src = "let a = r#\"Instant::now() \"quoted\" \"#; after()";
        let s = scrub(src);
        assert!(!s.code.contains("Instant"));
        assert!(s.code.contains("after()"));
        let s2 = scrub("let b = br##\"SystemTime\"##; tail");
        assert!(!s2.code.contains("SystemTime"));
        assert!(s2.code.contains("tail"));
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let s = scrub("fn f<'a>(x: &'a str) { let q = '\\''; let z = 'z'; }");
        // lifetimes stay as code; char contents blank
        assert!(s.code.contains("<'a>"));
        assert!(s.code.contains("&'a str"));
        assert!(!s.code.contains("'z'"), "char contents must be blanked");
    }

    #[test]
    fn multiline_string_keeps_line_numbers() {
        let s = scrub("let a = \"one\ntwo\nthree\";\nlet b = 1;\n");
        // `let b` must still be on line 4
        let off = s.code.find("let b").unwrap();
        assert_eq!(s.line_col(off).0, 4);
    }

    #[test]
    fn pragma_same_and_previous_line() {
        let src = "\
// lint:allow(rule-x)
code line two
code line three // lint:allow(rule-y, rule-z)
";
        let s = scrub(src);
        assert!(s.allows(1, "rule-x"));
        assert!(s.allows(2, "rule-x"), "pragma covers the next line");
        assert!(!s.allows(3, "rule-x"));
        assert!(s.allows(3, "rule-y"));
        assert!(s.allows(3, "rule-z"));
        assert!(s.allows(4, "rule-z"));
        assert!(!s.allows(3, "rule-w"));
    }

    #[test]
    fn virtual_path_directive() {
        let s = scrub("// lint:path(rust/src/sim/fixture.rs)\nfn f() {}\n");
        assert_eq!(s.virtual_path.as_deref(), Some("rust/src/sim/fixture.rs"));
        assert!(scrub("fn f() {}\n").virtual_path.is_none());
    }

    #[test]
    fn line_col_roundtrip() {
        let s = scrub("abc\ndefgh\n");
        let off = s.code.find("fgh").unwrap();
        assert_eq!(s.line_col(off), (2, 3));
        assert_eq!(s.line_col(0), (1, 1));
    }
}
