//! The determinism/concurrency rule set.
//!
//! Each rule is a plain function over a [`ScrubbedSource`] (comments
//! and literals already blanked, so substring matches only ever hit
//! code). Rules push [`Diagnostic`]s with spans mapped back to the
//! original file; [`check_source`] runs all of them, applies
//! `lint:allow` pragmas, and returns the kept findings plus the
//! suppressed count.
//!
//! Directory-scoped rules classify a file by its *effective path*: the
//! `lint:path(...)` override when present (fixtures use it to opt into
//! a scope from `tests/lint_fixtures/`), otherwise the display path.

use super::diagnostics::{Diagnostic, Severity};
use super::lexer::ScrubbedSource;

/// Registry entry for one rule — drives `--help`, the JSON report's
/// `rules` array, and the module documentation.
#[derive(Debug, Clone, Copy)]
pub struct RuleSpec {
    pub id: &'static str,
    pub severity: Severity,
    pub summary: &'static str,
}

pub const NO_UNORDERED_ITERATION: &str = "no-unordered-iteration";
pub const NO_WALL_CLOCK: &str = "no-wall-clock-in-pure-paths";
pub const NO_AMBIENT_RNG: &str = "no-ambient-rng";
pub const NO_FLOAT_ACCUMULATION: &str = "no-float-accumulation-across-threads";
pub const MUTEX_DISCIPLINE: &str = "mutex-discipline";

/// All rules, in documentation order.
pub const RULES: [RuleSpec; 5] = [
    RuleSpec {
        id: NO_UNORDERED_ITERATION,
        severity: Severity::Error,
        summary: "HashMap/HashSet in serialization/hash-identity code (use BTreeMap/BTreeSet)",
    },
    RuleSpec {
        id: NO_WALL_CLOCK,
        severity: Severity::Error,
        summary: "Instant::now/SystemTime in sim/dse/report/mapping (pure paths take cycles, not clocks)",
    },
    RuleSpec {
        id: NO_AMBIENT_RNG,
        severity: Severity::Error,
        summary: "ambient randomness (thread_rng/RandomState/DefaultHasher); use seeded util::rng",
    },
    RuleSpec {
        id: NO_FLOAT_ACCUMULATION,
        severity: Severity::Warning,
        summary: "float += inside a parallel_map*/parallel_for closure (fold in canonical order instead)",
    },
    RuleSpec {
        id: MUTEX_DISCIPLINE,
        severity: Severity::Warning,
        summary: "raw .lock().unwrap()/.expect() outside util wrappers, or nested lock acquisitions",
    },
];

fn severity_of(id: &str) -> Severity {
    RULES
        .iter()
        .find(|r| r.id == id)
        .map(|r| r.severity)
        .unwrap_or(Severity::Error)
}

/// Files whose output feeds serialized artifacts or hash identities:
/// iteration order there must be deterministic. `src/obs/` is in scope
/// because its Chrome-trace exporter and snapshot ordering feed
/// byte-stable artifacts.
const SCOPE_SERIALIZATION: &[&str] = &[
    "src/report/",
    "src/dse/",
    "src/obs/",
    "src/store/",
    "src/util/json.rs",
];
/// Pure simulation/reporting paths — cycle-accurate, never wall-clock.
/// `src/obs/` is in scope too: spans carry caller-supplied timestamps
/// (the injected `util::clock::Clock`), never their own clock reads.
const SCOPE_PURE: &[&str] = &[
    "src/sim/",
    "src/dse/",
    "src/obs/",
    "src/report/",
    "src/mapping/",
];
/// The blessed home of lock wrappers (lockcheck, threadpool, prop).
const SCOPE_MUTEX_WRAPPERS: &[&str] = &["src/util/"];

fn in_scope(path: &str, scopes: &[&str]) -> bool {
    scopes.iter().any(|s| path.contains(s))
}

/// Run every rule on one scrubbed file. `display_path` is what shows in
/// diagnostics; scoping uses the `lint:path` override when present.
/// Returns `(kept findings, suppressed count)`.
pub fn check_source(
    display_path: &str,
    scrubbed: &ScrubbedSource,
) -> (Vec<Diagnostic>, usize) {
    let effective = scrubbed
        .virtual_path
        .clone()
        .unwrap_or_else(|| display_path.to_string());
    let mut diags = Vec::new();
    rule_unordered_iteration(&effective, scrubbed, &mut diags);
    rule_wall_clock(&effective, scrubbed, &mut diags);
    rule_ambient_rng(scrubbed, &mut diags);
    rule_float_accumulation(scrubbed, &mut diags);
    rule_mutex_discipline(&effective, scrubbed, &mut diags);

    let mut kept = Vec::new();
    let mut suppressed = 0usize;
    for mut d in diags {
        d.path = display_path.to_string();
        if scrubbed.allows(d.line, d.rule) {
            suppressed += 1;
        } else {
            kept.push(d);
        }
    }
    (kept, suppressed)
}

fn push(
    diags: &mut Vec<Diagnostic>,
    scrubbed: &ScrubbedSource,
    offset: usize,
    rule: &'static str,
    message: String,
) {
    let (line, col) = scrubbed.line_col(offset);
    diags.push(Diagnostic {
        path: String::new(), // filled in by check_source
        line,
        col,
        rule,
        severity: severity_of(rule),
        message,
    });
}

// ---------------------------------------------------------------------------
// matching helpers
// ---------------------------------------------------------------------------

fn is_word(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Byte offsets of whole-identifier occurrences of `ident` in `text`
/// (no match inside a longer identifier).
fn ident_occurrences(text: &str, ident: &str) -> Vec<usize> {
    let tb = text.as_bytes();
    let mut hits = Vec::new();
    let mut from = 0usize;
    while let Some(p) = text[from..].find(ident) {
        let at = from + p;
        let end = at + ident.len();
        let before_ok = at == 0 || !is_word(tb[at - 1]);
        let after_ok = end >= tb.len() || !is_word(tb[end]);
        if before_ok && after_ok {
            hits.push(at);
        }
        from = at + ident.len();
    }
    hits
}

/// Skip ASCII whitespace (including newlines) from `i`.
fn skip_ws(tb: &[u8], mut i: usize) -> usize {
    while i < tb.len() && tb[i].is_ascii_whitespace() {
        i += 1;
    }
    i
}

// ---------------------------------------------------------------------------
// rules
// ---------------------------------------------------------------------------

/// Rule 1: `no-unordered-iteration`.
fn rule_unordered_iteration(
    path: &str,
    scrubbed: &ScrubbedSource,
    diags: &mut Vec<Diagnostic>,
) {
    if !in_scope(path, SCOPE_SERIALIZATION) {
        return;
    }
    for ty in ["HashMap", "HashSet"] {
        for at in ident_occurrences(&scrubbed.code, ty) {
            push(
                diags,
                scrubbed,
                at,
                NO_UNORDERED_ITERATION,
                format!(
                    "{ty} in serialization/hash-identity scope — iteration order \
                     is nondeterministic; use BTreeMap/BTreeSet or sort before emitting"
                ),
            );
        }
    }
}

/// Rule 2: `no-wall-clock-in-pure-paths`.
fn rule_wall_clock(path: &str, scrubbed: &ScrubbedSource, diags: &mut Vec<Diagnostic>) {
    if !in_scope(path, SCOPE_PURE) {
        return;
    }
    for at in ident_occurrences(&scrubbed.code, "Instant::now") {
        push(
            diags,
            scrubbed,
            at,
            NO_WALL_CLOCK,
            "Instant::now in a pure path — simulated time must come from cycle \
             counts, not the wall clock"
                .to_string(),
        );
    }
    for at in ident_occurrences(&scrubbed.code, "SystemTime") {
        push(
            diags,
            scrubbed,
            at,
            NO_WALL_CLOCK,
            "SystemTime in a pure path — artifacts must not depend on the wall clock"
                .to_string(),
        );
    }
}

/// Rule 3: `no-ambient-rng` (applies everywhere).
fn rule_ambient_rng(scrubbed: &ScrubbedSource, diags: &mut Vec<Diagnostic>) {
    for ident in ["thread_rng", "from_entropy", "RandomState", "DefaultHasher"] {
        for at in ident_occurrences(&scrubbed.code, ident) {
            push(
                diags,
                scrubbed,
                at,
                NO_AMBIENT_RNG,
                format!(
                    "{ident} is ambient/unseeded randomness — route through \
                     util::rng::Rng::seed_from so runs reproduce from a recorded seed"
                ),
            );
        }
    }
    // bare `rand::` paths (the crate is pure-std; any appearance is a
    // nondeterminism escape hatch sneaking in)
    let tb = scrubbed.code.as_bytes();
    for at in ident_occurrences(&scrubbed.code, "rand") {
        let after = skip_ws(tb, at + "rand".len());
        if scrubbed.code[after..].starts_with("::") {
            push(
                diags,
                scrubbed,
                at,
                NO_AMBIENT_RNG,
                "rand:: path — this crate's randomness flows through seeded util::rng"
                    .to_string(),
            );
        }
    }
}

/// Rule 4: `no-float-accumulation-across-threads`. Finds the lexical
/// extent of every `parallel_map(`, `parallel_map_indexed(`, and
/// `parallel_for(` call (balanced parentheses on scrubbed text) and
/// flags `+=` inside it: a shared-float accumulation inside a parallel
/// closure commits results in scheduling order, which breaks
/// byte-identical artifacts across thread counts. Fold the returned
/// per-item values in index order instead.
fn rule_float_accumulation(scrubbed: &ScrubbedSource, diags: &mut Vec<Diagnostic>) {
    let text = &scrubbed.code;
    let tb = text.as_bytes();
    for callee in ["parallel_map_indexed", "parallel_map", "parallel_for"] {
        for at in ident_occurrences(text, callee) {
            let open = skip_ws(tb, at + callee.len());
            if open >= tb.len() || tb[open] != b'(' {
                continue; // definition, import, or reference — not a call
            }
            let Some(close) = matching_paren(tb, open) else {
                continue;
            };
            let mut from = open;
            while let Some(p) = text[from..close].find("+=") {
                let hit = from + p;
                push(
                    diags,
                    scrubbed,
                    hit,
                    NO_FLOAT_ACCUMULATION,
                    format!(
                        "`+=` inside a {callee} closure — cross-thread accumulation \
                         commits in scheduling order; return per-item values and fold \
                         them in index order after the join"
                    ),
                );
                from = hit + 2;
            }
        }
    }
}

/// Offset of the `)` matching the `(` at `open`, if balanced.
fn matching_paren(tb: &[u8], open: usize) -> Option<usize> {
    debug_assert_eq!(tb[open], b'(');
    let mut depth = 0usize;
    for (i, &c) in tb.iter().enumerate().skip(open) {
        match c {
            b'(' => depth += 1,
            b')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

/// Rule 5: `mutex-discipline`. Outside `util` (home of the lockcheck
/// and threadpool wrappers) flags:
///
///   * `.lock().unwrap()` / `.lock().expect(` — raw poison-propagating
///     acquisition; go through `util::lockcheck::Mutex`, whose `lock()`
///     recovers poison and feeds the lock-order probe;
///   * two `.lock(` acquisitions inside one statement (no `;`/`{`/`}`
///     between them) — a nested hold with an order the compiler cannot
///     see; take one guard at a time or document the order in
///     lockcheck names.
fn rule_mutex_discipline(
    path: &str,
    scrubbed: &ScrubbedSource,
    diags: &mut Vec<Diagnostic>,
) {
    if in_scope(path, SCOPE_MUTEX_WRAPPERS) {
        return;
    }
    let text = &scrubbed.code;
    let tb = text.as_bytes();
    let mut lock_sites = Vec::new();
    let mut from = 0usize;
    while let Some(p) = text[from..].find(".lock(") {
        let at = from + p;
        lock_sites.push(at);
        from = at + ".lock(".len();
    }
    for &at in &lock_sites {
        // `.lock(` … `)` then optionally chained `.unwrap()` / `.expect(`
        let Some(close) = matching_paren(tb, at + ".lock".len()) else {
            continue;
        };
        let next = skip_ws(tb, close + 1);
        let tail = &text[next.min(text.len())..];
        if tail.starts_with(".unwrap()") || tail.starts_with(".expect(") {
            push(
                diags,
                scrubbed,
                at + 1,
                MUTEX_DISCIPLINE,
                "raw .lock().unwrap()/.expect() — poison propagates and wedges \
                 surviving threads; use util::lockcheck::Mutex (poison-recovering, \
                 order-checked under --features lockcheck)"
                    .to_string(),
            );
        }
    }
    for pair in lock_sites.windows(2) {
        let (a, b) = (pair[0], pair[1]);
        let between = &text[a..b];
        if !between.contains(';') && !between.contains('{') && !between.contains('}') {
            push(
                diags,
                scrubbed,
                b + 1,
                MUTEX_DISCIPLINE,
                "second lock acquisition in the same statement — nested holds have \
                 an implicit order the compiler cannot check; acquire one guard at \
                 a time (lockcheck asserts a global order at runtime)"
                    .to_string(),
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer::scrub;

    fn run(path: &str, src: &str) -> Vec<Diagnostic> {
        let s = scrub(src);
        check_source(path, &s).0
    }

    fn rules_of(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.rule).collect()
    }

    #[test]
    fn ident_boundaries_respected() {
        assert_eq!(ident_occurrences("MyHashMapLike HashMap x", "HashMap"), vec![14]);
        assert!(ident_occurrences("HashMapper", "HashMap").is_empty());
    }

    #[test]
    fn unordered_iteration_scoped() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(
            rules_of(&run("rust/src/report/mod.rs", src)),
            vec![NO_UNORDERED_ITERATION]
        );
        // out of scope: coordinator may keep hash containers
        assert!(run("rust/src/coordinator/mod.rs", src).is_empty());
    }

    #[test]
    fn wall_clock_scoped_and_comment_safe() {
        let bad = "let t = std::time::Instant::now();\n";
        assert_eq!(rules_of(&run("rust/src/sim/mod.rs", bad)), vec![NO_WALL_CLOCK]);
        assert!(run("rust/src/coordinator/mod.rs", bad).is_empty());
        // mention in a comment or string never fires
        let commented = "// Instant::now is banned here\nlet s = \"SystemTime\";\n";
        assert!(run("rust/src/sim/mod.rs", commented).is_empty());
    }

    /// The tracing layer is covered by both the wall-clock and the
    /// unordered-iteration scopes: spans must carry injected
    /// timestamps, and the Chrome exporter feeds byte-stable artifacts.
    #[test]
    fn obs_is_in_pure_and_serialization_scope() {
        let clock = "let t = std::time::Instant::now();\n";
        assert_eq!(
            rules_of(&run("rust/src/obs/span.rs", clock)),
            vec![NO_WALL_CLOCK]
        );
        let hash = "use std::collections::HashMap;\n";
        assert_eq!(
            rules_of(&run("rust/src/obs/chrome.rs", hash)),
            vec![NO_UNORDERED_ITERATION]
        );
    }

    #[test]
    fn ambient_rng_everywhere() {
        let src = "let h = DefaultHasher::new();\nlet r = rand::thread_rng();\n";
        let diags = run("rust/src/arch/mod.rs", src);
        // DefaultHasher + rand:: + thread_rng
        assert_eq!(diags.len(), 3);
        assert!(diags.iter().all(|d| d.rule == NO_AMBIENT_RNG));
    }

    #[test]
    fn float_accumulation_only_inside_extent() {
        let bad = "parallel_for(n, t, |i| {\n    total += parts[i];\n});\n";
        let diags = run("rust/src/sim/mod.rs", bad);
        assert_eq!(rules_of(&diags), vec![NO_FLOAT_ACCUMULATION]);
        assert_eq!(diags[0].line, 2);
        let good = "let v = parallel_map(items, t, |x| x * 2.0);\nlet mut s = 0.0;\nfor x in v { s += x; }\n";
        assert!(run("rust/src/sim/mod.rs", good).is_empty());
        // a definition (no call parens) is not an extent
        let def = "pub fn parallel_map<T>() {}\nlet mut z = 0.0; z += 1.0;\n";
        assert!(run("rust/src/sim/mod.rs", def).is_empty());
    }

    #[test]
    fn mutex_discipline_patterns() {
        let raw = "m.lock().unwrap().push(v);\n";
        assert_eq!(rules_of(&run("rust/src/coordinator/mod.rs", raw)), vec![MUTEX_DISCIPLINE]);
        // split across lines still matches
        let split = "m.lock()\n    .unwrap()\n    .push(v);\n";
        assert_eq!(rules_of(&run("rust/src/coordinator/mod.rs", split)), vec![MUTEX_DISCIPLINE]);
        // nested acquisition in one statement: 2 raw unwraps + 1 nesting
        let nested = "let n = a.lock().unwrap().len() + b.lock().unwrap().len();\n";
        assert_eq!(run("rust/src/coordinator/mod.rs", nested).len(), 3);
        // util wrappers are exempt
        assert!(run("rust/src/util/threadpool.rs", raw).is_empty());
        // a poison-recovering lock() without unwrap is clean
        let clean = "let g = m.lock();\ng.push(v);\n";
        assert!(run("rust/src/coordinator/mod.rs", clean).is_empty());
    }

    #[test]
    fn pragma_suppression_counted() {
        let src = "// lint:allow(no-wall-clock-in-pure-paths)\nlet t = std::time::Instant::now();\n";
        let s = scrub(src);
        let (kept, suppressed) = check_source("rust/src/sim/mod.rs", &s);
        assert!(kept.is_empty());
        assert_eq!(suppressed, 1);
    }

    #[test]
    fn virtual_path_opts_into_scope() {
        let src = "// lint:path(rust/src/report/fixture.rs)\nuse std::collections::HashSet;\n";
        let diags = run("tests/lint_fixtures/bad/x.rs", src);
        assert_eq!(rules_of(&diags), vec![NO_UNORDERED_ITERATION]);
        // display path stays the real one
        assert_eq!(diags[0].path, "tests/lint_fixtures/bad/x.rs");
    }
}
