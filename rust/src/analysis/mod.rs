//! In-tree static analysis: the `rram-accel lint` determinism &
//! concurrency pass.
//!
//! The repo's core promise — paper artifacts and DSE frontiers that are
//! byte-identical across thread counts and cache states — used to be
//! enforced only by after-the-fact snapshot tests. This module checks
//! the *sources* instead: a zero-dependency scanner (the crate is
//! pure-std by design, so no clippy plugins or external lint
//! frameworks) built from a comment/string-aware lexer
//! ([`lexer::scrub`]), a rule engine ([`rules::check_source`]), and
//! deterministic diagnostics ([`diagnostics::LintReport`]). The CLI
//! front-end is `rram-accel lint [--json] [--deny-warnings] [paths…]`,
//! which self-scans `rust/`, `tests/`, and `benches/` by default and is
//! gated in CI by the `lint-static` job.
//!
//! # Rules
//!
//! ### `no-unordered-iteration` (error)
//! **Where:** serialization/hash-identity scopes — `src/report/`,
//! `src/dse/`, `src/obs/`, `src/store/`, `src/util/json.rs`.
//! **Why:** `HashMap`/`HashSet` iteration order varies run to run (and
//! is seeded per-process by the std hasher), so any artifact or cache
//! key built by iterating one is nondeterministic. Everything feeding
//! `results/` goes through `BTreeMap`/sorted vectors.
//! **Example:** `for (k, v) in hash_map { out.push_str(k); }` in a JSON
//! emitter flags the `HashMap` type mention; rewrite on `BTreeMap` or
//! sort the pairs first.
//!
//! ### `no-wall-clock-in-pure-paths` (error)
//! **Where:** `src/sim/`, `src/dse/`, `src/obs/`, `src/report/`,
//! `src/mapping/`.
//! **Why:** pure paths model time as cycle counts; an `Instant::now()`
//! or `SystemTime` read makes outputs depend on host speed and breaks
//! replay. The tracing layer (`src/obs/`) records caller-supplied
//! timestamps from an injected `util::clock::Clock` for the same
//! reason. The coordinator/serving edge and benches measure real
//! latency and are out of scope (or use a pragma).
//! **Example:** `let t0 = Instant::now();` inside the simulator flags;
//! derive durations from `HardwareConfig` cycle counts instead.
//!
//! ### `no-ambient-rng` (error)
//! **Where:** everywhere.
//! **Why:** every experiment must reproduce from a seed recorded in the
//! report, so all randomness flows through seeded
//! [`crate::util::rng::Rng`]. `thread_rng`, `from_entropy`,
//! `RandomState`, `DefaultHasher`, and `rand::` paths are ambient
//! entropy.
//! **Example:** `let mut h = DefaultHasher::new();` flags; hash with
//! [`crate::util::fnv1a`] instead.
//!
//! ### `no-float-accumulation-across-threads` (warning)
//! **Where:** the lexical extent of `parallel_map(`,
//! `parallel_map_indexed(`, and `parallel_for(` calls, everywhere.
//! **Why:** float addition is not associative; `+=` onto shared state
//! inside a parallel closure commits in scheduling order, so totals
//! drift with thread count. Return per-item values and fold them in
//! index order after the join (what `parallel_map` already guarantees).
//! **Example:** `parallel_for(n, t, |i| { *total.lock() += part[i]; })`
//! flags the `+=`.
//!
//! ### `mutex-discipline` (warning)
//! **Where:** everywhere except `src/util/` (home of the blessed
//! wrappers).
//! **Why:** raw `.lock().unwrap()` propagates poison — one panicked
//! worker wedges every surviving thread that touches the same lock —
//! and nested single-statement acquisitions embed a lock order the
//! compiler cannot check. Use [`crate::util::lockcheck::Mutex`]: its
//! `lock()` recovers poison, and under `--features lockcheck` it
//! records per-thread acquisition stacks, asserts one global lock
//! order, and counts contention.
//! **Example:** `m.lock().unwrap().push(v)` flags; so does
//! `a.lock().x() + b.lock().y()` (nested hold in one statement).
//!
//! # Suppression pragmas
//!
//! `// lint:allow(<rule-id>[, <rule-id>…])` silences the named rules on
//! the pragma's own line and on the line directly below it:
//!
//! ```text
//! // lint:allow(no-wall-clock-in-pure-paths)
//! let t0 = std::time::Instant::now(); // benchmark-only code path
//! ```
//!
//! `lint:allow(all)` silences every rule for that span. Suppressed
//! findings are counted in the report's `suppressed` field, so a pragma
//! is visible, not free.
//!
//! `// lint:path(<virtual path>)` re-classifies the file for
//! directory-scoped rules — the fixture corpus under
//! `tests/lint_fixtures/` uses it to exercise scoped rules from outside
//! the real tree. Display paths in diagnostics stay real.
//!
//! # Determinism of the lint itself
//!
//! File walks are collected and sorted, findings are sorted by
//! `(path, line, col, rule)`, tree scans report repo-relative paths,
//! and the JSON encoder keys objects through `BTreeMap` — two runs over
//! the same tree produce byte-identical `--json` output and
//! `results/lint_report.json`, which the `lint-static` CI job verifies
//! with `cmp`.

pub mod diagnostics;
pub mod lexer;
pub mod rules;

pub use diagnostics::{Diagnostic, LintReport, Severity};
pub use rules::{RuleSpec, RULES};

use std::path::{Path, PathBuf};

/// Default scan roots for a tree scan, relative to `base`: every `.rs`
/// file under `rust/`, `tests/`, and `benches/`. The lint fixture
/// corpus is excluded — its `bad/` half exists to fail.
fn default_roots(base: &Path) -> Vec<PathBuf> {
    ["rust", "tests", "benches"]
        .iter()
        .map(|d| base.join(d))
        .filter(|p| p.is_dir())
        .collect()
}

/// Recursively collect `.rs` files under `root` (sorted for
/// deterministic reports). `skip_fixtures` drops anything under a
/// `lint_fixtures` directory — used by the default self-scan.
pub fn collect_rs_files(root: &Path, skip_fixtures: bool) -> std::io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    if root.is_file() {
        out.push(root.to_path_buf());
        return Ok(out);
    }
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in std::fs::read_dir(&dir)? {
            let path = entry?.path();
            if skip_fixtures
                && path
                    .file_name()
                    .is_some_and(|n| n == "lint_fixtures")
            {
                continue;
            }
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Lint one file, returning its findings under `display_path`.
fn lint_file(path: &Path, display_path: &str) -> Result<(Vec<Diagnostic>, usize), String> {
    let src = std::fs::read_to_string(path)
        .map_err(|e| format!("read {}: {e}", path.display()))?;
    let scrubbed = lexer::scrub(&src);
    Ok(rules::check_source(display_path, &scrubbed))
}

/// Self-scan the crate tree rooted at `base` (repo root /
/// `CARGO_MANIFEST_DIR`). Paths in diagnostics are reported relative to
/// `base` with `/` separators, so reports are byte-stable across
/// checkouts.
pub fn lint_tree(base: &Path) -> Result<LintReport, String> {
    let mut report = LintReport::default();
    for root in default_roots(base) {
        let files = collect_rs_files(&root, true)
            .map_err(|e| format!("walk {}: {e}", root.display()))?;
        for f in files {
            let display = display_path(&f, Some(base));
            let (diags, suppressed) = lint_file(&f, &display)?;
            report.absorb(diags, suppressed);
        }
    }
    Ok(report)
}

/// Lint an explicit list of files/directories (CLI positional args).
/// Explicit roots are scanned in full — no fixture exclusion — and
/// reported under the paths as given.
pub fn lint_roots(roots: &[PathBuf]) -> Result<LintReport, String> {
    let mut report = LintReport::default();
    for root in roots {
        let files = collect_rs_files(root, false)
            .map_err(|e| format!("walk {}: {e}", root.display()))?;
        for f in files {
            let display = display_path(&f, None);
            let (diags, suppressed) = lint_file(&f, &display)?;
            report.absorb(diags, suppressed);
        }
    }
    Ok(report)
}

/// Normalized display path: relative to `base` when given and possible,
/// always `/`-separated.
fn display_path(path: &Path, base: Option<&Path>) -> String {
    let p = match base.and_then(|b| path.strip_prefix(b).ok()) {
        Some(rel) => rel,
        None => path,
    };
    p.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_ids_unique_and_kebab() {
        let mut ids: Vec<&str> = RULES.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        let n = ids.len();
        ids.dedup();
        assert_eq!(ids.len(), n, "duplicate rule id");
        for id in ids {
            assert!(
                id.chars().all(|c| c.is_ascii_lowercase() || c == '-'),
                "rule id {id} is not kebab-case"
            );
        }
    }

    #[test]
    fn collect_skips_fixture_corpus() {
        let base = Path::new(env!("CARGO_MANIFEST_DIR"));
        let files = collect_rs_files(&base.join("tests"), true).unwrap();
        assert!(
            files.iter().all(|f| !f.to_string_lossy().contains("lint_fixtures")),
            "fixture corpus must be excluded from the self-scan"
        );
        let all = collect_rs_files(&base.join("tests"), false).unwrap();
        assert!(
            all.iter().any(|f| f.to_string_lossy().contains("lint_fixtures")),
            "explicit scans include fixtures"
        );
        // sorted ⇒ deterministic report order
        let mut sorted = files.clone();
        sorted.sort();
        assert_eq!(files, sorted);
    }

    #[test]
    fn display_paths_are_relative_and_slashed() {
        let base = Path::new("/repo");
        let p = Path::new("/repo/rust/src/sim/mod.rs");
        assert_eq!(display_path(p, Some(base)), "rust/src/sim/mod.rs");
        assert_eq!(display_path(Path::new("x/y.rs"), None), "x/y.rs");
    }
}
