//! Bounded, allocation-light HTTP/1.1 request reader.
//!
//! Reads one request head (request line + headers) and its
//! `Content-Length`-delimited body from any [`Read`] stream, enforcing
//! hard caps at every step so no peer can make the server buffer an
//! unbounded amount: the head is capped at [`MAX_HEAD_BYTES`] and
//! [`MAX_HEADERS`] header lines, the body at the caller's limit, and a
//! socket read timeout (set by the connection handler) surfaces as
//! [`ReadError::Timeout`]. Only the fields the router consumes are
//! retained — method, target, content length, keep-alive — header
//! names/values are scanned in place and dropped.
//!
//! The reader is generic over [`Read`] (not `TcpStream`) so the
//! malformed-input and fuzz suites can drive it from in-memory byte
//! slices without sockets.

use std::io::Read;

/// Hard cap on the request line + headers, terminator included.
pub const MAX_HEAD_BYTES: usize = 8 * 1024;

/// Hard cap on the number of header lines.
pub const MAX_HEADERS: usize = 64;

/// The subset of a request head the router needs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestHead {
    pub method: String,
    pub target: String,
    pub content_length: usize,
    /// Peer asked for `Connection: close` (or spoke HTTP/1.0).
    pub connection_close: bool,
}

/// Why a request could not be read. Each variant maps to exactly one
/// connection-handler behavior (see the module doc in `serve_http`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReadError {
    /// Clean EOF (or idle timeout) before the first byte of a request:
    /// normal keep-alive termination, close without a response.
    ClosedIdle,
    /// The read timeout expired mid-request → 408.
    Timeout,
    /// The peer closed the connection mid-request → 400.
    Truncated,
    /// Malformed request line, header, or Content-Length → 400.
    BadRequest(&'static str),
    /// Head exceeded [`MAX_HEAD_BYTES`] or [`MAX_HEADERS`] → 431.
    HeadTooLarge,
    /// Declared Content-Length exceeds the configured body cap → 413.
    BodyTooLarge,
}

impl ReadError {
    /// Human-readable detail for the error response body.
    pub fn detail(&self) -> &'static str {
        match self {
            ReadError::ClosedIdle => "connection closed",
            ReadError::Timeout => "read timeout",
            ReadError::Truncated => "connection closed mid-request",
            ReadError::BadRequest(m) => m,
            ReadError::HeadTooLarge => "request head too large",
            ReadError::BodyTooLarge => "request body exceeds limit",
        }
    }
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Read one request from `r`. `carry` holds bytes already read off the
/// stream but not yet consumed (pipelined data past the previous
/// request's body); it is consumed first and refilled with any overrun,
/// so back-to-back keep-alive requests never lose bytes.
///
/// Returns the parsed head and the exact `content_length` body bytes.
pub fn read_request<R: Read>(
    r: &mut R,
    carry: &mut Vec<u8>,
    max_body: usize,
) -> Result<(RequestHead, Vec<u8>), ReadError> {
    let mut buf = std::mem::take(carry);
    let mut chunk = [0u8; 4096];

    // Phase 1: accumulate until the head terminator, within the cap.
    // The cap applies to the head itself (terminator position), not
    // just the running buffer — otherwise a head whose terminator
    // lands inside the next read chunk would slip through or not
    // depending on how the peer's bytes happened to be segmented.
    let head_end = loop {
        if let Some(pos) = find_terminator(&buf) {
            if pos > MAX_HEAD_BYTES {
                return Err(ReadError::HeadTooLarge);
            }
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(ReadError::HeadTooLarge);
        }
        match r.read(&mut chunk) {
            Ok(0) => {
                return Err(if buf.is_empty() {
                    ReadError::ClosedIdle
                } else {
                    ReadError::Truncated
                });
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if is_timeout(&e) => {
                return Err(if buf.is_empty() {
                    ReadError::ClosedIdle
                } else {
                    ReadError::Timeout
                });
            }
            Err(_) => return Err(ReadError::Truncated),
        }
    };

    let head = parse_head(&buf[..head_end], max_body)?;
    let body_start = head_end + 4;

    // Phase 2: the body — take what phase 1 over-read, then the rest.
    let mut body = Vec::with_capacity(head.content_length.min(buf.len()));
    let available = buf.len() - body_start;
    let from_buf = available.min(head.content_length);
    body.extend_from_slice(&buf[body_start..body_start + from_buf]);
    // Anything past this request's body is the next pipelined request.
    *carry = buf.split_off(body_start + from_buf);
    while body.len() < head.content_length {
        match r.read(&mut chunk) {
            Ok(0) => return Err(ReadError::Truncated),
            Ok(n) => {
                let need = head.content_length - body.len();
                body.extend_from_slice(&chunk[..n.min(need)]);
                if n > need {
                    carry.extend_from_slice(&chunk[need..n]);
                }
            }
            Err(e) if is_timeout(&e) => return Err(ReadError::Timeout),
            Err(_) => return Err(ReadError::Truncated),
        }
    }
    Ok((head, body))
}

/// Byte offset of the `\r\n\r\n` head terminator, if present.
fn find_terminator(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Parse the request line + headers (everything before the terminator).
fn parse_head(head: &[u8], max_body: usize) -> Result<RequestHead, ReadError> {
    let text = std::str::from_utf8(head)
        .map_err(|_| ReadError::BadRequest("request head is not UTF-8"))?;
    let mut lines = text.split("\r\n");
    let request_line =
        lines.next().ok_or(ReadError::BadRequest("empty request"))?;
    let mut parts = request_line.split(' ');
    let method = parts.next().unwrap_or("");
    let target = parts.next().unwrap_or("");
    let version = parts.next().unwrap_or("");
    if parts.next().is_some() {
        return Err(ReadError::BadRequest("malformed request line"));
    }
    if method.is_empty()
        || !method.bytes().all(|b| b.is_ascii_uppercase())
        || method.len() > 16
    {
        return Err(ReadError::BadRequest("malformed method"));
    }
    if target.is_empty()
        || !target.starts_with('/')
        || target.bytes().any(|b| b <= b' ' || b == 0x7f)
    {
        return Err(ReadError::BadRequest("malformed request target"));
    }
    let connection_close_default = match version {
        "HTTP/1.1" => false,
        "HTTP/1.0" => true,
        _ => return Err(ReadError::BadRequest("unsupported HTTP version")),
    };

    let mut content_length: Option<usize> = None;
    let mut connection_close = connection_close_default;
    let mut n_headers = 0usize;
    for line in lines {
        if line.is_empty() {
            // split() yields one trailing empty piece when the head
            // ends in \r\n; an empty line elsewhere is malformed.
            continue;
        }
        n_headers += 1;
        if n_headers > MAX_HEADERS {
            return Err(ReadError::HeadTooLarge);
        }
        let (name, value) = line
            .split_once(':')
            .ok_or(ReadError::BadRequest("malformed header line"))?;
        // RFC 9112 §5.1: the field name is a token — no whitespace of
        // any kind (space, HTAB, bare CR, ...), no control bytes, no
        // DEL. Rejecting only ' ' would let `Content-Length\t: N` parse
        // as an *unknown* header, bypassing the body-length checks and
        // letting the payload be reparsed as a pipelined request
        // (request smuggling).
        if name.is_empty()
            || name.bytes().any(|b| b <= b' ' || b == 0x7f || !b.is_ascii())
        {
            return Err(ReadError::BadRequest("malformed header name"));
        }
        let value = value.trim();
        if name.eq_ignore_ascii_case("content-length") {
            if content_length.is_some() {
                return Err(ReadError::BadRequest("duplicate Content-Length"));
            }
            if value.is_empty() || !value.bytes().all(|b| b.is_ascii_digit()) {
                return Err(ReadError::BadRequest("malformed Content-Length"));
            }
            let n: u64 = value
                .parse()
                .map_err(|_| ReadError::BadRequest("Content-Length overflow"))?;
            if n > max_body as u64 {
                return Err(ReadError::BodyTooLarge);
            }
            content_length = Some(n as usize);
        } else if name.eq_ignore_ascii_case("transfer-encoding") {
            return Err(ReadError::BadRequest(
                "Transfer-Encoding is not supported; use Content-Length",
            ));
        } else if name.eq_ignore_ascii_case("connection") {
            // RFC 9110 §7.6.1: Connection carries a comma-separated
            // token list (`Connection: keep-alive, Upgrade`); comparing
            // the whole value would match neither branch and silently
            // keep the default.
            for token in value.split(',') {
                let token = token.trim();
                if token.eq_ignore_ascii_case("close") {
                    connection_close = true;
                } else if token.eq_ignore_ascii_case("keep-alive") {
                    connection_close = false;
                }
            }
        }
    }
    Ok(RequestHead {
        method: method.to_string(),
        target: target.to_string(),
        content_length: content_length.unwrap_or(0),
        connection_close,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn read_all(input: &[u8], max_body: usize) -> Result<(RequestHead, Vec<u8>), ReadError> {
        let mut carry = Vec::new();
        read_request(&mut &input[..], &mut carry, max_body)
    }

    #[test]
    fn parses_get_without_body() {
        let (h, body) =
            read_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n", 1024).unwrap();
        assert_eq!(h.method, "GET");
        assert_eq!(h.target, "/healthz");
        assert_eq!(h.content_length, 0);
        assert!(!h.connection_close);
        assert!(body.is_empty());
    }

    #[test]
    fn parses_post_with_body_and_pipelined_carry() {
        let input = b"POST /v1/infer HTTP/1.1\r\nContent-Length: 4\r\n\r\nabcdNEXT";
        let mut carry = Vec::new();
        let (h, body) =
            read_request(&mut &input[..], &mut carry, 1024).unwrap();
        assert_eq!(h.content_length, 4);
        assert_eq!(body, b"abcd");
        assert_eq!(carry, b"NEXT", "pipelined bytes preserved");
    }

    #[test]
    fn connection_close_variants() {
        let (h, _) = read_all(
            b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n",
            1024,
        )
        .unwrap();
        assert!(h.connection_close);
        let (h, _) = read_all(b"GET / HTTP/1.0\r\n\r\n", 1024).unwrap();
        assert!(h.connection_close, "HTTP/1.0 defaults to close");
    }

    #[test]
    fn connection_token_lists_match_per_token() {
        // `close` buried in a token list must still close.
        let (h, _) = read_all(
            b"GET / HTTP/1.1\r\nConnection: Upgrade, close\r\n\r\n",
            1024,
        )
        .unwrap();
        assert!(h.connection_close, "close token in a list");
        // `keep-alive` in a list overrides the HTTP/1.0 close default.
        let (h, _) = read_all(
            b"GET / HTTP/1.0\r\nConnection: keep-alive, Upgrade\r\n\r\n",
            1024,
        )
        .unwrap();
        assert!(!h.connection_close, "keep-alive token in a list");
        // Case-insensitive, arbitrary whitespace around tokens.
        let (h, _) = read_all(
            b"GET / HTTP/1.1\r\nConnection:  Keep-Alive ,  CLOSE \r\n\r\n",
            1024,
        )
        .unwrap();
        assert!(h.connection_close, "CLOSE recognized case-insensitively");
        // Unrelated tokens leave the version default untouched.
        let (h, _) = read_all(
            b"GET / HTTP/1.1\r\nConnection: Upgrade\r\n\r\n",
            1024,
        )
        .unwrap();
        assert!(!h.connection_close);
    }

    #[test]
    fn whitespace_and_control_bytes_in_header_names_are_rejected() {
        // The smuggling vector: `Content-Length\t:` must be malformed,
        // not an unknown header that silently drops the body length.
        for input in [
            &b"POST /x HTTP/1.1\r\nContent-Length\t: 4\r\n\r\nabcd"[..],
            b"POST /x HTTP/1.1\r\nContent-Length : 4\r\n\r\nabcd",
            b"POST /x HTTP/1.1\r\nContent-Length\r: 4\r\n\r\nabcd",
            b"POST /x HTTP/1.1\r\nX\x0bY: v\r\n\r\n",
            b"POST /x HTTP/1.1\r\nX\x01Y: v\r\n\r\n",
            b"POST /x HTTP/1.1\r\nX\x7fY: v\r\n\r\n",
        ] {
            match read_all(input, 1024) {
                Err(ReadError::BadRequest(m)) => {
                    assert!(m.contains("header name"), "{m:?} for {input:?}")
                }
                other => {
                    panic!("expected BadRequest for {input:?}, got {other:?}")
                }
            }
        }
    }

    #[test]
    fn rejects_malformed_heads() {
        for (input, want) in [
            (&b"garbage\r\n\r\n"[..], "malformed"),
            (b"GET /x HTTP/2.0\r\n\r\n", "version"),
            (b"GET  /x HTTP/1.1\r\n\r\n", "malformed"),
            (b"get /x HTTP/1.1\r\n\r\n", "method"),
            (b"GET x HTTP/1.1\r\n\r\n", "target"),
            (b"GET /x HTTP/1.1\r\nbroken header\r\n\r\n", "header"),
            (b"GET /x HTTP/1.1\r\nContent-Length: two\r\n\r\n", "Content-Length"),
            (
                b"GET /x HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\n",
                "duplicate",
            ),
            (
                b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
                "Transfer-Encoding",
            ),
        ] {
            match read_all(input, 1024) {
                Err(ReadError::BadRequest(m)) => {
                    assert!(m.contains(want), "{m:?} for {input:?}")
                }
                other => panic!("expected BadRequest for {input:?}, got {other:?}"),
            }
        }
    }

    #[test]
    fn enforces_head_and_body_caps() {
        // One absurd header blows the byte cap.
        let mut big = b"GET / HTTP/1.1\r\nX-Big: ".to_vec();
        big.extend(std::iter::repeat_n(b'a', MAX_HEAD_BYTES + 1));
        big.extend_from_slice(b"\r\n\r\n");
        assert_eq!(read_all(&big, 1024), Err(ReadError::HeadTooLarge));
        // Too many small headers blows the count cap.
        let mut many = b"GET / HTTP/1.1\r\n".to_vec();
        for i in 0..MAX_HEADERS + 1 {
            many.extend_from_slice(format!("X-{i}: v\r\n").as_bytes());
        }
        many.extend_from_slice(b"\r\n");
        assert_eq!(read_all(&many, 1024), Err(ReadError::HeadTooLarge));
        // Declared body over the cap is rejected before any body read.
        assert_eq!(
            read_all(b"POST / HTTP/1.1\r\nContent-Length: 2000\r\n\r\n", 1024),
            Err(ReadError::BodyTooLarge)
        );
        // Content-Length that overflows u64 is malformed, not a panic.
        assert!(matches!(
            read_all(
                b"POST / HTTP/1.1\r\nContent-Length: 99999999999999999999999\r\n\r\n",
                1024
            ),
            Err(ReadError::BadRequest(_))
        ));
    }

    #[test]
    fn truncation_and_idle_close() {
        assert_eq!(read_all(b"", 1024), Err(ReadError::ClosedIdle));
        assert_eq!(
            read_all(b"GET / HTT", 1024),
            Err(ReadError::Truncated),
            "EOF mid-head"
        );
        assert_eq!(
            read_all(b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc", 1024),
            Err(ReadError::Truncated),
            "EOF mid-body"
        );
    }

    #[test]
    fn non_utf8_head_is_bad_request() {
        let input = b"GET /\xff\xfe HTTP/1.1\r\n\r\n";
        assert!(matches!(
            read_all(input, 1024),
            Err(ReadError::BadRequest(_))
        ));
    }
}
