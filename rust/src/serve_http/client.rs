//! Minimal loopback HTTP/1.1 client + load generator.
//!
//! Test and bench harness for the server in this module: a keep-alive
//! client just capable enough to drive `rram-accel serve-http`
//! (request line + headers + Content-Length bodies, no chunking, no
//! TLS), and a multi-threaded closed-loop load generator that reports
//! sustained RPS with p50/p99 tail latency. Not a general HTTP client.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use crate::util::stats::Summary;
use crate::util::threadpool;

/// One parsed response.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn body_text(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

/// Keep-alive HTTP/1.1 connection to one server.
pub struct HttpClient {
    stream: TcpStream,
    carry: Vec<u8>,
}

impl HttpClient {
    pub fn connect(addr: SocketAddr) -> std::io::Result<HttpClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(10)))?;
        Ok(HttpClient { stream, carry: Vec::new() })
    }

    pub fn get(&mut self, target: &str) -> std::io::Result<HttpResponse> {
        self.request("GET", target, b"")
    }

    pub fn post(
        &mut self,
        target: &str,
        body: &[u8],
    ) -> std::io::Result<HttpResponse> {
        self.request("POST", target, body)
    }

    pub fn request(
        &mut self,
        method: &str,
        target: &str,
        body: &[u8],
    ) -> std::io::Result<HttpResponse> {
        let head = format!(
            "{method} {target} HTTP/1.1\r\nHost: localhost\r\n\
             Content-Length: {}\r\n\r\n",
            body.len()
        );
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(body)?;
        self.read_response()
    }

    /// Write raw bytes and read one response — for malformed-input
    /// tests that must not go through the well-formed request builder.
    pub fn raw(&mut self, bytes: &[u8]) -> std::io::Result<HttpResponse> {
        self.stream.write_all(bytes)?;
        self.read_response()
    }

    fn read_response(&mut self) -> std::io::Result<HttpResponse> {
        let mut buf = std::mem::take(&mut self.carry);
        let mut chunk = [0u8; 4096];
        let head_end = loop {
            if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
                break p;
            }
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "server closed before response head",
                ));
            }
            buf.extend_from_slice(&chunk[..n]);
        };
        let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
        let mut lines = head.split("\r\n");
        let status_line = lines.next().unwrap_or("");
        let status: u16 = status_line
            .split(' ')
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad status line: {status_line:?}"),
                )
            })?;
        let mut content_length = 0usize;
        for line in lines {
            if let Some((name, value)) = line.split_once(':') {
                if name.eq_ignore_ascii_case("content-length") {
                    content_length = value.trim().parse().unwrap_or(0);
                }
            }
        }
        let body_start = head_end + 4;
        let mut body = buf.split_off(body_start);
        while body.len() < content_length {
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "server closed mid-body",
                ));
            }
            body.extend_from_slice(&chunk[..n]);
        }
        self.carry = body.split_off(content_length);
        Ok(HttpResponse { status, body })
    }
}

/// Closed-loop load generator configuration.
#[derive(Debug, Clone)]
pub struct LoadConfig {
    pub addr: SocketAddr,
    /// Concurrent keep-alive client connections.
    pub clients: usize,
    /// Wall-clock run length.
    pub duration: Duration,
    /// Request body POSTed to `/v1/infer` by every client.
    pub body: Vec<u8>,
}

/// Aggregate result of one load run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub requests: u64,
    /// Responses with a non-200 status (any kind).
    pub non_200: u64,
    pub elapsed: Duration,
    /// Per-request wall latencies in microseconds, merged across
    /// clients.
    pub latencies_us: Summary,
}

impl LoadReport {
    pub fn rps(&self) -> f64 {
        self.requests as f64 / self.elapsed.as_secs_f64().max(1e-9)
    }

    /// One human-readable summary line (bench + CI smoke output).
    pub fn line(&self) -> String {
        format!(
            "{} requests in {:.2}s -> {:.0} req/s sustained, latency \
             p50 {:.0} us  p99 {:.0} us  max {:.0} us ({} non-200)",
            self.requests,
            self.elapsed.as_secs_f64(),
            self.rps(),
            self.latencies_us.percentile(50.0),
            self.latencies_us.percentile(99.0),
            self.latencies_us.max(),
            self.non_200,
        )
    }
}

/// Run a closed-loop load test: `clients` threads each hammer
/// `POST /v1/infer` over a keep-alive connection until the deadline,
/// then the per-thread tallies are merged. Connection failures stop
/// the failing thread (its partial tally still counts, and the
/// failure shows up as a request shortfall, not a hang).
pub fn run_load(cfg: &LoadConfig) -> LoadReport {
    let start = Instant::now();
    let deadline = start + cfg.duration;
    let mut joins = Vec::with_capacity(cfg.clients);
    for c in 0..cfg.clients {
        let addr = cfg.addr;
        let body = cfg.body.clone();
        joins.push(threadpool::spawn_named(
            &format!("http-load-{c}"),
            move || {
                let mut lat = Summary::new();
                let mut requests = 0u64;
                let mut non_200 = 0u64;
                let mut client = match HttpClient::connect(addr) {
                    Ok(c) => c,
                    Err(_) => return (lat, requests, non_200),
                };
                while Instant::now() < deadline {
                    let t0 = Instant::now();
                    match client.post("/v1/infer", &body) {
                        Ok(resp) => {
                            requests += 1;
                            if resp.status != 200 {
                                non_200 += 1;
                            }
                            lat.push(t0.elapsed().as_micros() as f64);
                        }
                        Err(_) => break,
                    }
                }
                (lat, requests, non_200)
            },
        ));
    }
    let mut latencies_us = Summary::new();
    let mut requests = 0u64;
    let mut non_200 = 0u64;
    for j in joins {
        if let Ok((lat, r, n)) = j.join() {
            latencies_us.merge(&lat);
            requests += r;
            non_200 += n;
        }
    }
    LoadReport { requests, non_200, elapsed: start.elapsed(), latencies_us }
}
