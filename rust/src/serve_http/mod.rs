//! Zero-dependency HTTP/1.1 front door over the serving coordinator.
//!
//! The pool (admission → dispatcher → N workers, `crate::coordinator`)
//! only takes in-process `submit` calls; this module is the network
//! edge that the ROADMAP's production north star needs, built on
//! `std::net` alone to keep the crate's zero-dependency policy. Every
//! byte read from a socket goes through the bounded reader
//! ([`request`]) and the lazy field scanner ([`scan`]) — both fuzzed in
//! `tests/serve_http.rs` — before anything allocates proportionally to
//! peer input.
//!
//! # Wire format
//!
//! HTTP/1.1 over TCP. Requests must carry `Content-Length` bodies
//! (`Transfer-Encoding` is rejected with 400); responses always carry
//! `Content-Length` and honor keep-alive (`Connection: close` or an
//! HTTP/1.0 request line opt out). The request head is capped at
//! [`request::MAX_HEAD_BYTES`] / [`request::MAX_HEADERS`], the body at
//! [`HttpConfig::max_body_bytes`], and socket reads at
//! [`HttpConfig::read_timeout`].
//!
//! ## `POST /v1/infer`
//!
//! Body: a JSON object scanned lazily — only these keys are read, the
//! rest are structurally skipped without building a tree:
//!
//! ```json
//! {"image": [f32; input_len], "deadline_us": u64?, "batch_hint": u64?}
//! ```
//!
//! `image` is required and must be exactly the pool's input length.
//! `deadline_us` (optional) becomes the request's completion deadline;
//! absent, [`HttpConfig::default_deadline`] applies. `batch_hint`
//! (optional, 1..=4096) is advisory — the pool batches by its own
//! `max_wait`/deadline policy — and is validated and echoed back.
//!
//! 200 response body:
//!
//! ```json
//! {"logits": [f32; output_len], "queue_us": u64, "batch_fill": usize}
//! ```
//!
//! ## `GET /healthz`
//!
//! 200 with `{"status": "ok", "workers": N}` while the pool is up.
//!
//! ## `GET /metrics`
//!
//! Pool-wide metrics built from `Metrics::merge` + `worker_stats`:
//! Prometheus-style text by default
//! ([`crate::report::metrics_export_text`]: `rram_*` counters, the
//! latency summary with p50/p99 quantile labels, per-worker
//! `{worker="i"}` series), plus the front door's own
//! `rram_http_{connections,requests,bad_requests,handler_panics}_total`
//! counters. `GET /metrics?format=json` returns the same view as JSON
//! ([`crate::report::metrics_export_json`] with an added `"http"`
//! object).
//!
//! # Observability
//!
//! With the pool started on a [`crate::obs::Registry`]
//! (`CoordinatorConfig::trace` — `rram-accel serve-http` always wires
//! one), every `POST /v1/infer` request is served under its own trace:
//! the front door opens the `http.infer` root span (child `http.parse`
//! around body scanning), assigns the trace ID, and hands the context
//! to [`Coordinator::submit_traced`] so the pool's `pool.admit` →
//! `pool.queue` → `pool.exec` spans (and `pool.retry`/`pool.requeue`
//! failure instants) nest under it; the reply echoes the ID in
//! `Reply::trace_id`. Exports:
//!
//! * **`GET /debug/trace?last=N`** — the last `N` spans (default 256)
//!   of the merged per-thread rings as Chrome trace-event JSON
//!   (`{"traceEvents": [...]}`, loadable in Perfetto /
//!   `chrome://tracing`). Without a registry, an empty document.
//! * **`/metrics` histogram series** — the bounded-memory pool
//!   telemetry: `rram_latency_us_hist_bucket{le="..."}` (+ `_sum`,
//!   `_count`), `rram_batch_fill_bucket{le="..."}`, plus
//!   `rram_quarantine_events_total` and the store/DSE cache totals
//!   `rram_store_{hits,misses}_total` /
//!   `rram_dse_cache_{hits,misses}_total`.
//!
//! Tracing off (no registry) costs the serving path nothing beyond one
//! `Option` check per request — pinned by `benches/http_load.rs`.
//!
//! # Status-code mapping to coordinator outcomes
//!
//! | condition                                      | status |
//! |------------------------------------------------|--------|
//! | inference completed                             | 200 |
//! | malformed head/body, bad field, wrong image len | 400 |
//! | unknown path                                    | 404 |
//! | known path, wrong method                        | 405 |
//! | read timeout mid-request                        | 408 |
//! | body over [`HttpConfig::max_body_bytes`]        | 413 |
//! | overload rejection ([`ERR_OVERLOAD_PREFIX`])    | 429 |
//! | head over the size/count caps                   | 431 |
//! | handler panic (counted, never kills the server) | 500 |
//! | backend failure after retries/requeues          | 502 |
//! | connection cap reached, or coordinator gone     | 503 |
//! | deadline exceeded ([`ERR_DEADLINE_PREFIX`])     | 504 |
//!
//! Deadline/overload classification matches on the stable
//! [`ERR_DEADLINE_PREFIX`]/[`ERR_OVERLOAD_PREFIX`] prefixes of
//! `Reply::result` errors rather than ad-hoc substrings.

pub mod client;
pub mod request;
pub mod scan;

use std::io::Write;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::{
    Coordinator, InferBackend, ERR_DEADLINE_PREFIX, ERR_OVERLOAD_PREFIX,
};
use crate::obs;
use crate::report;
use crate::util::json::{obj, Json};
use crate::util::threadpool;

use request::{read_request, ReadError, RequestHead};

/// Largest accepted `batch_hint` value.
pub const MAX_BATCH_HINT: u64 = 4096;

/// Front-door configuration.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Bind address; port 0 picks an ephemeral port (see
    /// [`HttpServer::addr`]).
    pub addr: String,
    /// Hard cap on request bodies (413 beyond it).
    pub max_body_bytes: usize,
    /// Socket read timeout; expiry mid-request answers 408.
    pub read_timeout: Duration,
    /// Concurrent connection cap; further accepts answer 503.
    pub max_connections: usize,
    /// Expected `image` element count (the pool backend's input_len).
    pub input_len: usize,
    /// Deadline applied to requests that do not carry `deadline_us`.
    pub default_deadline: Option<Duration>,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig {
            addr: "127.0.0.1:0".to_string(),
            max_body_bytes: 4 * 1024 * 1024,
            read_timeout: Duration::from_secs(5),
            max_connections: 256,
            input_len: 0,
            default_deadline: None,
        }
    }
}

/// Point-in-time front-door counters (also exported on `/metrics`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HttpStats {
    pub connections: u64,
    pub requests: u64,
    /// Requests answered with a 4xx status (malformed input).
    pub bad_requests: u64,
    /// Handler panics caught and answered with 500.
    pub handler_panics: u64,
}

struct Shared {
    coord: Coordinator,
    cfg: HttpConfig,
    /// Tracing registry (taken from the coordinator) plus the one ring
    /// all connection-handler threads share — handlers are ephemeral,
    /// so per-thread rings would grow without bound; one `http` ring
    /// keeps the buffer set fixed.
    trace: Option<(Arc<obs::Registry>, Arc<obs::SpanBuf>)>,
    stop: AtomicBool,
    open_connections: AtomicU64,
    connections_total: AtomicU64,
    requests_total: AtomicU64,
    bad_requests_total: AtomicU64,
    handler_panics_total: AtomicU64,
}

/// Handle to a running front door. Owns the accept thread; dropping
/// the handle (or calling [`HttpServer::shutdown`]) stops accepting,
/// and the coordinator shuts down once the last connection handler
/// releases it.
pub struct HttpServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept_join: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `cfg.addr` and start serving `coord`. The pool keeps its
    /// own policy (deadlines, retries, quarantine); the front door
    /// only maps requests onto it.
    pub fn start(coord: Coordinator, cfg: HttpConfig) -> std::io::Result<HttpServer> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let trace = coord.trace_registry().cloned().map(|t| {
            let buf = t.buffer("http");
            (t, buf)
        });
        let shared = Arc::new(Shared {
            coord,
            cfg,
            trace,
            stop: AtomicBool::new(false),
            open_connections: AtomicU64::new(0),
            connections_total: AtomicU64::new(0),
            requests_total: AtomicU64::new(0),
            bad_requests_total: AtomicU64::new(0),
            handler_panics_total: AtomicU64::new(0),
        });
        let accept_shared = shared.clone();
        let accept_join = threadpool::spawn_named("http-accept", move || {
            accept_loop(&listener, &accept_shared);
        });
        Ok(HttpServer { addr, shared, accept_join: Some(accept_join) })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn http_stats(&self) -> HttpStats {
        let r = Ordering::Relaxed;
        HttpStats {
            connections: self.shared.connections_total.load(r),
            requests: self.shared.requests_total.load(r),
            bad_requests: self.shared.bad_requests_total.load(r),
            handler_panics: self.shared.handler_panics_total.load(r),
        }
    }

    /// Stop accepting and join the accept thread. Open connections
    /// finish their current request and drain within the read timeout.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.shared.stop.store(true, Ordering::Release);
        // Unblock the blocking accept() with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.accept_join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(listener: &TcpListener, shared: &Arc<Shared>) {
    for conn in listener.incoming() {
        if shared.stop.load(Ordering::Acquire) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        shared.connections_total.fetch_add(1, Ordering::Relaxed);
        let open = shared.open_connections.load(Ordering::Relaxed);
        if open >= shared.cfg.max_connections as u64 {
            // Over the cap: answer 503 inline and close — never block
            // the accept loop on a slow peer.
            let resp =
                error_response(503, "connection limit reached, retry later");
            let _ = stream.set_write_timeout(Some(Duration::from_secs(1)));
            let _ = write_response(&stream, &resp, true);
            continue;
        }
        shared.open_connections.fetch_add(1, Ordering::Relaxed);
        let conn_shared = shared.clone();
        drop(threadpool::spawn_named("http-conn", move || {
            handle_connection(&stream, &conn_shared);
            conn_shared.open_connections.fetch_sub(1, Ordering::Relaxed);
        }));
    }
}

fn handle_connection(stream: &TcpStream, shared: &Arc<Shared>) {
    if stream.set_read_timeout(Some(shared.cfg.read_timeout)).is_err()
        || stream.set_nodelay(true).is_err()
    {
        return;
    }
    let mut carry = Vec::new();
    let mut reader = stream;
    loop {
        if shared.stop.load(Ordering::Acquire) {
            return;
        }
        match read_request(&mut reader, &mut carry, shared.cfg.max_body_bytes) {
            Ok((head, body)) => {
                shared.requests_total.fetch_add(1, Ordering::Relaxed);
                // A panic anywhere in routing/scan/submit answers 500
                // on this connection and never takes down the server.
                let resp = catch_unwind(AssertUnwindSafe(|| {
                    route(shared, &head, &body)
                }))
                .unwrap_or_else(|_| {
                    shared.handler_panics_total.fetch_add(1, Ordering::Relaxed);
                    error_response(500, "internal error")
                });
                if (400..500).contains(&resp.status) {
                    shared.bad_requests_total.fetch_add(1, Ordering::Relaxed);
                }
                let wrote =
                    write_response(stream, &resp, head.connection_close);
                if head.connection_close || wrote.is_err() {
                    return;
                }
            }
            Err(ReadError::ClosedIdle) => return,
            Err(e) => {
                shared.bad_requests_total.fetch_add(1, Ordering::Relaxed);
                let status = match e {
                    ReadError::Timeout => 408,
                    ReadError::HeadTooLarge => 431,
                    ReadError::BodyTooLarge => 413,
                    _ => 400,
                };
                // The stream is no longer in sync with the peer; the
                // error response is best-effort and the connection
                // always closes.
                let _ = write_response(
                    stream,
                    &error_response(status, e.detail()),
                    true,
                );
                return;
            }
        }
    }
}

/// One response ready to serialize.
struct Response {
    status: u16,
    content_type: &'static str,
    body: String,
}

fn json_response(status: u16, body: Json) -> Response {
    Response {
        status,
        content_type: "application/json",
        body: body.to_string_compact(),
    }
}

fn error_response(status: u16, detail: &str) -> Response {
    json_response(status, obj(vec![("error", detail.into())]))
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

fn write_response(
    mut stream: &TcpStream,
    resp: &Response,
    close: bool,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n\
         Connection: {}\r\n\r\n",
        resp.status,
        reason(resp.status),
        resp.content_type,
        resp.body.len(),
        if close { "close" } else { "keep-alive" },
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(resp.body.as_bytes())?;
    stream.flush()
}

fn route(shared: &Shared, head: &RequestHead, body: &[u8]) -> Response {
    let (path, query) = match head.target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (head.target.as_str(), ""),
    };
    match (head.method.as_str(), path) {
        ("POST", "/v1/infer") => infer(shared, body),
        ("GET", "/healthz") => json_response(
            200,
            obj(vec![
                ("status", "ok".into()),
                ("workers", shared.coord.n_workers().into()),
            ]),
        ),
        ("GET", "/metrics") => metrics(shared, query),
        ("GET", "/debug/trace") => debug_trace(shared, query),
        (_, "/v1/infer") | (_, "/healthz") | (_, "/metrics")
        | (_, "/debug/trace") => {
            error_response(405, "method not allowed on this path")
        }
        _ => error_response(404, "unknown path"),
    }
}

/// `GET /debug/trace?last=N` — the last `N` spans (default 256) of the
/// registry's merged rings, as Chrome trace-event JSON. Served even
/// without a registry (empty document) so probes never 404 based on
/// config.
fn debug_trace(shared: &Shared, query: &str) -> Response {
    let Some((t, _)) = &shared.trace else {
        return json_response(200, obs::chrome_trace_json(&[]));
    };
    let mut last = 256usize;
    for part in query.split('&') {
        if let Some(v) = part.strip_prefix("last=") {
            // untrusted input: a non-numeric or overflowing value keeps
            // the default rather than erroring a diagnostics endpoint
            if let Ok(n) = v.parse::<usize>() {
                last = n;
            }
        }
    }
    json_response(200, obs::chrome_trace_json(&t.snapshot_last(last)))
}

fn metrics(shared: &Shared, query: &str) -> Response {
    let snapshot = shared.coord.merged_metrics().snapshot();
    let workers = shared.coord.worker_stats();
    let r = Ordering::Relaxed;
    if query == "format=json" {
        let mut j = report::metrics_export_json(&snapshot, &workers);
        if let Json::Obj(m) = &mut j {
            m.insert(
                "http".to_string(),
                obj(vec![
                    (
                        "connections",
                        (shared.connections_total.load(r) as f64).into(),
                    ),
                    ("requests", (shared.requests_total.load(r) as f64).into()),
                    (
                        "bad_requests",
                        (shared.bad_requests_total.load(r) as f64).into(),
                    ),
                    (
                        "handler_panics",
                        (shared.handler_panics_total.load(r) as f64).into(),
                    ),
                ]),
            );
        }
        return json_response(200, j);
    }
    let mut text = report::metrics_export_text(&snapshot, &workers);
    for (name, v) in [
        ("rram_http_connections_total", shared.connections_total.load(r)),
        ("rram_http_requests_total", shared.requests_total.load(r)),
        ("rram_http_bad_requests_total", shared.bad_requests_total.load(r)),
        ("rram_http_handler_panics_total", shared.handler_panics_total.load(r)),
    ] {
        text.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
    }
    Response {
        status: 200,
        content_type: "text/plain; version=0.0.4",
        body: text,
    }
}

fn infer(shared: &Shared, body: &[u8]) -> Response {
    // Trace boundary: this request's trace ID is minted here, the
    // `http.infer` root span wraps the whole handler, and the context
    // rides into the pool via `submit_traced` so the dispatcher/worker
    // spans nest under it.
    let (ctx, root) = match &shared.trace {
        Some((t, _)) => {
            let id = t.new_trace();
            let root = t.begin(id, 0, "http.infer");
            (obs::TraceCtx { trace_id: id, parent: root.span_id }, root)
        }
        None => (obs::TraceCtx::default(), obs::ActiveSpan::INERT),
    };
    let resp = infer_inner(shared, body, ctx);
    if let Some((t, buf)) = &shared.trace {
        t.end(
            buf,
            root,
            &[
                ("status", resp.status as u64),
                ("body_bytes", body.len() as u64),
            ],
        );
    }
    resp
}

fn infer_inner(shared: &Shared, body: &[u8], ctx: obs::TraceCtx) -> Response {
    let parse = match &shared.trace {
        Some((t, _)) => t.begin(ctx.trace_id, ctx.parent, "http.parse"),
        None => obs::ActiveSpan::INERT,
    };
    let scanned = scan::scan_infer(body);
    if let Some((t, buf)) = &shared.trace {
        // logical counters only: bytes offered to the scanner, outcome
        t.end(
            buf,
            parse,
            &[("bytes", body.len() as u64), ("ok", scanned.is_ok() as u64)],
        );
    }
    let fields = match scanned {
        Ok(f) => f,
        Err(e) => return error_response(400, &e.to_string()),
    };
    if fields.image.len() != shared.cfg.input_len {
        return error_response(
            400,
            &format!(
                "\"image\" must have exactly {} elements, got {}",
                shared.cfg.input_len,
                fields.image.len()
            ),
        );
    }
    if let Some(h) = fields.batch_hint {
        if h == 0 || h > MAX_BATCH_HINT {
            return error_response(
                400,
                &format!("\"batch_hint\" must be in 1..={MAX_BATCH_HINT}"),
            );
        }
    }
    let deadline = fields
        .deadline_us
        .map(Duration::from_micros)
        .or(shared.cfg.default_deadline);
    let rx = shared.coord.submit_traced(fields.image, deadline, ctx);
    let reply = match rx.recv() {
        Ok(r) => r,
        Err(_) => return error_response(503, "coordinator unavailable"),
    };
    match reply.result {
        Ok(logits) => {
            let mut pairs = vec![
                (
                    "logits",
                    Json::Arr(
                        logits.iter().map(|v| Json::Num(f64::from(*v))).collect(),
                    ),
                ),
                ("queue_us", (reply.queue_us as f64).into()),
                ("batch_fill", reply.batch_fill.into()),
            ];
            if let Some(h) = fields.batch_hint {
                pairs.push(("batch_hint", (h as f64).into()));
            }
            json_response(200, obj(pairs))
        }
        Err(e) if e.starts_with(ERR_DEADLINE_PREFIX) => error_response(504, &e),
        Err(e) if e.starts_with(ERR_OVERLOAD_PREFIX) => error_response(429, &e),
        Err(e) => error_response(502, &e),
    }
}

/// Deterministic std-only backend for the front door in builds without
/// the PJRT runtime (the default image): logit `k` of a request is
/// `sum(image) + k`, so tests and the CI smoke can assert exact logits.
/// `delay` models backend latency; `fail` makes every batch error (for
/// the 502 path).
pub struct MockInferBackend {
    pub input_len: usize,
    pub output_len: usize,
    pub batch: usize,
    pub delay: Duration,
    pub fail: bool,
}

impl InferBackend for MockInferBackend {
    fn input_len(&self) -> usize {
        self.input_len
    }

    fn output_len(&self) -> usize {
        self.output_len
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
        if self.fail {
            return Err("mock backend configured to fail".to_string());
        }
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let mut out = Vec::with_capacity(self.batch * self.output_len);
        for slot in 0..self.batch {
            let sum: f32 = batch[slot * self.input_len..(slot + 1) * self.input_len]
                .iter()
                .sum();
            for k in 0..self.output_len {
                out.push(sum + k as f32);
            }
        }
        Ok(out)
    }
}
