//! Lazy JSON scanner for inference request bodies.
//!
//! Extracts exactly the three fields `POST /v1/infer` consumes —
//! `image` (flat array of finite numbers), `deadline_us` and
//! `batch_hint` (non-negative integers) — in one pass over the body
//! bytes, without building a [`crate::util::json::Json`] tree. `image`
//! numbers are parsed straight into the `Vec<f32>` the coordinator
//! takes, and every *other* key's value is skipped structurally
//! (strings escape-aware, containers by depth counting, capped at
//! [`MAX_SKIP_DEPTH`] like the full parser), so a megabyte of metadata
//! a client tacks onto a request costs one scan and zero allocations.
//! The mik-sdk ADR-002 exemplar measured ~33x for this partial
//! extraction over full-tree parsing; `benches/http_load.rs` keeps the
//! end-to-end number honest here.
//!
//! The scanner is as strict as the tree parser about what it *does*
//! read: bodies must be UTF-8, the top level must be an object, tracked
//! keys must not repeat, `image` is required and must be a flat array
//! of finite numbers (`1e999` overflows to infinity and is rejected),
//! and the integer fields reject signs, fractions, exponents and
//! anything ≥ 2^64.

use std::fmt;

/// Depth cap for skipped (untracked) values — same bound as
/// [`crate::util::json::MAX_PARSE_DEPTH`] so a depth bomb in an ignored
/// field is rejected, not recursed into (the skipper is iterative, but
/// an unbounded depth would still let absurd inputs through).
pub const MAX_SKIP_DEPTH: usize = crate::util::json::MAX_PARSE_DEPTH;

/// Fields of one inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct InferFields {
    pub image: Vec<f32>,
    /// Per-request completion deadline in microseconds.
    pub deadline_us: Option<u64>,
    /// Client batching hint (advisory; validated and echoed).
    pub batch_hint: Option<u64>,
}

/// Scan failure: message + byte offset, mirroring
/// [`crate::util::json::JsonError`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ScanError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for ScanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "bad request body at byte {}: {}", self.pos, self.msg)
    }
}

/// Scan an inference request body. Returns the extracted fields or the
/// first error encountered.
pub fn scan_infer(body: &[u8]) -> Result<InferFields, ScanError> {
    let text = std::str::from_utf8(body).map_err(|e| ScanError {
        msg: "body is not UTF-8".to_string(),
        pos: e.valid_up_to(),
    })?;
    let mut s = Scanner { b: text.as_bytes(), i: 0 };
    let mut image: Option<Vec<f32>> = None;
    let mut deadline_us: Option<u64> = None;
    let mut batch_hint: Option<u64> = None;

    s.skip_ws();
    s.eat(b'{', "request body must be a JSON object")?;
    s.skip_ws();
    if s.peek() != Some(b'}') {
        loop {
            s.skip_ws();
            let key = s.string()?;
            s.skip_ws();
            s.eat(b':', "expected ':' after key")?;
            s.skip_ws();
            match key.as_str() {
                "image" => {
                    if image.is_some() {
                        return Err(s.err("duplicate \"image\""));
                    }
                    image = Some(s.number_array()?);
                }
                "deadline_us" => {
                    if deadline_us.is_some() {
                        return Err(s.err("duplicate \"deadline_us\""));
                    }
                    deadline_us = Some(s.unsigned_int("deadline_us")?);
                }
                "batch_hint" => {
                    if batch_hint.is_some() {
                        return Err(s.err("duplicate \"batch_hint\""));
                    }
                    batch_hint = Some(s.unsigned_int("batch_hint")?);
                }
                _ => s.skip_value()?,
            }
            s.skip_ws();
            match s.next() {
                Some(b',') => continue,
                Some(b'}') => break,
                _ => return Err(s.err("expected ',' or '}'")),
            }
        }
    } else {
        s.i += 1;
    }
    s.skip_ws();
    if s.i != s.b.len() {
        return Err(s.err("trailing data after request object"));
    }
    let image = image.ok_or_else(|| s.err("missing required field \"image\""))?;
    Ok(InferFields { image, deadline_us, batch_hint })
}

struct Scanner<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Scanner<'a> {
    fn err(&self, msg: &str) -> ScanError {
        ScanError { msg: msg.to_string(), pos: self.i }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn next(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.i += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8, msg: &str) -> Result<(), ScanError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(msg))
        }
    }

    /// A JSON string, unescaped. Used for object keys; skipped string
    /// *values* go through `skip_string` which allocates nothing.
    fn string(&mut self) -> Result<String, ScanError> {
        self.eat(b'"', "expected string key")?;
        let start = self.i;
        let mut has_escape = false;
        loop {
            match self.next() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => break,
                Some(b'\\') => {
                    has_escape = true;
                    if self.next().is_none() {
                        return Err(self.err("unterminated escape"));
                    }
                }
                Some(_) => {}
            }
        }
        let raw = &self.b[start..self.i - 1];
        // Keys we care about contain no escapes; an escaped key simply
        // won't match "image"/"deadline_us"/"batch_hint" — decode it
        // just enough to stay correct for the untracked-key path.
        let key = std::str::from_utf8(raw).expect("validated UTF-8");
        if has_escape {
            Ok(key.replace("\\\"", "\"").replace("\\\\", "\\"))
        } else {
            Ok(key.to_string())
        }
    }

    fn skip_string(&mut self) -> Result<(), ScanError> {
        self.eat(b'"', "expected string")?;
        loop {
            match self.next() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(()),
                Some(b'\\') => {
                    if self.next().is_none() {
                        return Err(self.err("unterminated escape"));
                    }
                }
                Some(_) => {}
            }
        }
    }

    /// One number token as the f64 it parses to; rejects non-finite
    /// results (e.g. `1e999` overflowing to infinity).
    fn number(&mut self) -> Result<f64, ScanError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || c == b'.') {
            self.i += 1;
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).expect("ascii");
        let v: f64 = txt.parse().map_err(|_| self.err("malformed number"))?;
        if !v.is_finite() {
            return Err(self.err("number is not finite"));
        }
        Ok(v)
    }

    /// `image`: a flat array of numbers, parsed directly into the f32
    /// buffer the coordinator consumes.
    fn number_array(&mut self) -> Result<Vec<f32>, ScanError> {
        self.eat(b'[', "\"image\" must be an array of numbers")?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(out);
        }
        loop {
            self.skip_ws();
            match self.peek() {
                Some(c) if c == b'-' || c.is_ascii_digit() => {
                    out.push(self.number()? as f32);
                }
                _ => {
                    return Err(
                        self.err("\"image\" must contain only flat numbers")
                    )
                }
            }
            self.skip_ws();
            match self.next() {
                Some(b',') => continue,
                Some(b']') => return Ok(out),
                _ => return Err(self.err("expected ',' or ']' in \"image\"")),
            }
        }
    }

    /// Strict non-negative integer for `deadline_us` / `batch_hint`:
    /// digits only (no sign, fraction or exponent), checked u64
    /// accumulation so 2^64 overflow is an error, not a wrap.
    fn unsigned_int(&mut self, field: &str) -> Result<u64, ScanError> {
        let mut v: u64 = 0;
        let mut any = false;
        while let Some(c) = self.peek() {
            if !c.is_ascii_digit() {
                break;
            }
            any = true;
            v = v
                .checked_mul(10)
                .and_then(|v| v.checked_add(u64::from(c - b'0')))
                .ok_or_else(|| self.err(&format!("\"{field}\" out of range")))?;
            self.i += 1;
        }
        if !any || matches!(self.peek(), Some(b'.' | b'e' | b'E')) {
            return Err(self.err(&format!(
                "\"{field}\" must be a non-negative integer"
            )));
        }
        Ok(v)
    }

    /// Structurally skip one value of any type without materializing
    /// it. Containers are tracked with a depth counter (iterative — no
    /// recursion to overflow), capped at [`MAX_SKIP_DEPTH`].
    fn skip_value(&mut self) -> Result<(), ScanError> {
        let mut depth = 0usize;
        loop {
            self.skip_ws();
            match self.peek() {
                Some(b'{') | Some(b'[') => {
                    depth += 1;
                    if depth > MAX_SKIP_DEPTH {
                        return Err(self.err("nesting too deep"));
                    }
                    self.i += 1;
                }
                Some(b'}') | Some(b']') => {
                    if depth == 0 {
                        return Err(self.err("unexpected close bracket"));
                    }
                    depth -= 1;
                    self.i += 1;
                }
                Some(b'"') => self.skip_string()?,
                Some(b',') | Some(b':') if depth > 0 => self.i += 1,
                Some(c) if c == b'-' || c.is_ascii_digit() => {
                    self.number()?;
                }
                Some(b't') => self.literal("true")?,
                Some(b'f') => self.literal("false")?,
                Some(b'n') => self.literal("null")?,
                _ => return Err(self.err("unexpected character")),
            }
            if depth == 0 {
                return Ok(());
            }
        }
    }

    fn literal(&mut self, lit: &str) -> Result<(), ScanError> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(())
        } else {
            Err(self.err("unexpected character"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extracts_all_three_fields() {
        let f = scan_infer(
            br#"{"image": [1, -2.5, 3e2], "deadline_us": 5000, "batch_hint": 8}"#,
        )
        .unwrap();
        assert_eq!(f.image, vec![1.0, -2.5, 300.0]);
        assert_eq!(f.deadline_us, Some(5000));
        assert_eq!(f.batch_hint, Some(8));
    }

    #[test]
    fn skips_untracked_fields_of_any_shape() {
        let f = scan_infer(
            br#"{"meta": {"a": [1, {"b": "x\"y"}], "c": null}, "image": [4],
                 "tags": ["p", true, false, -1e3], "n": 12.5}"#,
        )
        .unwrap();
        assert_eq!(f.image, vec![4.0]);
        assert_eq!(f.deadline_us, None);
        assert_eq!(f.batch_hint, None);
    }

    #[test]
    fn missing_image_is_an_error() {
        let e = scan_infer(br#"{"deadline_us": 1}"#).unwrap_err();
        assert!(e.msg.contains("image"), "{e}");
    }

    #[test]
    fn duplicate_tracked_keys_rejected() {
        let e = scan_infer(br#"{"image": [1], "image": [2]}"#).unwrap_err();
        assert!(e.msg.contains("duplicate"), "{e}");
    }

    #[test]
    fn image_must_be_flat_finite_numbers() {
        assert!(scan_infer(br#"{"image": [[1]]}"#).is_err(), "nested");
        assert!(scan_infer(br#"{"image": ["a"]}"#).is_err(), "string");
        assert!(scan_infer(br#"{"image": 3}"#).is_err(), "scalar");
        let e = scan_infer(br#"{"image": [1e999]}"#).unwrap_err();
        assert!(e.msg.contains("finite"), "{e}");
    }

    #[test]
    fn integer_fields_are_strict() {
        assert!(scan_infer(br#"{"image": [], "deadline_us": -1}"#).is_err());
        assert!(scan_infer(br#"{"image": [], "deadline_us": 1.5}"#).is_err());
        assert!(scan_infer(br#"{"image": [], "deadline_us": 1e3}"#).is_err());
        let e = scan_infer(
            br#"{"image": [], "batch_hint": 99999999999999999999999999}"#,
        )
        .unwrap_err();
        assert!(e.msg.contains("out of range"), "{e}");
        let f = scan_infer(br#"{"image": [], "deadline_us": 0}"#).unwrap();
        assert_eq!(f.deadline_us, Some(0));
    }

    #[test]
    fn depth_bomb_in_ignored_field_is_rejected_flat() {
        // 100k-deep nesting in a field the scanner does not extract:
        // the iterative skipper must cap out with an error, never
        // recurse toward a stack overflow.
        let mut body = br#"{"junk": "#.to_vec();
        body.extend(std::iter::repeat_n(b'[', 100_000));
        let e = scan_infer(&body).unwrap_err();
        assert!(e.msg.contains("nesting too deep"), "{e}");
    }

    #[test]
    fn malformed_bodies_error_cleanly() {
        for body in [
            &b""[..],
            b"[1,2]",
            b"{",
            b"{\"image\": [1,}",
            b"{\"image\": [1] trailing",
            b"{\"image\": [1]} extra",
            b"not json at all",
            b"{\"image\": [1],}",
        ] {
            assert!(scan_infer(body).is_err(), "{:?}", body);
        }
        // Invalid UTF-8 reports the offset where it breaks.
        let e = scan_infer(b"{\"image\": [1], \"s\": \"\xff\xfe\"}").unwrap_err();
        assert!(e.msg.contains("UTF-8"), "{e}");
    }
}
