//! RRAM crossbar substrate: cell quantization, geometry helpers, and the
//! component-level energy model (paper Table I + §V-A).

pub mod energy;

use crate::config::HardwareConfig;

/// Geometry of the mapped region of crossbars, in *cell* units.
///
/// Mapping works in weight columns; physical columns = weight columns ×
/// `cells_per_weight` (bit-slicing, see [`HardwareConfig`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellGeometry {
    pub xbar_rows: usize,
    pub xbar_cols: usize,
    pub cells_per_weight: usize,
    pub ou_rows: usize,
    pub ou_cols: usize,
}

impl CellGeometry {
    pub fn from_hw(hw: &HardwareConfig) -> CellGeometry {
        CellGeometry {
            xbar_rows: hw.xbar_rows,
            xbar_cols: hw.xbar_cols,
            cells_per_weight: hw.cells_per_weight(),
            ou_rows: hw.ou_rows,
            ou_cols: hw.ou_cols,
        }
    }

    /// Physical column span of `n` weights.
    pub fn weight_cols(&self, n_weights: usize) -> usize {
        n_weights * self.cells_per_weight
    }

    /// Weight capacity of one crossbar row.
    pub fn weights_per_row(&self) -> usize {
        self.xbar_cols / self.cells_per_weight
    }

    /// OU operations needed to cover an `h × w_cells` dense block
    /// (`h` rows, `w_cells` physical columns), per input vector.
    pub fn ou_ops_for_block(&self, h: usize, w_cells: usize) -> usize {
        h.div_ceil(self.ou_rows) * w_cells.div_ceil(self.ou_cols)
    }

    /// Cells provisioned by one crossbar. The DSE engine reports area
    /// in cells (`crossbars × cells_per_xbar`) so configurations with
    /// different crossbar geometries stay comparable — a raw crossbar
    /// count would make a 128×128 array look as expensive as a 512×512.
    pub fn cells_per_xbar(&self) -> usize {
        self.xbar_rows * self.xbar_cols
    }
}

/// Signed fixed-point weight quantization mirroring
/// `python/compile/kernels/quant.py` (`quantize_w`).
pub fn quantize_weight(w: f32, scale: f32, w_bits: usize) -> i32 {
    let w_max = (1i32 << (w_bits - 1)) - 1;
    let q = (w / scale).round() as i64;
    q.clamp(-(w_max as i64), w_max as i64) as i32
}

/// Signed input (DAC) quantization mirroring `quantize_x`.
pub fn quantize_input(x: f32, scale: f32, x_bits: usize) -> i32 {
    let x_max = (1i32 << (x_bits - 1)) - 1;
    let q = (x / scale).round() as i64;
    q.clamp(-(x_max as i64), x_max as i64) as i32
}

/// Static ADC step for the worst-case OU/slice partial sum, mirroring
/// `QuantConfig.adc_lsb`.
pub fn adc_lsb(hw: &HardwareConfig, x_bits: usize) -> f64 {
    let cell_max = (1usize << hw.cell_bits) - 1;
    let x_max = (1usize << (x_bits - 1)) - 1;
    let max_abs = (hw.ou_rows * cell_max * x_max) as f64;
    let levels = ((1usize << (hw.adc_bits - 1)) - 1) as f64;
    (max_abs / levels).max(1.0)
}

/// Symmetric ADC transfer function (mirror of `adc_quantize`).
pub fn adc_quantize(v: f64, hw: &HardwareConfig, x_bits: usize) -> f64 {
    let lsb = adc_lsb(hw, x_bits);
    let levels = ((1usize << (hw.adc_bits - 1)) - 1) as f64;
    let code = (v / lsb).round().clamp(-levels, levels);
    code * lsb
}

/// Differential signed cell slice of a quantized weight:
/// `sign(wq) * nibble_s(|wq|)`, mirror of `signed_cell_slices`.
pub fn signed_cell_slice(wq: i32, slice: usize, cell_bits: usize) -> i32 {
    let cell_max = (1i32 << cell_bits) - 1;
    let mag = wq.abs();
    let nib = (mag >> (slice * cell_bits)) & cell_max;
    nib * wq.signum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_paper_defaults() {
        let g = CellGeometry::from_hw(&HardwareConfig::default());
        assert_eq!(g.cells_per_weight, 4);
        assert_eq!(g.weights_per_row(), 128);
        assert_eq!(g.weight_cols(16), 64);
        // 9x8 OU over a full 512x512 crossbar
        assert_eq!(g.ou_ops_for_block(512, 512), 57 * 64);
        // one pattern block of 3 rows x 16 kernels (64 cells)
        assert_eq!(g.ou_ops_for_block(3, 64), 8);
        // narrow block still costs one OU
        assert_eq!(g.ou_ops_for_block(1, 1), 1);
        assert_eq!(g.cells_per_xbar(), 512 * 512);
    }

    #[test]
    fn weight_quantization_clamps() {
        assert_eq!(quantize_weight(0.0, 1.0, 8), 0);
        assert_eq!(quantize_weight(1.0, 1.0 / 127.0, 8), 127);
        assert_eq!(quantize_weight(10.0, 1.0 / 127.0, 8), 127); // clamp
        assert_eq!(quantize_weight(-10.0, 1.0 / 127.0, 8), -127);
        assert_eq!(quantize_weight(0.5, 1.0 / 127.0, 8), 64); // round half up
    }

    #[test]
    fn input_quantization() {
        assert_eq!(quantize_input(7.0, 1.0, 4), 7);
        assert_eq!(quantize_input(100.0, 1.0, 4), 7);
        assert_eq!(quantize_input(-100.0, 1.0, 4), -7);
    }

    #[test]
    fn adc_matches_python_constants() {
        // Python: QuantConfig(x_bits=8) -> lsb = 9*15*127/127 = 135/... :
        // max_abs = 9 * 15 * 127 = 17145, levels = 127 -> lsb = 135.0
        let hw = HardwareConfig::smallcnn_functional();
        let lsb = adc_lsb(&hw, 8);
        assert!((lsb - 135.0).abs() < 1e-9, "lsb={lsb}");
        assert_eq!(adc_quantize(0.0, &hw, 8), 0.0);
        assert_eq!(adc_quantize(135.0 * 3.4, &hw, 8), 135.0 * 3.0);
        // clamps at +/- 127 codes
        assert_eq!(adc_quantize(1e9, &hw, 8), 135.0 * 127.0);
    }

    #[test]
    fn cell_slices_reconstruct() {
        for wq in [-127i32, -16, -1, 0, 1, 5, 16, 100, 127] {
            let lo = signed_cell_slice(wq, 0, 4);
            let hi = signed_cell_slice(wq, 1, 4);
            assert_eq!(hi * 16 + lo, wq, "wq={wq}");
        }
    }
}
