//! Component-level energy accounting (paper Table I, §V-A).
//!
//! The paper evaluates ADC + DAC + RRAM-array energy only ("RRAM related
//! components consume more than 80% energy of the total chip" — ISAAC),
//! so the ledger tracks exactly those three components.

use crate::config::HardwareConfig;

/// Energy ledger in picojoules, split by component (Fig. 8's stacking).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EnergyLedger {
    pub adc_pj: f64,
    pub dac_pj: f64,
    pub rram_pj: f64,
}

impl EnergyLedger {
    pub fn total_pj(&self) -> f64 {
        self.adc_pj + self.dac_pj + self.rram_pj
    }

    pub fn add(&mut self, other: &EnergyLedger) {
        self.adc_pj += other.adc_pj;
        self.dac_pj += other.dac_pj;
        self.rram_pj += other.rram_pj;
    }

    /// `self += k × other` — closed-form accumulation of `k` identical
    /// ledgers without `k` repeated [`EnergyLedger::add`] calls (the
    /// trace-aggregated simulator's per-block energy step).
    pub fn add_scaled(&mut self, other: &EnergyLedger, k: f64) {
        self.adc_pj += other.adc_pj * k;
        self.dac_pj += other.dac_pj * k;
        self.rram_pj += other.rram_pj * k;
    }

    pub fn scale(&self, k: f64) -> EnergyLedger {
        EnergyLedger {
            adc_pj: self.adc_pj * k,
            dac_pj: self.dac_pj * k,
            rram_pj: self.rram_pj * k,
        }
    }
}

/// Energy of one executed OU operation with `rows_active` wordlines and
/// `cols_active` bitline cells actually used.
///
/// - DAC: one conversion per active wordline per bit-serial phase
///   (`input_bits / dac_bits` phases).
/// - RRAM: the Table-I 4.8 pJ figure is for a full `ou_rows × ou_cols`
///   activation; partial activations scale by the active-cell fraction.
/// - ADC: one conversion per active bitline.
///
/// The pattern scheme activates exactly the pattern-block rows/cols of
/// the OU (paper §V-C: "less bitlines and wordlines, as well as the ADCs
/// and DACs, are activated because of the pattern pruned compression");
/// the naive scheme always activates full OUs except at array edges.
pub fn ou_op_energy(
    hw: &HardwareConfig,
    rows_active: usize,
    cols_active: usize,
) -> EnergyLedger {
    debug_assert!(rows_active <= hw.ou_rows);
    debug_assert!(cols_active <= hw.ou_cols);
    let phases = hw.dac_phases() as f64;
    let full_cells = (hw.ou_rows * hw.ou_cols) as f64;
    EnergyLedger {
        dac_pj: rows_active as f64 * phases * hw.dac_pj_per_op,
        rram_pj: hw.rram_pj_per_ou_op
            * (rows_active * cols_active) as f64
            / full_cells,
        adc_pj: cols_active as f64 * hw.adc_pj_per_op,
    }
}

/// Energy of `n` identical OU operations in one step — the batched
/// accumulation the trace-aggregated simulator uses when it knows a
/// tile shape repeats (`n` can be fractional after position scaling).
pub fn ou_op_energy_batch(
    hw: &HardwareConfig,
    rows_active: usize,
    cols_active: usize,
    n: f64,
) -> EnergyLedger {
    ou_op_energy(hw, rows_active, cols_active).scale(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_ou_energy_matches_table1() {
        let hw = HardwareConfig::default();
        let e = ou_op_energy(&hw, 9, 8);
        // ADC: 8 conversions x 1.67 pJ
        assert!((e.adc_pj - 8.0 * 1.67).abs() < 1e-12);
        // DAC: 9 wordlines x 2 phases (8-bit input / 4-bit DAC) x 0.0182
        assert!((e.dac_pj - 9.0 * 2.0 * 0.0182).abs() < 1e-12);
        // RRAM: full OU = 4.8 pJ
        assert!((e.rram_pj - 4.8).abs() < 1e-12);
        // ADC dominates — the paper's Fig. 8 observation
        assert!(e.adc_pj > e.rram_pj && e.rram_pj > e.dac_pj);
    }

    #[test]
    fn partial_activation_scales() {
        let hw = HardwareConfig::default();
        let full = ou_op_energy(&hw, 9, 8);
        let part = ou_op_energy(&hw, 3, 4);
        assert!((part.adc_pj - full.adc_pj * 0.5).abs() < 1e-12);
        assert!((part.dac_pj - full.dac_pj / 3.0).abs() < 1e-12);
        assert!((part.rram_pj - full.rram_pj * 12.0 / 72.0).abs() < 1e-12);
    }

    #[test]
    fn batched_energy_matches_repeated_adds() {
        let hw = HardwareConfig::default();
        let single = ou_op_energy(&hw, 7, 5);
        let mut acc = EnergyLedger::default();
        for _ in 0..13 {
            acc.add(&single);
        }
        let batch = ou_op_energy_batch(&hw, 7, 5, 13.0);
        assert!((acc.adc_pj - batch.adc_pj).abs() < 1e-9);
        assert!((acc.dac_pj - batch.dac_pj).abs() < 1e-9);
        assert!((acc.rram_pj - batch.rram_pj).abs() < 1e-9);
    }

    #[test]
    fn add_scaled_equals_scale_then_add() {
        let mut a = EnergyLedger { adc_pj: 1.0, dac_pj: 2.0, rram_pj: 3.0 };
        let mut a2 = a;
        let b = EnergyLedger { adc_pj: 0.25, dac_pj: 0.5, rram_pj: 0.75 };
        a.add_scaled(&b, 4.0);
        a2.add(&b.scale(4.0));
        assert_eq!(a, a2);
    }

    #[test]
    fn ledger_arithmetic() {
        let mut a = EnergyLedger { adc_pj: 1.0, dac_pj: 2.0, rram_pj: 3.0 };
        let b = EnergyLedger { adc_pj: 0.5, dac_pj: 0.5, rram_pj: 0.5 };
        a.add(&b);
        assert_eq!(a.total_pj(), 7.5);
        let s = a.scale(2.0);
        assert_eq!(s.total_pj(), 15.0);
        assert_eq!(EnergyLedger::default().total_pj(), 0.0);
    }
}
