//! `rram-accel` — CLI for the RRAM pattern-pruned CNN accelerator
//! reproduction.
//!
//! Subcommands:
//!   map       — map a network (synthetic VGG16 or artifacts SmallCNN)
//!               with a scheme; print crossbar/area/index stats
//!   simulate  — cycle/energy simulation + scheme comparison (Fig7/8/§V-C)
//!   batch-sim — batched multi-image simulation (per-image + batch
//!               totals, bit-exact with looped per-image runs;
//!               `--shards N` plans + checks cost-balanced sharding)
//!   dse       — design-space exploration: parallel sweep over
//!               mapping/OU/crossbar/pattern/pruning configs (plus the
//!               `--zd`/`--block-switch` simulation-policy axes and
//!               `--exact` trace mode and the `--cores`/`--noc-bw`/
//!               `--noc-hop` multi-core scale-out axes), Pareto
//!               frontier as table + results/<out>.{json,csv}, cached
//!               under results/dse_cache/; `--profile` times the
//!               sweep's stages from the CLI side (the dse module
//!               itself stays wall-clock-free) and writes
//!               results/dse_profile.json
//!   place     — layer-to-core placement on a multi-core CIM chip:
//!               plan the pipeline (greedy-LPT vs optimal-contiguous
//!               baseline, never worse than the baseline), print the
//!               per-core utilization + transfer breakdown, emit the
//!               deterministic results/placement.json artifact
//!   serve     — start the sharded serving coordinator over the PJRT
//!               artifact (`--workers N --balance cost|rr`, per-request
//!               cost estimates calibrated from exact traces,
//!               deadlines, per-worker retry/requeue/quarantine, alarm;
//!               `--auto-tune [--tune-exact]` builds the pool config
//!               from the DSE frontier winner)
//!   serve-http — production HTTP/1.1 front door over the coordinator
//!               (`POST /v1/infer`, `GET /metrics`, `GET /healthz`,
//!               `GET /debug/trace`; std-only server in
//!               `rram_pattern_accel::serve_http` with bounded request
//!               reading and a lazy JSON field scanner; every request
//!               is traced end to end through the `obs` registry;
//!               `--backend mock` serves without the PJRT runtime,
//!               `--auto-tune` builds the pool from the DSE frontier
//!               winner)
//!   trace     — run a traced mock-pool session and export the spans as
//!               Chrome trace-event JSON (load into Perfetto /
//!               chrome://tracing); results/trace.json by default
//!   e2e       — run the SmallCNN end-to-end check (golden + accuracy)
//!   report    — print every paper table/figure (sampled mode)
//!   artifacts — run every paper figure in sampled AND exact trace mode
//!               over the synthetic VGG16 datasets, emit versioned
//!               results/paper/{fig7,fig8,table2}_{sampled,exact}.json
//!               plus the machine-readable sampled-vs-exact
//!               delta_report.json (tolerance-banded; nonzero exit on
//!               an out-of-band delta)
//!   lint      — in-tree determinism/concurrency static analysis over
//!               rust/, tests/, benches/ (`--deny-warnings` in CI);
//!               exits 0 clean, 1 findings, 2 internal error, writes
//!               results/lint_report.json sorted by (path, line, rule)

use std::path::Path;
use std::time::Duration;

use rram_pattern_accel::analysis;
use rram_pattern_accel::config::{HardwareConfig, SimConfig};
use rram_pattern_accel::coordinator::{
    BalancePolicy, Coordinator, CoordinatorConfig, CostModel, PjrtBackend,
};
use rram_pattern_accel::dse::{
    self, Objective, ResultCache, SweepRunner, SweepSpec, SweepStage,
};
use rram_pattern_accel::mapping::{
    index, naive::NaiveMapping, pattern::PatternMapping, scheme_by_name,
    MappingScheme,
};
use rram_pattern_accel::nn::{NetworkSpec, Tensor};
use rram_pattern_accel::obs;
use rram_pattern_accel::pruning::synthetic::{DatasetProfile, ALL_PROFILES};
use rram_pattern_accel::report::{
    self,
    artifacts::{
        self, ArtifactCache, ArtifactConfig, DeltaTolerances, PaperArtifacts,
        TraceMode,
    },
};
use rram_pattern_accel::runtime::{Engine, EngineFactory};
use rram_pattern_accel::serve_http::{HttpConfig, HttpServer, MockInferBackend};
use rram_pattern_accel::sim::{self, smallcnn::SmallCnn, ShardPolicy};
use rram_pattern_accel::util::cli::Args;
use rram_pattern_accel::util::threadpool;
use rram_pattern_accel::xbar::CellGeometry;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = argv.first().cloned().unwrap_or_else(|| "help".to_string());
    let rest: Vec<String> = argv.into_iter().skip(1).collect();
    let code = match sub.as_str() {
        "map" => cmd_map(rest),
        "simulate" => cmd_simulate(rest),
        "batch-sim" => cmd_batch_sim(rest),
        "dse" => cmd_dse(rest),
        "place" => cmd_place(rest),
        "serve" => cmd_serve(rest),
        "serve-http" => cmd_serve_http(rest),
        "trace" => cmd_trace(rest),
        "e2e" => cmd_e2e(rest),
        "report" => cmd_report(rest),
        "artifacts" => cmd_artifacts(rest),
        "lint" => cmd_lint(rest),
        _ => {
            eprintln!(
                "usage: rram-accel <map|simulate|batch-sim|dse|place|serve|\
                 serve-http|trace|e2e|report|artifacts|lint> [options]\n\
                 run a subcommand with --help for its options"
            );
            if sub == "help" { 0 } else { 2 }
        }
    };
    std::process::exit(code);
}

fn cmd_map(rest: Vec<String>) -> i32 {
    let args = match Args::new("map a network onto RRAM crossbars")
        .opt("dataset", "cifar10", "cifar10|cifar100|imagenet (synthetic VGG16)")
        .opt("scheme", "pattern", "naive|pattern|kmeans|ou_sparse")
        .opt("seed", "42", "synthetic weight seed")
        .opt("threads", "0", "worker threads (0 = auto)")
        .parse(rest)
    {
        Ok(a) => a,
        Err(e) => return usage(e),
    };
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let profile = match DatasetProfile::by_name(args.get("dataset")) {
        Some(p) => p,
        None => return usage(format!("unknown dataset {}", args.get("dataset"))),
    };
    let scheme = match scheme_by_name(args.get("scheme")) {
        Some(s) => s,
        None => return usage(format!("unknown scheme {}", args.get("scheme"))),
    };
    let threads = auto_threads(&args);
    let seed = args.get_usize("seed").unwrap_or(42) as u64;

    println!("{}", report::table1(&hw));
    let nw = profile.generate(seed);
    let mapped = scheme.map_network(&nw, &geom, threads);
    println!(
        "network {} scheme {}: {} crossbars, {} used cells, utilization {:.1}%",
        mapped.network,
        mapped.scheme,
        mapped.total_crossbars(),
        mapped.total_used_cells(),
        100.0 * mapped.total_used_cells() as f64
            / (mapped.total_crossbars() * hw.xbar_rows * hw.xbar_cols).max(1) as f64,
    );
    let mut idx_bits = 0usize;
    for (li, ml) in mapped.layers.iter().enumerate() {
        let oh = index::overhead(ml);
        idx_bits += oh.total_bits();
        println!(
            "  layer {:>2}: {:>5} blocks {:>4} xbars  {:>9} cells  \
             {:>6} zero-kernels  index {:>8.1} KiB",
            li,
            ml.blocks.len(),
            ml.n_crossbars,
            ml.used_cells,
            ml.zero_kernels,
            oh.total_kib(),
        );
    }
    println!(
        "total index overhead: {:.1} KiB",
        idx_bits as f64 / 8.0 / 1024.0
    );
    0
}

fn cmd_simulate(rest: Vec<String>) -> i32 {
    let args = match Args::new("cycle/energy simulation vs the naive baseline")
        .opt("dataset", "cifar10", "cifar10|cifar100|imagenet")
        .opt("seed", "42", "synthetic weight seed")
        .opt("samples", "64", "sampled positions per layer")
        .opt("threads", "0", "worker threads (0 = auto)")
        .flag("no-zero-detect", "disable all-zero input detection")
        .parse(rest)
    {
        Ok(a) => a,
        Err(e) => return usage(e),
    };
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let threads = auto_threads(&args);
    let profile = match DatasetProfile::by_name(args.get("dataset")) {
        Some(p) => p,
        None => return usage(format!("unknown dataset {}", args.get("dataset"))),
    };
    let sim_cfg = SimConfig {
        sample_positions: Some(args.get_usize("samples").unwrap_or(64)),
        zero_detection: !args.get_flag("no-zero-detect"),
        ..Default::default()
    };
    let seed = args.get_usize("seed").unwrap_or(42) as u64;

    let nw = profile.generate(seed);
    let spec = nw.spec.clone();
    let naive = NaiveMapping.map_network(&nw, &geom, threads);
    let ours = PatternMapping.map_network(&nw, &geom, threads);
    let base = sim::simulate_network(&naive, &spec, &hw, &sim_cfg, threads);
    let mine = sim::simulate_network(&ours, &spec, &hw, &sim_cfg, threads);
    let cmp = sim::Comparison { baseline: base, ours: mine };
    println!("{}", report::table1(&hw));
    println!(
        "{:<10} area {:.2}x | energy {:.2}x | speedup {:.2}x",
        profile.name,
        cmp.area_efficiency(),
        cmp.energy_efficiency(),
        cmp.speedup(),
    );
    0
}

fn cmd_batch_sim(rest: Vec<String>) -> i32 {
    let args = match Args::new(
        "batched multi-image simulation: per-image + batch cycles/energy",
    )
    .opt("dataset", "cifar10", "cifar10|cifar100|imagenet")
    .opt("images", "8", "batch size in images")
    .opt("samples", "64", "sampled positions per layer")
    .opt("seed", "42", "synthetic weight seed")
    .opt("threads", "0", "worker threads (0 = auto)")
    .opt("shards", "0", "plan the batch over N shards (0 = off)")
    .opt(
        "shard-tolerance",
        "0.10",
        "max predicted/achieved per-shard share divergence",
    )
    .flag("smallcnn", "also run the exact-mode synthetic SmallCNN batch")
    .flag("json", "write results/batch_sim.json")
    .parse(rest)
    {
        Ok(a) => a,
        Err(e) => return usage(e),
    };
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let threads = auto_threads(&args);
    let profile = match DatasetProfile::by_name(args.get("dataset")) {
        Some(p) => p,
        None => return usage(format!("unknown dataset {}", args.get("dataset"))),
    };
    let n_images = args.get_usize("images").unwrap_or(8).max(1);
    let sim_cfg = SimConfig {
        sample_positions: Some(args.get_usize("samples").unwrap_or(64)),
        ..Default::default()
    };
    let seed = args.get_u64("seed").unwrap_or(42);

    let nw = profile.generate(seed);
    let spec = nw.spec.clone();
    let naive = NaiveMapping.map_network(&nw, &geom, threads);
    let ours = PatternMapping.map_network(&nw, &geom, threads);
    let base = sim::simulate_network_batch(&naive, &spec, &hw, &sim_cfg, n_images, threads);
    let mine = sim::simulate_network_batch(&ours, &spec, &hw, &sim_cfg, n_images, threads);
    println!("{}", report::batch_line(&base));
    println!("{}", report::batch_line(&mine));
    for (i, r) in mine.per_image.iter().enumerate() {
        println!(
            "  image {:>3}: cycles {:>15.0}  ou-ops {:>15.0}  energy {:.3e} pJ",
            i,
            r.total_cycles(),
            r.total_ou_ops(),
            r.total_energy().total_pj(),
        );
    }
    println!(
        "batch speedup pattern vs naive: {:.2}x",
        base.total_cycles() / mine.total_cycles().max(1.0)
    );

    // Cross-check the tentpole invariant on this exact run: the batch
    // totals equal the looped per-image oracle bit for bit.
    let looped =
        sim::simulate_network_looped(&ours, &spec, &hw, &sim_cfg, n_images, threads);
    let bit_exact = mine.total_cycles() == looped;
    println!(
        "batch-vs-looped cycle check: batch {} vs looped {} ({})",
        mine.total_cycles(),
        looped,
        if bit_exact { "bit-exact" } else { "MISMATCH" },
    );

    // Shard planning: balance the batch's predicted per-image costs
    // over N shards, then evaluate the same assignment against the
    // achieved (fully simulated) cycles. A divergence beyond tolerance
    // is an error — and the error path prints the per-shard table, so
    // the nonzero exit always comes with the numbers behind it.
    let shards = args.get_usize("shards").unwrap_or(0);
    let tolerance = args.get_f64("shard-tolerance").unwrap_or(0.10);
    let mut shard_ok = true;
    let mut shard_json = None;
    if shards > 0 {
        let plan = mine.shard_plan(shards, ShardPolicy::CostBalanced);
        let rr = mine.shard_plan(shards, ShardPolicy::RoundRobin);
        let achieved = plan.loads_with(&mine.image_cycles());
        let table = report::shard_balance_table(&plan, &achieved);
        println!("{table}");
        println!(
            "cost-balanced max shard load {:.0} vs round-robin {:.0} ({})",
            plan.max_load(),
            rr.max_load(),
            if plan.max_load() < rr.max_load() {
                "cost wins"
            } else {
                "tied"
            },
        );
        let divergence = report::shard_share_divergence(&plan.loads, &achieved);
        println!(
            "predicted/achieved share divergence {:.2}% (tolerance {:.0}%)",
            divergence * 100.0,
            tolerance * 100.0,
        );
        if divergence > tolerance {
            shard_ok = false;
            eprintln!(
                "batch-sim: shard plan diverged from achieved cycles by \
                 {:.2}% (> {:.0}% tolerance) — per-shard loads:\n{}",
                divergence * 100.0,
                tolerance * 100.0,
                table,
            );
        }
        shard_json = Some(report::shard_plan_json(&plan, &achieved));
    }

    if args.get_flag("smallcnn") {
        let model = SmallCnn::synthetic(NetworkSpec::smallcnn(), seed);
        let hw_s = HardwareConfig::smallcnn_functional();
        let mapped = model.map(&PatternMapping, &hw_s);
        let img_len = 3 * 32 * 32;
        let mut rng = rram_pattern_accel::util::rng::Rng::seed_from(seed ^ 0xBA7C);
        let mut batch_x = Tensor::zeros(&[n_images, 3, 32, 32]);
        for v in batch_x.data.iter_mut() {
            *v = if rng.chance(0.4) { 0.0 } else { rng.f32() };
        }
        debug_assert_eq!(batch_x.data.len(), n_images * img_len);
        let exact = model.simulate_exact_batch(
            &mapped,
            &batch_x,
            &hw_s,
            &SimConfig::default(),
            threads,
        );
        println!("exact-mode synthetic SmallCNN:");
        println!("{}", report::batch_line(&exact));
    }

    if args.get_flag("json") {
        let mut pairs = vec![
            ("naive", base.to_json()),
            ("pattern", mine.to_json()),
        ];
        if let Some(sj) = shard_json {
            pairs.push(("shard_plan", sj));
        }
        let j = rram_pattern_accel::util::json::obj(pairs);
        match report::write_json("batch_sim.json", &j) {
            Ok(()) => println!("wrote results/batch_sim.json"),
            Err(e) => eprintln!("write results/batch_sim.json: {e}"),
        }
    }
    if !bit_exact {
        eprintln!("batch-sim: batch/looped totals diverged — engine bug");
    }
    if bit_exact && shard_ok {
        0
    } else {
        1
    }
}

fn cmd_dse(rest: Vec<String>) -> i32 {
    let args = match Args::new(
        "design-space exploration: sweep mapping/OU/crossbar/pattern/\
         pruning configs in parallel and emit the Pareto frontier",
    )
    .opt("grid", "small", "sweep grid: small|medium|large")
    .opt("seed", "42", "workload seed")
    .opt("threads", "0", "sweep worker threads (0 = auto)")
    .opt("weights", "1,1,1", "selection weights: area,energy,cycles")
    .opt("cache-dir", "results/dse_cache", "on-disk result cache directory")
    .opt(
        "cache-backend",
        "binary",
        "cache layout: binary (pack store) | legacy (per-point JSON)",
    )
    .opt("out", "dse_frontier", "artifact basename under results/")
    .opt("zd", "on", "zero-detection axis: on|off|both")
    .opt("block-switch", "2", "block-switch cycle cost axis (comma-separated)")
    .opt("cores", "1", "CIM core-count axis (comma-separated)")
    .opt("noc-bw", "32", "NoC bandwidth axis, bytes/cycle (comma-separated)")
    .opt("noc-hop", "4", "NoC per-hop latency axis, cycles (comma-separated)")
    .flag("exact", "exact traces: cost every output position (no sampling)")
    .flag("no-cache", "evaluate every point fresh")
    .flag(
        "warm-start",
        "seed the frontier from the cache's snapshot of the last run \
         (same frontier bytes, less extraction work)",
    )
    .flag("sensitivity", "print the per-axis sensitivity summary")
    .flag(
        "profile",
        "time the sweep stages (expand/cache/evaluate/frontier/snapshot) \
         from the CLI side and write results/dse_profile.json",
    )
    .parse(rest)
    {
        Ok(a) => a,
        Err(e) => return usage(e),
    };
    let seed = args.get_u64("seed").unwrap_or(42);
    let mut spec = match SweepSpec::by_name(args.get("grid"), seed) {
        Some(s) => s,
        None => return usage(format!("unknown grid {}", args.get("grid"))),
    };
    if args.get_flag("exact") {
        spec.workload.exact = true;
    }
    let zd_axis: Vec<bool> = match args.get("zd") {
        "on" => vec![true],
        "off" => vec![false],
        "both" => vec![true, false],
        other => {
            return usage(format!(
                "unknown zero-detection axis {other} (use on|off|both)"
            ))
        }
    };
    let mut bs_axis = Vec::new();
    for part in args.get("block-switch").split(',') {
        match part.trim().parse::<f64>() {
            Ok(v) if v.is_finite() && v >= 0.0 => bs_axis.push(v),
            _ => {
                return usage(format!(
                    "bad block-switch value '{}'",
                    part.trim()
                ))
            }
        }
    }
    let mut core_axis = Vec::new();
    for part in args.get("cores").split(',') {
        match part.trim().parse::<usize>() {
            Ok(v) if v >= 1 => core_axis.push(v),
            _ => return usage(format!("bad cores value '{}'", part.trim())),
        }
    }
    let mut bw_axis = Vec::new();
    for part in args.get("noc-bw").split(',') {
        match part.trim().parse::<f64>() {
            Ok(v) if v.is_finite() && v > 0.0 => bw_axis.push(v),
            _ => return usage(format!("bad noc-bw value '{}'", part.trim())),
        }
    }
    let mut hop_axis = Vec::new();
    for part in args.get("noc-hop").split(',') {
        match part.trim().parse::<f64>() {
            Ok(v) if v.is_finite() && v >= 0.0 => hop_axis.push(v),
            _ => return usage(format!("bad noc-hop value '{}'", part.trim())),
        }
    }
    // Cross bandwidth × hop latency into the interconnect axis.
    let interconnect: Vec<(f64, f64)> = bw_axis
        .iter()
        .flat_map(|&bw| hop_axis.iter().map(move |&hop| (bw, hop)))
        .collect();
    let spec = spec
        .with_sim_axes(&zd_axis, &bs_axis)
        .with_core_axes(&core_axis, &interconnect);
    let obj = match Objective::parse(args.get("weights")) {
        Ok(o) => o,
        Err(e) => return usage(e),
    };
    let threads = auto_threads(&args);
    let cache = if args.get_flag("no-cache") {
        None
    } else {
        let dir = args.get("cache-dir").to_string();
        match args.get("cache-backend") {
            "binary" => Some(ResultCache::new(dir)),
            "legacy" => Some(ResultCache::legacy_json(dir)),
            other => {
                return usage(format!(
                    "unknown cache backend {other} (use binary|legacy)"
                ))
            }
        }
    };
    println!(
        "sweeping '{}' grid: {} points on {} threads ({}, {} traces)",
        spec.grid,
        spec.expand().len(),
        threads,
        match &cache {
            Some(c) if c.is_binary() => "cached: binary",
            Some(_) => "cached: legacy json",
            None => "uncached",
        },
        if spec.workload.exact { "exact" } else { "sampled" },
    );
    let warm_start = args.get_flag("warm-start");
    let runner = SweepRunner { spec, threads, cache };
    let mut profile_json = None;
    let outcome = if args.get_flag("profile") {
        // Stage timing is measured here, at the CLI boundary: the dse
        // module is a wall-clock-free pure path, so the runner only
        // reports logical stage boundaries and this closure reads the
        // clock around them.
        let t0 = std::time::Instant::now();
        let n_stages = SweepStage::ALL.len();
        let mut begin_us = vec![0u64; n_stages];
        let mut wall_us = vec![0u64; n_stages];
        let outcome = runner.run_observed(warm_start, &mut |stage, begin| {
            let i = SweepStage::ALL
                .iter()
                .position(|s| *s == stage)
                .expect("stage in ALL");
            let t = t0.elapsed().as_micros() as u64;
            if begin {
                begin_us[i] = t;
            } else {
                wall_us[i] += t.saturating_sub(begin_us[i]);
            }
        });
        let stages: Vec<rram_pattern_accel::util::json::Json> = SweepStage::ALL
            .iter()
            .enumerate()
            .map(|(i, s)| {
                rram_pattern_accel::util::json::obj(vec![
                    ("name", s.name().into()),
                    ("wall_us", (wall_us[i] as f64).into()),
                ])
            })
            .collect();
        profile_json = Some(rram_pattern_accel::util::json::obj(vec![
            ("grid", outcome.spec.grid.as_str().into()),
            ("points", outcome.results.len().into()),
            (
                "stages",
                rram_pattern_accel::util::json::Json::Arr(stages),
            ),
            (
                "logical",
                rram_pattern_accel::util::json::obj(vec![
                    ("cache_hits", outcome.cache_hits().into()),
                    ("cache_misses", outcome.cache_misses().into()),
                    ("evaluated", outcome.evaluated().into()),
                    ("skipped", outcome.skipped().into()),
                ]),
            ),
        ]));
        outcome
    } else {
        runner.run_with(warm_start)
    };
    println!("{}", outcome.summary_line());
    print!("{}", outcome.frontier.table(&outcome.results));
    if args.get_flag("sensitivity") {
        for axis in dse::sensitivity(&outcome.results) {
            print!("{}", axis.lines());
        }
    }
    if let Some(t) = outcome.select(&obj) {
        println!(
            "selected (weights area,energy,cycles = {}): {} — cycles {:.0}, \
             energy {:.4e} pJ, {} crossbars ({:.0} cells)",
            args.get("weights"),
            t.point.label(),
            t.metrics.cycles,
            t.metrics.energy_pj,
            t.metrics.crossbars,
            t.metrics.area_cells,
        );
    }
    // The artifacts are the command's contract: a failed write is a
    // failed run, not a warning.
    let mut write_ok = true;
    let json_name = format!("{}.json", args.get("out"));
    match report::write_json(&json_name, &outcome.frontier_json()) {
        Ok(()) => println!("wrote results/{json_name}"),
        Err(e) => {
            write_ok = false;
            eprintln!("write results/{json_name}: {e}");
        }
    }
    let csv_name = format!("{}.csv", args.get("out"));
    match report::write_text(&csv_name, &outcome.frontier_csv()) {
        Ok(()) => println!("wrote results/{csv_name}"),
        Err(e) => {
            write_ok = false;
            eprintln!("write results/{csv_name}: {e}");
        }
    }
    if let Some(pj) = &profile_json {
        match report::write_json("dse_profile.json", pj) {
            Ok(()) => println!("wrote results/dse_profile.json"),
            Err(e) => {
                write_ok = false;
                eprintln!("write results/dse_profile.json: {e}");
            }
        }
    }
    if outcome.frontier.is_empty() {
        eprintln!("dse: empty frontier — every grid point was skipped");
        1
    } else if !write_ok {
        1
    } else {
        0
    }
}

/// `rram-accel place` — plan the layer-to-core placement of a network
/// on a multi-core CIM chip and report the per-core utilization and
/// transfer breakdown. The JSON artifact under `results/` is pure
/// function of the flags: byte-identical across thread counts and
/// repeated runs.
fn cmd_place(rest: Vec<String>) -> i32 {
    let args = match Args::new(
        "layer-to-core placement + pipelining on a multi-core CIM chip",
    )
    .opt("dataset", "cifar10", "cifar10|cifar100|imagenet (synthetic VGG16)")
    .opt("scheme", "pattern", "naive|pattern|kmeans|ou_sparse")
    .opt("cores", "4", "CIM cores on the chip")
    .opt("noc-bw", "32", "NoC bandwidth, bytes per cycle")
    .opt("noc-hop", "4", "NoC per-hop latency, cycles")
    .opt("images", "8", "batch size in images")
    .opt("samples", "64", "sampled positions per layer")
    .opt("seed", "42", "synthetic weight seed")
    .opt(
        "threads",
        "0",
        "worker threads (0 = auto; the artifact is thread-invariant)",
    )
    .opt("out", "placement", "artifact basename under results/")
    .flag("no-zero-detect", "disable IPU zero detection (dense transfers)")
    .flag("json", "write results/<out>.json")
    .parse(rest)
    {
        Ok(a) => a,
        Err(e) => return usage(e),
    };
    let cores = args.get_usize("cores").unwrap_or(4).max(1);
    let bw = args.get_f64("noc-bw").unwrap_or(32.0);
    let hop = args.get_f64("noc-hop").unwrap_or(4.0);
    let hw = match HardwareConfig::default().with_cores(cores, bw, hop) {
        Ok(hw) => hw,
        Err(e) => return usage(format!("bad multi-core block: {e}")),
    };
    let geom = CellGeometry::from_hw(&hw);
    let threads = auto_threads(&args);
    let profile = match DatasetProfile::by_name(args.get("dataset")) {
        Some(p) => p,
        None => return usage(format!("unknown dataset {}", args.get("dataset"))),
    };
    let scheme = match scheme_by_name(args.get("scheme")) {
        Some(s) => s,
        None => return usage(format!("unknown scheme {}", args.get("scheme"))),
    };
    let n_images = args.get_usize("images").unwrap_or(8).max(1);
    let sim_cfg = SimConfig {
        sample_positions: Some(args.get_usize("samples").unwrap_or(64)),
        zero_detection: !args.get_flag("no-zero-detect"),
        ..Default::default()
    };
    let seed = args.get_u64("seed").unwrap_or(42);

    let nw = profile.generate(seed);
    let spec = nw.spec.clone();
    let mapped = scheme.map_network(&nw, &geom, threads);
    let batch =
        sim::simulate_network_batch(&mapped, &spec, &hw, &sim_cfg, n_images, threads);
    let ipu =
        sim::scheme_has_ipu(args.get("scheme")) && sim_cfg.zero_detection;
    let problem = sim::placement::PlacementProblem::from_batch(
        &batch, &spec, &hw, &sim_cfg, ipu,
    );
    let best = sim::placement::plan(&problem);
    let base = sim::placement::contiguous(&problem);
    println!("{}", report::placement_table(&best, n_images));
    println!(
        "planner max stage {:.0} vs contiguous baseline {:.0} ({})",
        best.max_stage_time(),
        base.max_stage_time(),
        if best.max_stage_time() < base.max_stage_time() {
            "greedy wins"
        } else {
            "baseline kept"
        },
    );
    let makespan = best.pipeline_makespan(n_images);
    println!(
        "batch of {}: single-core {:.0} cycles, pipelined {:.0} cycles \
         ({:.2}x)",
        n_images,
        batch.total_cycles(),
        makespan,
        batch.total_cycles() / makespan.max(1e-12),
    );
    // The never-worse pin is structural; a violation here is a
    // planner bug, not a tuning issue.
    let mut exit = 0;
    if best.max_stage_time() > base.max_stage_time() {
        exit = 1;
        eprintln!(
            "place: planner worse than its contiguous baseline — pin broken"
        );
    }
    if args.get_flag("json") {
        let j = report::placement_json(&best, n_images, batch.total_cycles());
        let name = format!("{}.json", args.get("out"));
        match report::write_json(&name, &j) {
            Ok(()) => println!("wrote results/{name}"),
            Err(e) => {
                exit = 1;
                eprintln!("write results/{name}: {e}");
            }
        }
    }
    exit
}

fn cmd_serve(rest: Vec<String>) -> i32 {
    let args = match Args::new("serve batched inference over the AOT artifact")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("requests", "32", "number of demo requests to run")
        .opt("max-wait-ms", "2", "batcher max wait")
        .opt("deadline-ms", "0", "per-request deadline (0 = none)")
        .opt("alarm-threshold", "0", "failed-request alarm threshold (0 = off)")
        .opt("workers", "1", "pool size: worker threads, one backend each")
        .opt("balance", "cost", "dispatch policy: cost|rr")
        .opt(
            "calib-images",
            "8",
            "exact-trace cost-model calibration images (0 = analytic fallback)",
        )
        .opt(
            "max-requeues",
            "1",
            "cross-worker requeues of a failed batch's requests (pools only)",
        )
        .opt(
            "quarantine-expiry-ms",
            "0",
            "quarantine expiry in ms (0 = release on next success only)",
        )
        .flag(
            "auto-tune",
            "sweep the design space and build the pool's config + cost \
             model from the Pareto-frontier winner",
        )
        .opt("tune-grid", "small", "auto-tune sweep grid: small|medium")
        .opt("tune-seed", "42", "auto-tune workload seed (match `dse --seed`)")
        .opt("tune-weights", "1,1,1", "auto-tune weights: area,energy,cycles")
        .flag(
            "tune-exact",
            "auto-tune from exact traces (every position; match `dse --exact`)",
        )
        .flag("json", "write results/serve_workers.json")
        .parse(rest)
    {
        Ok(a) => a,
        Err(e) => return usage(e),
    };
    if !Engine::available() {
        return usage(
            "PJRT runtime unavailable: rebuild with --features xla-runtime \
             (see Cargo.toml)"
                .to_string(),
        );
    }
    let dir = args.get("artifacts").to_string();
    let n = args.get_usize("requests").unwrap_or(32);
    let wait = Duration::from_millis(args.get_usize("max-wait-ms").unwrap_or(2) as u64);
    let deadline_ms = args.get_usize("deadline-ms").unwrap_or(0);
    let alarm_threshold = args.get_u64("alarm-threshold").unwrap_or(0);
    let workers = args.get_usize("workers").unwrap_or(1).max(1);
    let balance = match args.get("balance") {
        "cost" => BalancePolicy::CostAware,
        "rr" => BalancePolicy::RoundRobin,
        other => return usage(format!("unknown balance policy {other}")),
    };
    let calib_images = args.get_usize("calib-images").unwrap_or(8);

    let td = match sim::smallcnn::TestData::load(Path::new(&dir)) {
        Ok(t) => t,
        Err(e) => return usage(format!("load test data: {e} (run `make artifacts`)")),
    };

    // Auto-tune: sweep the design space (cached under
    // results/dse_cache/) and take the frontier point the weighted
    // objective selects; its scheme + OU/crossbar geometry become the
    // pool's accelerator config, so the cost model the dispatcher
    // balances on is calibrated against the sweep's winner.
    let tuned = if args.get_flag("auto-tune") {
        let obj = match Objective::parse(args.get("tune-weights")) {
            Ok(o) => o,
            Err(e) => return usage(e),
        };
        let tune_seed = args.get_u64("tune-seed").unwrap_or(42);
        let mut spec = match SweepSpec::by_name(args.get("tune-grid"), tune_seed) {
            Some(s) => s,
            None => {
                return usage(format!("unknown tune grid {}", args.get("tune-grid")))
            }
        };
        if args.get_flag("tune-exact") {
            spec.workload.exact = true;
        }
        let outcome = SweepRunner {
            spec,
            threads: threadpool::default_threads(),
            cache: Some(ResultCache::default_dir()),
        }
        .run();
        println!("[serve] auto-tune: {}", outcome.summary_line());
        match outcome.select(&obj) {
            Some(t) => {
                println!(
                    "[serve] auto-tune selected {} — cycles {:.0}, energy \
                     {:.4e} pJ, {} crossbars",
                    t.point.label(),
                    t.metrics.cycles,
                    t.metrics.energy_pj,
                    t.metrics.crossbars,
                );
                Some(t)
            }
            None => {
                return usage("auto-tune produced an empty frontier".to_string())
            }
        }
    } else {
        None
    };
    // Scheme + hardware the serving cost model runs on: the tuned
    // winner's geometry grafted onto the SmallCNN functional base, or
    // the paper defaults without --auto-tune.
    let (serve_scheme, serve_hw): (Box<dyn MappingScheme>, HardwareConfig) =
        match &tuned {
            Some(t) => {
                let hw = match t
                    .point
                    .apply_dims(&HardwareConfig::smallcnn_functional())
                {
                    Ok(hw) => hw,
                    Err(e) => {
                        return usage(format!(
                            "tuned geometry rejected by the serving base: {e}"
                        ))
                    }
                };
                match scheme_by_name(&t.point.scheme) {
                    Some(s) => (s, hw),
                    None => {
                        return usage(format!(
                            "tuned scheme {} not registered",
                            t.point.scheme
                        ))
                    }
                }
            }
            None => (
                Box::new(PatternMapping),
                HardwareConfig::smallcnn_functional(),
            ),
        };
    let serve_scheme_name: String = tuned
        .as_ref()
        .map(|t| t.point.scheme.clone())
        .unwrap_or_else(|| "pattern".to_string());

    // Per-request cost model, calibrated from *real* exact-mode
    // activation traces over the first test images (per-layer
    // zero-fraction→cycles regression); falls back to the first-order
    // analytic calibration when no calibration images are requested.
    let cost_model = SmallCnn::load(Path::new(&dir)).ok().map(|m| {
        let hw = serve_hw.clone();
        let mapped = m.map(serve_scheme.as_ref(), &hw);
        let sim_cfg = SimConfig::default();
        let threads = threadpool::default_threads();
        let k = calib_images.min(td.test_x.shape[0]);
        let cm = if k >= 2 {
            let img_len: usize = td.test_x.shape[1..].iter().product();
            let calib_x = Tensor::from_vec(
                &[k, td.test_x.shape[1], td.test_x.shape[2], td.test_x.shape[3]],
                td.test_x.data[..k * img_len].to_vec(),
            );
            let cal = m.calibrate(&mapped, &calib_x, &hw, &sim_cfg, threads);
            println!(
                "[serve] cost model calibrated from {k} exact traces: \
                 dense {:.0} cycles",
                cal.total_cycles_at(0.0),
            );
            CostModel::from_calibration(&cal)
        } else {
            let r = sim::simulate_network(&mapped, &m.spec, &hw, &sim_cfg, threads);
            CostModel::from_sim(
                &r,
                sim_cfg.dead_channel_ratio + sim_cfg.zero_blob_ratio,
            )
        };
        // A multi-core tuned winner pipelines the serving network over
        // its cores: the dispatcher balances/admits on the per-image
        // pipeline throughput cost, not the single-core total.
        if hw.cores > 1 {
            let batch = sim::simulate_network_batch(
                &mapped, &m.spec, &hw, &sim_cfg, 8, threads,
            );
            let ipu = sim::scheme_has_ipu(&serve_scheme_name)
                && sim_cfg.zero_detection;
            let problem = sim::placement::PlacementProblem::from_batch(
                &batch, &m.spec, &hw, &sim_cfg, ipu,
            );
            let plan = sim::placement::plan(&problem);
            let speedup = batch.total_cycles()
                / plan.pipeline_makespan(batch.n_images()).max(1e-12);
            println!(
                "[serve] multi-core placement: {} cores ({}), pipeline \
                 speedup {:.2}x",
                hw.cores, plan.method, speedup,
            );
            cm.with_pipeline_speedup(speedup)
        } else {
            cm
        }
    });
    let factory = EngineFactory::new(format!("{dir}/smallcnn_b8.hlo.txt"));
    let coord = Coordinator::start_pool(
        move |worker| {
            let engine = factory.load().expect("load HLO artifact");
            println!("[serve] worker {worker} engine up on {}", engine.platform());
            PjrtBackend {
                engine,
                batch: 8,
                input_shape: vec![3, 32, 32],
                output_len: 10,
            }
        },
        CoordinatorConfig {
            max_wait: wait,
            default_deadline: if deadline_ms == 0 {
                None
            } else {
                Some(Duration::from_millis(deadline_ms as u64))
            },
            alarm_threshold,
            workers,
            balance,
            max_requeues: args.get_usize("max-requeues").unwrap_or(1) as u32,
            quarantine_expiry: match args
                .get_usize("quarantine-expiry-ms")
                .unwrap_or(0)
            {
                0 => None,
                ms => Some(Duration::from_millis(ms as u64)),
            },
            ..Default::default()
        },
        cost_model,
    );

    let img_len = 3 * 32 * 32;
    let avail = td.test_x.shape[0];
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|i| {
            let img = &td.test_x.data[(i % avail) * img_len..((i % avail) + 1) * img_len];
            coord.submit(img.to_vec())
        })
        .collect();
    let mut correct = 0usize;
    let mut failed = 0usize;
    let mut est_cycles = Vec::new();
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx.recv().expect("reply");
        if let Some(c) = reply.cost {
            est_cycles.push(c.est_cycles);
        }
        match &reply.result {
            Ok(logits) => {
                if sim::smallcnn::argmax(logits) as i32 == td.test_y[i % avail] {
                    correct += 1;
                }
            }
            Err(e) => {
                failed += 1;
                eprintln!("[serve] request {i} failed: {e}");
            }
        }
    }
    let elapsed = t0.elapsed();
    use std::sync::atomic::Ordering::Relaxed;
    let merged = coord.merged_metrics();
    let lat = merged.latency_summary();
    println!(
        "[serve] {} requests in {:?} ({:.0} req/s) on {} worker(s), \
         accuracy {:.1}%, batches {}, mean queue+exec {:.2} ms, p99 {:.2} ms",
        n,
        elapsed,
        n as f64 / elapsed.as_secs_f64(),
        coord.n_workers(),
        100.0 * correct as f64 / n as f64,
        merged.batches.load(Relaxed),
        lat.mean() / 1000.0,
        lat.percentile(99.0) / 1000.0,
    );
    if !est_cycles.is_empty() {
        let mean = est_cycles.iter().sum::<f64>() / est_cycles.len() as f64;
        println!(
            "[serve] per-request cost estimates: mean {:.0} cycles over {} replies",
            mean,
            est_cycles.len()
        );
    }
    println!(
        "[serve] failed {failed} (deadline-expired {}, overload-rejected {}, \
         retried batches {}, cross-worker requeues {}), alarm {}",
        merged.deadline_expired.load(Relaxed),
        merged.rejected_overload.load(Relaxed),
        merged.retried_batches.load(Relaxed),
        merged.requeued_requests.load(Relaxed),
        if merged.failed_alarm() { "TRIPPED" } else { "ok" },
    );
    let stats = coord.worker_stats();
    println!("{}", report::worker_utilization_lines(&stats));
    if args.get_flag("json") {
        let j = report::worker_utilization_json(&stats);
        match report::write_json("serve_workers.json", &j) {
            Ok(()) => println!("wrote results/serve_workers.json"),
            Err(e) => eprintln!("write results/serve_workers.json: {e}"),
        }
    }
    coord.shutdown();
    0
}

/// `rram-accel serve-http` — the production HTTP front door: bind a
/// std-only HTTP/1.1 server (`rram_pattern_accel::serve_http`) over a
/// coordinator pool. `--backend mock` runs the deterministic mock
/// backend so the edge works in builds without the PJRT runtime (CI
/// smoke, load benches); `--backend pjrt` serves the real AOT artifact.
fn cmd_serve_http(rest: Vec<String>) -> i32 {
    let args = match Args::new("HTTP/1.1 front door over the coordinator pool")
        .opt("addr", "127.0.0.1:8080", "bind address (port 0 = ephemeral)")
        .opt("backend", "mock", "inference backend: mock|pjrt")
        .opt("workers", "1", "pool size: worker threads, one backend each")
        .opt("balance", "cost", "dispatch policy: cost|rr")
        .opt("max-wait-ms", "2", "batcher max wait")
        .opt(
            "deadline-ms",
            "0",
            "default deadline for requests without deadline_us (0 = none)",
        )
        .opt("alarm-threshold", "0", "failed-request alarm threshold (0 = off)")
        .opt(
            "max-requeues",
            "1",
            "cross-worker requeues of a failed batch's requests (pools only)",
        )
        .opt(
            "quarantine-expiry-ms",
            "0",
            "quarantine expiry in ms (0 = release on next success only)",
        )
        .opt(
            "max-outstanding-cost",
            "0",
            "overload admission limit in predicted cycles (0 = off; needs a \
             cost model: --mock-cost or --auto-tune)",
        )
        .flag(
            "auto-tune",
            "sweep the design space and build the pool's cost model from the \
             Pareto-frontier winner",
        )
        .opt("tune-grid", "small", "auto-tune sweep grid: small|medium")
        .opt("tune-seed", "42", "auto-tune workload seed (match `dse --seed`)")
        .opt("tune-weights", "1,1,1", "auto-tune weights: area,energy,cycles")
        .flag(
            "tune-exact",
            "auto-tune from exact traces (every position; match `dse --exact`)",
        )
        .opt("mock-input-len", "64", "mock backend: image element count")
        .opt("mock-output-len", "10", "mock backend: logit count")
        .opt("mock-batch", "8", "mock backend: batch capacity")
        .opt("mock-delay-us", "0", "mock backend: per-batch latency in us")
        .opt(
            "mock-cost",
            "0",
            "mock backend: dense cycles per request for the cost model \
             (0 = no cost model unless --auto-tune)",
        )
        .opt("artifacts", "artifacts", "artifacts directory (pjrt backend)")
        .opt("max-body-kib", "4096", "request body cap in KiB (413 beyond)")
        .opt("read-timeout-ms", "5000", "socket read timeout (408 on expiry)")
        .opt("max-connections", "256", "concurrent connection cap (503 beyond)")
        .opt("run-secs", "0", "serve for N seconds then exit (0 = until killed)")
        .parse(rest)
    {
        Ok(a) => a,
        Err(e) => return usage(e),
    };
    let workers = args.get_usize("workers").unwrap_or(1).max(1);
    let balance = match args.get("balance") {
        "cost" => BalancePolicy::CostAware,
        "rr" => BalancePolicy::RoundRobin,
        other => return usage(format!("unknown balance policy {other}")),
    };
    let deadline_ms = args.get_usize("deadline-ms").unwrap_or(0);
    // Always serve with tracing on: the registry's ring buffers are
    // bounded and write-cheap, and `GET /debug/trace` only works when
    // the pool was started with one.
    let trace_registry = obs::Registry::new(
        rram_pattern_accel::util::clock::monotonic(),
        obs::DEFAULT_RING_CAPACITY,
    );
    let cfg = CoordinatorConfig {
        max_wait: Duration::from_millis(
            args.get_usize("max-wait-ms").unwrap_or(2) as u64
        ),
        trace: Some(trace_registry),
        default_deadline: if deadline_ms == 0 {
            None
        } else {
            Some(Duration::from_millis(deadline_ms as u64))
        },
        alarm_threshold: args.get_u64("alarm-threshold").unwrap_or(0),
        workers,
        balance,
        max_requeues: args.get_usize("max-requeues").unwrap_or(1) as u32,
        quarantine_expiry: match args.get_usize("quarantine-expiry-ms").unwrap_or(0)
        {
            0 => None,
            ms => Some(Duration::from_millis(ms as u64)),
        },
        max_outstanding_cost: args.get_f64("max-outstanding-cost").unwrap_or(0.0),
        ..Default::default()
    };

    // Auto-tune: the sweep's frontier winner supplies the dense
    // per-request cost the dispatcher balances/admits on (its slopes
    // are zero — the mock backend has no zero-skip behavior to model).
    let tuned_cost = if args.get_flag("auto-tune") {
        let obj = match Objective::parse(args.get("tune-weights")) {
            Ok(o) => o,
            Err(e) => return usage(e),
        };
        let tune_seed = args.get_u64("tune-seed").unwrap_or(42);
        let mut spec = match SweepSpec::by_name(args.get("tune-grid"), tune_seed) {
            Some(s) => s,
            None => {
                return usage(format!("unknown tune grid {}", args.get("tune-grid")))
            }
        };
        if args.get_flag("tune-exact") {
            spec.workload.exact = true;
        }
        let outcome = SweepRunner {
            spec,
            threads: threadpool::default_threads(),
            cache: Some(ResultCache::default_dir()),
        }
        .run();
        println!("[serve-http] auto-tune: {}", outcome.summary_line());
        match outcome.select(&obj) {
            Some(t) => {
                println!(
                    "[serve-http] auto-tune selected {} — cycles {:.0}, \
                     energy {:.4e} pJ",
                    t.point.label(),
                    t.metrics.cycles,
                    t.metrics.energy_pj,
                );
                Some(CostModel {
                    dense_cycles: t.metrics.cycles,
                    dense_energy_pj: t.metrics.energy_pj,
                    skip_slope: 0.0,
                    energy_skip_slope: 0.0,
                })
            }
            None => {
                return usage("auto-tune produced an empty frontier".to_string())
            }
        }
    } else {
        None
    };

    let (coord, input_len) = match args.get("backend") {
        "mock" => {
            let input_len = args.get_usize("mock-input-len").unwrap_or(64);
            let output_len = args.get_usize("mock-output-len").unwrap_or(10);
            let batch = args.get_usize("mock-batch").unwrap_or(8).max(1);
            let delay = Duration::from_micros(
                args.get_u64("mock-delay-us").unwrap_or(0),
            );
            let mock_cost = args.get_f64("mock-cost").unwrap_or(0.0);
            let cost_model = tuned_cost.or(if mock_cost > 0.0 {
                Some(CostModel {
                    dense_cycles: mock_cost,
                    dense_energy_pj: mock_cost,
                    skip_slope: 0.0,
                    energy_skip_slope: 0.0,
                })
            } else {
                None
            });
            let coord = Coordinator::start_pool(
                move |_worker| MockInferBackend {
                    input_len,
                    output_len,
                    batch,
                    delay,
                    fail: false,
                },
                cfg,
                cost_model,
            );
            (coord, input_len)
        }
        "pjrt" => {
            if !Engine::available() {
                return usage(
                    "PJRT runtime unavailable: rebuild with --features \
                     xla-runtime, or use --backend mock"
                        .to_string(),
                );
            }
            let dir = args.get("artifacts").to_string();
            let factory = EngineFactory::new(format!("{dir}/smallcnn_b8.hlo.txt"));
            let coord = Coordinator::start_pool(
                move |worker| {
                    let engine = factory.load().expect("load HLO artifact");
                    println!(
                        "[serve-http] worker {worker} engine up on {}",
                        engine.platform()
                    );
                    PjrtBackend {
                        engine,
                        batch: 8,
                        input_shape: vec![3, 32, 32],
                        output_len: 10,
                    }
                },
                cfg,
                tuned_cost,
            );
            (coord, 3 * 32 * 32)
        }
        other => return usage(format!("unknown backend {other}")),
    };

    let http_cfg = HttpConfig {
        addr: args.get("addr").to_string(),
        max_body_bytes: args.get_usize("max-body-kib").unwrap_or(4096) * 1024,
        read_timeout: Duration::from_millis(
            args.get_u64("read-timeout-ms").unwrap_or(5000),
        ),
        max_connections: args.get_usize("max-connections").unwrap_or(256).max(1),
        input_len,
        default_deadline: if deadline_ms == 0 {
            None
        } else {
            Some(Duration::from_millis(deadline_ms as u64))
        },
    };
    let server = match HttpServer::start(coord, http_cfg) {
        Ok(s) => s,
        Err(e) => return usage(format!("bind {}: {e}", args.get("addr"))),
    };
    println!(
        "[serve-http] listening on {} ({} worker(s), backend {})",
        server.addr(),
        workers,
        args.get("backend"),
    );
    let run_secs = args.get_u64("run-secs").unwrap_or(0);
    if run_secs > 0 {
        std::thread::sleep(Duration::from_secs(run_secs));
        let stats = server.http_stats();
        println!(
            "[serve-http] exiting after {run_secs}s: {} connections, \
             {} requests ({} bad, {} handler panics)",
            stats.connections,
            stats.requests,
            stats.bad_requests,
            stats.handler_panics,
        );
        server.shutdown();
        return 0;
    }
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

/// `rram-accel trace` — drive a traced mock-pool session and export the
/// collected spans as Chrome trace-event JSON, loadable in Perfetto or
/// chrome://tracing. This is the offline counterpart of the live
/// `GET /debug/trace` endpoint: same span schema, same exporter, no
/// server required.
fn cmd_trace(rest: Vec<String>) -> i32 {
    let args = match Args::new(
        "run a traced mock-pool session and export Chrome trace-event JSON",
    )
    .opt("requests", "16", "demo requests to trace")
    .opt("workers", "2", "pool size: worker threads, one backend each")
    .opt("input-len", "64", "mock backend: image element count")
    .opt("mock-delay-us", "50", "mock backend: per-batch latency in us")
    .opt("out", "trace.json", "artifact name under results/")
    .parse(rest)
    {
        Ok(a) => a,
        Err(e) => return usage(e),
    };
    let n = args.get_usize("requests").unwrap_or(16).max(1);
    let workers = args.get_usize("workers").unwrap_or(2).max(1);
    let input_len = args.get_usize("input-len").unwrap_or(64).max(1);
    let delay =
        Duration::from_micros(args.get_u64("mock-delay-us").unwrap_or(50));

    let registry = obs::Registry::new(
        rram_pattern_accel::util::clock::monotonic(),
        obs::DEFAULT_RING_CAPACITY,
    );
    let coord = Coordinator::start_pool(
        move |_worker| MockInferBackend {
            input_len,
            output_len: 10,
            batch: 8,
            delay,
            fail: false,
        },
        CoordinatorConfig {
            workers,
            trace: Some(registry.clone()),
            ..Default::default()
        },
        None,
    );
    let rxs: Vec<_> = (0..n)
        .map(|i| coord.submit(vec![(i % 7) as f32; input_len]))
        .collect();
    let mut failed = 0usize;
    for rx in rxs {
        match rx.recv() {
            Ok(reply) if reply.result.is_ok() => {}
            _ => failed += 1,
        }
    }
    coord.shutdown();

    let spans = registry.snapshot();
    let j = obs::chrome_trace_json(&spans);
    let out = args.get("out").to_string();
    println!(
        "[trace] {} requests ({failed} failed) on {workers} worker(s): \
         {} spans across {} ring buffer(s)",
        n,
        spans.len(),
        registry.buffers().len(),
    );
    match report::write_json(&out, &j) {
        Ok(()) => {
            println!("wrote results/{out}");
            0
        }
        Err(e) => {
            eprintln!("write results/{out}: {e}");
            1
        }
    }
}

fn cmd_e2e(rest: Vec<String>) -> i32 {
    let args = match Args::new("end-to-end SmallCNN check (golden + accuracy)")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("images", "64", "test images for accuracy")
        .parse(rest)
    {
        Ok(a) => a,
        Err(e) => return usage(e),
    };
    let dir = Path::new(args.get("artifacts"));
    match run_e2e(dir, args.get_usize("images").unwrap_or(64)) {
        Ok(()) => 0,
        Err(e) => usage(e),
    }
}

fn run_e2e(dir: &Path, n_images: usize) -> Result<(), String> {
    let model = SmallCnn::load(dir)?;
    let td = sim::smallcnn::TestData::load(dir)?;
    let hw = HardwareConfig::smallcnn_functional();

    // 1. PJRT execution matches the python golden logits.
    let engine = Engine::load(&dir.join("smallcnn_b1.hlo.txt"))
        .map_err(|e| e.to_string())?;
    let n_golden = td.golden_x.shape[0];
    let mut max_err = 0.0f32;
    for i in 0..n_golden {
        let img = sim::smallcnn::image(&td.golden_x, i);
        let out = engine
            .run_f32(&[(&[1usize, 3, 32, 32], &img.data)])
            .map_err(|e| e.to_string())?;
        for (o, g) in out.iter().zip(
            td.golden_logits.data[i * 10..(i + 1) * 10].iter(),
        ) {
            max_err = max_err.max((o - g).abs());
        }
    }
    println!("[e2e] PJRT vs python golden logits: max |err| = {max_err:.2e}");
    if max_err > 1e-3 {
        return Err("golden check failed".to_string());
    }

    // 2. Rust functional simulator accuracy on test images.
    let mapped = model.map(&PatternMapping, &hw);
    mapped.validate().map_err(|e| e.to_string())?;
    let n = n_images.min(td.test_x.shape[0]);
    let mut correct = 0usize;
    for i in 0..n {
        let img = sim::smallcnn::image(&td.test_x, i);
        let logits = model.forward(&mapped, &img, &hw, true);
        if sim::smallcnn::argmax(&logits) as i32 == td.test_y[i] {
            correct += 1;
        }
    }
    let acc = correct as f64 / n as f64;
    let meta_acc = model.meta.get("accuracy").get("crossbar").as_f64().unwrap_or(0.0);
    println!(
        "[e2e] mapped-crossbar simulator accuracy: {:.1}% on {} images \
         (python crossbar-mode: {:.1}%)",
        acc * 100.0,
        n,
        meta_acc * 100.0
    );
    Ok(())
}

fn cmd_report(rest: Vec<String>) -> i32 {
    let args = match Args::new("print every paper table & figure (sampled mode)")
        .opt("seed", "42", "synthetic weight seed")
        .opt("samples", "64", "sampled positions per layer")
        .opt("threads", "0", "worker threads (0 = auto)")
        .parse(rest)
    {
        Ok(a) => a,
        Err(e) => return usage(e),
    };
    let threads = auto_threads(&args);
    let seed = args.get_u64("seed").unwrap_or(42);
    let samples = args.get_usize("samples").unwrap_or(64).max(1);

    println!("{}", report::table1(&HardwareConfig::default()));
    let cfg = ArtifactConfig { seed, mode: TraceMode::Sampled(samples), threads };
    for profile in ALL_PROFILES {
        let rows = artifacts::compute_dataset_rows(profile, &cfg);
        println!("{}", rows.table2.line());
        println!("{}", rows.fig7.line());
        println!("{}", rows.fig8.lines());
        println!(
            "{}",
            report::speedup_line(
                profile.name,
                &rows.comparison,
                rows.table2.paper_speedup
            )
        );
        println!();
    }
    0
}

fn cmd_artifacts(rest: Vec<String>) -> i32 {
    let args = match Args::new(
        "run every paper figure in sampled AND exact trace mode and emit \
         the versioned artifacts + sampled-vs-exact delta report",
    )
    .opt("datasets", "all", "all, or a comma list of cifar10|cifar100|imagenet")
    .opt("seed", "42", "synthetic weight seed")
    .opt("samples", "64", "sampled positions per layer (sampled mode)")
    .opt(
        "threads",
        "0",
        "worker threads (0 = auto; artifacts are thread-invariant)",
    )
    .opt("out-dir", "paper", "output directory under results/")
    .opt("cache-dir", "results/paper_cache", "on-disk artifact cache directory")
    .flag("no-cache", "compute every dataset fresh")
    .parse(rest)
    {
        Ok(a) => a,
        Err(e) => return usage(e),
    };
    let profiles: Vec<&DatasetProfile> = if args.get("datasets") == "all" {
        ALL_PROFILES.to_vec()
    } else {
        let mut v = Vec::new();
        for name in args.get("datasets").split(',') {
            match DatasetProfile::by_name(name.trim()) {
                Some(p) => v.push(p),
                None => {
                    return usage(format!("unknown dataset {}", name.trim()))
                }
            }
        }
        v
    };
    if profiles.is_empty() {
        return usage("no datasets selected".to_string());
    }
    let seed = args.get_u64("seed").unwrap_or(42);
    let samples = args.get_usize("samples").unwrap_or(64).max(1);
    let threads = auto_threads(&args);
    let cache = if args.get_flag("no-cache") {
        None
    } else {
        Some(ArtifactCache::new(args.get("cache-dir").to_string()))
    };
    let out_dir = args.get("out-dir").to_string();

    // The artifacts are the command's contract: a failed write or an
    // out-of-band delta is a failed run, not a warning.
    let mut exit = 0;
    let mut runs: Vec<PaperArtifacts> = Vec::with_capacity(2);
    for mode in [TraceMode::Sampled(samples), TraceMode::Exact] {
        let cfg = ArtifactConfig { seed, mode, threads };
        let arts = PaperArtifacts::generate(&profiles, &cfg, cache.as_ref());
        println!(
            "[artifacts] {} mode: {} datasets ({} from cache)",
            mode.name(),
            arts.datasets.len(),
            arts.cache_hits,
        );
        for d in &arts.datasets {
            println!(
                "  {:<10} area {:.2}x  energy {:.2}x  speedup {:.2}x",
                d.dataset,
                d.metric("fig7", "area_efficiency").unwrap_or(0.0),
                d.metric("fig8", "energy_efficiency").unwrap_or(0.0),
                d.metric("table2", "speedup").unwrap_or(0.0),
            );
        }
        match arts.write(&out_dir) {
            Ok(files) => {
                for f in files {
                    println!("wrote results/{f}");
                }
            }
            Err(e) => {
                exit = 1;
                eprintln!("artifacts: write failed: {e}");
            }
        }
        runs.push(arts);
    }
    let exact = runs.pop().expect("exact run");
    let sampled = runs.pop().expect("sampled run");
    match artifacts::delta_report(&sampled, &exact, &DeltaTolerances::default()) {
        Ok(rep) => {
            print!("{}", rep.lines());
            let name = format!("{out_dir}/delta_report.json");
            match report::write_json(&name, &rep.to_json()) {
                Ok(()) => println!("wrote results/{name}"),
                Err(e) => {
                    exit = 1;
                    eprintln!("artifacts: write results/{name}: {e}");
                }
            }
            if !rep.all_within() {
                exit = 1;
                eprintln!(
                    "artifacts: sampled-vs-exact deltas out of tolerance \
                     (see report above)"
                );
            }
        }
        Err(e) => {
            exit = 1;
            eprintln!("artifacts: delta report failed: {e}");
        }
    }
    exit
}

fn auto_threads(args: &Args) -> usize {
    match args.get_usize("threads") {
        Ok(0) | Err(_) => threadpool::default_threads(),
        Ok(n) => n,
    }
}

fn usage(e: String) -> i32 {
    eprintln!("{e}");
    2
}

/// `rram-accel lint` — the in-tree determinism/concurrency pass (see
/// `rram_pattern_accel::analysis` for the rule specifications).
///
/// Exit codes: 0 = clean, 1 = findings (errors, or warnings under
/// `--deny-warnings`), 2 = internal error (unreadable path, bad usage,
/// failed report write).
fn cmd_lint(rest: Vec<String>) -> i32 {
    let mut about = String::from(
        "determinism & concurrency static analysis over the crate sources\n\
         \n\
         scans rust/, tests/, benches/ under the current directory by\n\
         default (fixture corpus excluded); positional paths restrict\n\
         the scan to explicit files or directories.\n\
         \n\
         rules:\n",
    );
    for rule in analysis::RULES {
        about.push_str(&format!(
            "  {:<38} {:<8} {}\n",
            rule.id,
            rule.severity.name(),
            rule.summary
        ));
    }
    about.push_str(
        "\nsuppress with `// lint:allow(<rule-id>[, ...])` on the finding's\n\
         line or the line directly above it",
    );
    let args = match Args::new(&about)
        .flag("json", "print the full report as JSON on stdout")
        .flag("deny-warnings", "exit 1 on warning findings, not just errors")
        .opt("out", "lint_report.json", "report artifact path under results/")
        .parse(rest)
    {
        Ok(a) => a,
        Err(e) => return usage(e),
    };

    let scan = if args.positional().is_empty() {
        analysis::lint_tree(Path::new("."))
    } else {
        let roots: Vec<std::path::PathBuf> =
            args.positional().iter().map(std::path::PathBuf::from).collect();
        analysis::lint_roots(&roots)
    };
    let lint_report = match scan {
        Ok(r) => r,
        Err(e) => {
            eprintln!("lint: {e}");
            return 2;
        }
    };

    if args.get_flag("json") {
        println!("{}", lint_report.to_json().to_string_pretty());
    } else {
        print!("{}", lint_report.lines());
        println!("{}", lint_report.summary_line());
    }
    if let Err(e) = report::write_json(args.get("out"), &lint_report.to_json()) {
        eprintln!("lint: write results/{}: {e}", args.get("out"));
        return 2;
    }

    let deny = args.get_flag("deny-warnings");
    if lint_report.errors() > 0 || (deny && lint_report.warnings() > 0) {
        1
    } else {
        0
    }
}
