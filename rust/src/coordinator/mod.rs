//! L3 serving coordinator: request router + dynamic batcher.
//!
//! Requests are submitted from any thread; a worker thread collects them
//! into fixed-size batches (padding the tail), executes the AOT-compiled
//! functional model through [`crate::runtime::Engine`], and routes each
//! logit vector back to its requester. std::thread + mpsc throughout
//! (no async runtime exists in this offline image — and the paper's
//! contribution is the accelerator, so L3 stays a thin driver per the
//! architecture note in DESIGN.md §2).
//!
//! Serving policy (ISSUE-2 hardening):
//!
//! - **Cost estimates** — with a [`CostModel`] attached, every [`Reply`]
//!   carries a cheap trace-derived per-request cost estimate (cycles +
//!   energy from the request's own input zero fraction).
//! - **Deadlines** — [`Coordinator::submit_with_deadline`] requests are
//!   dispatched no later than their deadline (a near-deadline request
//!   fires its batch early, padded); a request whose deadline already
//!   passed while queued gets a timely deadline-exceeded error `Reply`
//!   instead of a stale result.
//! - **Retry** — a failed batch is re-run up to
//!   [`CoordinatorConfig::max_retries`] times before the backend error
//!   is delivered to every requester.
//! - **Alarm** — [`Metrics::failed_alarm`] trips once
//!   [`Metrics::failed_requests`] reaches the configured threshold.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::sim::NetworkSimResult;
use crate::util::stats::Summary;

/// Inference backend abstraction — the PJRT engine in production, mocks
/// in tests. Backends are constructed *inside* the worker thread (the
/// PJRT client is not `Send`), so the trait itself needs no `Send`.
pub trait InferBackend: 'static {
    /// Input element count per request (e.g. 3*32*32).
    fn input_len(&self) -> usize;
    /// Output element count per request (e.g. 10 logits).
    fn output_len(&self) -> usize;
    /// Batch capacity of the compiled executable.
    fn batch_size(&self) -> usize;
    /// Run a full batch (`batch_size * input_len` floats, zero-padded);
    /// returns `batch_size * output_len` floats.
    fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String>;
}

/// PJRT-backed backend for the SmallCNN artifact.
pub struct PjrtBackend {
    pub engine: crate::runtime::Engine,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub output_len: usize,
}

impl InferBackend for PjrtBackend {
    fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    fn output_len(&self) -> usize {
        self.output_len
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
        let mut shape = vec![self.batch];
        shape.extend_from_slice(&self.input_shape);
        self.engine
            .run_f32(&[(&shape, batch)])
            .map_err(|e| e.to_string())
    }
}

/// Cheap per-request cost estimate, attached to every [`Reply`] when
/// the coordinator runs with a [`CostModel`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostEstimate {
    pub est_cycles: f64,
    pub est_energy_pj: f64,
    /// Zero fraction of the submitted image the estimate derives from.
    pub input_zero_fraction: f64,
}

/// Trace-derived first-order request cost model: the dense (no-skip)
/// per-image cost, discounted by the request's own input zero fraction
/// times a skip slope calibrated from a traced simulation. Cheap enough
/// for the submit path — one pass over the image, two multiplies.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Cycles of the full (no skipping) schedule for one image.
    pub dense_cycles: f64,
    /// Energy (pJ) of the full schedule for one image.
    pub dense_energy_pj: f64,
    /// d(skipped work fraction) / d(input zero fraction), first order.
    pub skip_slope: f64,
}

impl CostModel {
    /// Calibrate from a simulated run with zero detection on:
    /// `calib_zero_fraction` is the zero fraction of the calibration
    /// trace the run was costed against (e.g. the synthetic trace's
    /// dead-channel + zero-blob share).
    pub fn from_sim(r: &NetworkSimResult, calib_zero_fraction: f64) -> CostModel {
        let executed = r.total_ou_ops();
        let skipped: f64 = r.layers.iter().map(|l| l.skipped_ou_ops).sum();
        let dense_ops = (executed + skipped).max(1.0);
        // scale the observed (post-skip) cycles/energy back up to the
        // dense schedule
        let dense_scale = dense_ops / executed.max(1.0);
        let skip_frac = skipped / dense_ops;
        CostModel {
            dense_cycles: r.total_cycles() * dense_scale,
            dense_energy_pj: r.total_energy().total_pj() * dense_scale,
            skip_slope: if calib_zero_fraction > 1e-9 {
                skip_frac / calib_zero_fraction
            } else {
                0.0
            },
        }
    }

    /// Estimate the cost of serving `image` (kept work is clamped to
    /// `[0, 1]` of the dense schedule).
    pub fn estimate(&self, image: &[f32]) -> CostEstimate {
        let zeros = image.iter().filter(|v| **v == 0.0).count();
        let zf = zeros as f64 / image.len().max(1) as f64;
        let keep = (1.0 - self.skip_slope * zf).clamp(0.0, 1.0);
        CostEstimate {
            est_cycles: self.dense_cycles * keep,
            est_energy_pj: self.dense_energy_pj * keep,
            input_zero_fraction: zf,
        }
    }
}

/// Batching / retry / deadline policy for a [`Coordinator`].
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// How long a partial batch waits for more requests before
    /// executing padded.
    pub max_wait: Duration,
    /// Re-runs of a failed batch before the error is delivered
    /// (ISSUE-2 default: one retry).
    pub max_retries: u32,
    /// Deadline attached to plain [`Coordinator::submit`] requests
    /// (`None` = no deadline).
    pub default_deadline: Option<Duration>,
    /// Failed-request count at which [`Metrics::failed_alarm`] trips
    /// (0 disables the alarm).
    pub alarm_threshold: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_wait: Duration::from_millis(2),
            max_retries: 1,
            default_deadline: None,
            alarm_threshold: 0,
        }
    }
}

/// One inference request.
struct Request {
    image: Vec<f32>,
    submitted: Instant,
    /// Latest instant at which the request may still be dispatched.
    deadline: Option<Instant>,
    reply: Sender<Reply>,
}

/// Reply with the batch outcome + timing. `result` carries the logits
/// on success, or the error on failure (backend error after retries, or
/// deadline exceeded) — a failed request is reported to its requester
/// instead of silently dropping the reply channel.
#[derive(Debug, Clone)]
pub struct Reply {
    pub result: Result<Vec<f32>, String>,
    pub queue_us: u64,
    pub batch_fill: usize,
    /// Trace-derived cost estimate (present when the coordinator was
    /// started with a [`CostModel`]).
    pub cost: Option<CostEstimate>,
}

impl Reply {
    /// Logits of a successful reply. Panics on a failed batch — a
    /// convenience for demos and tests; production callers match on
    /// [`Reply::result`].
    pub fn logits(&self) -> &[f32] {
        self.result.as_ref().expect("inference batch failed")
    }
}

/// Aggregate serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests that received a terminal reply — successes *and*
    /// failures — so `failed_requests / requests` is a coherent failure
    /// rate.
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    /// Requests that failed — backend error after retries, or deadline
    /// exceeded (each received the error through its [`Reply::result`]).
    pub failed_requests: AtomicU64,
    /// Batch re-runs after a backend failure.
    pub retried_batches: AtomicU64,
    /// Requests whose deadline passed while queued (also counted in
    /// `failed_requests`).
    pub deadline_expired: AtomicU64,
    /// Failed-request alarm threshold (0 = disabled).
    alarm_threshold: AtomicU64,
    alarm_logged: AtomicBool,
    latencies_us: Mutex<Summary>,
}

impl Metrics {
    pub fn latency_summary(&self) -> Summary {
        self.latencies_us.lock().unwrap().clone()
    }

    pub fn set_alarm_threshold(&self, n: u64) {
        self.alarm_threshold.store(n, Ordering::Relaxed);
    }

    pub fn alarm_threshold(&self) -> u64 {
        self.alarm_threshold.load(Ordering::Relaxed)
    }

    /// Has the failed-request count reached the alarm threshold?
    pub fn failed_alarm(&self) -> bool {
        let t = self.alarm_threshold.load(Ordering::Relaxed);
        t > 0 && self.failed_requests.load(Ordering::Relaxed) >= t
    }

    /// Count one terminally-failed request (in both `requests` and
    /// `failed_requests`) and raise (and log, once) the alarm if the
    /// threshold is crossed.
    fn record_failed(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.failed_requests.fetch_add(1, Ordering::Relaxed);
        if self.failed_alarm() && !self.alarm_logged.swap(true, Ordering::Relaxed) {
            eprintln!(
                "[coordinator] ALARM: failed requests reached threshold {}",
                self.alarm_threshold()
            );
        }
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Option<Sender<Request>>,
    pub metrics: Arc<Metrics>,
    default_deadline: Option<Duration>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the batching worker with the default retry/deadline policy.
    /// The backend is built by `make_backend` *inside* the worker thread
    /// (the PJRT client is not `Send`). `max_wait` bounds how long a
    /// partial batch waits for more requests before executing padded.
    pub fn start<B, F>(make_backend: F, max_wait: Duration) -> Coordinator
    where
        B: InferBackend,
        F: FnOnce() -> B + Send + 'static,
    {
        Self::start_with(
            make_backend,
            CoordinatorConfig { max_wait, ..Default::default() },
            None,
        )
    }

    /// Start with a full [`CoordinatorConfig`] and an optional
    /// [`CostModel`]; with a model, every reply carries a per-request
    /// cost estimate.
    pub fn start_with<B, F>(
        make_backend: F,
        cfg: CoordinatorConfig,
        cost_model: Option<CostModel>,
    ) -> Coordinator
    where
        B: InferBackend,
        F: FnOnce() -> B + Send + 'static,
    {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        metrics.set_alarm_threshold(cfg.alarm_threshold);
        let m = metrics.clone();
        let default_deadline = cfg.default_deadline;
        let worker = std::thread::spawn(move || {
            let backend = make_backend();
            batch_loop(backend, rx, cfg, cost_model, m)
        });
        Coordinator {
            tx: Some(tx),
            metrics,
            default_deadline,
            worker: Some(worker),
        }
    }

    /// Submit one image; returns the channel the reply arrives on.
    pub fn submit(&self, image: Vec<f32>) -> Receiver<Reply> {
        self.submit_inner(image, self.default_deadline)
    }

    /// Submit with an explicit completion deadline: the batcher
    /// dispatches the request no later than `deadline` from now (firing
    /// a partial batch early if needed), and a request that is already
    /// overdue when considered gets a deadline-exceeded error instead
    /// of a stale result.
    pub fn submit_with_deadline(
        &self,
        image: Vec<f32>,
        deadline: Duration,
    ) -> Receiver<Reply> {
        self.submit_inner(image, Some(deadline))
    }

    fn submit_inner(
        &self,
        image: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Receiver<Reply> {
        let (rtx, rrx) = channel();
        let now = Instant::now();
        let req = Request {
            image,
            submitted: now,
            deadline: deadline.map(|d| now + d),
            reply: rtx,
        };
        // A send failure means the worker exited; the caller sees it as
        // a closed reply channel.
        if let Some(tx) = &self.tx {
            let _ = tx.send(req);
        }
        rrx
    }

    /// Stop the worker (drains in-flight requests first).
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// If `r`'s deadline has already passed, deliver the deadline-exceeded
/// error (with its cost estimate) and consume it; otherwise hand the
/// request back for batching.
fn admit(
    r: Request,
    cost_model: Option<&CostModel>,
    metrics: &Metrics,
) -> Option<Request> {
    match r.deadline {
        Some(d) if Instant::now() >= d => {
            let queue_us = r.submitted.elapsed().as_micros() as u64;
            metrics.deadline_expired.fetch_add(1, Ordering::Relaxed);
            metrics.record_failed();
            let cost = cost_model.map(|m| m.estimate(&r.image));
            let _ = r.reply.send(Reply {
                result: Err(format!(
                    "deadline exceeded: request spent {queue_us} us queued"
                )),
                queue_us,
                batch_fill: 0,
                cost,
            });
            None
        }
        _ => Some(r),
    }
}

fn batch_loop<B: InferBackend>(
    backend: B,
    rx: Receiver<Request>,
    cfg: CoordinatorConfig,
    cost_model: Option<CostModel>,
    metrics: Arc<Metrics>,
) {
    let bs = backend.batch_size();
    let in_len = backend.input_len();
    let out_len = backend.output_len();

    loop {
        // Block for the first request of a batch; a request that sat in
        // a backed-up queue past its deadline is rejected right here.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders dropped
        };
        let mut pending: Vec<Request> =
            admit(first, cost_model.as_ref(), &metrics)
                .into_iter()
                .collect();
        let fill_deadline = Instant::now() + cfg.max_wait;
        // Fill until full, the batcher wait elapses, or the earliest
        // pending request deadline arrives — a near-deadline request
        // fires its batch early (padded) rather than waiting it out.
        while pending.len() < bs {
            let now = Instant::now();
            let mut until = fill_deadline;
            for r in &pending {
                if let Some(d) = r.deadline {
                    until = until.min(d);
                }
            }
            if now >= until {
                break;
            }
            match rx.recv_timeout(until - now) {
                Ok(r) => {
                    if let Some(r) = admit(r, cost_model.as_ref(), &metrics) {
                        pending.push(r);
                    }
                }
                Err(_) => break, // timeout or disconnect: run what we have
            }
        }
        if pending.is_empty() {
            continue;
        }

        // Assemble padded batch.
        let mut batch = vec![0.0f32; bs * in_len];
        for (i, r) in pending.iter().enumerate() {
            debug_assert_eq!(r.image.len(), in_len);
            batch[i * in_len..(i + 1) * in_len].copy_from_slice(&r.image);
        }
        let fill = pending.len();
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics
            .padded_slots
            .fetch_add((bs - fill) as u64, Ordering::Relaxed);

        // Execute; a failed batch is re-run up to `max_retries` times
        // before the error is delivered to every requester.
        let mut outcome = backend.run_batch(&batch);
        let mut attempts = 0u32;
        while outcome.is_err() && attempts < cfg.max_retries {
            attempts += 1;
            metrics.retried_batches.fetch_add(1, Ordering::Relaxed);
            eprintln!(
                "[coordinator] batch failed ({}); retry {attempts}/{}",
                outcome.as_ref().err().map(String::as_str).unwrap_or(""),
                cfg.max_retries
            );
            outcome = backend.run_batch(&batch);
        }

        match outcome {
            Ok(out) => {
                for (i, r) in pending.into_iter().enumerate() {
                    let logits = out[i * out_len..(i + 1) * out_len].to_vec();
                    let queue_us = r.submitted.elapsed().as_micros() as u64;
                    metrics.requests.fetch_add(1, Ordering::Relaxed);
                    metrics
                        .latencies_us
                        .lock()
                        .unwrap()
                        .push(queue_us as f64);
                    let cost = cost_model.as_ref().map(|m| m.estimate(&r.image));
                    let _ = r.reply.send(Reply {
                        result: Ok(logits),
                        queue_us,
                        batch_fill: fill,
                        cost,
                    });
                }
            }
            Err(e) => {
                // Deliver the cause to every waiting requester — a
                // dropped sender would only show them an opaque closed
                // channel.
                eprintln!(
                    "[coordinator] batch failed after {} attempt(s): {e}",
                    attempts + 1
                );
                for r in pending.into_iter() {
                    let queue_us = r.submitted.elapsed().as_micros() as u64;
                    metrics.record_failed();
                    let cost = cost_model.as_ref().map(|m| m.estimate(&r.image));
                    let _ = r.reply.send(Reply {
                        result: Err(e.clone()),
                        queue_us,
                        batch_fill: fill,
                        cost,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Identity-ish mock: logit k = sum(image) + k.
    struct MockBackend {
        in_len: usize,
        out_len: usize,
        batch: usize,
        calls: Arc<AtomicU64>,
    }

    impl InferBackend for MockBackend {
        fn input_len(&self) -> usize {
            self.in_len
        }
        fn output_len(&self) -> usize {
            self.out_len
        }
        fn batch_size(&self) -> usize {
            self.batch
        }
        fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            assert_eq!(batch.len(), self.batch * self.in_len);
            let mut out = Vec::with_capacity(self.batch * self.out_len);
            for i in 0..self.batch {
                let s: f32 = batch[i * self.in_len..(i + 1) * self.in_len]
                    .iter()
                    .sum();
                for k in 0..self.out_len {
                    out.push(s + k as f32);
                }
            }
            Ok(out)
        }
    }

    fn mock(batch: usize, calls: Arc<AtomicU64>) -> MockBackend {
        MockBackend { in_len: 4, out_len: 3, batch, calls }
    }

    #[test]
    fn single_request_roundtrip() {
        let calls = Arc::new(AtomicU64::new(0));
        let calls2 = calls.clone();
        let c = Coordinator::start(move || mock(4, calls2), Duration::from_millis(5));
        let rx = c.submit(vec![1.0, 2.0, 3.0, 4.0]);
        let reply = rx.recv().unwrap();
        assert_eq!(reply.logits(), &[10.0, 11.0, 12.0][..]);
        assert_eq!(reply.batch_fill, 1);
        c.shutdown();
        assert_eq!(calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn batching_coalesces_requests() {
        let calls = Arc::new(AtomicU64::new(0));
        let calls2 = calls.clone();
        let c = Coordinator::start(move || mock(4, calls2), Duration::from_millis(200));
        let rxs: Vec<_> = (0..4)
            .map(|i| c.submit(vec![i as f32; 4]))
            .collect();
        let replies: Vec<Reply> = rxs.iter().map(|r| r.recv().unwrap()).collect();
        for (i, rep) in replies.iter().enumerate() {
            assert_eq!(rep.logits()[0], 4.0 * i as f32);
            assert_eq!(rep.batch_fill, 4);
        }
        c.shutdown();
        // all four requests fit one batch
        assert_eq!(calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn partial_batch_fires_on_timeout() {
        let calls = Arc::new(AtomicU64::new(0));
        let calls2 = calls.clone();
        let c = Coordinator::start(move || mock(8, calls2), Duration::from_millis(10));
        let rx = c.submit(vec![0.5; 4]);
        let reply = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(reply.batch_fill, 1);
        c.shutdown();
        let m = calls.load(Ordering::Relaxed);
        assert_eq!(m, 1);
    }

    /// Backend that always fails; its error must reach every requester.
    struct FailingBackend;

    impl InferBackend for FailingBackend {
        fn input_len(&self) -> usize {
            2
        }
        fn output_len(&self) -> usize {
            1
        }
        fn batch_size(&self) -> usize {
            2
        }
        fn run_batch(&self, _batch: &[f32]) -> Result<Vec<f32>, String> {
            Err("backend exploded".to_string())
        }
    }

    #[test]
    fn failed_batch_reports_error_to_requesters() {
        let c = Coordinator::start(|| FailingBackend, Duration::from_millis(5));
        let rx1 = c.submit(vec![1.0, 2.0]);
        let rx2 = c.submit(vec![3.0, 4.0]);
        for rx in [rx1, rx2] {
            let reply = rx
                .recv_timeout(Duration::from_secs(5))
                .expect("reply must be delivered, not dropped");
            let err = reply.result.expect_err("must carry the backend error");
            assert!(err.contains("backend exploded"), "{err}");
        }
        assert_eq!(c.metrics.failed_requests.load(Ordering::Relaxed), 2);
        // failures still count as terminally-replied requests, so the
        // failure rate failed/requests stays coherent (2/2 here)
        assert_eq!(c.metrics.requests.load(Ordering::Relaxed), 2);
        c.shutdown();
    }

    #[test]
    fn metrics_track_requests_and_padding() {
        let calls = Arc::new(AtomicU64::new(0));
        let c = Coordinator::start(move || mock(4, calls), Duration::from_millis(10));
        for _ in 0..2 {
            let rx = c.submit(vec![0.0; 4]);
            rx.recv().unwrap();
        }
        let reqs = c.metrics.requests.load(Ordering::Relaxed);
        let pads = c.metrics.padded_slots.load(Ordering::Relaxed);
        assert_eq!(reqs, 2);
        assert!(pads >= 4, "pads={pads}"); // two batches of fill 1
        assert!(c.metrics.latency_summary().len() == 2);
        c.shutdown();
    }

    #[test]
    fn cost_model_estimates_scale_with_input_zeros() {
        let m = CostModel {
            dense_cycles: 1000.0,
            dense_energy_pj: 400.0,
            skip_slope: 1.0,
        };
        let dense = m.estimate(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(dense.input_zero_fraction, 0.0);
        assert_eq!(dense.est_cycles, 1000.0);
        let half = m.estimate(&[0.0, 0.0, 3.0, 4.0]);
        assert!((half.input_zero_fraction - 0.5).abs() < 1e-12);
        assert!((half.est_cycles - 500.0).abs() < 1e-9);
        assert!(half.est_energy_pj < dense.est_energy_pj);
        // kept work clamps at zero even for an extreme slope
        let all = m.estimate(&[0.0; 4]);
        assert_eq!(all.est_cycles, 0.0);
    }

    #[test]
    fn cost_model_from_sim_restores_dense_schedule() {
        use crate::sim::{LayerSimResult, NetworkSimResult};
        use crate::xbar::energy::EnergyLedger;
        let r = NetworkSimResult {
            scheme: "pattern".into(),
            network: "t".into(),
            layers: vec![LayerSimResult {
                layer_idx: 0,
                ou_ops: 80.0,
                skipped_ou_ops: 20.0,
                cycles: 80.0,
                energy: EnergyLedger { adc_pj: 8.0, dac_pj: 0.0, rram_pj: 0.0 },
                n_crossbars: 1,
            }],
        };
        // the calibration trace skipped 20% of the schedule at a 0.2
        // input zero fraction -> slope 1, dense = observed / 0.8
        let m = CostModel::from_sim(&r, 0.2);
        assert!((m.dense_cycles - 100.0).abs() < 1e-9, "{}", m.dense_cycles);
        assert!((m.dense_energy_pj - 10.0).abs() < 1e-9);
        assert!((m.skip_slope - 1.0).abs() < 1e-9);
    }

    #[test]
    fn alarm_threshold_accessors() {
        let m = Metrics::default();
        assert!(!m.failed_alarm());
        m.set_alarm_threshold(2);
        assert_eq!(m.alarm_threshold(), 2);
        m.record_failed();
        assert!(!m.failed_alarm());
        m.record_failed();
        assert!(m.failed_alarm());
    }

    #[test]
    fn many_threads_submit_concurrently() {
        let calls = Arc::new(AtomicU64::new(0));
        let c = Arc::new(Coordinator::start(
            move || mock(4, calls),
            Duration::from_millis(2),
        ));
        let mut handles = Vec::new();
        for t in 0..8 {
            let c2 = c.clone();
            handles.push(std::thread::spawn(move || {
                let rx = c2.submit(vec![t as f32; 4]);
                let rep = rx.recv().unwrap();
                assert_eq!(rep.logits()[0], 4.0 * t as f32);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.metrics.requests.load(Ordering::Relaxed), 8);
    }
}
