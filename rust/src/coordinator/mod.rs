//! L3 serving coordinator: request router + dynamic batcher.
//!
//! Requests are submitted from any thread; a worker thread collects them
//! into fixed-size batches (padding the tail), executes the AOT-compiled
//! functional model through [`crate::runtime::Engine`], and routes each
//! logit vector back to its requester. std::thread + mpsc throughout
//! (no async runtime exists in this offline image — and the paper's
//! contribution is the accelerator, so L3 stays a thin driver per the
//! architecture note in DESIGN.md §2).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// Inference backend abstraction — the PJRT engine in production, mocks
/// in tests. Backends are constructed *inside* the worker thread (the
/// PJRT client is not `Send`), so the trait itself needs no `Send`.
pub trait InferBackend: 'static {
    /// Input element count per request (e.g. 3*32*32).
    fn input_len(&self) -> usize;
    /// Output element count per request (e.g. 10 logits).
    fn output_len(&self) -> usize;
    /// Batch capacity of the compiled executable.
    fn batch_size(&self) -> usize;
    /// Run a full batch (`batch_size * input_len` floats, zero-padded);
    /// returns `batch_size * output_len` floats.
    fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String>;
}

/// PJRT-backed backend for the SmallCNN artifact.
pub struct PjrtBackend {
    pub engine: crate::runtime::Engine,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub output_len: usize,
}

impl InferBackend for PjrtBackend {
    fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    fn output_len(&self) -> usize {
        self.output_len
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
        let mut shape = vec![self.batch];
        shape.extend_from_slice(&self.input_shape);
        self.engine
            .run_f32(&[(&shape, batch)])
            .map_err(|e| e.to_string())
    }
}

/// One inference request.
struct Request {
    image: Vec<f32>,
    submitted: Instant,
    reply: Sender<Reply>,
}

/// Reply with the batch outcome + timing. `result` carries the logits
/// on success or the backend's error on failure — a failed batch is
/// reported to every waiting requester instead of silently dropping
/// their reply channels.
#[derive(Debug, Clone)]
pub struct Reply {
    pub result: Result<Vec<f32>, String>,
    pub queue_us: u64,
    pub batch_fill: usize,
}

impl Reply {
    /// Logits of a successful reply. Panics on a failed batch — a
    /// convenience for demos and tests; production callers match on
    /// [`Reply::result`].
    pub fn logits(&self) -> &[f32] {
        self.result.as_ref().expect("inference batch failed")
    }
}

/// Aggregate serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    /// Requests whose batch failed in the backend (each received the
    /// error through its [`Reply::result`]).
    pub failed_requests: AtomicU64,
    latencies_us: Mutex<Summary>,
}

impl Metrics {
    pub fn latency_summary(&self) -> Summary {
        self.latencies_us.lock().unwrap().clone()
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: Option<Sender<Request>>,
    pub metrics: Arc<Metrics>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the batching worker. The backend is built by `make_backend`
    /// *inside* the worker thread (the PJRT client is not `Send`).
    /// `max_wait` bounds how long a partial batch waits for more
    /// requests before executing padded.
    pub fn start<B, F>(make_backend: F, max_wait: Duration) -> Coordinator
    where
        B: InferBackend,
        F: FnOnce() -> B + Send + 'static,
    {
        let (tx, rx) = channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        let m = metrics.clone();
        let worker = std::thread::spawn(move || {
            let backend = make_backend();
            batch_loop(backend, rx, max_wait, m)
        });
        Coordinator { tx: Some(tx), metrics, worker: Some(worker) }
    }

    /// Submit one image; returns the channel the reply arrives on.
    pub fn submit(&self, image: Vec<f32>) -> Receiver<Reply> {
        let (rtx, rrx) = channel();
        let req = Request { image, submitted: Instant::now(), reply: rtx };
        // A send failure means the worker exited; the caller sees it as
        // a closed reply channel.
        if let Some(tx) = &self.tx {
            let _ = tx.send(req);
        }
        rrx
    }

    /// Stop the worker (drains in-flight requests first).
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn batch_loop<B: InferBackend>(
    backend: B,
    rx: Receiver<Request>,
    max_wait: Duration,
    metrics: Arc<Metrics>,
) {
    let bs = backend.batch_size();
    let in_len = backend.input_len();
    let out_len = backend.output_len();

    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // all senders dropped
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + max_wait;
        // Fill the batch until full or the deadline passes.
        while pending.len() < bs {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(_) => break,
            }
        }

        // Assemble padded batch.
        let mut batch = vec![0.0f32; bs * in_len];
        for (i, r) in pending.iter().enumerate() {
            debug_assert_eq!(r.image.len(), in_len);
            batch[i * in_len..(i + 1) * in_len].copy_from_slice(&r.image);
        }
        let fill = pending.len();
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics
            .padded_slots
            .fetch_add((bs - fill) as u64, Ordering::Relaxed);

        match backend.run_batch(&batch) {
            Ok(out) => {
                for (i, r) in pending.into_iter().enumerate() {
                    let logits = out[i * out_len..(i + 1) * out_len].to_vec();
                    let queue_us = r.submitted.elapsed().as_micros() as u64;
                    metrics.requests.fetch_add(1, Ordering::Relaxed);
                    metrics
                        .latencies_us
                        .lock()
                        .unwrap()
                        .push(queue_us as f64);
                    let _ = r.reply.send(Reply {
                        result: Ok(logits),
                        queue_us,
                        batch_fill: fill,
                    });
                }
            }
            Err(e) => {
                // Deliver the cause to every waiting requester — a
                // dropped sender would only show them an opaque closed
                // channel.
                eprintln!("[coordinator] batch failed: {e}");
                for r in pending.into_iter() {
                    let queue_us = r.submitted.elapsed().as_micros() as u64;
                    metrics.failed_requests.fetch_add(1, Ordering::Relaxed);
                    let _ = r.reply.send(Reply {
                        result: Err(e.clone()),
                        queue_us,
                        batch_fill: fill,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Identity-ish mock: logit k = sum(image) + k.
    struct MockBackend {
        in_len: usize,
        out_len: usize,
        batch: usize,
        calls: Arc<AtomicU64>,
    }

    impl InferBackend for MockBackend {
        fn input_len(&self) -> usize {
            self.in_len
        }
        fn output_len(&self) -> usize {
            self.out_len
        }
        fn batch_size(&self) -> usize {
            self.batch
        }
        fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            assert_eq!(batch.len(), self.batch * self.in_len);
            let mut out = Vec::with_capacity(self.batch * self.out_len);
            for i in 0..self.batch {
                let s: f32 = batch[i * self.in_len..(i + 1) * self.in_len]
                    .iter()
                    .sum();
                for k in 0..self.out_len {
                    out.push(s + k as f32);
                }
            }
            Ok(out)
        }
    }

    fn mock(batch: usize, calls: Arc<AtomicU64>) -> MockBackend {
        MockBackend { in_len: 4, out_len: 3, batch, calls }
    }

    #[test]
    fn single_request_roundtrip() {
        let calls = Arc::new(AtomicU64::new(0));
        let calls2 = calls.clone();
        let c = Coordinator::start(move || mock(4, calls2), Duration::from_millis(5));
        let rx = c.submit(vec![1.0, 2.0, 3.0, 4.0]);
        let reply = rx.recv().unwrap();
        assert_eq!(reply.logits(), &[10.0, 11.0, 12.0][..]);
        assert_eq!(reply.batch_fill, 1);
        c.shutdown();
        assert_eq!(calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn batching_coalesces_requests() {
        let calls = Arc::new(AtomicU64::new(0));
        let calls2 = calls.clone();
        let c = Coordinator::start(move || mock(4, calls2), Duration::from_millis(200));
        let rxs: Vec<_> = (0..4)
            .map(|i| c.submit(vec![i as f32; 4]))
            .collect();
        let replies: Vec<Reply> = rxs.iter().map(|r| r.recv().unwrap()).collect();
        for (i, rep) in replies.iter().enumerate() {
            assert_eq!(rep.logits()[0], 4.0 * i as f32);
            assert_eq!(rep.batch_fill, 4);
        }
        c.shutdown();
        // all four requests fit one batch
        assert_eq!(calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn partial_batch_fires_on_timeout() {
        let calls = Arc::new(AtomicU64::new(0));
        let calls2 = calls.clone();
        let c = Coordinator::start(move || mock(8, calls2), Duration::from_millis(10));
        let rx = c.submit(vec![0.5; 4]);
        let reply = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(reply.batch_fill, 1);
        c.shutdown();
        let m = calls.load(Ordering::Relaxed);
        assert_eq!(m, 1);
    }

    /// Backend that always fails; its error must reach every requester.
    struct FailingBackend;

    impl InferBackend for FailingBackend {
        fn input_len(&self) -> usize {
            2
        }
        fn output_len(&self) -> usize {
            1
        }
        fn batch_size(&self) -> usize {
            2
        }
        fn run_batch(&self, _batch: &[f32]) -> Result<Vec<f32>, String> {
            Err("backend exploded".to_string())
        }
    }

    #[test]
    fn failed_batch_reports_error_to_requesters() {
        let c = Coordinator::start(|| FailingBackend, Duration::from_millis(5));
        let rx1 = c.submit(vec![1.0, 2.0]);
        let rx2 = c.submit(vec![3.0, 4.0]);
        for rx in [rx1, rx2] {
            let reply = rx
                .recv_timeout(Duration::from_secs(5))
                .expect("reply must be delivered, not dropped");
            let err = reply.result.expect_err("must carry the backend error");
            assert!(err.contains("backend exploded"), "{err}");
        }
        assert_eq!(c.metrics.failed_requests.load(Ordering::Relaxed), 2);
        assert_eq!(c.metrics.requests.load(Ordering::Relaxed), 0);
        c.shutdown();
    }

    #[test]
    fn metrics_track_requests_and_padding() {
        let calls = Arc::new(AtomicU64::new(0));
        let c = Coordinator::start(move || mock(4, calls), Duration::from_millis(10));
        for _ in 0..2 {
            let rx = c.submit(vec![0.0; 4]);
            rx.recv().unwrap();
        }
        let reqs = c.metrics.requests.load(Ordering::Relaxed);
        let pads = c.metrics.padded_slots.load(Ordering::Relaxed);
        assert_eq!(reqs, 2);
        assert!(pads >= 4, "pads={pads}"); // two batches of fill 1
        assert!(c.metrics.latency_summary().len() == 2);
        c.shutdown();
    }

    #[test]
    fn many_threads_submit_concurrently() {
        let calls = Arc::new(AtomicU64::new(0));
        let c = Arc::new(Coordinator::start(
            move || mock(4, calls),
            Duration::from_millis(2),
        ));
        let mut handles = Vec::new();
        for t in 0..8 {
            let c2 = c.clone();
            handles.push(std::thread::spawn(move || {
                let rx = c2.submit(vec![t as f32; 4]);
                let rep = rx.recv().unwrap();
                assert_eq!(rep.logits()[0], 4.0 * t as f32);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.metrics.requests.load(Ordering::Relaxed), 8);
    }
}
