//! L3 serving coordinator: sharded dispatch pipeline over a pool of
//! inference workers.
//!
//! ```text
//!   submit() / submit_with_deadline()          (any thread)
//!        │
//!        ▼
//!   [shared admission queue]
//!        │  dispatcher thread:
//!        │    1. deadline admission (overdue requests get a timely
//!        │       deadline-exceeded error instead of a stale result)
//!        │    2. cost estimate (CostModel, one pass over the image)
//!        │    3. overload admission (reject when the pool's outstanding
//!        │       predicted cycles exceed `max_outstanding_cost`)
//!        │    4. routing: least-loaded-by-predicted-cycles
//!        │       (BalancePolicy::CostAware) or round-robin, skipping
//!        │       quarantined workers
//!        ▼
//!   [per-worker request channels]
//!        │  worker 0 … N-1, each its own failure domain:
//!        │    own backend (built in-thread: the PJRT client is not
//!        │    Send), own batcher (fill to batch_size, max_wait, or the
//!        │    earliest pending deadline), own retries, own Metrics
//!        │    shard. A worker that keeps failing batches is
//!        │    quarantined by the dispatcher; its failures never touch
//!        │    requests routed to its siblings.
//!        ▼
//!   [reply channel per request] — logits or the error, plus queue
//!   timing, batch fill and the request's cost estimate.
//! ```
//!
//! std::thread + mpsc throughout (no async runtime exists in this
//! offline image — and the paper's contribution is the accelerator, so
//! L3 stays a thin driver per the architecture note in DESIGN.md §2).
//!
//! Serving policy:
//!
//! - **Cost estimates** — with a [`CostModel`] attached, every [`Reply`]
//!   carries a cheap trace-derived per-request cost estimate (cycles +
//!   energy from the request's own input zero fraction). The model is
//!   calibrated from real exact-mode activation traces
//!   ([`CostModel::from_calibration`] over
//!   [`crate::sim::CostCalibration`]) or, as a fallback, from one
//!   analytic simulation ([`CostModel::from_sim`]).
//! - **Deadlines** — per worker: [`Coordinator::submit_with_deadline`]
//!   requests are dispatched no later than their deadline (a
//!   near-deadline request fires its batch early, padded); a request
//!   whose deadline already passed while queued gets a timely
//!   deadline-exceeded error `Reply` instead of a stale result.
//! - **Retry & cross-worker requeue** — per worker: a failed batch is
//!   re-run up to [`CoordinatorConfig::max_retries`] times on the
//!   worker that ran it. When that worker's retries are exhausted and
//!   the pool has siblings, each of the batch's requests is requeued
//!   through the dispatcher onto a *different* worker (up to
//!   [`CoordinatorConfig::max_requeues`] times per request) before the
//!   backend error is delivered — one dead backend no longer fails the
//!   requests that happened to be routed to it. One flaky backend
//!   retries (and, past [`CoordinatorConfig::quarantine_after`]
//!   consecutive failures, is routed around) without stalling or
//!   failing the rest of the pool.
//! - **Quarantine expiry** — a quarantined worker normally rejoins when
//!   a batch already in its queue succeeds; with
//!   [`CoordinatorConfig::quarantine_expiry`] set it also rejoins after
//!   that much wall time on probation (failure streak reset), so a
//!   recovered backend takes traffic again without needing a probe
//!   request to drain through its queue.
//! - **Alarm** — [`Metrics::failed_alarm`] trips once the *pool-wide*
//!   failure count reaches the configured threshold (all shards of one
//!   pool share a single alarm, so N workers keep the single-worker
//!   sensitivity); [`Coordinator::merged_metrics`] merges the shards.
//!
//! With `workers == 1` (the default) the pipeline degenerates to the
//! PR 2 single-worker batcher: one worker owns the only backend, the
//! dispatcher forwards requests in submission order, and the admission
//! shard *is* the worker shard — outputs are bit-exact with the
//! pre-pool coordinator.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::obs::{self, TraceCtx};
use crate::sim::{CostCalibration, NetworkSimResult};
use crate::util::lockcheck;
use crate::util::stats::Summary;
use crate::util::threadpool;

/// Inference backend abstraction — the PJRT engine in production, mocks
/// in tests. Backends are constructed *inside* the worker thread (the
/// PJRT client is not `Send`), so the trait itself needs no `Send`.
pub trait InferBackend: 'static {
    /// Input element count per request (e.g. 3*32*32).
    fn input_len(&self) -> usize;
    /// Output element count per request (e.g. 10 logits).
    fn output_len(&self) -> usize;
    /// Batch capacity of the compiled executable.
    fn batch_size(&self) -> usize;
    /// Run a full batch (`batch_size * input_len` floats, zero-padded);
    /// returns `batch_size * output_len` floats.
    fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String>;
}

/// PJRT-backed backend for the SmallCNN artifact.
pub struct PjrtBackend {
    pub engine: crate::runtime::Engine,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub output_len: usize,
}

impl InferBackend for PjrtBackend {
    fn input_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    fn output_len(&self) -> usize {
        self.output_len
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
        let mut shape = vec![self.batch];
        shape.extend_from_slice(&self.input_shape);
        self.engine
            .run_f32(&[(&shape, batch)])
            .map_err(|e| e.to_string())
    }
}

/// Cheap per-request cost estimate, attached to every [`Reply`] when
/// the coordinator runs with a [`CostModel`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostEstimate {
    pub est_cycles: f64,
    pub est_energy_pj: f64,
    /// Zero fraction of the submitted image the estimate derives from.
    pub input_zero_fraction: f64,
}

/// Trace-derived first-order request cost model: the dense (no-skip)
/// per-image cost, discounted by the request's own input zero fraction
/// times a skip slope. Cheap enough for the dispatch path — one pass
/// over the image, two multiplies.
///
/// Calibration sources, in decreasing fidelity:
/// [`CostModel::from_calibration`] (per-layer regressions over real
/// exact-mode activation traces, `SmallCnn::exact_traces` →
/// [`crate::sim::CostCalibration`]) and [`CostModel::from_sim`] (one
/// synthetic-trace simulation, first order).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Cycles of the full (no skipping) schedule for one image.
    pub dense_cycles: f64,
    /// Energy (pJ) of the full schedule for one image.
    pub dense_energy_pj: f64,
    /// d(skipped cycle fraction) / d(input zero fraction), first order.
    pub skip_slope: f64,
    /// d(saved energy fraction) / d(input zero fraction) — energy
    /// scales differently from cycles (ADC share vs control overhead),
    /// so calibration fits it separately.
    pub energy_skip_slope: f64,
}

impl CostModel {
    /// Calibrate from a simulated run with zero detection on:
    /// `calib_zero_fraction` is the zero fraction of the calibration
    /// trace the run was costed against (e.g. the synthetic trace's
    /// dead-channel + zero-blob share).
    pub fn from_sim(r: &NetworkSimResult, calib_zero_fraction: f64) -> CostModel {
        let executed = r.total_ou_ops();
        let skipped: f64 = r.layers.iter().map(|l| l.skipped_ou_ops).sum();
        let dense_ops = (executed + skipped).max(1.0);
        // scale the observed (post-skip) cycles/energy back up to the
        // dense schedule
        let dense_scale = dense_ops / executed.max(1.0);
        let skip_frac = skipped / dense_ops;
        let slope = if calib_zero_fraction > 1e-9 {
            skip_frac / calib_zero_fraction
        } else {
            0.0
        };
        CostModel {
            dense_cycles: r.total_cycles() * dense_scale,
            dense_energy_pj: r.total_energy().total_pj() * dense_scale,
            skip_slope: slope,
            // the analytic calibration has no separate energy signal:
            // one slope for both
            energy_skip_slope: slope,
        }
    }

    /// Calibrate from exact-mode activation traces: a
    /// [`CostCalibration`] holds one zero-fraction→cycles/energy
    /// regression per layer; the serving model sums the layer fits, so
    /// `dense_*` is the predicted cost at input zero fraction 0 and the
    /// skip slope is the fitted relative discount per unit of input
    /// zero fraction (clamped to ≥ 0 — more zeros never cost more).
    pub fn from_calibration(c: &CostCalibration) -> CostModel {
        let dense_cycles = c.total_cycles_at(0.0).max(0.0);
        let dense_energy_pj = c.total_energy_at(0.0).max(0.0);
        let cycles_slope: f64 = c.layers.iter().map(|l| l.cycles_slope).sum();
        let energy_slope: f64 =
            c.layers.iter().map(|l| l.energy_slope_pj).sum();
        let rel = |slope: f64, dense: f64| {
            if dense > 1e-12 {
                (-slope / dense).max(0.0)
            } else {
                0.0
            }
        };
        CostModel {
            dense_cycles,
            dense_energy_pj,
            skip_slope: rel(cycles_slope, dense_cycles),
            energy_skip_slope: rel(energy_slope, dense_energy_pj),
        }
    }

    /// Discount the per-request *cycle* cost by a multi-core pipeline
    /// speedup (from [`crate::sim::placement`]): with the network's
    /// layers pipelined over CIM cores, the per-image cycle cost the
    /// dispatcher balances and admits on is the pipeline's, not the
    /// single-core total. Energy is untouched — the same work runs,
    /// just spread over cores. Non-finite or `≤ 1` speedups are
    /// ignored (a broken placement must not inflate admission).
    pub fn with_pipeline_speedup(mut self, speedup: f64) -> CostModel {
        if speedup.is_finite() && speedup > 1.0 {
            self.dense_cycles /= speedup;
        }
        self
    }

    /// Estimate the cost of serving `image` (kept work is clamped to
    /// `[0, 1]` of the dense schedule, per signal).
    pub fn estimate(&self, image: &[f32]) -> CostEstimate {
        let zeros = image.iter().filter(|v| **v == 0.0).count();
        let zf = zeros as f64 / image.len().max(1) as f64;
        let keep_cycles = (1.0 - self.skip_slope * zf).clamp(0.0, 1.0);
        let keep_energy = (1.0 - self.energy_skip_slope * zf).clamp(0.0, 1.0);
        CostEstimate {
            est_cycles: self.dense_cycles * keep_cycles,
            est_energy_pj: self.dense_energy_pj * keep_energy,
            input_zero_fraction: zf,
        }
    }
}

/// How the dispatcher routes admitted requests to pool workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BalancePolicy {
    /// Least outstanding predicted cycles (trace-derived
    /// [`CostEstimate`]s); requests without an estimate — no cost model
    /// attached — fall back to round-robin.
    CostAware,
    /// Strict round-robin over healthy workers.
    RoundRobin,
}

/// Batching / retry / deadline / pool policy for a [`Coordinator`].
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// How long a partial batch waits for more requests before
    /// executing padded.
    pub max_wait: Duration,
    /// Per-worker re-runs of a failed batch before the error is
    /// delivered (ISSUE-2 default: one retry).
    pub max_retries: u32,
    /// Deadline attached to plain [`Coordinator::submit`] requests
    /// (`None` = no deadline).
    pub default_deadline: Option<Duration>,
    /// Failed-request count at which a shard's [`Metrics::failed_alarm`]
    /// trips (0 disables the alarm).
    pub alarm_threshold: u64,
    /// Pool size: number of worker threads, each owning one backend
    /// built by the factory. 1 reproduces the PR 2 single-worker
    /// batcher bit for bit.
    pub workers: usize,
    /// Routing policy for admitted requests.
    pub balance: BalancePolicy,
    /// Consecutive failed batches after which the dispatcher stops
    /// routing new requests to a worker (0 disables quarantine). A
    /// worker leaves quarantine when a later batch succeeds — which
    /// requires requests already queued in its channel to drain
    /// through — or, with [`CoordinatorConfig::quarantine_expiry`] set,
    /// when that much time has elapsed since it was quarantined.
    pub quarantine_after: u64,
    /// Time-based quarantine release: after this long in quarantine the
    /// worker rejoins routing on probation (its failure streak is
    /// reset; another `quarantine_after` consecutive failures
    /// re-quarantine it). `None` keeps the success-only release, which
    /// never readmits a worker whose queue is empty.
    pub quarantine_expiry: Option<Duration>,
    /// Cross-worker requeue: how many times a request whose batch
    /// failed (after the owning worker's retries) is re-dispatched to a
    /// *different* worker before the error is delivered. Only active
    /// with `workers > 1`; `0` restores strict per-worker failure
    /// domains (a request fails with the worker it was routed to).
    pub max_requeues: u32,
    /// Cost-aware admission: when > 0 and a cost model is attached, a
    /// new request is rejected with an overload error once the pool's
    /// total outstanding predicted cycles reach this limit
    /// (0 = unlimited).
    pub max_outstanding_cost: f64,
    /// Tracing registry ([`obs::Registry`]). With one attached, every
    /// submitted request gets a trace ID (unless the caller already
    /// assigned one via [`Coordinator::submit_traced`]) and the
    /// dispatcher/workers record `pool.admit` → `pool.queue` →
    /// `pool.exec` spans (plus `pool.retry`/`pool.requeue` instants on
    /// the failure paths) into its ring buffers. `None` (the default)
    /// keeps the hot path free of clock reads and ring writes.
    pub trace: Option<Arc<obs::Registry>>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_wait: Duration::from_millis(2),
            max_retries: 1,
            default_deadline: None,
            alarm_threshold: 0,
            workers: 1,
            balance: BalancePolicy::CostAware,
            quarantine_after: 2,
            quarantine_expiry: None,
            max_requeues: 1,
            max_outstanding_cost: 0.0,
            trace: None,
        }
    }
}

/// One inference request.
struct Request {
    image: Vec<f32>,
    submitted: Instant,
    /// Latest instant at which the request may still be dispatched.
    deadline: Option<Instant>,
    /// Cost estimate, computed once at dispatch (None without a model).
    cost: Option<CostEstimate>,
    /// Times this request was requeued after a failed batch.
    requeues: u32,
    /// Worker whose batch failure requeued it — avoided on re-dispatch
    /// while any alternative worker exists.
    exclude: Option<usize>,
    /// Trace identity: the request-scoped trace ID (0 = untraced) and
    /// the span the next pipeline stage nests under. Requeues keep the
    /// trace ID — a rescued request stays one trace end to end.
    trace: TraceCtx,
    /// Submit time on the registry clock (µs), for queue spans whose
    /// start predates the worker that records them. 0 when untraced.
    t_submit_us: u64,
    reply: Sender<Reply>,
}

/// Stable prefix of every deadline-exceeded error delivered through
/// [`Reply::result`]. Front ends (e.g. the HTTP layer's 504 mapping)
/// classify failures by prefix instead of ad-hoc substring heuristics;
/// changing the wording behind the prefix stays compatible.
pub const ERR_DEADLINE_PREFIX: &str = "deadline exceeded";

/// Stable prefix of every overload-rejection error delivered through
/// [`Reply::result`] (HTTP maps it to 429).
pub const ERR_OVERLOAD_PREFIX: &str = "pool overloaded";

/// Reply with the batch outcome + timing. `result` carries the logits
/// on success, or the error on failure (backend error after retries,
/// deadline exceeded, or overload rejection) — a failed request is
/// reported to its requester instead of silently dropping the reply
/// channel.
#[derive(Debug, Clone)]
pub struct Reply {
    pub result: Result<Vec<f32>, String>,
    pub queue_us: u64,
    pub batch_fill: usize,
    /// Trace-derived cost estimate (present when the coordinator was
    /// started with a [`CostModel`]).
    pub cost: Option<CostEstimate>,
    /// Trace ID the request was served under (0 when the pool runs
    /// without an [`obs::Registry`]); the key for correlating this
    /// reply with its spans in `/debug/trace`.
    pub trace_id: u64,
}

impl Reply {
    /// Logits of a successful reply. Panics on a failed batch — a
    /// convenience for demos and tests; production callers match on
    /// [`Reply::result`].
    pub fn logits(&self) -> &[f32] {
        self.result.as_ref().expect("inference batch failed")
    }
}

/// Serving metrics for one shard (the admission/dispatch side, or one
/// pool worker). Counters are recorded exactly once per terminal event
/// — a request's latency is pushed once at its terminal reply no matter
/// how many times its batch was retried — so [`Metrics::merge`] over
/// shards is a plain sum with no double counting.
#[derive(Debug)]
pub struct Metrics {
    /// Requests that received a terminal reply — successes *and*
    /// failures — so `failed_requests / requests` is a coherent failure
    /// rate.
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub padded_slots: AtomicU64,
    /// Requests that failed — backend error after retries, deadline
    /// exceeded, or overload rejection (each received the error through
    /// its [`Reply::result`]).
    pub failed_requests: AtomicU64,
    /// Batch re-runs after a backend failure.
    pub retried_batches: AtomicU64,
    /// Requests re-dispatched to a different worker after their batch
    /// failed (recorded on the shard of the worker whose batch failed;
    /// the request's terminal reply is counted wherever it lands).
    pub requeued_requests: AtomicU64,
    /// Requests whose deadline passed while queued (also counted in
    /// `failed_requests`).
    pub deadline_expired: AtomicU64,
    /// Requests rejected at admission because the pool's outstanding
    /// predicted cost exceeded the configured limit (also counted in
    /// `failed_requests`).
    pub rejected_overload: AtomicU64,
    /// Times a worker on this shard *entered* quarantine (the streak
    /// crossed the threshold while not already quarantined).
    pub quarantine_events: AtomicU64,
    /// Failure alarm — shared by every shard of one pool, so N workers
    /// trip at the same *total* failure count a single worker would.
    alarm: Arc<AlarmState>,
    /// Bounded latency/batch-fill accounting. A `lockcheck::Mutex`: a
    /// worker that panics mid-record must not wedge
    /// `merged_metrics`/`worker_stats` for the surviving pool —
    /// `lock()` recovers the poisoned telemetry.
    telemetry: lockcheck::Mutex<PoolTelemetry>,
}

/// O(1)-memory latency/queue-depth accounting for one metrics shard:
/// fixed-bucket histograms for unbounded request counts, plus a
/// deterministic first-K reservoir so small runs (and the test suite)
/// keep exact quantiles. This replaced the grow-forever latency vector
/// — memory no longer scales with requests served.
#[derive(Debug, Clone)]
struct PoolTelemetry {
    latency: obs::FixedHistogram,
    /// First [`obs::DEFAULT_RESERVOIR_CAP`] exact latency samples.
    latency_exact: obs::Reservoir,
    /// Requests per executed batch (queue-depth proxy).
    batch_fill: obs::FixedHistogram,
}

impl PoolTelemetry {
    fn new() -> PoolTelemetry {
        PoolTelemetry {
            latency: obs::FixedHistogram::new(obs::LATENCY_BOUNDS_US),
            latency_exact: obs::Reservoir::new(obs::DEFAULT_RESERVOIR_CAP),
            batch_fill: obs::FixedHistogram::new(obs::BATCH_FILL_BOUNDS),
        }
    }

    fn record_latency(&mut self, us: f64) {
        self.latency.record(us);
        self.latency_exact.push(us);
    }

    fn merge(&mut self, other: &PoolTelemetry) {
        self.latency.merge(&other.latency);
        self.latency_exact.merge(&other.latency_exact);
        self.batch_fill.merge(&other.batch_fill);
    }

    /// Latency quantile: exact (linear-interpolated over the retained
    /// samples) while the reservoir still holds everything, histogram
    /// interpolation after it saturates. `q` in percent (50.0, 99.0).
    fn latency_percentile(&self, q: f64) -> f64 {
        if self.latency.count() == 0 {
            return 0.0;
        }
        if self.latency_exact.is_exact() {
            Summary::from_values(self.latency_exact.values().to_vec())
                .percentile(q)
        } else {
            self.latency.quantile(q / 100.0)
        }
    }
}

/// Plain-data view of one [`Metrics`] shard or a merged pool, produced
/// by [`Metrics::snapshot`]. Latency aggregates are in microseconds;
/// with zero samples they are all 0 (never NaN), so serializers emit
/// numbers unconditionally.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub failed_requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub retried_batches: u64,
    pub requeued_requests: u64,
    pub deadline_expired: u64,
    pub rejected_overload: u64,
    /// Quarantine entries across the snapshotted shards.
    pub quarantine_events: u64,
    pub alarm_threshold: u64,
    pub alarm_tripped: bool,
    pub latency_count: u64,
    pub latency_mean_us: f64,
    pub latency_p50_us: f64,
    pub latency_p99_us: f64,
    pub latency_max_us: f64,
    /// Latency histogram, Prometheus cumulative form: `(le, count)`
    /// per bucket, final bound `f64::INFINITY`. Sum of observations is
    /// `latency_sum_us`.
    pub latency_buckets: Vec<(f64, u64)>,
    pub latency_sum_us: f64,
    /// Requests-per-batch histogram in the same cumulative form.
    pub batch_fill_buckets: Vec<(f64, u64)>,
}

/// Pool-wide failure-alarm state: the threshold plus the failure count
/// it is checked against. All metrics shards of one coordinator share a
/// single `AlarmState` (each terminal failure increments it exactly
/// once), preserving the single-worker alarm sensitivity at any pool
/// size.
#[derive(Debug, Default)]
struct AlarmState {
    /// Failed-request count at which the alarm trips (0 = disabled).
    threshold: AtomicU64,
    /// Terminal failures across every shard sharing this alarm.
    failed: AtomicU64,
    logged: AtomicBool,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            padded_slots: AtomicU64::new(0),
            failed_requests: AtomicU64::new(0),
            retried_batches: AtomicU64::new(0),
            requeued_requests: AtomicU64::new(0),
            deadline_expired: AtomicU64::new(0),
            rejected_overload: AtomicU64::new(0),
            quarantine_events: AtomicU64::new(0),
            alarm: Arc::default(),
            telemetry: lockcheck::Mutex::named(
                "metrics.telemetry",
                PoolTelemetry::new(),
            ),
        }
    }
}

impl Metrics {
    /// A shard wired to an existing (pool-shared) alarm.
    fn with_alarm(alarm: Arc<AlarmState>) -> Metrics {
        Metrics { alarm, ..Default::default() }
    }

    /// Record one terminal request latency (µs). O(1) time and memory:
    /// one histogram bucket increment plus a bounded reservoir push.
    pub fn record_latency_us(&self, us: f64) {
        self.telemetry.lock().record_latency(us);
    }

    /// Record the fill of one executed batch.
    pub fn record_batch_fill(&self, fill: usize) {
        self.telemetry.lock().batch_fill.record(fill as f64);
    }

    /// Exact latency samples retained in the bounded reservoir (all
    /// samples while under [`obs::DEFAULT_RESERVOIR_CAP`]; the first K
    /// thereafter — deterministic, no sampling entropy). Use
    /// [`Metrics::snapshot`] for totals once past the cap.
    pub fn latency_summary(&self) -> Summary {
        Summary::from_values(self.telemetry.lock().latency_exact.values().to_vec())
    }

    pub fn set_alarm_threshold(&self, n: u64) {
        self.alarm.threshold.store(n, Ordering::Relaxed);
    }

    pub fn alarm_threshold(&self) -> u64 {
        self.alarm.threshold.load(Ordering::Relaxed)
    }

    /// Has the (pool-wide) failed-request count reached the alarm
    /// threshold?
    pub fn failed_alarm(&self) -> bool {
        let t = self.alarm.threshold.load(Ordering::Relaxed);
        t > 0 && self.alarm.failed.load(Ordering::Relaxed) >= t
    }

    /// Merge shard views into one aggregate: counters sum, histograms
    /// add element-wise, reservoirs concatenate (bounded), and the
    /// alarm threshold is the largest shard threshold. Each terminal
    /// reply was recorded on exactly one shard (and retried batches on
    /// the worker that re-ran them), so summing never double-counts —
    /// pinned by the unit tests below.
    pub fn merge<'a, I>(shards: I) -> Metrics
    where
        I: IntoIterator<Item = &'a Metrics>,
    {
        let out = Metrics::default();
        let mut threshold = 0u64;
        let mut telemetry = PoolTelemetry::new();
        for s in shards {
            let r = Ordering::Relaxed;
            out.requests.fetch_add(s.requests.load(r), r);
            out.batches.fetch_add(s.batches.load(r), r);
            out.padded_slots.fetch_add(s.padded_slots.load(r), r);
            out.failed_requests.fetch_add(s.failed_requests.load(r), r);
            out.retried_batches.fetch_add(s.retried_batches.load(r), r);
            out.requeued_requests.fetch_add(s.requeued_requests.load(r), r);
            out.deadline_expired.fetch_add(s.deadline_expired.load(r), r);
            out.rejected_overload.fetch_add(s.rejected_overload.load(r), r);
            out.quarantine_events.fetch_add(s.quarantine_events.load(r), r);
            threshold = threshold.max(s.alarm_threshold());
            let shard_tel = s.telemetry.lock();
            telemetry.merge(&shard_tel);
        }
        out.set_alarm_threshold(threshold);
        // the merged alarm is evaluated against the summed failures
        // (shards sharing one AlarmState counted each failure once)
        out.alarm
            .failed
            .store(out.failed_requests.load(Ordering::Relaxed), Ordering::Relaxed);
        *out.telemetry.lock() = telemetry;
        out
    }

    /// Plain-data point-in-time view of this shard (or of a merged pool
    /// view), with latency aggregates pre-extracted and empty-sample
    /// NaN/∞ sentinels flattened to 0 — the export surface the HTTP
    /// front door and report writers serialize from without touching
    /// atomics or the latency lock themselves.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let r = Ordering::Relaxed;
        let tel = self.telemetry.lock();
        MetricsSnapshot {
            requests: self.requests.load(r),
            failed_requests: self.failed_requests.load(r),
            batches: self.batches.load(r),
            padded_slots: self.padded_slots.load(r),
            retried_batches: self.retried_batches.load(r),
            requeued_requests: self.requeued_requests.load(r),
            deadline_expired: self.deadline_expired.load(r),
            rejected_overload: self.rejected_overload.load(r),
            quarantine_events: self.quarantine_events.load(r),
            alarm_threshold: self.alarm_threshold(),
            alarm_tripped: self.failed_alarm(),
            // exact totals from the histogram (the reservoir is only a
            // bounded sample; count/mean/max never degrade with volume)
            latency_count: tel.latency.count(),
            latency_mean_us: tel.latency.mean(),
            latency_p50_us: tel.latency_percentile(50.0),
            latency_p99_us: tel.latency_percentile(99.0),
            latency_max_us: tel.latency.max(),
            latency_buckets: tel.latency.buckets(),
            latency_sum_us: tel.latency.sum(),
            batch_fill_buckets: tel.batch_fill.buckets(),
        }
    }

    /// Count one terminally-failed request (in both `requests` and
    /// `failed_requests`, plus the pool-shared alarm) and raise (and
    /// log, once) the alarm if the threshold is crossed.
    fn record_failed(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.failed_requests.fetch_add(1, Ordering::Relaxed);
        self.alarm.failed.fetch_add(1, Ordering::Relaxed);
        if self.failed_alarm() && !self.alarm.logged.swap(true, Ordering::Relaxed) {
            eprintln!(
                "[coordinator] ALARM: failed requests reached threshold {}",
                self.alarm_threshold()
            );
        }
    }
}

/// Dispatcher-visible state of one pool worker: its load accounting
/// (outstanding predicted cycles + in-flight requests) and its health
/// (consecutive failed batches), alongside its metrics shard.
struct WorkerState {
    /// Sum of the predicted `est_cycles` of requests routed to this
    /// worker and not yet terminally replied (whole cycles).
    outstanding_cost: AtomicU64,
    /// Requests routed and not yet terminally replied (a requeued
    /// request is settled here when its batch fails and re-charged on
    /// the worker the dispatcher re-routes it to).
    inflight: AtomicU64,
    /// Consecutive batches that failed after retries; reset on any
    /// successful batch (and on quarantine expiry). At
    /// `quarantine_after` the dispatcher routes around this worker.
    consecutive_failed_batches: AtomicU64,
    /// When the failure streak crossed the quarantine threshold.
    /// `None` means "not quarantined" — stated explicitly rather than
    /// through a 0-valued timestamp sentinel, which broke for a worker
    /// quarantined in its first microsecond alive.
    quarantined_at: lockcheck::Mutex<Option<Instant>>,
    /// Cleared when the worker thread exits — normally at shutdown, but
    /// also on a panic ([`WorkerAliveGuard`]). The dispatcher's drain
    /// and idle-blocking decisions ignore dead workers' in-flight
    /// counts (their requests can never settle), so a crashed worker
    /// cannot hang shutdown.
    alive: AtomicBool,
    metrics: Arc<Metrics>,
}

/// Drop guard marking a worker dead when its thread exits for any
/// reason — an unwinding panic mid-batch or a panicking backend
/// factory alike (it is installed in the spawn closure *before* the
/// factory runs).
struct WorkerAliveGuard(Arc<WorkerState>);

impl Drop for WorkerAliveGuard {
    fn drop(&mut self) {
        self.0.alive.store(false, Ordering::Release);
    }
}

impl WorkerState {
    fn new(metrics: Arc<Metrics>) -> WorkerState {
        WorkerState {
            outstanding_cost: AtomicU64::new(0),
            inflight: AtomicU64::new(0),
            consecutive_failed_batches: AtomicU64::new(0),
            quarantined_at: lockcheck::Mutex::named(
                "coordinator.worker.quarantined_at",
                None,
            ),
            alive: AtomicBool::new(true),
            metrics,
        }
    }

    fn alive(&self) -> bool {
        self.alive.load(Ordering::Acquire)
    }

    /// Record one terminally-failed batch; stamps the quarantine entry
    /// time when the streak crosses the threshold.
    fn note_batch_failure(&self, quarantine_after: u64) {
        let streak =
            self.consecutive_failed_batches.fetch_add(1, Ordering::Relaxed) + 1;
        if quarantine_after > 0 && streak >= quarantine_after {
            // only the first crossing stamps the clock; later failures
            // while quarantined keep the original entry time
            let mut at = self.quarantined_at.lock();
            if at.is_none() {
                *at = Some(Instant::now());
                self.metrics.quarantine_events.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// A successful batch ends both the failure streak and any
    /// quarantine.
    fn note_batch_success(&self) {
        self.consecutive_failed_batches.store(0, Ordering::Relaxed);
        *self.quarantined_at.lock() = None;
    }

    fn charge(&self, cost: Option<CostEstimate>) {
        if let Some(c) = cost {
            let add = c.est_cycles.max(0.0) as u64;
            self.outstanding_cost.fetch_add(add, Ordering::Relaxed);
        }
        self.inflight.fetch_add(1, Ordering::Relaxed);
    }

    /// Release the accounting charged at routing time — called exactly
    /// once per routed request, at its terminal reply. The in-flight
    /// decrement is a Release store (read with Acquire by the
    /// dispatcher): observing the count at zero proves every requeue
    /// sent before the settles is already visible in the requeue
    /// channel — the ordering the drain/idle logic relies on.
    fn settle(&self, cost: Option<CostEstimate>) {
        if let Some(c) = cost {
            let sub = c.est_cycles.max(0.0) as u64;
            let _ = self.outstanding_cost.fetch_update(
                Ordering::Relaxed,
                Ordering::Relaxed,
                |v| Some(v.saturating_sub(sub)),
            );
        }
        let _ = self.inflight.fetch_update(
            Ordering::AcqRel,
            Ordering::Acquire,
            |v| Some(v.saturating_sub(1)),
        );
    }

    /// In-flight count with Acquire ordering (see [`WorkerState::settle`]).
    fn inflight_acq(&self) -> u64 {
        self.inflight.load(Ordering::Acquire)
    }

    /// Is this worker currently quarantined? With an expiry configured,
    /// the check also *releases* an expired quarantine (probation: the
    /// failure streak resets, so readmission is observed by whichever
    /// caller — dispatcher or stats — looks first).
    fn quarantined(&self, quarantine_after: u64, expiry: Option<Duration>) -> bool {
        if quarantine_after == 0
            || self.consecutive_failed_batches.load(Ordering::Relaxed)
                < quarantine_after
        {
            return false;
        }
        if let Some(exp) = expiry {
            let at = *self.quarantined_at.lock();
            if matches!(at, Some(entered) if entered.elapsed() >= exp) {
                self.note_batch_success(); // parole: clean slate
                return false;
            }
        }
        true
    }
}

/// Point-in-time view of one pool worker, for reports and the CLI.
#[derive(Debug, Clone)]
pub struct WorkerStats {
    pub worker: usize,
    pub requests: u64,
    pub failed_requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub retried_batches: u64,
    /// Requests this worker's batch failures pushed to a sibling.
    pub requeued_requests: u64,
    pub inflight: u64,
    /// Outstanding predicted cycles routed to this worker.
    pub outstanding_cost: u64,
    pub quarantined: bool,
}

/// Handle to a running coordinator (dispatcher + worker pool).
pub struct Coordinator {
    tx: Option<Sender<Request>>,
    /// Admission/dispatch metrics shard. With `workers == 1` this is
    /// the *same* shard the worker records into, so single-worker
    /// callers see the full PR 2 view here.
    pub metrics: Arc<Metrics>,
    worker_shards: Vec<Arc<Metrics>>,
    worker_states: Vec<Arc<WorkerState>>,
    trace: Option<Arc<obs::Registry>>,
    default_deadline: Option<Duration>,
    quarantine_after: u64,
    quarantine_expiry: Option<Duration>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
    worker_joins: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start a single-worker coordinator with the default retry/deadline
    /// policy. The backend is built by `make_backend` *inside* the
    /// worker thread (the PJRT client is not `Send`). `max_wait` bounds
    /// how long a partial batch waits for more requests before
    /// executing padded.
    pub fn start<B, F>(make_backend: F, max_wait: Duration) -> Coordinator
    where
        B: InferBackend,
        F: FnOnce() -> B + Send + 'static,
    {
        Self::start_with(
            make_backend,
            CoordinatorConfig { max_wait, ..Default::default() },
            None,
        )
    }

    /// Start a **single-worker** coordinator with a full
    /// [`CoordinatorConfig`] and an optional [`CostModel`]; with a
    /// model, every reply carries a per-request cost estimate. The
    /// one-shot `make_backend` fixes the pool size at 1 (any
    /// `cfg.workers` is overridden); use [`Coordinator::start_pool`]
    /// with a reusable factory for a multi-worker pool.
    pub fn start_with<B, F>(
        make_backend: F,
        cfg: CoordinatorConfig,
        cost_model: Option<CostModel>,
    ) -> Coordinator
    where
        B: InferBackend,
        F: FnOnce() -> B + Send + 'static,
    {
        let cell =
            lockcheck::Mutex::named("coordinator.factory_cell", Some(make_backend));
        Self::start_pool(
            move |_worker| {
                let f = cell
                    .lock()
                    .take()
                    .expect("single-worker backend factory is one-shot");
                f()
            },
            CoordinatorConfig { workers: 1, ..cfg },
            cost_model,
        )
    }

    /// Start a pool of `cfg.workers` workers. `factory(worker_id)` is
    /// called once per worker, *inside* that worker's thread, so each
    /// worker owns an independent backend (its failure domain).
    pub fn start_pool<B, F>(
        factory: F,
        cfg: CoordinatorConfig,
        cost_model: Option<CostModel>,
    ) -> Coordinator
    where
        B: InferBackend,
        F: Fn(usize) -> B + Send + Sync + 'static,
    {
        let n = cfg.workers.max(1);
        let factory = Arc::new(factory);
        let (tx, rx) = channel::<Request>();

        // Cross-worker requeue path: workers send failed-batch requests
        // back to the dispatcher here. Only pools can requeue — a
        // single worker has no sibling to move the work to.
        let requeue_enabled = n > 1 && cfg.max_requeues > 0;
        let (requeue_tx, requeue_rx) = if requeue_enabled {
            let (qtx, qrx) = channel::<Request>();
            (Some(qtx), Some(qrx))
        } else {
            (None, None)
        };

        // One alarm for the whole pool: every shard's failures count
        // toward the same threshold, whatever the worker count.
        let alarm = Arc::new(AlarmState::default());
        let admission = Arc::new(Metrics::with_alarm(alarm.clone()));
        admission.set_alarm_threshold(cfg.alarm_threshold);

        let mut worker_txs = Vec::with_capacity(n);
        let mut worker_states = Vec::with_capacity(n);
        let mut worker_shards = Vec::with_capacity(n);
        let mut worker_joins = Vec::with_capacity(n);
        for worker in 0..n {
            let (wtx, wrx) = channel::<Request>();
            // Single-worker mode shares one shard between admission and
            // the worker (the PR 2 view); pools shard per worker, all
            // wired to the shared pool alarm.
            let shard = if n == 1 {
                admission.clone()
            } else {
                Arc::new(Metrics::with_alarm(alarm.clone()))
            };
            let state = Arc::new(WorkerState::new(shard.clone()));
            let f = factory.clone();
            let st = state.clone();
            let wcfg = cfg.clone();
            let rq = requeue_tx.clone();
            worker_joins.push(threadpool::spawn_named(
                &format!("coord-worker-{worker}"),
                move || {
                    // The guard must cover backend construction too: a
                    // panicking factory otherwise leaves `alive` set and
                    // the dispatcher keeps routing into the dead thread.
                    let _alive = WorkerAliveGuard(st.clone());
                    let backend = f(worker);
                    worker_loop(worker, backend, wrx, wcfg, st, rq);
                },
            ));
            worker_txs.push(wtx);
            worker_states.push(state);
            worker_shards.push(shard);
        }
        // Only workers hold requeue senders from here on; the
        // dispatcher's drain phase tracks in-flight counts, not channel
        // disconnection, so dropping this clone is just hygiene.
        drop(requeue_tx);

        let dcfg = cfg.clone();
        let dstates = worker_states.clone();
        let dmetrics = admission.clone();
        let dispatcher = threadpool::spawn_named("coord-dispatch", move || {
            dispatch_loop(
                rx,
                requeue_rx,
                worker_txs,
                dstates,
                dcfg,
                cost_model,
                dmetrics,
            );
        });

        Coordinator {
            tx: Some(tx),
            metrics: admission,
            worker_shards,
            worker_states,
            trace: cfg.trace.clone(),
            default_deadline: cfg.default_deadline,
            quarantine_after: cfg.quarantine_after,
            quarantine_expiry: cfg.quarantine_expiry,
            dispatcher: Some(dispatcher),
            worker_joins,
        }
    }

    /// Submit one image; returns the channel the reply arrives on.
    pub fn submit(&self, image: Vec<f32>) -> Receiver<Reply> {
        self.submit_inner(image, self.default_deadline, TraceCtx::default())
    }

    /// Submit with an explicit completion deadline: the batcher
    /// dispatches the request no later than `deadline` from now (firing
    /// a partial batch early if needed), and a request that is already
    /// overdue when considered gets a deadline-exceeded error instead
    /// of a stale result.
    pub fn submit_with_deadline(
        &self,
        image: Vec<f32>,
        deadline: Duration,
    ) -> Receiver<Reply> {
        self.submit_inner(image, Some(deadline), TraceCtx::default())
    }

    /// Submit with an explicit trace context: the front door (HTTP
    /// layer) opens the root span, assigns the trace ID, and hands it
    /// in here so the pool's spans nest under the HTTP request's.
    /// `deadline` of `None` falls back to the configured default. With
    /// a zero trace ID (or no registry attached), behaves exactly like
    /// [`Coordinator::submit`].
    pub fn submit_traced(
        &self,
        image: Vec<f32>,
        deadline: Option<Duration>,
        ctx: TraceCtx,
    ) -> Receiver<Reply> {
        self.submit_inner(image, deadline.or(self.default_deadline), ctx)
    }

    fn submit_inner(
        &self,
        image: Vec<f32>,
        deadline: Option<Duration>,
        ctx: TraceCtx,
    ) -> Receiver<Reply> {
        let (rtx, rrx) = channel();
        let now = Instant::now();
        // Requests get their trace identity at this boundary: keep the
        // caller's ID if the front door already assigned one, mint a
        // fresh one otherwise (registry attached), stay untraced (0)
        // without a registry.
        let mut trace = ctx;
        let mut t_submit_us = 0;
        if let Some(reg) = &self.trace {
            if trace.trace_id == 0 {
                trace.trace_id = reg.new_trace();
            }
            t_submit_us = reg.now_us();
        }
        let req = Request {
            image,
            submitted: now,
            deadline: deadline.map(|d| now + d),
            cost: None,
            requeues: 0,
            exclude: None,
            trace,
            t_submit_us,
            reply: rtx,
        };
        // A send failure means the dispatcher exited; the caller sees
        // it as a closed reply channel.
        if let Some(tx) = &self.tx {
            let _ = tx.send(req);
        }
        rrx
    }

    /// Number of pool workers.
    pub fn n_workers(&self) -> usize {
        self.worker_states.len()
    }

    /// The tracing registry the pool was started with, if any.
    pub fn trace_registry(&self) -> Option<&Arc<obs::Registry>> {
        self.trace.as_ref()
    }

    /// Per-worker metrics shards, in worker order. With `workers == 1`
    /// the only shard is [`Coordinator::metrics`] itself.
    pub fn worker_metrics(&self) -> &[Arc<Metrics>] {
        &self.worker_shards
    }

    /// Pool-wide metrics: the admission shard plus every worker shard,
    /// merged (shards shared between the two — single-worker mode — are
    /// counted once).
    pub fn merged_metrics(&self) -> Metrics {
        let mut refs: Vec<&Metrics> = vec![self.metrics.as_ref()];
        for w in &self.worker_shards {
            if !Arc::ptr_eq(w, &self.metrics) {
                refs.push(w.as_ref());
            }
        }
        Metrics::merge(refs)
    }

    /// Point-in-time per-worker load/health/metrics view.
    pub fn worker_stats(&self) -> Vec<WorkerStats> {
        let r = Ordering::Relaxed;
        self.worker_states
            .iter()
            .enumerate()
            .map(|(i, s)| WorkerStats {
                worker: i,
                requests: s.metrics.requests.load(r),
                failed_requests: s.metrics.failed_requests.load(r),
                batches: s.metrics.batches.load(r),
                padded_slots: s.metrics.padded_slots.load(r),
                retried_batches: s.metrics.retried_batches.load(r),
                requeued_requests: s.metrics.requeued_requests.load(r),
                inflight: s.inflight.load(r),
                outstanding_cost: s.outstanding_cost.load(r),
                quarantined: s
                    .quarantined(self.quarantine_after, self.quarantine_expiry),
            })
            .collect()
    }

    /// Stop dispatcher and workers (drains in-flight requests first).
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        drop(self.tx.take());
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.worker_joins.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Deliver an error reply for `r` and record it as a terminal failure
/// on `metrics`. `deadline` distinguishes the deadline-expired counter
/// from the overload counter.
fn reject(r: Request, metrics: &Metrics, err: String, deadline: bool) {
    let queue_us = r.submitted.elapsed().as_micros() as u64;
    if deadline {
        metrics.deadline_expired.fetch_add(1, Ordering::Relaxed);
    } else {
        metrics.rejected_overload.fetch_add(1, Ordering::Relaxed);
    }
    metrics.record_failed();
    let _ = r.reply.send(Reply {
        result: Err(err),
        queue_us,
        batch_fill: 0,
        cost: r.cost,
        trace_id: r.trace.trace_id,
    });
}

/// If `r`'s deadline has already passed, deliver the deadline-exceeded
/// error and consume it; otherwise hand the request back.
fn admit_deadline(r: Request, metrics: &Metrics) -> Option<Request> {
    match r.deadline {
        Some(d) if Instant::now() >= d => {
            let queue_us = r.submitted.elapsed().as_micros() as u64;
            reject(
                r,
                metrics,
                format!("{ERR_DEADLINE_PREFIX}: request spent {queue_us} us queued"),
                true,
            );
            None
        }
        _ => Some(r),
    }
}

/// Pick the worker for one admitted request. Quarantined workers — and
/// the worker a requeued request just failed on (`exclude`) — are
/// skipped while at least one other worker remains; with none, the
/// pool routes as if all were healthy (degraded service beats none).
/// `candidates` is a caller-owned scratch buffer (cleared and refilled
/// here) so the dispatch hot path allocates nothing per request.
#[allow(clippy::too_many_arguments)]
fn pick_worker(
    states: &[Arc<WorkerState>],
    policy: BalancePolicy,
    cost: Option<CostEstimate>,
    rr: &mut usize,
    quarantine_after: u64,
    quarantine_expiry: Option<Duration>,
    exclude: Option<usize>,
    candidates: &mut Vec<usize>,
) -> usize {
    candidates.clear();
    candidates.extend((0..states.len()).filter(|&i| {
        states[i].alive()
            && Some(i) != exclude
            && !states[i].quarantined(quarantine_after, quarantine_expiry)
    }));
    if candidates.is_empty() {
        // every live non-excluded worker quarantined: degraded service
        // beats none, but still prefer *live* workers over dead ones
        candidates.extend(
            (0..states.len())
                .filter(|&i| states[i].alive() && Some(i) != exclude),
        );
    }
    if candidates.is_empty() {
        // no live alternative: honor the exclusion before falling back
        // to "anyone" (a pick whose thread is gone gets a terminal
        // error at send time)
        candidates.extend((0..states.len()).filter(|&i| Some(i) != exclude));
    }
    if candidates.is_empty() {
        candidates.extend(0..states.len());
    }

    let cost_aware = policy == BalancePolicy::CostAware && cost.is_some();
    if !cost_aware {
        let pick = candidates[*rr % candidates.len()];
        *rr += 1;
        return pick;
    }

    // Least outstanding predicted cycles; ties broken by fewest
    // in-flight requests, then lowest worker index (deterministic).
    let mut best = candidates[0];
    let mut best_key = (
        states[best].outstanding_cost.load(Ordering::Relaxed),
        states[best].inflight.load(Ordering::Relaxed),
    );
    for &i in candidates.iter().skip(1) {
        let key = (
            states[i].outstanding_cost.load(Ordering::Relaxed),
            states[i].inflight.load(Ordering::Relaxed),
        );
        if key < best_key {
            best = i;
            best_key = key;
        }
    }
    best
}

/// Dispatcher: drain the shared admission queue (and, in a requeue-
/// enabled pool, the workers' requeue channel), run admission checks
/// (deadline, overload), attach cost estimates, and route each request
/// to a worker channel. Never blocks on a worker — channels are
/// unbounded, so a slow worker only grows its own queue.
fn dispatch_loop(
    rx: Receiver<Request>,
    requeue_rx: Option<Receiver<Request>>,
    worker_txs: Vec<Sender<Request>>,
    states: Vec<Arc<WorkerState>>,
    cfg: CoordinatorConfig,
    cost_model: Option<CostModel>,
    metrics: Arc<Metrics>,
) {
    let mut rr = 0usize;
    let mut scratch: Vec<usize> = Vec::with_capacity(states.len());
    // Tracing state for this dispatcher thread: its own ring, created
    // once. Untraced pools (`cfg.trace` None) skip every span below at
    // the cost of one Option check.
    let trace = cfg.trace.clone();
    let dbuf = trace.as_ref().map(|t| t.buffer("dispatch"));

    // Route one admitted request. Requeued requests skip the overload
    // gate: they were admitted once already, their original charge is
    // settled, and turning a near-success into an overload error would
    // make the requeue path strictly worse than delivering the backend
    // error.
    let handle = |mut r: Request,
                  requeued: bool,
                  rr: &mut usize,
                  scratch: &mut Vec<usize>| {
        let admit_span = match &trace {
            Some(t) => t.begin(r.trace.trace_id, r.trace.parent, "pool.admit"),
            None => obs::ActiveSpan::INERT,
        };
        if r.cost.is_none() {
            if let Some(m) = &cost_model {
                r.cost = Some(m.estimate(&r.image));
            }
        }
        let Some(mut r) = admit_deadline(r, &metrics) else {
            if let (Some(t), Some(buf)) = (&trace, &dbuf) {
                t.end(buf, admit_span, &[("admitted", 0)]);
            }
            return;
        };
        // Cost-aware admission: reject outright when the pool's
        // predicted backlog is already past the limit.
        if !requeued && cfg.max_outstanding_cost > 0.0 && r.cost.is_some() {
            let outstanding: u64 = states
                .iter()
                .map(|s| s.outstanding_cost.load(Ordering::Relaxed))
                .sum();
            if outstanding as f64 >= cfg.max_outstanding_cost {
                reject(
                    r,
                    &metrics,
                    format!(
                        "{ERR_OVERLOAD_PREFIX}: {outstanding} predicted cycles \
                         outstanding (admission limit {})",
                        cfg.max_outstanding_cost
                    ),
                    false,
                );
                if let (Some(t), Some(buf)) = (&trace, &dbuf) {
                    t.end(buf, admit_span, &[("admitted", 0)]);
                }
                return;
            }
        }
        let wi = pick_worker(
            &states,
            cfg.balance,
            r.cost,
            rr,
            cfg.quarantine_after,
            cfg.quarantine_expiry,
            r.exclude,
            scratch,
        );
        // Downstream spans (pool.queue/pool.exec on the worker) nest
        // under this admission span.
        if admit_span.is_recording() {
            r.trace.parent = admit_span.span_id;
        }
        states[wi].charge(r.cost);
        // A send failure means the worker thread died (e.g. backend
        // construction panicked): settle the charge and deliver a
        // terminal error so the request stays visible in the metrics
        // instead of vanishing into a closed reply channel.
        if let Err(failed) = worker_txs[wi].send(r) {
            let r = failed.0;
            states[wi].settle(r.cost);
            let queue_us = r.submitted.elapsed().as_micros() as u64;
            metrics.record_failed();
            let _ = r.reply.send(Reply {
                result: Err(format!(
                    "worker {wi} unavailable: its thread exited \
                     (backend construction failed or panicked)"
                )),
                queue_us,
                batch_fill: 0,
                cost: r.cost,
                trace_id: r.trace.trace_id,
            });
        }
        if let (Some(t), Some(buf)) = (&trace, &dbuf) {
            t.end(
                buf,
                admit_span,
                &[("admitted", 1), ("worker", wi as u64), ("requeued", requeued as u64)],
            );
        }
    };

    let Some(qrx) = requeue_rx else {
        // No requeue path (single worker or max_requeues == 0): the
        // original blocking loop, unchanged.
        while let Ok(r) = rx.recv() {
            handle(r, false, &mut rr, &mut scratch);
        }
        return;
        // Worker channels drop with `worker_txs`; each worker drains
        // its queue and exits.
    };

    // In-flight requests on *live* workers only: a crashed worker's
    // charges can never settle, and its requests are already lost (the
    // reply senders dropped with its queue), so they must not keep the
    // dispatcher polling or block shutdown.
    let live_inflight = |states: &[Arc<WorkerState>]| -> u64 {
        states
            .iter()
            .filter(|s| s.alive())
            .map(|s| s.inflight_acq())
            .sum()
    };

    const POLL: Duration = Duration::from_millis(1);
    loop {
        // Requeued requests first — they have already waited through a
        // failed batch.
        while let Ok(r) = qrx.try_recv() {
            handle(r, true, &mut rr, &mut scratch);
        }
        if live_inflight(&states) > 0 {
            // Work in flight may still requeue: poll so those requests
            // are picked up promptly.
            match rx.recv_timeout(POLL) {
                Ok(r) => handle(r, false, &mut rr, &mut scratch),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        } else {
            // Nothing in flight. Workers send a requeue *before*
            // settling its charge, so a zero in-flight count (Acquire)
            // proves every requeue is already in the channel — one last
            // look, then an idle pool can block without polling.
            if let Ok(r) = qrx.try_recv() {
                handle(r, true, &mut rr, &mut scratch);
                continue;
            }
            match rx.recv() {
                Ok(r) => handle(r, false, &mut rr, &mut scratch),
                Err(_) => break,
            }
        }
    }
    // Shutdown drain: the admission queue is closed, but batches still
    // in flight may yet fail and requeue. Keep serving the requeue
    // channel until no routed request on a live worker remains
    // unsettled (send-before-settle makes the final try_recv drain
    // complete, as above); anything it routes re-raises the count and
    // the loop continues.
    loop {
        while let Ok(r) = qrx.try_recv() {
            handle(r, true, &mut rr, &mut scratch);
        }
        if live_inflight(&states) == 0 {
            let mut routed_any = false;
            while let Ok(r) = qrx.try_recv() {
                handle(r, true, &mut rr, &mut scratch);
                routed_any = true;
            }
            if !routed_any {
                break;
            }
        } else if let Ok(r) = qrx.recv_timeout(POLL) {
            handle(r, true, &mut rr, &mut scratch);
        }
    }
    // Dropping `worker_txs` now lets the workers drain and exit.
}

/// One pool worker: own backend, own batcher, own retries, own metrics
/// shard. Structurally the PR 2 `batch_loop` — single-worker pools run
/// the exact same code path over the same channel contents.
/// `requeue_tx` (pools only) carries requests from a terminally-failed
/// batch back to the dispatcher for a different worker.
fn worker_loop<B: InferBackend>(
    worker: usize,
    backend: B,
    rx: Receiver<Request>,
    cfg: CoordinatorConfig,
    state: Arc<WorkerState>,
    requeue_tx: Option<Sender<Request>>,
) {
    let bs = backend.batch_size();
    let in_len = backend.input_len();
    let out_len = backend.output_len();
    let metrics = state.metrics.clone();
    // This worker's own span ring; one find-or-create at startup.
    let trace = cfg.trace.clone();
    let wbuf = trace
        .as_ref()
        .map(|t| t.buffer(&format!("worker-{worker}")));

    // Worker-side admission: a request that sat in this worker's queue
    // past its deadline is rejected with a timely error (and its load
    // accounting settled).
    let admit = |r: Request| -> Option<Request> {
        let cost = r.cost;
        match admit_deadline(r, &metrics) {
            Some(r) => Some(r),
            None => {
                state.settle(cost);
                None
            }
        }
    };

    loop {
        // Block for the first request of a batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // dispatcher exited
        };
        let mut pending: Vec<Request> = admit(first).into_iter().collect();
        let fill_deadline = Instant::now() + cfg.max_wait;
        // Fill until full, the batcher wait elapses, or the earliest
        // pending request deadline arrives — a near-deadline request
        // fires its batch early (padded) rather than waiting it out.
        while pending.len() < bs {
            let now = Instant::now();
            let mut until = fill_deadline;
            for r in &pending {
                if let Some(d) = r.deadline {
                    until = until.min(d);
                }
            }
            if now >= until {
                break;
            }
            match rx.recv_timeout(until - now) {
                Ok(r) => {
                    if let Some(r) = admit(r) {
                        pending.push(r);
                    }
                }
                Err(_) => break, // timeout or disconnect: run what we have
            }
        }
        if pending.is_empty() {
            continue;
        }

        // Assemble padded batch.
        let mut batch = vec![0.0f32; bs * in_len];
        for (i, r) in pending.iter().enumerate() {
            debug_assert_eq!(r.image.len(), in_len);
            batch[i * in_len..(i + 1) * in_len].copy_from_slice(&r.image);
        }
        let fill = pending.len();
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics
            .padded_slots
            .fetch_add((bs - fill) as u64, Ordering::Relaxed);
        metrics.record_batch_fill(fill);

        // Execute; a failed batch is re-run up to `max_retries` times on
        // this worker before the error is delivered to every requester.
        let exec_start_us = trace.as_ref().map(|t| t.now_us()).unwrap_or(0);
        let mut outcome = backend.run_batch(&batch);
        let mut attempts = 0u32;
        while outcome.is_err() && attempts < cfg.max_retries {
            attempts += 1;
            metrics.retried_batches.fetch_add(1, Ordering::Relaxed);
            eprintln!(
                "[coordinator] batch failed ({}); retry {attempts}/{}",
                outcome.as_ref().err().map(String::as_str).unwrap_or(""),
                cfg.max_retries
            );
            // Per-request retry instants: each trace in the batch sees
            // its own marker (the batch spans several traces).
            if let (Some(t), Some(buf)) = (&trace, &wbuf) {
                for r in &pending {
                    let now = t.now_us();
                    t.record(
                        buf,
                        r.trace.trace_id,
                        r.trace.parent,
                        "pool.retry",
                        now,
                        0,
                        &[("attempt", attempts as u64)],
                    );
                }
            }
            outcome = backend.run_batch(&batch);
        }

        match outcome {
            Ok(out) => {
                state.note_batch_success();
                for (i, r) in pending.into_iter().enumerate() {
                    let logits = out[i * out_len..(i + 1) * out_len].to_vec();
                    let queue_us = r.submitted.elapsed().as_micros() as u64;
                    state.settle(r.cost);
                    metrics.requests.fetch_add(1, Ordering::Relaxed);
                    metrics.record_latency_us(queue_us as f64);
                    // Two spans per served request: queue (submit →
                    // execution start, under the admission span) and
                    // exec (the batch run, under the queue span).
                    if let (Some(t), Some(buf)) = (&trace, &wbuf) {
                        let qid = t.record(
                            buf,
                            r.trace.trace_id,
                            r.trace.parent,
                            "pool.queue",
                            r.t_submit_us,
                            exec_start_us.saturating_sub(r.t_submit_us),
                            &[],
                        );
                        let now = t.now_us();
                        t.record(
                            buf,
                            r.trace.trace_id,
                            qid,
                            "pool.exec",
                            exec_start_us,
                            now.saturating_sub(exec_start_us),
                            &[
                                ("fill", fill as u64),
                                ("attempts", attempts as u64 + 1),
                            ],
                        );
                    }
                    let _ = r.reply.send(Reply {
                        result: Ok(logits),
                        queue_us,
                        batch_fill: fill,
                        cost: r.cost,
                        trace_id: r.trace.trace_id,
                    });
                }
            }
            Err(e) => {
                // This worker is out of retries. Requests that still
                // have requeue budget go back to the dispatcher for a
                // *different* worker; the rest get the cause delivered
                // — a dropped sender would only show them an opaque
                // closed channel.
                state.note_batch_failure(cfg.quarantine_after);
                eprintln!(
                    "[coordinator] batch failed after {} attempt(s): {e}",
                    attempts + 1
                );
                for mut r in pending.into_iter() {
                    if let Some(qtx) = requeue_tx
                        .as_ref()
                        .filter(|_| r.requeues < cfg.max_requeues)
                    {
                        r.requeues += 1;
                        r.exclude = Some(worker);
                        let cost = r.cost;
                        // Requeue instant: same trace ID — the rescued
                        // request's whole journey stays one trace.
                        if let (Some(t), Some(buf)) = (&trace, &wbuf) {
                            let now = t.now_us();
                            t.record(
                                buf,
                                r.trace.trace_id,
                                r.trace.parent,
                                "pool.requeue",
                                now,
                                0,
                                &[
                                    ("from_worker", worker as u64),
                                    ("requeues", r.requeues as u64),
                                ],
                            );
                        }
                        match qtx.send(r) {
                            Ok(()) => {
                                // Send happens *before* settle: the
                                // dispatcher's shutdown drain relies on
                                // "zero in-flight implies every requeue
                                // is already in the channel".
                                state.settle(cost);
                                metrics
                                    .requeued_requests
                                    .fetch_add(1, Ordering::Relaxed);
                                continue;
                            }
                            Err(failed) => {
                                // Dispatcher gone (cannot normally
                                // happen while our requests are
                                // unsettled): fall through to a
                                // terminal error.
                                r = failed.0;
                            }
                        }
                    }
                    let queue_us = r.submitted.elapsed().as_micros() as u64;
                    state.settle(r.cost);
                    metrics.record_failed();
                    let _ = r.reply.send(Reply {
                        result: Err(e.clone()),
                        queue_us,
                        batch_fill: fill,
                        cost: r.cost,
                        trace_id: r.trace.trace_id,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Identity-ish mock: logit k = sum(image) + k.
    struct MockBackend {
        in_len: usize,
        out_len: usize,
        batch: usize,
        calls: Arc<AtomicU64>,
        delay: Duration,
        fail: bool,
    }

    impl InferBackend for MockBackend {
        fn input_len(&self) -> usize {
            self.in_len
        }
        fn output_len(&self) -> usize {
            self.out_len
        }
        fn batch_size(&self) -> usize {
            self.batch
        }
        fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            if !self.delay.is_zero() {
                std::thread::sleep(self.delay);
            }
            if self.fail {
                return Err("mock backend configured to fail".to_string());
            }
            assert_eq!(batch.len(), self.batch * self.in_len);
            let mut out = Vec::with_capacity(self.batch * self.out_len);
            for i in 0..self.batch {
                let s: f32 = batch[i * self.in_len..(i + 1) * self.in_len]
                    .iter()
                    .sum();
                for k in 0..self.out_len {
                    out.push(s + k as f32);
                }
            }
            Ok(out)
        }
    }

    fn mock(batch: usize, calls: Arc<AtomicU64>) -> MockBackend {
        MockBackend {
            in_len: 4,
            out_len: 3,
            batch,
            calls,
            delay: Duration::ZERO,
            fail: false,
        }
    }

    #[test]
    fn single_request_roundtrip() {
        let calls = Arc::new(AtomicU64::new(0));
        let calls2 = calls.clone();
        let c = Coordinator::start(move || mock(4, calls2), Duration::from_millis(5));
        let rx = c.submit(vec![1.0, 2.0, 3.0, 4.0]);
        let reply = rx.recv().unwrap();
        assert_eq!(reply.logits(), &[10.0, 11.0, 12.0][..]);
        assert_eq!(reply.batch_fill, 1);
        c.shutdown();
        assert_eq!(calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn batching_coalesces_requests() {
        let calls = Arc::new(AtomicU64::new(0));
        let calls2 = calls.clone();
        let c = Coordinator::start(move || mock(4, calls2), Duration::from_millis(200));
        let rxs: Vec<_> = (0..4)
            .map(|i| c.submit(vec![i as f32; 4]))
            .collect();
        let replies: Vec<Reply> = rxs.iter().map(|r| r.recv().unwrap()).collect();
        for (i, rep) in replies.iter().enumerate() {
            assert_eq!(rep.logits()[0], 4.0 * i as f32);
            assert_eq!(rep.batch_fill, 4);
        }
        c.shutdown();
        // all four requests fit one batch
        assert_eq!(calls.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn partial_batch_fires_on_timeout() {
        let calls = Arc::new(AtomicU64::new(0));
        let calls2 = calls.clone();
        let c = Coordinator::start(move || mock(8, calls2), Duration::from_millis(10));
        let rx = c.submit(vec![0.5; 4]);
        let reply = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(reply.batch_fill, 1);
        c.shutdown();
        let m = calls.load(Ordering::Relaxed);
        assert_eq!(m, 1);
    }

    /// Backend that always fails; its error must reach every requester.
    struct FailingBackend;

    impl InferBackend for FailingBackend {
        fn input_len(&self) -> usize {
            2
        }
        fn output_len(&self) -> usize {
            1
        }
        fn batch_size(&self) -> usize {
            2
        }
        fn run_batch(&self, _batch: &[f32]) -> Result<Vec<f32>, String> {
            Err("backend exploded".to_string())
        }
    }

    #[test]
    fn failed_batch_reports_error_to_requesters() {
        let c = Coordinator::start(|| FailingBackend, Duration::from_millis(5));
        let rx1 = c.submit(vec![1.0, 2.0]);
        let rx2 = c.submit(vec![3.0, 4.0]);
        for rx in [rx1, rx2] {
            let reply = rx
                .recv_timeout(Duration::from_secs(5))
                .expect("reply must be delivered, not dropped");
            let err = reply.result.expect_err("must carry the backend error");
            assert!(err.contains("backend exploded"), "{err}");
        }
        assert_eq!(c.metrics.failed_requests.load(Ordering::Relaxed), 2);
        // failures still count as terminally-replied requests, so the
        // failure rate failed/requests stays coherent (2/2 here)
        assert_eq!(c.metrics.requests.load(Ordering::Relaxed), 2);
        c.shutdown();
    }

    #[test]
    fn metrics_track_requests_and_padding() {
        let calls = Arc::new(AtomicU64::new(0));
        let c = Coordinator::start(move || mock(4, calls), Duration::from_millis(10));
        for _ in 0..2 {
            let rx = c.submit(vec![0.0; 4]);
            rx.recv().unwrap();
        }
        let reqs = c.metrics.requests.load(Ordering::Relaxed);
        let pads = c.metrics.padded_slots.load(Ordering::Relaxed);
        assert_eq!(reqs, 2);
        assert!(pads >= 4, "pads={pads}"); // two batches of fill 1
        assert!(c.metrics.latency_summary().len() == 2);
        c.shutdown();
    }

    #[test]
    fn cost_model_estimates_scale_with_input_zeros() {
        let m = CostModel {
            dense_cycles: 1000.0,
            dense_energy_pj: 400.0,
            skip_slope: 1.0,
            energy_skip_slope: 0.5,
        };
        let dense = m.estimate(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(dense.input_zero_fraction, 0.0);
        assert_eq!(dense.est_cycles, 1000.0);
        let half = m.estimate(&[0.0, 0.0, 3.0, 4.0]);
        assert!((half.input_zero_fraction - 0.5).abs() < 1e-12);
        assert!((half.est_cycles - 500.0).abs() < 1e-9);
        assert!(half.est_energy_pj < dense.est_energy_pj);
        // kept work clamps at zero even for an extreme slope
        let all = m.estimate(&[0.0; 4]);
        assert_eq!(all.est_cycles, 0.0);
    }

    #[test]
    fn cost_model_pipeline_speedup_scales_cycles_only() {
        let m = CostModel {
            dense_cycles: 1000.0,
            dense_energy_pj: 400.0,
            skip_slope: 0.0,
            energy_skip_slope: 0.0,
        };
        let fast = m.clone().with_pipeline_speedup(2.0);
        assert!((fast.dense_cycles - 500.0).abs() < 1e-9);
        assert_eq!(fast.dense_energy_pj, 400.0);
        // no-speedup, sub-unity and pathological inputs are ignored
        for s in [1.0, 0.5, 0.0, -3.0, f64::NAN, f64::INFINITY] {
            let same = m.clone().with_pipeline_speedup(s);
            assert_eq!(same.dense_cycles, 1000.0, "speedup {s}");
        }
    }

    #[test]
    fn cost_model_from_sim_restores_dense_schedule() {
        use crate::sim::{LayerSimResult, NetworkSimResult};
        use crate::xbar::energy::EnergyLedger;
        let r = NetworkSimResult {
            scheme: "pattern".into(),
            network: "t".into(),
            layers: vec![LayerSimResult {
                layer_idx: 0,
                ou_ops: 80.0,
                skipped_ou_ops: 20.0,
                cycles: 80.0,
                energy: EnergyLedger { adc_pj: 8.0, dac_pj: 0.0, rram_pj: 0.0 },
                n_crossbars: 1,
            }],
        };
        // the calibration trace skipped 20% of the schedule at a 0.2
        // input zero fraction -> slope 1, dense = observed / 0.8
        let m = CostModel::from_sim(&r, 0.2);
        assert!((m.dense_cycles - 100.0).abs() < 1e-9, "{}", m.dense_cycles);
        assert!((m.dense_energy_pj - 10.0).abs() < 1e-9);
        assert!((m.skip_slope - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cost_model_from_calibration_sums_layer_fits() {
        use crate::sim::{CostCalibration, LayerCalibration};
        let c = CostCalibration {
            layers: vec![
                LayerCalibration {
                    layer_idx: 0,
                    cycles_at_dense: 600.0,
                    cycles_slope: -300.0,
                    energy_at_dense_pj: 60.0,
                    energy_slope_pj: -30.0,
                    n_samples: 8,
                },
                LayerCalibration {
                    layer_idx: 1,
                    cycles_at_dense: 400.0,
                    cycles_slope: -200.0,
                    energy_at_dense_pj: 40.0,
                    energy_slope_pj: -20.0,
                    n_samples: 8,
                },
            ],
        };
        let m = CostModel::from_calibration(&c);
        assert!((m.dense_cycles - 1000.0).abs() < 1e-9);
        assert!((m.dense_energy_pj - 100.0).abs() < 1e-9);
        // slope -500 cycles per unit zf on a 1000-cycle dense schedule
        assert!((m.skip_slope - 0.5).abs() < 1e-12, "{}", m.skip_slope);
        // energy gets its own fitted slope: -50 pJ per unit zf on 100 pJ
        assert!(
            (m.energy_skip_slope - 0.5).abs() < 1e-12,
            "{}",
            m.energy_skip_slope
        );
        // the estimate reproduces the summed regression lines
        let est = m.estimate(&[0.0, 1.0]); // zf = 0.5
        assert!((est.est_cycles - 750.0).abs() < 1e-9, "{}", est.est_cycles);
        assert!((est.est_energy_pj - 75.0).abs() < 1e-9, "{}", est.est_energy_pj);
    }

    #[test]
    fn alarm_threshold_accessors() {
        let m = Metrics::default();
        assert!(!m.failed_alarm());
        m.set_alarm_threshold(2);
        assert_eq!(m.alarm_threshold(), 2);
        m.record_failed();
        assert!(!m.failed_alarm());
        m.record_failed();
        assert!(m.failed_alarm());
    }

    #[test]
    fn metrics_merge_sums_counters_and_latencies() {
        let a = Metrics::default();
        let b = Metrics::default();
        a.requests.store(3, Ordering::Relaxed);
        a.batches.store(2, Ordering::Relaxed);
        a.retried_batches.store(1, Ordering::Relaxed);
        a.record_latency_us(10.0);
        a.record_latency_us(20.0);
        a.record_latency_us(30.0);
        b.requests.store(2, Ordering::Relaxed);
        b.failed_requests.store(1, Ordering::Relaxed);
        b.deadline_expired.store(1, Ordering::Relaxed);
        b.set_alarm_threshold(4);
        b.record_latency_us(40.0);
        let m = Metrics::merge([&a, &b]);
        assert_eq!(m.requests.load(Ordering::Relaxed), 5);
        assert_eq!(m.batches.load(Ordering::Relaxed), 2);
        assert_eq!(m.retried_batches.load(Ordering::Relaxed), 1);
        assert_eq!(m.failed_requests.load(Ordering::Relaxed), 1);
        assert_eq!(m.deadline_expired.load(Ordering::Relaxed), 1);
        assert_eq!(m.alarm_threshold(), 4);
        let lat = m.latency_summary();
        assert_eq!(lat.len(), 4);
        assert!((lat.mean() - 25.0).abs() < 1e-12);
        // the histogram carries the same totals (exact count/mean even
        // past the reservoir cap)
        let snap = m.snapshot();
        assert_eq!(snap.latency_count, 4);
        assert!((snap.latency_mean_us - 25.0).abs() < 1e-12);
        assert!((snap.latency_sum_us - 100.0).abs() < 1e-12);
        assert_eq!(snap.latency_max_us, 40.0);
    }

    /// A worker that panics while holding the latency lock must not
    /// wedge `latency_summary`/`merge` (and thus `merged_metrics` /
    /// `worker_stats`) for the surviving pool: the poisoned summary is
    /// recovered, not unwrapped.
    #[test]
    fn poisoned_latency_shard_does_not_wedge_survivors() {
        let a = Arc::new(Metrics::default());
        a.record_latency_us(10.0);
        let shard = Arc::clone(&a);
        let worker = std::thread::spawn(move || {
            let _guard = shard.telemetry.lock();
            panic!("worker dies holding the latency lock");
        });
        assert!(worker.join().is_err(), "worker must have panicked");

        // all three read paths survive the poisoned shard
        let summary = a.latency_summary();
        assert_eq!(summary.len(), 1);
        a.record_latency_us(20.0);
        let b = Metrics::default();
        b.record_latency_us(30.0);
        let merged = Metrics::merge([a.as_ref(), &b]);
        assert_eq!(merged.latency_summary().len(), 3);
    }

    /// The merge-without-double-counting invariant end to end: a batch
    /// that fails once and succeeds on retry contributes each of its
    /// requests' latencies exactly once, and one retried batch — not
    /// one per request, not one per attempt per request.
    #[test]
    fn merge_counts_each_request_once_despite_retries() {
        struct FlakyOnce {
            calls: Arc<AtomicU64>,
        }
        impl InferBackend for FlakyOnce {
            fn input_len(&self) -> usize {
                2
            }
            fn output_len(&self) -> usize {
                1
            }
            fn batch_size(&self) -> usize {
                2
            }
            fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
                if self.calls.fetch_add(1, Ordering::Relaxed) == 0 {
                    return Err("first call fails".to_string());
                }
                Ok(vec![batch[0] + batch[1], batch[2] + batch[3]])
            }
        }
        let calls = Arc::new(AtomicU64::new(0));
        let c = Coordinator::start_with(
            move || FlakyOnce { calls },
            CoordinatorConfig {
                max_wait: Duration::from_millis(200),
                max_retries: 1,
                ..Default::default()
            },
            None,
        );
        let rx1 = c.submit(vec![1.0, 2.0]);
        let rx2 = c.submit(vec![3.0, 4.0]);
        rx1.recv_timeout(Duration::from_secs(10)).unwrap();
        rx2.recv_timeout(Duration::from_secs(10)).unwrap();
        let merged = c.merged_metrics();
        assert_eq!(merged.requests.load(Ordering::Relaxed), 2);
        assert_eq!(merged.retried_batches.load(Ordering::Relaxed), 1);
        assert_eq!(merged.batches.load(Ordering::Relaxed), 1);
        // one latency sample per request, not per attempt
        assert_eq!(merged.latency_summary().len(), 2);
        c.shutdown();
    }

    #[test]
    fn many_threads_submit_concurrently() {
        let calls = Arc::new(AtomicU64::new(0));
        let c = Arc::new(Coordinator::start(
            move || mock(4, calls),
            Duration::from_millis(2),
        ));
        let mut handles = Vec::new();
        for t in 0..8 {
            let c2 = c.clone();
            handles.push(std::thread::spawn(move || {
                let rx = c2.submit(vec![t as f32; 4]);
                let rep = rx.recv().unwrap();
                assert_eq!(rep.logits()[0], 4.0 * t as f32);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.metrics.requests.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn pool_round_robin_distributes_across_workers() {
        let calls = Arc::new(AtomicU64::new(0));
        let calls2 = calls.clone();
        let c = Coordinator::start_pool(
            move |_worker| MockBackend {
                in_len: 4,
                out_len: 3,
                batch: 1,
                calls: calls2.clone(),
                delay: Duration::ZERO,
                fail: false,
            },
            CoordinatorConfig {
                max_wait: Duration::from_millis(1),
                workers: 4,
                balance: BalancePolicy::RoundRobin,
                ..Default::default()
            },
            None,
        );
        assert_eq!(c.n_workers(), 4);
        // sequential submit+recv: each request is routed (and finished)
        // before the next, so round-robin placement is deterministic
        for i in 0..8 {
            let rx = c.submit(vec![i as f32; 4]);
            let rep = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(rep.logits()[0], 4.0 * i as f32);
        }
        for shard in c.worker_metrics() {
            assert_eq!(shard.requests.load(Ordering::Relaxed), 2);
        }
        let merged = c.merged_metrics();
        assert_eq!(merged.requests.load(Ordering::Relaxed), 8);
        assert_eq!(merged.latency_summary().len(), 8);
        c.shutdown();
        assert_eq!(calls.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn pick_worker_prefers_least_outstanding_cost() {
        let states: Vec<Arc<WorkerState>> = (0..3)
            .map(|_| Arc::new(WorkerState::new(Arc::new(Metrics::default()))))
            .collect();
        states[0].outstanding_cost.store(500, Ordering::Relaxed);
        states[1].outstanding_cost.store(100, Ordering::Relaxed);
        states[2].outstanding_cost.store(300, Ordering::Relaxed);
        let est = Some(CostEstimate {
            est_cycles: 10.0,
            est_energy_pj: 1.0,
            input_zero_fraction: 0.0,
        });
        let mut rr = 0usize;
        let mut scratch = Vec::new();
        let pick = pick_worker(
            &states,
            BalancePolicy::CostAware,
            est,
            &mut rr,
            0,
            None,
            None,
            &mut scratch,
        );
        assert_eq!(pick, 1);
        // quarantine the cheapest worker: next-least wins
        states[1]
            .consecutive_failed_batches
            .store(5, Ordering::Relaxed);
        let pick = pick_worker(
            &states,
            BalancePolicy::CostAware,
            est,
            &mut rr,
            2,
            None,
            None,
            &mut scratch,
        );
        assert_eq!(pick, 2);
        // without an estimate, cost-aware falls back to round-robin
        // over healthy workers (0 and 2)
        let a = pick_worker(
            &states,
            BalancePolicy::CostAware,
            None,
            &mut rr,
            2,
            None,
            None,
            &mut scratch,
        );
        let b = pick_worker(
            &states,
            BalancePolicy::CostAware,
            None,
            &mut rr,
            2,
            None,
            None,
            &mut scratch,
        );
        assert_ne!(a, b);
        assert!(a != 1 && b != 1);
        // all quarantined: degraded routing still picks someone
        for s in &states {
            s.consecutive_failed_batches.store(9, Ordering::Relaxed);
        }
        let pick = pick_worker(
            &states,
            BalancePolicy::CostAware,
            est,
            &mut rr,
            2,
            None,
            None,
            &mut scratch,
        );
        assert!(pick < 3);
    }

    #[test]
    fn pick_worker_honors_requeue_exclusion() {
        let states: Vec<Arc<WorkerState>> = (0..2)
            .map(|_| Arc::new(WorkerState::new(Arc::new(Metrics::default()))))
            .collect();
        // worker 0 is the cheapest, but a request that just failed
        // there must go to its sibling
        states[1].outstanding_cost.store(500, Ordering::Relaxed);
        let est = Some(CostEstimate {
            est_cycles: 10.0,
            est_energy_pj: 1.0,
            input_zero_fraction: 0.0,
        });
        let mut rr = 0usize;
        let mut scratch = Vec::new();
        for _ in 0..3 {
            let pick = pick_worker(
                &states,
                BalancePolicy::CostAware,
                est,
                &mut rr,
                0,
                None,
                Some(0),
                &mut scratch,
            );
            assert_eq!(pick, 1, "excluded worker must not be picked");
        }
        // a single-worker "pool" ignores the exclusion rather than
        // stranding the request
        let one = vec![states[0].clone()];
        let pick = pick_worker(
            &one,
            BalancePolicy::CostAware,
            est,
            &mut rr,
            0,
            None,
            Some(0),
            &mut scratch,
        );
        assert_eq!(pick, 0);
    }

    #[test]
    fn quarantine_expiry_paroles_worker_state() {
        let s = WorkerState::new(Arc::new(Metrics::default()));
        s.note_batch_failure(2);
        assert!(!s.quarantined(2, None), "below threshold");
        s.note_batch_failure(2);
        assert!(s.quarantined(2, None), "streak 2 >= threshold 2");
        // success-only policy never expires
        assert!(s.quarantined(2, None));
        // an already-elapsed expiry paroles immediately and resets the
        // streak, so the worker is not instantly re-quarantined
        assert!(!s.quarantined(2, Some(Duration::ZERO)));
        assert!(!s.quarantined(2, None), "streak was reset on parole");
        // a fresh quarantine with a long expiry stays in force
        s.note_batch_failure(1);
        assert!(s.quarantined(1, Some(Duration::from_secs(3600))));
        // success releases it regardless
        s.note_batch_success();
        assert!(!s.quarantined(1, Some(Duration::from_secs(3600))));
    }

    /// Regression for the retired 0-sentinel timestamp encoding: a
    /// worker quarantined within the first microsecond of its life used
    /// to stamp an entry time indistinguishable from "never
    /// quarantined", so expiry either never fired or fired instantly
    /// depending on the ±1 adjustments. "Never quarantined" is now an
    /// explicit `None`, so the earliest possible entry time behaves
    /// like any other.
    #[test]
    fn quarantine_entered_in_first_microsecond_expires_correctly() {
        // Enter quarantine as fast as the API allows after construction
        // — on any real machine this lands inside the first microsecond
        // of the state's life, the old encoding's degenerate case.
        let s = WorkerState::new(Arc::new(Metrics::default()));
        s.note_batch_failure(1);
        // A long expiry must hold the quarantine (not instantly parole
        // or report "never quarantined").
        assert!(s.quarantined(1, Some(Duration::from_secs(3600))));
        assert!(s.quarantined(1, None), "success-only policy holds too");
        // An already-elapsed expiry must parole exactly once the entry
        // time is reached — including an entry time of "now".
        assert!(!s.quarantined(1, Some(Duration::ZERO)));
        assert!(!s.quarantined(1, None), "streak reset on parole");
    }

    #[test]
    fn metrics_snapshot_flattens_empty_latency_to_zero() {
        let m = Metrics::default();
        let s = m.snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.latency_count, 0);
        assert_eq!(s.latency_mean_us, 0.0, "no NaN for empty samples");
        assert_eq!(s.latency_p99_us, 0.0);
        assert_eq!(s.latency_max_us, 0.0);
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.record_latency_us(100.0);
        m.record_latency_us(300.0);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert_eq!(s.latency_count, 2);
        assert_eq!(s.latency_mean_us, 200.0);
        assert_eq!(s.latency_max_us, 300.0);
        // exact p50 from the reservoir while it holds everything
        assert_eq!(s.latency_p50_us, 200.0);
        // cumulative buckets end at +Inf with the full count
        let last = s.latency_buckets.last().unwrap();
        assert!(last.0.is_infinite());
        assert_eq!(last.1, 2);
    }

    /// Cross-worker requeue end to end: a pool where worker 0 always
    /// fails must still answer every request successfully — the failed
    /// batch's requests are re-dispatched to the healthy sibling — and
    /// count each terminal reply exactly once.
    #[test]
    fn failed_batch_requeues_to_sibling_worker() {
        struct DirectedBackend {
            dead: bool,
        }
        impl InferBackend for DirectedBackend {
            fn input_len(&self) -> usize {
                2
            }
            fn output_len(&self) -> usize {
                1
            }
            fn batch_size(&self) -> usize {
                1
            }
            fn run_batch(&self, batch: &[f32]) -> Result<Vec<f32>, String> {
                if self.dead {
                    return Err("dead backend".to_string());
                }
                Ok(vec![batch[0] + batch[1]])
            }
        }
        let c = Coordinator::start_pool(
            |worker| DirectedBackend { dead: worker == 0 },
            CoordinatorConfig {
                max_wait: Duration::from_millis(1),
                max_retries: 0,
                workers: 2,
                balance: BalancePolicy::RoundRobin,
                quarantine_after: 0, // keep routing to the dead worker
                max_requeues: 1,
                ..Default::default()
            },
            None,
        );
        for i in 0..6 {
            let rx = c.submit(vec![i as f32, 1.0]);
            let rep = rx
                .recv_timeout(Duration::from_secs(10))
                .expect("terminal reply");
            let logits = rep.result.expect("requeue must rescue the request");
            assert_eq!(logits[0], i as f32 + 1.0);
        }
        let merged = c.merged_metrics();
        assert_eq!(merged.requests.load(Ordering::Relaxed), 6);
        assert_eq!(merged.failed_requests.load(Ordering::Relaxed), 0);
        // every request was first routed to the dead worker (each
        // failed round advances the round-robin counter twice, so the
        // next initial pick lands on worker 0 again) and rescued once
        assert_eq!(merged.requeued_requests.load(Ordering::Relaxed), 6);
        assert_eq!(merged.latency_summary().len(), 6, "one sample per request");
        // requeues recorded on the failing worker's shard, replies on
        // the rescuer's
        let shards = c.worker_metrics();
        assert_eq!(shards[0].requeued_requests.load(Ordering::Relaxed), 6);
        assert_eq!(shards[0].requests.load(Ordering::Relaxed), 0);
        assert_eq!(shards[1].requests.load(Ordering::Relaxed), 6);
        c.shutdown();
    }

    /// With the requeue budget exhausted the error is delivered: two
    /// dead workers out of two mean the requeued request fails on the
    /// sibling and must not ping-pong forever.
    #[test]
    fn requeue_budget_bounds_the_ping_pong() {
        struct AlwaysDead;
        impl InferBackend for AlwaysDead {
            fn input_len(&self) -> usize {
                2
            }
            fn output_len(&self) -> usize {
                1
            }
            fn batch_size(&self) -> usize {
                1
            }
            fn run_batch(&self, _batch: &[f32]) -> Result<Vec<f32>, String> {
                Err("dead backend".to_string())
            }
        }
        let c = Coordinator::start_pool(
            |_worker| AlwaysDead,
            CoordinatorConfig {
                max_wait: Duration::from_millis(1),
                max_retries: 0,
                workers: 2,
                balance: BalancePolicy::RoundRobin,
                quarantine_after: 0,
                max_requeues: 1,
                ..Default::default()
            },
            None,
        );
        let rep = c
            .submit(vec![1.0, 2.0])
            .recv_timeout(Duration::from_secs(10))
            .expect("terminal reply");
        let err = rep.result.expect_err("both workers dead");
        assert!(err.contains("dead backend"), "{err}");
        let merged = c.merged_metrics();
        assert_eq!(merged.requests.load(Ordering::Relaxed), 1);
        assert_eq!(merged.failed_requests.load(Ordering::Relaxed), 1);
        assert_eq!(merged.requeued_requests.load(Ordering::Relaxed), 1);
        c.shutdown();
    }

    #[test]
    fn overload_admission_rejects_past_cost_limit() {
        let calls = Arc::new(AtomicU64::new(0));
        let calls2 = calls.clone();
        let model = CostModel {
            dense_cycles: 1000.0,
            dense_energy_pj: 1.0,
            skip_slope: 0.0,
            energy_skip_slope: 0.0,
        };
        let c = Coordinator::start_pool(
            move |_worker| MockBackend {
                in_len: 2,
                out_len: 1,
                batch: 1,
                calls: calls2.clone(),
                delay: Duration::from_millis(300),
                fail: false,
            },
            CoordinatorConfig {
                max_wait: Duration::from_millis(1),
                workers: 1,
                // any outstanding request is already ≥ the limit
                max_outstanding_cost: 1.0,
                ..Default::default()
            },
            Some(model),
        );
        // first request is admitted (nothing outstanding yet) and holds
        // the worker for 300 ms; the next two hit the admission limit
        let rx_a = c.submit(vec![1.0, 2.0]);
        std::thread::sleep(Duration::from_millis(50));
        let rx_b = c.submit(vec![3.0, 4.0]);
        let rx_c = c.submit(vec![5.0, 6.0]);
        for rx in [rx_b, rx_c] {
            let rep = rx.recv_timeout(Duration::from_secs(10)).expect("reply");
            let err = rep.result.expect_err("must be rejected as overload");
            assert!(err.contains("overloaded"), "{err}");
            assert!(rep.cost.is_some(), "rejections still carry the estimate");
        }
        let rep_a = rx_a.recv_timeout(Duration::from_secs(10)).expect("reply");
        assert!(rep_a.result.is_ok());
        assert_eq!(c.metrics.rejected_overload.load(Ordering::Relaxed), 2);
        // once the backlog drains, admission opens again
        let rx_d = c.submit(vec![1.0, 1.0]);
        assert!(rx_d
            .recv_timeout(Duration::from_secs(10))
            .expect("reply")
            .result
            .is_ok());
        c.shutdown();
    }
}
