//! Configuration system: hardware (paper Table I), quantization, mapping
//! and simulation knobs, with JSON round-trip via [`crate::util::json`].

use crate::util::json::{obj, Json};

/// RRAM macro + converter parameters — paper Table I defaults.
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareConfig {
    /// Crossbar array rows (wordlines). Paper: 512.
    pub xbar_rows: usize,
    /// Crossbar array columns (bitlines). Paper: 512.
    pub xbar_cols: usize,
    /// Bits stored per RRAM cell. Paper: 4.
    pub cell_bits: usize,
    /// Weight precision in bits. Paper: 16 ("16 bits per weight").
    pub weight_bits: usize,
    /// Differential (G+/G-) cell pairs per slice. The paper's model
    /// ([18], 1T1M dot-product engine) is single-ended, so the paper
    /// experiments run with `false`; the SmallCNN functional path uses
    /// `true` to match the Pallas kernel's exact-zero semantics.
    pub differential: bool,
    /// Operation Unit rows (wordlines activated per cycle). Paper: 9.
    pub ou_rows: usize,
    /// Operation Unit cols (bitlines activated per cycle). Paper: 8.
    pub ou_cols: usize,
    /// ADC resolution (bits). Paper: 8.
    pub adc_bits: usize,
    /// ADC energy per conversion (pJ). Paper: 1.67.
    pub adc_pj_per_op: f64,
    /// ADC sample rate (GSps). Paper: 1.2.
    pub adc_gsps: f64,
    /// DAC resolution (bits). Paper: 4.
    pub dac_bits: usize,
    /// DAC energy per conversion (pJ). Paper: 0.0182.
    pub dac_pj_per_op: f64,
    /// DAC sample rate (MSps). Paper: 18.
    pub dac_msps: f64,
    /// RRAM array energy per full OU activation (pJ). Paper: 4.8.
    pub rram_pj_per_ou_op: f64,
    /// Input activation precision (bits); fed bit-serially through the
    /// `dac_bits` DAC over `input_bits / dac_bits` phases (ISAAC-style).
    pub input_bits: usize,
    /// CIM cores on the chip. `1` (the default) is the paper's
    /// monolithic accelerator; `> 1` turns on layer-to-core pipelining
    /// (see `sim::placement`). Cores sit on a linear NoC chain, so the
    /// hop count between cores `a` and `b` is `|a - b|`.
    pub cores: usize,
    /// Interconnect bandwidth between cores, in activation bytes per
    /// cycle. Transfers of `v` bytes across the NoC cost
    /// `v / noc_bandwidth` cycles of serialization.
    pub noc_bandwidth: f64,
    /// Per-hop NoC latency in cycles, charged once per hop a transfer
    /// crosses on the chain.
    pub noc_hop_latency: f64,
}

impl Default for HardwareConfig {
    fn default() -> Self {
        HardwareConfig {
            xbar_rows: 512,
            xbar_cols: 512,
            cell_bits: 4,
            weight_bits: 16,
            differential: false,
            ou_rows: 9,
            ou_cols: 8,
            adc_bits: 8,
            adc_pj_per_op: 1.67,
            adc_gsps: 1.2,
            dac_bits: 4,
            dac_pj_per_op: 0.0182,
            dac_msps: 18.0,
            rram_pj_per_ou_op: 4.8,
            input_bits: 8,
            cores: 1,
            noc_bandwidth: 32.0,
            noc_hop_latency: 4.0,
        }
    }
}

impl HardwareConfig {
    /// Cells occupied by one weight (bit-slicing × differential pairing).
    pub fn cells_per_weight(&self) -> usize {
        let slices = self.weight_bits.div_ceil(self.cell_bits);
        if self.differential {
            2 * slices
        } else {
            slices
        }
    }

    /// Crossbar capacity in *weights* per row.
    pub fn weights_per_row(&self) -> usize {
        self.xbar_cols / self.cells_per_weight()
    }

    /// Cells per crossbar.
    pub fn cells_per_xbar(&self) -> usize {
        self.xbar_rows * self.xbar_cols
    }

    /// DAC conversions needed to feed one input (bit-serial phases).
    pub fn dac_phases(&self) -> usize {
        self.input_bits.div_ceil(self.dac_bits)
    }

    /// Derive a config from `self` with different OU / crossbar
    /// geometry, validated — how the DSE sweep turns a grid point into
    /// a concrete hardware config without touching the converter or
    /// precision parameters of its base.
    pub fn with_dims(
        &self,
        ou_rows: usize,
        ou_cols: usize,
        xbar_rows: usize,
        xbar_cols: usize,
    ) -> Result<HardwareConfig, String> {
        let hw = HardwareConfig {
            ou_rows,
            ou_cols,
            xbar_rows,
            xbar_cols,
            ..self.clone()
        };
        hw.validate()?;
        Ok(hw)
    }

    /// Derive a config from `self` with a different multi-core block,
    /// validated — how the DSE sweep applies its `cores` ×
    /// interconnect axes without touching the macro parameters.
    pub fn with_cores(
        &self,
        cores: usize,
        noc_bandwidth: f64,
        noc_hop_latency: f64,
    ) -> Result<HardwareConfig, String> {
        let hw = HardwareConfig {
            cores,
            noc_bandwidth,
            noc_hop_latency,
            ..self.clone()
        };
        hw.validate()?;
        Ok(hw)
    }

    /// Config for the SmallCNN functional path, matching the Pallas
    /// kernel quantization (`python/compile/kernels/quant.py` defaults
    /// with `x_bits = 8`).
    pub fn smallcnn_functional() -> Self {
        HardwareConfig {
            weight_bits: 8,
            differential: true,
            input_bits: 8,
            ..Default::default()
        }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("xbar_rows", self.xbar_rows.into()),
            ("xbar_cols", self.xbar_cols.into()),
            ("cell_bits", self.cell_bits.into()),
            ("weight_bits", self.weight_bits.into()),
            ("differential", self.differential.into()),
            ("ou_rows", self.ou_rows.into()),
            ("ou_cols", self.ou_cols.into()),
            ("adc_bits", self.adc_bits.into()),
            ("adc_pj_per_op", self.adc_pj_per_op.into()),
            ("adc_gsps", self.adc_gsps.into()),
            ("dac_bits", self.dac_bits.into()),
            ("dac_pj_per_op", self.dac_pj_per_op.into()),
            ("dac_msps", self.dac_msps.into()),
            ("rram_pj_per_ou_op", self.rram_pj_per_ou_op.into()),
            ("input_bits", self.input_bits.into()),
            ("cores", self.cores.into()),
            ("noc_bandwidth", self.noc_bandwidth.into()),
            ("noc_hop_latency", self.noc_hop_latency.into()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let d = HardwareConfig::default();
        let u = |k: &str, dv: usize| j.get(k).as_usize().unwrap_or(dv);
        let f = |k: &str, dv: f64| j.get(k).as_f64().unwrap_or(dv);
        let cfg = HardwareConfig {
            xbar_rows: u("xbar_rows", d.xbar_rows),
            xbar_cols: u("xbar_cols", d.xbar_cols),
            cell_bits: u("cell_bits", d.cell_bits),
            weight_bits: u("weight_bits", d.weight_bits),
            differential: j.get("differential").as_bool().unwrap_or(d.differential),
            ou_rows: u("ou_rows", d.ou_rows),
            ou_cols: u("ou_cols", d.ou_cols),
            adc_bits: u("adc_bits", d.adc_bits),
            adc_pj_per_op: f("adc_pj_per_op", d.adc_pj_per_op),
            adc_gsps: f("adc_gsps", d.adc_gsps),
            dac_bits: u("dac_bits", d.dac_bits),
            dac_pj_per_op: f("dac_pj_per_op", d.dac_pj_per_op),
            dac_msps: f("dac_msps", d.dac_msps),
            rram_pj_per_ou_op: f("rram_pj_per_ou_op", d.rram_pj_per_ou_op),
            input_bits: u("input_bits", d.input_bits),
            cores: u("cores", d.cores),
            noc_bandwidth: f("noc_bandwidth", d.noc_bandwidth),
            noc_hop_latency: f("noc_hop_latency", d.noc_hop_latency),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.ou_rows == 0 || self.ou_cols == 0 {
            return Err("OU dimensions must be positive".into());
        }
        if self.ou_rows > self.xbar_rows || self.ou_cols > self.xbar_cols {
            return Err("OU must fit inside the crossbar".into());
        }
        if self.cell_bits == 0 || self.weight_bits == 0 {
            return Err("bit widths must be positive".into());
        }
        if self.cells_per_weight() > self.xbar_cols {
            return Err("one weight must fit in a crossbar row".into());
        }
        if self.ou_cols % self.cells_per_weight() != 0
            && self.cells_per_weight() % self.ou_cols != 0
        {
            return Err(format!(
                "ou_cols ({}) must align with cells_per_weight ({})",
                self.ou_cols,
                self.cells_per_weight()
            ));
        }
        if self.cores == 0 {
            return Err("core count must be positive".into());
        }
        if !(self.noc_bandwidth > 0.0) || !self.noc_bandwidth.is_finite() {
            return Err("noc_bandwidth must be positive and finite".into());
        }
        if !(self.noc_hop_latency >= 0.0) || !self.noc_hop_latency.is_finite()
        {
            return Err("noc_hop_latency must be non-negative and finite".into());
        }
        Ok(())
    }
}

/// Simulation knobs (activation model + scheduling overheads).
#[derive(Debug, Clone, PartialEq)]
pub struct SimConfig {
    /// Fraction of feature-map channels that are entirely dead
    /// (post-ReLU) in the synthetic activation trace.
    pub dead_channel_ratio: f64,
    /// Fraction of spatial area covered by zero blobs in live channels.
    pub zero_blob_ratio: f64,
    /// Extra control cycles charged when the OU scheduler crosses a
    /// pattern-block boundary (index decode + input-preprocessing
    /// reconfiguration). Applies to the pattern scheme only.
    pub block_switch_cycles: f64,
    /// Enable the Input Preprocessing Unit's all-zero detection
    /// (paper §IV-A). Applies to the pattern scheme only.
    pub zero_detection: bool,
    /// Positions sampled per layer for the analytic VGG16 runs
    /// (`None` = exact, every position).
    pub sample_positions: Option<usize>,
    /// RNG seed for traces.
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        // Activation-trace defaults are calibrated so the all-zero
        // detection contributes the modest share the paper reports (its
        // speedup is driven "mainly by the deleted all-zero patterns",
        // §V-C); ablation A2 sweeps zero_blob_ratio 0..0.9.
        SimConfig {
            dead_channel_ratio: 0.02,
            zero_blob_ratio: 0.08,
            block_switch_cycles: 2.0,
            zero_detection: true,
            sample_positions: Some(64),
            seed: 0x5EED,
        }
    }
}

impl SimConfig {
    /// Defaults with `n` sampled positions per layer (the analytic
    /// VGG16 runs' historical mode).
    pub fn sampled(n: usize) -> SimConfig {
        SimConfig { sample_positions: Some(n), ..Default::default() }
    }

    /// Defaults in exact trace mode: every output position is traced,
    /// no sampling scale is applied (`sample_positions: None`).
    pub fn exact() -> SimConfig {
        SimConfig { sample_positions: None, ..Default::default() }
    }

    /// `true` when this config traces every output position.
    pub fn is_exact(&self) -> bool {
        self.sample_positions.is_none()
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("dead_channel_ratio", self.dead_channel_ratio.into()),
            ("zero_blob_ratio", self.zero_blob_ratio.into()),
            ("block_switch_cycles", self.block_switch_cycles.into()),
            ("zero_detection", self.zero_detection.into()),
            (
                "sample_positions",
                self.sample_positions.map(Json::from).unwrap_or(Json::Null),
            ),
            ("seed", (self.seed as usize).into()),
        ])
    }

    pub fn from_json(j: &Json) -> Self {
        let d = SimConfig::default();
        SimConfig {
            dead_channel_ratio: j
                .get("dead_channel_ratio")
                .as_f64()
                .unwrap_or(d.dead_channel_ratio),
            zero_blob_ratio: j
                .get("zero_blob_ratio")
                .as_f64()
                .unwrap_or(d.zero_blob_ratio),
            block_switch_cycles: j
                .get("block_switch_cycles")
                .as_f64()
                .unwrap_or(d.block_switch_cycles),
            zero_detection: j
                .get("zero_detection")
                .as_bool()
                .unwrap_or(d.zero_detection),
            sample_positions: j.get("sample_positions").as_usize(),
            seed: j.get("seed").as_usize().map(|s| s as u64).unwrap_or(d.seed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_defaults() {
        let hw = HardwareConfig::default();
        assert_eq!(hw.xbar_rows, 512);
        assert_eq!(hw.xbar_cols, 512);
        assert_eq!(hw.ou_rows, 9);
        assert_eq!(hw.ou_cols, 8);
        assert_eq!(hw.adc_bits, 8);
        assert!((hw.adc_pj_per_op - 1.67).abs() < 1e-12);
        assert!((hw.dac_pj_per_op - 0.0182).abs() < 1e-12);
        assert!((hw.rram_pj_per_ou_op - 4.8).abs() < 1e-12);
        assert_eq!(hw.cell_bits, 4);
        hw.validate().unwrap();
    }

    #[test]
    fn cells_per_weight_paper() {
        // 16-bit weights, 4 bits/cell, single-ended -> 4 cells.
        let hw = HardwareConfig::default();
        assert_eq!(hw.cells_per_weight(), 4);
        assert_eq!(hw.weights_per_row(), 128);
        // differential doubles it
        let hw2 = HardwareConfig { differential: true, ..Default::default() };
        assert_eq!(hw2.cells_per_weight(), 8);
    }

    #[test]
    fn dac_phases() {
        let hw = HardwareConfig::default(); // 8-bit inputs / 4-bit DAC
        assert_eq!(hw.dac_phases(), 2);
        let hw4 = HardwareConfig { input_bits: 4, ..Default::default() };
        assert_eq!(hw4.dac_phases(), 1);
    }

    #[test]
    fn json_roundtrip() {
        let hw = HardwareConfig { ou_rows: 4, ou_cols: 4, ..Default::default() };
        let j = hw.to_json();
        let back = HardwareConfig::from_json(&j).unwrap();
        assert_eq!(hw, back);

        let sc = SimConfig { sample_positions: None, ..Default::default() };
        let back = SimConfig::from_json(&sc.to_json());
        assert_eq!(sc, back);
    }

    #[test]
    fn validation_catches_bad_ou() {
        let hw = HardwareConfig { ou_rows: 0, ..Default::default() };
        assert!(hw.validate().is_err());
        let hw = HardwareConfig { ou_rows: 1024, ..Default::default() };
        assert!(hw.validate().is_err());
    }

    #[test]
    fn with_dims_keeps_base_and_validates() {
        let base = HardwareConfig::default();
        let hw = base.with_dims(16, 8, 256, 256).unwrap();
        assert_eq!(hw.ou_rows, 16);
        assert_eq!(hw.xbar_rows, 256);
        // non-geometry parameters come from the base
        assert_eq!(hw.weight_bits, base.weight_bits);
        assert!((hw.adc_pj_per_op - base.adc_pj_per_op).abs() < 1e-12);
        // invalid geometries are rejected, not constructed
        assert!(base.with_dims(1024, 8, 256, 256).is_err(), "OU taller than xbar");
        assert!(base.with_dims(9, 3, 512, 512).is_err(), "misaligned ou_cols");
    }

    #[test]
    fn sampled_and_exact_constructors() {
        let s = SimConfig::sampled(48);
        assert_eq!(s.sample_positions, Some(48));
        assert!(!s.is_exact());
        let e = SimConfig::exact();
        assert_eq!(e.sample_positions, None);
        assert!(e.is_exact());
        // everything else stays on the calibrated defaults
        assert_eq!(e.seed, SimConfig::default().seed);
        assert_eq!(s.zero_blob_ratio, SimConfig::default().zero_blob_ratio);
    }

    #[test]
    fn multicore_block_roundtrips_and_validates() {
        let hw = HardwareConfig::default();
        assert_eq!(hw.cores, 1, "default stays the paper's single core");
        let mc = hw.with_cores(4, 64.0, 2.0).unwrap();
        assert_eq!(mc.cores, 4);
        assert!((mc.noc_bandwidth - 64.0).abs() < 1e-12);
        // macro parameters come from the base
        assert_eq!(mc.xbar_rows, hw.xbar_rows);
        let back = HardwareConfig::from_json(&mc.to_json()).unwrap();
        assert_eq!(mc, back);
        // legacy JSON without the multi-core block reads as single-core
        let legacy = HardwareConfig::from_json(&hw.to_json()).unwrap();
        assert_eq!(legacy.cores, 1);
        assert!(hw.with_cores(0, 32.0, 4.0).is_err(), "zero cores");
        assert!(hw.with_cores(2, 0.0, 4.0).is_err(), "zero bandwidth");
        assert!(hw.with_cores(2, f64::NAN, 4.0).is_err(), "NaN bandwidth");
        assert!(hw.with_cores(2, 32.0, -1.0).is_err(), "negative hop");
    }

    #[test]
    fn smallcnn_functional_matches_kernel_quant() {
        let hw = HardwareConfig::smallcnn_functional();
        assert_eq!(hw.weight_bits, 8);
        assert!(hw.differential);
        assert_eq!(hw.cells_per_weight(), 4);
        hw.validate().unwrap();
    }
}
