//! Record and index framing of the pack format — the byte-level half
//! of [`crate::store`] (see the module doc there for the full layout
//! specification). Everything here is pure: bytes in, records out, no
//! I/O, so the framing is testable without touching a filesystem.

use crate::util::fnv1a_bytes;

/// Pack file magic: `RRPK`.
pub const PACK_MAGIC: [u8; 4] = *b"RRPK";
/// Index file magic: `RRIX`.
pub const INDEX_MAGIC: [u8; 4] = *b"RRIX";
/// Format version of both files. Bump on any layout change — the
/// golden-pack test in `tests/store.rs` fails loudly if the bytes move
/// without a bump.
pub const FORMAT_VERSION: u32 = 1;
/// Both file headers are magic (4) + u32 LE version.
pub const HEADER_LEN: u64 = 8;
/// Fixed record prefix: u64 key + u32 id_len + u32 payload_len.
pub const RECORD_HEAD_LEN: usize = 16;
/// Trailing u64 checksum per record.
pub const RECORD_TAIL_LEN: usize = 8;
/// One index entry: u64 key + u64 offset + u32 id_len + u32 payload_len.
pub const INDEX_ENTRY_LEN: usize = 24;

/// Sanity cap on identity strings (cache identities are well under
/// 1 MiB); a corrupt length field must not drive an absurd allocation.
pub const MAX_ID_LEN: u32 = 1 << 20;
/// Sanity cap on payloads (the largest real payload — an artifact JSON
/// bundle — is a few KiB; snapshots of 10^5-point grids are ~1 MiB).
pub const MAX_PAYLOAD_LEN: u32 = 1 << 28;

/// One decoded pack record.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Record {
    pub key: u64,
    pub id: String,
    pub payload: Vec<u8>,
}

/// Total on-disk size of a record with the given id/payload lengths.
pub fn record_len(id_len: u32, payload_len: u32) -> u64 {
    RECORD_HEAD_LEN as u64
        + id_len as u64
        + payload_len as u64
        + RECORD_TAIL_LEN as u64
}

/// Encode one record (head + id + payload + FNV-1a checksum over
/// everything before the checksum).
pub fn encode_record(key: u64, id: &str, payload: &[u8]) -> Vec<u8> {
    let id_bytes = id.as_bytes();
    let mut out = Vec::with_capacity(
        record_len(id_bytes.len() as u32, payload.len() as u32) as usize,
    );
    out.extend_from_slice(&key.to_le_bytes());
    out.extend_from_slice(&(id_bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(id_bytes);
    out.extend_from_slice(payload);
    let sum = fnv1a_bytes(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Decode the record starting at `buf[0]`. Returns the record and its
/// total encoded length, or `None` on truncation, an out-of-range
/// length field, a checksum mismatch, or a non-UTF-8 identity — any of
/// which marks the end of the valid prefix during a pack scan.
pub fn decode_record(buf: &[u8]) -> Option<(Record, u64)> {
    if buf.len() < RECORD_HEAD_LEN {
        return None;
    }
    let key = u64::from_le_bytes(buf[0..8].try_into().ok()?);
    let id_len = u32::from_le_bytes(buf[8..12].try_into().ok()?);
    let payload_len = u32::from_le_bytes(buf[12..16].try_into().ok()?);
    if id_len > MAX_ID_LEN || payload_len > MAX_PAYLOAD_LEN {
        return None;
    }
    let total = record_len(id_len, payload_len);
    if (buf.len() as u64) < total {
        return None;
    }
    let body_end = RECORD_HEAD_LEN + id_len as usize + payload_len as usize;
    let want = fnv1a_bytes(&buf[..body_end]);
    let got = u64::from_le_bytes(
        buf[body_end..body_end + RECORD_TAIL_LEN].try_into().ok()?,
    );
    if want != got {
        return None;
    }
    let id = std::str::from_utf8(&buf[RECORD_HEAD_LEN..RECORD_HEAD_LEN + id_len as usize])
        .ok()?
        .to_string();
    let payload =
        buf[RECORD_HEAD_LEN + id_len as usize..body_end].to_vec();
    Some((Record { key, id, payload }, total))
}

/// One side-index entry: where a key's (latest) record starts in the
/// pack, with the lengths needed to read it in one shot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IndexEntry {
    pub key: u64,
    pub offset: u64,
    pub id_len: u32,
    pub payload_len: u32,
}

impl IndexEntry {
    /// End offset of the record this entry points at.
    pub fn end(&self) -> u64 {
        self.offset + record_len(self.id_len, self.payload_len)
    }
}

/// Encode one index entry (24 bytes LE).
pub fn encode_index_entry(e: &IndexEntry) -> [u8; INDEX_ENTRY_LEN] {
    let mut out = [0u8; INDEX_ENTRY_LEN];
    out[0..8].copy_from_slice(&e.key.to_le_bytes());
    out[8..16].copy_from_slice(&e.offset.to_le_bytes());
    out[16..20].copy_from_slice(&e.id_len.to_le_bytes());
    out[20..24].copy_from_slice(&e.payload_len.to_le_bytes());
    out
}

/// Decode one index entry; `None` on truncation (a partial trailing
/// entry from an interrupted append is simply ignored).
pub fn decode_index_entry(buf: &[u8]) -> Option<IndexEntry> {
    if buf.len() < INDEX_ENTRY_LEN {
        return None;
    }
    Some(IndexEntry {
        key: u64::from_le_bytes(buf[0..8].try_into().ok()?),
        offset: u64::from_le_bytes(buf[8..16].try_into().ok()?),
        id_len: u32::from_le_bytes(buf[16..20].try_into().ok()?),
        payload_len: u32::from_le_bytes(buf[20..24].try_into().ok()?),
    })
}

/// The 8-byte header of either file.
pub fn encode_header(magic: [u8; 4]) -> [u8; 8] {
    let mut out = [0u8; 8];
    out[0..4].copy_from_slice(&magic);
    out[4..8].copy_from_slice(&FORMAT_VERSION.to_le_bytes());
    out
}

/// Validate a file header against the expected magic; returns the
/// format version on success.
pub fn check_header(buf: &[u8], magic: [u8; 4]) -> Option<u32> {
    if buf.len() < HEADER_LEN as usize || buf[0..4] != magic {
        return None;
    }
    let version = u32::from_le_bytes(buf[4..8].try_into().ok()?);
    if version != FORMAT_VERSION {
        return None;
    }
    Some(version)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_roundtrips() {
        let enc = encode_record(42, "hello", b"\x00\x01\xff");
        let (rec, len) = decode_record(&enc).expect("decodes");
        assert_eq!(len as usize, enc.len());
        assert_eq!(rec.key, 42);
        assert_eq!(rec.id, "hello");
        assert_eq!(rec.payload, b"\x00\x01\xff");
        // empty id and payload are legal
        let enc = encode_record(0, "", b"");
        let (rec, _) = decode_record(&enc).expect("empty record decodes");
        assert_eq!(rec.id, "");
        assert!(rec.payload.is_empty());
    }

    #[test]
    fn corruption_is_detected() {
        let enc = encode_record(7, "id", b"payload");
        // every truncation fails
        for cut in 0..enc.len() {
            assert!(decode_record(&enc[..cut]).is_none(), "cut {cut}");
        }
        // any single flipped byte fails the checksum (or a length gate)
        for i in 0..enc.len() {
            let mut bad = enc.clone();
            bad[i] ^= 0x5a;
            assert!(decode_record(&bad).is_none(), "flip at {i}");
        }
    }

    #[test]
    fn length_fields_are_capped() {
        let mut enc = encode_record(7, "id", b"p");
        // forge an absurd id_len; the cap rejects it before allocating
        enc[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(decode_record(&enc).is_none());
        let mut enc = encode_record(7, "id", b"p");
        enc[12..16].copy_from_slice(&(MAX_PAYLOAD_LEN + 1).to_le_bytes());
        assert!(decode_record(&enc).is_none());
    }

    #[test]
    fn index_entry_roundtrips() {
        let e = IndexEntry { key: 9, offset: 8, id_len: 3, payload_len: 5 };
        let enc = encode_index_entry(&e);
        assert_eq!(decode_index_entry(&enc), Some(e));
        assert_eq!(e.end(), 8 + record_len(3, 5));
        assert!(decode_index_entry(&enc[..INDEX_ENTRY_LEN - 1]).is_none());
    }

    #[test]
    fn headers_check_magic_and_version() {
        let h = encode_header(PACK_MAGIC);
        assert_eq!(check_header(&h, PACK_MAGIC), Some(FORMAT_VERSION));
        assert_eq!(check_header(&h, INDEX_MAGIC), None, "wrong magic");
        let mut bad = h;
        bad[4] = 0xff;
        assert_eq!(check_header(&bad, PACK_MAGIC), None, "wrong version");
        assert_eq!(check_header(&h[..7], PACK_MAGIC), None, "truncated");
    }
}
