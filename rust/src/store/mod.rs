//! Unified content-addressed artifact store: one append-able **pack
//! file** per cache domain plus a small **side index**, replacing the
//! one-pretty-JSON-file-per-entry layout of `results/dse_cache/` and
//! `results/paper_cache/`. At 10^4–10^5 DSE points the old layout
//! meant one `open`+`Json::parse` per point per warm sweep; a pack is
//! one read and one scan.
//!
//! Both caches ([`crate::dse::ResultCache`],
//! [`crate::report::artifacts::ArtifactCache`]) sit on top of
//! [`PackStore`] behind their existing APIs. Identity semantics are
//! unchanged: the full identity string is stored *in* each record and
//! verified on load, so an FNV key collision still degrades to a miss,
//! never a wrong hit. Existing JSON cache entries remain readable
//! through a legacy fallback in each cache (see `dse/cache.rs`).
//!
//! # On-disk format, byte for byte (version 1)
//!
//! All integers are **little-endian**. Hashes/checksums are FNV-1a 64
//! ([`crate::util::fnv1a_bytes`]).
//!
//! ## Pack file (`<domain>.pack`)
//!
//! ```text
//! offset  size  field
//! 0       4     magic            "RRPK" (52 52 50 4b)
//! 4       4     u32 version      = 1
//! 8       ...   records, back to back, no padding
//! ```
//!
//! Each record:
//!
//! ```text
//! offset  size        field
//! +0      8           u64 key          content hash of the identity string
//! +8      4           u32 id_len       byte length of the identity string
//! +12     4           u32 payload_len  byte length of the payload
//! +16     id_len      id               identity string, UTF-8
//! +...    payload_len payload          opaque bytes (domain-defined)
//! +...    8           u64 checksum     FNV-1a 64 over the preceding
//!                                      16 + id_len + payload_len bytes
//! ```
//!
//! Records are append-only; re-storing a key appends a new record and
//! **the last record for a key wins**. A write interrupted mid-append
//! leaves a tail whose checksum (or framing) fails to verify; on the
//! next open the pack is truncated back to the longest valid record
//! prefix — the same "corrupt entry = miss, then overwrite" contract
//! the per-file JSON caches had, minus the file-per-entry cost.
//!
//! ## Index file (`<domain>.idx`)
//!
//! ```text
//! offset  size  field
//! 0       4     magic        "RRIX" (52 52 49 58)
//! 4       4     u32 version  = 1
//! 8       24×n  entries
//! ```
//!
//! Each entry (24 bytes):
//!
//! ```text
//! offset  size  field
//! +0      8     u64 key
//! +8      8     u64 offset       start of the key's latest record in the pack
//! +16     4     u32 id_len
//! +20     4     u32 payload_len
//! ```
//!
//! The index is **purely an accelerator and never authoritative**: on
//! open it is cross-checked against a full pack scan, and on any
//! disagreement (missing, corrupt, stale after a tail truncation,
//! extra/missing keys) it is discarded and rebuilt from the pack.
//! Fresh-key puts append their entry in put order; an overwrite or a
//! rebuild rewrites the whole file in ascending key order (`BTreeMap`
//! iteration). Either way the on-disk bytes are a deterministic
//! function of the record history — the `no-unordered-iteration` lint
//! rule covers this module for exactly that reason.
//!
//! The format is pinned by `tests/store.rs` against golden files
//! (`tests/golden/store_v1.{pack,idx}`); any byte-level change must
//! bump [`format::FORMAT_VERSION`] and regenerate the goldens.

pub mod format;
pub mod pack;

pub use format::{FORMAT_VERSION, Record};
pub use pack::{OpenStats, PackStore};
