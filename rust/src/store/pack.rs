//! `PackStore` — one append-able pack file plus a side index, fronted
//! by an in-memory key map. All mutation goes through a single named
//! [`crate::util::lockcheck::Mutex`], so a store handle can be cloned
//! (`Arc` inside) and shared across sweep worker threads.
//!
//! Durability model (mirrors the per-file JSON caches it replaces):
//! a put that is interrupted mid-append leaves a truncated tail record
//! whose checksum cannot verify; `open` (and the next `put`) truncate
//! back to the longest valid record prefix, so the pack self-heals at
//! the cost of the interrupted record only. The side index is purely
//! an accelerator — whenever it disagrees with the pack (stale, short,
//! corrupt, or pointing at bytes that no longer verify), it is
//! discarded and rebuilt from the pack, which is always authoritative.

use std::collections::BTreeMap;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::util::lockcheck::Mutex;

use super::format::{
    check_header, decode_index_entry, decode_record, encode_header,
    encode_index_entry, encode_record, record_len, IndexEntry, Record,
    HEADER_LEN, INDEX_ENTRY_LEN, INDEX_MAGIC, PACK_MAGIC,
};

/// Outcome counters for `open`, surfaced so tests (and curious humans
/// via `--verbose` style probes) can see what recovery did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpenStats {
    /// Records live in the in-memory index after open.
    pub live_records: usize,
    /// Records found by scanning the pack beyond index coverage.
    pub tail_scanned: usize,
    /// True if the side index was unusable and rebuilt from the pack.
    pub index_rebuilt: bool,
    /// Bytes of corrupt/truncated tail dropped from the pack.
    pub truncated_bytes: u64,
}

struct Inner {
    pack_path: PathBuf,
    idx_path: PathBuf,
    /// key -> latest entry. BTreeMap so every iteration (index rewrite,
    /// `keys`) is deterministic.
    index: BTreeMap<u64, IndexEntry>,
    /// Length of the valid pack prefix; appends go here.
    pack_len: u64,
    stats: OpenStats,
}

/// Handle to one pack-file cache domain. Cheap to clone; all clones
/// share the same lock and in-memory index.
#[derive(Clone)]
pub struct PackStore {
    inner: Arc<Mutex<Inner>>,
}

impl PackStore {
    /// Open (creating if absent) the pack `<dir>/<name>.pack` and its
    /// side index `<dir>/<name>.idx`. Never fails on corrupt content —
    /// recovery truncates/rebuilds as described in the module doc.
    /// Returns an error only for real I/O failures (unwritable dir).
    pub fn open(dir: &str, name: &str) -> Result<PackStore, String> {
        fs::create_dir_all(dir)
            .map_err(|e| format!("store: create {dir}: {e}"))?;
        let pack_path = Path::new(dir).join(format!("{name}.pack"));
        let idx_path = Path::new(dir).join(format!("{name}.idx"));
        let mut stats = OpenStats::default();

        let pack_bytes = match fs::read(&pack_path) {
            Ok(b) => b,
            Err(_) => Vec::new(),
        };
        // A pack with a bad/missing header is treated as empty: the
        // cache rebuilds from scratch rather than erroring, exactly
        // like a corrupt per-file JSON entry was a miss before.
        let usable = !pack_bytes.is_empty()
            && check_header(&pack_bytes, PACK_MAGIC).is_some();
        let (valid_len, records) = if usable {
            scan_pack(&pack_bytes)
        } else {
            (HEADER_LEN, Vec::new())
        };
        if usable {
            stats.truncated_bytes = pack_bytes.len() as u64 - valid_len;
        }

        // Load the side index and validate it against the pack scan.
        let mut index = BTreeMap::new();
        let mut index_ok = false;
        if let Ok(idx_bytes) = fs::read(&idx_path) {
            if let Some(loaded) = load_index(&idx_bytes, valid_len) {
                // The index must agree with the authoritative pack:
                // same key set, each entry pointing at a record that
                // decodes to that key.
                index_ok = index_matches_pack(&loaded, &records);
                if index_ok {
                    index = loaded;
                }
            }
        }
        if !index_ok {
            stats.index_rebuilt =
                idx_path.exists() || !records.is_empty();
            index = records
                .iter()
                .map(|(off, r)| {
                    (
                        r.key,
                        IndexEntry {
                            key: r.key,
                            offset: *off,
                            id_len: r.id.len() as u32,
                            payload_len: r.payload.len() as u32,
                        },
                    )
                })
                .collect();
        }
        stats.live_records = index.len();

        let inner = Inner { pack_path, idx_path, index, pack_len: valid_len, stats };
        // Materialise a healed pack/index on disk so the next open is
        // clean. (No-op when nothing was truncated or rebuilt.)
        if (!usable && !pack_bytes.is_empty()) || stats.truncated_bytes > 0 {
            write_pack_prefix(&inner, if usable { &pack_bytes } else { &[] })?;
        } else if !inner.pack_path.exists() {
            write_pack_prefix(&inner, &[])?;
        }
        if !index_ok || !inner.idx_path.exists() {
            rewrite_index(&inner)?;
        }
        Ok(PackStore { inner: Arc::new(Mutex::named("store.pack", inner)) })
    }

    /// Recovery counters from `open`.
    pub fn open_stats(&self) -> OpenStats {
        self.inner.lock().stats
    }

    /// Number of live (latest-version) records.
    pub fn len(&self) -> usize {
        self.inner.lock().index.len()
    }

    /// True when the store holds no records.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// All live keys, ascending (BTreeMap order — deterministic).
    pub fn keys(&self) -> Vec<u64> {
        self.inner.lock().index.keys().copied().collect()
    }

    /// Fetch the latest record for `key`, verifying the on-disk bytes
    /// (checksum + key match). A record that fails verification is
    /// treated as a miss and evicted from the in-memory index so a
    /// subsequent `put` repairs it. Every lookup lands on one of the
    /// process-wide [`crate::obs::counters`] store tallies (hit or
    /// miss — a corrupt record counts as a miss), which `/metrics`
    /// exports.
    pub fn get(&self, key: u64) -> Option<Record> {
        let got = self.get_uncounted(key);
        if got.is_some() {
            crate::obs::counters::store_hit();
        } else {
            crate::obs::counters::store_miss();
        }
        got
    }

    fn get_uncounted(&self, key: u64) -> Option<Record> {
        let mut inner = self.inner.lock();
        let entry = *inner.index.get(&key)?;
        match read_record_at(&inner.pack_path, entry) {
            Some(rec) if rec.key == key => Some(rec),
            _ => {
                inner.index.remove(&key);
                None
            }
        }
    }

    /// Append (or overwrite — last write wins) the record for `key`.
    /// The pack is appended and the index entry written through to the
    /// side file immediately.
    pub fn put(&self, key: u64, id: &str, payload: &[u8]) -> Result<(), String> {
        let mut inner = self.inner.lock();
        let encoded = encode_record(key, id, payload);
        let offset = inner.pack_len;
        append_pack(&inner.pack_path, offset, &encoded)?;
        inner.pack_len = offset + encoded.len() as u64;
        let entry = IndexEntry {
            key,
            offset,
            id_len: id.len() as u32,
            payload_len: payload.len() as u32,
        };
        let fresh_key = inner.index.insert(key, entry).is_none();
        if fresh_key {
            append_index(&inner.idx_path, entry)?;
        } else {
            // Overwrite: the old entry for this key is now stale, so
            // rewrite the (small) index wholesale to keep it 1:1 with
            // live records.
            rewrite_index(&inner)?;
        }
        Ok(())
    }
}

impl std::fmt::Debug for PackStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.inner.lock();
        f.debug_struct("PackStore")
            .field("pack", &inner.pack_path)
            .field("live_records", &inner.index.len())
            .field("pack_len", &inner.pack_len)
            .finish()
    }
}

/// Scan the pack body, returning the length of the longest valid
/// prefix and every record in it (offset, record) in file order.
fn scan_pack(bytes: &[u8]) -> (u64, Vec<(u64, Record)>) {
    let mut offset = HEADER_LEN;
    let mut records = Vec::new();
    while (offset as usize) < bytes.len() {
        match decode_record(&bytes[offset as usize..]) {
            Some((rec, len)) => {
                records.push((offset, rec));
                offset += len;
            }
            None => break,
        }
    }
    (offset, records)
}

/// Parse the side index file; `None` if the header is bad. Entries
/// pointing past `pack_len` (stale index from before a tail
/// truncation) invalidate the whole index. A truncated final entry is
/// ignored (interrupted index append).
fn load_index(bytes: &[u8], pack_len: u64) -> Option<BTreeMap<u64, IndexEntry>> {
    check_header(bytes, INDEX_MAGIC)?;
    let mut index = BTreeMap::new();
    let mut at = HEADER_LEN as usize;
    while at + INDEX_ENTRY_LEN <= bytes.len() {
        let e = decode_index_entry(&bytes[at..])?;
        if e.offset < HEADER_LEN || e.end() > pack_len {
            return None;
        }
        index.insert(e.key, e);
        at += INDEX_ENTRY_LEN;
    }
    Some(index)
}

/// True when the index is exactly the last-write-wins view of the
/// scanned records: same key set, and each entry points at a record
/// with that key and those lengths.
fn index_matches_pack(
    index: &BTreeMap<u64, IndexEntry>,
    records: &[(u64, Record)],
) -> bool {
    let mut latest: BTreeMap<u64, (u64, &Record)> = BTreeMap::new();
    for (off, rec) in records {
        latest.insert(rec.key, (*off, rec));
    }
    if latest.len() != index.len() {
        return false;
    }
    latest.iter().all(|(key, (off, rec))| match index.get(key) {
        Some(e) => {
            e.offset == *off
                && e.id_len == rec.id.len() as u32
                && e.payload_len == rec.payload.len() as u32
        }
        None => false,
    })
}

/// Read and decode the record a (trusted-length) index entry points at.
fn read_record_at(pack_path: &Path, entry: IndexEntry) -> Option<Record> {
    use std::io::{Read as _, Seek as _, SeekFrom};
    let mut f = fs::File::open(pack_path).ok()?;
    f.seek(SeekFrom::Start(entry.offset)).ok()?;
    let want = record_len(entry.id_len, entry.payload_len) as usize;
    let mut buf = vec![0u8; want];
    f.read_exact(&mut buf).ok()?;
    let (rec, len) = decode_record(&buf)?;
    if len as usize != want {
        return None;
    }
    Some(rec)
}

/// Rewrite the pack as header + the valid prefix of `old_bytes`
/// (callers pass the original file content, or empty to reset).
fn write_pack_prefix(inner: &Inner, old_bytes: &[u8]) -> Result<(), String> {
    let mut out = Vec::with_capacity(inner.pack_len as usize);
    out.extend_from_slice(&encode_header(PACK_MAGIC));
    if old_bytes.len() as u64 >= inner.pack_len && inner.pack_len > HEADER_LEN {
        out.extend_from_slice(
            &old_bytes[HEADER_LEN as usize..inner.pack_len as usize],
        );
    }
    fs::write(&inner.pack_path, &out)
        .map_err(|e| format!("store: write {:?}: {e}", inner.pack_path))
}

/// Rewrite the side index from the in-memory map (ascending key order).
fn rewrite_index(inner: &Inner) -> Result<(), String> {
    let mut out =
        Vec::with_capacity(HEADER_LEN as usize + inner.index.len() * INDEX_ENTRY_LEN);
    out.extend_from_slice(&encode_header(INDEX_MAGIC));
    for e in inner.index.values() {
        out.extend_from_slice(&encode_index_entry(e));
    }
    fs::write(&inner.idx_path, &out)
        .map_err(|e| format!("store: write {:?}: {e}", inner.idx_path))
}

/// Append one encoded record at `offset`, truncating any corrupt tail
/// first (offset is the end of the valid prefix by construction).
fn append_pack(pack_path: &Path, offset: u64, encoded: &[u8]) -> Result<(), String> {
    let f = fs::OpenOptions::new()
        .write(true)
        .create(true)
        .open(pack_path)
        .map_err(|e| format!("store: open {pack_path:?}: {e}"))?;
    f.set_len(offset)
        .map_err(|e| format!("store: truncate {pack_path:?}: {e}"))?;
    let mut f = f;
    use std::io::{Seek as _, SeekFrom};
    f.seek(SeekFrom::Start(offset))
        .map_err(|e| format!("store: seek {pack_path:?}: {e}"))?;
    f.write_all(encoded)
        .map_err(|e| format!("store: append {pack_path:?}: {e}"))
}

/// Append one index entry to the side file (fast path for new keys).
fn append_index(idx_path: &Path, entry: IndexEntry) -> Result<(), String> {
    let mut f = fs::OpenOptions::new()
        .append(true)
        .create(true)
        .open(idx_path)
        .map_err(|e| format!("store: open {idx_path:?}: {e}"))?;
    f.write_all(&encode_index_entry(&entry))
        .map_err(|e| format!("store: append {idx_path:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(tag: &str) -> String {
        let dir = std::env::temp_dir().join(format!(
            "rram_store_{tag}_{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir.to_string_lossy().to_string()
    }

    #[test]
    fn put_get_roundtrip_and_reopen() {
        let dir = temp_dir("roundtrip");
        let store = PackStore::open(&dir, "t").expect("open");
        assert!(store.is_empty());
        store.put(1, "one", b"alpha").expect("put");
        store.put(2, "two", b"").expect("put");
        assert_eq!(store.len(), 2);
        let rec = store.get(1).expect("hit");
        assert_eq!((rec.id.as_str(), rec.payload.as_slice()), ("one", &b"alpha"[..]));
        assert!(store.get(3).is_none());
        drop(store);
        let store = PackStore::open(&dir, "t").expect("reopen");
        assert_eq!(store.open_stats().live_records, 2);
        assert!(!store.open_stats().index_rebuilt);
        assert_eq!(store.get(2).expect("hit").payload, b"");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn last_write_wins() {
        let dir = temp_dir("lww");
        let store = PackStore::open(&dir, "t").expect("open");
        store.put(5, "id", b"old").expect("put");
        store.put(5, "id", b"new").expect("put");
        assert_eq!(store.len(), 1);
        assert_eq!(store.get(5).expect("hit").payload, b"new");
        drop(store);
        let store = PackStore::open(&dir, "t").expect("reopen");
        assert_eq!(store.get(5).expect("hit").payload, b"new");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_tail_self_heals() {
        let dir = temp_dir("heal");
        let store = PackStore::open(&dir, "t").expect("open");
        store.put(1, "keep", b"kept").expect("put");
        store.put(2, "lose", b"interrupted").expect("put");
        drop(store);
        let pack = Path::new(&dir).join("t.pack");
        let bytes = fs::read(&pack).expect("read pack");
        fs::write(&pack, &bytes[..bytes.len() - 3]).expect("truncate");
        let store = PackStore::open(&dir, "t").expect("reopen");
        let stats = store.open_stats();
        assert!(stats.truncated_bytes > 0, "tail was dropped");
        assert_eq!(store.len(), 1);
        assert_eq!(store.get(1).expect("survivor").payload, b"kept");
        assert!(store.get(2).is_none());
        // healed store accepts new writes and reopens cleanly
        store.put(3, "next", b"fresh").expect("put after heal");
        drop(store);
        let store = PackStore::open(&dir, "t").expect("second reopen");
        assert_eq!(store.open_stats().truncated_bytes, 0);
        assert_eq!(store.len(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_index_rebuilds_from_pack() {
        let dir = temp_dir("idx");
        let store = PackStore::open(&dir, "t").expect("open");
        store.put(7, "seven", b"payload7").expect("put");
        store.put(8, "eight", b"payload8").expect("put");
        drop(store);
        let idx = Path::new(&dir).join("t.idx");
        // garbage index: pack must win
        fs::write(&idx, b"not an index at all").expect("corrupt idx");
        let store = PackStore::open(&dir, "t").expect("reopen");
        assert!(store.open_stats().index_rebuilt);
        assert_eq!(store.len(), 2);
        assert_eq!(store.get(7).expect("hit").payload, b"payload7");
        // missing index also rebuilds
        drop(store);
        fs::remove_file(&idx).expect("rm idx");
        let store = PackStore::open(&dir, "t").expect("reopen no idx");
        assert_eq!(store.len(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn index_pack_disagreement_prefers_pack() {
        let dir = temp_dir("disagree");
        let store = PackStore::open(&dir, "t").expect("open");
        store.put(1, "a", b"aa").expect("put");
        drop(store);
        // Forge an index claiming a key the pack doesn't have.
        let idx = Path::new(&dir).join("t.idx");
        let mut bytes = fs::read(&idx).expect("read idx");
        let bogus = IndexEntry { key: 99, offset: HEADER_LEN, id_len: 1, payload_len: 2 };
        bytes.extend_from_slice(&encode_index_entry(&bogus));
        fs::write(&idx, &bytes).expect("forge idx");
        let store = PackStore::open(&dir, "t").expect("reopen");
        assert!(store.open_stats().index_rebuilt, "disagreement forces rebuild");
        assert_eq!(store.keys(), vec![1]);
        assert!(store.get(99).is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn garbage_pack_resets_to_empty() {
        let dir = temp_dir("garbage");
        fs::create_dir_all(&dir).expect("mkdir");
        fs::write(Path::new(&dir).join("t.pack"), b"complete nonsense")
            .expect("garbage pack");
        let store = PackStore::open(&dir, "t").expect("open");
        assert!(store.is_empty());
        store.put(1, "a", b"b").expect("put into reset store");
        drop(store);
        let store = PackStore::open(&dir, "t").expect("reopen");
        assert_eq!(store.len(), 1);
        let _ = fs::remove_dir_all(&dir);
    }
}
