//! Pareto-frontier extraction, per-axis sensitivity summaries, and the
//! weighted-objective config selection that closes the DSE → serving
//! loop.
//!
//! All three objectives are minimized: provisioned area (cells), batch
//! energy (pJ), batch cycles. A point dominates another when it is no
//! worse on every objective and strictly better on at least one; the
//! frontier is the non-dominated subset of the evaluated grid, reported
//! in ascending grid-index order so the artifact is independent of
//! evaluation order and thread count (`tests/prop_invariants.rs` pins
//! the invariants).

use crate::config::HardwareConfig;
use crate::util::json::{obj, Json};

use super::{PointMetrics, PointResult, SweepSpec};

/// `(area_cells, energy_pj, cycles)` — the minimized objective tuple.
pub fn objectives(m: &PointMetrics) -> (f64, f64, f64) {
    (m.area_cells, m.energy_pj, m.cycles)
}

/// Strict Pareto dominance: `a` no worse everywhere, better somewhere.
pub fn dominates(a: &PointMetrics, b: &PointMetrics) -> bool {
    let (aa, ae, ac) = objectives(a);
    let (ba, be, bc) = objectives(b);
    aa <= ba && ae <= be && ac <= bc && (aa < ba || ae < be || ac < bc)
}

/// Objective tuple normalized for the sort-based extraction: `-0.0`
/// collapses to `+0.0` (`x + 0.0`), so `f64::total_cmp`'s ordering
/// agrees exactly with the operator comparisons [`dominates`] uses
/// (which treat the two zeros as equal). NaN passes through and is
/// handled separately.
fn norm_objectives(m: &PointMetrics) -> (f64, f64, f64) {
    let (a, e, c) = objectives(m);
    (a + 0.0, e + 0.0, c + 0.0)
}

/// Pareto staircase over `(energy, cycles)` pairs of already-processed
/// points: `es` strictly ascending, `cs` strictly descending — the 2D
/// minima envelope. `dominated(e, c)` answers "does any processed
/// point have `e' <= e` and `c' <= c`" in O(log n).
struct Staircase {
    es: Vec<f64>,
    cs: Vec<f64>,
}

impl Staircase {
    fn new() -> Staircase {
        Staircase { es: Vec::new(), cs: Vec::new() }
    }

    fn dominated(&self, e: f64, c: f64) -> bool {
        // The best candidate is the largest e' <= e: cs decreases with
        // es, so it carries the minimum c over that prefix.
        let i = self.es.partition_point(|x| *x <= e);
        i > 0 && self.cs[i - 1] <= c
    }

    fn insert(&mut self, e: f64, c: f64) {
        let i = self.es.partition_point(|x| *x <= e);
        if i > 0 && self.cs[i - 1] <= c {
            return; // already covered by the envelope
        }
        let at = if i > 0 && self.es[i - 1] == e {
            // same e, strictly lower c (not covered): tighten in place
            self.cs[i - 1] = c;
            i - 1
        } else {
            self.es.insert(i, e);
            self.cs.insert(i, c);
            i
        };
        // drop following steps the new point covers (e' > e, c' >= c)
        let mut j = at + 1;
        while j < self.es.len() && self.cs[j] >= c {
            j += 1;
        }
        self.es.drain(at + 1..j);
        self.cs.drain(at + 1..j);
    }
}

/// Sort-based non-dominated extraction over `(index, normalized
/// objectives)` pairs — O(n log n) comparisons against the O(n²)
/// pairwise oracle, bit-identical members (pinned by the property test
/// in `tests/prop_invariants.rs` and this module's unit tests).
///
/// Shape: sort by `(area, energy, cycles)`; walk equal-`area` groups in
/// order, testing each candidate against (a) the staircase of all
/// strictly-smaller-area points — `e' <= e && c' <= c` there is strict
/// dominance, area being strictly better — and (b) its own group,
/// where a same-area point dominates iff it is weakly better on
/// `(energy, cycles)` and strictly better on one (equal tuples never
/// dominate each other, so exact duplicates all stay members, exactly
/// like the oracle). NaN never compares, so a NaN-coordinate point is
/// neither dominated nor dominating: an automatic member, excluded
/// from the sort machinery.
fn extract_non_dominated(valid: &[(usize, (f64, f64, f64))]) -> Vec<usize> {
    let mut members: Vec<usize> = valid
        .iter()
        .filter(|(_, (a, e, c))| a.is_nan() || e.is_nan() || c.is_nan())
        .map(|&(i, _)| i)
        .collect();
    let mut pts: Vec<(usize, (f64, f64, f64))> = valid
        .iter()
        .filter(|(_, (a, e, c))| !(a.is_nan() || e.is_nan() || c.is_nan()))
        .copied()
        .collect();
    pts.sort_unstable_by(|x, y| {
        (x.1 .0)
            .total_cmp(&y.1 .0)
            .then((x.1 .1).total_cmp(&y.1 .1))
            .then((x.1 .2).total_cmp(&y.1 .2))
            .then(x.0.cmp(&y.0))
    });
    let mut stair = Staircase::new();
    let mut g = 0;
    while g < pts.len() {
        let a = pts[g].1 .0;
        let mut h = g;
        while h < pts.len() && pts[h].1 .0 == a {
            h += 1;
        }
        // One equal-area group [g, h), sorted by (energy, cycles).
        // Within the group, a run of equal energy keeps only its
        // minimum-cycles points, and only when that minimum strictly
        // beats every lower-energy run's (same-area points with less
        // energy and <= cycles would dominate).
        let mut prefix_min_c = f64::INFINITY;
        let mut r = g;
        while r < h {
            let e = pts[r].1 .1;
            let mut rr = r;
            while rr < h && pts[rr].1 .1 == e {
                rr += 1;
            }
            let run_min_c = pts[r].1 .2; // run sorted by cycles
            if run_min_c < prefix_min_c {
                for &(i, (_, _, c)) in &pts[r..rr] {
                    if c != run_min_c {
                        break; // equal-minimum block is a prefix
                    }
                    if !stair.dominated(e, c) {
                        members.push(i);
                    }
                }
            }
            prefix_min_c = prefix_min_c.min(run_min_c);
            r = rr;
        }
        // Fold the whole group into the staircase for later groups
        // (strictly larger area from here on).
        for &(_, (_, e, c)) in &pts[g..h] {
            stair.insert(e, c);
        }
        g = h;
    }
    members.sort_unstable();
    members
}

/// The non-dominated subset of a sweep's results.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParetoFrontier {
    /// Indices into the results slice, ascending. Skipped points never
    /// appear.
    pub members: Vec<usize>,
}

impl ParetoFrontier {
    /// Extract the frontier via sort-based non-dominated extraction —
    /// O(n log n) against the old O(n²) pairwise pass (kept as
    /// [`ParetoFrontier::from_results_oracle`]), with bit-identical
    /// `members`. At the `large` grid (~10^4 points) the pairwise pass
    /// is ~10^8 dominance checks; this is one sort plus a staircase
    /// walk.
    pub fn from_results(results: &[PointResult]) -> ParetoFrontier {
        let valid: Vec<(usize, (f64, f64, f64))> = results
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.metrics().map(|m| (i, norm_objectives(m))))
            .collect();
        ParetoFrontier { members: extract_non_dominated(&valid) }
    }

    /// The original O(n²) pairwise extraction, kept verbatim as the
    /// reference oracle: the property test in
    /// `tests/prop_invariants.rs` pins `from_results == from_results_oracle`
    /// on randomized grids (ties, duplicates, skips, signed zeros), and
    /// `benches/dse_sweep.rs` races the two at 10^4 points.
    pub fn from_results_oracle(results: &[PointResult]) -> ParetoFrontier {
        let valid: Vec<(usize, &PointMetrics)> = results
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.metrics().map(|m| (i, m)))
            .collect();
        let members = valid
            .iter()
            .filter(|&&(_, m)| !valid.iter().any(|&(_, o)| dominates(o, m)))
            .map(|&(i, _)| i)
            .collect();
        ParetoFrontier { members }
    }

    /// Fold newly evaluated points into a warm-started frontier.
    ///
    /// Re-extracts over `current members ∪ new_indices` only — sound
    /// whenever `self` is the exact frontier of some subset `S` of
    /// `results` and `new_indices` covers every valid index outside
    /// `S` (the sweep runner enforces this by only warm-starting when
    /// the snapshot's covered set is a subset of the current grid: a
    /// point dominated by an old member stays dominated, and any old
    /// member a new point dominates is re-checked here). Indices
    /// without valid metrics are ignored; the result is identical to a
    /// full [`ParetoFrontier::from_results`] pass under that contract
    /// (pinned by tests).
    pub fn update(&mut self, results: &[PointResult], new_indices: &[usize]) {
        let mut cand: Vec<usize> = self
            .members
            .iter()
            .chain(new_indices.iter())
            .copied()
            .collect();
        cand.sort_unstable();
        cand.dedup();
        let valid: Vec<(usize, (f64, f64, f64))> = cand
            .into_iter()
            .filter_map(|i| {
                results
                    .get(i)
                    .and_then(|r| r.metrics())
                    .map(|m| (i, norm_objectives(m)))
            })
            .collect();
        self.members = extract_non_dominated(&valid);
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    /// Text table of the frontier, one row per member.
    pub fn table(&self, results: &[PointResult]) -> String {
        let mut s = format!(
            "PARETO FRONTIER — {} of {} points non-dominated on \
             (area cells, energy pJ, cycles)\n  {:<5} {:<10} {:>6} {:>9} \
             {:>4} {:>6} {:>14} {:>14} {:>12} {:>6} {:>6}\n",
            self.len(),
            results.len(),
            "idx",
            "scheme",
            "ou",
            "xbar",
            "pat",
            "prune",
            "cycles",
            "energy_pj",
            "area_cells",
            "xbars",
            "util%",
        );
        for &i in &self.members {
            let p = &results[i].point;
            let m = results[i].metrics().expect("frontier members are valid");
            let ou = format!("{}x{}", p.ou_rows, p.ou_cols);
            let xb = format!("{}x{}", p.xbar_rows, p.xbar_cols);
            s.push_str(&format!(
                "  {:<5} {:<10} {:>6} {:>9} {:>4} {:>6.2} {:>14.0} {:>14.4e} \
                 {:>12.0} {:>6} {:>6.1}\n",
                i,
                p.scheme,
                ou,
                xb,
                p.n_patterns,
                p.pruning,
                m.cycles,
                m.energy_pj,
                m.area_cells,
                m.crossbars,
                m.utilization * 100.0,
            ));
        }
        s
    }

    /// The deterministic frontier artifact: spec, counts, members (with
    /// point + metrics), per-axis sensitivity. No timing, no cache
    /// state — byte-identical for any thread count and for cached vs
    /// fresh runs.
    pub fn to_json(&self, spec: &SweepSpec, results: &[PointResult]) -> Json {
        let evaluated = results.iter().filter(|r| r.outcome.is_ok()).count();
        obj(vec![
            ("spec", spec.to_json()),
            ("n_points", results.len().into()),
            ("evaluated", evaluated.into()),
            ("skipped", (results.len() - evaluated).into()),
            (
                "frontier",
                Json::Arr(
                    self.members
                        .iter()
                        .map(|&i| {
                            obj(vec![
                                ("index", i.into()),
                                ("point", results[i].point.to_json()),
                                (
                                    "metrics",
                                    results[i]
                                        .metrics()
                                        .expect("frontier members are valid")
                                        .to_json(),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "sensitivity",
                Json::Arr(sensitivity(results).iter().map(|a| a.to_json()).collect()),
            ),
        ])
    }

    /// CSV of the frontier members (one header + one row per member).
    pub fn to_csv(&self, results: &[PointResult]) -> String {
        let mut s = String::from(
            "index,scheme,ou_rows,ou_cols,xbar_rows,xbar_cols,patterns,\
             pruning,cycles,energy_pj,area_cells,crossbars,utilization\n",
        );
        for &i in &self.members {
            let p = &results[i].point;
            let m = results[i].metrics().expect("frontier members are valid");
            s.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
                i,
                p.scheme,
                p.ou_rows,
                p.ou_cols,
                p.xbar_rows,
                p.xbar_cols,
                p.n_patterns,
                p.pruning,
                m.cycles,
                m.energy_pj,
                m.area_cells,
                m.crossbars,
                m.utilization,
            ));
        }
        s
    }
}

/// User-weighted selection objective over the frontier. Each metric is
/// normalized by the frontier's per-metric minimum before weighting, so
/// the weights are scale-free ("area matters twice as much as cycles"
/// is `2,1,1` regardless of units).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Objective {
    pub w_area: f64,
    pub w_energy: f64,
    pub w_cycles: f64,
}

impl Objective {
    pub fn balanced() -> Objective {
        Objective { w_area: 1.0, w_energy: 1.0, w_cycles: 1.0 }
    }

    /// Parse `"area,energy,cycles"` weights, e.g. `"1,1,1"` or
    /// `"2,0.5,1"`. Weights must be non-negative and not all zero.
    pub fn parse(s: &str) -> Result<Objective, String> {
        let parts: Vec<f64> = s
            .split(',')
            .map(|p| {
                p.trim()
                    .parse::<f64>()
                    .map_err(|_| format!("bad objective weight '{}'", p.trim()))
            })
            .collect::<Result<_, _>>()?;
        if parts.len() != 3 {
            return Err(format!(
                "expected 3 comma-separated weights (area,energy,cycles), \
                 got {}",
                parts.len()
            ));
        }
        if parts.iter().any(|w| *w < 0.0 || !w.is_finite()) {
            return Err("objective weights must be finite and >= 0".into());
        }
        if parts.iter().all(|w| *w == 0.0) {
            return Err("at least one objective weight must be > 0".into());
        }
        Ok(Objective { w_area: parts[0], w_energy: parts[1], w_cycles: parts[2] })
    }
}

/// The frontier point a weighted objective selects, ready to configure
/// the serving stack.
#[derive(Debug, Clone)]
pub struct TunedConfig {
    pub point: super::SweepPoint,
    pub metrics: PointMetrics,
    /// The point's hardware config on the Table I base (use
    /// [`super::SweepPoint::apply_dims`] to graft the geometry onto a
    /// different base, e.g. the SmallCNN functional config).
    pub hw: HardwareConfig,
}

/// Pick the frontier point minimizing the weighted normalized objective
/// (ties broken by lowest grid index — deterministic). `None` when the
/// frontier is empty.
pub fn select_config(
    results: &[PointResult],
    frontier: &ParetoFrontier,
    obj: &Objective,
) -> Option<TunedConfig> {
    let min3 = frontier.members.iter().fold(
        (f64::INFINITY, f64::INFINITY, f64::INFINITY),
        |(a, e, c), &i| {
            let m = results[i].metrics().expect("frontier members are valid");
            (a.min(m.area_cells), e.min(m.energy_pj), c.min(m.cycles))
        },
    );
    let score = |m: &PointMetrics| {
        obj.w_area * m.area_cells / min3.0.max(1e-12)
            + obj.w_energy * m.energy_pj / min3.1.max(1e-12)
            + obj.w_cycles * m.cycles / min3.2.max(1e-12)
    };
    let mut best: Option<(usize, f64)> = None;
    for &i in &frontier.members {
        let s = score(results[i].metrics().expect("valid"));
        match best {
            Some((_, bs)) if bs <= s => {}
            _ => best = Some((i, s)),
        }
    }
    let (i, _) = best?;
    let point = results[i].point.clone();
    let metrics = results[i].metrics().expect("valid").clone();
    let hw = point.hardware().ok()?;
    Some(TunedConfig { point, metrics, hw })
}

/// Per-axis sensitivity: results grouped by each axis's value, with
/// mean objectives per group — a quick read on which knob moves which
/// metric.
#[derive(Debug, Clone)]
pub struct AxisSensitivity {
    pub axis: String,
    pub groups: Vec<AxisGroup>,
}

#[derive(Debug, Clone)]
pub struct AxisGroup {
    pub value: String,
    pub n: usize,
    pub mean_cycles: f64,
    pub mean_energy_pj: f64,
    pub mean_area_cells: f64,
    pub min_cycles: f64,
}

impl AxisSensitivity {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("axis", self.axis.as_str().into()),
            (
                "groups",
                Json::Arr(
                    self.groups
                        .iter()
                        .map(|g| {
                            obj(vec![
                                ("value", g.value.as_str().into()),
                                ("n", g.n.into()),
                                ("mean_cycles", g.mean_cycles.into()),
                                ("mean_energy_pj", g.mean_energy_pj.into()),
                                ("mean_area_cells", g.mean_area_cells.into()),
                                ("min_cycles", g.min_cycles.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn lines(&self) -> String {
        let mut s = format!("axis {}:\n", self.axis);
        for g in &self.groups {
            s.push_str(&format!(
                "  {:<10} n={:<4} mean cycles {:>14.0}  mean energy {:>12.4e} pJ  \
                 mean area {:>12.0} cells\n",
                g.value, g.n, g.mean_cycles, g.mean_energy_pj, g.mean_area_cells,
            ));
        }
        s
    }
}

/// Group the valid results along each sweep axis, in first-appearance
/// order (deterministic: results are in grid order).
pub fn sensitivity(results: &[PointResult]) -> Vec<AxisSensitivity> {
    let axes: [(&str, fn(&super::SweepPoint) -> String); 9] = [
        ("scheme", |p| p.scheme.clone()),
        ("ou", |p| format!("{}x{}", p.ou_rows, p.ou_cols)),
        ("xbar", |p| format!("{}x{}", p.xbar_rows, p.xbar_cols)),
        ("patterns", |p| p.n_patterns.to_string()),
        ("pruning", |p| format!("{:.2}", p.pruning)),
        ("zero_detection", |p| p.zero_detection.to_string()),
        ("block_switch", |p| p.block_switch_cycles.to_string()),
        ("cores", |p| p.cores.to_string()),
        (
            "interconnect",
            |p| format!("bw{}hop{}", p.noc_bandwidth, p.noc_hop_latency),
        ),
    ];
    axes.iter()
        .map(|(axis, labeler)| {
            let mut order: Vec<String> = Vec::new();
            let mut sums: Vec<(usize, f64, f64, f64, f64)> = Vec::new();
            for r in results {
                let Some(m) = r.metrics() else { continue };
                let label = labeler(&r.point);
                let gi = match order.iter().position(|l| *l == label) {
                    Some(gi) => gi,
                    None => {
                        order.push(label);
                        sums.push((0, 0.0, 0.0, 0.0, f64::INFINITY));
                        order.len() - 1
                    }
                };
                let g = &mut sums[gi];
                g.0 += 1;
                g.1 += m.cycles;
                g.2 += m.energy_pj;
                g.3 += m.area_cells;
                g.4 = g.4.min(m.cycles);
            }
            AxisSensitivity {
                axis: axis.to_string(),
                groups: order
                    .into_iter()
                    .zip(sums)
                    .map(|(value, (n, c, e, a, minc))| AxisGroup {
                        value,
                        n,
                        mean_cycles: c / n.max(1) as f64,
                        mean_energy_pj: e / n.max(1) as f64,
                        mean_area_cells: a / n.max(1) as f64,
                        min_cycles: minc,
                    })
                    .collect(),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::super::{PointMetrics, PointResult, SweepPoint};
    use super::*;

    fn point(scheme: &str) -> SweepPoint {
        SweepPoint {
            scheme: scheme.into(),
            ou_rows: 9,
            ou_cols: 8,
            xbar_rows: 512,
            xbar_cols: 512,
            n_patterns: 8,
            pruning: 0.86,
            zero_detection: true,
            block_switch_cycles: 2.0,
            cores: 1,
            noc_bandwidth: 32.0,
            noc_hop_latency: 4.0,
        }
    }

    fn result(i: usize, area: f64, energy: f64, cycles: f64) -> PointResult {
        PointResult {
            index: i,
            point: point("pattern"),
            outcome: Ok(PointMetrics {
                cycles,
                energy_pj: energy,
                area_cells: area,
                crossbars: 1,
                ou_ops: cycles,
                utilization: 0.5,
            }),
            cache_hit: false,
        }
    }

    #[test]
    fn dominance_is_strict() {
        let a = result(0, 1.0, 1.0, 1.0);
        let b = result(1, 2.0, 2.0, 2.0);
        assert!(dominates(a.metrics().unwrap(), b.metrics().unwrap()));
        assert!(!dominates(b.metrics().unwrap(), a.metrics().unwrap()));
        // equal tuples never dominate each other
        let c = result(2, 1.0, 1.0, 1.0);
        assert!(!dominates(a.metrics().unwrap(), c.metrics().unwrap()));
        assert!(!dominates(c.metrics().unwrap(), a.metrics().unwrap()));
    }

    #[test]
    fn frontier_keeps_tradeoffs_drops_dominated() {
        let results = vec![
            result(0, 1.0, 3.0, 3.0), // best area
            result(1, 3.0, 1.0, 3.0), // best energy
            result(2, 3.0, 3.0, 1.0), // best cycles
            result(3, 3.0, 3.0, 3.0), // dominated by all three
            PointResult {
                index: 4,
                point: point("bogus"),
                outcome: Err("skipped".into()),
                cache_hit: false,
            },
        ];
        let f = ParetoFrontier::from_results(&results);
        assert_eq!(f.members, vec![0, 1, 2]);
        assert!(!f.is_empty());
        let table = f.table(&results);
        assert!(table.contains("3 of 5 points"), "{table}");
        let csv = f.to_csv(&results);
        assert_eq!(csv.lines().count(), 4, "{csv}");
        assert!(csv.starts_with("index,scheme"), "{csv}");
    }

    #[test]
    fn fast_extraction_matches_oracle_on_random_grids() {
        use crate::util::prop;
        prop::check(
            "pareto fast == oracle",
            prop::cases(64),
            |rng| {
                let n = 1 + rng.below(120);
                // Draw coords from a small discrete set so ties,
                // duplicate tuples, and equal-axis runs are common; a
                // few signed zeros keep the normalization honest.
                fn coord(rng: &mut crate::util::rng::Rng) -> f64 {
                    if rng.chance(0.05) {
                        -0.0
                    } else {
                        rng.below(6) as f64
                    }
                }
                let results: Vec<PointResult> = (0..n)
                    .map(|i| {
                        if rng.chance(0.1) {
                            PointResult {
                                index: i,
                                point: point("bogus"),
                                outcome: Err("skipped".into()),
                                cache_hit: false,
                            }
                        } else {
                            let a = coord(rng);
                            let e = coord(rng);
                            let c = coord(rng);
                            result(i, a, e, c)
                        }
                    })
                    .collect();
                let fast = ParetoFrontier::from_results(&results);
                let oracle = ParetoFrontier::from_results_oracle(&results);
                assert_eq!(
                    fast.members, oracle.members,
                    "fast/oracle divergence on {} points",
                    n
                );
            },
        );
    }

    #[test]
    fn fast_extraction_handles_ties_duplicates_and_signed_zero() {
        // Exact duplicates never dominate each other: both stay.
        let results = vec![
            result(0, 1.0, 2.0, 3.0),
            result(1, 1.0, 2.0, 3.0),
            result(2, 1.0, 2.0, 4.0), // dominated by 0/1 (same a, e)
            result(3, 1.0, 1.0, 9.0), // tradeoff within same area group
            result(4, 0.5, 2.0, 3.0), // dominates nothing of 0/1? a smaller, e/c equal => dominates 0,1,2
        ];
        let fast = ParetoFrontier::from_results(&results);
        let oracle = ParetoFrontier::from_results_oracle(&results);
        assert_eq!(fast.members, oracle.members);
        assert_eq!(fast.members, vec![3, 4]);

        // -0.0 and +0.0 compare equal under `dominates`; the sort path
        // must agree (normalization collapses the two zeros).
        let results = vec![result(0, 0.0, 1.0, 1.0), result(1, -0.0, 1.0, 1.0)];
        let fast = ParetoFrontier::from_results(&results);
        let oracle = ParetoFrontier::from_results_oracle(&results);
        assert_eq!(fast.members, oracle.members);
        assert_eq!(fast.members, vec![0, 1]);

        // NaN coords never compare: the point is an automatic member
        // and dominates nothing, same as the pairwise oracle.
        let results = vec![
            result(0, f64::NAN, 0.0, 0.0),
            result(1, 5.0, 5.0, 5.0),
            result(2, 1.0, 1.0, 1.0),
        ];
        let fast = ParetoFrontier::from_results(&results);
        let oracle = ParetoFrontier::from_results_oracle(&results);
        assert_eq!(fast.members, oracle.members);
        assert_eq!(fast.members, vec![0, 2]);
    }

    #[test]
    fn update_matches_full_extraction() {
        use crate::util::prop;
        prop::check(
            "pareto update == full extraction",
            prop::cases(64),
            |rng| {
                let n = 2 + rng.below(80);
                let results: Vec<PointResult> = (0..n)
                    .map(|i| {
                        let a = rng.below(5) as f64;
                        let e = rng.below(5) as f64;
                        let c = rng.below(5) as f64;
                        result(i, a, e, c)
                    })
                    .collect();
                // Warm-start from a prefix, fold in the rest.
                let split = 1 + rng.below(n - 1);
                let mut warm = ParetoFrontier::from_results(&results[..split]);
                let rest: Vec<usize> = (split..n).collect();
                warm.update(&results, &rest);
                let full = ParetoFrontier::from_results(&results);
                assert_eq!(warm.members, full.members);
            },
        );
    }

    #[test]
    fn update_evicts_newly_dominated_members() {
        let results = vec![
            result(0, 2.0, 2.0, 2.0),
            result(1, 1.0, 1.0, 1.0), // dominates 0
        ];
        let mut f = ParetoFrontier::from_results(&results[..1]);
        assert_eq!(f.members, vec![0]);
        f.update(&results, &[1]);
        assert_eq!(f.members, vec![1]);
        // no-op update keeps the frontier stable
        f.update(&results, &[]);
        assert_eq!(f.members, vec![1]);
    }

    #[test]
    fn objective_parse_and_validation() {
        let o = Objective::parse("2, 0.5,1").unwrap();
        assert_eq!(o.w_area, 2.0);
        assert_eq!(o.w_energy, 0.5);
        assert_eq!(o.w_cycles, 1.0);
        assert!(Objective::parse("1,1").is_err());
        assert!(Objective::parse("1,x,1").is_err());
        assert!(Objective::parse("-1,1,1").is_err());
        assert!(Objective::parse("0,0,0").is_err());
    }

    #[test]
    fn select_config_follows_weights() {
        let results = vec![
            result(0, 1.0, 3.0, 3.0),
            result(1, 3.0, 1.0, 3.0),
            result(2, 3.0, 3.0, 1.0),
        ];
        let f = ParetoFrontier::from_results(&results);
        let area_only =
            Objective { w_area: 1.0, w_energy: 0.0, w_cycles: 0.0 };
        let t = select_config(&results, &f, &area_only).expect("selected");
        assert_eq!(t.metrics.area_cells, 1.0);
        let cycles_only =
            Objective { w_area: 0.0, w_energy: 0.0, w_cycles: 1.0 };
        let t = select_config(&results, &f, &cycles_only).expect("selected");
        assert_eq!(t.metrics.cycles, 1.0);
        // balanced: all three tie at score 1 + 3 + 3 = 7; lowest index
        let t = select_config(&results, &f, &Objective::balanced()).unwrap();
        assert_eq!(t.point, results[0].point);
        assert_eq!(t.hw.ou_rows, 9);
        // empty frontier selects nothing
        assert!(select_config(&[], &ParetoFrontier { members: vec![] },
                              &Objective::balanced()).is_none());
    }

    #[test]
    fn sensitivity_groups_along_axes() {
        let mut a = result(0, 1.0, 1.0, 10.0);
        a.point.scheme = "naive".into();
        let mut b = result(1, 1.0, 1.0, 20.0);
        b.point.scheme = "naive".into();
        let c = result(2, 1.0, 1.0, 40.0); // pattern
        let axes = sensitivity(&[a, b, c]);
        assert_eq!(axes.len(), 9);
        assert_eq!(axes[7].axis, "cores");
        assert_eq!(axes[8].axis, "interconnect");
        let scheme = &axes[0];
        assert_eq!(scheme.axis, "scheme");
        assert_eq!(scheme.groups.len(), 2);
        assert_eq!(scheme.groups[0].value, "naive");
        assert_eq!(scheme.groups[0].n, 2);
        assert!((scheme.groups[0].mean_cycles - 15.0).abs() < 1e-12);
        assert_eq!(scheme.groups[0].min_cycles, 10.0);
        assert_eq!(scheme.groups[1].value, "pattern");
        assert!(scheme.lines().contains("naive"));
        let j = scheme.to_json();
        assert_eq!(j.get("groups").as_arr().map(|g| g.len()), Some(2));
    }
}
