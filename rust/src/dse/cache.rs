//! Content-hashed result cache for sweep evaluations, backed by the
//! binary pack store ([`crate::store`]).
//!
//! A point's cache identity is the FNV-1a hash of the canonical compact
//! JSON of `(format version, workload, point, environment)` —
//! evaluation is a pure function of exactly those inputs, so an
//! interrupted or repeated sweep resumes from `results/dse_cache/`
//! instead of recomputing. Entries store the identity strings alongside
//! the metrics and are verified on load (a hash collision or a
//! corrupt / truncated record falls back to a fresh evaluation, which
//! overwrites the bad entry).
//!
//! Storage backends:
//!
//! * **Binary (default)** — all entries live in one append-able pack
//!   (`dse.pack` + `dse.idx`) per cache directory; see [`crate::store`]
//!   for the byte format. Metrics are stored as raw little-endian f64
//!   bits ([`encode_metrics`]), so a cache hit reproduces the fresh
//!   evaluation's floats bit for bit by construction.
//! * **Legacy JSON** ([`ResultCache::legacy_json`]) — the historical
//!   one-file-per-entry layout (`{key:016x}.json`). In the binary
//!   backend this layout is a **read-only migration path**: a pack miss
//!   falls back to the matching v2 JSON entry, verifies it, migrates it
//!   into the pack and serves it — so no one's cache goes cold across
//!   the format change — but new entries are never written as JSON
//!   except through the explicit legacy backend (which exists for that
//!   migration test surface and writes compact, not pretty, JSON).
//!
//! Bit-exactness of the legacy path: metrics are serialized through
//! [`crate::util::json`], whose f64 writer emits the shortest
//! round-trippable decimal form, so both backends reproduce fresh
//! floats exactly (`tests/dse.rs` pins this).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::store::PackStore;
use crate::util::json::{obj, Json};

use super::{PointMetrics, SweepPoint, Workload};

/// Bump when the evaluation semantics or the metrics layout change:
/// old entries stop matching and are recomputed. v2: the identity
/// gained the trace mode (`Workload::exact`) and the per-point
/// simulation-policy axes (zero-detection, block-switch cost). v3: the
/// binary pack backend (metrics as raw f64 bits; v2 JSON entries are
/// still readable through the legacy fallback). v4: multi-core points
/// (`cores` × interconnect axes, pipelined cycle metric) — the point
/// and base-hardware JSON gained fields too, but the explicit bump
/// guarantees no stale single-core entry is ever served for the new
/// semantics.
const CACHE_FORMAT: usize = 4;

/// The last per-file JSON format — what the read-only legacy fallback
/// (and the explicit legacy backend) speaks.
const LEGACY_CACHE_FORMAT: usize = 2;

/// Pack domain name: `results/dse_cache/dse.{pack,idx}`.
const PACK_DOMAIN: &str = "dse";

/// Byte length of the binary metrics payload (6 × 8-byte LE fields).
const METRICS_LEN: usize = 48;

/// Encode metrics as 48 little-endian bytes: `cycles`, `energy_pj`,
/// `area_cells` (f64 bits), `crossbars` (u64), `ou_ops`, `utilization`
/// (f64 bits). Raw bits in, raw bits out — bit-exact by construction.
fn encode_metrics(m: &PointMetrics) -> [u8; METRICS_LEN] {
    let mut out = [0u8; METRICS_LEN];
    out[0..8].copy_from_slice(&m.cycles.to_bits().to_le_bytes());
    out[8..16].copy_from_slice(&m.energy_pj.to_bits().to_le_bytes());
    out[16..24].copy_from_slice(&m.area_cells.to_bits().to_le_bytes());
    out[24..32].copy_from_slice(&(m.crossbars as u64).to_le_bytes());
    out[32..40].copy_from_slice(&m.ou_ops.to_bits().to_le_bytes());
    out[40..48].copy_from_slice(&m.utilization.to_bits().to_le_bytes());
    out
}

/// Inverse of [`encode_metrics`]; `None` on a wrong-length payload
/// (treated as a miss, like any other corrupt entry).
fn decode_metrics(b: &[u8]) -> Option<PointMetrics> {
    if b.len() != METRICS_LEN {
        return None;
    }
    let word = |at: usize| -> u64 {
        u64::from_le_bytes(b[at..at + 8].try_into().expect("length checked"))
    };
    Some(PointMetrics {
        cycles: f64::from_bits(word(0)),
        energy_pj: f64::from_bits(word(8)),
        area_cells: f64::from_bits(word(16)),
        crossbars: word(24) as usize,
        ou_ops: f64::from_bits(word(32)),
        utilization: f64::from_bits(word(40)),
    })
}

/// Per-sweep cache environment: every identity component that does not
/// change across the grid, serialized **once** instead of once per
/// point per load/store. The workload JSON and the base
/// `HardwareConfig` are sweep constants; the effective `SimConfig` only
/// varies through the point's two simulation-policy axes, so one JSON
/// string per distinct `(zero_detection, block_switch)` pair covers the
/// whole grid (a handful of strings for 10^4+ points).
#[derive(Debug, Clone)]
pub struct CacheEnv {
    workload_json: String,
    base_hw_json: String,
    /// `(zero_detection, block_switch_cycles bits)` → effective
    /// `SimConfig` compact JSON.
    sim_json: BTreeMap<(bool, u64), String>,
}

impl CacheEnv {
    /// Environment for a whole sweep: serialize the constants once and
    /// pre-serialize the effective `SimConfig` of every distinct
    /// simulation-policy pair in the grid.
    pub fn for_sweep(w: &Workload, points: &[SweepPoint]) -> CacheEnv {
        let mut env = CacheEnv {
            workload_json: w.to_json().to_string_compact(),
            base_hw_json: crate::config::HardwareConfig::default()
                .to_json()
                .to_string_compact(),
            sim_json: BTreeMap::new(),
        };
        for p in points {
            let k = (p.zero_detection, p.block_switch_cycles.to_bits());
            if !env.sim_json.contains_key(&k) {
                env.sim_json.insert(
                    k,
                    super::runner::effective_sim_config(w, p)
                        .to_json()
                        .to_string_compact(),
                );
            }
        }
        env
    }

    /// One-point environment (the standalone `load`/`store` path).
    pub fn for_point(w: &Workload, p: &SweepPoint) -> CacheEnv {
        CacheEnv::for_sweep(w, std::slice::from_ref(p))
    }

    fn sim_json(&self, w: &Workload, p: &SweepPoint) -> String {
        match self
            .sim_json
            .get(&(p.zero_detection, p.block_switch_cycles.to_bits()))
        {
            Some(s) => s.clone(),
            // Point outside the grid the env was built for: fall back
            // to the uncached serialization (correct, just slower).
            None => super::runner::effective_sim_config(w, p)
                .to_json()
                .to_string_compact(),
        }
    }

    /// `(key, legacy key, workload identity, point identity,
    /// environment identity)` of one evaluation. The environment
    /// identity is the *effective* `SimConfig` the runner evaluates
    /// under — which carries the trace mode (sampled positions vs exact
    /// `null`) and the point's zero-detection / block-switch axes —
    /// plus the base `HardwareConfig` the point's geometry is grafted
    /// onto — every default included — so changing any simulation or
    /// hardware default invalidates old entries without anyone
    /// remembering to bump `CACHE_FORMAT`. A sampled-mode entry can
    /// therefore never be served for an exact-mode point (or vice
    /// versa): their effective `sample_positions` differ, and the
    /// workload JSON differs too.
    ///
    /// The env must have been built for the same `w`; identity
    /// components are shared per sweep precisely so the per-point cost
    /// is one point serialization plus two hashes.
    fn identity(&self, w: &Workload, p: &SweepPoint) -> CacheIdentity {
        let pj = p.to_json().to_string_compact();
        let ej = format!("{}|{}", self.sim_json(w, p), self.base_hw_json);
        let wj = self.workload_json.clone();
        let key = crate::util::fnv1a(&format!(
            "v{CACHE_FORMAT}|{wj}|{pj}|{ej}"
        ));
        let legacy_key = crate::util::fnv1a(&format!(
            "v{LEGACY_CACHE_FORMAT}|{wj}|{pj}|{ej}"
        ));
        CacheIdentity { key, legacy_key, wj, pj, ej }
    }

    /// The pack-record key of one evaluation — what frontier snapshots
    /// ([`ResultCache::store_snapshot`]) use to name covered points.
    pub fn point_key(&self, w: &Workload, p: &SweepPoint) -> u64 {
        self.identity(w, p).key
    }

    /// Key of the frontier snapshot for this sweep environment: one
    /// snapshot per `(workload, base hardware)` identity, so changing
    /// either starts a fresh warm-start history.
    fn snapshot_identity(&self) -> (u64, String) {
        let id = format!(
            "frontier|v{CACHE_FORMAT}|{}|{}",
            self.workload_json, self.base_hw_json
        );
        (crate::util::fnv1a(&id), id)
    }
}

/// Fully resolved identity of one cache entry.
struct CacheIdentity {
    /// v3 key — the pack record key.
    key: u64,
    /// v2 key — the legacy per-file JSON entry name.
    legacy_key: u64,
    wj: String,
    pj: String,
    ej: String,
}

impl CacheIdentity {
    /// The full identity string stored as the pack record id and
    /// verified on load.
    fn id_string(&self) -> String {
        format!(
            "v{CACHE_FORMAT}|{}|{}|{}",
            self.wj, self.pj, self.ej
        )
    }
}

/// Which storage layout a [`ResultCache`] writes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Backend {
    /// Pack store; per-file JSON entries are read-only fallback.
    Binary,
    /// Historical per-file JSON entries (compact form). Exists for the
    /// migration test surface and CI's legacy-seeding leg.
    LegacyJson,
}

/// Previously computed frontier state for warm-started sweeps: which
/// point keys the last run covered, and which of them were frontier
/// members. Sound to reuse only when the current grid is a superset of
/// `covered` — every non-member was dominated by a member that is
/// still in the grid ([`ResultCache::load_snapshot`] enforces nothing;
/// the runner checks).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FrontierSnapshot {
    /// Cache keys of every successfully evaluated point of the run.
    pub covered: Vec<u64>,
    /// Cache keys of the frontier members among them.
    pub members: Vec<u64>,
}

impl FrontierSnapshot {
    /// Binary payload: `u32 n_covered`, `u32 n_members`, then the
    /// covered keys and member keys as u64 LE.
    fn encode(&self) -> Vec<u8> {
        let mut out =
            Vec::with_capacity(8 + 8 * (self.covered.len() + self.members.len()));
        out.extend_from_slice(&(self.covered.len() as u32).to_le_bytes());
        out.extend_from_slice(&(self.members.len() as u32).to_le_bytes());
        for k in self.covered.iter().chain(self.members.iter()) {
            out.extend_from_slice(&k.to_le_bytes());
        }
        out
    }

    fn decode(b: &[u8]) -> Option<FrontierSnapshot> {
        if b.len() < 8 {
            return None;
        }
        let nc = u32::from_le_bytes(b[0..4].try_into().ok()?) as usize;
        let nm = u32::from_le_bytes(b[4..8].try_into().ok()?) as usize;
        if b.len() != 8 + 8 * (nc + nm) {
            return None;
        }
        let key_at = |i: usize| {
            u64::from_le_bytes(b[8 + 8 * i..16 + 8 * i].try_into().unwrap())
        };
        Some(FrontierSnapshot {
            covered: (0..nc).map(key_at).collect(),
            members: (nc..nc + nm).map(key_at).collect(),
        })
    }
}

/// Handle to one cache directory. Cheap to clone — binary-backend
/// clones share one pack handle.
#[derive(Debug, Clone)]
pub struct ResultCache {
    dir: PathBuf,
    backend: Backend,
    /// `None` in the legacy backend, or when the pack could not be
    /// opened (unwritable directory): loads then fall back to legacy
    /// JSON only and stores report the failure, keeping the cache
    /// best-effort like the per-file layout was.
    pack: Option<PackStore>,
}

impl ResultCache {
    /// Binary-backend cache at `dir` (the default everywhere).
    pub fn new<P: Into<PathBuf>>(dir: P) -> ResultCache {
        let dir: PathBuf = dir.into();
        let pack = match PackStore::open(&dir.to_string_lossy(), PACK_DOMAIN) {
            Ok(p) => Some(p),
            Err(e) => {
                eprintln!("[dse] cache store unavailable: {e} (continuing uncached)");
                None
            }
        };
        ResultCache { dir, backend: Backend::Binary, pack }
    }

    /// Legacy per-file JSON cache at `dir`: writes one compact JSON
    /// entry per point (`{key:016x}.json`, v2 layout). The binary
    /// backend reads these as a migration fallback; this constructor
    /// exists so tests and CI can *produce* them.
    pub fn legacy_json<P: Into<PathBuf>>(dir: P) -> ResultCache {
        ResultCache { dir: dir.into(), backend: Backend::LegacyJson, pack: None }
    }

    /// The conventional location the `dse` CLI and `serve --auto-tune`
    /// share: `results/dse_cache/`.
    pub fn default_dir() -> ResultCache {
        ResultCache::new("results/dse_cache")
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// True when this cache writes the binary pack layout.
    pub fn is_binary(&self) -> bool {
        self.backend == Backend::Binary
    }

    fn path_for(&self, legacy_key: u64) -> PathBuf {
        self.dir.join(format!("{legacy_key:016x}.json"))
    }

    /// Load a point's cached metrics, verifying the stored identity
    /// matches. Any miss, mismatch or parse failure returns `None`.
    /// Sweeps should build one [`CacheEnv`] and call
    /// [`ResultCache::load_with`] instead — this convenience re-derives
    /// the environment per call.
    pub fn load(&self, w: &Workload, p: &SweepPoint) -> Option<PointMetrics> {
        self.load_with(&CacheEnv::for_point(w, p), w, p)
    }

    /// [`ResultCache::load`] with a pre-built sweep environment.
    ///
    /// Every call lands on exactly one of the process-wide
    /// [`crate::obs::counters`] DSE-cache tallies (hit or miss), which
    /// the `/metrics` exposition exports.
    pub fn load_with(
        &self,
        env: &CacheEnv,
        w: &Workload,
        p: &SweepPoint,
    ) -> Option<PointMetrics> {
        let got = self.load_with_uncounted(env, w, p);
        if got.is_some() {
            crate::obs::counters::dse_cache_hit();
        } else {
            crate::obs::counters::dse_cache_miss();
        }
        got
    }

    fn load_with_uncounted(
        &self,
        env: &CacheEnv,
        w: &Workload,
        p: &SweepPoint,
    ) -> Option<PointMetrics> {
        let id = env.identity(w, p);
        match self.backend {
            Backend::Binary => {
                if let Some(pack) = &self.pack {
                    if let Some(rec) = pack.get(id.key) {
                        if rec.id == id.id_string() {
                            if let Some(m) = decode_metrics(&rec.payload) {
                                return Some(m);
                            }
                        }
                        // collision or corrupt payload: fall through to
                        // the legacy entry / a fresh evaluation
                    }
                }
                let m = self.load_legacy(&id)?;
                // Migrate the hit into the pack (best-effort) so the
                // JSON file is never parsed again.
                if let Some(pack) = &self.pack {
                    let _ = pack.put(id.key, &id.id_string(), &encode_metrics(&m));
                }
                Some(m)
            }
            Backend::LegacyJson => self.load_legacy(&id),
        }
    }

    /// Read-only legacy path: one v2 JSON entry per point. Accepts both
    /// pretty and compact serializations (the parser does not care).
    fn load_legacy(&self, id: &CacheIdentity) -> Option<PointMetrics> {
        let text = std::fs::read_to_string(self.path_for(id.legacy_key)).ok()?;
        let j = Json::parse(&text).ok()?;
        if j.get("format").as_usize() != Some(LEGACY_CACHE_FORMAT) {
            return None;
        }
        if j.get("workload").as_str() != Some(id.wj.as_str())
            || j.get("point").as_str() != Some(id.pj.as_str())
            || j.get("environment").as_str() != Some(id.ej.as_str())
        {
            return None; // hash collision or stale defaults: recompute
        }
        PointMetrics::from_json(j.get("metrics"))
    }

    /// Persist a point's metrics. Write failures are returned, not
    /// fatal — the runner treats the cache as best-effort. Sweeps
    /// should use [`ResultCache::store_with`] with a shared env.
    pub fn store(
        &self,
        w: &Workload,
        p: &SweepPoint,
        m: &PointMetrics,
    ) -> std::io::Result<()> {
        self.store_with(&CacheEnv::for_point(w, p), w, p, m)
    }

    /// [`ResultCache::store`] with a pre-built sweep environment.
    pub fn store_with(
        &self,
        env: &CacheEnv,
        w: &Workload,
        p: &SweepPoint,
        m: &PointMetrics,
    ) -> std::io::Result<()> {
        let id = env.identity(w, p);
        match self.backend {
            Backend::Binary => {
                let pack = self.pack.as_ref().ok_or_else(|| {
                    std::io::Error::new(
                        std::io::ErrorKind::Other,
                        "cache pack store unavailable",
                    )
                })?;
                pack.put(id.key, &id.id_string(), &encode_metrics(m))
                    .map_err(|e| {
                        std::io::Error::new(std::io::ErrorKind::Other, e)
                    })
            }
            Backend::LegacyJson => {
                std::fs::create_dir_all(&self.dir)?;
                let entry = obj(vec![
                    ("format", LEGACY_CACHE_FORMAT.into()),
                    ("workload", id.wj.as_str().into()),
                    ("point", id.pj.as_str().into()),
                    ("environment", id.ej.as_str().into()),
                    ("metrics", m.to_json()),
                ]);
                // Machine-read only: compact, not pretty.
                std::fs::write(
                    self.path_for(id.legacy_key),
                    entry.to_string_compact(),
                )
            }
        }
    }

    /// The last stored frontier snapshot for this sweep environment
    /// (binary backend only — the legacy layout predates warm starts).
    pub fn load_snapshot(&self, env: &CacheEnv) -> Option<FrontierSnapshot> {
        let pack = self.pack.as_ref()?;
        let (key, id) = env.snapshot_identity();
        let rec = pack.get(key)?;
        if rec.id != id {
            return None;
        }
        FrontierSnapshot::decode(&rec.payload)
    }

    /// Persist the frontier snapshot for this sweep environment
    /// (no-op `Ok` miss on the legacy backend).
    pub fn store_snapshot(
        &self,
        env: &CacheEnv,
        snap: &FrontierSnapshot,
    ) -> std::io::Result<()> {
        let Some(pack) = self.pack.as_ref() else {
            return Ok(());
        };
        let (key, id) = env.snapshot_identity();
        pack.put(key, &id, &snap.encode())
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::Other, e))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_cache(tag: &str) -> ResultCache {
        ResultCache::new(temp_dir(tag))
    }

    fn temp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("rram-dse-cache-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn point() -> SweepPoint {
        SweepPoint {
            scheme: "pattern".into(),
            ou_rows: 9,
            ou_cols: 8,
            xbar_rows: 512,
            xbar_cols: 512,
            n_patterns: 8,
            pruning: 0.86,
            zero_detection: true,
            block_switch_cycles: 2.0,
            cores: 1,
            noc_bandwidth: 32.0,
            noc_hop_latency: 4.0,
        }
    }

    fn metrics() -> PointMetrics {
        PointMetrics {
            cycles: 12345.625, // exactly representable: survives the trip
            energy_pj: 6.7e8,
            area_cells: 262144.0,
            crossbars: 1,
            ou_ops: 11111.0,
            utilization: 0.421875,
        }
    }

    #[test]
    fn metrics_binary_codec_is_bit_exact() {
        // awkward floats round-trip exactly: raw bits in, raw bits out
        let m = PointMetrics {
            cycles: 0.1 + 0.2,
            energy_pj: 1.0 / 3.0,
            area_cells: f64::MAX,
            crossbars: usize::MAX >> 1,
            ou_ops: 5e-324, // smallest subnormal
            utilization: -0.0,
        };
        let enc = encode_metrics(&m);
        let back = decode_metrics(&enc).expect("decodes");
        assert_eq!(m.cycles.to_bits(), back.cycles.to_bits());
        assert_eq!(m.energy_pj.to_bits(), back.energy_pj.to_bits());
        assert_eq!(m.ou_ops.to_bits(), back.ou_ops.to_bits());
        assert_eq!(m.utilization.to_bits(), back.utilization.to_bits());
        assert_eq!(m.crossbars, back.crossbars);
        assert!(decode_metrics(&enc[..47]).is_none(), "short payload misses");
        assert!(decode_metrics(&[0u8; 49]).is_none(), "long payload misses");
    }

    #[test]
    fn store_then_load_roundtrips_bitwise() {
        let c = temp_cache("roundtrip");
        let w = Workload::small(7);
        let p = point();
        assert!(c.load(&w, &p).is_none(), "cold cache misses");
        c.store(&w, &p, &metrics()).unwrap();
        let got = c.load(&w, &p).expect("hit after store");
        assert_eq!(got, metrics());
        // survives reopen (a second process / a later sweep)
        let c2 = ResultCache::new(c.dir().to_path_buf());
        assert_eq!(c2.load(&w, &p).expect("hit after reopen"), metrics());
        let _ = std::fs::remove_dir_all(c.dir());
    }

    #[test]
    fn identity_separates_points_and_workloads() {
        let c = temp_cache("identity");
        let w = Workload::small(7);
        let p = point();
        c.store(&w, &p, &metrics()).unwrap();
        // different point: miss
        let mut p2 = point();
        p2.ou_rows = 4;
        assert!(c.load(&w, &p2).is_none());
        // different workload seed: miss
        let w2 = Workload::small(8);
        assert!(c.load(&w2, &p).is_none());
        let _ = std::fs::remove_dir_all(c.dir());
    }

    /// Regression (ISSUE-5): a sampled-mode cache entry must never be
    /// served for an exact-mode point, and the simulation-policy axes
    /// are part of the identity too.
    #[test]
    fn sampled_entry_never_serves_exact_or_other_sim_axes() {
        let c = temp_cache("trace-mode");
        let w_sampled = Workload::small(7);
        assert!(!w_sampled.exact, "small workload defaults to sampled");
        let p = point();
        c.store(&w_sampled, &p, &metrics()).unwrap();
        assert!(c.load(&w_sampled, &p).is_some(), "own mode hits");

        // exact mode: same workload otherwise, must miss
        let w_exact = Workload { exact: true, ..w_sampled.clone() };
        assert!(
            c.load(&w_exact, &p).is_none(),
            "sampled entry served for an exact-mode point"
        );
        // and the exact entry lands in its own slot, leaving the
        // sampled one intact
        c.store(&w_exact, &p, &metrics()).unwrap();
        assert!(c.load(&w_exact, &p).is_some());
        assert!(c.load(&w_sampled, &p).is_some());

        // zero-detection axis: miss
        let p_zd = SweepPoint { zero_detection: false, ..point() };
        assert!(c.load(&w_sampled, &p_zd).is_none());
        // block-switch axis: miss
        let p_bs = SweepPoint { block_switch_cycles: 0.0, ..point() };
        assert!(c.load(&w_sampled, &p_bs).is_none());
        // multi-core axes: a single-core entry never serves a
        // multi-core point (or a different interconnect)
        let p_mc = SweepPoint { cores: 2, ..point() };
        assert!(c.load(&w_sampled, &p_mc).is_none());
        let p_ic = SweepPoint { cores: 2, noc_bandwidth: 64.0, ..point() };
        c.store(&w_sampled, &p_mc, &metrics()).unwrap();
        assert!(c.load(&w_sampled, &p_ic).is_none());
        assert!(c.load(&w_sampled, &p_mc).is_some());
        let _ = std::fs::remove_dir_all(c.dir());
    }

    #[test]
    fn hoisted_env_matches_per_point_identity() {
        let c = temp_cache("env");
        let w = Workload::small(7);
        let p = point();
        let mut p2 = point();
        p2.zero_detection = false;
        let points = [p.clone(), p2.clone()];
        let env = CacheEnv::for_sweep(&w, &points);
        // store through the hoisted env, load through the per-point
        // path (and vice versa): identities must agree
        c.store_with(&env, &w, &p, &metrics()).unwrap();
        assert_eq!(c.load(&w, &p), Some(metrics()));
        c.store(&w, &p2, &metrics()).unwrap();
        assert_eq!(c.load_with(&env, &w, &p2), Some(metrics()));
        // a point outside the env's grid still resolves (fallback)
        let mut p3 = point();
        p3.block_switch_cycles = 9.0;
        assert!(c.load_with(&env, &w, &p3).is_none());
        c.store_with(&env, &w, &p3, &metrics()).unwrap();
        assert_eq!(c.load(&w, &p3), Some(metrics()));
        // keys are stable and distinct per point
        assert_ne!(env.point_key(&w, &p), env.point_key(&w, &p2));
        assert_eq!(env.point_key(&w, &p), env.point_key(&w, &p));
        let _ = std::fs::remove_dir_all(c.dir());
    }

    #[test]
    fn legacy_backend_writes_compact_v2_entries() {
        let dir = temp_dir("legacy");
        let c = ResultCache::legacy_json(dir.clone());
        assert!(!c.is_binary());
        let w = Workload::small(7);
        let p = point();
        assert!(c.load(&w, &p).is_none(), "cold cache misses");
        c.store(&w, &p, &metrics()).unwrap();
        assert_eq!(c.load(&w, &p), Some(metrics()));
        // exactly one per-point JSON file, in compact form, v2 layout
        let files: Vec<PathBuf> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .filter(|p| p.extension().is_some_and(|e| e == "json"))
            .collect();
        assert_eq!(files.len(), 1, "{files:?}");
        let text = std::fs::read_to_string(&files[0]).unwrap();
        assert!(!text.contains('\n'), "compact, not pretty: {text}");
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.get("format").as_usize(), Some(2));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn binary_backend_migrates_legacy_entries() {
        let dir = temp_dir("migrate");
        let w = Workload::small(7);
        let p = point();
        // seed via the legacy writer (compact), plus a hand-written
        // pretty entry for a second point — the fallback reads both
        let legacy = ResultCache::legacy_json(dir.clone());
        legacy.store(&w, &p, &metrics()).unwrap();
        let mut p2 = point();
        p2.ou_rows = 4;
        legacy.store(&w, &p2, &metrics()).unwrap();
        {
            // re-write p2's entry pretty-printed (the historical form)
            let env = CacheEnv::for_point(&w, &p2);
            let id = env.identity(&w, &p2);
            let text =
                std::fs::read_to_string(legacy.path_for(id.legacy_key)).unwrap();
            let pretty = Json::parse(&text).unwrap().to_string_pretty();
            assert!(pretty.contains('\n'));
            std::fs::write(legacy.path_for(id.legacy_key), pretty).unwrap();
        }

        let c = ResultCache::new(dir.clone());
        assert!(c.is_binary());
        assert_eq!(c.load(&w, &p), Some(metrics()), "compact legacy hit");
        assert_eq!(c.load(&w, &p2), Some(metrics()), "pretty legacy hit");
        // the hits migrated into the pack: remove the JSON files and
        // they still hit
        for f in std::fs::read_dir(&dir).unwrap() {
            let f = f.unwrap().path();
            if f.extension().is_some_and(|e| e == "json") {
                std::fs::remove_file(f).unwrap();
            }
        }
        assert_eq!(c.load(&w, &p), Some(metrics()), "served from pack");
        assert_eq!(c.load(&w, &p2), Some(metrics()), "served from pack");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_legacy_entry_reads_as_miss() {
        let dir = temp_dir("corrupt");
        let c = ResultCache::new(dir.clone());
        let w = Workload::small(7);
        let p = point();
        let env = CacheEnv::for_point(&w, &p);
        let id = env.identity(&w, &p);
        std::fs::write(c.path_for(id.legacy_key), "{truncated").unwrap();
        assert!(c.load(&w, &p).is_none(), "corrupt file must miss");
        // a fresh store heals it (into the pack)
        c.store(&w, &p, &metrics()).unwrap();
        assert!(c.load(&w, &p).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn frontier_snapshot_roundtrips() {
        let dir = temp_dir("snapshot");
        let c = ResultCache::new(dir.clone());
        let w = Workload::small(7);
        let env = CacheEnv::for_sweep(&w, &[point()]);
        assert!(c.load_snapshot(&env).is_none(), "cold snapshot misses");
        let snap = FrontierSnapshot {
            covered: vec![3, 1, u64::MAX, 7],
            members: vec![1, 7],
        };
        c.store_snapshot(&env, &snap).unwrap();
        assert_eq!(c.load_snapshot(&env), Some(snap.clone()));
        // a different workload env has its own snapshot slot
        let env8 = CacheEnv::for_sweep(&Workload::small(8), &[point()]);
        assert!(c.load_snapshot(&env8).is_none());
        // overwrite wins
        let snap2 = FrontierSnapshot { covered: vec![9], members: vec![9] };
        c.store_snapshot(&env, &snap2).unwrap();
        assert_eq!(c.load_snapshot(&env), Some(snap2));
        // empty snapshot is representable
        let empty = FrontierSnapshot::default();
        assert_eq!(
            FrontierSnapshot::decode(&empty.encode()),
            Some(empty)
        );
        // legacy backend: snapshots are absent but not an error
        let legacy = ResultCache::legacy_json(dir.clone());
        assert!(legacy.load_snapshot(&env).is_none());
        legacy.store_snapshot(&env, &snap).unwrap();
        assert!(legacy.load_snapshot(&env).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
