//! Content-hashed on-disk result cache for sweep evaluations.
//!
//! A point's cache identity is the FNV-1a hash of the canonical compact
//! JSON of `(format version, workload, point)` — evaluation is a pure
//! function of exactly those inputs, so an interrupted or repeated
//! sweep resumes from `results/dse_cache/` instead of recomputing.
//! Entries store the identity strings alongside the metrics and are
//! verified on load (a hash collision or a corrupt / truncated file
//! from an interrupted run falls back to a fresh evaluation, which
//! overwrites the bad entry).
//!
//! Bit-exactness: metrics are serialized through
//! [`crate::util::json`], whose f64 writer emits the shortest
//! round-trippable decimal form, so a cache hit reproduces the fresh
//! evaluation's floats bit for bit (`tests/dse.rs` pins this).

use std::path::{Path, PathBuf};

use crate::util::json::{obj, Json};

use super::{PointMetrics, SweepPoint, Workload};

/// Bump when the evaluation semantics or the metrics layout change:
/// old entries stop matching and are recomputed. v2: the identity
/// gained the trace mode (`Workload::exact`) and the per-point
/// simulation-policy axes (zero-detection, block-switch cost).
const CACHE_FORMAT: usize = 2;

/// Handle to one cache directory.
#[derive(Debug, Clone)]
pub struct ResultCache {
    dir: PathBuf,
}

impl ResultCache {
    pub fn new<P: Into<PathBuf>>(dir: P) -> ResultCache {
        ResultCache { dir: dir.into() }
    }

    /// The conventional location the `dse` CLI and `serve --auto-tune`
    /// share: `results/dse_cache/`.
    pub fn default_dir() -> ResultCache {
        ResultCache::new("results/dse_cache")
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// `(hash, workload identity, point identity, environment identity)`
    /// of one evaluation. The environment identity is the *effective*
    /// `SimConfig` the runner evaluates under — which carries the trace
    /// mode (sampled positions vs exact `null`) and the point's
    /// zero-detection / block-switch axes — plus the base
    /// `HardwareConfig` the point's geometry is grafted onto — every
    /// default included — so changing any simulation or hardware
    /// default invalidates old entries without anyone remembering to
    /// bump `CACHE_FORMAT`. A sampled-mode entry can therefore never be
    /// served for an exact-mode point (or vice versa): their effective
    /// `sample_positions` differ, and the workload JSON differs too.
    fn identity(w: &Workload, p: &SweepPoint) -> (u64, String, String, String) {
        let wj = w.to_json().to_string_compact();
        let pj = p.to_json().to_string_compact();
        let sim = super::runner::effective_sim_config(w, p)
            .to_json()
            .to_string_compact();
        let base = crate::config::HardwareConfig::default()
            .to_json()
            .to_string_compact();
        let ej = format!("{sim}|{base}");
        let key =
            crate::util::fnv1a(&format!("v{CACHE_FORMAT}|{wj}|{pj}|{ej}"));
        (key, wj, pj, ej)
    }

    fn path_for(&self, key: u64) -> PathBuf {
        self.dir.join(format!("{key:016x}.json"))
    }

    /// Load a point's cached metrics, verifying the stored identity
    /// matches. Any miss, mismatch or parse failure returns `None`.
    pub fn load(&self, w: &Workload, p: &SweepPoint) -> Option<PointMetrics> {
        let (key, wj, pj, ej) = Self::identity(w, p);
        let text = std::fs::read_to_string(self.path_for(key)).ok()?;
        let j = Json::parse(&text).ok()?;
        if j.get("format").as_usize() != Some(CACHE_FORMAT) {
            return None;
        }
        if j.get("workload").as_str() != Some(wj.as_str())
            || j.get("point").as_str() != Some(pj.as_str())
            || j.get("environment").as_str() != Some(ej.as_str())
        {
            return None; // hash collision or stale defaults: recompute
        }
        PointMetrics::from_json(j.get("metrics"))
    }

    /// Persist a point's metrics (creates the cache directory). Write
    /// failures are returned, not fatal — the runner treats the cache
    /// as best-effort.
    pub fn store(
        &self,
        w: &Workload,
        p: &SweepPoint,
        m: &PointMetrics,
    ) -> std::io::Result<()> {
        let (key, wj, pj, ej) = Self::identity(w, p);
        std::fs::create_dir_all(&self.dir)?;
        let entry = obj(vec![
            ("format", CACHE_FORMAT.into()),
            ("workload", wj.into()),
            ("point", pj.into()),
            ("environment", ej.into()),
            ("metrics", m.to_json()),
        ]);
        std::fs::write(self.path_for(key), entry.to_string_pretty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_cache(tag: &str) -> ResultCache {
        let dir = std::env::temp_dir()
            .join(format!("rram-dse-cache-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        ResultCache::new(dir)
    }

    fn point() -> SweepPoint {
        SweepPoint {
            scheme: "pattern".into(),
            ou_rows: 9,
            ou_cols: 8,
            xbar_rows: 512,
            xbar_cols: 512,
            n_patterns: 8,
            pruning: 0.86,
            zero_detection: true,
            block_switch_cycles: 2.0,
        }
    }

    fn metrics() -> PointMetrics {
        PointMetrics {
            cycles: 12345.625, // exactly representable: survives the trip
            energy_pj: 6.7e8,
            area_cells: 262144.0,
            crossbars: 1,
            ou_ops: 11111.0,
            utilization: 0.421875,
        }
    }

    #[test]
    fn store_then_load_roundtrips_bitwise() {
        let c = temp_cache("roundtrip");
        let w = Workload::small(7);
        let p = point();
        assert!(c.load(&w, &p).is_none(), "cold cache misses");
        c.store(&w, &p, &metrics()).unwrap();
        let got = c.load(&w, &p).expect("hit after store");
        assert_eq!(got, metrics());
        let _ = std::fs::remove_dir_all(c.dir());
    }

    #[test]
    fn identity_separates_points_and_workloads() {
        let c = temp_cache("identity");
        let w = Workload::small(7);
        let p = point();
        c.store(&w, &p, &metrics()).unwrap();
        // different point: miss
        let mut p2 = point();
        p2.ou_rows = 4;
        assert!(c.load(&w, &p2).is_none());
        // different workload seed: miss
        let w2 = Workload::small(8);
        assert!(c.load(&w2, &p).is_none());
        let _ = std::fs::remove_dir_all(c.dir());
    }

    /// Regression (ISSUE-5): a sampled-mode cache entry must never be
    /// served for an exact-mode point, and the simulation-policy axes
    /// are part of the identity too.
    #[test]
    fn sampled_entry_never_serves_exact_or_other_sim_axes() {
        let c = temp_cache("trace-mode");
        let w_sampled = Workload::small(7);
        assert!(!w_sampled.exact, "small workload defaults to sampled");
        let p = point();
        c.store(&w_sampled, &p, &metrics()).unwrap();
        assert!(c.load(&w_sampled, &p).is_some(), "own mode hits");

        // exact mode: same workload otherwise, must miss
        let w_exact = Workload { exact: true, ..w_sampled.clone() };
        assert!(
            c.load(&w_exact, &p).is_none(),
            "sampled entry served for an exact-mode point"
        );
        // and the exact entry lands in its own slot, leaving the
        // sampled one intact
        c.store(&w_exact, &p, &metrics()).unwrap();
        assert!(c.load(&w_exact, &p).is_some());
        assert!(c.load(&w_sampled, &p).is_some());

        // zero-detection axis: miss
        let p_zd = SweepPoint { zero_detection: false, ..point() };
        assert!(c.load(&w_sampled, &p_zd).is_none());
        // block-switch axis: miss
        let p_bs = SweepPoint { block_switch_cycles: 0.0, ..point() };
        assert!(c.load(&w_sampled, &p_bs).is_none());
        let _ = std::fs::remove_dir_all(c.dir());
    }

    #[test]
    fn corrupt_entry_reads_as_miss() {
        let c = temp_cache("corrupt");
        let w = Workload::small(7);
        let p = point();
        c.store(&w, &p, &metrics()).unwrap();
        let (key, _, _, _) = ResultCache::identity(&w, &p);
        std::fs::write(c.path_for(key), "{truncated").unwrap();
        assert!(c.load(&w, &p).is_none(), "corrupt file must miss");
        // a fresh store heals it
        c.store(&w, &p, &metrics()).unwrap();
        assert!(c.load(&w, &p).is_some());
        let _ = std::fs::remove_dir_all(c.dir());
    }
}
