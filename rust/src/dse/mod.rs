//! Design-space exploration (DSE) engine.
//!
//! The paper reports one hand-picked configuration (Table I: 512×512
//! crossbars, 9×8 OUs, per-dataset pattern counts); this subsystem
//! sweeps that configuration space systematically and feeds the winner
//! back into the serving stack:
//!
//! ```text
//!   SweepSpec (axes: OU dims × crossbar dims × pattern count ×
//!              pruning rate × mapping scheme)
//!        │ expand() — deterministic grid order
//!        ▼
//!   SweepRunner — points in parallel on util::threadpool, each point a
//!        │        pure function of (workload, point): synthesize the
//!        │        pattern-pruned weights, map with the point's scheme,
//!        │        cost the batch through sim::simulate_network_batch.
//!        │        A content-hashed on-disk cache (results/dse_cache/)
//!        │        makes repeated / interrupted sweeps resume.
//!        ▼
//!   ParetoFrontier — non-dominated (area, energy, cycles) set with
//!        │           per-axis sensitivity summaries
//!        ▼
//!   select_config(Objective) → TunedConfig — the frontier point
//!   optimizing the user-weighted objective; `serve --auto-tune`
//!   builds its worker pool's hardware config and calibrated CostModel
//!   from it, so the sweep winner is what actually serves traffic.
//! ```
//!
//! Determinism contract (pinned by `tests/dse.rs`): for a fixed
//! [`SweepSpec`], the frontier JSON is byte-identical for any thread
//! count, across repeated runs, and across cached vs fresh evaluation.
//! Every quantity in the emitted artifact is derived from the sweep
//! itself — no timestamps, no cache metadata.

pub mod cache;
pub mod pareto;
pub mod runner;

pub use cache::{CacheEnv, FrontierSnapshot, ResultCache};
pub use pareto::{
    select_config, sensitivity, AxisSensitivity, Objective, ParetoFrontier,
    TunedConfig,
};
pub use runner::{SweepOutcome, SweepRunner, SweepStage};

use crate::config::HardwareConfig;
use crate::nn::{ConvLayer, NetworkSpec};
use crate::util::json::{obj, Json};

/// One grid point of the sweep: a full accelerator + compression +
/// simulation-policy configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    /// Mapping scheme name (resolved via
    /// [`crate::mapping::scheme_by_name`]).
    pub scheme: String,
    pub ou_rows: usize,
    pub ou_cols: usize,
    pub xbar_rows: usize,
    pub xbar_cols: usize,
    /// Distinct pruning patterns per layer (Table II knob).
    pub n_patterns: usize,
    /// Target weight sparsity of the pattern pruning (Table II knob).
    pub pruning: f64,
    /// `SimConfig::zero_detection` for this point (Input Preprocessing
    /// Unit on/off — applies to IPU schemes only, as in the simulator).
    pub zero_detection: bool,
    /// `SimConfig::block_switch_cycles` for this point (§IV-C index
    /// decode overhead per pattern-block crossing).
    pub block_switch_cycles: f64,
    /// CIM cores for this point (`HardwareConfig::cores`); `> 1` routes
    /// the point through the layer-to-core placement planner
    /// ([`crate::sim::placement`]) and its pipelined cycle model.
    pub cores: usize,
    /// NoC bandwidth, bytes/cycle (`HardwareConfig::noc_bandwidth`).
    pub noc_bandwidth: f64,
    /// NoC per-hop latency, cycles (`HardwareConfig::noc_hop_latency`).
    pub noc_hop_latency: f64,
}

impl SweepPoint {
    /// Short human label, e.g. `pattern ou9x8 xb512 p8 s0.86 zd1 bs2`;
    /// multi-core points append ` c4 bw64 hop2`.
    pub fn label(&self) -> String {
        let mut l = format!(
            "{} ou{}x{} xb{}x{} p{} s{:.2} zd{} bs{}",
            self.scheme,
            self.ou_rows,
            self.ou_cols,
            self.xbar_rows,
            self.xbar_cols,
            self.n_patterns,
            self.pruning,
            self.zero_detection as u8,
            self.block_switch_cycles,
        );
        if self.cores > 1 {
            l.push_str(&format!(
                " c{} bw{} hop{}",
                self.cores, self.noc_bandwidth, self.noc_hop_latency
            ));
        }
        l
    }

    /// The point's hardware config on the paper's Table I base.
    pub fn hardware(&self) -> Result<HardwareConfig, String> {
        self.apply_dims(&HardwareConfig::default())
    }

    /// Graft this point's OU / crossbar geometry and multi-core block
    /// onto an arbitrary base config (e.g.
    /// [`HardwareConfig::smallcnn_functional`] when tuning the serving
    /// stack), validated.
    pub fn apply_dims(&self, base: &HardwareConfig) -> Result<HardwareConfig, String> {
        base.with_dims(self.ou_rows, self.ou_cols, self.xbar_rows, self.xbar_cols)?
            .with_cores(self.cores, self.noc_bandwidth, self.noc_hop_latency)
    }

    /// Canonical JSON (BTreeMap-ordered keys): the cache identity and
    /// the frontier artifact's point encoding. The simulation-policy
    /// axes are part of it, so points that differ only in
    /// zero-detection or block-switch cost never share a cache entry.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("scheme", self.scheme.as_str().into()),
            ("ou_rows", self.ou_rows.into()),
            ("ou_cols", self.ou_cols.into()),
            ("xbar_rows", self.xbar_rows.into()),
            ("xbar_cols", self.xbar_cols.into()),
            ("n_patterns", self.n_patterns.into()),
            ("pruning", self.pruning.into()),
            ("zero_detection", self.zero_detection.into()),
            ("block_switch_cycles", self.block_switch_cycles.into()),
            ("cores", self.cores.into()),
            ("noc_bandwidth", self.noc_bandwidth.into()),
            ("noc_hop_latency", self.noc_hop_latency.into()),
        ])
    }
}

/// The fixed workload every point of a sweep is costed on. Weights are
/// synthesized per point from `(seed, layer, n_patterns, pruning)`, so
/// points that share the compression knobs simulate identical networks
/// and differ only in hardware.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    pub name: String,
    pub layers: Vec<ConvLayer>,
    /// Images per simulated batch (all metrics are batch totals).
    pub n_images: usize,
    /// Sampled positions per layer (`SimConfig::sample_positions`),
    /// ignored in exact mode.
    pub samples: usize,
    /// Exact trace mode: every output position is traced
    /// (`SimConfig::sample_positions = None`) instead of `samples`
    /// sampled ones. Part of the cache identity — sampled and exact
    /// evaluations of the same point never collide.
    pub exact: bool,
    /// All-zero-kernel ratio fed to the synthetic generator.
    pub zero_ratio: f64,
    /// Seed for weight synthesis and activation traces.
    pub seed: u64,
}

impl Workload {
    /// Small 3-layer CNN: fast enough that CI sweeps a full grid in
    /// seconds, large enough that mapping schemes separate.
    pub fn small(seed: u64) -> Workload {
        Workload {
            name: "dse-small".into(),
            layers: vec![
                ConvLayer { name: "d0".into(), cin: 3, cout: 16, fmap: 8 },
                ConvLayer { name: "d1".into(), cin: 16, cout: 32, fmap: 8 },
                ConvLayer { name: "d2".into(), cin: 32, cout: 32, fmap: 4 },
            ],
            n_images: 2,
            samples: 32,
            exact: false,
            zero_ratio: 0.3,
            seed,
        }
    }

    pub fn spec(&self) -> NetworkSpec {
        NetworkSpec { name: self.name.clone(), layers: self.layers.clone() }
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", self.name.as_str().into()),
            (
                "layers",
                Json::Arr(
                    self.layers
                        .iter()
                        .map(|l| {
                            obj(vec![
                                ("cin", l.cin.into()),
                                ("cout", l.cout.into()),
                                ("fmap", l.fmap.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("n_images", self.n_images.into()),
            ("samples", self.samples.into()),
            ("exact", self.exact.into()),
            ("zero_ratio", self.zero_ratio.into()),
            ("seed", (self.seed as usize).into()),
        ])
    }
}

/// A sweep: the axes of the configuration grid plus the workload each
/// point is evaluated on. `expand()` yields the cross product in a
/// fixed nested order, so result indices are stable across runs.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepSpec {
    /// Grid name (`small`, `medium`, `large`, or caller-defined).
    pub grid: String,
    pub schemes: Vec<String>,
    /// (rows, cols) of the Operation Unit.
    pub ou: Vec<(usize, usize)>,
    /// (rows, cols) of the crossbar array.
    pub xbar: Vec<(usize, usize)>,
    pub patterns: Vec<usize>,
    /// Pruning rates (target sparsities).
    pub pruning: Vec<f64>,
    /// `SimConfig::zero_detection` axis (singleton `[true]` in the
    /// named grids; widen via [`SweepSpec::with_sim_axes`] or the CLI).
    pub zero_detection: Vec<bool>,
    /// `SimConfig::block_switch_cycles` axis (singleton `[2.0]` — the
    /// simulator default — in the named grids).
    pub block_switch: Vec<f64>,
    /// Core-count axis (singleton `[1]` — the paper's monolithic chip —
    /// in the named grids; widen via [`SweepSpec::with_core_axes`] or
    /// the CLI's `--cores`).
    pub cores: Vec<usize>,
    /// Interconnect axis: `(noc_bandwidth, noc_hop_latency)` pairs
    /// (singleton hardware default in the named grids). Single-core
    /// points collapse this axis — with no inter-core traffic the knobs
    /// are inert, and expanding them would evaluate bit-identical
    /// duplicates.
    pub interconnect: Vec<(f64, f64)>,
    pub workload: Workload,
}

impl SweepSpec {
    /// 48-point grid for CI smoke runs and quick local sweeps.
    pub fn small(seed: u64) -> SweepSpec {
        SweepSpec {
            grid: "small".into(),
            schemes: vec!["naive".into(), "pattern".into()],
            ou: vec![(4, 4), (9, 8), (16, 8)],
            xbar: vec![(256, 256), (512, 512)],
            patterns: vec![4, 8],
            pruning: vec![0.70, 0.86],
            zero_detection: vec![true],
            block_switch: vec![2.0],
            cores: vec![1],
            interconnect: vec![(32.0, 4.0)],
            workload: Workload::small(seed),
        }
    }

    /// Wider grid: every mapping scheme, five OU shapes, three crossbar
    /// sizes, four pattern counts, five pruning rates (1200 points).
    pub fn medium(seed: u64) -> SweepSpec {
        SweepSpec {
            grid: "medium".into(),
            schemes: vec![
                "naive".into(),
                "pattern".into(),
                "kmeans".into(),
                "ou_sparse".into(),
            ],
            ou: vec![(4, 4), (8, 8), (9, 8), (16, 8), (32, 8)],
            xbar: vec![(128, 128), (256, 256), (512, 512)],
            patterns: vec![2, 4, 8, 12],
            pruning: vec![0.60, 0.70, 0.80, 0.86, 0.92],
            zero_detection: vec![true],
            block_switch: vec![2.0],
            cores: vec![1],
            interconnect: vec![(32.0, 4.0)],
            workload: Workload::small(seed),
        }
    }

    /// Stress grid for raw speed at DSE scale (~10^4 points): every
    /// scheme, six OU shapes, four crossbar sizes, five pattern counts,
    /// seven pruning rates, and both simulation-policy axes widened —
    /// 10920 points after the IPU collapse (840 for `naive`, 3360 for
    /// each IPU scheme). Geometry combinations a crossbar rejects
    /// (e.g. a 32-row OU on a 128-row array with tall cell stacking)
    /// are expanded and skipped, exercising the skip path at scale.
    pub fn large(seed: u64) -> SweepSpec {
        SweepSpec {
            grid: "large".into(),
            schemes: vec![
                "naive".into(),
                "pattern".into(),
                "kmeans".into(),
                "ou_sparse".into(),
            ],
            ou: vec![(4, 4), (8, 8), (9, 8), (16, 8), (16, 16), (32, 8)],
            xbar: vec![(128, 128), (256, 256), (512, 512), (1024, 1024)],
            patterns: vec![2, 4, 8, 12, 16],
            pruning: vec![0.60, 0.65, 0.70, 0.75, 0.80, 0.86, 0.92],
            zero_detection: vec![true, false],
            block_switch: vec![2.0, 8.0],
            cores: vec![1],
            interconnect: vec![(32.0, 4.0)],
            workload: Workload::small(seed),
        }
    }

    /// Widen the simulation-policy axes: zero-detection on *and* off,
    /// and the given block-switch costs (empty slices keep the current
    /// axis). Returns `self` for builder-style use.
    pub fn with_sim_axes(mut self, zero_detection: &[bool], block_switch: &[f64]) -> SweepSpec {
        if !zero_detection.is_empty() {
            self.zero_detection = zero_detection.to_vec();
        }
        if !block_switch.is_empty() {
            self.block_switch = block_switch.to_vec();
        }
        self
    }

    /// Widen the multi-core axes: core counts and `(bandwidth,
    /// hop_latency)` interconnect pairs (empty slices keep the current
    /// axis). Returns `self` for builder-style use.
    pub fn with_core_axes(
        mut self,
        cores: &[usize],
        interconnect: &[(f64, f64)],
    ) -> SweepSpec {
        if !cores.is_empty() {
            self.cores = cores.to_vec();
        }
        if !interconnect.is_empty() {
            self.interconnect = interconnect.to_vec();
        }
        self
    }

    pub fn by_name(name: &str, seed: u64) -> Option<SweepSpec> {
        match name {
            "small" => Some(SweepSpec::small(seed)),
            "medium" => Some(SweepSpec::medium(seed)),
            "large" => Some(SweepSpec::large(seed)),
            _ => None,
        }
    }

    /// Expand the axes into the full grid, scheme-major then OU, xbar,
    /// pattern count, pruning rate, zero-detection, block-switch cost,
    /// core count, interconnect innermost. The order is part of the
    /// determinism contract (frontier members are reported by index);
    /// the singleton simulation-policy and multi-core defaults keep the
    /// named grids' historical order and point counts. Schemes without
    /// an Input Preprocessing Unit ([`crate::sim::scheme_has_ipu`])
    /// ignore the simulation-policy knobs entirely, and single-core
    /// points ignore the interconnect knobs — in both cases the inert
    /// axes collapse to their leading value, because expanding them
    /// would evaluate bit-identical duplicates and report duplicate
    /// frontier members.
    pub fn expand(&self) -> Vec<SweepPoint> {
        let mut points = Vec::new();
        for scheme in &self.schemes {
            let ipu = crate::sim::scheme_has_ipu(scheme);
            let zd_axis: &[bool] = if ipu {
                &self.zero_detection
            } else {
                &self.zero_detection[..self.zero_detection.len().min(1)]
            };
            let bs_axis: &[f64] = if ipu {
                &self.block_switch
            } else {
                &self.block_switch[..self.block_switch.len().min(1)]
            };
            for &(ou_rows, ou_cols) in &self.ou {
                for &(xbar_rows, xbar_cols) in &self.xbar {
                    for &n_patterns in &self.patterns {
                        for &pruning in &self.pruning {
                            for &zero_detection in zd_axis {
                                for &block_switch_cycles in bs_axis {
                                    for &cores in &self.cores {
                                        let ic_axis: &[(f64, f64)] =
                                            if cores > 1 {
                                                &self.interconnect
                                            } else {
                                                &self.interconnect[..self
                                                    .interconnect
                                                    .len()
                                                    .min(1)]
                                            };
                                        for &(bw, hop) in ic_axis {
                                            points.push(SweepPoint {
                                                scheme: scheme.clone(),
                                                ou_rows,
                                                ou_cols,
                                                xbar_rows,
                                                xbar_cols,
                                                n_patterns,
                                                pruning,
                                                zero_detection,
                                                block_switch_cycles,
                                                cores,
                                                noc_bandwidth: bw,
                                                noc_hop_latency: hop,
                                            });
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        points
    }

    pub fn to_json(&self) -> Json {
        let pair =
            |v: &[(usize, usize)]| {
                Json::Arr(
                    v.iter()
                        .map(|(r, c)| Json::Arr(vec![(*r).into(), (*c).into()]))
                        .collect(),
                )
            };
        obj(vec![
            ("grid", self.grid.as_str().into()),
            (
                "schemes",
                Json::Arr(self.schemes.iter().map(|s| s.as_str().into()).collect()),
            ),
            ("ou", pair(&self.ou)),
            ("xbar", pair(&self.xbar)),
            (
                "patterns",
                Json::Arr(self.patterns.iter().map(|p| (*p).into()).collect()),
            ),
            (
                "pruning",
                Json::Arr(self.pruning.iter().map(|p| (*p).into()).collect()),
            ),
            (
                "zero_detection",
                Json::Arr(self.zero_detection.iter().map(|z| (*z).into()).collect()),
            ),
            (
                "block_switch",
                Json::Arr(self.block_switch.iter().map(|b| (*b).into()).collect()),
            ),
            (
                "cores",
                Json::Arr(self.cores.iter().map(|c| (*c).into()).collect()),
            ),
            (
                "interconnect",
                Json::Arr(
                    self.interconnect
                        .iter()
                        .map(|(b, h)| Json::Arr(vec![(*b).into(), (*h).into()]))
                        .collect(),
                ),
            ),
            ("workload", self.workload.to_json()),
        ])
    }
}

/// Metrics of one evaluated point — the three Pareto objectives (area
/// in provisioned cells, total energy, total cycles over the batch)
/// plus context.
#[derive(Debug, Clone, PartialEq)]
pub struct PointMetrics {
    /// Batch-total simulated cycles.
    pub cycles: f64,
    /// Batch-total energy (pJ).
    pub energy_pj: f64,
    /// Provisioned cells: crossbars × rows × cols. Comparable across
    /// crossbar geometries, unlike the raw crossbar count.
    pub area_cells: f64,
    pub crossbars: usize,
    /// Batch-total executed OU operations.
    pub ou_ops: f64,
    /// Used / provisioned cells.
    pub utilization: f64,
}

impl PointMetrics {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("cycles", self.cycles.into()),
            ("energy_pj", self.energy_pj.into()),
            ("area_cells", self.area_cells.into()),
            ("crossbars", self.crossbars.into()),
            ("ou_ops", self.ou_ops.into()),
            ("utilization", self.utilization.into()),
        ])
    }

    /// Inverse of [`PointMetrics::to_json`]; `None` on any missing or
    /// mistyped field (a corrupt cache entry falls back to a fresh
    /// evaluation).
    pub fn from_json(j: &Json) -> Option<PointMetrics> {
        Some(PointMetrics {
            cycles: j.get("cycles").as_f64()?,
            energy_pj: j.get("energy_pj").as_f64()?,
            area_cells: j.get("area_cells").as_f64()?,
            crossbars: j.get("crossbars").as_usize()?,
            ou_ops: j.get("ou_ops").as_f64()?,
            utilization: j.get("utilization").as_f64()?,
        })
    }
}

/// One point's sweep outcome: the metrics, or the reason the point was
/// skipped (invalid geometry, unknown scheme). `cache_hit` is runtime
/// bookkeeping only — it is deliberately absent from the frontier
/// artifact so cached and fresh sweeps emit identical bytes.
#[derive(Debug, Clone)]
pub struct PointResult {
    /// Index in the expanded grid (== position in the results vec).
    pub index: usize,
    pub point: SweepPoint,
    pub outcome: Result<PointMetrics, String>,
    pub cache_hit: bool,
}

impl PointResult {
    pub fn metrics(&self) -> Option<&PointMetrics> {
        self.outcome.as_ref().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_grid_expands_in_stable_order() {
        let spec = SweepSpec::small(42);
        let pts = spec.expand();
        assert_eq!(
            pts.len(),
            2 * 3 * 2 * 2 * 2 * 1 * 1,
            "48-point small grid (singleton sim-policy axes)"
        );
        // innermost multi-value axis varies fastest
        assert_eq!(pts[0].pruning, 0.70);
        assert_eq!(pts[1].pruning, 0.86);
        assert_eq!(pts[0].n_patterns, pts[1].n_patterns);
        // the named grids pin the simulator defaults on every point
        assert!(pts.iter().all(|p| p.zero_detection));
        assert!(pts.iter().all(|p| p.block_switch_cycles == 2.0));
        // ... and stay single-core on the hardware-default interconnect
        assert!(pts.iter().all(|p| p.cores == 1));
        assert!(pts.iter().all(|p| p.noc_bandwidth == 32.0));
        // scheme-major
        assert!(pts[..24].iter().all(|p| p.scheme == "naive"));
        assert!(pts[24..].iter().all(|p| p.scheme == "pattern"));
        // expansion is deterministic
        assert_eq!(pts, spec.expand());
    }

    #[test]
    fn sim_policy_axes_expand_innermost() {
        let spec = SweepSpec::small(42)
            .with_sim_axes(&[true, false], &[0.0, 2.0]);
        let pts = spec.expand();
        // naive has no IPU and ignores both knobs: its 24 base points
        // keep the leading axis values; pattern's 24 expand 2×2
        assert_eq!(pts.len(), 24 + 24 * 4, "IPU-only sim-axis expansion");
        let naive: Vec<&SweepPoint> =
            pts.iter().filter(|p| p.scheme == "naive").collect();
        assert_eq!(naive.len(), 24);
        assert!(naive
            .iter()
            .all(|p| p.zero_detection && p.block_switch_cycles == 0.0));
        let pat: Vec<&SweepPoint> =
            pts.iter().filter(|p| p.scheme == "pattern").collect();
        assert_eq!(pat.len(), 96);
        // block-switch is innermost, zero-detection just outside it
        assert!(pat[0].zero_detection && pat[0].block_switch_cycles == 0.0);
        assert!(pat[1].zero_detection && pat[1].block_switch_cycles == 2.0);
        assert!(!pat[2].zero_detection && pat[2].block_switch_cycles == 0.0);
        assert!(!pat[3].zero_detection && pat[3].block_switch_cycles == 2.0);
        assert_eq!(pat[0].pruning, pat[3].pruning);
        assert_ne!(pat[0].to_json(), pat[1].to_json(), "axes reach identity");
        // no two expanded points share an identity — the collapse
        // leaves no duplicate evaluations behind
        let ids: Vec<String> =
            pts.iter().map(|p| p.to_json().to_string_compact()).collect();
        let mut dedup = ids.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len(), "duplicate grid points");
        // empty slices keep the existing axes
        let kept = SweepSpec::small(42).with_sim_axes(&[], &[]);
        assert_eq!(kept.expand().len(), 48);
    }

    #[test]
    fn core_axes_expand_innermost_and_collapse_single_core() {
        let spec = SweepSpec::small(42)
            .with_core_axes(&[1, 2], &[(32.0, 4.0), (64.0, 1.0)]);
        let pts = spec.expand();
        // cores=1 collapses the interconnect axis (1 variant), cores=2
        // expands it (2 variants): 48 × 3
        assert_eq!(pts.len(), 48 * 3, "single-core interconnect collapse");
        // interconnect is innermost, cores just outside it
        assert!(pts[0].cores == 1 && pts[0].noc_bandwidth == 32.0);
        assert!(pts[1].cores == 2 && pts[1].noc_bandwidth == 32.0);
        assert!(pts[2].cores == 2 && pts[2].noc_bandwidth == 64.0);
        assert_eq!(pts[0].pruning, pts[2].pruning);
        // multi-core reaches the identity and the label
        assert_ne!(pts[0].to_json(), pts[1].to_json());
        assert!(pts[2].label().contains("c2 bw64"), "{}", pts[2].label());
        assert!(!pts[0].label().contains(" c1"), "single-core label stays");
        // no duplicate identities survive the collapse
        let ids: Vec<String> =
            pts.iter().map(|p| p.to_json().to_string_compact()).collect();
        let mut dedup = ids.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len(), "duplicate grid points");
        // empty slices keep the existing axes
        let kept = SweepSpec::small(42).with_core_axes(&[], &[]);
        assert_eq!(kept.expand().len(), 48);
    }

    #[test]
    fn large_grid_hits_dse_scale() {
        let spec = SweepSpec::large(42);
        let pts = spec.expand();
        // 6 ou × 4 xbar × 5 patterns × 7 pruning = 840 base points per
        // scheme; naive (no IPU) keeps the sim-policy singletons, the
        // three IPU schemes expand 2 × 2.
        assert_eq!(pts.len(), 840 + 3 * 840 * 4, "10920-point large grid");
        assert!(pts.len() >= 10_000, "the grid must reach DSE scale");
        assert_eq!(SweepSpec::by_name("large", 42), Some(spec));
        assert_eq!(SweepSpec::by_name("nope", 42), None);
    }

    #[test]
    fn point_hardware_validates_geometry() {
        let mut p = SweepPoint {
            scheme: "pattern".into(),
            ou_rows: 9,
            ou_cols: 8,
            xbar_rows: 256,
            xbar_cols: 256,
            n_patterns: 4,
            pruning: 0.8,
            zero_detection: true,
            block_switch_cycles: 2.0,
            cores: 1,
            noc_bandwidth: 32.0,
            noc_hop_latency: 4.0,
        };
        let hw = p.hardware().expect("valid point");
        assert_eq!(hw.ou_rows, 9);
        assert_eq!(hw.xbar_rows, 256);
        p.ou_rows = 1024; // OU taller than the crossbar
        assert!(p.hardware().is_err());
        p.ou_rows = 9;
        p.ou_cols = 3; // misaligned with 4 cells/weight
        assert!(p.hardware().is_err());
    }

    #[test]
    fn point_json_is_canonical() {
        let p = SweepPoint {
            scheme: "pattern".into(),
            ou_rows: 9,
            ou_cols: 8,
            xbar_rows: 512,
            xbar_cols: 512,
            n_patterns: 8,
            pruning: 0.86,
            zero_detection: true,
            block_switch_cycles: 2.0,
            cores: 1,
            noc_bandwidth: 32.0,
            noc_hop_latency: 4.0,
        };
        let s = p.to_json().to_string_compact();
        // BTreeMap ordering: stable bytes for the cache key
        assert_eq!(s, p.to_json().to_string_compact());
        assert!(s.contains("\"scheme\":\"pattern\""), "{s}");
        assert!(s.contains("\"zero_detection\":true"), "{s}");
        assert!(s.contains("\"block_switch_cycles\":2"), "{s}");
        assert!(p.label().contains("ou9x8"), "{}", p.label());
        assert!(p.label().contains("zd1"), "{}", p.label());
    }

    #[test]
    fn metrics_json_roundtrip() {
        let m = PointMetrics {
            cycles: 123456.75,
            energy_pj: 9.5e6,
            area_cells: 262144.0,
            crossbars: 1,
            ou_ops: 120000.0,
            utilization: 0.43,
        };
        let back = PointMetrics::from_json(&m.to_json()).expect("roundtrip");
        assert_eq!(m, back);
        assert!(PointMetrics::from_json(&Json::Null).is_none());
    }
}
